//! Offline stub of the `xla` (xla-rs) PJRT bindings.
//!
//! This build environment has no XLA/PJRT toolchain, so the real
//! bindings cannot link; this stub mirrors exactly the API surface
//! `platinum::runtime` uses and fails fast — `PjRtClient::cpu()` returns
//! an error — so every consumer degrades gracefully at runtime while
//! the crate stays compilable and testable offline.  Swap the `xla`
//! entry in Cargo.toml for the real bindings to enable artifact
//! execution; no call site changes.

use std::fmt;

/// Error type for all stubbed PJRT operations.
#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "{what}: PJRT unavailable — built against the offline `xla` stub \
         (substitute the real xla-rs bindings in rust/Cargo.toml to run artifacts)"
    )))
}

/// Stub PJRT client; [`PjRtClient::cpu`] always errors.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }
}

/// Stub HLO module handle.
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// Stub XLA computation handle.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Stub host literal.
pub struct Literal;

impl Literal {
    pub fn vec1<T>(_values: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        unavailable("Literal::reshape")
    }

    pub fn to_tuple1(&self) -> Result<Literal> {
        unavailable("Literal::to_tuple1")
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }
}

/// Stub device buffer.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// Stub loaded executable.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[Literal]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_fails_fast_with_actionable_message() {
        let err = PjRtClient::cpu().err().expect("stub must not pretend to work");
        let msg = err.to_string();
        assert!(msg.contains("stub") && msg.contains("PJRT"), "{msg}");
    }
}
