//! Offline drop-in subset of the `anyhow` error-handling crate.
//!
//! This environment builds with no network access, so the real crates.io
//! `anyhow` cannot be fetched; this vendored shim implements exactly the
//! surface the workspace uses — `Result`, `Error`, `anyhow!`, `bail!`,
//! and the `Context` extension trait (including context on an existing
//! `anyhow::Result`, via the same sealed-trait trick the real crate
//! uses).  Swapping back to crates.io `anyhow` is a one-line change in
//! Cargo.toml; no call site depends on anything beyond the real API.

use std::fmt::{self, Display};

/// `Result` defaulting the error type to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A message plus an optional boxed source chain.
pub struct Error {
    msg: String,
    source: Option<Box<dyn std::error::Error + Send + Sync + 'static>>,
}

impl Error {
    /// Create an error from a displayable message.
    pub fn msg<M: Display>(message: M) -> Error {
        Error { msg: message.to_string(), source: None }
    }

    fn from_parts(
        msg: String,
        source: Option<Box<dyn std::error::Error + Send + Sync + 'static>>,
    ) -> Error {
        Error { msg, source }
    }
}

impl Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)?;
        let mut source: Option<&(dyn std::error::Error + 'static)> = match &self.source {
            Some(b) => Some(b.as_ref()),
            None => None,
        };
        if source.is_some() {
            write!(f, "\n\nCaused by:")?;
        }
        while let Some(err) = source {
            write!(f, "\n    {err}")?;
            source = err.source();
        }
        Ok(())
    }
}

// Any std error converts via `?` (mirrors anyhow: `Error` itself never
// implements `std::error::Error`, which keeps this coherent with the
// blanket `From<T> for T`).
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(err: E) -> Error {
        Error::from_parts(err.to_string(), Some(Box::new(err)))
    }
}

/// Carrier for an `Error`'s payload once it is demoted into the source
/// chain of a wrapping context error.
struct ChainLink {
    msg: String,
    source: Option<Box<dyn std::error::Error + Send + Sync + 'static>>,
}

impl Display for ChainLink {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for ChainLink {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for ChainLink {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match &self.source {
            Some(b) => Some(b.as_ref()),
            None => None,
        }
    }
}

mod ext {
    use super::*;

    /// Sealed dispatch: "something that can absorb a context message" —
    /// implemented for std errors and for [`Error`] itself, which is how
    /// `.context(..)` works on both plain and already-`anyhow` results.
    pub trait StdError {
        fn ext_context<C: Display + Send + Sync + 'static>(self, context: C) -> Error;
    }

    impl<E: std::error::Error + Send + Sync + 'static> StdError for E {
        fn ext_context<C: Display + Send + Sync + 'static>(self, context: C) -> Error {
            Error::from_parts(context.to_string(), Some(Box::new(self)))
        }
    }

    impl StdError for Error {
        fn ext_context<C: Display + Send + Sync + 'static>(self, context: C) -> Error {
            Error::from_parts(
                context.to_string(),
                Some(Box::new(ChainLink { msg: self.msg, source: self.source })),
            )
        }
    }
}

/// Extension trait attaching context to `Result`/`Option` errors.
pub trait Context<T, E> {
    fn context<C: Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error>;
    fn with_context<C: Display + Send + Sync + 'static, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T, Error>;
}

impl<T, E: ext::StdError> Context<T, E> for Result<T, E> {
    fn context<C: Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.ext_context(context))
    }

    fn with_context<C: Display + Send + Sync + 'static, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T, Error> {
        self.map_err(|e| e.ext_context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: Display + Send + Sync + 'static, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Early-return with an [`Error`] built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($tok:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($tok)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse_fail() -> Result<i32> {
        let n: i32 = "notanumber".parse()?; // ParseIntError → Error
        Ok(n)
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let err = parse_fail().unwrap_err();
        assert!(err.to_string().contains("invalid digit"));
    }

    #[test]
    fn context_on_std_and_anyhow_results() {
        let base: Result<(), std::io::Error> =
            Err(std::io::Error::new(std::io::ErrorKind::Other, "disk on fire"));
        let wrapped = base.context("reading manifest").unwrap_err();
        assert_eq!(wrapped.to_string(), "reading manifest");
        let rewrapped: Result<()> = Err(wrapped);
        let twice = rewrapped.with_context(|| "loading artifacts").unwrap_err();
        assert_eq!(twice.to_string(), "loading artifacts");
        let dbg = format!("{twice:?}");
        assert!(dbg.contains("reading manifest") && dbg.contains("disk on fire"), "{dbg}");
    }

    #[test]
    fn macros_build_messages() {
        let name = "x";
        let e = anyhow!("missing {name:?} at {}", 7);
        assert_eq!(e.to_string(), "missing \"x\" at 7");
        fn bails() -> Result<()> {
            bail!("nope {}", 1)
        }
        assert_eq!(bails().unwrap_err().to_string(), "nope 1");
    }

    #[test]
    fn option_context() {
        let v: Option<u8> = None;
        assert_eq!(v.context("empty").unwrap_err().to_string(), "empty");
    }
}
