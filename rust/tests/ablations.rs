//! Design-choice ablations (DESIGN.md §IV): each test flips one design
//! knob of the paper and asserts the direction and rough magnitude of
//! the effect — the evidence behind Platinum's parameter choices.

use platinum::analysis::{adds_platinum, adds_ternary_lut, Gemm};
use platinum::config::{ExecMode, PlatinumConfig, Stationarity, Tiling};
use platinum::coordinator::DispatchPlan;
use platinum::models::{B158_3B, DECODE_N, PREFILL_N};
use platinum::sim::{simulate_gemm, simulate_model};
use platinum::util::{check_prop, rng::Rng};

fn cfg() -> PlatinumConfig {
    PlatinumConfig::default()
}

#[test]
fn ablation_fewer_ppes_cuts_throughput() {
    // §IV-A: L=52 chosen for throughput; halving L should roughly halve
    // steady-state throughput on large kernels.
    let g = Gemm::new(8640, 3200, 1024);
    let full = simulate_gemm(&cfg(), ExecMode::Ternary, g);
    let mut half = cfg();
    half.num_ppes = 26;
    half.tiling.k = 260; // keep chunk alignment
    let r = simulate_gemm(&half, ExecMode::Ternary, g);
    let ratio = full.throughput_gops / r.throughput_gops;
    assert!((1.6..=2.4).contains(&ratio), "L ablation ratio {ratio:.2}");
}

#[test]
fn ablation_ncols_1_hurts_everything() {
    // §IV-A: n_cols=8 amortizes construction across columns; a
    // single-column LUT design repeats construction per column.
    let g = Gemm::new(3200, 3200, 64);
    let full = simulate_gemm(&cfg(), ExecMode::Ternary, g);
    let mut narrow = cfg();
    narrow.n_cols = 1;
    let r = simulate_gemm(&narrow, ExecMode::Ternary, g);
    assert!(
        r.latency_s > full.latency_s * 3.0,
        "n_cols=1 only {:.2}x slower",
        r.latency_s / full.latency_s
    );
}

#[test]
fn ablation_single_lut_port_halves_query_rate() {
    // §IV-B: both LUT ports serve queries; one port ⇒ 1 row/cycle.
    let g = Gemm::new(1080, 520, 32);
    let dual = simulate_gemm(&cfg(), ExecMode::Ternary, g);
    let mut single = cfg();
    single.lut_ports = 1;
    let r = simulate_gemm(&single, ExecMode::Ternary, g);
    let ratio = r.phases.query as f64 / dual.phases.query as f64;
    assert!((1.9..=2.1).contains(&ratio), "port ablation {ratio:.2}");
}

#[test]
fn ablation_mirror_consolidation_halves_construction() {
    // §III-C: without mirror consolidation the ternary LUT stores 3^c
    // entries; Eq(2) vs Eq(3) at the construction-dominated regime.
    let g = Gemm::new(64, 3200, 1); // tiny M → construction dominates
    let with = adds_platinum(g, 5);
    let without = adds_ternary_lut(g, 5);
    assert!(
        without as f64 / with as f64 > 5.0,
        "mirror+path ablation only {:.2}x",
        without as f64 / with as f64
    );
}

#[test]
fn ablation_bitserial_planes_scale_cost() {
    // general-precision path: int4 (4 planes) costs ~2x int2 (2 planes)
    let g = Gemm::new(3200, 3200, 64);
    let p2 = simulate_gemm(&cfg(), ExecMode::BitSerial { planes: 2 }, g);
    let p4 = simulate_gemm(&cfg(), ExecMode::BitSerial { planes: 4 }, g);
    let ratio = p4.latency_s / p2.latency_s;
    assert!((1.6..=2.4).contains(&ratio), "plane scaling {ratio:.2}");
}

#[test]
fn ablation_decode_utilization_vs_prosperity_style_lanes() {
    // §V-C: Platinum's n_cols=8 matches decode N=8 exactly; a 64-wide
    // column design (Prosperity-style) would idle 7/8 of its lanes.
    // §IV-A: "for small N, large n_cols values cause resource
    // under-utilization" — wide lanes burn construct/reduce energy on
    // columns that don't exist at decode N=8 (latency is unchanged; the
    // waste shows up as energy per op and idle adders).
    let model = &B158_3B;
    let plat = simulate_model(&cfg(), ExecMode::Ternary, model, DECODE_N);
    let mut wide = cfg();
    wide.n_cols = 64; // hypothetical wide-lane Platinum
    let r = simulate_model(&wide, ExecMode::Ternary, model, DECODE_N);
    assert!(
        r.energy_j() > plat.energy_j() * 1.3,
        "wide lanes should waste energy at decode: {:.2}x",
        r.energy_j() / plat.energy_j()
    );
}

#[test]
fn ablation_stationarity_output_vs_weight() {
    // §IV-C: k-innermost (output stationary) avoids partial-sum spills;
    // weight-stationary orders pay 4-byte partial traffic per k step.
    let g = Gemm::new(8640, 8640, 1024);
    let mut out_st = cfg();
    out_st.tiling.order = Stationarity::Mnk;
    let mut w_st = cfg();
    w_st.tiling.order = Stationarity::Mkn;
    let a = simulate_gemm(&out_st, ExecMode::Ternary, g);
    let b = simulate_gemm(&w_st, ExecMode::Ternary, g);
    assert!(
        b.activity.dram_total_bytes() > a.activity.dram_total_bytes(),
        "weight-stationary should move more DRAM here"
    );
}

#[test]
fn throughput_plateaus_with_n() {
    // Platinum throughput grows with N then saturates near peak
    let mut last = 0.0;
    for n in [8, 32, 128, 1024] {
        let r = simulate_model(&cfg(), ExecMode::Ternary, &B158_3B, n);
        assert!(r.throughput_gops >= last * 0.98, "non-monotonic at N={n}");
        last = r.throughput_gops;
    }
    assert!(last > 1300.0 && last < 2081.0, "plateau {last:.0} outside peak bound");
}

#[test]
fn prop_tile_plans_cover_random_shapes() {
    check_prop("tile_coverage", 24, |seed| {
        let mut rng = Rng::seed_from(seed);
        let g = Gemm::new(
            1 + rng.below(4000) as usize,
            1 + rng.below(4000) as usize,
            1 + rng.below(1200) as usize,
        );
        let orders = Stationarity::ALL;
        let order = orders[rng.below(6) as usize];
        let t = Tiling { m: 1080, k: 520, n: 32, order };
        let plan = DispatchPlan::build(g, t);
        if !plan.validate_coverage() {
            return Err(format!("coverage failed for {g:?} {order:?}"));
        }
        Ok(())
    });
}

#[test]
fn prop_sim_energy_and_cycles_scale_with_work() {
    check_prop("sim_scaling", 12, |seed| {
        let mut rng = Rng::seed_from(seed);
        let m = 500 + rng.below(4000) as usize;
        let k = 500 + rng.below(4000) as usize;
        let n = 8 + rng.below(512) as usize;
        let g1 = Gemm::new(m, k, n);
        let g2 = Gemm::new(m * 2, k, n);
        let r1 = simulate_gemm(&cfg(), ExecMode::Ternary, g1);
        let r2 = simulate_gemm(&cfg(), ExecMode::Ternary, g2);
        if r2.cycles <= r1.cycles {
            return Err(format!("cycles not monotonic in M: {} vs {}", r1.cycles, r2.cycles));
        }
        if r2.energy_j() <= r1.energy_j() {
            return Err("energy not monotonic in M".into());
        }
        Ok(())
    });
}

#[test]
fn prefill_matches_table1_under_retiling() {
    // robustness: moderate tile-size changes keep throughput in band
    for (m, k) in [(1080, 520), (2160, 520), (1080, 1040)] {
        let mut c = cfg();
        c.tiling.m = m;
        c.tiling.k = k;
        let r = simulate_model(&c, ExecMode::Ternary, &B158_3B, PREFILL_N);
        assert!(
            r.throughput_gops > 1100.0,
            "tile m{m} k{k}: {:.0} GOP/s",
            r.throughput_gops
        );
    }
}
