//! Engine API contract tests: registry round-trips, unified-report JSON
//! golden output, and equivalence pins tying `Backend::run` on
//! `Workload::ModelPass` to the legacy `simulate_model` /
//! `model_report` aggregation it replaced.

use platinum::analysis::Gemm;
use platinum::baselines::{eyeriss, prosperity, tmac};
use platinum::config::{ExecMode, PlatinumConfig};
use platinum::engine::{Backend, Registry, Report, Stage, Workload, COMPARISON_IDS};
use platinum::models::{B158_3B, DECODE_N, PREFILL_N};
use platinum::sim::simulate_model;
use platinum::util::json::Json;

fn run(id: &str, w: &Workload) -> Report {
    Registry::with_defaults().build(id).unwrap().run(w)
}

// ---------------------------------------------------------------------------
// registry round-trip
// ---------------------------------------------------------------------------

#[test]
fn every_registered_backend_runs_a_kernel() {
    let reg = Registry::with_defaults();
    let g = Gemm::new(128, 65, 8);
    assert!(
        reg.ids().len() >= 7,
        "expected all five systems + tmac-cpu + platinum-cpu"
    );
    for id in reg.ids() {
        let be = reg.build(id).unwrap();
        let r = be.run(&Workload::Kernel(g));
        assert_eq!(r.backend, id);
        assert_eq!(r.workload, "gemm-128x65x8");
        assert_eq!(r.ops, g.naive_adds());
        assert!(r.latency_s > 0.0 && r.throughput_gops > 0.0, "{id}");
    }
}

#[test]
fn all_five_comparison_systems_run_model_passes() {
    // acceptance: platinum-ternary, platinum-bitserial, eyeriss,
    // prosperity, tmac all runnable through Registry/Backend::run
    let reg = Registry::with_defaults();
    for be in reg.build_selection(COMPARISON_IDS).unwrap() {
        let r = be.run(&Workload::decode(B158_3B));
        assert!(r.latency_s > 0.0 && r.energy_j.unwrap() > 0.0, "{}", be.id());
        assert_eq!(r.workload, "b1.58-3B-decode-n8");
    }
}

#[test]
fn platinum_cpu_backend_is_selectable_and_measured() {
    // acceptance: the golden datapath runs for real behind `--backend
    // platinum-cpu`, reporting measured latency and null energy
    let reg = Registry::with_defaults();
    let be = reg.build("platinum-cpu").unwrap();
    assert_eq!(be.describe().id, "platinum-cpu");
    let r = be.run(&Workload::Kernel(Gemm::new(96, 70, 8)));
    assert_eq!(r.backend, "platinum-cpu");
    assert!(r.latency_s > 0.0 && r.throughput_gops > 0.0);
    assert_eq!(r.energy_j, None, "measured backend must not fake energy");
    let j = Json::parse(&r.to_json().to_string()).unwrap();
    assert_eq!(j.get("energy_j"), Some(&Json::Null));
    assert_eq!(j.get("power_w"), Some(&Json::Null));
    assert!(j.get("latency_s").and_then(Json::as_f64).unwrap() > 0.0);
}

// ---------------------------------------------------------------------------
// Report::to_json golden output
// ---------------------------------------------------------------------------

#[test]
fn report_json_golden() {
    let r = Report {
        backend: "tmac".into(),
        workload: "b1.58-3B-decode-n8".into(),
        latency_s: 0.25,
        energy_j: Some(8.0),
        throughput_gops: 2.5,
        ops: 4096,
        ..Report::default()
    };
    assert_eq!(
        r.to_json().to_string(),
        "{\"backend\":\"tmac\",\"energy_j\":8,\"latency_s\":0.25,\"ops\":4096,\
         \"power_w\":32,\"throughput_gops\":2.5,\"workload\":\"b1.58-3B-decode-n8\"}"
    );
}

#[test]
fn live_report_json_parses_with_detail_sections() {
    let r = run("platinum-ternary", &Workload::Kernel(Gemm::new(1080, 520, 32)));
    let j = Json::parse(&r.to_json().to_string()).unwrap();
    assert_eq!(j.get("backend").unwrap().as_str(), Some("platinum-ternary"));
    for key in ["latency_s", "energy_j", "power_w", "throughput_gops", "cycles"] {
        assert!(j.get(key).and_then(Json::as_f64).unwrap() > 0.0, "{key}");
    }
    for section in ["phases", "activity", "energy_breakdown_j", "utilization"] {
        assert!(j.get(section).is_some(), "missing {section}");
    }
    assert_eq!(
        j.get("cycles").unwrap().as_f64().unwrap(),
        r.cycles.unwrap() as f64
    );
}

// ---------------------------------------------------------------------------
// equivalence pins vs the legacy aggregation
// ---------------------------------------------------------------------------

fn close(a: f64, b: f64) -> bool {
    (a - b).abs() <= b.abs() * 1e-12
}

#[test]
fn platinum_model_pass_pins_legacy_simulate_model() {
    for (mode_id, mode, retile_k) in [
        ("platinum-ternary", ExecMode::Ternary, None),
        ("platinum-bitserial", ExecMode::BitSerial { planes: 2 }, Some(728)),
    ] {
        for n in [PREFILL_N, DECODE_N] {
            let r = run(mode_id, &Workload::model_pass(B158_3B, n));
            let mut cfg = PlatinumConfig::default();
            if let Some(k) = retile_k {
                cfg.tiling.k = k;
            }
            let legacy = simulate_model(&cfg, mode, &B158_3B, n);
            assert_eq!(r.cycles, Some(legacy.cycles), "{mode_id} n={n} cycles");
            assert!(close(r.latency_s, legacy.latency_s), "{mode_id} n={n} latency");
            assert!(close(r.energy_j.unwrap(), legacy.energy_j()), "{mode_id} n={n} energy");
            assert!(
                close(r.throughput_gops, legacy.throughput_gops),
                "{mode_id} n={n} throughput"
            );
            let ph = r.phases.expect("detail");
            assert_eq!(ph.busy(), legacy.phases.busy(), "{mode_id} n={n} phases");
        }
    }
}

#[test]
#[allow(deprecated)]
fn baseline_model_passes_pin_legacy_model_report() {
    use platinum::baselines::model_report;
    type Sim = fn(Gemm, usize) -> platinum::baselines::BaselineReport;
    let eye: Sim = eyeriss::simulate;
    let pro: Sim = prosperity::simulate;
    for (id, f) in [("eyeriss", eye), ("prosperity", pro)] {
        for n in [PREFILL_N, DECODE_N] {
            let r = run(id, &Workload::model_pass(B158_3B, n));
            let legacy = model_report(&B158_3B, n, |g| f(g, n));
            assert!(close(r.latency_s, legacy.latency_s), "{id} n={n} latency");
            assert!(close(r.energy_j.unwrap(), legacy.energy_j), "{id} n={n} energy");
            assert!(
                close(r.throughput_gops, legacy.throughput_gops),
                "{id} n={n} throughput"
            );
        }
    }
    let r = run("tmac", &Workload::prefill(B158_3B));
    let legacy = model_report(&B158_3B, PREFILL_N, tmac::simulate_m2pro);
    assert!(
        close(r.latency_s, legacy.latency_s) && close(r.energy_j.unwrap(), legacy.energy_j)
    );
}

#[test]
fn stage_and_n_agree_on_paper_operating_points() {
    assert_eq!(Stage::Prefill.default_n(), PREFILL_N);
    assert_eq!(Stage::Decode.default_n(), DECODE_N);
    match Workload::prefill(B158_3B) {
        Workload::ModelPass { n, stage, .. } => {
            assert_eq!((n, stage), (PREFILL_N, Stage::Prefill));
        }
        _ => panic!("prefill() must build a model pass"),
    }
}
