//! Engine API contract tests: registry round-trips, unified-report JSON
//! golden output, equivalence pins tying `Backend::run` on
//! `Workload::ModelPass` to the legacy `simulate_model` /
//! `model_report` aggregation it replaced, and the sharded multi-chip
//! composite's partition/aggregation contract.

use platinum::analysis::Gemm;
use platinum::baselines::{eyeriss, prosperity, tmac};
use platinum::config::{ExecMode, PlatinumConfig};
use platinum::encoding::pack_ternary;
use platinum::engine::{
    Backend, PlatinumBackend, Registry, Report, ShardStrategy, Sharded, Stage, Workload,
    COMPARISON_IDS, SHARDED_GRAMMAR,
};
use platinum::lut::ternary_mpgemm;
use platinum::models::{B158_3B, DECODE_N, PREFILL_N};
use platinum::sim::simulate_model;
use platinum::util::json::Json;
use platinum::util::rng::Rng;

fn run(id: &str, w: &Workload) -> Report {
    Registry::with_defaults().build(id).unwrap().run(w)
}

// ---------------------------------------------------------------------------
// registry round-trip
// ---------------------------------------------------------------------------

#[test]
fn every_registered_backend_runs_a_kernel() {
    let reg = Registry::with_defaults();
    let g = Gemm::new(128, 65, 8);
    assert!(
        reg.ids().len() >= 7,
        "expected all five systems + tmac-cpu + platinum-cpu"
    );
    for id in reg.ids() {
        let be = reg.build(id).unwrap();
        let r = be.run(&Workload::Kernel(g));
        assert_eq!(r.backend, id);
        assert_eq!(r.workload, "gemm-128x65x8");
        assert_eq!(r.ops, g.naive_adds());
        assert!(r.latency_s > 0.0 && r.throughput_gops > 0.0, "{id}");
    }
}

#[test]
fn all_five_comparison_systems_run_model_passes() {
    // acceptance: platinum-ternary, platinum-bitserial, eyeriss,
    // prosperity, tmac all runnable through Registry/Backend::run
    let reg = Registry::with_defaults();
    for be in reg.build_selection(COMPARISON_IDS).unwrap() {
        let r = be.run(&Workload::decode(B158_3B));
        assert!(r.latency_s > 0.0 && r.energy_j.unwrap() > 0.0, "{}", be.id());
        assert_eq!(r.workload, "b1.58-3B-decode-n8");
    }
}

#[test]
fn platinum_cpu_backend_is_selectable_and_measured() {
    // acceptance: the golden datapath runs for real behind `--backend
    // platinum-cpu`, reporting measured latency and null energy
    let reg = Registry::with_defaults();
    let be = reg.build("platinum-cpu").unwrap();
    assert_eq!(be.describe().id, "platinum-cpu");
    let r = be.run(&Workload::Kernel(Gemm::new(96, 70, 8)));
    assert_eq!(r.backend, "platinum-cpu");
    assert!(r.latency_s > 0.0 && r.throughput_gops > 0.0);
    assert_eq!(r.energy_j, None, "measured backend must not fake energy");
    let j = Json::parse(&r.to_json().to_string()).unwrap();
    assert_eq!(j.get("energy_j"), Some(&Json::Null));
    assert_eq!(j.get("power_w"), Some(&Json::Null));
    assert!(j.get("latency_s").and_then(Json::as_f64).unwrap() > 0.0);
}

// ---------------------------------------------------------------------------
// Report::to_json golden output
// ---------------------------------------------------------------------------

#[test]
fn report_json_golden() {
    let r = Report {
        backend: "tmac".into(),
        workload: "b1.58-3B-decode-n8".into(),
        latency_s: 0.25,
        energy_j: Some(8.0),
        throughput_gops: 2.5,
        ops: 4096,
        ..Report::default()
    };
    assert_eq!(
        r.to_json().to_string(),
        "{\"backend\":\"tmac\",\"energy_j\":8,\"latency_s\":0.25,\"ops\":4096,\
         \"power_w\":32,\"throughput_gops\":2.5,\"workload\":\"b1.58-3B-decode-n8\"}"
    );
}

#[test]
fn live_report_json_parses_with_detail_sections() {
    let r = run("platinum-ternary", &Workload::Kernel(Gemm::new(1080, 520, 32)));
    let j = Json::parse(&r.to_json().to_string()).unwrap();
    assert_eq!(j.get("backend").unwrap().as_str(), Some("platinum-ternary"));
    for key in ["latency_s", "energy_j", "power_w", "throughput_gops", "cycles"] {
        assert!(j.get(key).and_then(Json::as_f64).unwrap() > 0.0, "{key}");
    }
    for section in ["phases", "activity", "energy_breakdown_j", "utilization"] {
        assert!(j.get(section).is_some(), "missing {section}");
    }
    assert_eq!(
        j.get("cycles").unwrap().as_f64().unwrap(),
        r.cycles.unwrap() as f64
    );
}

// ---------------------------------------------------------------------------
// equivalence pins vs the legacy aggregation
// ---------------------------------------------------------------------------

fn close(a: f64, b: f64) -> bool {
    (a - b).abs() <= b.abs() * 1e-12
}

#[test]
fn platinum_model_pass_pins_legacy_simulate_model() {
    for (mode_id, mode, retile_k) in [
        ("platinum-ternary", ExecMode::Ternary, None),
        ("platinum-bitserial", ExecMode::BitSerial { planes: 2 }, Some(728)),
    ] {
        for n in [PREFILL_N, DECODE_N] {
            let r = run(mode_id, &Workload::model_pass(B158_3B, n));
            let mut cfg = PlatinumConfig::default();
            if let Some(k) = retile_k {
                cfg.tiling.k = k;
            }
            let legacy = simulate_model(&cfg, mode, &B158_3B, n);
            assert_eq!(r.cycles, Some(legacy.cycles), "{mode_id} n={n} cycles");
            assert!(close(r.latency_s, legacy.latency_s), "{mode_id} n={n} latency");
            assert!(close(r.energy_j.unwrap(), legacy.energy_j()), "{mode_id} n={n} energy");
            assert!(
                close(r.throughput_gops, legacy.throughput_gops),
                "{mode_id} n={n} throughput"
            );
            let ph = r.phases.expect("detail");
            assert_eq!(ph.busy(), legacy.phases.busy(), "{mode_id} n={n} phases");
        }
    }
}

#[test]
#[allow(deprecated)]
fn baseline_model_passes_pin_legacy_model_report() {
    use platinum::baselines::model_report;
    type Sim = fn(Gemm, usize) -> platinum::baselines::BaselineReport;
    let eye: Sim = eyeriss::simulate;
    let pro: Sim = prosperity::simulate;
    for (id, f) in [("eyeriss", eye), ("prosperity", pro)] {
        for n in [PREFILL_N, DECODE_N] {
            let r = run(id, &Workload::model_pass(B158_3B, n));
            let legacy = model_report(&B158_3B, n, |g| f(g, n));
            assert!(close(r.latency_s, legacy.latency_s), "{id} n={n} latency");
            assert!(close(r.energy_j.unwrap(), legacy.energy_j), "{id} n={n} energy");
            assert!(
                close(r.throughput_gops, legacy.throughput_gops),
                "{id} n={n} throughput"
            );
        }
    }
    let r = run("tmac", &Workload::prefill(B158_3B));
    let legacy = model_report(&B158_3B, PREFILL_N, tmac::simulate_m2pro);
    assert!(
        close(r.latency_s, legacy.latency_s) && close(r.energy_j.unwrap(), legacy.energy_j)
    );
}

// ---------------------------------------------------------------------------
// sharded multi-chip composite
// ---------------------------------------------------------------------------

/// `sharded:N:platinum-ternary` built straight from the registry.
fn sharded_platinum(n: usize) -> Box<dyn Backend> {
    Registry::with_defaults().build(&format!("sharded:{n}:platinum-ternary")).unwrap()
}

#[test]
fn sharded_single_replica_is_bit_exact_with_inner() {
    // acceptance: 1 replica ≡ the inner backend — not approximately,
    // bit-exactly (passthrough partition, zero merge term)
    let sh = sharded_platinum(1);
    let inner = PlatinumBackend::ternary();
    for w in [
        Workload::Kernel(Gemm::new(1080, 520, 32)),
        Workload::model_pass(B158_3B, DECODE_N),
        Workload::Batch(vec![Gemm::new(64, 40, 8), Gemm::new(16, 40, 8)]),
    ] {
        let a = sh.run(&w);
        let b = inner.run(&w);
        assert_eq!(a.backend, "sharded:1:platinum-ternary");
        assert_eq!(a.latency_s.to_bits(), b.latency_s.to_bits(), "{}", w.label());
        assert_eq!(a.energy_j.unwrap().to_bits(), b.energy_j.unwrap().to_bits(), "{}", w.label());
        assert_eq!(a.throughput_gops.to_bits(), b.throughput_gops.to_bits());
        assert_eq!(a.cycles, b.cycles);
        assert_eq!(a.ops, b.ops);
        assert_eq!(a.phases, b.phases);
    }
}

#[test]
fn sharded_latency_is_max_plus_merge_energy_is_sum() {
    // acceptance: the aggregation rules, verified against manual
    // per-shard runs through the public partition()/merge_latency_s()
    let chips: Vec<Box<dyn Backend>> = (0..4)
        .map(|_| Box::new(PlatinumBackend::ternary()) as Box<dyn Backend>)
        .collect();
    let sh = Sharded::new(chips, ShardStrategy::Rows).unwrap();
    let inner = PlatinumBackend::ternary();
    let w = Workload::Kernel(Gemm::new(1080, 520, 32));
    let shards = sh.partition(&w);
    assert_eq!(shards.len(), 4);
    let parts: Vec<Report> = shards.iter().map(|s| inner.run(s)).collect();
    let max_lat = parts.iter().map(|r| r.latency_s).fold(0.0f64, f64::max);
    let sum_energy: f64 = parts.iter().map(|r| r.energy_j.unwrap()).sum();
    let r = sh.run(&w);
    let expect_lat = max_lat + sh.merge_latency_s(&w, 4);
    assert!((r.latency_s - expect_lat).abs() <= expect_lat * 1e-12, "max+merge rule");
    assert!((r.energy_j.unwrap() - sum_energy).abs() <= sum_energy * 1e-12, "sum rule");
    assert_eq!(r.ops, w.naive_adds());
    assert_eq!(r.cycles, parts.iter().map(|p| p.cycles.unwrap()).max());
}

#[test]
fn sharded_handles_more_replicas_than_rows() {
    // 8 chips, 3 output rows: 3 active shards, 5 idle chips — the
    // composite must not fabricate work or divide by the idle count
    let sh = sharded_platinum(8);
    let g = Gemm::new(3, 40, 8);
    let r = sh.run(&Workload::Kernel(g));
    assert_eq!(r.ops, g.naive_adds());
    assert!(r.latency_s > 0.0 && r.throughput_gops > 0.0);
    let single = PlatinumBackend::ternary().run(&Workload::Kernel(g));
    // rows can't shrink below one per chip; per-shard construct is
    // replicated, so tiny kernels gain nothing — but the aggregate must
    // stay within the per-shard latency + merge envelope
    assert!(r.latency_s >= single.latency_s / 3.0);
}

#[test]
fn sharded_ragged_row_split_covers_every_row() {
    // m=10 over 4 chips → stripes 3,3,2,2: every row assigned exactly
    // once, cross-shard adds equal to the whole kernel's
    let chips: Vec<Box<dyn Backend>> = (0..4)
        .map(|_| Box::new(PlatinumBackend::ternary()) as Box<dyn Backend>)
        .collect();
    let sh = Sharded::new(chips, ShardStrategy::Rows).unwrap();
    let g = Gemm::new(10, 20, 8);
    let shards = sh.partition(&Workload::Kernel(g));
    let ms: Vec<usize> = shards.iter().flat_map(|s| s.kernels()).map(|(sg, _)| sg.m).collect();
    assert_eq!(ms, vec![3, 3, 2, 2]);
    let r = sh.run(&Workload::Kernel(g));
    assert_eq!(r.ops, g.naive_adds(), "ragged split must not drop rows");
}

#[test]
fn sharded_batch_with_empty_shards() {
    // 2 batch entries over 4 chips under the batch strategy: two chips
    // idle, nothing lost, energy still the sum of the active pair
    let reg = Registry::with_defaults();
    let sh = reg.build("sharded:4:batch:platinum-ternary").unwrap();
    let g1 = Gemm::new(64, 40, 8);
    let g2 = Gemm::new(32, 40, 8);
    let w = Workload::Batch(vec![g1, g2]);
    let r = sh.run(&w);
    assert_eq!(r.ops, w.naive_adds());
    let inner = PlatinumBackend::ternary();
    let (a, b) = (inner.run(&Workload::Kernel(g1)), inner.run(&Workload::Kernel(g2)));
    let sum_energy = a.energy_j.unwrap() + b.energy_j.unwrap();
    assert!((r.energy_j.unwrap() - sum_energy).abs() <= sum_energy * 1e-12);
    // an entirely empty batch degenerates to a zero report, not a panic
    let empty = sh.run(&Workload::Batch(Vec::new()));
    assert_eq!(empty.ops, 0);
    assert_eq!(empty.latency_s, 0.0);
    assert_eq!(empty.energy_j, Some(0.0));
}

#[test]
fn sharded_registry_roundtrip_and_json_golden() {
    // acceptance: a sharded:* id round-trips through the registry and
    // its Report serializes through the same unified JSON surface
    let reg = Registry::with_defaults();
    let be = reg.build("sharded:4:platinum-ternary").unwrap();
    assert_eq!(be.id(), "sharded:4:platinum-ternary");
    assert_eq!(be.describe().id, "sharded:4:platinum-ternary");
    let r = be.run(&Workload::decode(B158_3B));
    let j = Json::parse(&r.to_json().to_string()).unwrap();
    assert_eq!(j.get("backend").unwrap().as_str(), Some("sharded:4:platinum-ternary"));
    assert_eq!(j.get("workload").unwrap().as_str(), Some("b1.58-3B-decode-n8"));
    for key in ["latency_s", "energy_j", "power_w", "throughput_gops"] {
        assert!(j.get(key).and_then(Json::as_f64).unwrap() > 0.0, "{key}");
    }
    // fixed-shape golden for the scalar prefix of a sharded report
    let golden = Report {
        backend: "sharded:2:eyeriss".into(),
        workload: "gemm-8x8x8".into(),
        latency_s: 0.5,
        energy_j: Some(2.0),
        throughput_gops: 1.0,
        ops: 512,
        ..Report::default()
    };
    assert_eq!(
        golden.to_json().to_string(),
        "{\"backend\":\"sharded:2:eyeriss\",\"energy_j\":2,\"latency_s\":0.5,\
         \"ops\":512,\"power_w\":4,\"throughput_gops\":1,\"workload\":\"gemm-8x8x8\"}"
    );
}

#[test]
fn sharded_platinum_cpu_null_energy_json_golden() {
    // golden-JSON pin for the measured-backend + sharding composition:
    // a sharded report whose inner backend is the measured platinum-cpu
    // kernel serializes energy_j AND power_w as JSON null (never 0.0),
    // with the scalar key order unchanged.  Fixed-field golden first —
    // latency of a live run is machine-dependent, serialization is not.
    let golden = Report {
        backend: "sharded:2:platinum-cpu".into(),
        workload: "gemm-64x40x8".into(),
        latency_s: 0.5,
        energy_j: None,
        throughput_gops: 2.0,
        ops: 20480,
        ..Report::default()
    };
    assert_eq!(
        golden.to_json().to_string(),
        "{\"backend\":\"sharded:2:platinum-cpu\",\"energy_j\":null,\"latency_s\":0.5,\
         \"ops\":20480,\"power_w\":null,\"throughput_gops\":2,\"workload\":\"gemm-64x40x8\"}"
    );
    // and the live composition produces exactly that shape: measured
    // latency, null energy, same workload label and op count
    let reg = Registry::with_defaults();
    let be = reg.build("sharded:2:platinum-cpu").unwrap();
    let g = Gemm::new(64, 40, 8);
    let r = be.run(&Workload::Kernel(g));
    assert_eq!(r.backend, "sharded:2:platinum-cpu");
    assert_eq!(r.workload, "gemm-64x40x8");
    assert_eq!(r.ops, g.naive_adds());
    assert_eq!(r.energy_j, None);
    let j = Json::parse(&r.to_json().to_string()).unwrap();
    assert_eq!(j.get("energy_j"), Some(&Json::Null));
    assert_eq!(j.get("power_w"), Some(&Json::Null));
    assert!(j.get("latency_s").and_then(Json::as_f64).unwrap() > 0.0);
    assert_eq!(j.get("ops").and_then(Json::as_usize), Some(20480));
}

#[test]
fn sharded_preserves_energy_null_propagation() {
    // a measured inner backend (energy unmodelled) must surface as
    // null through the composite, never a fabricated 0.0
    let reg = Registry::with_defaults();
    let be = reg.build("sharded:2:platinum-cpu").unwrap();
    let r = be.run(&Workload::Kernel(Gemm::new(64, 40, 8)));
    assert!(r.latency_s > 0.0);
    assert_eq!(r.energy_j, None);
    let j = Json::parse(&r.to_json().to_string()).unwrap();
    assert_eq!(j.get("energy_j"), Some(&Json::Null));
}

#[test]
fn unknown_backend_error_teaches_the_sharded_grammar() {
    // satellite fix: the error text must list the fixed ids AND the
    // parameterized sharded form
    let err = Registry::with_defaults().build("tpu-v6").unwrap_err().to_string();
    assert!(err.contains("platinum-ternary") && err.contains("tmac-cpu"), "{err}");
    assert!(err.contains(SHARDED_GRAMMAR), "{err}");
}

#[test]
fn row_sharding_is_functionally_lossless() {
    // acceptance: the functional path — run the golden datapath on
    // row-partitioned weights and stitch the stripes; the result must
    // equal the unsharded output bit-for-bit
    let (m, k, n) = (37, 43, 5); // deliberately ragged everywhere
    let cfg = PlatinumConfig::default();
    let mut rng = Rng::seed_from(0x5AAD);
    let w = rng.ternary_vec(m * k);
    let x = rng.act_vec(k * n);
    let full = ternary_mpgemm(&cfg, &pack_ternary(&w, m, k, cfg.c_ternary), &x, n).0;
    let replicas = 4;
    let mut stitched = Vec::with_capacity(m * n);
    let base = m / replicas;
    let rem = m % replicas;
    let mut row = 0;
    for i in 0..replicas {
        let rows = base + usize::from(i < rem);
        let shard_w = &w[row * k..(row + rows) * k];
        let part = ternary_mpgemm(&cfg, &pack_ternary(shard_w, rows, k, cfg.c_ternary), &x, n).0;
        stitched.extend_from_slice(&part);
        row += rows;
    }
    assert_eq!(row, m);
    assert_eq!(stitched, full, "stitched row shards must equal the unsharded output");
}

#[test]
fn stage_and_n_agree_on_paper_operating_points() {
    assert_eq!(Stage::Prefill.default_n(), PREFILL_N);
    assert_eq!(Stage::Decode.default_n(), DECODE_N);
    match Workload::prefill(B158_3B) {
        Workload::ModelPass { n, stage, .. } => {
            assert_eq!((n, stage), (PREFILL_N, Stage::Prefill));
        }
        _ => panic!("prefill() must build a model pass"),
    }
}
