//! Traffic-subsystem contract tests (ISSUE 5 acceptance): virtual-clock
//! determinism (same seed ⇒ byte-identical metrics JSON), invariance of
//! scheduler decisions and metrics across worker-pool sizes {1, 8}
//! while real golden-datapath work runs inside the loop, bounded
//! deadlock-free behavior past saturation, and the batch-size-vs-load
//! saturation curve.
//!
//! ISSUE 7 extends the contract to degraded runs: a fault plan + a
//! resilience config must keep the same byte-identity guarantees
//! (per-seed, across pool sizes), `Sharded` failover must lose no
//! sequences, the clean-run JSON schema must not grow, and an executor
//! panic must propagate without wedging the pool or the scheduler.

use platinum::config::PlatinumConfig;
use platinum::coordinator::serve::GoldenExecutor;
use platinum::encoding::pack_ternary;
use platinum::engine::{Backend, PlatinumBackend, Registry, Workload};
use platinum::fault::{FaultPlan, ResilienceConfig};
use platinum::kv::{KvConfig, KvPolicy};
use platinum::lut::ternary_mpgemm_pool;
use platinum::models::BitNetModel;
use platinum::runtime::pool::Pool;
use platinum::traffic::{
    decode_capacity_tok_s, with_shared_prefix, ArrivalPattern, ExecutorBridge, LenDist, LoadSpec,
    Outcome, PushSource, Scheduler, SchedulerConfig, StepKind, StepRecord, TenantMix,
    TrafficRequest, VirtualClock,
};
use platinum::util::json::Json;
use platinum::util::rng::Rng;
use std::panic::{catch_unwind, AssertUnwindSafe};

/// 2-layer toy model: modelled pricing stays microseconds-fast and the
/// functional golden work in the pool-invariance tests stays tiny.
const TINY: BitNetModel = BitNetModel {
    name: "tiny",
    params: "2M",
    hidden: 64,
    ffn: 160,
    heads: 4,
    kv_heads: 4,
    layers: 2,
};

fn poisson_spec(rate: f64, requests: usize, seed: u64) -> LoadSpec {
    LoadSpec {
        pattern: ArrivalPattern::Poisson { rate_rps: rate },
        prompt: LenDist::Uniform { lo: 4, hi: 12 },
        output: LenDist::Fixed(6),
        requests,
        seed,
    }
}

/// Requests/s one `max_batch`-wide decode step can sustain on the
/// modelled backend, for placing rates relative to the knee.
fn capacity_rps(be: &dyn Backend, cfg: &SchedulerConfig, output_tokens: usize) -> f64 {
    decode_capacity_tok_s(be, TINY, cfg.max_batch) / output_tokens as f64
}

#[test]
fn virtual_clock_metrics_are_byte_identical_per_seed() {
    let be = PlatinumBackend::ternary();
    let sched = Scheduler::new(&be, TINY, SchedulerConfig::default());
    let run = |seed: u64| {
        let reqs = poisson_spec(150.0, 64, seed).generate().unwrap();
        let r = sched.serve(&reqs, &mut VirtualClock::new()).unwrap();
        r.metrics.to_json().to_string()
    };
    let a = run(42);
    assert_eq!(a, run(42), "same seed + same rate must serialize byte-identical");
    assert_ne!(a, run(43), "a different seed must move the metrics");
    // and the JSON is well-formed with the advertised headline fields
    let doc = Json::parse(&a).unwrap();
    let ttft = doc.get("latency_s").unwrap().get("ttft").unwrap();
    let p99 = ttft.get("p99").unwrap().as_f64().unwrap();
    assert!(p99.is_finite() && p99 > 0.0);
    let goodput = doc.get("throughput").unwrap().get("goodput_tokens_per_s").unwrap();
    assert!(goodput.as_f64().unwrap() > 0.0);
    let depth = doc.get("series").unwrap().get("queue_depth").unwrap();
    assert!(depth.as_arr().unwrap().len() > 1);
    // the kv section rides inside the same byte-identical document
    let kv = doc.get("kv").unwrap();
    assert!(kv.get("capacity_blocks").unwrap().as_f64().unwrap() > 0.0);
    assert!(kv.get("allocated_blocks_max").unwrap().as_f64().unwrap() > 0.0);
    assert_eq!(kv.get("evictions").unwrap().as_f64(), Some(0.0), "ample capacity");
    assert!(kv.get("prefix_cache").unwrap().get("lookups").is_some());
    assert!(kv.get("dram").unwrap().get("model").unwrap().as_str().is_some());
}

#[test]
fn metrics_and_decisions_invariant_across_pool_sizes_1_and_8() {
    // real golden-datapath GEMMs execute on an explicit worker pool
    // inside every scheduler step; the virtual timeline is priced by
    // the deterministic model, so pool size {1, 8} must not move a
    // single byte of the metrics or a single scheduling decision
    let cfg = SchedulerConfig { max_batch: 8, ..SchedulerConfig::default() };
    let run = |threads: usize| -> (String, Vec<StepRecord>) {
        let be = PlatinumBackend::ternary();
        let sched = Scheduler::new(&be, TINY, cfg);
        let reqs = poisson_spec(200.0, 48, 42).generate().unwrap();
        let pool = Pool::new(threads);
        let pcfg = PlatinumConfig::default();
        let mut wrng = Rng::seed_from(1);
        let w = wrng.ternary_vec(64 * 64);
        let packed = pack_ternary(&w, 64, 64, pcfg.c_ternary);
        let mut exec = |s: &StepRecord, _w: &Workload| -> anyhow::Result<()> {
            let n = s.tokens.max(1);
            let mut xrng = Rng::seed_from(0x5EED ^ s.index);
            let x = xrng.act_vec(64 * n);
            let (y, _) = ternary_mpgemm_pool(&pcfg, &packed, &x, n, &pool, threads);
            assert_eq!(y.len(), 64 * n);
            Ok(())
        };
        let r = sched.serve_with(&reqs, &mut VirtualClock::new(), Some(&mut exec)).unwrap();
        (r.metrics.to_json().to_string(), r.steps)
    };
    let (json1, steps1) = run(1);
    let (json8, steps8) = run(8);
    assert_eq!(steps1, steps8, "scheduler decisions leaked the pool size");
    assert_eq!(json1, json8, "metrics JSON leaked the pool size");
    assert!(!steps1.is_empty());
}

#[test]
fn golden_executor_bridge_executes_without_perturbing_the_run() {
    // the PR 2 serving substrate (GoldenExecutor on the worker pool)
    // rides along through ExecutorBridge; pricing-only and
    // functionally-executing runs must agree exactly
    let cfg = SchedulerConfig { max_batch: 8, ..SchedulerConfig::default() };
    let be = PlatinumBackend::ternary();
    let sched = Scheduler::new(&be, TINY, cfg);
    let reqs = poisson_spec(120.0, 24, 7).generate().unwrap();
    let priced_only = sched.serve(&reqs, &mut VirtualClock::new()).unwrap();
    let mut wrng = Rng::seed_from(11);
    let w = wrng.ternary_vec(48 * 64);
    let golden = GoldenExecutor::new(&w, 48, 64, PlatinumConfig::default());
    let mut bridge = ExecutorBridge::new(golden);
    let executed =
        sched.serve_with(&reqs, &mut VirtualClock::new(), Some(&mut bridge)).unwrap();
    assert_eq!(priced_only.steps, executed.steps);
    assert_eq!(
        priced_only.metrics.to_json().to_string(),
        executed.metrics.to_json().to_string()
    );
    assert_eq!(executed.metrics.completed, 24);
}

#[test]
fn saturation_triggers_backpressure_bounds_queue_and_never_deadlocks() {
    let cfg = SchedulerConfig {
        max_batch: 4,
        max_queue: 8,
        ..SchedulerConfig::default()
    };
    let be = PlatinumBackend::ternary();
    let sched = Scheduler::new(&be, TINY, cfg);
    // offered load 20× the decode capacity of the modelled backend
    let rate = 20.0 * capacity_rps(&be, &cfg, 6);
    let reqs = poisson_spec(rate, 96, 5).generate().unwrap();
    // real pool work inside the loop: overload must not wedge the pool
    let pool = Pool::new(4);
    let mut exec = |s: &StepRecord, _w: &Workload| -> anyhow::Result<()> {
        pool.for_each_chunk(4, s.tokens.max(1) * 64, 0, &|r| {
            std::hint::black_box(r.len());
        });
        Ok(())
    };
    let r = sched.serve_with(&reqs, &mut VirtualClock::new(), Some(&mut exec)).unwrap();
    let m = &r.metrics;
    assert_eq!(m.offered, 96);
    assert!(m.rejected > 0, "overload must shed load (admitted {})", m.admitted);
    assert_eq!(m.admitted + m.rejected, m.offered);
    assert_eq!(m.completed, m.admitted, "every admitted request must finish");
    assert!(m.queue_depth_max <= 8, "queue bound violated: {}", m.queue_depth_max);
    // saturated: the running batch fills up
    assert!(
        m.mean_decode_batch() > 0.7 * cfg.max_batch as f64,
        "saturated batch {:.2}",
        m.mean_decode_batch()
    );
    let p99 = m.ttft.quantile(0.99).unwrap();
    assert!(p99.is_finite() && p99 > 0.0);
}

#[test]
fn batch_size_grows_then_saturates_with_offered_load() {
    let cfg = SchedulerConfig { max_batch: 8, ..SchedulerConfig::default() };
    let be = PlatinumBackend::ternary();
    let sched = Scheduler::new(&be, TINY, cfg);
    let capacity = capacity_rps(&be, &cfg, 6);
    let batch_at = |mult: f64| {
        let reqs = poisson_spec(capacity * mult, 64, 42).generate().unwrap();
        let r = sched.serve(&reqs, &mut VirtualClock::new()).unwrap();
        r.metrics.mean_decode_batch()
    };
    let light = batch_at(0.2);
    let heavy = batch_at(8.0);
    assert!(light < heavy, "batch must grow with load: {light:.2} vs {heavy:.2}");
    assert!(light < 0.6 * cfg.max_batch as f64, "light load overfills: {light:.2}");
    assert!(heavy > 0.7 * cfg.max_batch as f64, "heavy load must saturate: {heavy:.2}");
}

#[test]
fn sharded_and_measured_backends_serve_through_the_same_scheduler() {
    // any registry id drops in as the pricing backend, including the
    // multi-chip composite and the measured golden kernel
    let reqs: Vec<TrafficRequest> = (0..6)
        .map(|i| TrafficRequest {
            id: i,
            arrival_s: 0.0,
            prompt_tokens: 4,
            output_tokens: 3,
            ..TrafficRequest::default()
        })
        .collect();
    let cfg = SchedulerConfig { max_batch: 4, ..SchedulerConfig::default() };
    for id in ["sharded:2:platinum-ternary", "platinum-cpu"] {
        let be = Registry::with_defaults().build(id).unwrap();
        let sched = Scheduler::new(be.as_ref(), TINY, cfg);
        let r = sched.serve(&reqs, &mut VirtualClock::new()).unwrap();
        assert_eq!(r.metrics.completed, 6, "{id}");
        assert!(r.metrics.makespan_s > 0.0, "{id}");
        assert!(r.metrics.ttft.quantile(0.99).unwrap() > 0.0, "{id}");
    }
}

#[test]
fn swap_and_recompute_agree_byte_identically_at_ample_capacity() {
    // with the default (ample) capacity the eviction path never fires,
    // so the pressure policy must not move a single metrics byte — the
    // policy label is deliberately kept out of the JSON
    let be = PlatinumBackend::ternary();
    let run = |policy: KvPolicy| {
        let cfg = SchedulerConfig {
            kv: KvConfig { policy, ..KvConfig::default() },
            ..SchedulerConfig::default()
        };
        let sched = Scheduler::new(&be, TINY, cfg);
        let reqs = poisson_spec(150.0, 48, 21).generate().unwrap();
        let r = sched.serve(&reqs, &mut VirtualClock::new()).unwrap();
        (r.metrics.to_json().to_string(), r.steps)
    };
    let (swap_json, swap_steps) = run(KvPolicy::Swap);
    let (rec_json, rec_steps) = run(KvPolicy::Recompute);
    assert_eq!(swap_steps, rec_steps, "policy leaked into decisions without pressure");
    assert_eq!(swap_json, rec_json, "policy leaked into metrics without pressure");
}

#[test]
fn tight_kv_pressure_is_deterministic_and_counts_in_the_json() {
    // TINY stores 256 B/token ⇒ 4-token blocks are 1 KiB: a 12-block
    // pool under 32 simultaneous requests forces admission backpressure
    // and decode-time preemption on both policies, deterministically
    for policy in [KvPolicy::Swap, KvPolicy::Recompute] {
        let be = PlatinumBackend::ternary();
        let cfg = SchedulerConfig {
            kv: KvConfig {
                block_tokens: 4,
                sram_kib: 12,
                dram_mib: 0,
                policy,
                ..KvConfig::default()
            },
            ..SchedulerConfig::default()
        };
        let sched = Scheduler::new(&be, TINY, cfg);
        let reqs = LoadSpec {
            pattern: ArrivalPattern::Replay { times_s: vec![0.0; 32] },
            prompt: LenDist::Uniform { lo: 4, hi: 12 },
            output: LenDist::Fixed(6),
            requests: 32,
            seed: 9,
        }
        .generate()
        .unwrap();
        let run = || {
            let r = sched.serve(&reqs, &mut VirtualClock::new()).unwrap();
            assert_eq!(r.metrics.completed, 32, "{:?}", policy);
            r.metrics.to_json().to_string()
        };
        let a = run();
        assert_eq!(a, run(), "pressure path must be deterministic ({policy:?})");
        let kv = Json::parse(&a).unwrap().get("kv").unwrap().clone();
        assert!(kv.get("evictions").unwrap().as_f64().unwrap() >= 1.0, "{policy:?}");
        assert!(kv.get("utilization").unwrap().as_f64().unwrap() >= 0.9, "{policy:?}");
        match policy {
            KvPolicy::Swap => {
                assert!(kv.get("swap").unwrap().get("outs").unwrap().as_f64().unwrap() >= 1.0);
                assert!(kv.get("swap").unwrap().get("stall_s").unwrap().as_f64().unwrap() > 0.0);
            }
            KvPolicy::Recompute => {
                assert!(kv.get("recomputed_tokens").unwrap().as_f64().unwrap() >= 1.0);
            }
        }
    }
}

#[test]
fn shared_prefix_serving_cuts_ttft_and_blocks_end_to_end() {
    // the acceptance trace: a replayed burst sharing one system prompt,
    // served with the prefix cache on vs off through the full stack
    let be = PlatinumBackend::ternary();
    let trace = || {
        let mut reqs = LoadSpec {
            pattern: ArrivalPattern::Replay {
                times_s: (0..24).map(|i| (i / 8) as f64 * 0.05).collect(),
            },
            prompt: LenDist::Uniform { lo: 4, hi: 12 },
            output: LenDist::Fixed(6),
            requests: 24,
            seed: 13,
        }
        .generate()
        .unwrap();
        with_shared_prefix(&mut reqs, 64);
        reqs
    };
    let run = |prefix_cache: bool| {
        let cfg = SchedulerConfig {
            kv: KvConfig { prefix_cache, ..KvConfig::default() },
            ..SchedulerConfig::default()
        };
        let sched = Scheduler::new(&be, TINY, cfg);
        sched.serve(&trace(), &mut VirtualClock::new()).unwrap().metrics
    };
    let on = run(true);
    let off = run(false);
    assert_eq!(on.completed, 24);
    assert_eq!(off.completed, 24);
    assert!(on.kv.prefix_hits >= 20, "bursts reuse the cached prompt: {}", on.kv.prefix_hits);
    assert!(on.kv.prefix_hit_rate().unwrap() > 0.8);
    assert!(
        on.ttft.mean().unwrap() < off.ttft.mean().unwrap(),
        "prefix caching must cut TTFT: {:?} vs {:?}",
        on.ttft.mean(),
        off.ttft.mean()
    );
    assert!(
        on.kv.allocated_max < off.kv.allocated_max,
        "prefix caching must cut peak blocks: {} vs {}",
        on.kv.allocated_max,
        off.kv.allocated_max
    );
}

// ---------------------------------------------------------------------------
// ISSUE 7: deterministic fault injection + resilience
// ---------------------------------------------------------------------------

#[test]
fn faulted_metrics_invariant_across_pool_sizes_1_and_8() {
    // the injector's RNG stream is consulted only at fixed points in the
    // single-threaded serve loop, so a faulted, deadline-bound run with
    // real golden work inside every step must not move a byte between
    // pools of 1 and 8 threads — the ISSUE 5 invariance contract holds
    // under chaos too
    let plan = FaultPlan::parse("straggler:r0:p0.3:x4,linkdeg:0.3:1gbps").unwrap();
    let cfg = SchedulerConfig {
        max_batch: 8,
        step_overhead_s: 1e-3,
        resilience: ResilienceConfig {
            deadline_s: Some(0.012),
            max_retries: 2,
            retry_base_s: 2e-3,
            retry_cap_s: 8e-3,
            fault_seed: 42,
            ..ResilienceConfig::default()
        },
        ..SchedulerConfig::default()
    };
    let run = |threads: usize| -> (String, Vec<StepRecord>) {
        let be = PlatinumBackend::ternary();
        let sched = Scheduler::new(&be, TINY, cfg);
        let reqs = poisson_spec(200.0, 48, 42).generate().unwrap();
        let pool = Pool::new(threads);
        let pcfg = PlatinumConfig::default();
        let mut wrng = Rng::seed_from(1);
        let w = wrng.ternary_vec(64 * 64);
        let packed = pack_ternary(&w, 64, 64, pcfg.c_ternary);
        let mut exec = |s: &StepRecord, _w: &Workload| -> anyhow::Result<()> {
            let n = s.tokens.max(1);
            let mut xrng = Rng::seed_from(0x5EED ^ s.index);
            let x = xrng.act_vec(64 * n);
            let (y, _) = ternary_mpgemm_pool(&pcfg, &packed, &x, n, &pool, threads);
            assert_eq!(y.len(), 64 * n);
            Ok(())
        };
        let r = sched
            .serve_faults(&reqs, &mut VirtualClock::new(), Some(&mut exec), &plan)
            .unwrap();
        (r.metrics.to_json().to_string(), r.steps)
    };
    let (json1, steps1) = run(1);
    let (json8, steps8) = run(8);
    assert_eq!(steps1, steps8, "faulted scheduler decisions leaked the pool size");
    assert_eq!(json1, json8, "faulted metrics JSON leaked the pool size");
    let doc = Json::parse(&json1).unwrap();
    let res = doc.get("resilience").expect("faulted run must emit the resilience section");
    let faults = res.get("faults").unwrap();
    let hits = faults.get("straggler_hits").unwrap().as_f64().unwrap()
        + faults.get("linkdeg_hits").unwrap().as_f64().unwrap();
    assert!(hits > 0.0, "the plan must actually fire at these probabilities");
    assert!(res.get("availability").unwrap().as_f64().unwrap() <= 1.0);
}

#[test]
fn sharded_failover_redistributes_and_loses_no_sequences() {
    // a replica crash mid-run on the 4-way sharded composite: survivors
    // absorb the dead replica's shard after a priced weight
    // redistribution, every sequence still completes exactly once, and
    // the failover counters land in the metrics
    let reqs: Vec<TrafficRequest> = (0..12)
        .map(|i| TrafficRequest {
            id: i,
            arrival_s: i as f64 * 1e-4,
            prompt_tokens: 8,
            output_tokens: 6,
            ..TrafficRequest::default()
        })
        .collect();
    let cfg = SchedulerConfig { max_batch: 4, ..SchedulerConfig::default() };
    let be = Registry::with_defaults().build("sharded:4:platinum-ternary").unwrap();
    let sched = Scheduler::new(be.as_ref(), TINY, cfg);
    let clean = sched.serve(&reqs, &mut VirtualClock::new()).unwrap();
    let plan = FaultPlan::parse("crash:r2@t=0.000001s").unwrap();
    let run = || sched.serve_faults(&reqs, &mut VirtualClock::new(), None, &plan).unwrap();
    let r = run();
    let m = &r.metrics;
    assert_eq!(m.offered, 12);
    assert_eq!(m.completed, 12, "failover must lose (or double-count) no sequence");
    let res = m.resilience.as_ref().expect("crash plan emits the resilience section");
    assert_eq!(res.crashed_replicas, 1, "the crash clause must fire exactly once");
    assert_eq!(res.failovers, 1);
    assert!(res.redistribution_s > 0.0, "failover must be priced through the interconnect");
    assert!((res.availability - 1.0).abs() < 1e-12, "no deadline ⇒ everything completes");
    assert!(
        m.makespan_s > clean.metrics.makespan_s,
        "3 survivors + redistribution must cost time: {} vs {}",
        m.makespan_s,
        clean.metrics.makespan_s
    );
    // the same crash replays byte-identically
    assert_eq!(r.metrics.to_json().to_string(), run().metrics.to_json().to_string());
}

#[test]
fn clean_runs_emit_neither_resilience_nor_leak_keys() {
    // schema-compat guard: with no fault plan and an inert resilience
    // config the metrics JSON must match the pre-fault-subsystem shape
    // key for key — downstream diffing (CI serve-smoke) relies on it
    let be = PlatinumBackend::ternary();
    let sched = Scheduler::new(&be, TINY, SchedulerConfig::default());
    let reqs = poisson_spec(150.0, 32, 11).generate().unwrap();
    let r = sched.serve(&reqs, &mut VirtualClock::new()).unwrap();
    let doc = Json::parse(&r.metrics.to_json().to_string()).unwrap();
    assert!(doc.get("resilience").is_none(), "inert config must not grow the schema");
    assert!(doc.get("kv").unwrap().get("leaks").is_none(), "clean drains leak nothing");
}

// ---------------------------------------------------------------------------
// ISSUE 8: arrival sources — the `platinum serve` enabling refactor
// ---------------------------------------------------------------------------

#[test]
fn pushed_arrivals_are_decision_identical_to_prematerialized() {
    // the daemon's PushSource and the legacy slice path must drive the
    // scheduler to the same decisions, step for step and byte for byte —
    // the determinism contract that lets a captured live session replay
    // exactly through serve-bench
    let be = PlatinumBackend::ternary();
    let cfg = SchedulerConfig { max_batch: 8, ..SchedulerConfig::default() };
    let sched = Scheduler::new(&be, TINY, cfg);
    let reqs = poisson_spec(200.0, 48, 42).generate().unwrap();
    let base = sched.serve(&reqs, &mut VirtualClock::new()).unwrap();
    let (mut source, handle) = PushSource::new();
    for r in &reqs {
        handle.push(*r);
    }
    handle.close();
    let pushed = sched
        .serve_source(&mut source, &mut VirtualClock::new(), None, &FaultPlan::default())
        .unwrap();
    assert_eq!(base.steps, pushed.steps, "pushed arrivals changed scheduler decisions");
    assert_eq!(
        base.metrics.to_json().to_string(),
        pushed.metrics.to_json().to_string(),
        "pushed arrivals changed the metrics JSON"
    );
}

#[test]
fn client_cancellation_releases_kv_and_counts() {
    // a client hanging up mid-stream cancels through the push handle:
    // the sequence is killed wherever it sits, its KV blocks and token
    // reservation come back, the run counts it, and the source observer
    // sees exactly one Cancelled terminal
    let be = PlatinumBackend::ternary();
    let cfg = SchedulerConfig { max_batch: 8, ..SchedulerConfig::default() };
    let sched = Scheduler::new(&be, TINY, cfg);
    let (mut source, handle) = PushSource::new();
    let outcomes = std::sync::Arc::new(std::sync::Mutex::new(Vec::new()));
    let sink = outcomes.clone();
    source.set_observer(Box::new(move |id, o| sink.lock().unwrap().push((id, o))));
    for i in 0..8 {
        handle.push(TrafficRequest {
            id: i,
            arrival_s: 0.0,
            prompt_tokens: 8,
            output_tokens: 6,
            ..TrafficRequest::default()
        });
    }
    handle.close();
    let canceller = handle.clone();
    let mut cancelled_once = false;
    let mut exec = |s: &StepRecord, _w: &Workload| -> anyhow::Result<()> {
        if s.kind == StepKind::Decode && !cancelled_once {
            cancelled_once = true;
            canceller.cancel(5); // disconnect mid-generation
        }
        Ok(())
    };
    let r = sched
        .serve_source(&mut source, &mut VirtualClock::new(), Some(&mut exec), &FaultPlan::default())
        .unwrap();
    let m = &r.metrics;
    assert_eq!(m.offered, 8);
    assert_eq!(m.cancelled, 1, "the hang-up must be counted");
    assert_eq!(m.completed, 7, "the other sequences must finish");
    assert!(!m.kv.leaked(), "cancellation must return every block");
    assert_eq!(m.kv.allocated_final, 0);
    let doc = Json::parse(&m.to_json().to_string()).unwrap();
    assert_eq!(
        doc.get("counts").unwrap().get("cancelled").unwrap().as_f64(),
        Some(1.0),
        "cancelled count must serialize (and only when nonzero)"
    );
    let seen = outcomes.lock().unwrap();
    assert_eq!(seen.len(), 8, "exactly one terminal per offered request");
    assert_eq!(seen.iter().filter(|(_, o)| *o == Outcome::Cancelled).count(), 1);
    assert_eq!(seen.iter().filter(|(_, o)| *o == Outcome::Completed).count(), 7);
    assert!(seen.contains(&(5, Outcome::Cancelled)));
}

#[test]
fn stale_cancellations_are_harmless_and_alter_no_decisions() {
    // a cancel can race past its request's terminal state (the client
    // hangs up in the instant the last token lands) or name an id the
    // scheduler never sees; either way it must neither wedge the drain
    // nor perturb a single scheduling decision — the stale id ages out
    // instead of triggering retain sweeps for the daemon's lifetime
    let be = PlatinumBackend::ternary();
    let cfg = SchedulerConfig { max_batch: 8, ..SchedulerConfig::default() };
    let sched = Scheduler::new(&be, TINY, cfg);
    let reqs = poisson_spec(200.0, 24, 7).generate().unwrap();
    let base = sched.serve(&reqs, &mut VirtualClock::new()).unwrap();
    let (mut source, handle) = PushSource::new();
    for r in &reqs {
        handle.push(*r);
    }
    handle.close();
    let canceller = handle.clone();
    let mut fired = false;
    let mut exec = |s: &StepRecord, _w: &Workload| -> anyhow::Result<()> {
        if !fired && s.kind == StepKind::Decode {
            fired = true;
            canceller.cancel(10_000); // an id no request ever carries
        }
        Ok(())
    };
    let r = sched
        .serve_source(&mut source, &mut VirtualClock::new(), Some(&mut exec), &FaultPlan::default())
        .unwrap();
    assert!(fired, "the stale cancel must actually have been issued");
    assert_eq!(r.metrics.cancelled, 0, "a stale cancel must not count");
    assert_eq!(r.metrics.completed, 24, "every real request still drains");
    assert_eq!(base.steps, r.steps, "a stale cancel must not perturb decisions");
    assert_eq!(
        base.metrics.to_json().to_string(),
        r.metrics.to_json().to_string(),
        "a stale cancel must not change the metrics JSON"
    );
}

#[test]
fn executor_panic_propagates_without_wedging_pool_or_scheduler() {
    // an Err from the executor is absorbed by a resilient scheduler and
    // retried, but a panic is a bug: it must propagate to the caller —
    // and must not wedge the worker pool or the scheduler for later runs
    let cfg = SchedulerConfig {
        resilience: ResilienceConfig { max_retries: 2, ..ResilienceConfig::default() },
        ..SchedulerConfig::default()
    };
    let be = PlatinumBackend::ternary();
    let sched = Scheduler::new(&be, TINY, cfg);
    let reqs = poisson_spec(150.0, 16, 3).generate().unwrap();
    let pool = Pool::new(4);
    let panicked = {
        let mut arena = vec![0usize; 4 * 4];
        let mut exec = |s: &StepRecord, _w: &Workload| -> anyhow::Result<()> {
            pool.for_each_chunk_arena(4, s.tokens.max(1) * 64, 0, &mut arena, &|scratch, r| {
                scratch[0] += r.len();
                if s.index == 3 {
                    panic!("injected arena-body panic at step {}", s.index);
                }
            });
            Ok(())
        };
        catch_unwind(AssertUnwindSafe(|| {
            sched.serve_with(&reqs, &mut VirtualClock::new(), Some(&mut exec))
        }))
        .is_err()
    };
    assert!(panicked, "a panic inside pool work must reach the caller, not be absorbed");
    // neither the pool nor the scheduler is wedged: the same pool drives
    // a clean serve to full completion afterwards
    let mut exec = |s: &StepRecord, _w: &Workload| -> anyhow::Result<()> {
        pool.for_each_chunk(4, s.tokens.max(1) * 64, 0, &|r| {
            std::hint::black_box(r.len());
        });
        Ok(())
    };
    let r = sched.serve_with(&reqs, &mut VirtualClock::new(), Some(&mut exec)).unwrap();
    assert_eq!(r.metrics.completed, r.metrics.admitted, "post-panic serve must drain");
    assert!(!r.metrics.kv.leaked(), "post-panic serve must not report KV leaks");
}

// ---------------------------------------------------------------------------
// ISSUE 9: multi-tenant SLO classes + chunked prefill
// ---------------------------------------------------------------------------

#[test]
fn inert_class_and_chunk_config_is_byte_identical_to_legacy() {
    // the acceptance pin: one class, default weights, and a chunk budget
    // at least as large as the longest prompt must reproduce the PR 8
    // schema byte for byte — no `classes` key, no decision drift
    let be = PlatinumBackend::ternary();
    let reqs = poisson_spec(150.0, 48, 17).generate().unwrap();
    let legacy = Scheduler::new(&be, TINY, SchedulerConfig::default())
        .serve(&reqs, &mut VirtualClock::new())
        .unwrap();
    // prompts are Uniform{4,12}: chunk 12 covers every admission exactly
    for chunk in [12, 2048] {
        let cfg = SchedulerConfig {
            prefill_chunk: chunk,
            classes: 1,
            ..SchedulerConfig::default()
        };
        let inert =
            Scheduler::new(&be, TINY, cfg).serve(&reqs, &mut VirtualClock::new()).unwrap();
        assert_eq!(legacy.steps, inert.steps, "chunk {chunk} ≥ max prompt moved a decision");
        assert_eq!(
            legacy.metrics.to_json().to_string(),
            inert.metrics.to_json().to_string(),
            "chunk {chunk} ≥ max prompt moved a metrics byte"
        );
    }
    let doc = Json::parse(&legacy.metrics.to_json().to_string()).unwrap();
    assert!(doc.get("classes").is_none(), "single-class runs must not grow the schema");
}

#[test]
fn tenant_mix_metrics_invariant_across_pool_sizes_1_and_8() {
    // the ISSUE 5 pool-invariance contract extends to the tentpole: a
    // two-class tenant mix with chunked prefill engaged, real golden
    // GEMMs inside every step, byte-identical between pools of 1 and 8
    let mix = TenantMix::parse("interactive:0.7:w4,batch:0.3:w1").unwrap();
    let mut cfg = SchedulerConfig {
        max_batch: 8,
        prefill_chunk: 8, // below the max prompt of 12: chunking engages
        ..SchedulerConfig::default()
    };
    cfg.classes = mix.classes.len();
    cfg.class_weights = mix.weights();
    let run = |threads: usize| -> (String, Vec<StepRecord>) {
        let be = PlatinumBackend::ternary();
        let sched = Scheduler::new(&be, TINY, cfg);
        let mut reqs = poisson_spec(200.0, 48, 42).generate().unwrap();
        mix.assign(&mut reqs, 42);
        let pool = Pool::new(threads);
        let pcfg = PlatinumConfig::default();
        let mut wrng = Rng::seed_from(1);
        let w = wrng.ternary_vec(64 * 64);
        let packed = pack_ternary(&w, 64, 64, pcfg.c_ternary);
        let mut exec = |s: &StepRecord, _w: &Workload| -> anyhow::Result<()> {
            let n = s.tokens.max(1);
            let mut xrng = Rng::seed_from(0x5EED ^ s.index);
            let x = xrng.act_vec(64 * n);
            let (y, _) = ternary_mpgemm_pool(&pcfg, &packed, &x, n, &pool, threads);
            assert_eq!(y.len(), 64 * n);
            Ok(())
        };
        let r = sched.serve_with(&reqs, &mut VirtualClock::new(), Some(&mut exec)).unwrap();
        (r.metrics.to_json().to_string(), r.steps)
    };
    let (json1, steps1) = run(1);
    let (json8, steps8) = run(8);
    assert_eq!(steps1, steps8, "tenant-mix scheduler decisions leaked the pool size");
    assert_eq!(json1, json8, "tenant-mix metrics JSON leaked the pool size");
    // the per-class section rides inside the byte-identical document
    let doc = Json::parse(&json1).unwrap();
    let classes = doc.get("classes").expect("two-class run must emit per-class metrics");
    let arr = classes.as_arr().unwrap();
    assert_eq!(arr.len(), 2);
    let completed: f64 = arr
        .iter()
        .map(|c| c.get("counts").unwrap().get("completed").unwrap().as_f64().unwrap())
        .sum();
    let total = doc.get("counts").unwrap().get("completed").unwrap().as_f64().unwrap();
    assert_eq!(completed, total, "per-class counts must partition the global count");
}

#[test]
fn chunked_prefill_interleaves_decode_steps_and_drains() {
    // prompts 4× the chunk budget: prefill splits across steps, decode
    // steps interleave between chunk steps once a sequence is running,
    // every sequence still completes, and the run replays byte-identically
    let be = PlatinumBackend::ternary();
    let reqs: Vec<TrafficRequest> = (0..6)
        .map(|i| TrafficRequest {
            id: i,
            arrival_s: i as f64 * 1e-4,
            prompt_tokens: 64,
            output_tokens: 8,
            ..TrafficRequest::default()
        })
        .collect();
    let base_cfg = SchedulerConfig { max_batch: 8, ..SchedulerConfig::default() };
    let unchunked =
        Scheduler::new(&be, TINY, base_cfg).serve(&reqs, &mut VirtualClock::new()).unwrap();
    let cfg = SchedulerConfig { prefill_chunk: 16, ..base_cfg };
    let sched = Scheduler::new(&be, TINY, cfg);
    let run = || sched.serve(&reqs, &mut VirtualClock::new()).unwrap();
    let r = run();
    assert_eq!(r.metrics.completed, 6, "chunking must not lose sequences");
    assert!(!r.metrics.kv.leaked(), "carried partials must release their blocks");
    assert!(
        r.metrics.prefill_steps > unchunked.metrics.prefill_steps,
        "64-token prompts under a 16-token budget must take extra prefill steps: {} vs {}",
        r.metrics.prefill_steps,
        unchunked.metrics.prefill_steps
    );
    let kinds: Vec<StepKind> = r.steps.iter().map(|s| s.kind).collect();
    assert!(
        kinds
            .windows(3)
            .any(|w| w == [StepKind::Prefill, StepKind::Decode, StepKind::Prefill]),
        "decode steps must interleave between prefill chunks: {kinds:?}"
    );
    assert_eq!(
        r.metrics.to_json().to_string(),
        run().metrics.to_json().to_string(),
        "the chunked path must stay deterministic"
    );
}

#[test]
fn wfq_gives_interactive_lower_ttft_than_batch_at_saturation() {
    // past the knee with a tight in-flight token budget, a weight-4
    // interactive class must clear the queue faster than a weight-1
    // batch class sharing the same scheduler — the SLO the tentpole buys
    let be = PlatinumBackend::ternary();
    let mut cfg = SchedulerConfig {
        max_batch: 8,
        max_inflight_tokens: 120,
        ..SchedulerConfig::default()
    };
    cfg.classes = 2;
    cfg.class_weights[0] = 4;
    cfg.class_weights[1] = 1;
    let rate = 8.0 * capacity_rps(&be, &cfg, 6);
    let mut reqs = poisson_spec(rate, 96, 23).generate().unwrap();
    for (i, r) in reqs.iter_mut().enumerate() {
        r.class = (i % 2) as u8; // even split, identical shape distribution
    }
    let sched = Scheduler::new(&be, TINY, cfg);
    let run = || sched.serve(&reqs, &mut VirtualClock::new()).unwrap();
    let r = run();
    let classes = r.metrics.classes.as_ref().expect("two-class run must emit the section");
    assert_eq!(classes.len(), 2);
    assert!(classes[0].completed > 0 && classes[1].completed > 0);
    let p99 = |c: usize| classes[c].ttft.quantile(0.99).unwrap();
    assert!(
        p99(0) < p99(1),
        "weight-4 interactive must beat weight-1 batch at saturation: {:.4}s vs {:.4}s",
        p99(0),
        p99(1)
    );
    assert!(
        classes[0].ttft.mean().unwrap() < classes[1].ttft.mean().unwrap(),
        "the ordering must hold in the mean, not just the tail"
    );
    assert_eq!(
        r.metrics.to_json().to_string(),
        run().metrics.to_json().to_string(),
        "the WFQ path must stay deterministic"
    );
}

// ---------------------------------------------------------------------------
// ISSUE 10: event-driven interconnect (sim::net) + per-class brownout slack
// ---------------------------------------------------------------------------

#[test]
fn net_failover_is_priced_on_the_event_timeline_and_pool_invariant() {
    // a replica crash mid-serve on the event-driven ring composite: the
    // redistribution stall is the sim::net timeline makespan (it must
    // differ from the analytic interconnect's price for the same crash),
    // the resilience accounting balances, and the whole faulted run is
    // byte-identical across worker-pool sizes {1, 8} with real golden
    // work executing inside every step
    let reqs: Vec<TrafficRequest> = (0..12)
        .map(|i| TrafficRequest {
            id: i,
            arrival_s: i as f64 * 1e-4,
            prompt_tokens: 8,
            output_tokens: 6,
            ..TrafficRequest::default()
        })
        .collect();
    let cfg = SchedulerConfig { max_batch: 4, ..SchedulerConfig::default() };
    let reg = Registry::with_defaults();
    let net_be = reg.build("sharded:4:net=ring:platinum-ternary").unwrap();
    let analytic_be = reg.build("sharded:4:platinum-ternary").unwrap();
    let plan = FaultPlan::parse("crash:r2@t=0.000001s").unwrap();
    let run = |threads: usize| -> (String, Vec<StepRecord>, f64) {
        let sched = Scheduler::new(net_be.as_ref(), TINY, cfg);
        let pool = Pool::new(threads);
        let pcfg = PlatinumConfig::default();
        let mut wrng = Rng::seed_from(1);
        let w = wrng.ternary_vec(64 * 64);
        let packed = pack_ternary(&w, 64, 64, pcfg.c_ternary);
        let mut exec = |s: &StepRecord, _w: &Workload| -> anyhow::Result<()> {
            let n = s.tokens.max(1);
            let mut xrng = Rng::seed_from(0x5EED ^ s.index);
            let x = xrng.act_vec(64 * n);
            let (y, _) = ternary_mpgemm_pool(&pcfg, &packed, &x, n, &pool, threads);
            assert_eq!(y.len(), 64 * n);
            Ok(())
        };
        let r = sched
            .serve_faults(&reqs, &mut VirtualClock::new(), Some(&mut exec), &plan)
            .unwrap();
        let redist = r.metrics.resilience.as_ref().unwrap().redistribution_s;
        (r.metrics.to_json().to_string(), r.steps, redist)
    };
    let (json1, steps1, redist) = run(1);
    let (json8, steps8, _) = run(8);
    assert_eq!(steps1, steps8, "net-priced scheduler decisions leaked the pool size");
    assert_eq!(json1, json8, "net-priced metrics JSON leaked the pool size");

    // the stall is exactly the event timeline's price for this crash …
    let weight_bytes = TINY.weight_bytes_ternary();
    let event_cost = net_be.redistribute_cost_s(weight_bytes, 3);
    assert!((redist - event_cost).abs() < 1e-15, "{redist} vs {event_cost}");
    // … which is not the analytic interconnect's price (the timeline
    // sees link contention on the fan-out that the closed form ignores)
    let analytic_cost = analytic_be.redistribute_cost_s(weight_bytes, 3);
    assert!(
        (event_cost - analytic_cost).abs() > 1e-9,
        "event {event_cost} vs analytic {analytic_cost} should diverge under contention"
    );

    // and the resilience accounting balances: nothing lost, nothing
    // double-counted
    let doc = Json::parse(&json1).unwrap();
    let counts = doc.get("counts").unwrap();
    let g = |k: &str| counts.get(k).unwrap().as_f64().unwrap();
    let res = doc.get("resilience").unwrap().get("counts").unwrap();
    let shed = res.get("shed").unwrap().as_f64().unwrap();
    let exhausted = res.get("retry_exhausted").unwrap().as_f64().unwrap();
    assert_eq!(g("offered"), g("completed") + shed + exhausted + g("rejected"));
    assert_eq!(g("completed"), 12.0, "failover must lose no sequence");
    assert_eq!(res.get("failovers").unwrap().as_f64(), Some(1.0));
}

#[test]
fn looser_brownout_slack_sheds_batch_before_interactive() {
    // per-class brownout slack (ISSUE 10 satellite): at equal queue
    // depth, the class with the *looser* slack threshold (batch, 10 s)
    // sheds under brownout while the tight class (interactive, 0 ms)
    // rides through — the regression that pins
    // `ResilienceConfig::brownout_slack_for` to real per-class values
    let mut cfg = SchedulerConfig { max_batch: 2, ..SchedulerConfig::default() };
    cfg.classes = 2;
    let mut rc = ResilienceConfig {
        deadline_s: Some(5.0),
        brownout_queue: 4,
        brownout_slack_s: 0.0,
        ..ResilienceConfig::default()
    };
    let classes = ["interactive", "batch"];
    let lookup = |name: &str| classes.iter().position(|c| *c == name);
    rc.set_brownout_slack_spec("interactive:0,batch:10000", &lookup).unwrap();
    cfg.resilience = rc;
    // a t=0 burst, even class split: both class queues sit at the same
    // depth when brownout evaluates
    let reqs: Vec<TrafficRequest> = (0..24)
        .map(|i| TrafficRequest {
            id: i,
            arrival_s: 0.0,
            prompt_tokens: 8,
            output_tokens: 6,
            class: (i % 2) as u8,
            ..TrafficRequest::default()
        })
        .collect();
    let be = PlatinumBackend::ternary();
    let sched = Scheduler::new(&be, TINY, cfg);
    let run = || sched.serve(&reqs, &mut VirtualClock::new()).unwrap();
    let r = run();
    let cls = r.metrics.classes.as_ref().expect("two-class run must emit the section");
    assert_eq!(cls.len(), 2);
    assert!(
        cls[1].shed > 0,
        "the loose-slack batch class must shed under brownout (queue {} deep)",
        r.metrics.queue_depth_max
    );
    assert_eq!(
        cls[0].shed, 0,
        "the tight-slack interactive class must ride through the same depth"
    );
    assert_eq!(
        r.metrics.offered,
        r.metrics.completed + cls[0].shed + cls[1].shed,
        "shed accounting must balance"
    );
    assert_eq!(
        r.metrics.to_json().to_string(),
        run().metrics.to_json().to_string(),
        "per-class shedding must stay deterministic"
    );
}
