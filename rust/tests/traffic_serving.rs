//! Traffic-subsystem contract tests (ISSUE 5 acceptance): virtual-clock
//! determinism (same seed ⇒ byte-identical metrics JSON), invariance of
//! scheduler decisions and metrics across worker-pool sizes {1, 8}
//! while real golden-datapath work runs inside the loop, bounded
//! deadlock-free behavior past saturation, and the batch-size-vs-load
//! saturation curve.

use platinum::config::PlatinumConfig;
use platinum::coordinator::serve::GoldenExecutor;
use platinum::encoding::pack_ternary;
use platinum::engine::{Backend, PlatinumBackend, Registry, Workload};
use platinum::lut::ternary_mpgemm_pool;
use platinum::models::BitNetModel;
use platinum::runtime::pool::Pool;
use platinum::traffic::{
    decode_capacity_tok_s, ArrivalPattern, ExecutorBridge, LenDist, LoadSpec, Scheduler,
    SchedulerConfig, StepRecord, TrafficRequest, VirtualClock,
};
use platinum::util::json::Json;
use platinum::util::rng::Rng;

/// 2-layer toy model: modelled pricing stays microseconds-fast and the
/// functional golden work in the pool-invariance tests stays tiny.
const TINY: BitNetModel = BitNetModel {
    name: "tiny",
    params: "2M",
    hidden: 64,
    ffn: 160,
    heads: 4,
    kv_heads: 4,
    layers: 2,
};

fn poisson_spec(rate: f64, requests: usize, seed: u64) -> LoadSpec {
    LoadSpec {
        pattern: ArrivalPattern::Poisson { rate_rps: rate },
        prompt: LenDist::Uniform { lo: 4, hi: 12 },
        output: LenDist::Fixed(6),
        requests,
        seed,
    }
}

/// Requests/s one `max_batch`-wide decode step can sustain on the
/// modelled backend, for placing rates relative to the knee.
fn capacity_rps(be: &dyn Backend, cfg: &SchedulerConfig, output_tokens: usize) -> f64 {
    decode_capacity_tok_s(be, TINY, cfg.max_batch) / output_tokens as f64
}

#[test]
fn virtual_clock_metrics_are_byte_identical_per_seed() {
    let be = PlatinumBackend::ternary();
    let sched = Scheduler::new(&be, TINY, SchedulerConfig::default());
    let run = |seed: u64| {
        let reqs = poisson_spec(150.0, 64, seed).generate().unwrap();
        let r = sched.serve(&reqs, &mut VirtualClock::new()).unwrap();
        r.metrics.to_json().to_string()
    };
    let a = run(42);
    assert_eq!(a, run(42), "same seed + same rate must serialize byte-identical");
    assert_ne!(a, run(43), "a different seed must move the metrics");
    // and the JSON is well-formed with the advertised headline fields
    let doc = Json::parse(&a).unwrap();
    let ttft = doc.get("latency_s").unwrap().get("ttft").unwrap();
    let p99 = ttft.get("p99").unwrap().as_f64().unwrap();
    assert!(p99.is_finite() && p99 > 0.0);
    let goodput = doc.get("throughput").unwrap().get("goodput_tokens_per_s").unwrap();
    assert!(goodput.as_f64().unwrap() > 0.0);
    let depth = doc.get("series").unwrap().get("queue_depth").unwrap();
    assert!(depth.as_arr().unwrap().len() > 1);
}

#[test]
fn metrics_and_decisions_invariant_across_pool_sizes_1_and_8() {
    // real golden-datapath GEMMs execute on an explicit worker pool
    // inside every scheduler step; the virtual timeline is priced by
    // the deterministic model, so pool size {1, 8} must not move a
    // single byte of the metrics or a single scheduling decision
    let cfg = SchedulerConfig { max_batch: 8, ..SchedulerConfig::default() };
    let run = |threads: usize| -> (String, Vec<StepRecord>) {
        let be = PlatinumBackend::ternary();
        let sched = Scheduler::new(&be, TINY, cfg);
        let reqs = poisson_spec(200.0, 48, 42).generate().unwrap();
        let pool = Pool::new(threads);
        let pcfg = PlatinumConfig::default();
        let mut wrng = Rng::seed_from(1);
        let w = wrng.ternary_vec(64 * 64);
        let packed = pack_ternary(&w, 64, 64, pcfg.c_ternary);
        let mut exec = |s: &StepRecord, _w: &Workload| -> anyhow::Result<()> {
            let n = s.tokens.max(1);
            let mut xrng = Rng::seed_from(0x5EED ^ s.index);
            let x = xrng.act_vec(64 * n);
            let (y, _) = ternary_mpgemm_pool(&pcfg, &packed, &x, n, &pool, threads);
            assert_eq!(y.len(), 64 * n);
            Ok(())
        };
        let r = sched.serve_with(&reqs, &mut VirtualClock::new(), Some(&mut exec)).unwrap();
        (r.metrics.to_json().to_string(), r.steps)
    };
    let (json1, steps1) = run(1);
    let (json8, steps8) = run(8);
    assert_eq!(steps1, steps8, "scheduler decisions leaked the pool size");
    assert_eq!(json1, json8, "metrics JSON leaked the pool size");
    assert!(!steps1.is_empty());
}

#[test]
fn golden_executor_bridge_executes_without_perturbing_the_run() {
    // the PR 2 serving substrate (GoldenExecutor on the worker pool)
    // rides along through ExecutorBridge; pricing-only and
    // functionally-executing runs must agree exactly
    let cfg = SchedulerConfig { max_batch: 8, ..SchedulerConfig::default() };
    let be = PlatinumBackend::ternary();
    let sched = Scheduler::new(&be, TINY, cfg);
    let reqs = poisson_spec(120.0, 24, 7).generate().unwrap();
    let priced_only = sched.serve(&reqs, &mut VirtualClock::new()).unwrap();
    let mut wrng = Rng::seed_from(11);
    let w = wrng.ternary_vec(48 * 64);
    let golden = GoldenExecutor::new(&w, 48, 64, PlatinumConfig::default());
    let mut bridge = ExecutorBridge::new(golden);
    let executed =
        sched.serve_with(&reqs, &mut VirtualClock::new(), Some(&mut bridge)).unwrap();
    assert_eq!(priced_only.steps, executed.steps);
    assert_eq!(
        priced_only.metrics.to_json().to_string(),
        executed.metrics.to_json().to_string()
    );
    assert_eq!(executed.metrics.completed, 24);
}

#[test]
fn saturation_triggers_backpressure_bounds_queue_and_never_deadlocks() {
    let cfg = SchedulerConfig {
        max_batch: 4,
        max_queue: 8,
        ..SchedulerConfig::default()
    };
    let be = PlatinumBackend::ternary();
    let sched = Scheduler::new(&be, TINY, cfg);
    // offered load 20× the decode capacity of the modelled backend
    let rate = 20.0 * capacity_rps(&be, &cfg, 6);
    let reqs = poisson_spec(rate, 96, 5).generate().unwrap();
    // real pool work inside the loop: overload must not wedge the pool
    let pool = Pool::new(4);
    let mut exec = |s: &StepRecord, _w: &Workload| -> anyhow::Result<()> {
        pool.for_each_chunk(4, s.tokens.max(1) * 64, 0, &|r| {
            std::hint::black_box(r.len());
        });
        Ok(())
    };
    let r = sched.serve_with(&reqs, &mut VirtualClock::new(), Some(&mut exec)).unwrap();
    let m = &r.metrics;
    assert_eq!(m.offered, 96);
    assert!(m.rejected > 0, "overload must shed load (admitted {})", m.admitted);
    assert_eq!(m.admitted + m.rejected, m.offered);
    assert_eq!(m.completed, m.admitted, "every admitted request must finish");
    assert!(m.queue_depth_max <= 8, "queue bound violated: {}", m.queue_depth_max);
    // saturated: the running batch fills up
    assert!(
        m.mean_decode_batch() > 0.7 * cfg.max_batch as f64,
        "saturated batch {:.2}",
        m.mean_decode_batch()
    );
    let p99 = m.ttft.quantile(0.99).unwrap();
    assert!(p99.is_finite() && p99 > 0.0);
}

#[test]
fn batch_size_grows_then_saturates_with_offered_load() {
    let cfg = SchedulerConfig { max_batch: 8, ..SchedulerConfig::default() };
    let be = PlatinumBackend::ternary();
    let sched = Scheduler::new(&be, TINY, cfg);
    let capacity = capacity_rps(&be, &cfg, 6);
    let batch_at = |mult: f64| {
        let reqs = poisson_spec(capacity * mult, 64, 42).generate().unwrap();
        let r = sched.serve(&reqs, &mut VirtualClock::new()).unwrap();
        r.metrics.mean_decode_batch()
    };
    let light = batch_at(0.2);
    let heavy = batch_at(8.0);
    assert!(light < heavy, "batch must grow with load: {light:.2} vs {heavy:.2}");
    assert!(light < 0.6 * cfg.max_batch as f64, "light load overfills: {light:.2}");
    assert!(heavy > 0.7 * cfg.max_batch as f64, "heavy load must saturate: {heavy:.2}");
}

#[test]
fn sharded_and_measured_backends_serve_through_the_same_scheduler() {
    // any registry id drops in as the pricing backend, including the
    // multi-chip composite and the measured golden kernel
    let reqs: Vec<TrafficRequest> = (0..6)
        .map(|i| TrafficRequest {
            id: i,
            arrival_s: 0.0,
            prompt_tokens: 4,
            output_tokens: 3,
        })
        .collect();
    let cfg = SchedulerConfig { max_batch: 4, ..SchedulerConfig::default() };
    for id in ["sharded:2:platinum-ternary", "platinum-cpu"] {
        let be = Registry::with_defaults().build(id).unwrap();
        let sched = Scheduler::new(be.as_ref(), TINY, cfg);
        let r = sched.serve(&reqs, &mut VirtualClock::new()).unwrap();
        assert_eq!(r.metrics.completed, 6, "{id}");
        assert!(r.metrics.makespan_s > 0.0, "{id}");
        assert!(r.metrics.ttft.quantile(0.99).unwrap() > 0.0, "{id}");
    }
}
