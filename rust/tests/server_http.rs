//! Socket-free tests for the `platinum serve` wire layer (S18).
//!
//! Everything here drives [`platinum::server::http`] on raw byte
//! slices — no `TcpListener`, no threads — so the parser's handling of
//! malformed input, size limits, and arbitrary read-boundary splits is
//! pinned without any timing sensitivity.  The live socket path is
//! exercised end-to-end by CI's `daemon-smoke` job
//! (`python/tools/daemon_smoke.py`).

use platinum::server::http::{
    chunk, last_chunk, response, streaming_head, RequestParser, MAX_BODY_BYTES, MAX_HEAD_BYTES,
};

fn parse_one(raw: &[u8]) -> anyhow::Result<Option<platinum::server::http::HttpRequest>> {
    let mut p = RequestParser::new();
    p.feed(raw);
    p.poll()
}

#[test]
fn malformed_request_lines_are_rejected_not_hung() {
    for raw in [
        &b"GET\r\n\r\n"[..],                             // too few parts
        b"GET /x HTTP/1.1 extra\r\n\r\n",                // too many parts
        b" /x HTTP/1.1\r\n\r\n",                         // empty method
        b"GET  HTTP/1.1\r\n\r\n",                        // empty path
        b"GET /x SPDY/3\r\n\r\n",                        // wrong protocol
        b"GET /x HTTP/2\r\n\r\n",                        // wrong major version
        b"GET /x HTTP/1.1\r\nno-colon-here\r\n\r\n",     // header without ':'
        b"GET /x HTTP/1.1\r\nBad Name: v\r\n\r\n",       // space in header name
        b"GET /x HTTP/1.1\r\n: value\r\n\r\n",           // empty header name
        b"GET /x HTTP/1.1\r\nContent-Length: ten\r\n\r\n", // non-numeric length
        b"GET /x HTTP/1.1\r\nContent-Length: -4\r\n\r\n",  // negative length
        b"\xff\xfe /x HTTP/1.1\r\n\r\n",                 // non-UTF-8 head
    ] {
        assert!(
            parse_one(raw).is_err(),
            "must 400, not hang or accept: {:?}",
            String::from_utf8_lossy(raw)
        );
    }
}

#[test]
fn oversized_heads_and_bodies_are_bounded() {
    // a head that never terminates must error once past the cap, not
    // buffer forever
    let mut p = RequestParser::new();
    p.feed(b"GET /x HTTP/1.1\r\nX-Junk: ");
    p.feed(&vec![b'a'; MAX_HEAD_BYTES]);
    assert!(p.poll().is_err(), "unterminated head past the cap must error");

    // a terminated head over the cap is equally rejected
    let mut raw = b"GET /x HTTP/1.1\r\nX-Junk: ".to_vec();
    raw.extend_from_slice(&vec![b'a'; MAX_HEAD_BYTES]);
    raw.extend_from_slice(b"\r\n\r\n");
    assert!(parse_one(&raw).is_err());

    // a declared body over the cap is rejected up front — before any
    // body bytes arrive
    let raw = format!("POST /x HTTP/1.1\r\nContent-Length: {}\r\n\r\n", MAX_BODY_BYTES + 1);
    assert!(parse_one(raw.as_bytes()).is_err());

    // exactly at the cap is fine
    let mut raw =
        format!("POST /x HTTP/1.1\r\nContent-Length: {MAX_BODY_BYTES}\r\n\r\n").into_bytes();
    raw.extend_from_slice(&vec![b'b'; MAX_BODY_BYTES]);
    let req = parse_one(&raw).unwrap().expect("body at the cap parses");
    assert_eq!(req.body.len(), MAX_BODY_BYTES);
}

#[test]
fn partial_reads_across_every_boundary_reassemble() {
    // split a full POST (head + body) at every byte offset, feeding the
    // two halves separately; poll() must return need-more then the
    // complete request, identical for all cuts
    let raw = b"POST /v1/generate HTTP/1.1\r\nHost: h\r\nContent-Length: 17\r\n\r\n{\"prompt\": \"abc\"}";
    let whole = parse_one(raw).unwrap().expect("whole request parses");
    for cut in 1..raw.len() {
        let mut p = RequestParser::new();
        p.feed(&raw[..cut]);
        let first = p.poll().unwrap_or_else(|e| panic!("cut {cut}: spurious error {e}"));
        p.feed(&raw[cut..]);
        let req = match first {
            Some(r) => r,
            None => p.poll().unwrap().unwrap_or_else(|| panic!("cut {cut}: incomplete")),
        };
        assert_eq!(req, whole, "cut at {cut} changed the parse");
    }
}

#[test]
fn byte_at_a_time_delivery_parses() {
    let raw = b"GET /metrics HTTP/1.1\r\nAccept: application/json\r\n\r\n";
    let mut p = RequestParser::new();
    for (i, byte) in raw.iter().enumerate() {
        p.feed(&[*byte]);
        let got = p.poll().unwrap();
        if i + 1 < raw.len() {
            assert!(got.is_none(), "complete before byte {i}?");
        } else {
            let req = got.expect("complete at final byte");
            assert_eq!(req.path, "/metrics");
        }
    }
}

#[test]
fn pipelined_requests_pop_one_at_a_time() {
    let mut p = RequestParser::new();
    p.feed(b"GET /health HTTP/1.1\r\n\r\nPOST /v1/generate HTTP/1.1\r\nContent-Length: 2\r\n\r\nhi");
    let a = p.poll().unwrap().expect("first request");
    assert_eq!((a.method.as_str(), a.path.as_str()), ("GET", "/health"));
    let b = p.poll().unwrap().expect("second request");
    assert_eq!((b.method.as_str(), b.path.as_str()), ("POST", "/v1/generate"));
    assert_eq!(b.body, b"hi");
    assert!(p.poll().unwrap().is_none(), "buffer drained");
}

#[test]
fn header_lookup_is_case_insensitive_and_first_wins() {
    let req = parse_one(b"GET /x HTTP/1.1\r\nX-Deadline-Ms: 250\r\nx-deadline-ms: 900\r\n\r\n")
        .unwrap()
        .unwrap();
    assert_eq!(req.header("X-DEADLINE-MS"), Some("250"));
    assert_eq!(req.header("x-deadline-ms"), Some("250"));
    assert_eq!(req.header("absent"), None);
}

#[test]
fn response_and_stream_framing_golden_bytes() {
    let r = String::from_utf8(response(404, "Not Found", "application/json", b"{}")).unwrap();
    assert!(r.starts_with("HTTP/1.1 404 Not Found\r\n"), "{r}");
    assert!(r.contains("Content-Length: 2\r\n"));
    assert!(r.contains("Connection: close\r\n"));
    assert!(r.ends_with("\r\n\r\n{}"));

    let head = String::from_utf8(streaming_head(200, "OK", "application/x-ndjson")).unwrap();
    assert!(head.contains("Transfer-Encoding: chunked\r\n"));
    assert!(!head.contains("Content-Length"), "chunked and length are exclusive");

    // a full chunked body, decoded by hand: two chunks + terminator
    let mut wire = chunk(b"{\"token\":0}\n");
    wire.extend_from_slice(&chunk(b"{\"done\":true}\n"));
    wire.extend_from_slice(last_chunk());
    let text = String::from_utf8(wire).unwrap();
    assert_eq!(text, "c\r\n{\"token\":0}\n\r\ne\r\n{\"done\":true}\n\r\n0\r\n\r\n");
}
