//! Stress + property suite for the work-stealing runtime scheduler
//! (`runtime::pool`, PR 4).
//!
//! The scheduler is the substrate under every measured hot path
//! (`platinum-cpu`, `tmac-cpu`, `serve::GoldenExecutor`), so this suite
//! pins the two contracts those paths rely on:
//!
//! 1. **Liveness/robustness** — thousands of sub-microsecond tasks,
//!    nested `run()` submitted from inside a worker's task, panic
//!    propagation while other lanes are mid-steal, `threads > items`,
//!    and zero-item batches all complete without wedging the pool.
//! 2. **Bit-exactness** — seeded-RNG randomized GEMM shapes run through
//!    `ternary_mpgemm` / `bitserial_mpgemm` / `TMacCpu::gemm` on pools
//!    of every thread count the CI matrix exercises via
//!    `PLATINUM_THREADS` ∈ {1, 3, 8} (explicit `Pool::new(t)` instances
//!    here, because the env var is read once per process) must equal
//!    the single-threaded result bit for bit.

use platinum::baselines::tmac::TMacCpu;
use platinum::config::PlatinumConfig;
use platinum::encoding::{pack_binary, pack_ternary, ternary_planes};
use platinum::lut::{bitserial_mpgemm_pool, naive_mpgemm, ternary_mpgemm_pool};
use platinum::runtime::pool::{auto_grain, Pool, Task};
use platinum::util::rng::Rng;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};

/// The thread counts the bit-exactness matrix pins (mirrors the CI
/// bench-smoke `PLATINUM_THREADS` axis).
const THREAD_MATRIX: [usize; 3] = [1, 3, 8];

// ---------------------------------------------------------------------------
// scheduler stress: liveness and robustness
// ---------------------------------------------------------------------------

#[test]
fn thousands_of_sub_microsecond_tasks() {
    // decode-shaped GEMMs submit huge numbers of tiny tasks; the
    // steal path must keep every lane busy without losing or
    // double-running any of them
    let pool = Pool::new(8);
    for round in 0..10 {
        let count = 2_000 + round * 100;
        let counter = AtomicUsize::new(0);
        let tasks: Vec<Task> = (0..count)
            .map(|_| {
                Box::new(|| {
                    counter.fetch_add(1, Ordering::Relaxed);
                }) as Task
            })
            .collect();
        pool.run(tasks);
        assert_eq!(counter.load(Ordering::Relaxed), count, "round {round}");
    }
}

#[test]
fn nested_run_from_worker_tasks() {
    // a task submitting its own batch must complete even while its
    // parent batch is still in flight on other lanes (the nested
    // submitter claims from its own lane's deque and steals)
    let pool = Pool::new(4);
    let inner_total = AtomicUsize::new(0);
    let outer: Vec<Task> = (0..16)
        .map(|_| {
            let inner_total = &inner_total;
            let pool_ref = &pool;
            Box::new(move || {
                let tasks: Vec<Task> = (0..8)
                    .map(|_| {
                        Box::new(|| {
                            inner_total.fetch_add(1, Ordering::Relaxed);
                        }) as Task
                    })
                    .collect();
                pool_ref.run(tasks);
            }) as Task
        })
        .collect();
    pool.run(outer);
    assert_eq!(inner_total.load(Ordering::Relaxed), 16 * 8);
}

#[test]
fn doubly_nested_run_completes() {
    let pool = Pool::new(3);
    let hits = AtomicUsize::new(0);
    let hits_ref = &hits;
    let pool_ref = &pool;
    // a leaf batch of 4 counting tasks, submitted from one mid task
    let leaf_batch = move || {
        let leaf: Vec<Task> = (0..4)
            .map(|_| {
                Box::new(move || {
                    hits_ref.fetch_add(1, Ordering::Relaxed);
                }) as Task
            })
            .collect();
        pool_ref.run(leaf);
    };
    let outer: Vec<Task> = (0..4)
        .map(|_| {
            Box::new(move || {
                let mid: Vec<Task> = (0..4).map(|_| Box::new(leaf_batch) as Task).collect();
                pool_ref.run(mid);
            }) as Task
        })
        .collect();
    pool.run(outer);
    assert_eq!(hits.load(Ordering::Relaxed), 4 * 4 * 4);
}

#[test]
fn panic_mid_batch_propagates_and_pool_survives() {
    // one task panics while the rest of the batch is being stolen and
    // executed across lanes: the submitter must re-panic, every other
    // task must still run exactly once, and the pool must stay usable
    let pool = Pool::new(4);
    for round in 0..5 {
        let survivors = AtomicUsize::new(0);
        let total = 200;
        let bomb = 97 + round; // vary where in the batch the panic sits
        let tasks: Vec<Task> = (0..total)
            .map(|i| {
                let survivors = &survivors;
                Box::new(move || {
                    if i == bomb {
                        panic!("mid-steal boom {i}");
                    }
                    survivors.fetch_add(1, Ordering::Relaxed);
                }) as Task
            })
            .collect();
        let err = catch_unwind(AssertUnwindSafe(|| pool.run(tasks)));
        assert!(err.is_err(), "round {round}: panic must propagate to the submitter");
        assert_eq!(
            survivors.load(Ordering::Relaxed),
            total - 1,
            "round {round}: every non-panicking task still runs"
        );
    }
    // the pool is not wedged: a clean batch afterwards completes
    let after = AtomicUsize::new(0);
    let tasks: Vec<Task> = (0..64)
        .map(|_| {
            Box::new(|| {
                after.fetch_add(1, Ordering::Relaxed);
            }) as Task
        })
        .collect();
    pool.run(tasks);
    assert_eq!(after.load(Ordering::Relaxed), 64);
}

#[test]
fn arena_body_panic_mid_run_propagates_and_pool_is_reusable() {
    // the serving executors run through for_each_chunk_arena; a panic in
    // the body mid-claim (other lanes still pulling chunks) must reach
    // the submitter and leave the pool fully usable — both entry points
    // must complete afterwards (ISSUE 7 wedge-resistance)
    let pool = Pool::new(4);
    let mut arena = vec![0usize; 4 * 4];
    let err = catch_unwind(AssertUnwindSafe(|| {
        pool.for_each_chunk_arena(4, 500, 1, &mut arena, &|scratch, r| {
            scratch[0] += 1;
            if r.contains(&250) {
                panic!("arena boom at {}", r.start);
            }
        });
    }));
    assert!(err.is_err(), "arena-body panic must propagate to the submitter");
    // not wedged: chunked dynamic scheduling still covers every index
    let seen = AtomicUsize::new(0);
    pool.for_each_chunk(4, 1000, 0, &|r| {
        seen.fetch_add(r.len(), Ordering::Relaxed);
    });
    assert_eq!(seen.load(Ordering::Relaxed), 1000);
    // and the arena path itself still completes with fresh scratch
    let mut arena2 = vec![0usize; 4 * 2];
    let total = AtomicUsize::new(0);
    pool.for_each_chunk_arena(4, 333, 1, &mut arena2, &|_scratch, r| {
        total.fetch_add(r.len(), Ordering::Relaxed);
    });
    assert_eq!(total.load(Ordering::Relaxed), 333);
}

#[test]
fn threads_exceed_items_and_zero_items() {
    let pool = Pool::new(8);
    // more lanes than tasks: nothing idles forever, all complete
    let counter = AtomicUsize::new(0);
    let tasks: Vec<Task> = (0..3)
        .map(|_| {
            Box::new(|| {
                counter.fetch_add(1, Ordering::Relaxed);
            }) as Task
        })
        .collect();
    pool.run(tasks);
    assert_eq!(counter.load(Ordering::Relaxed), 3);
    // zero items: a no-op, not a hang
    pool.run(Vec::new());
    // dynamic scheduling with zero items and with items < threads
    let hits = AtomicUsize::new(0);
    pool.for_each_chunk(8, 0, 0, &|_r| {
        hits.fetch_add(1, Ordering::Relaxed);
    });
    assert_eq!(hits.load(Ordering::Relaxed), 0);
    let seen = AtomicUsize::new(0);
    pool.for_each_chunk(8, 2, 0, &|r| {
        seen.fetch_add(r.len(), Ordering::Relaxed);
    });
    assert_eq!(seen.load(Ordering::Relaxed), 2);
}

#[test]
fn for_each_chunk_exactness_under_contention() {
    // every index claimed exactly once even when many lanes hammer the
    // cursor with grain 1 (maximum claim contention)
    let pool = Pool::new(8);
    let len = 10_007; // prime: ragged against every grain
    for grain in [0usize, 1, 3, 64] {
        let hits: Vec<AtomicUsize> = (0..len).map(|_| AtomicUsize::new(0)).collect();
        pool.for_each_chunk(8, len, grain, &|r| {
            for i in r {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(
            hits.iter().all(|h| h.load(Ordering::Relaxed) == 1),
            "grain {grain}: some index missed or double-claimed"
        );
    }
}

#[test]
fn auto_grain_bounds() {
    for (len, threads) in [(0, 1), (1, 8), (7, 8), (512, 8), (1 << 20, 4)] {
        let g = auto_grain(len, threads);
        assert!(g >= 1, "grain must be positive (len={len} threads={threads})");
        if len > 0 {
            // never so coarse that one claim swallows everything a
            // multi-lane run should share
            assert!(g <= len.max(1), "grain {g} exceeds len {len}");
        }
    }
}

// ---------------------------------------------------------------------------
// randomized GEMM bit-exactness across the thread matrix
// ---------------------------------------------------------------------------

struct Shape {
    m: usize,
    k: usize,
    n: usize,
}

/// Seeded random shape spanning the regimes that stress the scheduler:
/// decode (n small), threads > rows (m tiny), and multi-round k.
fn random_shape(rng: &mut Rng) -> Shape {
    Shape {
        m: 1 + rng.below(64) as usize,
        k: 1 + rng.below(400) as usize,
        n: 1 + rng.below(12) as usize,
    }
}

#[test]
fn ternary_pool_vs_serial_bit_exact_across_thread_matrix() {
    let cfg = PlatinumConfig::default();
    let pools: Vec<Pool> = THREAD_MATRIX.iter().map(|&t| Pool::new(t)).collect();
    let serial = Pool::new(1);
    platinum::util::check_prop("ternary_pool_matrix", 12, |seed| {
        let mut rng = Rng::seed_from(seed);
        let s = random_shape(&mut rng);
        let w = rng.ternary_vec(s.m * s.k);
        let x = rng.act_vec(s.k * s.n);
        let packed = pack_ternary(&w, s.m, s.k, cfg.c_ternary);
        let (want, ops_serial) = ternary_mpgemm_pool(&cfg, &packed, &x, s.n, &serial, 1);
        platinum::ensure_prop!(
            want == naive_mpgemm(&w, s.m, s.k, &x, s.n),
            "serial wrong vs naive at m={} k={} n={}",
            s.m,
            s.k,
            s.n
        );
        for (&t, pool) in THREAD_MATRIX.iter().zip(&pools) {
            let (got, ops) = ternary_mpgemm_pool(&cfg, &packed, &x, s.n, pool, t);
            platinum::ensure_prop!(
                got == want,
                "threads={t} diverged at m={} k={} n={}",
                s.m,
                s.k,
                s.n
            );
            platinum::ensure_prop!(
                ops == ops_serial,
                "op counts must be thread-count independent (threads={t})"
            );
        }
        Ok(())
    });
}

#[test]
fn bitserial_pool_vs_serial_bit_exact_across_thread_matrix() {
    let cfg = PlatinumConfig::default();
    let pools: Vec<Pool> = THREAD_MATRIX.iter().map(|&t| Pool::new(t)).collect();
    let serial = Pool::new(1);
    platinum::util::check_prop("bitserial_pool_matrix", 10, |seed| {
        let mut rng = Rng::seed_from(seed ^ 0xb5);
        let s = random_shape(&mut rng);
        let w = rng.ternary_vec(s.m * s.k);
        let x = rng.act_vec(s.k * s.n);
        let (pos, neg) = ternary_planes(&w, s.m, s.k);
        let planes = vec![pack_binary(&pos, s.m, s.k, 7), pack_binary(&neg, s.m, s.k, 7)];
        let (want, _) =
            bitserial_mpgemm_pool(&cfg, &planes, &[1, -1], &x, s.n, &serial, 1);
        platinum::ensure_prop!(
            want == naive_mpgemm(&w, s.m, s.k, &x, s.n),
            "serial bitserial wrong vs naive at m={} k={} n={}",
            s.m,
            s.k,
            s.n
        );
        for (&t, pool) in THREAD_MATRIX.iter().zip(&pools) {
            let (got, _) = bitserial_mpgemm_pool(&cfg, &planes, &[1, -1], &x, s.n, pool, t);
            platinum::ensure_prop!(
                got == want,
                "bitserial threads={t} diverged at m={} k={} n={}",
                s.m,
                s.k,
                s.n
            );
        }
        Ok(())
    });
}

#[test]
fn tmac_pool_vs_serial_bit_exact_across_thread_matrix() {
    let pools: Vec<Pool> = THREAD_MATRIX.iter().map(|&t| Pool::new(t)).collect();
    let serial = Pool::new(1);
    platinum::util::check_prop("tmac_pool_matrix", 10, |seed| {
        let mut rng = Rng::seed_from(seed ^ 0x7ac);
        let s = random_shape(&mut rng);
        let w = rng.ternary_vec(s.m * s.k);
        let x = rng.act_vec(s.k * s.n);
        let kernel = TMacCpu::new(&w, s.m, s.k);
        let mut want = vec![0i32; s.m * s.n];
        kernel.gemm_pool(&x, s.n, &mut want, 1, &serial);
        let naive = naive_mpgemm(&w, s.m, s.k, &x, s.n);
        for i in 0..s.m * s.n {
            platinum::ensure_prop!(
                want[i] as i64 == naive[i],
                "serial tmac wrong vs naive at {i} (m={} k={} n={})",
                s.m,
                s.k,
                s.n
            );
        }
        for (&t, pool) in THREAD_MATRIX.iter().zip(&pools) {
            let mut got = vec![0i32; s.m * s.n];
            kernel.gemm_pool(&x, s.n, &mut got, t, pool);
            platinum::ensure_prop!(
                got == want,
                "tmac threads={t} diverged at m={} k={} n={}",
                s.m,
                s.k,
                s.n
            );
        }
        Ok(())
    });
}

#[test]
fn gemms_inside_pool_tasks_do_not_deadlock() {
    // the serving path runs whole GEMMs from inside pool tasks (the
    // batcher prices while workers execute); a GEMM's nested
    // for_each_chunk phases must complete from within a worker
    let pool = Pool::new(4);
    let cfg = PlatinumConfig::default();
    let mut rng = Rng::seed_from(0xD15C);
    let (m, k, n) = (24, 57, 4);
    let w = rng.ternary_vec(m * k);
    let x = rng.act_vec(k * n);
    let packed = pack_ternary(&w, m, k, cfg.c_ternary);
    let want = naive_mpgemm(&w, m, k, &x, n);
    let ok = AtomicUsize::new(0);
    let tasks: Vec<Task> = (0..8)
        .map(|_| {
            let (cfg, packed, x, want, ok, pool_ref) = (&cfg, &packed, &x, &want, &ok, &pool);
            Box::new(move || {
                let (out, _) = ternary_mpgemm_pool(cfg, packed, x, n, pool_ref, 4);
                if out == *want {
                    ok.fetch_add(1, Ordering::Relaxed);
                }
            }) as Task
        })
        .collect();
    pool.run(tasks);
    assert_eq!(ok.load(Ordering::Relaxed), 8, "nested GEMMs must all be correct");
}
