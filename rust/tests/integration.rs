//! Cross-layer integration tests: the L1/L2 PJRT artifacts must agree
//! bit-for-bit with the L3 golden model, and the Python and Rust offline
//! toolchains must be interchangeable (shared path ISA).
//!
//! Requires `make artifacts` (skips politely if missing so `cargo test`
//! stays runnable on a fresh checkout).

use platinum::config::PlatinumConfig;
use platinum::encoding::{self, pack_binary, pack_ternary, ternary_planes};
use platinum::lut::{bitserial_mpgemm, naive_mpgemm, ternary_mpgemm};
use platinum::pathgen;
use platinum::runtime::{HostTensor, Runtime};
use platinum::util::rng::Rng;
use std::path::PathBuf;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        None
    }
}

/// Path entries as the kernel artifacts expect them: (P, 4) i32 rows.
fn path_rows(path: &pathgen::BuildPath) -> Vec<i32> {
    path.entries
        .iter()
        .flat_map(|e| [e.dst as i32, e.src as i32, e.j as i32, e.sign as i32])
        .collect()
}

/// Group a (k × n) activation matrix into the kernel's (C, c, n) layout.
fn chunk_acts(acts: &[i32], k: usize, n: usize, c: usize) -> Vec<i32> {
    let nchunks = k.div_ceil(c);
    let mut out = vec![0i32; nchunks * c * n];
    for kk in 0..k {
        for col in 0..n {
            out[kk * n + col] = acts[kk * n + col];
        }
    }
    out
}

#[test]
fn pjrt_lut_gemm_matches_golden_model() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::new(&dir).unwrap();
    let spec = rt.manifest().find_prefix("lut_gemm").unwrap().clone();
    let (m, k, n) = (
        spec.meta["m"] as usize,
        spec.meta["k"] as usize,
        spec.meta["n"] as usize,
    );
    let c = spec.meta["c"] as usize;

    let mut rng = Rng::seed_from(0xA11CE);
    let w = rng.ternary_vec(m * k);
    let acts = rng.act_vec(k * n);
    let packed = pack_ternary(&w, m, k, c);
    // the RUST-generated path drives the PYTHON-lowered kernel — the
    // cross-language ISA compatibility check
    let path = pathgen::ternary_path(c);

    let inputs = vec![
        HostTensor::I32(packed.data.iter().map(|&b| b as i32).collect()),
        HostTensor::I32(chunk_acts(&acts, k, n, c)),
        HostTensor::I32(path_rows(&path)),
    ];
    let out = rt.execute(&spec.name, &inputs).unwrap();
    let got = out.as_i32().expect("i32 output");

    let want = naive_mpgemm(&w, m, k, &acts, n);
    let cfg = PlatinumConfig::default();
    let (golden, _) = ternary_mpgemm(&cfg, &packed, &acts, n);
    assert_eq!(golden, want, "golden model sanity");
    for i in 0..m * n {
        assert_eq!(got[i] as i64, want[i], "PJRT vs naive at {i}");
    }
}

#[test]
fn pjrt_bitserial_matches_golden_model() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::new(&dir).unwrap();
    let spec = rt.manifest().find_prefix("bitserial").unwrap().clone();
    let (m, k, n) = (
        spec.meta["m"] as usize,
        spec.meta["k"] as usize,
        spec.meta["n"] as usize,
    );
    let c = spec.meta["c"] as usize;

    let mut rng = Rng::seed_from(0xB0B);
    let w = rng.ternary_vec(m * k);
    let acts = rng.act_vec(k * n);
    let (pos, neg) = ternary_planes(&w, m, k);
    let planes = [pack_binary(&pos, m, k, c), pack_binary(&neg, m, k, c)];
    let path = pathgen::binary_path(c);

    let mut planes_i32 = Vec::with_capacity(2 * m * planes[0].chunks());
    for p in &planes {
        planes_i32.extend(p.data.iter().map(|&b| b as i32));
    }
    let inputs = vec![
        HostTensor::I32(planes_i32),
        HostTensor::I32(chunk_acts(&acts, k, n, c)),
        HostTensor::I32(path_rows(&path)),
        HostTensor::I32(vec![1, -1]),
    ];
    let out = rt.execute(&spec.name, &inputs).unwrap();
    let got = out.as_i32().unwrap();

    let cfg = PlatinumConfig::default();
    let (golden, _) = bitserial_mpgemm(&cfg, &planes, &[1, -1], &acts, n);
    for i in 0..m * n {
        assert_eq!(got[i] as i64, golden[i], "PJRT vs golden at {i}");
    }
}

#[test]
fn python_paths_replay_identically_in_rust() {
    let Some(dir) = artifacts_dir() else { return };
    for (tag, c, entries) in [
        ("ternary_c5", 5usize, encoding::lut_entries(5)),
        ("binary_c7", 7, 128),
    ] {
        let p = platinum::isa::load_path_json(&dir.join("paths").join(format!("{tag}.json")))
            .unwrap();
        assert_eq!(p.c, c);
        assert!(p.min_raw_distance >= pathgen::PIPELINE_DEPTH, "{tag} not hazard-free");
        // python-generated path must compute the same LUT as the rust one
        let mut rng = Rng::seed_from(42);
        let acts: Vec<i32> = (0..c).map(|_| rng.range_i64(-127, 127) as i32).collect();
        let rust_path = match p.kind {
            pathgen::PathKind::Ternary => pathgen::ternary_path(c),
            pathgen::PathKind::Binary => pathgen::binary_path(c),
        };
        let lut_py = pathgen::replay(&p, &acts, 1, entries);
        let lut_rs = pathgen::replay(&rust_path, &acts, 1, entries);
        assert_eq!(lut_py, lut_rs, "{tag}: python and rust paths disagree");
    }
}

#[test]
fn pjrt_bitlinear_dequantizes_correctly() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::new(&dir).unwrap();
    let spec = rt.manifest().find_prefix("bitlinear").unwrap().clone();
    let s = spec.meta["s"] as usize;
    let k = spec.meta["k"] as usize;
    let m = spec.meta["m"] as usize;
    let c = spec.meta["c"] as usize;

    let mut rng = Rng::seed_from(7);
    let w = rng.ternary_vec(m * k);
    let packed = pack_ternary(&w, m, k, c);
    let x: Vec<f32> = (0..s * k).map(|_| (rng.f64() as f32 - 0.5)).collect();
    let beta = 0.03f32;
    let path = pathgen::ternary_path(c);

    let inputs = vec![
        HostTensor::F32(x.clone()),
        HostTensor::I32(packed.data.iter().map(|&b| b as i32).collect()),
        HostTensor::F32(vec![beta]),
        HostTensor::I32(path_rows(&path)),
    ];
    let out = rt.execute(&spec.name, &inputs).unwrap();
    let y = out.as_f32().unwrap();
    assert_eq!(y.len(), s * m);

    // reference: absmax-quantize per row, int matmul, dequant
    for row in 0..s {
        let xr = &x[row * k..(row + 1) * k];
        let amax = xr.iter().fold(1e-5f32, |a, &v| a.max(v.abs()));
        let scale = 127.0 / amax;
        let xq: Vec<i64> =
            xr.iter().map(|&v| (v * scale).round().clamp(-127.0, 127.0) as i64).collect();
        for col in (0..m).step_by(97) {
            let dot: i64 = (0..k).map(|i| w[col * k + i] as i64 * xq[i]).sum();
            let want = dot as f32 * beta / scale;
            let got = y[row * m + col];
            assert!(
                (got - want).abs() <= want.abs() * 1e-4 + 1e-4,
                "({row},{col}): {got} vs {want}"
            );
        }
    }
}

#[test]
fn pjrt_block_runs_and_is_causal() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::new(&dir).unwrap();
    let spec = rt.manifest().find("block_s8").unwrap().clone();
    let d = spec.meta["d_model"] as usize;
    let f = spec.meta["d_ffn"] as usize;
    let s = spec.meta["s"] as usize;
    let c = spec.meta["c"] as usize;

    let mut rng = Rng::seed_from(99);
    let path = pathgen::ternary_path(c);
    let mk_packed = |m: usize, k: usize, rng: &mut Rng| -> HostTensor {
        let w = rng.ternary_vec(m * k);
        HostTensor::I32(pack_ternary(&w, m, k, c).data.iter().map(|&b| b as i32).collect())
    };
    let x: Vec<f32> = (0..s * d).map(|_| (rng.f64() as f32 - 0.5) * 0.6).collect();
    let mut inputs = vec![HostTensor::F32(x.clone())];
    inputs.push(mk_packed(3 * d, d, &mut rng)); // wqkv
    inputs.push(HostTensor::F32(vec![0.02]));
    inputs.push(mk_packed(d, d, &mut rng)); // wo
    inputs.push(HostTensor::F32(vec![0.02]));
    inputs.push(mk_packed(f, d, &mut rng)); // wup
    inputs.push(HostTensor::F32(vec![0.02]));
    inputs.push(mk_packed(d, f, &mut rng)); // wdown
    inputs.push(HostTensor::F32(vec![0.02]));
    inputs.push(HostTensor::F32(vec![1.0; d])); // g_attn
    inputs.push(HostTensor::F32(vec![1.0; d])); // g_ffn
    inputs.push(HostTensor::I32(path_rows(&path)));

    let y1 = rt.execute("block_s8", &inputs).unwrap();
    let y1 = y1.as_f32().unwrap().to_vec();
    assert_eq!(y1.len(), s * d);
    assert!(y1.iter().all(|v| v.is_finite()), "block produced non-finite values");

    // causality: perturb the last token, earlier outputs unchanged
    let mut x2 = x.clone();
    x2[(s - 1) * d] += 1.0;
    inputs[0] = HostTensor::F32(x2);
    let y2 = rt.execute("block_s8", &inputs).unwrap();
    let y2 = y2.as_f32().unwrap();
    for i in 0..(s - 1) * d {
        assert!(
            (y1[i] - y2[i]).abs() < 1e-5,
            "causality violated at {i}: {} vs {}",
            y1[i],
            y2[i]
        );
    }
    let last_changed = (0..d).any(|i| (y1[(s - 1) * d + i] - y2[(s - 1) * d + i]).abs() > 1e-6);
    assert!(last_changed, "perturbation had no effect");
}
