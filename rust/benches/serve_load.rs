//! Serving-under-load benchmarks: host-side scheduler overhead per
//! decode step (the control plane must stay negligible next to even a
//! decode-shaped kernel), and goodput at 0.5×/0.9× of saturation on the
//! measured `platinum-cpu` backend (the latency-under-load counterpart
//! to the paper's throughput claims).
//!
//! Rows land in `BENCH_serve_load.json` (override with
//! `BENCH_SERVE_LOAD_JSON=<path>`); `SERVE_LOAD_BUDGET_MS` bounds the
//! overhead measurement like `HOTPATH_BUDGET_MS` does for hotpath.

use platinum::engine::{
    Backend, BackendInfo, BackendKind, PlatinumBackend, Registry, Report, Workload,
};
use platinum::models::BitNetModel;
use platinum::traffic::{
    decode_capacity_tok_s, ArrivalPattern, LenDist, LoadSpec, Scheduler, SchedulerConfig,
    VirtualClock,
};
use platinum::util::bench::{bench, report};
use platinum::util::json::{arr, b as jbool, num, obj, s as jstr, Json};
use std::time::Duration;

/// Small-but-real model for the measured goodput rows (the 700M+ zoo
/// models would push CI's wallclock budget).
const SMALL: BitNetModel = BitNetModel {
    name: "b-small",
    params: "30M",
    hidden: 256,
    ffn: 640,
    heads: 8,
    kv_heads: 8,
    layers: 2,
};

/// Constant-latency pricer: isolates the scheduler's own control-plane
/// cost (queue ops, admission checks, bookkeeping) from backend time.
struct FixedLatency(f64);

impl Backend for FixedLatency {
    fn id(&self) -> &str {
        "fixed-latency"
    }

    fn describe(&self) -> BackendInfo {
        BackendInfo {
            id: "fixed-latency".into(),
            name: "fixed".into(),
            kind: BackendKind::Cpu,
            freq_hz: 0.0,
            pes: None,
            area_mm2: None,
            tech_nm: None,
            notes: "bench-only constant-latency pricer".into(),
        }
    }

    fn run(&self, w: &Workload) -> Report {
        Report {
            backend: "fixed-latency".into(),
            workload: w.label(),
            latency_s: self.0,
            ops: w.naive_adds(),
            ..Report::default()
        }
    }
}

fn main() {
    let budget_ms: u64 = std::env::var("SERVE_LOAD_BUDGET_MS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(300);
    let budget = Duration::from_millis(budget_ms);
    let mut rows: Vec<Json> = Vec::new();

    // --- scheduler overhead per decode step --------------------------------
    // closed-form load: 64 simultaneous requests lockstep-decoding on a
    // zero-ish-cost pricer; wallclock / steps = control-plane ns/step
    let cfg = SchedulerConfig { max_batch: 16, ..SchedulerConfig::default() };
    let spec = LoadSpec {
        pattern: ArrivalPattern::Poisson { rate_rps: 1e6 },
        prompt: LenDist::Fixed(8),
        output: LenDist::Fixed(16),
        requests: 64,
        seed: 42,
    };
    let reqs = spec.generate().unwrap();
    let pricer = FixedLatency(1e-4);
    let sched = Scheduler::new(&pricer, SMALL, cfg);
    let probe = sched.serve(&reqs, &mut VirtualClock::new()).unwrap();
    let steps = probe.metrics.steps().max(1);
    let st = bench(2, budget, || sched.serve(&reqs, &mut VirtualClock::new()).unwrap());
    let ns_per_step = st.per_iter_ns() / steps as f64;
    report(
        "traffic/sched_overhead_per_step",
        &st,
        &format!("  {ns_per_step:.0} ns/step over {steps} steps"),
    );
    rows.push(obj(vec![
        ("name", jstr("traffic/sched_overhead_per_step")),
        ("ns_per_iter", num(st.per_iter_ns())),
        ("steps", num(steps as f64)),
        ("ns_per_step", num(ns_per_step)),
    ]));

    // --- goodput at 0.5× / 0.9× saturation on measured platinum-cpu --------
    // capacity anchor: one full-batch decode step on the real golden
    // kernel; offered token rate is then placed relative to it
    let cpu = Registry::with_defaults().build("platinum-cpu").unwrap();
    let cfg = SchedulerConfig { max_batch: 8, max_queue: 64, ..SchedulerConfig::default() };
    let output = LenDist::Fixed(8);
    let capacity_tok_s = decode_capacity_tok_s(cpu.as_ref(), SMALL, cfg.max_batch);
    println!(
        "\nplatinum-cpu decode capacity on {}: {:.0} tok/s at batch {}",
        SMALL.name, capacity_tok_s, cfg.max_batch
    );
    for frac in [0.5, 0.9] {
        let rate_rps = frac * capacity_tok_s / output.mean();
        let spec = LoadSpec {
            pattern: ArrivalPattern::Poisson { rate_rps },
            prompt: LenDist::Fixed(8),
            output,
            requests: 48,
            seed: 42,
        };
        let sched = Scheduler::new(cpu.as_ref(), SMALL, cfg);
        let r = sched.serve(&spec.generate().unwrap(), &mut VirtualClock::new()).unwrap();
        let m = &r.metrics;
        let name = format!("traffic/goodput_{frac}x_saturation_platinum_cpu");
        println!(
            "{name:<44} {:>8.1} tok/s goodput  batch {:.2}  p99 TTFT {:.2} ms  util {:.0}%",
            m.goodput_tokens_per_s(),
            m.mean_decode_batch(),
            m.ttft.quantile(0.99).unwrap_or(f64::NAN) * 1e3,
            m.utilization() * 100.0
        );
        rows.push(obj(vec![
            ("name", jstr(&name)),
            ("offered_frac_of_capacity", num(frac)),
            ("offered_rps", num(rate_rps)),
            ("goodput_tokens_per_s", num(m.goodput_tokens_per_s())),
            ("mean_decode_batch", num(m.mean_decode_batch())),
            (
                "p99_ttft_s",
                m.ttft.quantile(0.99).map(num).unwrap_or(Json::Null),
            ),
            ("utilization", num(m.utilization())),
        ]));
    }

    // --- chunked prefill: interactive tail TTFT under a mixed tenant load --
    // weight-4 interactive shorts share the scheduler with weight-1 batch
    // longs at 2× the decode knee; splitting the 256-token batch prefills
    // into 32-token chunks lets interactive first tokens land between
    // chunk steps instead of behind a monolithic long prefill
    let ternary = PlatinumBackend::ternary();
    let base = SchedulerConfig {
        max_batch: 8,
        max_queue: 256,
        max_inflight_tokens: 1024,
        ..SchedulerConfig::default()
    };
    let rate_rps = 2.0 * decode_capacity_tok_s(&ternary, SMALL, base.max_batch) / 8.0;
    let mixed_trace = || {
        let spec = LoadSpec {
            pattern: ArrivalPattern::Poisson { rate_rps },
            prompt: LenDist::Fixed(8),
            output: LenDist::Fixed(8),
            requests: 48,
            seed: 42,
        };
        let mut reqs = spec.generate().unwrap();
        for (i, r) in reqs.iter_mut().enumerate() {
            if i % 2 == 1 {
                r.class = 1;
                r.prompt_tokens = 256; // the long batch prompts
            }
        }
        reqs
    };
    let interactive_p99 = |chunk: usize| {
        let mut cfg = SchedulerConfig { prefill_chunk: chunk, ..base };
        cfg.classes = 2;
        cfg.class_weights[0] = 4;
        let sched = Scheduler::new(&ternary, SMALL, cfg);
        let r = sched.serve(&mixed_trace(), &mut VirtualClock::new()).unwrap();
        let classes = r.metrics.classes.expect("two-class run emits per-class metrics");
        classes[0].ttft.quantile(0.99).unwrap_or(f64::NAN)
    };
    let unsplit = interactive_p99(0);
    let chunked = interactive_p99(32);
    println!(
        "\ntraffic/chunked_prefill_interactive_p99_ttft   unsplit {:.2} ms  chunk=32 {:.2} ms  ({:.2}x)",
        unsplit * 1e3,
        chunked * 1e3,
        chunked / unsplit
    );
    rows.push(obj(vec![
        ("name", jstr("traffic/chunked_prefill_interactive_p99_ttft")),
        ("offered_frac_of_capacity", num(2.0)),
        ("p99_ttft_unsplit_s", num(unsplit)),
        ("p99_ttft_chunk32_s", num(chunked)),
        ("ratio_chunked_over_unsplit", num(chunked / unsplit)),
        ("improved", jbool(chunked < unsplit)),
    ]));

    let path = std::env::var("BENCH_SERVE_LOAD_JSON")
        .unwrap_or_else(|_| "BENCH_serve_load.json".to_string());
    let doc = obj(vec![("bench", jstr("serve_load")), ("results", arr(rows))]);
    match std::fs::write(&path, doc.to_string() + "\n") {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("warning: could not write {path}: {e}"),
    }
}
