//! Fig 8 — kernel-level latency across Platinum, T-MAC (CPU),
//! SpikingEyeriss and Prosperity, on every unique BitLinear kernel shape
//! of the three BitNet-b1.58 models, for prefill (N=1024) and decode
//! (N=8) — the same grid the paper plots.

use platinum::analysis::Gemm;
use platinum::baselines::{eyeriss, prosperity, tmac};
use platinum::config::{ExecMode, PlatinumConfig};
use platinum::models::{ALL_MODELS, DECODE_N, PREFILL_N};
use platinum::sim::simulate_gemm;

fn main() {
    let cfg = PlatinumConfig::default();
    println!("Fig 8: kernel latency (ms) — lower is better");
    for (stage, n) in [("prefill", PREFILL_N), ("decode", DECODE_N)] {
        println!("\n== {stage} (N = {n}) ==");
        println!(
            "{:<10} {:<14} {:>12} {:>12} {:>12} {:>12} {:>10}",
            "model", "kernel MxK", "Eyeriss", "Prosperity", "T-MAC", "Platinum", "best spd"
        );
        for model in &ALL_MODELS {
            for (m, k) in model.unique_shapes() {
                let g = Gemm::new(m, k, n);
                let eye = eyeriss::simulate(g, n).latency_s * 1e3;
                let pro = prosperity::simulate(g, n).latency_s * 1e3;
                let tm = tmac::simulate_m2pro(g).latency_s * 1e3;
                let plat = simulate_gemm(&cfg, ExecMode::Ternary, g).latency_s * 1e3;
                let best_base = pro.min(tm);
                println!(
                    "{:<10} {:<14} {:>12.3} {:>12.3} {:>12.3} {:>12.3} {:>9.2}x",
                    model.name,
                    format!("{m}x{k}"),
                    eye,
                    pro,
                    tm,
                    plat,
                    best_base / plat
                );
                assert!(plat < eye && plat < pro, "Platinum must beat the ASIC baselines");
            }
        }
    }
    println!("\npaper shape: Platinum fastest on every kernel, both stages — HOLDS");
}
