//! Fig 8 — kernel-level latency across Platinum, T-MAC (CPU),
//! SpikingEyeriss and Prosperity, on every unique BitLinear kernel shape
//! of the three BitNet-b1.58 models, for prefill (N=1024) and decode
//! (N=8) — the same grid the paper plots.  All systems run through the
//! engine registry.

use platinum::analysis::Gemm;
use platinum::engine::{Backend, Registry, Workload};
use platinum::models::{ALL_MODELS, DECODE_N, PREFILL_N};

fn main() {
    let registry = Registry::with_defaults();
    let eye = registry.build("eyeriss").unwrap();
    let pro = registry.build("prosperity").unwrap();
    let tm = registry.build("tmac").unwrap();
    let plat = registry.build("platinum-ternary").unwrap();
    println!("Fig 8: kernel latency (ms) — lower is better");
    for (stage, n) in [("prefill", PREFILL_N), ("decode", DECODE_N)] {
        println!("\n== {stage} (N = {n}) ==");
        println!(
            "{:<10} {:<14} {:>12} {:>12} {:>12} {:>12} {:>10}",
            "model", "kernel MxK", "Eyeriss", "Prosperity", "T-MAC", "Platinum", "best spd"
        );
        for model in &ALL_MODELS {
            for (m, k) in model.unique_shapes() {
                let w = Workload::Kernel(Gemm::new(m, k, n));
                let r_eye = eye.run(&w).latency_s * 1e3;
                let r_pro = pro.run(&w).latency_s * 1e3;
                let r_tm = tm.run(&w).latency_s * 1e3;
                let r_plat = plat.run(&w).latency_s * 1e3;
                let best_base = r_pro.min(r_tm);
                println!(
                    "{:<10} {:<14} {:>12.3} {:>12.3} {:>12.3} {:>12.3} {:>9.2}x",
                    model.name,
                    format!("{m}x{k}"),
                    r_eye,
                    r_pro,
                    r_tm,
                    r_plat,
                    best_base / r_plat
                );
                assert!(r_plat < r_eye && r_plat < r_pro, "Platinum must beat the ASIC baselines");
            }
        }
    }
    println!("\npaper shape: Platinum fastest on every kernel, both stages — HOLDS");
}
