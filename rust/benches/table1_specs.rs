//! Table I — accelerator specifications: type, frequency, technology,
//! PE count, area, and throughput (GOP/s, naive-adds normalization on
//! b1.58-3B prefill N=1024).

use platinum::baselines::{eyeriss, model_report, prosperity, tmac};
use platinum::config::{ExecMode, PlatinumConfig};
use platinum::energy::AreaModel;
use platinum::models::{B158_3B, PREFILL_N};
use platinum::sim::simulate_model;

fn main() {
    let cfg = PlatinumConfig::default();
    let plat = simulate_model(&cfg, ExecMode::Ternary, &B158_3B, PREFILL_N);
    let area = AreaModel::platinum(&cfg).breakdown().total();
    let eye = model_report(&B158_3B, PREFILL_N, |g| eyeriss::simulate(g, PREFILL_N));
    let pro = model_report(&B158_3B, PREFILL_N, |g| prosperity::simulate(g, PREFILL_N));
    let tm = model_report(&B158_3B, PREFILL_N, |g| tmac::simulate_m2pro(g));

    println!("Table I: accelerator specifications (throughput on b1.58-3B, N=1024)");
    println!(
        "{:<16} {:>6} {:>11} {:>10} {:>8} {:>12} {:>14} {:>12}",
        "", "type", "freq (MHz)", "tech (nm)", "#PEs", "area (mm2)", "GOP/s (ours)", "paper"
    );
    println!(
        "{:<16} {:>6} {:>11} {:>10} {:>8} {:>12} {:>14.1} {:>12}",
        "Eyeriss", "ASIC", 500, 28, 168, "1.07", eye.throughput_gops, "20.8"
    );
    println!(
        "{:<16} {:>6} {:>11} {:>10} {:>8} {:>12} {:>14.1} {:>12}",
        "Prosperity", "ASIC", 500, 28, 256, "1.06*", pro.throughput_gops, "375"
    );
    println!(
        "{:<16} {:>6} {:>11} {:>10} {:>8} {:>12} {:>14.1} {:>12}",
        "T-MAC", "CPU", 3490, 5, "-", "289", tm.throughput_gops, "715"
    );
    println!(
        "{:<16} {:>6} {:>11} {:>10} {:>8} {:>12.3} {:>14.1} {:>12}",
        "Platinum (ours)", "ASIC", 500, 28, cfg.num_pes(), area, plat.throughput_gops, "1534"
    );
    println!("\n* Prosperity scaled for fair comparison (as in the paper)");
    println!("#PEs Platinum = L x n_cols = 52 x 8 = {}", cfg.num_pes());

    // residuals vs paper
    for (name, ours, paper) in [
        ("Eyeriss", eye.throughput_gops, 20.8),
        ("Prosperity", pro.throughput_gops, 375.0),
        ("T-MAC", tm.throughput_gops, 715.0),
        ("Platinum", plat.throughput_gops, 1534.0),
    ] {
        println!("residual {:<12} {:>+7.1}%", name, (ours / paper - 1.0) * 100.0);
    }
    println!("area residual Platinum {:>+7.1}% (ours {:.3} vs paper 0.955)", (area / 0.955 - 1.0) * 100.0, area);
}
