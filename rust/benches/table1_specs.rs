//! Table I — accelerator specifications: type, frequency, technology,
//! PE count, area, and throughput (GOP/s, naive-adds normalization on
//! b1.58-3B prefill N=1024).  Specs come from each engine backend's
//! `describe()`, throughput from `Backend::run` — the whole table is
//! registry-driven.

use platinum::engine::{Backend, Registry, Workload};
use platinum::models::B158_3B;

fn main() {
    let registry = Registry::with_defaults();
    let systems = [
        ("eyeriss", 20.8),
        ("prosperity", 375.0),
        ("tmac", 715.0),
        ("platinum-ternary", 1534.0),
    ];
    let w = Workload::prefill(B158_3B);

    println!("Table I: accelerator specifications (throughput on b1.58-3B, N=1024)");
    println!(
        "{:<20} {:>6} {:>11} {:>10} {:>8} {:>12} {:>14} {:>12}",
        "", "type", "freq (MHz)", "tech (nm)", "#PEs", "area (mm2)", "GOP/s (ours)", "paper"
    );
    let mut rows = Vec::new();
    let mut plat_area = None;
    for (id, paper) in systems {
        let be = registry.build(id).unwrap();
        let info = be.describe();
        let r = be.run(&w);
        println!(
            "{:<20} {:>6} {:>11.0} {:>10} {:>8} {:>12} {:>14.1} {:>12}",
            info.name,
            info.kind.label(),
            info.freq_hz / 1e6,
            info.tech_nm.map(|t| t.to_string()).unwrap_or_else(|| "-".to_string()),
            info.pes.map(|p| p.to_string()).unwrap_or_else(|| "-".to_string()),
            info.area_mm2.map(|a| format!("{a:.3}")).unwrap_or_else(|| "-".to_string()),
            r.throughput_gops,
            paper
        );
        rows.push((info.name, r.throughput_gops, paper));
        if id == "platinum-ternary" {
            plat_area = info.area_mm2;
        }
    }
    println!("\n(Prosperity area scaled for fair comparison, as in the paper)");

    // residuals vs paper
    for (name, ours, paper) in rows {
        println!("residual {:<16} {:>+7.1}%", name, (ours / paper - 1.0) * 100.0);
    }
    let area = plat_area.expect("platinum-ternary models its area");
    println!(
        "area residual Platinum {:>+7.1}% (ours {:.3} vs paper 0.955)",
        (area / 0.955 - 1.0) * 100.0,
        area
    );
}
