//! Fig 7 — design-space exploration over tiling sizes and stationarity,
//! evaluated on the prefill stages of the three BitNet-b1.58 models.
//!
//! Prints the full (latency, energy, area) cloud, marks the Pareto
//! frontier, and verifies the paper's chosen point (m1080 k520 n32,
//! mnk-stationary, red marker in the figure) balances the objectives.

use platinum::config::Tiling;
use platinum::dse;
use platinum::models::ALL_MODELS;
use std::time::Instant;

fn main() {
    let t0 = Instant::now();
    let grid = dse::default_grid();
    let points = dse::sweep(&grid, &ALL_MODELS);
    let front = dse::pareto(&points);
    println!(
        "Fig 7: {} design points (3 models x prefill), swept in {:.2} s",
        points.len(),
        t0.elapsed().as_secs_f64()
    );

    let lat0 = points.iter().map(|p| p.latency_s).fold(f64::MAX, f64::min);
    let en0 = points.iter().map(|p| p.energy_j).fold(f64::MAX, f64::min);
    let ar0 = points.iter().map(|p| p.area_mm2).fold(f64::MAX, f64::min);
    println!(
        "{:<24} {:>8} {:>9} {:>8} {:>9}  flags",
        "tiling", "lat x", "energy x", "area x", "SRAM KB"
    );
    for (i, p) in points.iter().enumerate() {
        let chosen = p.tiling == Tiling::default();
        if front.contains(&i) || chosen {
            println!(
                "{:<24} {:>8.3} {:>9.3} {:>8.3} {:>9.0}  {}{}",
                format!(
                    "m{} k{} n{} {}",
                    p.tiling.m,
                    p.tiling.k,
                    p.tiling.n,
                    p.tiling.order.label()
                ),
                p.latency_s / lat0,
                p.energy_j / en0,
                p.area_mm2 / ar0,
                p.sram_kb,
                if front.contains(&i) { "pareto" } else { "" },
                if chosen { " <-- paper's choice" } else { "" }
            );
        }
    }

    let chosen = points.iter().find(|p| p.tiling == Tiling::default()).unwrap();
    let best_eda = points.iter().map(|p| p.eda_product()).fold(f64::MAX, f64::min);
    let ratio = chosen.eda_product() / best_eda;
    println!("\npaper's choice: EDA product {ratio:.2}x of sweep best (balanced per §IV-C)");
    assert!(ratio < 1.5, "chosen point badly dominated");
    println!("SRAM at chosen point: {:.0} KB (paper: 324 KB)", chosen.sram_kb);
}
