//! Paged-KV pressure benchmarks: what the memory subsystem buys and
//! costs.  Three row families, all on the modelled `platinum-ternary`
//! pricer so the runs are deterministic discrete-event simulations:
//!
//! 1. **Prefix caching** — a replayed trace whose requests share a
//!    system prompt, served with the prefix cache on vs. off: TTFT and
//!    peak block usage must both drop when the shared span is stored
//!    once (the PR's acceptance evidence).
//! 2. **Capacity × policy** — the same load against shrinking block
//!    pools under swap vs. recompute preemption: eviction counts, swap
//!    stall, recomputed tokens, makespan.
//! 3. **DRAM timing models** — the pipe and bank-state models priced on
//!    a streaming and a row-conflict sweep: the bank model must agree
//!    with the pipe on streaming (within the documented bound) and
//!    diverge sharply on conflicts.
//!
//! Rows land in `BENCH_kv.json` (override with `BENCH_KV_JSON=<path>`).

use platinum::engine::Registry;
use platinum::kv::{KvConfig, KvPolicy};
use platinum::models::BitNetModel;
use platinum::sim::{DramModelKind, DRAM_BANKS, DRAM_ROW_BYTES};
use platinum::traffic::{
    with_shared_prefix, ArrivalPattern, LenDist, LoadSpec, Scheduler, SchedulerConfig,
    TrafficRequest, VirtualClock,
};
use platinum::util::json::{arr, num, obj, s as jstr, Json};

/// 2-layer toy model (256 KV bytes/token): pricing stays microseconds.
const TINY: BitNetModel = BitNetModel {
    name: "tiny",
    params: "2M",
    hidden: 64,
    ffn: 160,
    heads: 4,
    kv_heads: 4,
    layers: 2,
};

/// Replayed trace: 32 requests in 4 bursts of 8, every prompt carrying
/// the same 96-token system prefix plus a short unique tail.
fn shared_prompt_trace() -> Vec<TrafficRequest> {
    let times_s: Vec<f64> = (0..32).map(|i| (i / 8) as f64 * 0.02).collect();
    let spec = LoadSpec {
        pattern: ArrivalPattern::Replay { times_s },
        prompt: LenDist::Uniform { lo: 4, hi: 12 },
        output: LenDist::Fixed(8),
        requests: 32,
        seed: 17,
    };
    let mut reqs = spec.generate().unwrap();
    with_shared_prefix(&mut reqs, 96);
    reqs
}

fn serve(reqs: &[TrafficRequest], kv: KvConfig) -> platinum::traffic::TrafficMetrics {
    let be = Registry::with_defaults().build("platinum-ternary").unwrap();
    let cfg = SchedulerConfig { kv, ..SchedulerConfig::default() };
    let sched = Scheduler::new(be.as_ref(), TINY, cfg);
    sched.serve(reqs, &mut VirtualClock::new()).unwrap().metrics
}

fn main() {
    let mut rows: Vec<Json> = Vec::new();
    let reqs = shared_prompt_trace();

    // --- 1. prefix caching: TTFT + peak blocks, cache on vs off ------------
    println!("== prefix caching on a replayed shared-prompt trace ==");
    let mut by_cache: Vec<(bool, f64, u64)> = Vec::new();
    for prefix_cache in [true, false] {
        let kv = KvConfig { prefix_cache, ..KvConfig::default() };
        let m = serve(&reqs, kv);
        let ttft = m.ttft.mean().unwrap();
        let label = if prefix_cache { "on" } else { "off" };
        println!(
            "  cache {label:<3}  mean TTFT {:>8.3} ms  peak blocks {:>4}  \
             hits {}/{}  tokens saved {}",
            ttft * 1e3,
            m.kv.allocated_max,
            m.kv.prefix_hits,
            m.kv.prefix_lookups,
            m.kv.prefix_tokens_saved
        );
        rows.push(obj(vec![
            ("name", jstr(&format!("kv/prefix_cache_{label}"))),
            ("prefix_cache", jstr(label)),
            ("mean_ttft_s", num(ttft)),
            ("p99_ttft_s", m.ttft.quantile(0.99).map(num).unwrap_or(Json::Null)),
            ("allocated_blocks_max", num(m.kv.allocated_max as f64)),
            ("prefix_hits", num(m.kv.prefix_hits as f64)),
            ("prefix_tokens_saved", num(m.kv.prefix_tokens_saved as f64)),
            ("makespan_s", num(m.makespan_s)),
        ]));
        by_cache.push((prefix_cache, ttft, m.kv.allocated_max));
    }
    let (on, off) = (&by_cache[0], &by_cache[1]);
    assert!(on.1 < off.1, "prefix caching must cut TTFT: {} vs {}", on.1, off.1);
    assert!(on.2 < off.2, "prefix caching must cut peak blocks: {} vs {}", on.2, off.2);
    println!(
        "  -> TTFT x{:.2}, peak blocks x{:.2} with the cache on",
        on.1 / off.1,
        on.2 as f64 / off.2 as f64
    );

    // --- 2. capacity sweep × pressure policy -------------------------------
    // TINY blocks are 4 KiB at the default 16 tok/block; shrink the pool
    // until preemption starts, under both policies
    println!("\n== capacity x policy (shrinking pool, same load) ==");
    for sram_kib in [512, 96, 48] {
        for policy in [KvPolicy::Recompute, KvPolicy::Swap] {
            let kv = KvConfig { sram_kib, dram_mib: 0, policy, ..KvConfig::default() };
            let m = serve(&reqs, kv);
            assert_eq!(m.completed, 32, "pressure must delay, not drop");
            println!(
                "  {:>4} KiB {:<9}  makespan {:>8.3} ms  evictions {:>3}  \
                 swap stall {:>7.3} ms  recomputed {:>4} tok  util {:>5.2}",
                sram_kib,
                policy.label(),
                m.makespan_s * 1e3,
                m.kv.evictions,
                m.kv.swap_stall_s * 1e3,
                m.kv.recomputed_tokens,
                m.kv.utilization()
            );
            rows.push(obj(vec![
                ("name", jstr(&format!("kv/pressure_{}kib_{}", sram_kib, policy.label()))),
                ("sram_kib", num(sram_kib as f64)),
                ("policy", jstr(policy.label())),
                ("capacity_blocks", num(m.kv.capacity_blocks as f64)),
                ("makespan_s", num(m.makespan_s)),
                ("evictions", num(m.kv.evictions as f64)),
                ("swap_stall_s", num(m.kv.swap_stall_s)),
                ("recomputed_tokens", num(m.kv.recomputed_tokens as f64)),
                ("utilization", num(m.kv.utilization())),
                ("mean_ttft_s", m.ttft.mean().map(num).unwrap_or(Json::Null)),
            ]));
        }
    }

    // --- 3. DRAM timing models: streaming agreement, conflict divergence ---
    println!("\n== dram models: 64 KiB streaming vs row-conflict stride ==");
    let sweep = |kind: DramModelKind, stride: u64, label: &str| -> u64 {
        let mut dram = kind.build(64e9, 500e6).unwrap();
        let mut cycles = 0u64;
        for i in 0..256u64 {
            cycles += dram.transfer_cycles_at(i * stride, 256);
        }
        println!("  {:<4} {label:<18} {cycles:>8} cycles", kind.label());
        cycles
    };
    let conflict_stride = DRAM_ROW_BYTES as u64 * DRAM_BANKS as u64;
    let pipe_stream = sweep(DramModelKind::Pipe, 256, "streaming");
    let bank_stream = sweep(DramModelKind::Bank, 256, "streaming");
    let pipe_conflict = sweep(DramModelKind::Pipe, conflict_stride, "conflict stride");
    let bank_conflict = sweep(DramModelKind::Bank, conflict_stride, "conflict stride");
    let stream_rel = (bank_stream as f64 - pipe_stream as f64).abs() / pipe_stream as f64;
    let conflict_x = bank_conflict as f64 / pipe_conflict as f64;
    assert!(stream_rel < 0.25, "streaming agreement bound blown: {stream_rel:.3}");
    assert!(conflict_x > 3.0, "conflicts must diverge: x{conflict_x:.1}");
    println!("  -> streaming divergence {:.1}%, conflict slowdown x{conflict_x:.1}", stream_rel * 100.0);
    rows.push(obj(vec![
        ("name", jstr("kv/dram_model_agreement")),
        ("pipe_stream_cycles", num(pipe_stream as f64)),
        ("bank_stream_cycles", num(bank_stream as f64)),
        ("stream_rel_divergence", num(stream_rel)),
        ("pipe_conflict_cycles", num(pipe_conflict as f64)),
        ("bank_conflict_cycles", num(bank_conflict as f64)),
        ("conflict_slowdown_x", num(conflict_x)),
    ]));

    let path = std::env::var("BENCH_KV_JSON").unwrap_or_else(|_| "BENCH_kv.json".to_string());
    let doc = obj(vec![("bench", jstr("kv_pressure")), ("results", arr(rows))]);
    match std::fs::write(&path, doc.to_string() + "\n") {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("warning: could not write {path}: {e}"),
    }
}
