//! Fig 5 — #addition reduction for ternary-weight mpGEMM over LUT sizes
//! (M = 1080 per the caption; K from b1.58-3B, N = 1).
//!
//! Regenerates the four curves: naive, bit-serial Eq(1), ternary-LUT
//! Eq(2), Platinum Eq(3); cross-checks Eq(3)'s construction term against
//! the golden datapath's measured op counters.

use platinum::analysis::{self, Gemm};
use platinum::config::PlatinumConfig;
use platinum::encoding::pack_ternary;
use platinum::lut::ternary_mpgemm;
use platinum::util::rng::Rng;

fn main() {
    let g = Gemm::new(1080, 3200, 1);
    println!("Fig 5: additions vs LUT size (M={}, K={}, N={})", g.m, g.k, g.n);
    println!(
        "{:<4} {:>10} {:>14} {:>14} {:>14} {:>14}  reduction",
        "c", "LUT size", "naive", "bit-serial(1)", "ternary(2)", "Platinum(3)"
    );
    let rows = analysis::fig5_series(g, 2..=8);
    for r in &rows {
        println!(
            "{:<4} {:>10} {:>14} {:>14} {:>14} {:>14}  {:>6.2}x",
            r.c,
            r.lut_size_ternary,
            r.naive,
            r.bitserial,
            r.ternary_lut,
            r.platinum,
            r.naive as f64 / r.platinum as f64
        );
    }
    let best = rows.iter().min_by_key(|r| r.platinum).unwrap();
    println!(
        "\nbest chunk: c={} ({}-entry LUT) — {:.2}x fewer additions than naive",
        best.c,
        best.lut_size_ternary,
        best.naive as f64 / best.platinum as f64
    );
    assert_eq!(analysis::best_chunk(g, 8), best.c);

    // cross-check Eq(3) construction term against measured golden ops
    let cfg = PlatinumConfig::default();
    let mut rng = Rng::seed_from(5);
    let (m, k, n) = (64, 200, 1);
    let w = rng.ternary_vec(m * k);
    let x = rng.act_vec(k * n);
    let packed = pack_ternary(&w, m, k, 5);
    let (_, ops) = ternary_mpgemm(&cfg, &packed, &x, n);
    let kc = (k as u64).div_ceil(5);
    let expect_construct = kc * 121; // ⌈3^c/2⌉−1 adds per chunk, 1 lane
    assert_eq!(ops.construct_adds, expect_construct, "Eq(3) vs measured");
    println!(
        "golden-model cross-check: construct adds {} == Eq(3) term {} ✓",
        ops.construct_adds, expect_construct
    );
    let holds =
        rows.iter().all(|r| best.platinum <= r.bitserial && best.platinum <= r.ternary_lut);
    println!(
        "\npaper shape: Platinum lowest across all chunk sizes — {}",
        if holds { "HOLDS" } else { "VIOLATED" }
    );
}
