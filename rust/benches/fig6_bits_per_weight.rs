//! Fig 6 — average encoded bits per ternary weight vs pack size c.
//!
//! The sign|index encoding packs c weights into ⌈log2 3^c⌉ bits; the
//! minimum (1.6 b/w) lands at c=5, fitting one byte — the paper's choice.

use platinum::analysis::fig6_series;
use platinum::encoding::{self, pack_ternary};
use platinum::util::rng::Rng;

fn main() {
    println!(
        "Fig 6: encoded bits per weight vs pack size (entropy floor: log2(3) = {:.3})",
        3f64.log2()
    );
    println!("{:<4} {:>10} {:>12} {:>14}", "c", "bits", "bits/weight", "overhead vs H");
    for (c, bpw) in fig6_series(1..=10) {
        println!(
            "{:<4} {:>10} {:>12.3} {:>13.1}%{}",
            c,
            encoding::index_bits(c) + 1,
            bpw,
            (bpw / 3f64.log2() - 1.0) * 100.0,
            if c == 5 { "   <-- minimum (paper's choice: 1 byte / 5 weights)" } else { "" }
        );
    }

    // empirical check: pack a real matrix and measure the actual rate
    let mut rng = Rng::seed_from(6);
    let (m, k) = (1024, 3200);
    let w = rng.ternary_vec(m * k);
    let p = pack_ternary(&w, m, k, 5);
    let measured = p.data.len() as f64 * 8.0 / (m * k) as f64;
    println!("\nmeasured on a {m}x{k} matrix: {measured:.3} bits/weight");
    assert!((measured - 1.6).abs() < 1e-9);
    println!(
        "vs T-MAC's 2-bit encoding: {:.0}% smaller weight footprint",
        (1.0 - 1.6 / 2.0) * 100.0
    );
}
