//! Hot-path microbenchmarks (real wallclock on this machine) — the
//! §Perf substrate: offline toolchain throughput, golden-datapath
//! throughput (with 1/4/8-thread pool sweeps), the real T-MAC CPU
//! kernel (same sweeps), scheduler microbenches (tiny-task fork-join,
//! dynamic chunk claiming, a ragged decode shape — the work-stealing
//! paths PR 4 introduced), simulator speed, and manifest parsing.
//! Regenerated before/after every optimization iteration.
//!
//! Besides the human-readable report, every row is recorded to
//! `BENCH_hotpath.json` (override with `BENCH_HOTPATH_JSON=<path>`) as
//! `{name, ns_per_iter, rate_per_s, unit}` so the perf trajectory is
//! machine-diffable across commits; CI runs a smoke invocation with
//! `HOTPATH_BUDGET_MS=40`.

use platinum::analysis::Gemm;
use platinum::baselines::tmac::TMacCpu;
use platinum::config::{ExecMode, PlatinumConfig};
use platinum::encoding::pack_ternary;
use platinum::engine::{Backend, PlatinumBackend, PlatinumCpuBackend, Registry, Workload};
use platinum::lut::{naive_mpgemm, ternary_mpgemm, ternary_mpgemm_pool};
use platinum::models::B158_3B;
use platinum::pathgen;
use platinum::runtime::pool::{Pool, Task};
use platinum::sim::{simulate_gemm, simulate_model};
use platinum::util::bench::{bench, fmt_rate, report, Stats};
use platinum::util::json::{arr, num, obj, s as jstr, Json};
use platinum::util::rng::Rng;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::Duration;

/// Collects every reported row for the machine-readable sidecar.
struct Recorder {
    rows: Vec<Json>,
}

impl Recorder {
    fn new() -> Recorder {
        Recorder { rows: Vec::new() }
    }

    /// Print the human row and record the JSON one.  `rate` is
    /// (per-second quantity, unit), e.g. `(1.2e9, "op")`.
    fn row(&mut self, name: &str, stats: &Stats, rate: Option<(f64, &str)>) {
        let extra = rate.map(|(r, u)| fmt_rate(r, u)).unwrap_or_default();
        report(name, stats, &extra);
        self.rows.push(obj(vec![
            ("name", jstr(name)),
            ("ns_per_iter", num(stats.per_iter_ns())),
            (
                "rate_per_s",
                rate.map(|(r, _)| num(r)).unwrap_or(Json::Null),
            ),
            (
                "unit",
                rate.map(|(_, u)| jstr(u)).unwrap_or(Json::Null),
            ),
        ]));
    }

    fn write(self, path: &str) {
        let doc = obj(vec![("bench", jstr("hotpath")), ("results", arr(self.rows))]);
        match std::fs::write(path, doc.to_string() + "\n") {
            Ok(()) => println!("\nwrote {path}"),
            Err(e) => eprintln!("warning: could not write {path}: {e}"),
        }
    }
}

fn main() {
    let budget_ms: u64 = std::env::var("HOTPATH_BUDGET_MS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(300);
    let budget = Duration::from_millis(budget_ms);
    let mut rec = Recorder::new();
    let mut rng = Rng::seed_from(0xBE);

    // --- offline toolchain -------------------------------------------------
    let st = bench(2, budget, || pathgen::ternary_path(5));
    rec.row("pathgen/ternary_c5", &st, None);
    let st = bench(2, budget, || pathgen::binary_path(7));
    rec.row("pathgen/binary_c7", &st, None);

    let (m, k) = (1080, 520);
    let w = rng.ternary_vec(m * k);
    let st = bench(2, budget, || pack_ternary(&w, m, k, 5));
    let rate = (m * k) as f64 / (st.per_iter_ns() * 1e-9);
    rec.row("encode/pack_ternary_1080x520", &st, Some((rate, "wt")));

    // --- golden datapath vs naive vs real T-MAC ----------------------------
    let (gm, gk, gn) = (512, 520, 8);
    let gw = rng.ternary_vec(gm * gk);
    let gx = rng.act_vec(gk * gn);
    let packed = pack_ternary(&gw, gm, gk, 5);
    let cfg = PlatinumConfig::default();
    let ops = (gm * gk * gn) as f64;

    // headline: the default entry point (process-wide pool, all cores)
    let st = bench(2, budget, || ternary_mpgemm(&cfg, &packed, &gx, gn));
    let r = ops / (st.per_iter_ns() * 1e-9);
    rec.row("golden/lut_mpgemm_512x520x8", &st, Some((r, "op")));

    let st = bench(2, budget, || naive_mpgemm(&gw, gm, gk, &gx, gn));
    rec.row(
        "golden/naive_512x520x8",
        &st,
        Some((ops / (st.per_iter_ns() * 1e-9), "op")),
    );

    let tm = TMacCpu::new(&gw, gm, gk);
    let mut out = vec![0i32; gm * gn];

    // thread sweeps on pinned-size pools: the scaling trajectory the
    // acceptance criteria pin (golden ≥4x, tmac ≥2x at 8T vs seed)
    for threads in [1usize, 4, 8] {
        let pool = Pool::new(threads);
        let st = bench(2, budget, || {
            ternary_mpgemm_pool(&cfg, &packed, &gx, gn, &pool, threads)
        });
        let r = ops / (st.per_iter_ns() * 1e-9);
        rec.row(
            &format!("golden/lut_mpgemm_512x520x8_{threads}T"),
            &st,
            Some((r, "op")),
        );
        let st = bench(2, budget, || tm.gemm_pool(&gx, gn, &mut out, threads, &pool));
        let r = ops / (st.per_iter_ns() * 1e-9);
        rec.row(
            &format!("tmac_cpu/gemm_512x520x8_{threads}T"),
            &st,
            Some((r, "op")),
        );
    }

    let gx1 = rng.act_vec(gk);
    let mut out1 = vec![0i32; gm];
    let st = bench(2, budget, || tm.gemv(&gx1, &mut out1));
    rec.row(
        "tmac_cpu/gemv_512x520",
        &st,
        Some(((gm * gk) as f64 / (st.per_iter_ns() * 1e-9), "op")),
    );

    // --- scheduler (PR 4: work stealing + dynamic chunking) -----------------
    // fork-join of thousands of sub-microsecond tasks — the decode-shaped
    // submission pattern that convoyed on the seed's single shared queue
    let pool8 = Pool::new(8);
    let st = bench(2, budget, || {
        let hits = AtomicUsize::new(0);
        let tasks: Vec<Task> = (0..2048)
            .map(|_| {
                Box::new(|| {
                    hits.fetch_add(1, Ordering::Relaxed);
                }) as Task
            })
            .collect();
        pool8.run(tasks);
        hits.load(Ordering::Relaxed)
    });
    rec.row(
        "pool/forkjoin_2048_tiny_8T",
        &st,
        Some((2048.0 / (st.per_iter_ns() * 1e-9), "task")),
    );

    // chunk-claim overhead of the dynamic scheduler: 64K trivial indices
    let st = bench(2, budget, || {
        let sum = AtomicUsize::new(0);
        pool8.for_each_chunk(8, 65_536, 0, &|r| {
            sum.fetch_add(r.len(), Ordering::Relaxed);
        });
        sum.load(Ordering::Relaxed)
    });
    rec.row(
        "pool/for_each_chunk_64k_8T",
        &st,
        Some((65_536.0 / (st.per_iter_ns() * 1e-9), "idx")),
    );

    // ragged decode shape: 97 rows over 8 lanes, k across a round
    // boundary — the load-balance case static stripes handled worst
    let (rm, rk, rn) = (97, 523, 3);
    let rw = rng.ternary_vec(rm * rk);
    let rx = rng.act_vec(rk * rn);
    let rpacked = pack_ternary(&rw, rm, rk, 5);
    let st = bench(2, budget, || ternary_mpgemm_pool(&cfg, &rpacked, &rx, rn, &pool8, 8));
    let r = (rm * rk * rn) as f64 / (st.per_iter_ns() * 1e-9);
    rec.row("golden/lut_mpgemm_97x523x3_8T", &st, Some((r, "op")));

    // --- simulator speed ----------------------------------------------------
    let g = Gemm::new(3200, 3200, 1024);
    let st = bench(1, budget, || simulate_gemm(&cfg, ExecMode::Ternary, g));
    let r = simulate_gemm(&cfg, ExecMode::Ternary, g);
    rec.row(
        "sim/kernel_3200x3200x1024",
        &st,
        Some((r.cycles as f64 / (st.per_iter_ns() * 1e-9), "simcycle")),
    );

    let st = bench(1, budget, || simulate_model(&cfg, ExecMode::Ternary, &B158_3B, 1024));
    rec.row("sim/model_3B_prefill", &st, None);

    // --- engine API overhead ------------------------------------------------
    // the unified Backend surface must stay a zero-ish-cost wrapper over
    // the raw simulator calls above
    let be = PlatinumBackend::ternary();
    let st = bench(1, budget, || be.run(&Workload::Kernel(g)));
    rec.row("engine/kernel_3200x3200x1024", &st, None);
    let st = bench(1, budget, || be.run(&Workload::prefill(B158_3B)));
    rec.row("engine/model_3B_prefill", &st, None);
    let st = bench(2, budget, || Registry::with_defaults().build("prosperity").unwrap());
    rec.row("engine/registry_build", &st, None);

    // the multi-chip composite: partition + 4 replica sim runs + merge
    // must stay cheap relative to the single-chip model pass above
    let sharded4 = Registry::with_defaults().build("sharded:4:platinum-ternary").unwrap();
    let st = bench(1, budget, || sharded4.run(&Workload::prefill(B158_3B)));
    rec.row("engine/sharded4_model_3B_prefill", &st, None);

    // the measured golden backend end to end (includes weight synthesis
    // + packing per call, amortized by its internal shape memo)
    let pcpu = PlatinumCpuBackend::new();
    let st = bench(1, budget, || pcpu.run(&Workload::Kernel(Gemm::new(gm, gk, gn))));
    rec.row("engine/platinum_cpu_kernel_512x520x8", &st, None);

    // --- manifest / json ----------------------------------------------------
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        let text = std::fs::read_to_string(dir.join("manifest.json")).unwrap();
        let st = bench(2, budget, || platinum::util::json::Json::parse(&text).unwrap());
        rec.row(
            "json/manifest_parse",
            &st,
            Some((text.len() as f64 / (st.per_iter_ns() * 1e-9), "B")),
        );
    }

    let path = std::env::var("BENCH_HOTPATH_JSON")
        .unwrap_or_else(|_| "BENCH_hotpath.json".to_string());
    rec.write(&path);
}
