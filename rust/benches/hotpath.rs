//! Hot-path microbenchmarks (real wallclock on this machine) — the
//! §Perf substrate: offline toolchain throughput, golden-datapath
//! throughput, the real T-MAC CPU kernel, simulator speed, and manifest
//! parsing.  Regenerated before/after every optimization iteration.

use platinum::analysis::Gemm;
use platinum::baselines::tmac::TMacCpu;
use platinum::config::{ExecMode, PlatinumConfig};
use platinum::encoding::pack_ternary;
use platinum::engine::{Backend, PlatinumBackend, Registry, Workload};
use platinum::lut::{naive_mpgemm, ternary_mpgemm};
use platinum::models::B158_3B;
use platinum::pathgen;
use platinum::sim::{simulate_gemm, simulate_model};
use platinum::util::bench::{bench, fmt_rate, report};
use platinum::util::rng::Rng;
use std::time::Duration;

fn main() {
    let budget = Duration::from_millis(300);
    let mut rng = Rng::seed_from(0xBE);

    // --- offline toolchain -------------------------------------------------
    let s = bench(2, budget, || pathgen::ternary_path(5));
    report("pathgen/ternary_c5", &s, "");
    let s = bench(2, budget, || pathgen::binary_path(7));
    report("pathgen/binary_c7", &s, "");

    let (m, k) = (1080, 520);
    let w = rng.ternary_vec(m * k);
    let s = bench(2, budget, || pack_ternary(&w, m, k, 5));
    let rate = (m * k) as f64 / (s.per_iter_ns() * 1e-9);
    report("encode/pack_ternary_1080x520", &s, &fmt_rate(rate, "wt"));

    // --- golden datapath vs naive vs real T-MAC ----------------------------
    let (gm, gk, gn) = (512, 520, 8);
    let gw = rng.ternary_vec(gm * gk);
    let gx = rng.act_vec(gk * gn);
    let packed = pack_ternary(&gw, gm, gk, 5);
    let cfg = PlatinumConfig::default();
    let ops = (gm * gk * gn) as f64;

    let s = bench(2, budget, || ternary_mpgemm(&cfg, &packed, &gx, gn));
    report("golden/lut_mpgemm_512x520x8", &s, &fmt_rate(ops / (s.per_iter_ns() * 1e-9), "op"));

    let s = bench(2, budget, || naive_mpgemm(&gw, gm, gk, &gx, gn));
    report("golden/naive_512x520x8", &s, &fmt_rate(ops / (s.per_iter_ns() * 1e-9), "op"));

    let tm = TMacCpu::new(&gw, gm, gk);
    let mut out = vec![0i32; gm * gn];
    let s = bench(2, budget, || tm.gemm(&gx, gn, &mut out, 1));
    report("tmac_cpu/gemm_512x520x8_1T", &s, &fmt_rate(ops / (s.per_iter_ns() * 1e-9), "op"));

    let gx1 = rng.act_vec(gk);
    let mut out1 = vec![0i32; gm];
    let s = bench(2, budget, || tm.gemv(&gx1, &mut out1));
    report("tmac_cpu/gemv_512x520", &s, &fmt_rate((gm * gk) as f64 / (s.per_iter_ns() * 1e-9), "op"));

    // --- simulator speed ----------------------------------------------------
    let g = Gemm::new(3200, 3200, 1024);
    let s = bench(1, budget, || simulate_gemm(&cfg, ExecMode::Ternary, g));
    let r = simulate_gemm(&cfg, ExecMode::Ternary, g);
    report(
        "sim/kernel_3200x3200x1024",
        &s,
        &fmt_rate(r.cycles as f64 / (s.per_iter_ns() * 1e-9), "simcycle"),
    );

    let s = bench(1, budget, || simulate_model(&cfg, ExecMode::Ternary, &B158_3B, 1024));
    report("sim/model_3B_prefill", &s, "");

    // --- engine API overhead ------------------------------------------------
    // the unified Backend surface must stay a zero-ish-cost wrapper over
    // the raw simulator calls above
    let be = PlatinumBackend::ternary();
    let s = bench(1, budget, || be.run(&Workload::Kernel(g)));
    report("engine/kernel_3200x3200x1024", &s, "");
    let s = bench(1, budget, || be.run(&Workload::prefill(B158_3B)));
    report("engine/model_3B_prefill", &s, "");
    let s = bench(2, budget, || Registry::with_defaults().build("prosperity").unwrap());
    report("engine/registry_build", &s, "");

    // --- manifest / json ----------------------------------------------------
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        let text = std::fs::read_to_string(dir.join("manifest.json")).unwrap();
        let s = bench(2, budget, || platinum::util::json::Json::parse(&text).unwrap());
        report("json/manifest_parse", &s, &fmt_rate(text.len() as f64 / (s.per_iter_ns() * 1e-9), "B"));
    }
}
