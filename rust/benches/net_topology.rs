//! Interconnect-topology benchmarks (ISSUE 10): what the event-driven
//! `sim::net` timeline prices that the closed-form analytic
//! interconnect cannot, and how the three topologies compare at the
//! same chip count.  Three row families, all deterministic (modelled
//! `platinum-ternary` pricer, virtual clock, fixed calibration):
//!
//! 1. **Analytic agreement** — a contention-free single-hop gather
//!    (2-replica ring) priced by both models: the gap must stay under
//!    10% (the validation pin the ROADMAP records).
//! 2. **Congestion divergence** — an all-to-all burst on an 8-node
//!    ring: the event makespan must exceed the contention-blind bound
//!    (the slowest solo transfer) by more than 1.5x, because every
//!    stripe queues on shared links the analytic model never sees.
//! 3. **Topology comparison** — ring / mesh2d / fattree at 8 chips:
//!    gather makespan and queueing, end-to-end sharded kernel latency,
//!    and the priced failover redistribution fan-out per topology.
//!
//! Rows land in `BENCH_net.json` (override with `BENCH_NET_JSON=<path>`).

use platinum::config::Gemm;
use platinum::engine::{Interconnect, Registry, Workload};
use platinum::models::B158_3B;
use platinum::sim::net::{NetSim, Topology, Transfer};
use platinum::util::json::{arr, num, obj, s as jstr, Json};

fn main() {
    let mut rows: Vec<Json> = Vec::new();
    let reg = Registry::with_defaults();
    let ic = Interconnect::default();
    let w = Workload::Kernel(Gemm::new(4320, 2080, 32));

    // --- 1. contention-free agreement --------------------------------------
    println!("== analytic vs event: contention-free 2-replica gather ==");
    let analytic = reg.build("sharded:2:platinum-ternary").unwrap().run(&w).latency_s;
    let event = reg.build("sharded:2:net=ring:platinum-ternary").unwrap().run(&w).latency_s;
    let gap = (event - analytic).abs() / analytic;
    assert!(gap < 0.10, "contention-free gap must stay under 10%: {gap:.4}");
    println!(
        "  analytic {:>9.3} us  event {:>9.3} us  gap {:.2}%",
        analytic * 1e6,
        event * 1e6,
        gap * 100.0
    );
    rows.push(obj(vec![
        ("name", jstr("net/contention_free_agreement")),
        ("analytic_latency_s", num(analytic)),
        ("event_latency_s", num(event)),
        ("rel_gap", num(gap)),
    ]));

    // --- 2. all-to-all congestion vs the contention-blind bound ------------
    println!("\n== all-to-all congestion on an 8-node ring ==");
    let chips = 8;
    let net = NetSim::new(Topology::Ring, chips, ic.link_bytes_per_s, ic.hop_s).unwrap();
    let stripe = 1_048_576.0; // 1 MiB per pairwise stripe
    let mut xfers = Vec::new();
    let mut blind: f64 = 0.0;
    for src in 0..chips {
        for dst in 0..chips {
            if src != dst {
                xfers.push(Transfer { src, dst, bytes: stripe, start_s: 0.0 });
                blind = blind.max(net.solo_latency_s(src, dst, stripe));
            }
        }
    }
    let rep = net.simulate(&xfers);
    let ratio = rep.makespan_s / blind;
    assert!(ratio > 1.5, "congestion must exceed the contention-blind bound: x{ratio:.2}");
    println!(
        "  {} transfers  blind bound {:>8.3} us  event {:>8.3} us  x{ratio:.2}  \
         queue wait {:>8.3} us (max {:>7.3} us)",
        xfers.len(),
        blind * 1e6,
        rep.makespan_s * 1e6,
        rep.queue_wait_s * 1e6,
        rep.max_queue_wait_s * 1e6
    );
    rows.push(obj(vec![
        ("name", jstr("net/all_to_all_congestion")),
        ("transfers", num(xfers.len() as f64)),
        ("blind_bound_s", num(blind)),
        ("event_makespan_s", num(rep.makespan_s)),
        ("congestion_x", num(ratio)),
        ("queue_wait_s", num(rep.queue_wait_s)),
        ("max_queue_wait_s", num(rep.max_queue_wait_s)),
    ]));

    // --- 3. topology comparison at 8 chips ----------------------------------
    // same gather, same kernel, same crash: only the wiring changes
    println!("\n== topologies at 8 chips: gather / kernel / failover ==");
    let weight_bytes = B158_3B.weight_bytes_ternary();
    for topo in Topology::ALL {
        let net = NetSim::new(topo, chips, ic.link_bytes_per_s, ic.hop_s).unwrap();
        let gather: Vec<Transfer> = (1..chips)
            .map(|src| Transfer { src, dst: 0, bytes: stripe, start_s: 0.0 })
            .collect();
        let g = net.simulate(&gather);
        let id = format!("sharded:8:net={}:platinum-ternary", topo.label());
        let be = reg.build(&id).unwrap();
        let latency = be.run(&w).latency_s;
        let redist = be.redistribute_cost_s(weight_bytes, chips - 1);
        assert!(latency > 0.0 && redist > 0.0);
        println!(
            "  {:<7}  gather {:>8.3} us (queue {:>7.3} us)  kernel {:>9.3} us  \
             redistribution {:>9.3} us",
            topo.label(),
            g.makespan_s * 1e6,
            g.queue_wait_s * 1e6,
            latency * 1e6,
            redist * 1e6
        );
        rows.push(obj(vec![
            ("name", jstr(&format!("net/topology_{}", topo.label()))),
            ("topology", jstr(topo.label())),
            ("chips", num(chips as f64)),
            ("gather_makespan_s", num(g.makespan_s)),
            ("gather_queue_wait_s", num(g.queue_wait_s)),
            ("kernel_latency_s", num(latency)),
            ("redistribution_s", num(redist)),
        ]));
    }

    let path = std::env::var("BENCH_NET_JSON").unwrap_or_else(|_| "BENCH_net.json".to_string());
    let doc = obj(vec![("bench", jstr("net_topology")), ("results", arr(rows))]);
    match std::fs::write(&path, doc.to_string() + "\n") {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("warning: could not write {path}: {e}"),
    }
}
