//! Fig 9 — kernel-level energy across Platinum, T-MAC (CPU),
//! SpikingEyeriss and Prosperity, same kernel grid as Fig 8.

use platinum::analysis::Gemm;
use platinum::baselines::{eyeriss, prosperity, tmac};
use platinum::config::{ExecMode, PlatinumConfig};
use platinum::models::{ALL_MODELS, DECODE_N, PREFILL_N};
use platinum::sim::simulate_gemm;

fn main() {
    let cfg = PlatinumConfig::default();
    println!("Fig 9: kernel energy (mJ) — lower is better");
    for (stage, n) in [("prefill", PREFILL_N), ("decode", DECODE_N)] {
        println!("\n== {stage} (N = {n}) ==");
        println!(
            "{:<10} {:<14} {:>12} {:>12} {:>12} {:>12} {:>10}",
            "model", "kernel MxK", "Eyeriss", "Prosperity", "T-MAC", "Platinum", "best sav"
        );
        for model in &ALL_MODELS {
            for (m, k) in model.unique_shapes() {
                let g = Gemm::new(m, k, n);
                let eye = eyeriss::simulate(g, n).energy_j * 1e3;
                let pro = prosperity::simulate(g, n).energy_j * 1e3;
                let tm = tmac::simulate_m2pro(g).energy_j * 1e3;
                let plat = simulate_gemm(&cfg, ExecMode::Ternary, g).energy_j() * 1e3;
                let best_base = pro.min(tm).min(eye);
                println!(
                    "{:<10} {:<14} {:>12.3} {:>12.3} {:>12.3} {:>12.3} {:>9.2}x",
                    model.name,
                    format!("{m}x{k}"),
                    eye,
                    pro,
                    tm,
                    plat,
                    best_base / plat
                );
                assert!(plat < eye && plat < tm, "Platinum must beat Eyeriss and T-MAC energy");
            }
        }
    }
    println!("\npaper shape: Platinum most energy-efficient on every kernel — HOLDS");
}
