//! Fig 9 — kernel-level energy across Platinum, T-MAC (CPU),
//! SpikingEyeriss and Prosperity, same kernel grid as Fig 8, all
//! systems through the engine registry.

use platinum::analysis::Gemm;
use platinum::engine::{Backend, Registry, Workload};
use platinum::models::{ALL_MODELS, DECODE_N, PREFILL_N};

fn main() {
    let registry = Registry::with_defaults();
    let eye = registry.build("eyeriss").unwrap();
    let pro = registry.build("prosperity").unwrap();
    let tm = registry.build("tmac").unwrap();
    let plat = registry.build("platinum-ternary").unwrap();
    println!("Fig 9: kernel energy (mJ) — lower is better");
    for (stage, n) in [("prefill", PREFILL_N), ("decode", DECODE_N)] {
        println!("\n== {stage} (N = {n}) ==");
        println!(
            "{:<10} {:<14} {:>12} {:>12} {:>12} {:>12} {:>10}",
            "model", "kernel MxK", "Eyeriss", "Prosperity", "T-MAC", "Platinum", "best sav"
        );
        for model in &ALL_MODELS {
            for (m, k) in model.unique_shapes() {
                let w = Workload::Kernel(Gemm::new(m, k, n));
                let e = |r: platinum::engine::Report| r.energy_j.expect("modelled") * 1e3;
                let e_eye = e(eye.run(&w));
                let e_pro = e(pro.run(&w));
                let e_tm = e(tm.run(&w));
                let e_plat = e(plat.run(&w));
                let best_base = e_pro.min(e_tm).min(e_eye);
                println!(
                    "{:<10} {:<14} {:>12.3} {:>12.3} {:>12.3} {:>12.3} {:>9.2}x",
                    model.name,
                    format!("{m}x{k}"),
                    e_eye,
                    e_pro,
                    e_tm,
                    e_plat,
                    best_base / e_plat
                );
                assert!(e_plat < e_eye && e_plat < e_tm, "Platinum must beat Eyeriss/T-MAC");
            }
        }
    }
    println!("\npaper shape: Platinum most energy-efficient on every kernel — HOLDS");
}
