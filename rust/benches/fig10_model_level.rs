//! Fig 10 — model-level speedup and energy-efficiency improvements of
//! Platinum on BitNet b1.58-3B (prefill N=1024 / decode N=8), vs
//! SpikingEyeriss, Prosperity, 16-thread T-MAC, and Platinum-bs.
//!
//! Paper values: prefill speedups 73.6x / 4.09x / 2.15x; decode 47.6x /
//! 28.4x / 1.75x; prefill energy 32.4x / 3.23x / 20.9x / 1.34x(bs);
//! decode energy 18.4x / 15.3x / 15.0x / 1.31x(bs).

use platinum::baselines::{eyeriss, model_report, prosperity, tmac};
use platinum::config::{ExecMode, PlatinumConfig};
use platinum::models::{B158_3B, DECODE_N, PREFILL_N};
use platinum::sim::simulate_model;

fn main() {
    let cfg = PlatinumConfig::default();
    let mut cfg_bs = cfg.clone();
    cfg_bs.tiling.k = 728; // Platinum-bs retiles k to 2 rounds of 52x7

    for (stage, n, paper_spd, paper_en) in [
        ("prefill", PREFILL_N, [73.6, 4.09, 2.15], [32.4, 3.23, 20.9]),
        ("decode", DECODE_N, [47.6, 28.4, 1.75], [18.4, 15.3, 15.0]),
    ] {
        let plat = simulate_model(&cfg, ExecMode::Ternary, &B158_3B, n);
        let bs = simulate_model(&cfg_bs, ExecMode::BitSerial { planes: 2 }, &B158_3B, n);
        let eye = model_report(&B158_3B, n, |g| eyeriss::simulate(g, n));
        let pro = model_report(&B158_3B, n, |g| prosperity::simulate(g, n));
        let tm = model_report(&B158_3B, n, |g| tmac::simulate_m2pro(g));

        println!("\n== {stage} (N = {n}) — b1.58-3B ==");
        println!(
            "{:<16} {:>12} {:>12} {:>14} {:>14}",
            "vs", "speedup", "paper", "energy sav", "paper"
        );
        for (name, lat, en, ps, pe) in [
            ("SpikingEyeriss", eye.latency_s, eye.energy_j, paper_spd[0], paper_en[0]),
            ("Prosperity", pro.latency_s, pro.energy_j, paper_spd[1], paper_en[1]),
            ("T-MAC 16T", tm.latency_s, tm.energy_j, paper_spd[2], paper_en[2]),
        ] {
            println!(
                "{:<16} {:>11.2}x {:>11.2}x {:>13.2}x {:>13.2}x",
                name,
                lat / plat.latency_s,
                ps,
                en / plat.energy_j(),
                pe
            );
        }
        let bs_spd = bs.latency_s / plat.latency_s;
        let bs_en = bs.energy_j() / plat.energy_j();
        let paper_bs_en = if stage == "prefill" { 1.34 } else { 1.31 };
        println!(
            "{:<16} {:>11.2}x {:>11} {:>13.2}x {:>13.2}x",
            "Platinum-bs", bs_spd, "1.3-1.4x", bs_en, paper_bs_en
        );
        println!(
            "Platinum absolute: {:.0} GOP/s, {:.3} J, {:.2} W",
            plat.throughput_gops,
            plat.energy_j(),
            plat.power_w()
        );
    }
    println!("\npaper shape (who wins, roughly what factor): HOLDS (see asserts in `cargo test`)");
}
