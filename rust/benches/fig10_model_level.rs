//! Fig 10 — model-level speedup and energy-efficiency improvements of
//! Platinum on BitNet b1.58-3B (prefill N=1024 / decode N=8), vs
//! SpikingEyeriss, Prosperity, 16-thread T-MAC, and Platinum-bs — every
//! system selected from the engine registry and run through
//! `Backend::run` on the same `Workload::ModelPass`.
//!
//! Paper values: prefill speedups 73.6x / 4.09x / 2.15x; decode 47.6x /
//! 28.4x / 1.75x; prefill energy 32.4x / 3.23x / 20.9x / 1.34x(bs);
//! decode energy 18.4x / 15.3x / 15.0x / 1.31x(bs).

use platinum::engine::{Backend, Registry, Workload};
use platinum::models::{B158_3B, DECODE_N, PREFILL_N};

fn main() {
    let registry = Registry::with_defaults();
    let plat = registry.build("platinum-ternary").unwrap();
    let bs = registry.build("platinum-bitserial").unwrap();
    let eye = registry.build("eyeriss").unwrap();
    let pro = registry.build("prosperity").unwrap();
    let tm = registry.build("tmac").unwrap();

    // single-chip Platinum passes, computed once and reused by both the
    // per-stage tables and the multi-chip scaling section below
    let r_plat_pre = plat.run(&Workload::model_pass(B158_3B, PREFILL_N));
    let r_plat_dec = plat.run(&Workload::model_pass(B158_3B, DECODE_N));

    for (stage, n, paper_spd, paper_en) in [
        ("prefill", PREFILL_N, [73.6, 4.09, 2.15], [32.4, 3.23, 20.9]),
        ("decode", DECODE_N, [47.6, 28.4, 1.75], [18.4, 15.3, 15.0]),
    ] {
        let w = Workload::model_pass(B158_3B, n);
        let r_plat = if n == PREFILL_N { &r_plat_pre } else { &r_plat_dec };
        let r_bs = bs.run(&w);
        let r_eye = eye.run(&w);
        let r_pro = pro.run(&w);
        let r_tm = tm.run(&w);

        println!("\n== {stage} (N = {n}) — b1.58-3B ==");
        println!(
            "{:<16} {:>12} {:>12} {:>14} {:>14}",
            "vs", "speedup", "paper", "energy sav", "paper"
        );
        let plat_energy = r_plat.energy_j.expect("platinum models energy");
        for (name, lat, en, ps, pe) in [
            ("SpikingEyeriss", r_eye.latency_s, r_eye.energy_j, paper_spd[0], paper_en[0]),
            ("Prosperity", r_pro.latency_s, r_pro.energy_j, paper_spd[1], paper_en[1]),
            ("T-MAC 16T", r_tm.latency_s, r_tm.energy_j, paper_spd[2], paper_en[2]),
        ] {
            println!(
                "{:<16} {:>11.2}x {:>11.2}x {:>13.2}x {:>13.2}x",
                name,
                lat / r_plat.latency_s,
                ps,
                en.expect("modelled") / plat_energy,
                pe
            );
        }
        let bs_spd = r_bs.latency_s / r_plat.latency_s;
        let bs_en = r_bs.energy_j.expect("modelled") / plat_energy;
        let paper_bs_en = if stage == "prefill" { 1.34 } else { 1.31 };
        println!(
            "{:<16} {:>11.2}x {:>11} {:>13.2}x {:>13.2}x",
            "Platinum-bs", bs_spd, "1.3-1.4x", bs_en, paper_bs_en
        );
        println!(
            "Platinum absolute: {:.0} GOP/s, {:.3} J, {:.2} W",
            r_plat.throughput_gops,
            plat_energy,
            r_plat.power_w().expect("platinum models energy")
        );
    }
    println!("\npaper shape (who wins, roughly what factor): HOLDS (see asserts in `cargo test`)");

    // --- multi-chip scaling (beyond the paper: the engine's sharded
    // composite, rows strategy, modelled interconnect included) --------
    println!("\n== multi-chip scaling — sharded:<N>:platinum-ternary, b1.58-3B ==");
    println!(
        "{:<28} {:>14} {:>10} {:>14} {:>10}",
        "backend", "prefill GOP/s", "scale eff", "decode GOP/s", "scale eff"
    );
    // chips = 1 is the hoisted single-chip pass (sharded:1 is a
    // bit-exact passthrough — no need to simulate it again)
    println!(
        "{:<28} {:>14.0} {:>9.1}% {:>14.0} {:>9.1}%",
        "platinum-ternary", r_plat_pre.throughput_gops, 100.0, r_plat_dec.throughput_gops, 100.0
    );
    for chips in [2usize, 4, 8] {
        let be = registry.build(&format!("sharded:{chips}:platinum-ternary")).unwrap();
        let pre = be.run(&Workload::model_pass(B158_3B, PREFILL_N));
        let dec = be.run(&Workload::model_pass(B158_3B, DECODE_N));
        let eff = |r: &platinum::engine::Report, base: &platinum::engine::Report| {
            100.0 * r.throughput_gops / (base.throughput_gops * chips as f64)
        };
        println!(
            "{:<28} {:>14.0} {:>9.1}% {:>14.0} {:>9.1}%",
            be.id(),
            pre.throughput_gops,
            eff(&pre, &r_plat_pre),
            dec.throughput_gops,
            eff(&dec, &r_plat_dec)
        );
    }
    println!("(efficiency <100%: replicated LUT construction + the modelled interconnect merge)");
}
