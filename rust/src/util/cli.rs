//! Tiny CLI argument parser — replacement for `clap`.
//!
//! Supports `command --flag`, `--key value`, `--key=value` and
//! positional arguments, with typed getters and usage errors.

use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;

#[derive(Debug, Default)]
pub struct Args {
    pub command: Option<String>,
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
}

/// Parse process args (everything after argv[0]); the first bare token
/// becomes the subcommand.
pub fn parse(argv: impl IntoIterator<Item = String>) -> Result<Args> {
    let mut out = Args::default();
    let mut iter = argv.into_iter().peekable();
    while let Some(tok) = iter.next() {
        if let Some(stripped) = tok.strip_prefix("--") {
            if stripped.is_empty() {
                bail!("bare '--' is not supported");
            }
            if let Some((k, v)) = stripped.split_once('=') {
                out.flags.insert(k.to_string(), v.to_string());
            } else if iter.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                let v = iter.next().unwrap();
                out.flags.insert(stripped.to_string(), v);
            } else {
                out.flags.insert(stripped.to_string(), "true".to_string());
            }
        } else if out.command.is_none() {
            out.command = Some(tok);
        } else {
            out.positional.push(tok);
        }
    }
    Ok(out)
}

impl Args {
    pub fn flag(&self, key: &str) -> bool {
        self.flags.get(key).map(|v| v != "false").unwrap_or(false)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{key} expects an integer, got {v:?}")),
        }
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{key} expects a number, got {v:?}")),
        }
    }

    pub fn get_str<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.flags.get(key).map(|s| s.as_str()).unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn p(s: &str) -> Args {
        parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn parses_subcommand_and_flags() {
        let a = p("simulate --model 3b --n 1024 --verbose");
        assert_eq!(a.command.as_deref(), Some("simulate"));
        assert_eq!(a.get("model"), Some("3b"));
        assert_eq!(a.get_usize("n", 0).unwrap(), 1024);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn parses_equals_form_and_positional() {
        let a = p("dse --points=4 out.json");
        assert_eq!(a.get_usize("points", 0).unwrap(), 4);
        assert_eq!(a.positional, vec!["out.json"]);
    }

    #[test]
    fn negative_numbers_as_values() {
        let a = p("x --bias -3");
        assert_eq!(a.get("bias"), Some("-3"));
    }

    #[test]
    fn type_errors() {
        assert!(p("x --n abc").get_usize("n", 0).is_err());
    }
}
