//! Deterministic PRNG (xoshiro256**) — replacement for the `rand` crate.
//!
//! Workload generators, synthetic weights, and property tests all seed
//! from this so every experiment is reproducible bit-for-bit.

/// xoshiro256** by Blackman & Vigna (public domain reference algorithm).
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via splitmix64 (never yields the all-zero state).
    pub fn seed_from(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9e37_79b9_7f4a_7c15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, n) via Lemire's multiply-shift (unbiased enough for
    /// simulation workloads; n must be > 0).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform integer in [lo, hi] inclusive.
    #[inline]
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u64) as i64
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform ternary weight in {-1, 0, 1} (the BitNet distribution the
    /// paper assumes: "uniformly distributed weights").
    #[inline]
    pub fn ternary(&mut self) -> i8 {
        (self.below(3) as i8) - 1
    }

    /// Vector of uniform ternary weights.
    pub fn ternary_vec(&mut self, len: usize) -> Vec<i8> {
        (0..len).map(|_| self.ternary()).collect()
    }

    /// Vector of int8-range activations.
    pub fn act_vec(&mut self, len: usize) -> Vec<i32> {
        (0..len).map(|_| self.range_i64(-127, 127) as i32).collect()
    }

    /// Exponentially distributed f64 with rate λ (request arrivals).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        -(1.0 - self.f64()).ln() / lambda
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::seed_from(42);
        let mut b = Rng::seed_from(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_is_in_range() {
        let mut r = Rng::seed_from(7);
        for _ in 0..10_000 {
            assert!(r.below(13) < 13);
        }
    }

    #[test]
    fn ternary_hits_all_values_uniformly() {
        let mut r = Rng::seed_from(9);
        let mut counts = [0u32; 3];
        for _ in 0..30_000 {
            counts[(r.ternary() + 1) as usize] += 1;
        }
        for c in counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "{counts:?}");
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::seed_from(11);
        let mean: f64 = (0..10_000).map(|_| r.f64()).sum::<f64>() / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02);
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::seed_from(13);
        let mean: f64 = (0..20_000).map(|_| r.exponential(2.0)).sum::<f64>() / 20_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }
}
