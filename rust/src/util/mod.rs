//! Self-contained substrates (S0): this environment is fully offline, so
//! the usual ecosystem crates (serde_json, rand, clap, criterion,
//! proptest) are unavailable — each is replaced by a small, tested,
//! purpose-built implementation here.

pub mod bench;
pub mod cli;
pub mod env;
pub mod json;
pub mod rng;

/// Lightweight property-testing loop: runs `f` over `cases` seeds
/// derived from a fixed master seed; on failure reports the seed so the
/// case can be replayed.  The stand-in for proptest.
pub fn check_prop<F: FnMut(u64) -> Result<(), String>>(name: &str, cases: u32, mut f: F) {
    let mut rng = rng::Rng::seed_from(0x9e37_79b9_7f4a_7c15 ^ name.len() as u64);
    for i in 0..cases {
        let seed = rng.next_u64();
        if let Err(msg) = f(seed) {
            panic!("property {name} failed at case {i} (seed {seed:#x}): {msg}");
        }
    }
}

/// `prop_assert!`-style helper for [`check_prop`] closures.
#[macro_export]
macro_rules! ensure_prop {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_prop_runs_all_cases() {
        let mut n = 0;
        check_prop("counter", 25, |_| {
            n += 1;
            Ok(())
        });
        assert_eq!(n, 25);
    }

    #[test]
    #[should_panic(expected = "property failing")]
    fn check_prop_reports_failure() {
        check_prop("failing", 5, |s| if s % 2 == 0 { Err("even".into()) } else { Ok(()) });
    }
}
