//! Loud environment-knob parsing: every `PLATINUM_*` tuning variable
//! funnels through here so a typo'd value is a startup error naming the
//! variable and the offending text — never a silent fallback to the
//! default (which looks exactly like a successful calibration until the
//! numbers are wrong).

use anyhow::{bail, Result};

/// Read `key` from the environment.  Unset → `Ok(None)` (the caller
/// keeps its default).  Set → `parse` must accept the trimmed value,
/// otherwise this is a hard error naming the variable, the offending
/// value, and what would have been accepted.
pub fn read<T>(key: &str, expect: &str, parse: impl Fn(&str) -> Option<T>) -> Result<Option<T>> {
    match std::env::var(key) {
        Err(std::env::VarError::NotPresent) => Ok(None),
        Err(std::env::VarError::NotUnicode(_)) => {
            bail!("invalid {key}: value is not valid unicode (expected {expect})")
        }
        Ok(raw) => match parse(raw.trim()) {
            Some(v) => Ok(Some(v)),
            None => bail!("invalid {key}={raw:?}: expected {expect}"),
        },
    }
}

/// Positive-integer knob (block sizes, KiB/MiB budgets).
pub fn positive_usize(key: &str) -> Result<Option<usize>> {
    read(key, "a positive integer", |t| t.parse::<usize>().ok().filter(|v| *v > 0))
}

/// Strictly-positive finite float knob (bandwidths, time constants).
pub fn positive_f64(key: &str) -> Result<Option<f64>> {
    read(key, "a finite number > 0", |t| {
        t.parse::<f64>().ok().filter(|v| v.is_finite() && *v > 0.0)
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unset_is_none_set_parses_and_junk_is_loud() {
        // narrow set → read → remove windows (PR 5 pattern)
        std::env::remove_var("PLATINUM_ENV_TEST_A");
        assert_eq!(positive_f64("PLATINUM_ENV_TEST_A").unwrap(), None);

        std::env::set_var("PLATINUM_ENV_TEST_A", " 2.5 ");
        let got = positive_f64("PLATINUM_ENV_TEST_A");
        std::env::remove_var("PLATINUM_ENV_TEST_A");
        assert_eq!(got.unwrap(), Some(2.5));

        std::env::set_var("PLATINUM_ENV_TEST_A", "fast");
        let err = positive_f64("PLATINUM_ENV_TEST_A").unwrap_err().to_string();
        std::env::remove_var("PLATINUM_ENV_TEST_A");
        assert!(err.contains("PLATINUM_ENV_TEST_A"), "{err}");
        assert!(err.contains("fast"), "error must name the offending value: {err}");
    }

    #[test]
    fn zero_negative_and_nonfinite_are_rejected() {
        for bad in ["0", "-3", "nan", "inf", ""] {
            std::env::set_var("PLATINUM_ENV_TEST_B", bad);
            let got = positive_f64("PLATINUM_ENV_TEST_B");
            std::env::remove_var("PLATINUM_ENV_TEST_B");
            assert!(got.is_err(), "{bad:?} must be rejected loudly");
        }
        std::env::set_var("PLATINUM_ENV_TEST_C", "0");
        let got = positive_usize("PLATINUM_ENV_TEST_C");
        std::env::remove_var("PLATINUM_ENV_TEST_C");
        assert!(got.is_err(), "zero is not a usable knob value");
    }
}
