//! Micro-benchmark harness — replacement for `criterion`.
//!
//! Warmup + timed iterations with median/mean/min reporting, used by the
//! `rust/benches/*.rs` targets (all `harness = false`).

use std::hint::black_box;
use std::time::{Duration, Instant};

#[derive(Debug, Clone, Copy)]
pub struct Stats {
    pub iters: u32,
    pub mean: Duration,
    pub median: Duration,
    pub min: Duration,
}

impl Stats {
    pub fn per_iter_ns(&self) -> f64 {
        self.median.as_nanos() as f64
    }
}

/// Run `f` repeatedly for roughly `budget` (after `warmup` iterations)
/// and report timing statistics.  `f`'s return value is black-boxed.
pub fn bench<T, F: FnMut() -> T>(warmup: u32, budget: Duration, mut f: F) -> Stats {
    for _ in 0..warmup {
        black_box(f());
    }
    let mut samples: Vec<Duration> = Vec::new();
    let start = Instant::now();
    while start.elapsed() < budget || samples.len() < 5 {
        let t0 = Instant::now();
        black_box(f());
        samples.push(t0.elapsed());
        if samples.len() >= 10_000 {
            break;
        }
    }
    samples.sort_unstable();
    let n = samples.len();
    let mean = samples.iter().sum::<Duration>() / n as u32;
    Stats { iters: n as u32, mean, median: samples[n / 2], min: samples[0] }
}

/// Format a duration human-readably (ns/µs/ms/s).
pub fn fmt_duration(d: Duration) -> String {
    let ns = d.as_nanos() as f64;
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Format a rate (x/s) with SI prefixes.
pub fn fmt_rate(per_sec: f64, unit: &str) -> String {
    if per_sec >= 1e12 {
        format!("{:.2} T{unit}/s", per_sec / 1e12)
    } else if per_sec >= 1e9 {
        format!("{:.2} G{unit}/s", per_sec / 1e9)
    } else if per_sec >= 1e6 {
        format!("{:.2} M{unit}/s", per_sec / 1e6)
    } else if per_sec >= 1e3 {
        format!("{:.2} K{unit}/s", per_sec / 1e3)
    } else {
        format!("{per_sec:.2} {unit}/s")
    }
}

/// Print one result row in a stable, grep-friendly format.
pub fn report(name: &str, stats: &Stats, extra: &str) {
    println!(
        "bench {name:<44} median {:>10}  mean {:>10}  min {:>10}  iters {:>5}  {extra}",
        fmt_duration(stats.median),
        fmt_duration(stats.mean),
        fmt_duration(stats.min),
        stats.iters,
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_collects_samples() {
        let s = bench(2, Duration::from_millis(10), || 2u64 + 2);
        assert!(s.iters >= 5);
        assert!(s.min <= s.median && s.median <= s.mean * 10);
    }

    #[test]
    fn formats() {
        assert_eq!(fmt_duration(Duration::from_nanos(500)), "500 ns");
        assert_eq!(fmt_duration(Duration::from_micros(1500)), "1.50 ms");
        assert!(fmt_rate(1.5e9, "op").contains("Gop/s"));
    }
}
