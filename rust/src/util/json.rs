//! Minimal JSON parser/serializer — replacement for `serde_json`.
//!
//! Parses the artifact manifest and build-path payloads emitted by the
//! python toolchain, and serializes experiment reports.  Supports the
//! full JSON grammar except `\u` surrogate pairs outside the BMP.

use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing characters at byte {}", p.i);
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Object member lookup that errors with the key name.
    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key).ok_or_else(|| anyhow!("missing key {key:?}"))
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Serialize (stable key order via BTreeMap).
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    x.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience constructors for report emission.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(n: f64) -> Json {
    Json::Num(n)
}

pub fn b(v: bool) -> Json {
    Json::Bool(v)
}

pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

pub fn arr(v: Vec<Json>) -> Json {
    Json::Arr(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b.get(self.i).copied().ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn expect(&mut self, ch: u8) -> Result<()> {
        if self.peek()? != ch {
            bail!("expected {:?} at byte {}, found {:?}", ch as char, self.i, self.peek()? as char);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => bail!("unexpected character {:?} at byte {}", c as char, self.i),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(key, v);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}', found {:?}", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected ',' or ']', found {:?}", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            out.push(char::from_u32(cp).ok_or_else(|| anyhow!("bad codepoint"))?);
                        }
                        c => bail!("bad escape \\{}", c as char),
                    }
                }
                c if c < 0x80 => out.push(c as char),
                _ => {
                    // multi-byte UTF-8: find the char boundary
                    let start = self.i - 1;
                    let mut end = self.i;
                    while end < self.b.len() && (self.b[end] & 0xc0) == 0x80 {
                        end += 1;
                    }
                    out.push_str(std::str::from_utf8(&self.b[start..end])?);
                    self.i = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        if self.peek()? == b'-' {
            self.i += 1;
        }
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(text.parse()?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("\"hi\\n\"").unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": {}}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("c")
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("{'a':1}").is_err());
    }

    #[test]
    fn roundtrip() {
        let text = r#"{"arr":[1,2.5,"x"],"nested":{"k":true},"z":null}"#;
        let j = Json::parse(text).unwrap();
        assert_eq!(Json::parse(&j.to_string()).unwrap(), j);
    }

    #[test]
    fn unicode_and_escapes() {
        let j = Json::parse(r#""é\t€ é""#).unwrap();
        assert_eq!(j.as_str(), Some("é\t€ é"));
    }

    #[test]
    fn parses_real_manifest() {
        // shape of the python-emitted manifest
        let text = r#"{"artifacts": [{"name": "x", "file": "x.hlo.txt",
            "inputs": [{"name": "a", "shape": [2, 3], "dtype": "i32"}],
            "outputs": [{"shape": [2], "dtype": "f32"}], "meta": {"m": 2}}],
            "c_ternary": 5}"#;
        let j = Json::parse(text).unwrap();
        let a = &j.get("artifacts").unwrap().as_arr().unwrap()[0];
        assert_eq!(a.get("name").unwrap().as_str(), Some("x"));
        assert_eq!(
            a.get("inputs").unwrap().as_arr().unwrap()[0]
                .get("shape")
                .unwrap()
                .as_arr()
                .unwrap()
                .len(),
            2
        );
    }
}
