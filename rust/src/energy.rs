//! Area / energy models at 28 nm (paper §V-A "Hardware Modeling").
//!
//! The paper synthesizes RTL with Synopsys DC against a commercial 28 nm
//! library, models SRAM with CACTI 7.0, and DRAM with DRAMsim3
//! (64 GB DDR4-2133R).  None of those tools exist in this environment,
//! so this module substitutes *calibrated analytical models*:
//!
//! * [`SramMacro`] — a CACTI-shaped model: capacity/ports/banks →
//!   area (mm²), read/write energy (pJ/byte), leakage (mW).  The fitted
//!   constants reproduce the paper's published aggregates (§V-B: buffers
//!   ≈ 65 % of 0.955 mm²; +LUT ≈ 83.3 %; weight-buffer ≈ 31.6 % of 3.2 W).
//! * [`SynthTable`] — per-cell dynamic energies and areas for adders,
//!   pipeline registers, and controllers at 28 nm / 500 MHz, in line
//!   with public 28 nm characterization data.
//! * [`DRAM_PJ_PER_BIT`] — an aggregate DDR4-2133 access energy
//!   (activate + rd/wr + IO + refresh amortized), the quantity DRAMsim3
//!   ultimately feeds into the paper's energy totals.
//!
//! Every constant is a *model parameter*, documented and unit-tested
//! against the paper's breakdown; EXPERIMENTS.md records the residuals.

use crate::config::PlatinumConfig;

/// Aggregate DDR4-2133 energy per bit transferred (pJ/bit).
///
/// DRAMsim3-style decomposition at ~2133 MT/s: ACT/PRE ≈ 2–4, RD/WR core
/// ≈ 6–8, IO/termination ≈ 7–10 pJ/bit ⇒ ~18 pJ/bit sustained.
pub const DRAM_PJ_PER_BIT: f64 = 18.0;

/// DRAM static/refresh power for the 64 GB DDR4 rank pool (mW).
pub const DRAM_STATIC_MW: f64 = 150.0;

/// One on-chip SRAM macro (CACTI-like analytical model).
#[derive(Debug, Clone, Copy)]
pub struct SramMacro {
    pub kbytes: f64,
    pub read_ports: u32,
    pub write_ports: u32,
    pub banks: u32,
}

impl SramMacro {
    pub fn single_port(kbytes: f64, banks: u32) -> Self {
        SramMacro { kbytes, read_ports: 1, write_ports: 1, banks }
    }

    /// Dual-ported macro (the per-PPE LUT buffer: 1RW + 1R, §III-A).
    pub fn dual_port(kbytes: f64, banks: u32) -> Self {
        SramMacro { kbytes, read_ports: 2, write_ports: 1, banks }
    }

    /// Area in mm² at 28 nm.
    ///
    /// Base density ~2.0 mm²/MB for single-port 28 nm SRAM incl.
    /// periphery; each extra port costs ~50 % (CACTI multiport scaling);
    /// each bank pays a periphery floor (decoders/sense amps).
    pub fn area_mm2(&self) -> f64 {
        let mb = self.kbytes / 1024.0;
        let port_factor = 1.0 + 0.5 * ((self.read_ports + self.write_ports) as f64 - 2.0);
        let periphery_floor = 0.0006 * self.banks as f64; // mm² per bank
        2.0 * mb * port_factor + periphery_floor
    }

    /// Read energy for a *broadcast* macro whose outputs traverse the
    /// whole PPE array (the weight buffer feeds all 52 PPEs every
    /// cycle): wire energy dominates, so it scales with total macro
    /// capacity rather than bank size.  Anchored to reproduce the
    /// paper's §V-B weight-buffer power share (31.6 % of 3.2 W).
    pub fn broadcast_read_pj_per_byte(&self) -> f64 {
        2.2 * self.kbytes.sqrt()
    }

    /// Read energy in pJ per byte.
    ///
    /// CACTI-shaped capacity scaling: E/B grows ~√capacity of the *bank*;
    /// anchored at ~1.1 pJ/B for a 1 KB bank and ~20 pJ/B for a ~300 KB
    /// single-bank macro — which reproduces the paper's weight-buffer
    /// power share (§V-B).
    pub fn read_pj_per_byte(&self) -> f64 {
        let bank_kb = (self.kbytes / self.banks as f64).max(0.25);
        1.1 * bank_kb.sqrt().max(1.0)
    }

    /// Write energy in pJ per byte (~1.15× read for SRAM).
    pub fn write_pj_per_byte(&self) -> f64 {
        self.read_pj_per_byte() * 1.15
    }

    /// Leakage power in mW (≈0.09 mW/KB at 28 nm HVT periphery mix,
    /// plus port overhead).
    pub fn leakage_mw(&self) -> f64 {
        let port_factor = 1.0 + 0.4 * ((self.read_ports + self.write_ports) as f64 - 2.0);
        0.09 * self.kbytes * port_factor
    }
}

/// Synthesized-logic unit costs at 28 nm, 500 MHz (DC-style estimates).
#[derive(Debug, Clone, Copy)]
pub struct SynthTable {
    /// 8-bit adder dynamic energy (pJ/op).
    pub add8_pj: f64,
    /// 32-bit accumulator add (pJ/op).
    pub add32_pj: f64,
    /// 8-bit adder area (mm²).
    pub add8_mm2: f64,
    /// 32-bit adder area (mm²).
    pub add32_mm2: f64,
    /// Pipeline register bank per PPE (mm²).
    pub ppe_regs_mm2: f64,
    /// PPE controller (decode + addressing) area (mm²).
    pub ppe_ctrl_mm2: f64,
    /// Logic leakage per mm² (mW/mm²).
    pub logic_leak_mw_per_mm2: f64,
}

impl Default for SynthTable {
    fn default() -> Self {
        SynthTable {
            add8_pj: 0.03,
            add32_pj: 0.1,
            add8_mm2: 6.0e-5,
            add32_mm2: 1.2e-4,
            ppe_regs_mm2: 6.0e-4,
            ppe_ctrl_mm2: 5.0e-4,
            logic_leak_mw_per_mm2: 25.0,
        }
    }
}

/// Full-chip area model (→ §V-B area breakdown, Table I).
#[derive(Debug, Clone)]
pub struct AreaModel {
    pub weight_buf: SramMacro,
    pub input_buf: SramMacro,
    pub output_buf: SramMacro,
    pub path_buf: SramMacro,
    pub lut_bufs: SramMacro, // aggregate of L dual-port macros
    pub synth: SynthTable,
    pub num_ppes: usize,
    pub n_cols: usize,
    /// Extra reduction adders provisioned per PPE (§IV-B).
    pub extra_adders_per_ppe: usize,
    /// SFU block (vector mul, activation funcs — §III-A: "serves as a
    /// hardware overhead for fair comparison").
    pub sfu_mm2: f64,
}

/// Component-wise area breakdown in mm².
#[derive(Debug, Clone, Copy, Default)]
pub struct AreaBreakdown {
    pub weight_buf: f64,
    pub input_buf: f64,
    pub output_buf: f64,
    pub path_buf: f64,
    pub lut_bufs: f64,
    pub ppes: f64,
    pub aggregator: f64,
    pub sfu: f64,
}

impl AreaBreakdown {
    pub fn total(&self) -> f64 {
        self.weight_buf
            + self.input_buf
            + self.output_buf
            + self.path_buf
            + self.lut_bufs
            + self.ppes
            + self.aggregator
            + self.sfu
    }

    /// Data buffers excluding LUT (the paper's "weights and activations
    /// ... approximately 65%").
    pub fn data_buffers(&self) -> f64 {
        self.weight_buf + self.input_buf + self.output_buf + self.path_buf
    }
}

impl AreaModel {
    /// The shipped Platinum floorplan (§IV-C: 272 KB buffers + 52 KB LUT).
    pub fn platinum(cfg: &PlatinumConfig) -> Self {
        let t = cfg.tiling;
        // weight tile: m×k at 1.6 b/w (loads overlap via banked staging,
        // so capacity is single-buffered — §IV-C quotes 272 KB total)
        let wt_kb = (t.m * t.k) as f64 * 0.2 / 1024.0;
        // output tile: m×n 32-bit accumulators
        let out_kb = (t.m * t.n * 4) as f64 / 1024.0;
        // input tile: k×n int8 ("minimal input buffering", §IV-C)
        let in_kb = (t.k * t.n) as f64 / 1024.0;
        let path_kb = 1.0;
        AreaModel {
            weight_buf: SramMacro::single_port(wt_kb, 16),
            input_buf: SramMacro::single_port(in_kb, 4),
            output_buf: SramMacro::single_port(out_kb, 8),
            path_buf: SramMacro::single_port(path_kb, 1),
            lut_bufs: SramMacro::dual_port(
                cfg.total_lut_bytes() as f64 / 1024.0,
                cfg.num_ppes as u32,
            ),
            synth: SynthTable::default(),
            num_ppes: cfg.num_ppes,
            n_cols: cfg.n_cols,
            extra_adders_per_ppe: cfg.n_cols, // doubled for reduction (§IV-B)
            sfu_mm2: 0.016,
        }
    }

    /// Total on-chip SRAM capacity (KB) — §IV-C quotes 272 + 52 = 324 KB.
    pub fn total_sram_kb(&self) -> f64 {
        self.weight_buf.kbytes
            + self.input_buf.kbytes
            + self.output_buf.kbytes
            + self.path_buf.kbytes
            + self.lut_bufs.kbytes
    }

    pub fn breakdown(&self) -> AreaBreakdown {
        let s = &self.synth;
        // per PPE: n_cols construction adders (8-bit datapath) + regs + ctrl
        let ppe = self.n_cols as f64 * s.add8_mm2 + s.ppe_regs_mm2 + s.ppe_ctrl_mm2;
        // aggregator: pipelined adder tree over L PPEs × n_cols lanes at
        // 32-bit, plus the extra reduction adders of §IV-B
        let tree_adders = (self.num_ppes - 1) * self.n_cols;
        let extra = self.extra_adders_per_ppe * self.num_ppes;
        let agg = tree_adders as f64 * s.add32_mm2 + extra as f64 * s.add8_mm2;
        AreaBreakdown {
            weight_buf: self.weight_buf.area_mm2(),
            input_buf: self.input_buf.area_mm2(),
            output_buf: self.output_buf.area_mm2(),
            path_buf: self.path_buf.area_mm2(),
            lut_bufs: self.lut_bufs.area_mm2(),
            ppes: ppe * self.num_ppes as f64,
            aggregator: agg,
            sfu: self.sfu_mm2,
        }
    }
}

/// Per-access energy table consumed by the simulator.
#[derive(Debug, Clone, Copy)]
pub struct EnergyTable {
    pub wbuf_read_pj_per_byte: f64,
    pub wbuf_write_pj_per_byte: f64,
    pub ibuf_read_pj_per_byte: f64,
    pub ibuf_write_pj_per_byte: f64,
    pub obuf_rw_pj_per_byte: f64,
    pub lut_read_pj_per_byte: f64,
    pub lut_write_pj_per_byte: f64,
    pub path_read_pj_per_byte: f64,
    pub add8_pj: f64,
    pub add32_pj: f64,
    pub dram_pj_per_bit: f64,
    /// Total static power (SRAM + logic leakage + DRAM background), mW.
    pub static_mw: f64,
}

impl EnergyTable {
    pub fn from_area(model: &AreaModel) -> Self {
        let b = model.breakdown();
        let logic_mm2 = b.ppes + b.aggregator + b.sfu;
        let static_mw = model.weight_buf.leakage_mw()
            + model.input_buf.leakage_mw()
            + model.output_buf.leakage_mw()
            + model.path_buf.leakage_mw()
            + model.lut_bufs.leakage_mw()
            + logic_mm2 * model.synth.logic_leak_mw_per_mm2
            + DRAM_STATIC_MW;
        EnergyTable {
            wbuf_read_pj_per_byte: model.weight_buf.broadcast_read_pj_per_byte(),
            wbuf_write_pj_per_byte: model.weight_buf.write_pj_per_byte(),
            ibuf_read_pj_per_byte: model.input_buf.read_pj_per_byte(),
            ibuf_write_pj_per_byte: model.input_buf.write_pj_per_byte(),
            obuf_rw_pj_per_byte: model.output_buf.read_pj_per_byte() * 1.07,
            lut_read_pj_per_byte: model.lut_bufs.read_pj_per_byte(),
            lut_write_pj_per_byte: model.lut_bufs.write_pj_per_byte(),
            path_read_pj_per_byte: model.path_buf.read_pj_per_byte(),
            add8_pj: model.synth.add8_pj,
            add32_pj: model.synth.add32_pj,
            dram_pj_per_bit: DRAM_PJ_PER_BIT,
            static_mw,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn platinum_area() -> AreaBreakdown {
        AreaModel::platinum(&PlatinumConfig::default()).breakdown()
    }

    #[test]
    fn total_area_matches_paper() {
        // Table I: 0.955 mm² (±15 % tolerance for the analytical model)
        let total = platinum_area().total();
        assert!(
            (total - 0.955).abs() / 0.955 < 0.15,
            "total area {total:.3} mm² vs paper 0.955"
        );
    }

    #[test]
    fn sram_capacity_matches_paper() {
        let m = AreaModel::platinum(&PlatinumConfig::default());
        // §IV-C: 272 KB buffers + 52 KB LUT = 324 KB (±15 %)
        let total = m.total_sram_kb();
        assert!((total - 324.0).abs() / 324.0 < 0.15, "{total} KB");
        assert!((m.lut_bufs.kbytes - 52.0).abs() < 1.0);
    }

    #[test]
    fn buffer_share_matches_paper() {
        // §V-B: weight/activation buffers ≈ 65 %, incl. LUT ≈ 83.3 %
        let b = platinum_area();
        let data_share = b.data_buffers() / b.total();
        let with_lut = (b.data_buffers() + b.lut_bufs) / b.total();
        assert!((data_share - 0.65).abs() < 0.08, "data buffers {data_share:.3}");
        assert!((with_lut - 0.833).abs() < 0.08, "buffers+LUT {with_lut:.3}");
    }

    #[test]
    fn compute_share_matches_paper() {
        // §V-B: aggregator + PPEs ≈ 15 %
        let b = platinum_area();
        let compute = (b.ppes + b.aggregator) / b.total();
        assert!((compute - 0.15).abs() < 0.06, "compute share {compute:.3}");
    }

    #[test]
    fn lut_reads_cheaper_than_weight_reads() {
        // §V-B: "the LUT buffer exhibits lower power usage compared to
        // the weight buffer" — per-access energy must reflect the small
        // per-PPE banks.
        let m = AreaModel::platinum(&PlatinumConfig::default());
        let t = EnergyTable::from_area(&m);
        assert!(t.lut_read_pj_per_byte < t.wbuf_read_pj_per_byte / 3.0);
    }

    #[test]
    fn sram_model_monotonic_in_capacity() {
        let small = SramMacro::single_port(16.0, 1);
        let big = SramMacro::single_port(256.0, 1);
        assert!(big.area_mm2() > small.area_mm2() * 8.0);
        assert!(big.read_pj_per_byte() > small.read_pj_per_byte());
        assert!(big.leakage_mw() > small.leakage_mw());
    }

    #[test]
    fn dual_port_costs_more() {
        let sp = SramMacro::single_port(52.0, 52);
        let dp = SramMacro::dual_port(52.0, 52);
        assert!(dp.area_mm2() > sp.area_mm2() * 1.3);
    }
}
