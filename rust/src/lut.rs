//! Functional golden model of the Platinum datapath (Algorithms 1 & 2).
//!
//! This is the bit-exact software twin of the PPE array: path-replay LUT
//! construction, sign|index queries via `Flip(LUT[index[6:0]], index[7])`,
//! and aggregator reduction.  The cycle-accurate simulator ([`crate::sim`])
//! charges time/energy for exactly the operations this model performs;
//! a property test pins the two op counts to each other, and the L1
//! Pallas kernel plus the PJRT artifacts are validated against this model
//! by the integration tests.
//!
//! §Perf iteration 5 — blocked parallel execution on
//! [`crate::runtime::pool`]: a GEMM runs as *rounds* of up to
//! [`PlatinumConfig::num_ppes`] chunks.  Per round, every chunk's LUT is
//! built exactly once into a shared arena (parallel across chunks), then
//! all output rows query the arena, each row accumulating the round
//! into an `i32` block register that spills to the `i64` output once
//! per round — mirroring the PPE-array / aggregator split in hardware.
//!
//! §PR 4 — both phases are scheduled **dynamically** through
//! [`Pool::for_each_chunk`] on the work-stealing pool: construct claims
//! activation chunks and query claims output rows from an atomic
//! cursor, so ragged rounds (`gsz % threads != 0`), `threads > rows`
//! decode shapes, and straggler lanes load-balance instead of idling on
//! the old static `split_even` stripes.  Row results are bit-exact
//! regardless of thread count or claim order: every output element sees
//! the same integer summands in the same chunk order as the sequential
//! path (rounds are sequential; chunk order within a round is a fixed
//! per-row loop; the scheduler only decides *which lane* runs a row).
//! The i32 round accumulator assumes `round · c · max|activation|`
//! (ternary) or `round · Σ|plane_weight| · c · max|activation|`
//! (bit-serial) stays below 2³¹ — comfortably true for the int8-range
//! activations every caller feeds (|a| ≤ 127 leaves headroom beyond
//! 2²⁰).

use crate::config::PlatinumConfig;
use crate::encoding::{self, PackedBinary, PackedTernary};
use crate::pathgen::BuildPath;
use crate::runtime::pool::{self, DisjointSlice, Pool};

/// Operation counters for cross-checking against the analytical model
/// (Eq 1–3) and the simulator's activity-based energy accounting.
///
/// Counts model the datapath's work and are independent of thread
/// count; the per-round i64 spill is bookkeeping of the aggregator's
/// existing adds, not extra datapath work, and is not counted.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct OpCounts {
    /// Adder operations during LUT construction.
    pub construct_adds: u64,
    /// LUT read accesses during the query phase.
    pub queries: u64,
    /// Adder operations in the aggregation/merge tree (incl. partial-sum
    /// accumulation across chunks).
    pub reduce_adds: u64,
}

impl OpCounts {
    pub fn total_adds(&self) -> u64 {
        self.construct_adds + self.reduce_adds
    }
}

/// Algorithm 2: replay the build path for one activation chunk into a
/// caller-provided LUT slice (`entries × n_cols`, reused across
/// chunks).  `acts` is (c × n_cols) row-major.  Returns adds performed.
pub fn construct_into(path: &BuildPath, acts: &[i32], n_cols: usize, lut: &mut [i32]) -> u64 {
    debug_assert_eq!(acts.len(), path.c * n_cols);
    lut.fill(0); // root (and padding) entries read as zero
    for e in &path.entries {
        let (dst, src, j) =
            (e.dst as usize * n_cols, e.src as usize * n_cols, e.j as usize * n_cols);
        // split_at_mut-free: src and dst rows never alias (tree edges)
        for col in 0..n_cols {
            let a = acts[j + col];
            let v = lut[src + col] + if e.sign { -a } else { a };
            lut[dst + col] = v;
        }
    }
    (path.entries.len() * n_cols) as u64
}

/// One PPE's LUT storage: `entries × n_cols` accumulators.
pub struct LutBuffer {
    data: Vec<i32>,
    pub entries: usize,
    pub n_cols: usize,
}

impl LutBuffer {
    pub fn new(entries: usize, n_cols: usize) -> Self {
        LutBuffer { data: vec![0; entries * n_cols], entries, n_cols }
    }

    /// Algorithm 2: replay the build path for one activation chunk.
    /// `acts` is (c × n_cols) row-major. Returns adds performed.
    pub fn construct(&mut self, path: &BuildPath, acts: &[i32]) -> u64 {
        construct_into(path, acts, self.n_cols, &mut self.data)
    }

    /// Algorithm 1's PPE.QUERY: `Flip(LUT[idx], sign)` for one column.
    #[inline]
    pub fn query(&self, idx: usize, sign: bool, col: usize) -> i32 {
        let v = self.data[idx * self.n_cols + col];
        if sign {
            -v
        } else {
            v
        }
    }

    /// Borrow one LUT entry's n_cols-wide row (one port's read data).
    #[inline]
    pub fn row(&self, idx: usize) -> &[i32] {
        &self.data[idx * self.n_cols..(idx + 1) * self.n_cols]
    }

    /// Vector query across all n_cols (what one LUT port returns).
    #[inline]
    pub fn query_row(&self, idx: usize, sign: bool, out: &mut [i32]) {
        let row = &self.data[idx * self.n_cols..(idx + 1) * self.n_cols];
        if sign {
            for (o, &v) in out.iter_mut().zip(row) {
                *o = -v;
            }
        } else {
            out.copy_from_slice(row);
        }
    }
}

/// Golden ternary mpGEMM through the full Platinum datapath:
/// rounds of (construct L LUTs → query m rows → aggregate), executed in
/// parallel on the process-wide worker pool.
///
/// `acts` is (k × n) row-major int (activations); output is (m × n)
/// i64.  Exactness contract: per-round partials accumulate in i32 (the
/// PPE's accumulator width), so `num_ppes · c · max|act|` must stay
/// below 2³¹ — any int8-range activations qualify by ~4 orders of
/// magnitude; see the module docs for the derivation.
pub fn ternary_mpgemm(
    cfg: &PlatinumConfig,
    weights: &PackedTernary,
    acts: &[i32],
    n: usize,
) -> (Vec<i64>, OpCounts) {
    let pool = pool::global();
    ternary_mpgemm_pool(cfg, weights, acts, n, pool, pool.threads())
}

/// [`ternary_mpgemm`] on an explicit pool with an explicit lane count
/// (`threads` = max lanes claiming chunks; results are bit-exact for
/// any value).
pub fn ternary_mpgemm_pool(
    cfg: &PlatinumConfig,
    weights: &PackedTernary,
    acts: &[i32],
    n: usize,
    pool: &Pool,
    threads: usize,
) -> (Vec<i64>, OpCounts) {
    let c = weights.c;
    let k = weights.k;
    let m = weights.m;
    assert_eq!(acts.len(), k * n);
    let path = crate::pathgen::ternary_path_cached(c);
    let entries = encoding::lut_entries(c);
    let nchunks = weights.chunks();
    let threads = threads.max(1);
    let mut out = vec![0i64; m * n];
    let mut ops = OpCounts::default();

    // process n in blocks of n_cols, chunks in rounds of L
    let ncols = cfg.n_cols.min(n.max(1)).max(1);
    let round = cfg.num_ppes.max(1);
    let ib = encoding::index_bits(c);
    let ib_mask = (1usize << ib) - 1;
    let slot = entries * ncols;

    // hoisted working storage, reused across every round and n-block:
    // the round's LUT arena (one slot per chunk), plus per-lane
    // construct staging and query accumulators, partitioned across the
    // lanes by `for_each_chunk_arena` each phase — dynamic claims have
    // no stable lane index, so the scratch travels with the lane's
    // claim loop instead of being re-allocated per claim or per round
    let mut arena = vec![0i32; round.min(nchunks.max(1)) * slot];
    let mut staging = vec![0i32; threads * c * ncols];
    let mut accs = vec![0i32; threads * ncols];

    let wdata = &weights.data[..];
    for n0 in (0..n).step_by(ncols) {
        let nb = ncols.min(n - n0);
        for ch0 in (0..nchunks).step_by(round) {
            let gsz = round.min(nchunks - ch0);

            // phase 1: build this round's LUTs — chunks claimed
            // dynamically, each written into its disjoint arena slot
            {
                let arena_sl = DisjointSlice::new(&mut arena);
                pool.for_each_chunk_arena(threads, gsz, 0, &mut staging, &|stage, chunks| {
                    let stage = &mut stage[..c * ncols];
                    for g in chunks {
                        let ch = ch0 + g;
                        // gather the chunk's activation block
                        // (c × nb, zero-padded)
                        stage.fill(0);
                        for i in 0..c {
                            let kk = ch * c + i;
                            if kk < k {
                                let src = &acts[kk * n + n0..kk * n + n0 + nb];
                                stage[i * ncols..i * ncols + nb].copy_from_slice(src);
                            }
                        }
                        // SAFETY: chunk g's arena slot is written only
                        // by this claim; claims are disjoint ranges
                        let lut = unsafe { arena_sl.range(g * slot..(g + 1) * slot) };
                        construct_into(path, stage, ncols, lut);
                    }
                });
            }

            // phase 2: query — output rows claimed dynamically; each
            // row accumulates the round in i32 and spills to i64 once
            {
                let arena_ref = &arena[..];
                let out_sl = DisjointSlice::new(&mut out);
                pool.for_each_chunk_arena(threads, m, 0, &mut accs, &|acc, rows| {
                    let acc = &mut acc[..nb];
                    for row in rows {
                        let wrow = &wdata[row * nchunks + ch0..row * nchunks + ch0 + gsz];
                        acc.fill(0);
                        for (g, &byte) in wrow.iter().enumerate() {
                            let byte = byte as usize;
                            let idx = byte & ib_mask;
                            let base = g * slot + idx * ncols;
                            let lrow = &arena_ref[base..base + nb];
                            if byte >> ib == 1 {
                                for (a, &v) in acc.iter_mut().zip(lrow) {
                                    *a -= v;
                                }
                            } else {
                                for (a, &v) in acc.iter_mut().zip(lrow) {
                                    *a += v;
                                }
                            }
                        }
                        // SAFETY: row's output segment is written only
                        // by this claim; row ranges are disjoint
                        let orow = unsafe { out_sl.range(row * n + n0..row * n + n0 + nb) };
                        for (o, &a) in orow.iter_mut().zip(acc.iter()) {
                            *o += a as i64;
                        }
                    }
                });
            }

            // thread-count-independent op accounting (identical to the
            // sequential per-chunk formulas; pinned by tests)
            ops.construct_adds += (gsz * path.entries.len() * ncols) as u64;
            ops.queries += (gsz * m) as u64;
            ops.reduce_adds += (gsz * m * nb) as u64;
        }
    }
    (out, ops)
}

/// Golden bit-serial mpGEMM (Platinum-bs / SNN-baseline execution):
/// binary LUT shared across planes, merged with plane weights, on the
/// process-wide worker pool.
///
/// Exactness contract: per-round partials accumulate in i32, so
/// `num_ppes · Σ|plane_weight| · c · max|act|` must stay below 2³¹
/// (int8 activations with ≤8-bit integer plane weights qualify
/// comfortably; see the module docs).
pub fn bitserial_mpgemm(
    cfg: &PlatinumConfig,
    planes: &[PackedBinary],
    plane_weights: &[i32],
    acts: &[i32],
    n: usize,
) -> (Vec<i64>, OpCounts) {
    let pool = pool::global();
    bitserial_mpgemm_pool(cfg, planes, plane_weights, acts, n, pool, pool.threads())
}

/// [`bitserial_mpgemm`] on an explicit pool with an explicit lane
/// count.
pub fn bitserial_mpgemm_pool(
    cfg: &PlatinumConfig,
    planes: &[PackedBinary],
    plane_weights: &[i32],
    acts: &[i32],
    n: usize,
    pool: &Pool,
    threads: usize,
) -> (Vec<i64>, OpCounts) {
    assert_eq!(planes.len(), plane_weights.len());
    assert!(!planes.is_empty());
    let c = planes[0].c;
    let k = planes[0].k;
    let m = planes[0].m;
    assert_eq!(acts.len(), k * n);
    let path = crate::pathgen::binary_path_cached(c);
    let entries = 1usize << c;
    let nchunks = planes[0].chunks();
    let threads = threads.max(1);
    let mut out = vec![0i64; m * n];
    let mut ops = OpCounts::default();

    let ncols = cfg.n_cols.min(n.max(1)).max(1);
    let round = cfg.num_ppes.max(1);
    let slot = entries * ncols;

    let mut arena = vec![0i32; round.min(nchunks.max(1)) * slot];
    let mut staging = vec![0i32; threads * c * ncols];
    let mut accs = vec![0i32; threads * ncols];

    for n0 in (0..n).step_by(ncols) {
        let nb = ncols.min(n - n0);
        for ch0 in (0..nchunks).step_by(round) {
            let gsz = round.min(nchunks - ch0);

            // phase 1: one binary LUT per chunk, shared by all planes —
            // chunks claimed dynamically into disjoint arena slots
            {
                let arena_sl = DisjointSlice::new(&mut arena);
                pool.for_each_chunk_arena(threads, gsz, 0, &mut staging, &|stage, chunks| {
                    let stage = &mut stage[..c * ncols];
                    for g in chunks {
                        let ch = ch0 + g;
                        stage.fill(0);
                        for i in 0..c {
                            let kk = ch * c + i;
                            if kk < k {
                                let src = &acts[kk * n + n0..kk * n + n0 + nb];
                                stage[i * ncols..i * ncols + nb].copy_from_slice(src);
                            }
                        }
                        // SAFETY: chunk g's arena slot is written only
                        // by this claim; claims are disjoint ranges
                        let lut = unsafe { arena_sl.range(g * slot..(g + 1) * slot) };
                        construct_into(path, stage, ncols, lut);
                    }
                });
            }

            // phase 2: per row, merge every plane's query of the shared
            // LUT with its plane weight in an i32 round accumulator —
            // rows claimed dynamically
            {
                let arena_ref = &arena[..];
                let out_sl = DisjointSlice::new(&mut out);
                pool.for_each_chunk_arena(threads, m, 0, &mut accs, &|acc, rows| {
                    let acc = &mut acc[..nb];
                    for row in rows {
                        acc.fill(0);
                        for g in 0..gsz {
                            let ch = ch0 + g;
                            for (p, &pw) in planes.iter().zip(plane_weights) {
                                let idx = p.data[row * nchunks + ch] as usize;
                                let base = g * slot + idx * ncols;
                                let lrow = &arena_ref[base..base + nb];
                                for (a, &v) in acc.iter_mut().zip(lrow) {
                                    *a += pw * v;
                                }
                            }
                        }
                        // SAFETY: row's output segment is written only
                        // by this claim; row ranges are disjoint
                        let orow = unsafe { out_sl.range(row * n + n0..row * n + n0 + nb) };
                        for (o, &a) in orow.iter_mut().zip(acc.iter()) {
                            *o += a as i64;
                        }
                    }
                });
            }

            let nplanes = planes.len();
            ops.construct_adds += (gsz * path.entries.len() * ncols) as u64;
            ops.queries += (gsz * m * nplanes) as u64;
            ops.reduce_adds += (gsz * m * nplanes * nb) as u64;
        }
    }
    (out, ops)
}

/// Naive reference mpGEMM for validation: (m×k) i8 × (k×n) i32 → i64.
pub fn naive_mpgemm(w: &[i8], m: usize, k: usize, acts: &[i32], n: usize) -> Vec<i64> {
    let mut out = vec![0i64; m * n];
    for row in 0..m {
        for kk in 0..k {
            let wv = w[row * k + kk] as i64;
            if wv == 0 {
                continue;
            }
            for col in 0..n {
                out[row * n + col] += wv * acts[kk * n + col] as i64;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::encoding::{pack_binary, pack_ternary, ternary_planes};
    use crate::util::rng::Rng;

    fn rand_case(seed: u64, m: usize, k: usize, n: usize) -> (Vec<i8>, Vec<i32>) {
        let mut rng = Rng::seed_from(seed);
        (rng.ternary_vec(m * k), rng.act_vec(k * n))
    }

    #[test]
    fn golden_ternary_matches_naive() {
        let cfg = PlatinumConfig::default();
        let (m, k, n) = (64, 75, 12);
        let (w, x) = rand_case(1, m, k, n);
        let packed = pack_ternary(&w, m, k, 5);
        let (out, ops) = ternary_mpgemm(&cfg, &packed, &x, n);
        assert_eq!(out, naive_mpgemm(&w, m, k, &x, n));
        assert!(ops.construct_adds > 0 && ops.queries > 0);
    }

    #[test]
    fn golden_ternary_padded_k() {
        let cfg = PlatinumConfig::default();
        let (m, k, n) = (9, 23, 3); // k not a multiple of 5
        let (w, x) = rand_case(2, m, k, n);
        let packed = pack_ternary(&w, m, k, 5);
        let (out, _) = ternary_mpgemm(&cfg, &packed, &x, n);
        assert_eq!(out, naive_mpgemm(&w, m, k, &x, n));
    }

    #[test]
    fn golden_bitserial_two_pass_matches_naive() {
        let cfg = PlatinumConfig::default();
        let (m, k, n) = (40, 49, 9);
        let (w, x) = rand_case(3, m, k, n);
        let (pos, neg) = ternary_planes(&w, m, k);
        let planes = vec![pack_binary(&pos, m, k, 7), pack_binary(&neg, m, k, 7)];
        let (out, _) = bitserial_mpgemm(&cfg, &planes, &[1, -1], &x, n);
        assert_eq!(out, naive_mpgemm(&w, m, k, &x, n));
    }

    #[test]
    fn bitserial_int_weights() {
        let cfg = PlatinumConfig::default();
        let (m, k, n) = (12, 21, 4);
        let mut rng = Rng::seed_from(4);
        let w: Vec<i32> = (0..m * k).map(|_| rng.range_i64(-4, 3) as i32).collect();
        let x: Vec<i32> = rng.act_vec(k * n);
        let (bitplanes, pw) = crate::encoding::int_bit_planes(&w, 3);
        let planes: Vec<PackedBinary> =
            bitplanes.iter().map(|p| pack_binary(p, m, k, 7)).collect();
        let (out, _) = bitserial_mpgemm(&cfg, &planes, &pw, &x, n);
        // int3 reference
        let mut want = vec![0i64; m * n];
        for row in 0..m {
            for kk in 0..k {
                for col in 0..n {
                    want[row * n + col] += w[row * k + kk] as i64 * x[kk * n + col] as i64;
                }
            }
        }
        assert_eq!(out, want);
    }

    #[test]
    fn ternary_and_bitserial_paths_agree() {
        // §V-C: Platinum vs Platinum-bs — same function, different path.
        let cfg = PlatinumConfig::default();
        let (m, k, n) = (30, 70, 5);
        let (w, x) = rand_case(5, m, k, n);
        let packed = pack_ternary(&w, m, k, 5);
        let (t, _) = ternary_mpgemm(&cfg, &packed, &x, n);
        let (pos, neg) = ternary_planes(&w, m, k);
        let planes = vec![pack_binary(&pos, m, k, 7), pack_binary(&neg, m, k, 7)];
        let (b, _) = bitserial_mpgemm(&cfg, &planes, &[1, -1], &x, n);
        assert_eq!(t, b);
    }

    #[test]
    fn op_counts_match_eq3_structure() {
        // construct adds = ⌈K/c⌉ · (⌈3^c/2⌉−1) · min(n_cols, N) · ⌈N/n_cols⌉-ish;
        // with N == n_cols exactly one n-block:
        let cfg = PlatinumConfig::default();
        let (m, k, n) = (16, 50, 8);
        let (w, x) = rand_case(6, m, k, n);
        let packed = pack_ternary(&w, m, k, 5);
        let (_, ops) = ternary_mpgemm(&cfg, &packed, &x, n);
        let chunks = 10u64;
        assert_eq!(ops.construct_adds, chunks * 121 * 8);
        assert_eq!(ops.queries, chunks * m as u64);
        assert_eq!(ops.reduce_adds, chunks * (m as u64) * 8);
    }

    #[test]
    fn prop_golden_matches_naive() {
        crate::util::check_prop("golden_matches_naive", 16, |seed| {
            let mut rng = Rng::seed_from(seed);
            let m = 1 + rng.below(32) as usize;
            let k = 1 + rng.below(64) as usize;
            let n = 1 + rng.below(11) as usize;
            let cfg = PlatinumConfig::default();
            let (w, x) = rand_case(seed ^ 0xabc, m, k, n);
            let packed = pack_ternary(&w, m, k, 5);
            let (out, _) = ternary_mpgemm(&cfg, &packed, &x, n);
            crate::ensure_prop!(
                out == naive_mpgemm(&w, m, k, &x, n),
                "mismatch at m={m} k={k} n={n}"
            );
            Ok(())
        });
    }

    // --- pool-vs-single-thread bit-exactness -----------------------------

    #[test]
    fn prop_pool_matches_single_thread_ternary() {
        let pools = [Pool::new(1), Pool::new(2), Pool::new(4)];
        crate::util::check_prop("pool_matches_single_thread_ternary", 12, |seed| {
            let mut rng = Rng::seed_from(seed);
            let m = 1 + rng.below(48) as usize;
            let k = 1 + rng.below(300) as usize; // spans multi-round (k > 260)
            let n = 1 + rng.below(10) as usize;
            let cfg = PlatinumConfig::default();
            let (w, x) = rand_case(seed ^ 0x517, m, k, n);
            let packed = pack_ternary(&w, m, k, 5);
            let want = naive_mpgemm(&w, m, k, &x, n);
            let (seq, seq_ops) =
                ternary_mpgemm_pool(&cfg, &packed, &x, n, &pools[0], 1);
            crate::ensure_prop!(seq == want, "sequential mismatch m={m} k={k} n={n}");
            for (pi, pool) in pools.iter().enumerate() {
                let threads = 1 + rng.below(9) as usize;
                let (par, par_ops) =
                    ternary_mpgemm_pool(&cfg, &packed, &x, n, pool, threads);
                crate::ensure_prop!(
                    par == seq,
                    "pool {pi} threads={threads} diverged at m={m} k={k} n={n}"
                );
                crate::ensure_prop!(
                    par_ops == seq_ops,
                    "op counts must be thread-count independent"
                );
            }
            Ok(())
        });
    }

    #[test]
    fn prop_pool_matches_single_thread_bitserial() {
        let pool = Pool::new(4);
        crate::util::check_prop("pool_matches_single_thread_bitserial", 10, |seed| {
            let mut rng = Rng::seed_from(seed);
            let m = 1 + rng.below(40) as usize;
            let k = 1 + rng.below(120) as usize;
            let n = 1 + rng.below(9) as usize;
            let cfg = PlatinumConfig::default();
            let (w, x) = rand_case(seed ^ 0xb17, m, k, n);
            let (pos, neg) = ternary_planes(&w, m, k);
            let planes = vec![pack_binary(&pos, m, k, 7), pack_binary(&neg, m, k, 7)];
            let single = Pool::new(1);
            let (seq, _) =
                bitserial_mpgemm_pool(&cfg, &planes, &[1, -1], &x, n, &single, 1);
            let (par, _) = bitserial_mpgemm_pool(&cfg, &planes, &[1, -1], &x, n, &pool, 7);
            crate::ensure_prop!(seq == par, "bitserial diverged at m={m} k={k} n={n}");
            crate::ensure_prop!(
                seq == naive_mpgemm(&w, m, k, &x, n),
                "bitserial wrong at m={m} k={k} n={n}"
            );
            Ok(())
        });
    }

    #[test]
    fn parallel_threads_exceed_rows() {
        // more stripes requested than output rows: degenerate striping
        let cfg = PlatinumConfig::default();
        let (m, k, n) = (3, 57, 5);
        let (w, x) = rand_case(7, m, k, n);
        let packed = pack_ternary(&w, m, k, 5);
        let pool = Pool::new(8);
        let (out, _) = ternary_mpgemm_pool(&cfg, &packed, &x, n, &pool, 8);
        assert_eq!(out, naive_mpgemm(&w, m, k, &x, n));
    }

    #[test]
    fn parallel_decode_shape_n1() {
        // the decode hot shape: a single activation column
        let cfg = PlatinumConfig::default();
        let (m, k, n) = (128, 260, 1);
        let (w, x) = rand_case(8, m, k, n);
        let packed = pack_ternary(&w, m, k, 5);
        let pool = Pool::new(4);
        let (out, _) = ternary_mpgemm_pool(&cfg, &packed, &x, n, &pool, 4);
        assert_eq!(out, naive_mpgemm(&w, m, k, &x, n));
    }

    #[test]
    fn parallel_ragged_k_across_round_boundary() {
        // k not a multiple of c, chunk count not a multiple of the
        // round size (104 full + 1 ragged chunk = 2 full + 1 short round)
        let cfg = PlatinumConfig::default();
        let (m, k, n) = (17, 523, 4);
        let (w, x) = rand_case(9, m, k, n);
        let packed = pack_ternary(&w, m, k, 5);
        let pool = Pool::new(3);
        let (out, _) = ternary_mpgemm_pool(&cfg, &packed, &x, n, &pool, 3);
        assert_eq!(out, naive_mpgemm(&w, m, k, &x, n));
    }
}
