//! Functional golden model of the Platinum datapath (Algorithms 1 & 2).
//!
//! This is the bit-exact software twin of the PPE array: path-replay LUT
//! construction, sign|index queries via `Flip(LUT[index[6:0]], index[7])`,
//! and aggregator reduction.  The cycle-accurate simulator ([`crate::sim`])
//! charges time/energy for exactly the operations this model performs;
//! a property test pins the two op counts to each other, and the L1
//! Pallas kernel plus the PJRT artifacts are validated against this model
//! by the integration tests.

use crate::config::PlatinumConfig;
use crate::encoding::{self, PackedBinary, PackedTernary};
use crate::pathgen::BuildPath;

/// Operation counters for cross-checking against the analytical model
/// (Eq 1–3) and the simulator's activity-based energy accounting.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct OpCounts {
    /// Adder operations during LUT construction.
    pub construct_adds: u64,
    /// LUT read accesses during the query phase.
    pub queries: u64,
    /// Adder operations in the aggregation/merge tree (incl. partial-sum
    /// accumulation across chunks).
    pub reduce_adds: u64,
}

impl OpCounts {
    pub fn total_adds(&self) -> u64 {
        self.construct_adds + self.reduce_adds
    }
}

/// One PPE's LUT storage: `entries × n_cols` accumulators.
pub struct LutBuffer {
    data: Vec<i32>,
    pub entries: usize,
    pub n_cols: usize,
}

impl LutBuffer {
    pub fn new(entries: usize, n_cols: usize) -> Self {
        LutBuffer { data: vec![0; entries * n_cols], entries, n_cols }
    }

    /// Algorithm 2: replay the build path for one activation chunk.
    /// `acts` is (c × n_cols) row-major. Returns adds performed.
    pub fn construct(&mut self, path: &BuildPath, acts: &[i32]) -> u64 {
        debug_assert_eq!(acts.len(), path.c * self.n_cols);
        self.data[..].fill(0); // root (and padding) entries read as zero
        let n = self.n_cols;
        for e in &path.entries {
            let (dst, src, j) = (e.dst as usize * n, e.src as usize * n, e.j as usize * n);
            // split_at_mut-free: src and dst rows never alias (tree edges)
            for col in 0..n {
                let a = acts[j + col];
                let v = self.data[src + col] + if e.sign { -a } else { a };
                self.data[dst + col] = v;
            }
        }
        (path.entries.len() * n) as u64
    }

    /// Algorithm 1's PPE.QUERY: `Flip(LUT[idx], sign)` for one column.
    #[inline]
    pub fn query(&self, idx: usize, sign: bool, col: usize) -> i32 {
        let v = self.data[idx * self.n_cols + col];
        if sign {
            -v
        } else {
            v
        }
    }

    /// Borrow one LUT entry's n_cols-wide row (one port's read data).
    #[inline]
    pub fn row(&self, idx: usize) -> &[i32] {
        &self.data[idx * self.n_cols..(idx + 1) * self.n_cols]
    }

    /// Vector query across all n_cols (what one LUT port returns).
    #[inline]
    pub fn query_row(&self, idx: usize, sign: bool, out: &mut [i32]) {
        let row = &self.data[idx * self.n_cols..(idx + 1) * self.n_cols];
        if sign {
            for (o, &v) in out.iter_mut().zip(row) {
                *o = -v;
            }
        } else {
            out.copy_from_slice(row);
        }
    }
}

/// Golden ternary mpGEMM through the full Platinum datapath:
/// rounds of (construct L LUTs → query m rows → aggregate).
///
/// `acts` is (k × n) row-major int (activations); output is (m × n) i64.
pub fn ternary_mpgemm(
    cfg: &PlatinumConfig,
    weights: &PackedTernary,
    acts: &[i32],
    n: usize,
) -> (Vec<i64>, OpCounts) {
    let c = weights.c;
    let k = weights.k;
    let m = weights.m;
    assert_eq!(acts.len(), k * n);
    let path = crate::pathgen::ternary_path_cached(c);
    let entries = encoding::lut_entries(c);
    let nchunks = weights.chunks();
    let mut out = vec![0i64; m * n];
    let mut ops = OpCounts::default();

    // process n in blocks of n_cols, chunks in groups of L (one "round")
    let ncols = cfg.n_cols.min(n.max(1));
    let mut lut = LutBuffer::new(entries, ncols);
    // §Perf iteration 3: hoisted activation staging buffer + sliced query
    // accumulation (row windows let the compiler elide bounds checks and
    // keep the idx·n_cols address math out of the column loop).
    let mut a = vec![0i32; c * ncols];
    let ib_mask = (1usize << encoding::index_bits(c)) - 1;
    let ib = encoding::index_bits(c);
    for n0 in (0..n).step_by(ncols) {
        let nb = ncols.min(n - n0);
        for ch_group in (0..nchunks).step_by(cfg.num_ppes) {
            let gsz = cfg.num_ppes.min(nchunks - ch_group);
            for g in 0..gsz {
                let ch = ch_group + g;
                // gather this chunk's activation block (c × nb, padded)
                a.fill(0);
                for i in 0..c {
                    let kk = ch * c + i;
                    if kk < k {
                        let src = &acts[kk * n + n0..kk * n + n0 + nb];
                        a[i * ncols..i * ncols + nb].copy_from_slice(src);
                    }
                }
                ops.construct_adds += lut.construct(path, &a);
                // query phase: every output row queries this PPE's LUT
                for row in 0..m {
                    let byte = weights.at(row, ch) as usize;
                    let idx = byte & ib_mask;
                    let sign = byte >> ib == 1;
                    let lrow = lut.row(idx);
                    let orow = &mut out[row * n + n0..row * n + n0 + nb];
                    if sign {
                        for (o, &v) in orow.iter_mut().zip(lrow) {
                            *o -= v as i64;
                        }
                    } else {
                        for (o, &v) in orow.iter_mut().zip(lrow) {
                            *o += v as i64;
                        }
                    }
                }
                ops.queries += m as u64;
                ops.reduce_adds += (m * nb) as u64;
            }
        }
    }
    (out, ops)
}

/// Golden bit-serial mpGEMM (Platinum-bs / SNN-baseline execution):
/// binary LUT shared across planes, merged with plane weights.
pub fn bitserial_mpgemm(
    cfg: &PlatinumConfig,
    planes: &[PackedBinary],
    plane_weights: &[i32],
    acts: &[i32],
    n: usize,
) -> (Vec<i64>, OpCounts) {
    assert_eq!(planes.len(), plane_weights.len());
    assert!(!planes.is_empty());
    let c = planes[0].c;
    let k = planes[0].k;
    let m = planes[0].m;
    assert_eq!(acts.len(), k * n);
    let path = crate::pathgen::binary_path_cached(c);
    let entries = 1usize << c;
    let nchunks = planes[0].chunks();
    let mut out = vec![0i64; m * n];
    let mut ops = OpCounts::default();

    let ncols = cfg.n_cols.min(n.max(1));
    let mut lut = LutBuffer::new(entries, ncols);
    for n0 in (0..n).step_by(ncols) {
        let nb = ncols.min(n - n0);
        for ch in 0..nchunks {
            let mut a = vec![0i32; c * ncols];
            for i in 0..c {
                let kk = ch * c + i;
                if kk < k {
                    for col in 0..nb {
                        a[i * ncols + col] = acts[kk * n + n0 + col];
                    }
                }
            }
            ops.construct_adds += lut.construct(path, &a);
            for row in 0..m {
                for (p, &pw) in planes.iter().zip(plane_weights) {
                    let idx = p.at(row, ch) as usize;
                    ops.queries += 1;
                    for col in 0..nb {
                        let v = lut.query(idx, false, col) as i64;
                        out[row * n + n0 + col] += pw as i64 * v;
                        ops.reduce_adds += 1;
                    }
                }
            }
        }
    }
    (out, ops)
}

/// Naive reference mpGEMM for validation: (m×k) i8 × (k×n) i32 → i64.
pub fn naive_mpgemm(w: &[i8], m: usize, k: usize, acts: &[i32], n: usize) -> Vec<i64> {
    let mut out = vec![0i64; m * n];
    for row in 0..m {
        for kk in 0..k {
            let wv = w[row * k + kk] as i64;
            if wv == 0 {
                continue;
            }
            for col in 0..n {
                out[row * n + col] += wv * acts[kk * n + col] as i64;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::encoding::{pack_binary, pack_ternary, ternary_planes};
    use crate::util::rng::Rng;

    fn rand_case(seed: u64, m: usize, k: usize, n: usize) -> (Vec<i8>, Vec<i32>) {
        let mut rng = Rng::seed_from(seed);
        (rng.ternary_vec(m * k), rng.act_vec(k * n))
    }

    #[test]
    fn golden_ternary_matches_naive() {
        let cfg = PlatinumConfig::default();
        let (m, k, n) = (64, 75, 12);
        let (w, x) = rand_case(1, m, k, n);
        let packed = pack_ternary(&w, m, k, 5);
        let (out, ops) = ternary_mpgemm(&cfg, &packed, &x, n);
        assert_eq!(out, naive_mpgemm(&w, m, k, &x, n));
        assert!(ops.construct_adds > 0 && ops.queries > 0);
    }

    #[test]
    fn golden_ternary_padded_k() {
        let cfg = PlatinumConfig::default();
        let (m, k, n) = (9, 23, 3); // k not a multiple of 5
        let (w, x) = rand_case(2, m, k, n);
        let packed = pack_ternary(&w, m, k, 5);
        let (out, _) = ternary_mpgemm(&cfg, &packed, &x, n);
        assert_eq!(out, naive_mpgemm(&w, m, k, &x, n));
    }

    #[test]
    fn golden_bitserial_two_pass_matches_naive() {
        let cfg = PlatinumConfig::default();
        let (m, k, n) = (40, 49, 9);
        let (w, x) = rand_case(3, m, k, n);
        let (pos, neg) = ternary_planes(&w, m, k);
        let planes = vec![pack_binary(&pos, m, k, 7), pack_binary(&neg, m, k, 7)];
        let (out, _) = bitserial_mpgemm(&cfg, &planes, &[1, -1], &x, n);
        assert_eq!(out, naive_mpgemm(&w, m, k, &x, n));
    }

    #[test]
    fn bitserial_int_weights() {
        let cfg = PlatinumConfig::default();
        let (m, k, n) = (12, 21, 4);
        let mut rng = Rng::seed_from(4);
        let w: Vec<i32> = (0..m * k).map(|_| rng.range_i64(-4, 3) as i32).collect();
        let x: Vec<i32> = rng.act_vec(k * n);
        let (bitplanes, pw) = crate::encoding::int_bit_planes(&w, 3);
        let planes: Vec<PackedBinary> =
            bitplanes.iter().map(|p| pack_binary(p, m, k, 7)).collect();
        let (out, _) = bitserial_mpgemm(&cfg, &planes, &pw, &x, n);
        // int3 reference
        let mut want = vec![0i64; m * n];
        for row in 0..m {
            for kk in 0..k {
                for col in 0..n {
                    want[row * n + col] += w[row * k + kk] as i64 * x[kk * n + col] as i64;
                }
            }
        }
        assert_eq!(out, want);
    }

    #[test]
    fn ternary_and_bitserial_paths_agree() {
        // §V-C: Platinum vs Platinum-bs — same function, different path.
        let cfg = PlatinumConfig::default();
        let (m, k, n) = (30, 70, 5);
        let (w, x) = rand_case(5, m, k, n);
        let packed = pack_ternary(&w, m, k, 5);
        let (t, _) = ternary_mpgemm(&cfg, &packed, &x, n);
        let (pos, neg) = ternary_planes(&w, m, k);
        let planes = vec![pack_binary(&pos, m, k, 7), pack_binary(&neg, m, k, 7)];
        let (b, _) = bitserial_mpgemm(&cfg, &planes, &[1, -1], &x, n);
        assert_eq!(t, b);
    }

    #[test]
    fn op_counts_match_eq3_structure() {
        // construct adds = ⌈K/c⌉ · (⌈3^c/2⌉−1) · min(n_cols, N) · ⌈N/n_cols⌉-ish;
        // with N == n_cols exactly one n-block:
        let cfg = PlatinumConfig::default();
        let (m, k, n) = (16, 50, 8);
        let (w, x) = rand_case(6, m, k, n);
        let packed = pack_ternary(&w, m, k, 5);
        let (_, ops) = ternary_mpgemm(&cfg, &packed, &x, n);
        let chunks = 10u64;
        assert_eq!(ops.construct_adds, chunks * 121 * 8);
        assert_eq!(ops.queries, chunks * m as u64);
        assert_eq!(ops.reduce_adds, chunks * (m as u64) * 8);
    }

    #[test]
    fn prop_golden_matches_naive() {
        crate::util::check_prop("golden_matches_naive", 16, |seed| {
            let mut rng = Rng::seed_from(seed);
            let m = 1 + rng.below(32) as usize;
            let k = 1 + rng.below(64) as usize;
            let n = 1 + rng.below(11) as usize;
            let cfg = PlatinumConfig::default();
            let (w, x) = rand_case(seed ^ 0xabc, m, k, n);
            let packed = pack_ternary(&w, m, k, 5);
            let (out, _) = ternary_mpgemm(&cfg, &packed, &x, n);
            crate::ensure_prop!(
                out == naive_mpgemm(&w, m, k, &x, n),
                "mismatch at m={m} k={k} n={n}"
            );
            Ok(())
        });
    }
}
