//! Per-connection protocol handling for `platinum serve`: parse one
//! HTTP/1.1 request off the socket ([`super::http::RequestParser`]),
//! route it, and for generation requests stream token events back as
//! chunked ndjson until the scheduler reports the terminal outcome.
//!
//! One request per connection (`Connection: close`) keeps the lifetime
//! story trivial: a connection thread exists exactly as long as its
//! request is in flight, and a write failure mid-stream *is* the
//! client hanging up — the handler cancels the request so the
//! scheduler reclaims its KV blocks.

use super::http::{chunk, last_chunk, response, streaming_head, HttpRequest, RequestParser};
use super::{Gateway, TokenEvent};
use crate::traffic::{Outcome, MAX_CLASSES};
use crate::util::json::{b, num, obj, s, Json};
use anyhow::{anyhow, Result};
use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::Duration;

/// How long a connection may sit idle mid-parse or mid-generation
/// before the daemon gives up on it.
const IO_TIMEOUT: Duration = Duration::from_secs(60);

/// Serve one connection to completion.  Errors are connection-local:
/// the caller logs-and-drops, the daemon keeps running.
pub fn handle(mut sock: TcpStream, gw: &Gateway) -> Result<()> {
    sock.set_read_timeout(Some(IO_TIMEOUT))?;
    sock.set_write_timeout(Some(IO_TIMEOUT))?;
    let req = match read_request(&mut sock) {
        Ok(r) => r,
        Err(e) => {
            let body = err_json(&e.to_string());
            let _ = sock.write_all(&response(400, "Bad Request", "application/json", &body));
            return Err(e);
        }
    };
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/health") => {
            let body = gw.health_json().to_string().into_bytes();
            sock.write_all(&response(200, "OK", "application/json", &body))?;
        }
        ("GET", "/metrics") => {
            let body = gw.metrics_json().to_string().into_bytes();
            sock.write_all(&response(200, "OK", "application/json", &body))?;
        }
        ("POST", "/v1/generate") => return generate(sock, gw, &req),
        ("POST", "/shutdown") => {
            gw.request_stop();
            let body = obj(vec![("ok", b(true)), ("draining", b(true))]).to_string().into_bytes();
            sock.write_all(&response(200, "OK", "application/json", &body))?;
        }
        _ => {
            let body = err_json(&format!("no route {} {}", req.method, req.path));
            sock.write_all(&response(404, "Not Found", "application/json", &body))?;
        }
    }
    Ok(())
}

/// Immediate 503 for connections over the `max_conns` cap (best
/// effort — the client may already be gone).
pub fn refuse_overloaded(mut sock: TcpStream) {
    let body = err_json("connection limit reached");
    let _ = sock.write_all(&response(503, "Service Unavailable", "application/json", &body));
}

fn err_json(msg: &str) -> Vec<u8> {
    obj(vec![("error", s(msg))]).to_string().into_bytes()
}

/// Pull bytes until the parser yields one complete request.
fn read_request(sock: &mut TcpStream) -> Result<HttpRequest> {
    let mut parser = RequestParser::new();
    let mut buf = [0u8; 4096];
    loop {
        if let Some(req) = parser.poll()? {
            return Ok(req);
        }
        let n = sock.read(&mut buf).map_err(|e| anyhow!("read failed: {e}"))?;
        if n == 0 {
            return Err(anyhow!("connection closed mid-request"));
        }
        parser.feed(&buf[..n]);
    }
}

/// `POST /v1/generate`: body `{"prompt_tokens": N, "output_tokens": N
/// [, "shared_prefix_tokens": N]}`, optional `X-Deadline-Ms` header.
///
/// Response: `200` chunked `application/x-ndjson` — one
/// `{"token":k}` line per generated token, then a final
/// `{"done":true,"outcome":"..."}` line.  A request that terminates
/// before its first token (rejected / shed / exhausted / cancelled)
/// gets a plain `503` with the outcome instead of an empty stream.
fn generate(mut sock: TcpStream, gw: &Gateway, req: &HttpRequest) -> Result<()> {
    if gw.stop_requested() {
        let body = err_json("draining: not accepting new requests");
        sock.write_all(&response(503, "Service Unavailable", "application/json", &body))?;
        return Ok(());
    }
    let (prompt, output, shared, deadline_s, class) = match parse_generate(req) {
        Ok(p) => p,
        Err(e) => {
            let body = err_json(&e.to_string());
            sock.write_all(&response(400, "Bad Request", "application/json", &body))?;
            return Err(e);
        }
    };
    let (id, rx) = gw.submit(prompt, output, shared, deadline_s, class);

    // wait for the first event before committing to a status line
    let first = match rx.recv_timeout(IO_TIMEOUT) {
        Ok(ev) => ev,
        Err(_) => {
            gw.cancel(id);
            let body = err_json("timed out waiting for the scheduler");
            sock.write_all(&response(503, "Service Unavailable", "application/json", &body))?;
            return Err(anyhow!("request {id}: no event within {IO_TIMEOUT:?}"));
        }
    };
    if let TokenEvent::Done { outcome } = first {
        let (status, reason) = match outcome {
            Outcome::Completed => (200, "OK"), // zero-token completion: degenerate but honest
            _ => (503, "Service Unavailable"),
        };
        let body = done_line(outcome, 0);
        sock.write_all(&response(status, reason, "application/json", &body))?;
        return Ok(());
    }

    sock.write_all(&streaming_head(200, "OK", "application/x-ndjson"))?;
    let mut ev = first;
    let mut streamed = 0usize;
    loop {
        match ev {
            TokenEvent::Token { index } => {
                let line = format!("{}\n", obj(vec![("token", num(index as f64))]).to_string());
                if sock.write_all(&chunk(line.as_bytes())).is_err() {
                    // client hung up mid-stream: reclaim the KV blocks
                    gw.cancel(id);
                    return Err(anyhow!("request {id}: client disconnected mid-stream"));
                }
                streamed += 1;
            }
            TokenEvent::Done { outcome } => {
                let mut tail = chunk(&done_line(outcome, streamed));
                tail.extend_from_slice(last_chunk());
                sock.write_all(&tail)?;
                return Ok(());
            }
        }
        ev = match rx.recv_timeout(IO_TIMEOUT) {
            Ok(ev) => ev,
            Err(_) => {
                gw.cancel(id);
                return Err(anyhow!("request {id}: event stream stalled"));
            }
        };
    }
}

/// The final ndjson line of a generation stream.
fn done_line(outcome: Outcome, tokens: usize) -> Vec<u8> {
    format!(
        "{}\n",
        obj(vec![
            ("done", b(true)),
            ("outcome", s(outcome.label())),
            ("tokens", num(tokens as f64)),
        ])
        .to_string()
    )
    .into_bytes()
}

/// Decode the generate request: JSON body + `X-Deadline-Ms` and
/// `X-Tenant-Class` headers.
fn parse_generate(req: &HttpRequest) -> Result<(usize, usize, usize, Option<f64>, u8)> {
    let body = std::str::from_utf8(&req.body).map_err(|_| anyhow!("body is not UTF-8"))?;
    let json = Json::parse(body).map_err(|e| anyhow!("bad JSON body: {e}"))?;
    let field = |key: &str| -> Result<usize> {
        json.req(key)?
            .as_f64()
            .filter(|v| v.fract() == 0.0 && *v >= 1.0)
            .map(|v| v as usize)
            .ok_or_else(|| anyhow!("{key} must be a positive integer"))
    };
    let prompt = field("prompt_tokens")?;
    let output = field("output_tokens")?;
    let shared = match json.get("shared_prefix_tokens") {
        Some(v) => v
            .as_f64()
            .filter(|x| x.fract() == 0.0 && *x >= 0.0)
            .map(|x| x as usize)
            .ok_or_else(|| anyhow!("shared_prefix_tokens must be a non-negative integer"))?,
        None => 0,
    };
    if shared > prompt {
        return Err(anyhow!("shared_prefix_tokens cannot exceed prompt_tokens"));
    }
    let deadline_s = match req.header("x-deadline-ms") {
        Some(v) => {
            let ms: f64 = v.parse().map_err(|_| anyhow!("bad X-Deadline-Ms {v:?}"))?;
            if !ms.is_finite() || ms <= 0.0 {
                return Err(anyhow!("X-Deadline-Ms must be a positive number of milliseconds"));
            }
            Some(ms * 1e-3)
        }
        None => None,
    };
    // SLO class: the built-in names map to the default two-class
    // layout; a bare digit addresses a custom class table directly
    let class = match req.header("x-tenant-class") {
        Some(v) => match v.trim().to_ascii_lowercase().as_str() {
            "interactive" => 0u8,
            "batch" => 1u8,
            t => t
                .parse::<u8>()
                .ok()
                .filter(|&c| (c as usize) < MAX_CLASSES)
                .ok_or_else(|| {
                    anyhow!(
                        "bad X-Tenant-Class {v:?}: expected interactive, batch, \
                         or a class id 0..{}",
                        MAX_CLASSES - 1
                    )
                })?,
        },
        None => 0,
    };
    Ok((prompt, output, shared, deadline_s, class))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn post(body: &str, deadline: Option<&str>) -> HttpRequest {
        post_with_class(body, deadline, None)
    }

    fn post_with_class(body: &str, deadline: Option<&str>, class: Option<&str>) -> HttpRequest {
        let mut headers = vec![("Content-Length".to_string(), body.len().to_string())];
        if let Some(d) = deadline {
            headers.push(("X-Deadline-Ms".to_string(), d.to_string()));
        }
        if let Some(c) = class {
            headers.push(("X-Tenant-Class".to_string(), c.to_string()));
        }
        HttpRequest {
            method: "POST".into(),
            path: "/v1/generate".into(),
            headers,
            body: body.as_bytes().to_vec(),
        }
    }

    #[test]
    fn parses_generate_body_and_deadline() {
        let req = post(r#"{"prompt_tokens": 32, "output_tokens": 8}"#, Some("250"));
        let (p, o, sh, dl, c) = parse_generate(&req).unwrap();
        assert_eq!((p, o, sh, c), (32, 8, 0, 0));
        assert_eq!(dl, Some(0.25));
        let req = post(
            r#"{"prompt_tokens": 70, "output_tokens": 4, "shared_prefix_tokens": 64}"#,
            None,
        );
        let (p, _, sh, dl, _) = parse_generate(&req).unwrap();
        assert_eq!((p, sh), (70, 64));
        assert_eq!(dl, None);
    }

    #[test]
    fn parses_tenant_class_header() {
        let body = r#"{"prompt_tokens": 8, "output_tokens": 4}"#;
        for (hdr, want) in [
            (Some("interactive"), 0u8),
            (Some("Batch"), 1),
            (Some("2"), 2),
            (Some("3"), 3),
            (None, 0),
        ] {
            let (_, _, _, _, c) = parse_generate(&post_with_class(body, None, hdr)).unwrap();
            assert_eq!(c, want, "header {hdr:?}");
        }
        for bad in ["premium", "4", "255", "-1", ""] {
            assert!(
                parse_generate(&post_with_class(body, None, Some(bad))).is_err(),
                "class {bad:?} must be rejected"
            );
        }
    }

    #[test]
    fn rejects_bad_generate_requests() {
        for (body, dl) in [
            ("not json", None),
            (r#"{"output_tokens": 8}"#, None),
            (r#"{"prompt_tokens": 0, "output_tokens": 8}"#, None),
            (r#"{"prompt_tokens": 4, "output_tokens": 8, "shared_prefix_tokens": 9}"#, None),
            (r#"{"prompt_tokens": 4, "output_tokens": 8}"#, Some("soon")),
            (r#"{"prompt_tokens": 4, "output_tokens": 8}"#, Some("-5")),
        ] {
            assert!(parse_generate(&post(body, dl)).is_err(), "{body:?} dl={dl:?}");
        }
    }

    #[test]
    fn done_line_is_one_ndjson_record() {
        let line = String::from_utf8(done_line(Outcome::Completed, 7)).unwrap();
        assert!(line.ends_with('\n'));
        let parsed = Json::parse(line.trim()).unwrap();
        assert_eq!(parsed.get("done"), Some(&Json::Bool(true)));
        assert_eq!(parsed.get("outcome").unwrap().as_str(), Some("completed"));
        assert_eq!(parsed.get("tokens").unwrap().as_usize(), Some(7));
    }
}
