//! Hand-rolled HTTP/1.1 primitives for `platinum serve` — std-only per
//! the vendored-deps rule (no hyper/axum), and deliberately tiny: an
//! incremental request parser that survives arbitrary read-boundary
//! splits, plus response and chunked-transfer-encoding writers.
//!
//! Everything here is pure byte-in/byte-out and unit-tested without
//! sockets (`tests/server_http.rs`); [`super::stream`] owns the actual
//! `TcpStream` I/O.

use anyhow::{anyhow, bail, Result};

/// Upper bound on the request head (request line + headers + CRLFCRLF).
pub const MAX_HEAD_BYTES: usize = 8 * 1024;
/// Upper bound on a request body (`Content-Length`).
pub const MAX_BODY_BYTES: usize = 64 * 1024;

/// One parsed request.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct HttpRequest {
    pub method: String,
    pub path: String,
    /// Headers in arrival order, names verbatim; look up through
    /// [`HttpRequest::header`] (names are case-insensitive).
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl HttpRequest {
    /// Case-insensitive header lookup (first match wins).
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(k, _)| k.eq_ignore_ascii_case(name))
            .map(|(_, v)| v.as_str())
    }
}

/// Incremental request parser: [`RequestParser::feed`] bytes as they
/// arrive off the socket, then [`RequestParser::poll`] — `Ok(None)`
/// means "need more bytes", `Err` means the connection should be
/// answered 400 and closed.  Pipelined requests queue up: each `poll`
/// consumes exactly one complete request from the buffer.
#[derive(Debug, Default)]
pub struct RequestParser {
    buf: Vec<u8>,
}

impl RequestParser {
    pub fn new() -> RequestParser {
        RequestParser { buf: Vec::new() }
    }

    /// Append bytes read from the connection.
    pub fn feed(&mut self, bytes: &[u8]) {
        self.buf.extend_from_slice(bytes);
    }

    /// Try to parse one complete request out of the buffered bytes.
    pub fn poll(&mut self) -> Result<Option<HttpRequest>> {
        let Some(head_end) = find_head_end(&self.buf) else {
            if self.buf.len() > MAX_HEAD_BYTES {
                bail!("request head exceeds {MAX_HEAD_BYTES} bytes");
            }
            return Ok(None);
        };
        if head_end > MAX_HEAD_BYTES {
            bail!("request head exceeds {MAX_HEAD_BYTES} bytes");
        }
        let head = std::str::from_utf8(&self.buf[..head_end])
            .map_err(|_| anyhow!("request head is not valid UTF-8"))?;
        let mut lines = head.split("\r\n");
        let request_line = lines.next().unwrap_or("");
        let mut parts = request_line.split(' ');
        let (method, path, version) = match (parts.next(), parts.next(), parts.next(), parts.next())
        {
            (Some(m), Some(p), Some(v), None) if !m.is_empty() && !p.is_empty() => (m, p, v),
            _ => bail!("malformed request line {request_line:?}"),
        };
        if !version.starts_with("HTTP/1.") {
            bail!("unsupported protocol version {version:?}");
        }
        let mut headers: Vec<(String, String)> = Vec::new();
        for line in lines {
            let (name, value) = line
                .split_once(':')
                .ok_or_else(|| anyhow!("malformed header line {line:?}"))?;
            if name.is_empty() || name.contains(' ') {
                bail!("malformed header name {name:?}");
            }
            headers.push((name.to_string(), value.trim().to_string()));
        }
        let content_length = match headers
            .iter()
            .find(|(k, _)| k.eq_ignore_ascii_case("content-length"))
        {
            Some((_, v)) => v
                .parse::<usize>()
                .map_err(|_| anyhow!("bad Content-Length {v:?}"))?,
            None => 0,
        };
        if content_length > MAX_BODY_BYTES {
            bail!("request body of {content_length} bytes exceeds {MAX_BODY_BYTES}");
        }
        let total = head_end + 4 + content_length;
        if self.buf.len() < total {
            return Ok(None); // body still in flight
        }
        let body = self.buf[head_end + 4..total].to_vec();
        self.buf.drain(..total);
        Ok(Some(HttpRequest {
            method: method.to_string(),
            path: path.to_string(),
            headers,
            body,
        }))
    }
}

/// Byte offset of the head/body boundary (`\r\n\r\n`), if buffered.
fn find_head_end(buf: &[u8]) -> Option<usize> {
    buf.windows(4).position(|w| w == b"\r\n\r\n")
}

/// A complete non-streaming response with `Content-Length`.
pub fn response(status: u16, reason: &str, content_type: &str, body: &[u8]) -> Vec<u8> {
    let mut out = format!(
        "HTTP/1.1 {status} {reason}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    )
    .into_bytes();
    out.extend_from_slice(body);
    out
}

/// The head of a chunked streaming response; follow with [`chunk`]s and
/// one [`last_chunk`].
pub fn streaming_head(status: u16, reason: &str, content_type: &str) -> Vec<u8> {
    format!(
        "HTTP/1.1 {status} {reason}\r\nContent-Type: {content_type}\r\nTransfer-Encoding: chunked\r\nConnection: close\r\n\r\n"
    )
    .into_bytes()
}

/// One transfer-encoding chunk: hex length, CRLF, payload, CRLF.
pub fn chunk(payload: &[u8]) -> Vec<u8> {
    let mut out = format!("{:x}\r\n", payload.len()).into_bytes();
    out.extend_from_slice(payload);
    out.extend_from_slice(b"\r\n");
    out
}

/// The zero-length terminator chunk.
pub fn last_chunk() -> &'static [u8] {
    b"0\r\n\r\n"
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_a_request_with_body() {
        let mut p = RequestParser::new();
        p.feed(b"POST /v1/generate HTTP/1.1\r\nHost: x\r\nContent-Length: 4\r\n\r\nabcd");
        let r = p.poll().unwrap().expect("complete request");
        assert_eq!(r.method, "POST");
        assert_eq!(r.path, "/v1/generate");
        assert_eq!(r.header("host"), Some("x"));
        assert_eq!(r.body, b"abcd");
        assert!(p.poll().unwrap().is_none(), "buffer fully consumed");
    }

    #[test]
    fn survives_arbitrary_split_boundaries() {
        let raw = b"GET /health HTTP/1.1\r\nX-Deadline-Ms: 250\r\n\r\n";
        for cut in 1..raw.len() {
            let mut p = RequestParser::new();
            p.feed(&raw[..cut]);
            let first = p.poll().unwrap();
            p.feed(&raw[cut..]);
            let r = match first {
                Some(r) => r,
                None => p.poll().unwrap().expect("complete after second feed"),
            };
            assert_eq!(r.path, "/health", "cut at {cut}");
            assert_eq!(r.header("x-deadline-ms"), Some("250"));
        }
    }

    #[test]
    fn chunk_encoding_golden_bytes() {
        assert_eq!(chunk(b"hello"), b"5\r\nhello\r\n");
        assert_eq!(chunk(&[0u8; 16]).len(), 2 + 2 + 16 + 2, "hex length for 16 is '10'");
        assert_eq!(last_chunk(), b"0\r\n\r\n");
        let head = String::from_utf8(streaming_head(200, "OK", "application/x-ndjson")).unwrap();
        assert!(head.contains("Transfer-Encoding: chunked"), "{head}");
    }
}
