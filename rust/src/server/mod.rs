//! `platinum serve` (S18): a long-running serving daemon with a wire
//! protocol, built entirely on `std` (the vendored-deps rule rules out
//! hyper/tokio — the accept loop is blocking threads, the protocol is
//! hand-rolled HTTP/1.1 in [`http`]).
//!
//! Architecture — three planes sharing one [`Gateway`]:
//!
//! * **accept loop** (main thread): nonblocking `TcpListener`, one OS
//!   thread per connection ([`stream::handle`]).  Connections must
//!   never run on the compute worker pool — a pool task blocking on
//!   socket I/O would violate the pool's no-external-blocking
//!   invariant — so the pool stays the compute plane and connection
//!   threads are plain `thread::spawn`.
//! * **scheduler thread**: the *unmodified* continuous-batching serve
//!   loop ([`Scheduler::serve_source`]) on a [`WallClock`] anchored at
//!   the same instant the accept loop stamps arrival offsets with,
//!   pulling arrivals from a [`PushSource`].  The daemon is therefore
//!   the same control plane the virtual-clock benchmarks and tests pin
//!   — one code path, two clocks.
//! * **connection threads**: parse one request, [`Gateway::submit`] it,
//!   and stream token events back as chunked ndjson until the
//!   scheduler reports the terminal [`Outcome`].
//!
//! Graceful shutdown (SIGTERM/SIGINT or `POST /shutdown`): stop
//! accepting, let in-flight connections drain (the scheduler keeps
//! running their sequences), close the push source, join the scheduler,
//! then write the captured arrival trace ([`format_capture`]) and the
//! final metrics JSON.  A captured trace replayed through `serve-bench
//! --pattern replay --clock virtual` is byte-reproducible — the
//! determinism contract CI's `daemon-smoke` job enforces end-to-end.

pub mod http;
pub mod stream;

use crate::engine::Registry;
use crate::fault::FaultPlan;
use crate::models::BitNetModel;
use crate::traffic::metrics::Histogram;
use crate::traffic::{
    format_capture, Outcome, PushHandle, PushSource, RunResult, Scheduler, SchedulerConfig,
    StepRecord, TraceRecord, TrafficRequest, WallClock,
};
use crate::util::json::{b, num, obj, s, Json};
use anyhow::{anyhow, Result};
use std::collections::HashMap;
use std::net::TcpListener;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{Receiver, Sender};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

/// Daemon configuration (CLI flags + `PLATINUM_ADDR`/`PLATINUM_MAX_CONNS`
/// env knobs, resolved in `main.rs`).
pub struct ServeOptions {
    /// Listen address, `host:port`.
    pub addr: String,
    /// Concurrent-connection cap; excess connections get an immediate
    /// 503 instead of an unbounded thread pile-up.
    pub max_conns: usize,
    /// Write every live arrival as a capture-v1 replay trace here on
    /// shutdown.
    pub capture: Option<String>,
    /// Write the final metrics JSON here on shutdown.
    pub metrics_out: Option<String>,
    /// Engine backend id pricing (or measuring) the steps.
    pub backend_id: String,
    pub model: BitNetModel,
    pub cfg: SchedulerConfig,
    pub plan: FaultPlan,
}

/// What a connection thread receives while its request is in flight.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TokenEvent {
    /// One generated token (0-based position in the output).
    Token { index: usize },
    /// Terminal state — always the last event a sink sees.
    Done { outcome: Outcome },
}

/// One waiting connection's event channel plus its latency bookkeeping.
struct Sink {
    tx: Sender<TokenEvent>,
    t_submit_s: f64,
    t_last_s: Option<f64>,
    tokens: usize,
}

/// Live serving statistics for `/metrics` — the same [`Histogram`]
/// machinery the PR 5 bench metrics use, fed by wall-clock events.
#[derive(Default)]
struct LiveStats {
    submitted: u64,
    completed: u64,
    cancelled: u64,
    rejected: u64,
    shed: u64,
    exhausted: u64,
    ttft: Histogram,
    tpot: Histogram,
    e2e: Histogram,
}

/// The meeting point of the three planes: connection threads submit
/// requests and wait on per-request channels; the scheduler thread
/// reports tokens (step-executor hook) and terminals (source observer);
/// `/metrics` reads the aggregate.
///
/// Lock order: `sinks` before `live`.  [`Gateway::on_step_token`] is
/// the *only* path that holds both at once (sinks → live); every other
/// path takes one lock at a time and releases it before touching the
/// other, so the two mutexes cannot deadlock.
pub struct Gateway {
    handle: PushHandle,
    anchor: Instant,
    next_id: AtomicU64,
    stop: AtomicBool,
    sinks: Mutex<HashMap<u64, Sink>>,
    live: Mutex<LiveStats>,
    captures: Mutex<Vec<TraceRecord>>,
}

impl Gateway {
    fn new(handle: PushHandle, anchor: Instant) -> Gateway {
        Gateway {
            handle,
            anchor,
            next_id: AtomicU64::new(0),
            stop: AtomicBool::new(false),
            sinks: Mutex::new(HashMap::new()),
            live: Mutex::new(LiveStats::default()),
            captures: Mutex::new(Vec::new()),
        }
    }

    /// Seconds since the daemon's t = 0 (shared with the scheduler's
    /// anchored wall clock).
    fn now_s(&self) -> f64 {
        self.anchor.elapsed().as_secs_f64()
    }

    /// Enqueue one generation request into the live timeline.  Returns
    /// the request id and the channel its [`TokenEvent`]s arrive on.
    pub fn submit(
        &self,
        prompt_tokens: usize,
        output_tokens: usize,
        shared_prefix_tokens: usize,
        deadline_s: Option<f64>,
        class: u8,
    ) -> (u64, Receiver<TokenEvent>) {
        let id = self.next_id.fetch_add(1, Ordering::SeqCst);
        let arrival_s = self.now_s();
        let (tx, rx) = mpsc::channel();
        self.sinks
            .lock()
            .unwrap()
            .insert(id, Sink { tx, t_submit_s: arrival_s, t_last_s: None, tokens: 0 });
        self.live.lock().unwrap().submitted += 1;
        self.captures.lock().unwrap().push(TraceRecord {
            arrival_s,
            prompt_tokens: Some(prompt_tokens),
            output_tokens: Some(output_tokens),
            deadline_s,
            shared_prefix_tokens,
            class,
        });
        self.handle.push(TrafficRequest {
            id,
            arrival_s,
            prompt_tokens,
            output_tokens,
            shared_prefix_tokens,
            deadline_s,
            class,
        });
        (id, rx)
    }

    /// Client hung up mid-stream: tell the scheduler to kill the
    /// request wherever it sits and reclaim its KV blocks.
    pub fn cancel(&self, id: u64) {
        self.handle.cancel(id);
    }

    /// Ask the daemon to shut down (`POST /shutdown` — the portable
    /// sibling of SIGTERM).
    pub fn request_stop(&self) {
        self.stop.store(true, Ordering::SeqCst);
    }

    pub fn stop_requested(&self) -> bool {
        self.stop.load(Ordering::SeqCst)
    }

    /// Step-executor hook: every sequence a step served emitted one
    /// token.  Records TTFT on the first and true inter-token gaps
    /// after, then forwards the event to the waiting connection.
    fn on_step_token(&self, id: u64) {
        let now = self.now_s();
        let mut sinks = self.sinks.lock().unwrap();
        let Some(sink) = sinks.get_mut(&id) else { return };
        let index = sink.tokens;
        sink.tokens += 1;
        let mut live = self.live.lock().unwrap();
        match sink.t_last_s {
            None => live.ttft.record(now - sink.t_submit_s),
            Some(prev) => live.tpot.record(now - prev),
        }
        drop(live);
        sink.t_last_s = Some(now);
        let _ = sink.tx.send(TokenEvent::Token { index });
    }

    /// Source-observer hook: the request reached its terminal state.
    /// Routes the outcome to the connection and closes its sink.
    fn on_terminal(&self, id: u64, outcome: Outcome) {
        let now = self.now_s();
        let sink = self.sinks.lock().unwrap().remove(&id);
        let mut live = self.live.lock().unwrap();
        match outcome {
            Outcome::Completed => {
                live.completed += 1;
                if let Some(sk) = &sink {
                    live.e2e.record(now - sk.t_submit_s);
                }
            }
            Outcome::Cancelled => live.cancelled += 1,
            Outcome::Rejected => live.rejected += 1,
            Outcome::Shed => live.shed += 1,
            Outcome::Exhausted => live.exhausted += 1,
        }
        drop(live);
        if let Some(sk) = sink {
            let _ = sk.tx.send(TokenEvent::Done { outcome });
        }
    }

    /// `/health` payload.
    pub fn health_json(&self) -> Json {
        obj(vec![
            ("status", s("ok")),
            ("active", num(self.sinks.lock().unwrap().len() as f64)),
            ("draining", b(self.stop_requested())),
            ("uptime_s", num(self.now_s())),
        ])
    }

    /// `/metrics` payload: request counters plus the live TTFT / TPOT /
    /// E2E histograms (same serialization as the bench metrics).
    pub fn metrics_json(&self) -> Json {
        // read (and release) `sinks` before taking `live`: holding
        // `live` while acquiring `sinks` would invert on_step_token's
        // sinks → live order and ABBA-deadlock against the scheduler
        // thread's token path
        let active = self.sinks.lock().unwrap().len();
        let live = self.live.lock().unwrap();
        obj(vec![
            (
                "counts",
                obj(vec![
                    ("submitted", num(live.submitted as f64)),
                    ("completed", num(live.completed as f64)),
                    ("cancelled", num(live.cancelled as f64)),
                    ("rejected", num(live.rejected as f64)),
                    ("shed", num(live.shed as f64)),
                    ("exhausted", num(live.exhausted as f64)),
                    ("active", num(active as f64)),
                ]),
            ),
            (
                "latency_s",
                obj(vec![
                    ("ttft", live.ttft.to_json()),
                    ("tpot", live.tpot.to_json()),
                    ("e2e", live.e2e.to_json()),
                ]),
            ),
            ("uptime_s", num(self.now_s())),
        ])
    }

    /// Captured arrivals so far, in arrival order.
    fn capture_records(&self) -> Vec<TraceRecord> {
        let mut recs = self.captures.lock().unwrap().clone();
        recs.sort_by(|a, b| a.arrival_s.total_cmp(&b.arrival_s));
        recs
    }
}

/// Process-wide shutdown flag flipped by SIGTERM/SIGINT.  Pure std: the
/// handler is registered through the C `signal` entry point (no libc
/// crate), and only stores into an atomic — async-signal-safe.
#[cfg(unix)]
mod sig {
    use std::sync::atomic::{AtomicBool, Ordering};

    pub static SHUTDOWN: AtomicBool = AtomicBool::new(false);

    extern "C" fn on_signal(_signum: i32) {
        SHUTDOWN.store(true, Ordering::SeqCst);
    }

    extern "C" {
        fn signal(signum: i32, handler: extern "C" fn(i32)) -> usize;
    }

    pub fn install() {
        const SIGINT: i32 = 2;
        const SIGTERM: i32 = 15;
        unsafe {
            signal(SIGTERM, on_signal);
            signal(SIGINT, on_signal);
        }
    }

    pub fn requested() -> bool {
        SHUTDOWN.load(Ordering::SeqCst)
    }
}

#[cfg(not(unix))]
mod sig {
    /// Non-unix: no signal plumbing; `POST /shutdown` is the only stop.
    pub fn install() {}

    pub fn requested() -> bool {
        false
    }
}

/// Run the daemon until SIGTERM/SIGINT or `POST /shutdown`, then drain
/// and write the capture / metrics artifacts.  See the module docs for
/// the three-plane architecture.
pub fn run(opts: ServeOptions) -> Result<()> {
    sig::install();
    let listener = TcpListener::bind(&opts.addr)
        .map_err(|e| anyhow!("cannot bind {:?}: {e}", opts.addr))?;
    listener.set_nonblocking(true)?;
    let local = listener.local_addr()?;

    let anchor = Instant::now();
    let (mut source, handle) = PushSource::new();
    let gw = Arc::new(Gateway::new(handle.clone(), anchor));
    let obs = gw.clone();
    source.set_observer(Box::new(move |id, outcome| obs.on_terminal(id, outcome)));

    // scheduler thread: builds its own backend (trait objects stay
    // thread-local) and runs the shared serve loop on the anchored
    // wall clock until the source closes and drains
    let sched_gw = gw.clone();
    let backend_id = opts.backend_id.clone();
    let model = opts.model;
    let cfg = opts.cfg;
    let plan = opts.plan.clone();
    let scheduler = std::thread::Builder::new().name("platinum-sched".into()).spawn(
        move || -> Result<RunResult> {
            let backend = Registry::with_defaults().build(&backend_id)?;
            let sched = Scheduler::new(backend.as_ref(), model, cfg);
            let mut clock = WallClock::anchored_at(anchor);
            let mut hook = |step: &StepRecord, _w: &crate::engine::Workload| -> Result<()> {
                for &id in &step.seq_ids {
                    sched_gw.on_step_token(id);
                }
                Ok(())
            };
            sched.serve_source(&mut source, &mut clock, Some(&mut hook), &plan)
        },
    )?;

    eprintln!(
        "platinum serve: listening on {local} (backend {}, model {}, max {} conns)",
        opts.backend_id, opts.model.name, opts.max_conns
    );

    // accept loop: one OS thread per connection, bounded by max_conns.
    // Transient accept failures (EMFILE under fd pressure,
    // ECONNABORTED, EINTR, …) shed that connection and keep serving; a
    // persistently failing listener gives up through the graceful
    // drain below, so the capture trace and final metrics still land.
    const MAX_CONSECUTIVE_ACCEPT_ERRORS: u32 = 100;
    let conns = Arc::new(AtomicUsize::new(0));
    let mut accept_errors = 0u32;
    while !sig::requested() && !gw.stop_requested() {
        match listener.accept() {
            Ok((stream_sock, _peer)) => {
                accept_errors = 0;
                if conns.load(Ordering::SeqCst) >= opts.max_conns {
                    stream::refuse_overloaded(stream_sock);
                    continue;
                }
                conns.fetch_add(1, Ordering::SeqCst);
                let gw2 = gw.clone();
                let conns2 = conns.clone();
                std::thread::spawn(move || {
                    let _ = stream::handle(stream_sock, &gw2);
                    conns2.fetch_sub(1, Ordering::SeqCst);
                });
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(e) => {
                accept_errors += 1;
                eprintln!("platinum serve: accept error ({e}); retrying");
                if accept_errors >= MAX_CONSECUTIVE_ACCEPT_ERRORS {
                    eprintln!("platinum serve: accept failing persistently; draining");
                    break;
                }
                std::thread::sleep(Duration::from_millis(20));
            }
        }
    }

    // graceful drain: stop accepting, let in-flight connections finish
    // (the scheduler is still serving their sequences), then close the
    // source so the serve loop exits once everything completes
    eprintln!("platinum serve: shutting down, draining in-flight requests");
    let grace = Instant::now();
    while conns.load(Ordering::SeqCst) > 0 && grace.elapsed() < Duration::from_secs(20) {
        std::thread::sleep(Duration::from_millis(10));
    }
    handle.close();
    let result = scheduler
        .join()
        .map_err(|_| anyhow!("scheduler thread panicked"))??;

    if let Some(path) = &opts.capture {
        let recs = gw.capture_records();
        std::fs::write(path, format_capture(&recs))
            .map_err(|e| anyhow!("cannot write capture {path:?}: {e}"))?;
        eprintln!("platinum serve: wrote {} captured arrivals to {path}", recs.len());
    }
    if let Some(path) = &opts.metrics_out {
        let doc = obj(vec![
            ("serve", gw.metrics_json()),
            ("scheduler", result.metrics.to_json()),
        ]);
        std::fs::write(path, doc.to_string())
            .map_err(|e| anyhow!("cannot write metrics {path:?}: {e}"))?;
        eprintln!("platinum serve: wrote final metrics to {path}");
    }
    let m = &result.metrics;
    eprintln!(
        "platinum serve: drained — offered {} completed {} cancelled {} steps {}",
        m.offered,
        m.completed,
        m.cancelled,
        m.steps()
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::traffic::PushSource;

    #[test]
    fn gateway_routes_tokens_and_terminals() {
        let (_source, handle) = PushSource::new();
        let gw = Gateway::new(handle, Instant::now());
        let (id, rx) = gw.submit(8, 2, 0, Some(0.25), 0);
        gw.on_step_token(id);
        gw.on_step_token(id);
        gw.on_terminal(id, Outcome::Completed);
        assert_eq!(rx.recv().unwrap(), TokenEvent::Token { index: 0 });
        assert_eq!(rx.recv().unwrap(), TokenEvent::Token { index: 1 });
        assert_eq!(rx.recv().unwrap(), TokenEvent::Done { outcome: Outcome::Completed });
        assert!(rx.recv().is_err(), "sink closed after the terminal");
        let m = gw.metrics_json().to_string();
        assert!(m.contains("\"submitted\":1"), "{m}");
        assert!(m.contains("\"completed\":1"), "{m}");
        // the capture recorded the request shape and deadline
        let recs = gw.capture_records();
        assert_eq!(recs.len(), 1);
        assert_eq!(recs[0].prompt_tokens, Some(8));
        assert_eq!(recs[0].output_tokens, Some(2));
        assert_eq!(recs[0].deadline_s, Some(0.25));
        assert_eq!(recs[0].shared_prefix_tokens, 0);
        assert_eq!(recs[0].class, 0);
    }

    #[test]
    fn capture_preserves_shared_prefix_for_replay() {
        // a live prefix-cache session must replay with the same shared
        // span, not shared=0 — otherwise KV/admission decisions diverge
        let (_source, handle) = PushSource::new();
        let gw = Gateway::new(handle, Instant::now());
        let (id, _rx) = gw.submit(70, 4, 64, None, 0);
        gw.on_terminal(id, Outcome::Completed);
        let (id2, _rx2) = gw.submit(16, 2, 0, None, 1);
        gw.on_terminal(id2, Outcome::Completed);
        let recs = gw.capture_records();
        assert_eq!(recs[0].shared_prefix_tokens, 64);
        assert_eq!(recs[1].class, 1, "the tenant class is captured");
        let parsed =
            crate::traffic::parse_trace_records(&format_capture(&recs)).unwrap();
        assert_eq!(parsed, recs, "shared prefix and class must survive the capture round-trip");
    }

    #[test]
    fn gateway_counts_non_completed_outcomes() {
        let (_source, handle) = PushSource::new();
        let gw = Gateway::new(handle, Instant::now());
        let (a, rx_a) = gw.submit(4, 1, 0, None, 0);
        let (b_id, rx_b) = gw.submit(4, 1, 0, None, 1);
        gw.on_terminal(a, Outcome::Rejected);
        gw.on_terminal(b_id, Outcome::Cancelled);
        assert_eq!(rx_a.recv().unwrap(), TokenEvent::Done { outcome: Outcome::Rejected });
        assert_eq!(rx_b.recv().unwrap(), TokenEvent::Done { outcome: Outcome::Cancelled });
        let health = gw.health_json().to_string();
        assert!(health.contains("\"active\":0"), "{health}");
        assert!(health.contains("\"draining\":false"), "{health}");
        gw.request_stop();
        assert!(gw.health_json().to_string().contains("\"draining\":true"));
    }
}
