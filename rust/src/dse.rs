//! Design-space exploration over tiling sizes and stationarity (S7,
//! Fig 7): for each candidate (m_t, k_t, n_t, order) evaluate the
//! prefill stages of the three BitNet-b1.58 models with the simulator
//! and the area model, and report (latency, energy, area) points.
//!
//! The paper's chosen point — m=1080, k=520, n=32, mnk-stationary —
//! must lie on (or near) the Pareto frontier; a test pins this.

use crate::config::{ExecMode, PlatinumConfig, Stationarity, Tiling};
use crate::energy::AreaModel;
use crate::engine::{Backend, PlatinumBackend, Workload};
use crate::models::{BitNetModel, ALL_MODELS, PREFILL_N};

/// One evaluated design point.
#[derive(Debug, Clone)]
pub struct DsePoint {
    pub tiling: Tiling,
    /// Summed prefill latency across the evaluated models (s).
    pub latency_s: f64,
    /// Summed prefill energy across the evaluated models (J).
    pub energy_j: f64,
    /// Chip area at this buffer provisioning (mm²).
    pub area_mm2: f64,
    /// Total on-chip SRAM (KB).
    pub sram_kb: f64,
}

impl DsePoint {
    /// The latency·energy·area product the paper's "balance" implies.
    pub fn eda_product(&self) -> f64 {
        self.latency_s * self.energy_j * self.area_mm2
    }
}

/// Default candidate grid (mirrors the Fig 7 sweep granularity).
pub fn default_grid() -> Vec<Tiling> {
    let ms = [540, 1080, 2160];
    let ks = [260, 520, 1040];
    let ns = [16, 32, 64];
    let mut out = Vec::new();
    for &m in &ms {
        for &k in &ks {
            for &n in &ns {
                for order in Stationarity::ALL {
                    out.push(Tiling { m, k, n, order });
                }
            }
        }
    }
    out
}

/// Evaluate one tiling on the given models' prefill stages (through the
/// engine's Platinum backend — the sweep is itself an engine consumer).
pub fn evaluate(tiling: Tiling, models: &[BitNetModel]) -> DsePoint {
    let mut cfg = PlatinumConfig::default();
    cfg.tiling = tiling;
    let area_model = AreaModel::platinum(&cfg);
    let area = area_model.breakdown().total();
    let backend = PlatinumBackend::with_config(cfg, ExecMode::Ternary);
    let mut latency = 0.0;
    let mut energy = 0.0;
    for model in models {
        let r = backend.run(&Workload::model_pass(*model, PREFILL_N));
        latency += r.latency_s;
        energy += r.energy_j.expect("platinum models energy");
    }
    DsePoint {
        tiling,
        latency_s: latency,
        energy_j: energy,
        area_mm2: area,
        sram_kb: area_model.total_sram_kb(),
    }
}

/// Run the full sweep (Fig 7). `models` defaults to all three b1.58
/// sizes when empty.
pub fn sweep(grid: &[Tiling], models: &[BitNetModel]) -> Vec<DsePoint> {
    let models = if models.is_empty() { &ALL_MODELS[..] } else { models };
    grid.iter().map(|&t| evaluate(t, models)).collect()
}

/// Pareto frontier under (latency, energy, area) minimization.
pub fn pareto(points: &[DsePoint]) -> Vec<usize> {
    let dominated = |a: &DsePoint, b: &DsePoint| {
        // b dominates a
        b.latency_s <= a.latency_s
            && b.energy_j <= a.energy_j
            && b.area_mm2 <= a.area_mm2
            && (b.latency_s < a.latency_s || b.energy_j < a.energy_j || b.area_mm2 < a.area_mm2)
    };
    (0..points.len())
        .filter(|&i| !points.iter().any(|b| dominated(&points[i], b)))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::B158_3B;

    fn small_grid() -> Vec<Tiling> {
        // keep unit tests fast: single model, coarse grid
        let mut g = Vec::new();
        for &m in &[540, 1080] {
            for &k in &[260, 520] {
                for &n in &[16, 32] {
                    for order in [Stationarity::Mnk, Stationarity::Kmn] {
                        g.push(Tiling { m, k, n, order });
                    }
                }
            }
        }
        g
    }

    #[test]
    fn chosen_point_near_pareto() {
        // E3: the paper's (1080, 520, 32, mnk) should not be badly
        // dominated — its EDA product must be within 1.35× of the best.
        let mut grid = small_grid();
        grid.push(Tiling::default());
        let pts = sweep(&grid, &[B158_3B]);
        let best = pts.iter().map(DsePoint::eda_product).fold(f64::MAX, f64::min);
        let chosen = pts
            .iter()
            .find(|p| p.tiling == Tiling::default())
            .unwrap()
            .eda_product();
        assert!(chosen / best < 1.35, "chosen {:.3e} vs best {best:.3e}", chosen);
    }

    #[test]
    fn pareto_is_nonempty_and_consistent() {
        let pts = sweep(&small_grid(), &[B158_3B]);
        let front = pareto(&pts);
        assert!(!front.is_empty());
        // frontier points must not dominate each other
        for &i in &front {
            for &j in &front {
                if i != j {
                    let (a, b) = (&pts[i], &pts[j]);
                    assert!(
                        !(b.latency_s < a.latency_s
                            && b.energy_j < a.energy_j
                            && b.area_mm2 < a.area_mm2)
                    );
                }
            }
        }
    }

    #[test]
    fn bigger_tiles_cost_area() {
        let small = evaluate(
            Tiling { m: 540, k: 260, n: 16, order: Stationarity::Mnk },
            &[B158_3B],
        );
        let big = evaluate(
            Tiling { m: 2160, k: 1040, n: 64, order: Stationarity::Mnk },
            &[B158_3B],
        );
        assert!(big.area_mm2 > small.area_mm2 * 1.5);
        assert!(big.sram_kb > small.sram_kb * 2.0);
    }
}
