//! Design-space exploration over tiling sizes, stationarity, and chip
//! count (S7, Fig 7 + the multi-chip axis): for each candidate
//! (m_t, k_t, n_t, order) — optionally replicated across N chips via
//! [`crate::engine::Sharded`] — evaluate the prefill stages of the
//! BitNet-b1.58 models with the simulator and the area model, and
//! report (latency, energy, area) points.
//!
//! The paper's chosen point — m=1080, k=520, n=32, mnk-stationary —
//! must lie on (or near) the Pareto frontier; a test pins this.  The
//! replica sweep exposes the scaling trade: latency drops toward
//! 1/N (bounded by the interconnect merge term) while energy and area
//! grow with N.
//!
//! The topology axis (`--topology` on the CLI) crosses the replica
//! sweep with [`crate::sim::net`] event-driven interconnects: the same
//! (tiling, N) point is priced under `ring`/`mesh2d`/`fattree` gather
//! timelines, so a sweep answers "which topology at N chips" — the
//! system-level co-design question the LUT-accelerator DSE papers pose.
//! (Topology, count) pairs the topology cannot form (a prime count on
//! a mesh, a non-power-of-two fat tree) are skipped; the CLI prints
//! which, so the sweep never silently thins.

use crate::config::{ExecMode, PlatinumConfig, Stationarity, Tiling};
use crate::energy::AreaModel;
use crate::engine::{Backend, PlatinumBackend, ShardStrategy, Sharded, Workload};
use crate::models::{BitNetModel, ALL_MODELS, PREFILL_N};
use crate::sim::net::Topology;

/// One evaluated design point.
#[derive(Debug, Clone)]
pub struct DsePoint {
    pub tiling: Tiling,
    /// Chip replicas this point was evaluated at (1 = single chip).
    pub replicas: usize,
    /// Interconnect model: `None` = analytic merge term, `Some` = the
    /// event-driven network simulator over that topology.
    pub topology: Option<Topology>,
    /// Summed prefill latency across the evaluated models (s).
    pub latency_s: f64,
    /// Summed prefill energy across the evaluated models (J).
    pub energy_j: f64,
    /// Total silicon area at this provisioning (all replicas, mm²).
    pub area_mm2: f64,
    /// Total on-chip SRAM (all replicas, KB).
    pub sram_kb: f64,
}

impl DsePoint {
    /// The latency·energy·area product the paper's "balance" implies.
    pub fn eda_product(&self) -> f64 {
        self.latency_s * self.energy_j * self.area_mm2
    }
}

/// Default candidate grid (mirrors the Fig 7 sweep granularity).
pub fn default_grid() -> Vec<Tiling> {
    let ms = [540, 1080, 2160];
    let ks = [260, 520, 1040];
    let ns = [16, 32, 64];
    let mut out = Vec::new();
    for &m in &ms {
        for &k in &ks {
            for &n in &ns {
                for order in Stationarity::ALL {
                    out.push(Tiling { m, k, n, order });
                }
            }
        }
    }
    out
}

/// Evaluate one tiling on the given models' prefill stages (through the
/// engine's Platinum backend — the sweep is itself an engine consumer).
pub fn evaluate(tiling: Tiling, models: &[BitNetModel]) -> DsePoint {
    evaluate_replicated(tiling, 1, models)
}

/// Evaluate one (tiling, chip count) point: `replicas` row-sharded
/// Platinum chips behind one [`Sharded`] backend (a single replica is
/// the plain chip — no interconnect term).  Area and SRAM scale with
/// the replica count; latency/energy come out of the engine's
/// max+interconnect / sum aggregation.
pub fn evaluate_replicated(tiling: Tiling, replicas: usize, models: &[BitNetModel]) -> DsePoint {
    evaluate_topology(tiling, replicas, None, models)
        .expect("analytic evaluation accepts any replica count")
}

/// [`evaluate_replicated`] with an explicit interconnect model: `None`
/// keeps the analytic merge term, `Some(topology)` routes the gather
/// traffic through the event-driven network simulator.  Errors when the
/// replica count cannot form the topology (callers either pre-validate
/// or surface the message).
pub fn evaluate_topology(
    tiling: Tiling,
    replicas: usize,
    topology: Option<Topology>,
    models: &[BitNetModel],
) -> anyhow::Result<DsePoint> {
    let replicas = replicas.max(1);
    let mut cfg = PlatinumConfig::default();
    cfg.tiling = tiling;
    let area_model = AreaModel::platinum(&cfg);
    let area = area_model.breakdown().total();
    let chips: Vec<Box<dyn Backend>> = (0..replicas)
        .map(|_| {
            Box::new(PlatinumBackend::with_config(cfg.clone(), ExecMode::Ternary))
                as Box<dyn Backend>
        })
        .collect();
    let backend = match topology {
        None => Sharded::new(chips, ShardStrategy::Rows)?,
        Some(t) => Sharded::with_net(chips, ShardStrategy::Rows, t)?,
    };
    let mut latency = 0.0;
    let mut energy = 0.0;
    for model in models {
        let r = backend.run(&Workload::model_pass(*model, PREFILL_N));
        latency += r.latency_s;
        energy += r.energy_j.expect("platinum models energy");
    }
    Ok(DsePoint {
        tiling,
        replicas,
        topology,
        latency_s: latency,
        energy_j: energy,
        area_mm2: area * replicas as f64,
        sram_kb: area_model.total_sram_kb() * replicas as f64,
    })
}

/// Run the full sweep (Fig 7). `models` defaults to all three b1.58
/// sizes when empty.
pub fn sweep(grid: &[Tiling], models: &[BitNetModel]) -> Vec<DsePoint> {
    let models = if models.is_empty() { &ALL_MODELS[..] } else { models };
    grid.iter().map(|&t| evaluate(t, models)).collect()
}

/// The multi-chip sweep: the tiling grid crossed with every replica
/// count, each evaluated through a [`Sharded`] composite.
pub fn sweep_replicated(
    grid: &[Tiling],
    replica_counts: &[usize],
    models: &[BitNetModel],
) -> Vec<DsePoint> {
    sweep_topology(grid, replica_counts, &[None], models)
}

/// The full system sweep: tiling grid × replica counts × interconnect
/// models.  (Topology, count) pairs the topology cannot form are
/// skipped — use [`skipped_topology_pairs`] to report them (the CLI
/// does), so nothing is dropped silently.
pub fn sweep_topology(
    grid: &[Tiling],
    replica_counts: &[usize],
    topologies: &[Option<Topology>],
    models: &[BitNetModel],
) -> Vec<DsePoint> {
    let models = if models.is_empty() { &ALL_MODELS[..] } else { models };
    let counts = if replica_counts.is_empty() { &[1][..] } else { replica_counts };
    let topos = if topologies.is_empty() { &[None][..] } else { topologies };
    grid.iter()
        .flat_map(|&t| counts.iter().map(move |&r| (t, r)))
        .flat_map(|(t, r)| topos.iter().map(move |&topo| (t, r, topo)))
        .filter(|(_, r, topo)| topo.map(|t| t.validate(*r).is_ok()).unwrap_or(true))
        .map(|(t, r, topo)| {
            evaluate_topology(t, r, topo, models).expect("validated (topology, count) pair")
        })
        .collect()
}

/// The (topology, replica-count) combinations [`sweep_topology`] would
/// skip, with the validation message explaining why.
pub fn skipped_topology_pairs(
    replica_counts: &[usize],
    topologies: &[Option<Topology>],
) -> Vec<(Topology, usize, String)> {
    let mut out = Vec::new();
    for &topo in topologies {
        let Some(t) = topo else { continue };
        for &r in replica_counts {
            if let Err(e) = t.validate(r) {
                out.push((t, r, e.to_string()));
            }
        }
    }
    out
}

/// Pareto frontier under (latency, energy, area) minimization.
pub fn pareto(points: &[DsePoint]) -> Vec<usize> {
    let dominated = |a: &DsePoint, b: &DsePoint| {
        // b dominates a
        b.latency_s <= a.latency_s
            && b.energy_j <= a.energy_j
            && b.area_mm2 <= a.area_mm2
            && (b.latency_s < a.latency_s || b.energy_j < a.energy_j || b.area_mm2 < a.area_mm2)
    };
    (0..points.len())
        .filter(|&i| !points.iter().any(|b| dominated(&points[i], b)))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::B158_3B;

    fn small_grid() -> Vec<Tiling> {
        // keep unit tests fast: single model, coarse grid
        let mut g = Vec::new();
        for &m in &[540, 1080] {
            for &k in &[260, 520] {
                for &n in &[16, 32] {
                    for order in [Stationarity::Mnk, Stationarity::Kmn] {
                        g.push(Tiling { m, k, n, order });
                    }
                }
            }
        }
        g
    }

    #[test]
    fn chosen_point_near_pareto() {
        // E3: the paper's (1080, 520, 32, mnk) should not be badly
        // dominated — its EDA product must be within 1.35× of the best.
        let mut grid = small_grid();
        grid.push(Tiling::default());
        let pts = sweep(&grid, &[B158_3B]);
        let best = pts.iter().map(DsePoint::eda_product).fold(f64::MAX, f64::min);
        let chosen = pts
            .iter()
            .find(|p| p.tiling == Tiling::default())
            .unwrap()
            .eda_product();
        assert!(chosen / best < 1.35, "chosen {:.3e} vs best {best:.3e}", chosen);
    }

    #[test]
    fn pareto_is_nonempty_and_consistent() {
        let pts = sweep(&small_grid(), &[B158_3B]);
        let front = pareto(&pts);
        assert!(!front.is_empty());
        // frontier points must not dominate each other
        for &i in &front {
            for &j in &front {
                if i != j {
                    let (a, b) = (&pts[i], &pts[j]);
                    assert!(
                        !(b.latency_s < a.latency_s
                            && b.energy_j < a.energy_j
                            && b.area_mm2 < a.area_mm2)
                    );
                }
            }
        }
    }

    #[test]
    fn replica_sweep_trades_latency_for_area() {
        let single = evaluate(Tiling::default(), &[B158_3B]);
        assert_eq!(single.replicas, 1);
        let quad = evaluate_replicated(Tiling::default(), 4, &[B158_3B]);
        assert_eq!(quad.replicas, 4);
        // latency improves but sublinearly (interconnect merge term);
        // area scales exactly with chips; energy never shrinks
        assert!(quad.latency_s < single.latency_s);
        assert!(quad.latency_s > single.latency_s / 4.0 - 1e-15);
        assert!((quad.area_mm2 - 4.0 * single.area_mm2).abs() < 1e-9);
        assert!((quad.sram_kb - 4.0 * single.sram_kb).abs() < 1e-9);
        assert!(quad.energy_j >= single.energy_j * 0.99);
    }

    #[test]
    fn sweep_replicated_crosses_grid_and_counts() {
        let grid = vec![
            Tiling { m: 540, k: 260, n: 16, order: Stationarity::Mnk },
            Tiling { m: 1080, k: 520, n: 32, order: Stationarity::Mnk },
        ];
        let pts = sweep_replicated(&grid, &[1, 2], &[B158_3B]);
        assert_eq!(pts.len(), 4);
        assert_eq!(pts.iter().filter(|p| p.replicas == 2).count(), 2);
        // a single-replica point from the new sweep matches the classic one
        let classic = evaluate(grid[0], &[B158_3B]);
        let p = pts.iter().find(|p| p.tiling == grid[0] && p.replicas == 1).unwrap();
        assert_eq!(p.latency_s, classic.latency_s);
        assert_eq!(p.energy_j, classic.energy_j);
    }

    #[test]
    fn topology_sweep_crosses_and_skips_invalid() {
        let grid = vec![Tiling { m: 540, k: 260, n: 16, order: Stationarity::Mnk }];
        let topos = [None, Some(Topology::Ring), Some(Topology::Mesh2d)];
        let pts = sweep_topology(&grid, &[2, 4], &topos, &[B158_3B]);
        // 2 counts × 3 interconnect models, minus the (mesh2d, 2) pair
        // no rectangular mesh can form
        assert_eq!(pts.len(), 5);
        assert!(pts.iter().any(|p| p.topology == Some(Topology::Ring) && p.replicas == 2));
        assert!(!pts.iter().any(|p| p.topology == Some(Topology::Mesh2d) && p.replicas == 2));
        let skipped = skipped_topology_pairs(&[2, 4], &topos);
        assert_eq!(skipped.len(), 1);
        assert_eq!((skipped[0].0, skipped[0].1), (Topology::Mesh2d, 2));
        assert!(skipped[0].2.contains("rectangular"), "{}", skipped[0].2);
        // the interconnect model changes pricing, not provisioning
        let analytic = pts.iter().find(|p| p.topology.is_none() && p.replicas == 4).unwrap();
        let ring =
            pts.iter().find(|p| p.topology == Some(Topology::Ring) && p.replicas == 4).unwrap();
        assert_eq!(analytic.area_mm2, ring.area_mm2);
        assert_eq!(analytic.energy_j, ring.energy_j);
        assert!(ring.latency_s > 0.0);
        // classic sweep stays the analytic model
        let classic = sweep_replicated(&grid, &[2], &[B158_3B]);
        assert!(classic.iter().all(|p| p.topology.is_none()));
    }

    #[test]
    fn bigger_tiles_cost_area() {
        let small = evaluate(
            Tiling { m: 540, k: 260, n: 16, order: Stationarity::Mnk },
            &[B158_3B],
        );
        let big = evaluate(
            Tiling { m: 2160, k: 1040, n: 64, order: Stationarity::Mnk },
            &[B158_3B],
        );
        assert!(big.area_mm2 > small.area_mm2 * 1.5);
        assert!(big.sram_kb > small.sram_kb * 2.0);
    }
}
