//! Analytical cost model — Eq (1), (2), (3) of §III-C verbatim, plus the
//! naive baseline and the Fig 5 / Fig 6 series generators.
//!
//! The addition counts here are *algorithmic* (what the paper plots);
//! the simulator charges cycles/energy for the same operations and a
//! property test ties its counters back to these formulas.

use crate::encoding;

/// Workload dimensions for one mpGEMM kernel (weights M×K, input K×N).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Gemm {
    pub m: usize,
    pub k: usize,
    pub n: usize,
}

impl Gemm {
    pub fn new(m: usize, k: usize, n: usize) -> Self {
        Gemm { m, k, n }
    }

    /// Naive addition count MKN (the paper's op-count normalization —
    /// subtractions count as additions, sign flips are free).
    pub fn naive_adds(&self) -> u64 {
        self.m as u64 * self.k as u64 * self.n as u64
    }
}

#[inline]
fn ceil_div(a: usize, b: usize) -> u64 {
    a.div_ceil(b) as u64
}

/// Eq (1): bit-serial LUT method addition count for ternary weights
/// (naive per-entry construction, two-pass query with merge).
///
/// #add_bs = [⌈K/c⌉·c·2^c + M·⌈K/c⌉ + M·(⌈K/c⌉−1)] · N
pub fn adds_bitserial(g: Gemm, c: usize) -> u64 {
    let kc = ceil_div(g.k, c);
    let construct = kc * (c as u64) * (1u64 << c);
    let merge = g.m as u64 * kc;
    let accum = g.m as u64 * (kc - 1).max(0);
    (construct + merge + accum) * g.n as u64
}

/// Eq (2): plain ternary LUT method (naive per-entry construction,
/// no merge term — ternary LUT entries are final results).
///
/// #add_ter = [⌈K/c⌉·c·3^c + M·(⌈K/c⌉−1)] · N
pub fn adds_ternary_lut(g: Gemm, c: usize) -> u64 {
    let kc = ceil_div(g.k, c);
    let construct = kc * (c as u64) * encoding::pow3(c) as u64;
    let accum = g.m as u64 * (kc - 1).max(0);
    (construct + accum) * g.n as u64
}

/// Eq (3): Platinum — path-based construction (one add per stored entry,
/// ⌈3^c/2⌉ after mirror consolidation) plus accumulation.
///
/// #add_platinum = [⌈K/c⌉·⌈3^c/2⌉ + M·(⌈K/c⌉−1)] · N
pub fn adds_platinum(g: Gemm, c: usize) -> u64 {
    let kc = ceil_div(g.k, c);
    let construct = kc * ((encoding::pow3(c) as u64 + 1) / 2);
    let accum = g.m as u64 * (kc - 1).max(0);
    (construct + accum) * g.n as u64
}

/// Platinum-bs: bit-serial with *path-based* binary construction
/// (2^c − 1 adds per chunk instead of c·2^c) — what the Platinum-bs
/// configuration actually executes.
pub fn adds_platinum_bs(g: Gemm, c: usize) -> u64 {
    let kc = ceil_div(g.k, c);
    let construct = kc * ((1u64 << c) - 1);
    let merge = g.m as u64 * kc;
    let accum = g.m as u64 * (kc - 1).max(0);
    (construct + merge + accum) * g.n as u64
}

/// One row of the Fig 5 series: addition counts (relative to naive) for
/// each method at a given LUT size (chunk c).
#[derive(Debug, Clone, Copy)]
pub struct Fig5Row {
    pub c: usize,
    pub lut_size_ternary: usize,
    pub naive: u64,
    pub bitserial: u64,
    pub ternary_lut: u64,
    pub platinum: u64,
}

/// Generate the Fig 5 sweep (reduction of additions over chunk sizes,
/// M = 1080 per the paper's caption, K/N from the evaluated kernel).
pub fn fig5_series(g: Gemm, cs: impl IntoIterator<Item = usize>) -> Vec<Fig5Row> {
    cs.into_iter()
        .map(|c| Fig5Row {
            c,
            lut_size_ternary: encoding::lut_entries(c),
            naive: g.naive_adds(),
            bitserial: adds_bitserial(g, c),
            ternary_lut: adds_ternary_lut(g, c),
            platinum: adds_platinum(g, c),
        })
        .collect()
}

/// Fig 6 series: average encoded bits per ternary weight vs pack size.
pub fn fig6_series(cs: impl IntoIterator<Item = usize>) -> Vec<(usize, f64)> {
    cs.into_iter().map(|c| (c, encoding::bits_per_weight(c))).collect()
}

/// Best chunk size for Platinum under Eq (3) for a workload.
pub fn best_chunk(g: Gemm, max_c: usize) -> usize {
    (2..=max_c).min_by_key(|&c| adds_platinum(g, c)).unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The b1.58-3B-scale kernel the paper's Fig 5 assumes (M=1080 tile).
    fn fig5_gemm() -> Gemm {
        Gemm::new(1080, 3200, 1)
    }

    #[test]
    fn platinum_beats_other_methods_at_c5() {
        let g = fig5_gemm();
        let p = adds_platinum(g, 5);
        assert!(p < adds_ternary_lut(g, 5));
        assert!(p < adds_bitserial(g, 5));
        assert!(p < adds_bitserial(g, 7), "vs bit-serial at its own best c");
        assert!(p < g.naive_adds());
    }

    #[test]
    fn fig5_platinum_lowest_across_sweep() {
        // "our method achieves the lowest addition count across varying
        // chunk sizes" — Platinum at its best c vs each method at each c.
        let g = fig5_gemm();
        let rows = fig5_series(g, 2..=8);
        let best_p = rows.iter().map(|r| r.platinum).min().unwrap();
        for r in &rows {
            assert!(best_p <= r.bitserial, "c={}", r.c);
            assert!(best_p <= r.ternary_lut, "c={}", r.c);
        }
    }

    #[test]
    fn construction_reduction_2c_times() {
        // §III-C: path-based + mirror reduces construction from c·3^c to
        // ⌈3^c/2⌉ — a ~2c× reduction.
        let c = 5;
        let naive_cons = (c * encoding::pow3(c)) as f64;
        let ours = encoding::lut_entries(c) as f64;
        let ratio = naive_cons / ours;
        assert!(ratio > 2.0 * c as f64 * 0.95, "ratio {ratio}");
    }

    #[test]
    fn ternary_lut_beats_bitserial_for_ternary_weights() {
        // The §I claim: >1.3× improvement with ternary LUTs over binary
        // LUTs for ternary weights (compare at each method's shipped c).
        let g = fig5_gemm();
        let bs = adds_platinum_bs(g, 7) as f64;
        let ter = adds_platinum(g, 5) as f64;
        assert!(bs / ter > 1.3, "only {:.2}×", bs / ter);
    }

    #[test]
    fn bitserial_reduction_factor_approx_c_over_2() {
        // §III-C: "the bit-serial LUT method reduces this cost by
        // approximately c/2 when M is large"
        let g = Gemm::new(100_000, 3200, 1);
        let c = 4;
        let factor = g.naive_adds() as f64 / adds_bitserial(g, c) as f64;
        assert!((factor / (c as f64 / 2.0) - 1.0).abs() < 0.1, "factor {factor}");
    }

    #[test]
    fn fig6_minimum() {
        let series = fig6_series(1..=10);
        let (best_c, best_v) = series
            .iter()
            .copied()
            .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
            .unwrap();
        assert_eq!(best_c, 5);
        assert!((best_v - 1.6).abs() < 1e-12);
    }

    #[test]
    fn prop_all_methods_scale_linearly_in_n() {
        crate::util::check_prop("methods_linear_in_n", 32, |seed| {
            let mut rng = crate::util::rng::Rng::seed_from(seed);
            let m = 1 + rng.below(5000) as usize;
            let k = 10 + rng.below(5000) as usize;
            let n = 1 + rng.below(63) as usize;
            let c = 2 + rng.below(6) as usize;
            let g1 = Gemm::new(m, k, 1);
            let gn = Gemm::new(m, k, n);
            crate::ensure_prop!(
                adds_platinum(gn, c) == adds_platinum(g1, c) * n as u64,
                "platinum nonlinear"
            );
            crate::ensure_prop!(
                adds_bitserial(gn, c) == adds_bitserial(g1, c) * n as u64,
                "bitserial nonlinear"
            );
            crate::ensure_prop!(
                adds_ternary_lut(gn, c) == adds_ternary_lut(g1, c) * n as u64,
                "ternary nonlinear"
            );
            Ok(())
        });
    }

    #[test]
    fn prop_platinum_never_worse_than_ternary_lut() {
        crate::util::check_prop("platinum_le_ternary", 32, |seed| {
            let mut rng = crate::util::rng::Rng::seed_from(seed);
            let m = 1 + rng.below(10_000) as usize;
            let k = 10 + rng.below(10_000) as usize;
            let c = 2 + rng.below(6) as usize;
            let g = Gemm::new(m, k, 1);
            crate::ensure_prop!(
                adds_platinum(g, c) <= adds_ternary_lut(g, c),
                "platinum worse at m={m} k={k} c={c}"
            );
            Ok(())
        });
    }
}
