//! Offline build-path generation (paper §III-B) — the rust mirror of
//! `python/compile/kernels/pathgen.py`.
//!
//! LUT construction is formalized as a spanning-tree problem: nodes are
//! stored LUT entries, edges are single additions `LUT[dst] = LUT[src] ±
//! a_j`.  All edges cost one addition, so any spanning tree is an MST
//! (Prim over unit weights); the freedom left — parent choice and
//! emission order — is spent on the hazard constraint: consecutive
//! entries must keep read-after-write distance ≥ the construction
//! pipeline depth so the 4-stage pipeline (Fig 4) needs no interlocks.

use crate::encoding;

/// Construction pipeline depth (fetch / read / add / write — Fig 4).
pub const PIPELINE_DEPTH: usize = 4;

/// One build-path operation: `LUT[dst] = LUT[src] + (sign ? -a[j] : a[j])`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PathEntry {
    pub dst: u16,
    pub src: u16,
    pub j: u8,
    pub sign: bool,
}

/// A complete build path for one LUT kind.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BuildPath {
    pub kind: PathKind,
    pub c: usize,
    /// Pre-initialized root entry (LUT[root] = 0).
    pub root: usize,
    pub entries: Vec<PathEntry>,
    /// Achieved minimum RAW distance (≥ PIPELINE_DEPTH ⇒ hazard-free).
    pub min_raw_distance: usize,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PathKind {
    Ternary,
    Binary,
}

impl BuildPath {
    /// Number of runtime additions (= entries; the Eq (3) construction
    /// cost term ⌈3^c/2⌉ for ternary, 2^c for binary).
    pub fn additions(&self) -> usize {
        self.entries.len()
    }

    /// True when the shipped pipeline can replay this path with no
    /// hazard hardware and no stalls.
    pub fn hazard_free(&self) -> bool {
        self.min_raw_distance >= PIPELINE_DEPTH
    }

    /// Construction cycles on the hardware pipeline: one entry per cycle
    /// plus pipeline fill (and any forced bubbles for toy chunk sizes).
    pub fn construct_cycles(&self, pipeline_depth: usize) -> usize {
        let bubbles = if self.min_raw_distance >= pipeline_depth {
            0
        } else {
            // worst-case stall per violating hop
            self.entries.len() * (pipeline_depth - self.min_raw_distance)
        };
        self.entries.len() + pipeline_depth + bubbles
    }
}

/// Graph predecessors of canonical ternary node `t`: (parent, j, sign).
fn ternary_parents(t: usize, c: usize) -> Vec<(usize, u8, bool)> {
    let tz = encoding::zero_index(c);
    let mut out = Vec::with_capacity(2 * c);
    let mut p = 1usize;
    for j in 0..c {
        let digit = (t / p) % 3;
        if digit > 0 {
            out.push((t - p, j as u8, false)); // chunk(t) = chunk(t-p) + e_j
        }
        if digit < 2 && t + p <= tz {
            out.push((t + p, j as u8, true)); // chunk(t) = chunk(t+p) - e_j
        }
        p *= 3;
    }
    out
}

/// Predecessors of binary address `t`: drop a set bit (add) or borrow a
/// clear bit (subtract — signs are free in the datapath).
fn binary_parents(t: usize, c: usize) -> Vec<(usize, u8, bool)> {
    let mut out = Vec::with_capacity(c);
    for j in 0..c {
        let bit = 1usize << j;
        if t & bit != 0 {
            out.push((t & !bit, j as u8, false));
        } else if (t | bit) < (1 << c) {
            out.push((t | bit, j as u8, true));
        }
    }
    out
}

/// Spanning-tree growth fused with pipeline scheduling (see module doc).
/// Greedy: shallowest BFS depth first; a node is eligible at slot `s`
/// only if some parent was written at slot ≤ s − min_dist (or is the
/// root).  Returns None if a bubble would be required.
fn grow_scheduled_tree(
    nodes: &[usize],
    root: usize,
    parents_of: &dyn Fn(usize) -> Vec<(usize, u8, bool)>,
    min_dist: usize,
    depth_of: &dyn Fn(usize) -> usize,
) -> Option<Vec<PathEntry>> {
    const ROOT_SLOT: i64 = i64::MIN / 2;
    let max_node = *nodes.iter().max().unwrap() + 1;
    let mut write_slot: Vec<Option<i64>> = vec![None; max_node];
    write_slot[root] = Some(ROOT_SLOT);
    let mut remaining: Vec<usize> = nodes.iter().copied().filter(|&n| n != root).collect();
    remaining.sort_by_key(|&n| depth_of(n));
    let mut entries = Vec::with_capacity(remaining.len());
    let mut slot: i64 = 0;
    while !remaining.is_empty() {
        let mut picked: Option<(usize, usize, u8, bool)> = None;
        'outer: for (i, &t) in remaining.iter().enumerate() {
            let mut best: Option<(i64, usize, u8, bool)> = None;
            for (p, j, sign) in parents_of(t) {
                if let Some(ws) = write_slot[p] {
                    if slot - ws >= min_dist as i64 {
                        match best {
                            Some((bs, ..)) if bs <= ws => {}
                            _ => best = Some((ws, p, j, sign)),
                        }
                    }
                }
            }
            if let Some((_, p, j, sign)) = best {
                picked = Some((i, p, j, sign));
                // remaining is depth-sorted; first eligible is our greedy pick
                let _ = t;
                break 'outer;
            }
        }
        let (i, p, j, sign) = picked?;
        let t = remaining.remove(i);
        entries.push(PathEntry { dst: t as u16, src: p as u16, j, sign });
        write_slot[t] = Some(slot);
        slot += 1;
    }
    Some(entries)
}

fn grow_with_relaxation(
    nodes: &[usize],
    root: usize,
    parents_of: &dyn Fn(usize) -> Vec<(usize, u8, bool)>,
    min_dist: usize,
    depth_of: &dyn Fn(usize) -> usize,
) -> Vec<PathEntry> {
    for md in (1..=min_dist).rev() {
        if let Some(entries) = grow_scheduled_tree(nodes, root, parents_of, md, depth_of) {
            return entries;
        }
    }
    unreachable!("min_dist=1 always schedulable on a connected graph")
}

/// Memoized shipped-configuration paths (§Perf iteration 1: the
/// simulator calls path generation once per `simulate_gemm`, which
/// dominated its profile; paths are value-independent so caching is
/// semantically free).
pub fn ternary_path_cached(c: usize) -> &'static BuildPath {
    use std::sync::OnceLock;
    static C5: OnceLock<BuildPath> = OnceLock::new();
    static OTHER: OnceLock<std::sync::Mutex<std::collections::HashMap<usize, &'static BuildPath>>> =
        OnceLock::new();
    if c == 5 {
        return C5.get_or_init(|| ternary_path(5));
    }
    let map = OTHER.get_or_init(Default::default);
    let mut m = map.lock().unwrap();
    m.entry(c).or_insert_with(|| Box::leak(Box::new(ternary_path(c))))
}

/// Memoized binary path (see [`ternary_path_cached`]).
pub fn binary_path_cached(c: usize) -> &'static BuildPath {
    use std::sync::OnceLock;
    static C7: OnceLock<BuildPath> = OnceLock::new();
    static OTHER: OnceLock<std::sync::Mutex<std::collections::HashMap<usize, &'static BuildPath>>> =
        OnceLock::new();
    if c == 7 {
        return C7.get_or_init(|| binary_path(7));
    }
    let map = OTHER.get_or_init(Default::default);
    let mut m = map.lock().unwrap();
    m.entry(c).or_insert_with(|| Box::leak(Box::new(binary_path(c))))
}

/// Build path for the ternary LUT with mirror consolidation (c=5 in the
/// shipped design): ⌈3^c/2⌉ − 1 additions, one per stored entry.
pub fn ternary_path(c: usize) -> BuildPath {
    let root = encoding::zero_index(c);
    let nodes: Vec<usize> = (0..encoding::lut_entries(c)).collect();
    let depth_of = |t: usize| -> usize {
        encoding::chunk_of_index(t, c)
            .iter()
            .map(|&v| v.unsigned_abs() as usize)
            .sum()
    };
    let entries = grow_with_relaxation(
        &nodes,
        root,
        &|t| ternary_parents(t, c),
        PIPELINE_DEPTH,
        &depth_of,
    );
    let min_raw = raw_distance(&entries, root);
    BuildPath { kind: PathKind::Ternary, c, root, entries, min_raw_distance: min_raw }
}

/// Build path for the binary (bit-serial) LUT: 2^c − 1 additions.
pub fn binary_path(c: usize) -> BuildPath {
    let nodes: Vec<usize> = (0..(1usize << c)).collect();
    let entries = grow_with_relaxation(
        &nodes,
        0,
        &|t| binary_parents(t, c),
        PIPELINE_DEPTH,
        &|t| t.count_ones() as usize,
    );
    let min_raw = raw_distance(&entries, 0);
    BuildPath { kind: PathKind::Binary, c, root: 0, entries, min_raw_distance: min_raw }
}

/// Minimum RAW distance over a path; panics on use-before-write (an
/// invalid path). Root reads never hazard.
pub fn raw_distance(entries: &[PathEntry], root: usize) -> usize {
    let mut write_slot = std::collections::HashMap::new();
    write_slot.insert(root, i64::MIN / 2);
    let mut best = usize::MAX;
    for (i, e) in entries.iter().enumerate() {
        let ws = *write_slot
            .get(&(e.src as usize))
            .unwrap_or_else(|| panic!("entry {i} reads unwritten src {}", e.src));
        let d = (i as i64 - ws).min(usize::MAX as i64) as usize;
        best = best.min(d);
        write_slot.insert(e.dst as usize, i as i64);
    }
    best
}

/// Replay a path against concrete activations — Algorithm 2 in software.
/// `acts` is (c × n_cols) row-major; returns (entries × n_cols) LUT.
pub fn replay(path: &BuildPath, acts: &[i32], n_cols: usize, total_entries: usize) -> Vec<i64> {
    assert_eq!(acts.len(), path.c * n_cols);
    let mut lut = vec![0i64; total_entries * n_cols];
    for e in &path.entries {
        let (dst, src, j) = (e.dst as usize, e.src as usize, e.j as usize);
        for col in 0..n_cols {
            let a = acts[j * n_cols + col] as i64;
            let v = lut[src * n_cols + col] + if e.sign { -a } else { a };
            lut[dst * n_cols + col] = v;
        }
    }
    lut
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ternary_c5_covers_all_and_is_hazard_free() {
        let p = ternary_path(5);
        assert_eq!(p.additions(), 121); // ⌈3^5/2⌉ − 1
        assert!(p.hazard_free(), "RAW {} < {}", p.min_raw_distance, PIPELINE_DEPTH);
        let mut dsts: Vec<_> = p.entries.iter().map(|e| e.dst).collect();
        dsts.sort_unstable();
        dsts.dedup();
        assert_eq!(dsts.len(), 121);
    }

    #[test]
    fn binary_c7_covers_all_and_is_hazard_free() {
        let p = binary_path(7);
        assert_eq!(p.additions(), 127);
        assert!(p.hazard_free());
    }

    #[test]
    fn ternary_replay_matches_dot_product() {
        let p = ternary_path(5);
        let acts: Vec<i32> = vec![13, -7, 100, -128, 127];
        let lut = replay(&p, &acts, 1, encoding::lut_entries(5));
        for idx in 0..encoding::lut_entries(5) {
            let chunk = encoding::chunk_of_index(idx, 5);
            let want: i64 = chunk.iter().zip(&acts).map(|(&w, &a)| w as i64 * a as i64).sum();
            assert_eq!(lut[idx], want, "entry {idx}");
        }
    }

    #[test]
    fn binary_replay_matches_dot_product() {
        let p = binary_path(7);
        let acts: Vec<i32> = vec![5, -3, 9, 0, -11, 2, 7];
        let lut = replay(&p, &acts, 1, 128);
        for t in 0..128usize {
            let want: i64 = (0..7).map(|j| ((t >> j) & 1) as i64 * acts[j] as i64).sum();
            assert_eq!(lut[t], want, "address {t}");
        }
    }

    #[test]
    fn replay_vectorized_matches_scalar() {
        let p = ternary_path(5);
        let acts: Vec<i32> = (0..40).map(|i| (i * 17 % 255) - 127).collect(); // c=5 × n=8
        let lut = replay(&p, &acts, 8, encoding::lut_entries(5));
        for col in 0..8 {
            let col_acts: Vec<i32> = (0..5).map(|j| acts[j * 8 + col]).collect();
            let scalar = replay(&p, &col_acts, 1, encoding::lut_entries(5));
            for idx in 0..encoding::lut_entries(5) {
                assert_eq!(lut[idx * 8 + col], scalar[idx]);
            }
        }
    }

    #[test]
    fn construction_cost_reduction_is_10x_at_c5() {
        // E10: naive ternary construction is c·3^c adds per chunk.
        let naive = 5 * encoding::pow3(5);
        let ours = ternary_path(5).additions();
        assert!(naive as f64 / ours as f64 > 9.5);
    }

    #[test]
    fn construct_cycles_hazard_free_has_no_bubbles() {
        let p = ternary_path(5);
        assert_eq!(p.construct_cycles(PIPELINE_DEPTH), 121 + 4);
    }

    #[test]
    fn prop_ternary_path_valid_any_c() {
        for c in 2..=5 {
            let p = ternary_path(c);
            assert_eq!(p.additions(), encoding::lut_entries(c) - 1);
            // topological validity: raw_distance panics on use-before-write
            let _ = raw_distance(&p.entries, p.root);
        }
    }

    #[test]
    fn prop_replay_is_linear() {
        // LUT construction is linear in the activations:
        // replay(a + b) == replay(a) + replay(b)
        crate::util::check_prop("replay_is_linear", 24, |seed| {
            let mut rng = crate::util::rng::Rng::seed_from(seed);
            let p = ternary_path(4);
            let n = encoding::lut_entries(4);
            let a: Vec<i32> = (0..4).map(|_| rng.range_i64(-100, 100) as i32).collect();
            let b: Vec<i32> = (0..4).map(|_| rng.range_i64(-100, 100) as i32).collect();
            let ab: Vec<i32> = a.iter().zip(&b).map(|(x, y)| x + y).collect();
            let ra = replay(&p, &a, 1, n);
            let rb = replay(&p, &b, 1, n);
            let rab = replay(&p, &ab, 1, n);
            for i in 0..n {
                crate::ensure_prop!(rab[i] == ra[i] + rb[i], "nonlinear at entry {i}");
            }
            Ok(())
        });
    }
}
