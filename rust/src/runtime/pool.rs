//! Persistent worker pool for the functional hot paths (S14).
//!
//! The golden datapath ([`crate::lut`]) and the real T-MAC kernel
//! ([`crate::baselines::tmac::TMacCpu`]) are the repo's latency ground
//! truth, and decode-shaped GEMMs are far too small to amortize a
//! `std::thread::scope` spawn per call (tens of microseconds of spawn
//! and join for a kernel that runs in hundreds).  This module provides
//! the alternative: a pool of long-lived workers fed through a
//! mutex/condvar job queue, with a scoped [`Pool::run`] that blocks
//! until every submitted task finishes.
//!
//! **Why not rayon:** the build is fully offline (see `Cargo.toml`:
//! every dependency is vendored under `rust/vendor/`), so pulling in
//! rayon and its crossbeam dependency tree is not an option.  The hot
//! paths need exactly one primitive — fork-join over borrowed slices —
//! and ~200 lines of std suffice; NUMA-aware striping and work stealing
//! are ROADMAP follow-ups if profiles ever demand them.
//!
//! Soundness of the scoped API: `run` transmutes each boxed task to
//! `'static` to push it through the `'static` queue, then blocks on a
//! completion latch before returning.  No borrow captured by a task can
//! therefore outlive the call, which is the same contract
//! `std::thread::scope` enforces.  Tasks must not block waiting for
//! other pool work (the submitting thread helps drain the queue, so
//! plain nested `run` calls complete, but hand-rolled cross-task
//! waiting can deadlock).
//!
//! Panics inside a task are caught, the latch still releases, and the
//! submitting `run` call re-panics — a poisoned worker never wedges the
//! pool.

use std::collections::VecDeque;
use std::ops::Range;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::thread;

/// A task as it lives in the queue ('static; scoped tasks are lifetime-
/// erased by [`Pool::run`], which guarantees completion before return).
type Job = Box<dyn FnOnce() + Send + 'static>;

/// A scoped task as callers submit it: may borrow from the caller's
/// stack frame for the duration of the [`Pool::run`] call.
pub type Task<'scope> = Box<dyn FnOnce() + Send + 'scope>;

struct Shared {
    queue: Mutex<VecDeque<Job>>,
    work: Condvar,
    shutdown: AtomicBool,
}

/// Completion latch for one `run` batch: counts tasks down to zero and
/// records whether any of them panicked.
struct Latch {
    state: Mutex<(usize, bool)>,
    done: Condvar,
}

impl Latch {
    fn new(count: usize) -> Latch {
        Latch { state: Mutex::new((count, false)), done: Condvar::new() }
    }

    fn complete(&self, ok: bool) {
        let mut st = self.state.lock().unwrap();
        st.0 -= 1;
        if !ok {
            st.1 = true;
        }
        if st.0 == 0 {
            self.done.notify_all();
        }
    }

    /// Block until all tasks completed; returns true if any panicked.
    fn wait(&self) -> bool {
        let mut st = self.state.lock().unwrap();
        while st.0 > 0 {
            st = self.done.wait(st).unwrap();
        }
        st.1
    }
}

/// Persistent fork-join worker pool.
///
/// A pool of `threads` has `threads - 1` OS workers: the thread calling
/// [`Pool::run`] participates in executing the batch, so total
/// concurrency equals `threads` without oversubscribing the machine.
pub struct Pool {
    shared: Arc<Shared>,
    workers: Vec<thread::JoinHandle<()>>,
    threads: usize,
}

impl Pool {
    /// Pool with the given total concurrency (clamped to ≥ 1).
    pub fn new(threads: usize) -> Pool {
        let threads = threads.max(1);
        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            work: Condvar::new(),
            shutdown: AtomicBool::new(false),
        });
        let workers = (1..threads)
            .map(|i| {
                let shared = Arc::clone(&shared);
                thread::Builder::new()
                    .name(format!("platinum-pool-{i}"))
                    .spawn(move || worker_loop(&shared))
                    .expect("spawning pool worker")
            })
            .collect();
        Pool { shared, workers, threads }
    }

    /// Total concurrency (workers + the participating caller).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Execute every task and return once all have finished.
    ///
    /// Tasks may borrow from the caller's frame (see module docs for the
    /// soundness argument).  The caller's thread helps drain the queue,
    /// so a 1-thread pool degenerates to inline sequential execution.
    /// Re-panics on the calling thread if any task panicked.
    pub fn run<'scope>(&self, tasks: Vec<Task<'scope>>) {
        match tasks.len() {
            0 => return,
            // nothing to overlap: run inline, skip the latch machinery
            1 => {
                let mut tasks = tasks;
                (tasks.pop().unwrap())();
                return;
            }
            _ => {}
        }
        if self.workers.is_empty() {
            for task in tasks {
                task();
            }
            return;
        }
        let latch = Arc::new(Latch::new(tasks.len()));
        {
            let mut q = self.shared.queue.lock().unwrap();
            for task in tasks {
                // SAFETY: this call blocks on `latch` until every task
                // has run to completion, so no borrow captured by `task`
                // outlives the `'scope` it was created in.
                let task: Job = unsafe {
                    std::mem::transmute::<Task<'scope>, Task<'static>>(task)
                };
                let latch = Arc::clone(&latch);
                q.push_back(Box::new(move || {
                    let ok = catch_unwind(AssertUnwindSafe(task)).is_ok();
                    latch.complete(ok);
                }));
            }
        }
        self.shared.work.notify_all();
        // help: the submitting thread drains jobs (possibly including
        // other batches') until the queue is empty, then waits
        loop {
            let job = self.shared.queue.lock().unwrap().pop_front();
            match job {
                Some(job) => job(),
                None => break,
            }
        }
        if latch.wait() {
            panic!("platinum worker pool: a task panicked (see stderr)");
        }
    }
}

impl Drop for Pool {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::Release);
        self.shared.work.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_loop(shared: &Shared) {
    loop {
        let job = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if let Some(job) = q.pop_front() {
                    break Some(job);
                }
                if shared.shutdown.load(Ordering::Acquire) {
                    break None;
                }
                q = shared.work.wait(q).unwrap();
            }
        };
        match job {
            Some(job) => job(),
            None => return,
        }
    }
}

/// Default concurrency: `PLATINUM_THREADS` env override, else the
/// machine's available parallelism.
pub fn default_threads() -> usize {
    std::env::var("PLATINUM_THREADS")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .filter(|&n| n > 0)
        .unwrap_or_else(|| thread::available_parallelism().map(|n| n.get()).unwrap_or(1))
}

/// The process-wide pool every default hot-path entry point runs on
/// (sized by [`default_threads`], created on first use, never torn
/// down).  Callers needing an exact concurrency — bench sweeps, the
/// `with_threads` backend constructors — build their own [`Pool`].
pub fn global() -> &'static Pool {
    static GLOBAL: OnceLock<Pool> = OnceLock::new();
    GLOBAL.get_or_init(|| Pool::new(default_threads()))
}

/// Split `buf` into consecutive mutable slices of the given widths —
/// the arena-partitioning companion to [`split_even`], used to hand
/// each task its disjoint output/scratch region.  Trailing capacity
/// beyond the widths' sum stays unborrowed.
pub fn take_slices<'a, T>(
    mut buf: &'a mut [T],
    widths: impl Iterator<Item = usize>,
) -> Vec<&'a mut [T]> {
    let mut out = Vec::new();
    for w in widths {
        let (head, tail) = std::mem::take(&mut buf).split_at_mut(w);
        out.push(head);
        buf = tail;
    }
    out
}

/// Split `len` items into at most `parts` contiguous, near-equal,
/// non-empty ranges (fewer than `parts` when `len < parts`) — the
/// row-stripe decomposition every parallel hot path uses.
pub fn split_even(len: usize, parts: usize) -> Vec<Range<usize>> {
    let parts = parts.max(1).min(len);
    let mut out = Vec::with_capacity(parts);
    if len == 0 {
        return out;
    }
    let base = len / parts;
    let rem = len % parts;
    let mut start = 0;
    for i in 0..parts {
        let size = base + usize::from(i < rem);
        out.push(start..start + size);
        start += size;
    }
    debug_assert_eq!(start, len);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn runs_every_task() {
        let pool = Pool::new(4);
        let counter = AtomicUsize::new(0);
        let tasks: Vec<Task> = (0..64)
            .map(|_| {
                Box::new(|| {
                    counter.fetch_add(1, Ordering::Relaxed);
                }) as Task
            })
            .collect();
        pool.run(tasks);
        assert_eq!(counter.load(Ordering::Relaxed), 64);
    }

    #[test]
    fn scoped_borrows_of_disjoint_slices() {
        let pool = Pool::new(3);
        let mut data = vec![0u64; 100];
        let tasks: Vec<Task> = data
            .chunks_mut(7)
            .enumerate()
            .map(|(i, chunk)| {
                Box::new(move || {
                    for (j, v) in chunk.iter_mut().enumerate() {
                        *v = (i * 7 + j) as u64;
                    }
                }) as Task
            })
            .collect();
        pool.run(tasks);
        for (i, &v) in data.iter().enumerate() {
            assert_eq!(v, i as u64);
        }
    }

    #[test]
    fn single_thread_pool_runs_inline() {
        let pool = Pool::new(1);
        assert_eq!(pool.threads(), 1);
        let mut hits = 0;
        let tasks: Vec<Task> = (0..5).map(|_| Box::new(|| {}) as Task).collect();
        pool.run(tasks);
        // borrowed mutation still observable after run returns
        pool.run(vec![Box::new(|| hits += 1) as Task]);
        assert_eq!(hits, 1);
    }

    #[test]
    fn reuse_across_many_batches() {
        // the whole point vs thread::scope: no spawn per call
        let pool = Pool::new(2);
        let total = AtomicUsize::new(0);
        for round in 0..50 {
            let tasks: Vec<Task> = (0..4)
                .map(|_| {
                    Box::new(|| {
                        total.fetch_add(round, Ordering::Relaxed);
                    }) as Task
                })
                .collect();
            pool.run(tasks);
        }
        assert_eq!(total.load(Ordering::Relaxed), 4 * (0..50).sum::<usize>());
    }

    #[test]
    #[should_panic(expected = "a task panicked")]
    fn task_panic_propagates_without_wedging() {
        let pool = Pool::new(2);
        let tasks: Vec<Task> =
            vec![Box::new(|| {}) as Task, Box::new(|| panic!("boom")) as Task];
        pool.run(tasks);
    }

    #[test]
    fn pool_survives_a_panicked_batch() {
        let pool = Pool::new(2);
        let bad: Vec<Task> = vec![
            Box::new(|| panic!("expected")) as Task,
            Box::new(|| {}) as Task,
        ];
        assert!(catch_unwind(AssertUnwindSafe(|| pool.run(bad))).is_err());
        // the pool still executes subsequent batches
        let counter = AtomicUsize::new(0);
        let tasks: Vec<Task> = (0..8)
            .map(|_| {
                Box::new(|| {
                    counter.fetch_add(1, Ordering::Relaxed);
                }) as Task
            })
            .collect();
        pool.run(tasks);
        assert_eq!(counter.load(Ordering::Relaxed), 8);
    }

    #[test]
    fn take_slices_partitions_disjointly() {
        let mut buf = vec![0u8; 10];
        {
            let parts = take_slices(&mut buf, [3usize, 2, 4].into_iter());
            assert_eq!(parts.iter().map(|p| p.len()).collect::<Vec<_>>(), vec![3, 2, 4]);
            for (i, p) in parts.into_iter().enumerate() {
                p.fill(i as u8 + 1);
            }
        }
        assert_eq!(buf, vec![1, 1, 1, 2, 2, 3, 3, 3, 3, 0]);
    }

    #[test]
    fn split_even_covers_and_balances() {
        assert_eq!(split_even(10, 3), vec![0..4, 4..7, 7..10]);
        assert_eq!(split_even(8, 4), vec![0..2, 2..4, 4..6, 6..8]);
        // more parts than items: one range per item
        assert_eq!(split_even(3, 8), vec![0..1, 1..2, 2..3]);
        assert_eq!(split_even(0, 4), Vec::<Range<usize>>::new());
        assert_eq!(split_even(5, 1), vec![0..5]);
    }

    #[test]
    fn global_pool_is_reused() {
        let a = global() as *const Pool;
        let b = global() as *const Pool;
        assert_eq!(a, b);
        assert!(global().threads() >= 1);
    }

    #[test]
    fn default_threads_positive() {
        assert!(default_threads() >= 1);
    }
}
