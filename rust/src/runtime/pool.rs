//! Persistent work-stealing worker pool for the functional hot paths
//! (S14).
//!
//! The golden datapath ([`crate::lut`]) and the real T-MAC kernel
//! ([`crate::baselines::tmac::TMacCpu`]) are the repo's latency ground
//! truth, and decode-shaped GEMMs are far too small to amortize a
//! `std::thread::scope` spawn per call (tens of microseconds of spawn
//! and join for a kernel that runs in hundreds).  This module provides
//! the alternative: a pool of long-lived workers, with a scoped
//! [`Pool::run`] that blocks until every submitted task finishes and a
//! [`Pool::for_each_chunk`] that schedules loop iterations dynamically.
//!
//! **Scheduler (PR 4, replacing the single shared queue):** each lane
//! (worker, plus lane 0 for external submitters) owns a mutex-protected
//! deque.  [`Pool::run`] distributes a batch as contiguous blocks, one
//! lock acquisition per lane, starting at the submitter's own lane, so
//! each deque is bounded to ⌈tasks/lanes⌉ entries per submission;
//! owners pop their own **tail**
//! (LIFO — the cache-warm end), and a lane that runs dry steals from
//! the **head** (FIFO — the oldest work) of victims visited in a
//! randomized rotation.  This removes the global-queue convoy the seed
//! implementation had: decode-shaped GEMMs submit many sub-microsecond
//! tasks, and under one shared mutex every pop serialized on every
//! push.  Idle lanes park on a single condvar; submitters notify under
//! the same mutex, so wakeups cannot be lost.
//!
//! **Why not rayon:** the build is fully offline (see `Cargo.toml`:
//! every dependency is vendored under `rust/vendor/`), so pulling in
//! rayon and its crossbeam dependency tree is not an option.  The hot
//! paths need fork-join over borrowed slices plus a dynamic parallel
//! loop, and ~300 lines of std suffice; NUMA-aware lane striping is the
//! remaining ROADMAP follow-up.
//!
//! Soundness of the scoped API: `run` transmutes each boxed task to
//! `'static` to push it through the `'static` deques, then blocks on a
//! completion latch before returning.  No borrow captured by a task can
//! therefore outlive the call, which is the same contract
//! `std::thread::scope` enforces.  Tasks must not block waiting for
//! other pool work (the submitting thread helps drain the deques, and
//! nested `run` calls from inside a task complete because every lane —
//! including the nested submitter — can claim any queued job; but
//! hand-rolled cross-task waiting can deadlock).
//!
//! Panics inside a task are caught — even when the task was claimed by
//! a stealing lane — the latch still releases, and the submitting `run`
//! call re-panics: a poisoned worker never wedges the pool.
//!
//! **Bit-exactness invariant** every hot path relies on: the scheduler
//! decides only *which lane* executes a task or claims a chunk, never
//! the order of arithmetic *within* a task or chunk.  Hot paths keep
//! per-output accumulation order fixed (rounds are sequential, chunk
//! order within a round is fixed per row), so results are bit-identical
//! at every thread count.

use std::cell::Cell;
use std::collections::VecDeque;
use std::marker::PhantomData;
use std::ops::Range;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::thread;

/// A task as it lives in a deque ('static; scoped tasks are lifetime-
/// erased by [`Pool::run`], which guarantees completion before return).
type Job = Box<dyn FnOnce() + Send + 'static>;

/// A scoped task as callers submit it: may borrow from the caller's
/// stack frame for the duration of the [`Pool::run`] call.
pub type Task<'scope> = Box<dyn FnOnce() + Send + 'scope>;

/// Per-submission deque capacity hint: block distribution bounds a
/// single batch's share of one deque to ⌈tasks/lanes⌉, and hot-path
/// batches are at most a few dozen tasks, so this avoids regrowth
/// (larger batches regrow at most once per submission — `extend` from
/// an exact-size iterator reserves up front).
const DEQUE_CAPACITY: usize = 64;

/// One lane's work deque.  The owning lane pushes/pops at the back
/// (LIFO); thieves pop at the front (FIFO), so stolen work is the
/// oldest — the standard work-stealing discipline.  Cache-line aligned
/// so neighbouring lanes' deque mutexes never share a line (false
/// sharing would partially recreate the convoy the per-lane split
/// removes).
#[repr(align(64))]
struct Slot {
    deque: Mutex<VecDeque<Job>>,
}

struct Shared {
    /// One slot per lane: lane 0 belongs to external submitters, lanes
    /// `1..threads` to the OS workers.
    slots: Vec<Slot>,
    /// Queued-but-unclaimed jobs across all slots (a fast "is there
    /// anything to do" signal for parking lanes).
    pending: AtomicUsize,
    /// Parking lot: idle workers wait here; submitters notify while
    /// holding `sleep`, which makes the sleep/notify race lossless.
    sleep: Mutex<()>,
    work: Condvar,
    shutdown: AtomicBool,
}

thread_local! {
    /// (pool identity, lane) of the current thread when it is a pool
    /// worker — lets nested `run`/`for_each_chunk` calls from inside a
    /// task submit to their own lane instead of contending on lane 0.
    static WORKER_LANE: Cell<(usize, usize)> = const { Cell::new((0, 0)) };

    /// Per-thread xorshift state for the randomized victim rotation —
    /// thread-local so the steal path never writes a shared cache line
    /// (a global RMW per claim attempt would partially recreate the
    /// single-queue convoy in steal-heavy tiny-task regimes).
    static STEAL_RNG: Cell<usize> = const { Cell::new(0) };
}

/// Next per-thread pseudo-random value: xorshift over thread-local
/// state, seeded once per thread from a global counter (the only
/// shared write, once per thread lifetime).
fn steal_rand() -> usize {
    static SEED: AtomicUsize = AtomicUsize::new(0x9e37_79b9);
    STEAL_RNG.with(|c| {
        let mut s = c.get();
        if s == 0 {
            s = SEED.fetch_add(0x9e37_79b9, Ordering::Relaxed) | 1;
        }
        s ^= s << 13;
        s ^= s >> 7;
        s ^= s << 17;
        c.set(s);
        s
    })
}

/// Completion latch for one `run` batch: a lock-free atomic countdown
/// — per-task completions and the submitter's between-claims polls
/// touch only atomics, so thousands of sub-microsecond tasks don't
/// convoy on a latch mutex.  The mutex/condvar pair exists solely for
/// the final wakeup handshake: the last completer notifies while
/// holding the mutex, which serializes with the waiter's
/// check-then-wait and makes the wakeup lossless.
struct Latch {
    remaining: AtomicUsize,
    panicked: AtomicBool,
    sleep: Mutex<()>,
    done: Condvar,
}

impl Latch {
    fn new(count: usize) -> Latch {
        Latch {
            remaining: AtomicUsize::new(count),
            panicked: AtomicBool::new(false),
            sleep: Mutex::new(()),
            done: Condvar::new(),
        }
    }

    fn complete(&self, ok: bool) {
        if !ok {
            self.panicked.store(true, Ordering::Release);
        }
        if self.remaining.fetch_sub(1, Ordering::AcqRel) == 1 {
            // last task out: notify under the mutex (see type docs)
            let _guard = self.sleep.lock().unwrap();
            self.done.notify_all();
        }
    }

    /// Block until all tasks completed; returns true if any panicked.
    fn wait(&self) -> bool {
        let mut guard = self.sleep.lock().unwrap();
        while self.remaining.load(Ordering::Acquire) > 0 {
            guard = self.done.wait(guard).unwrap();
        }
        self.panicked.load(Ordering::Acquire)
    }

    /// Lock-free completion probe (the helping submitter polls this
    /// so it stops claiming *other* batches' work once its own is done).
    fn is_done(&self) -> bool {
        self.remaining.load(Ordering::Acquire) == 0
    }
}

/// Persistent fork-join worker pool with per-lane work stealing.
///
/// A pool of `threads` has `threads - 1` OS workers: the thread calling
/// [`Pool::run`] participates in executing the batch, so total
/// concurrency equals `threads` without oversubscribing the machine.
pub struct Pool {
    shared: Arc<Shared>,
    workers: Vec<thread::JoinHandle<()>>,
    threads: usize,
}

impl Pool {
    /// Pool with the given total concurrency (clamped to ≥ 1).
    pub fn new(threads: usize) -> Pool {
        let threads = threads.max(1);
        let shared = Arc::new(Shared {
            slots: (0..threads)
                .map(|_| Slot { deque: Mutex::new(VecDeque::with_capacity(DEQUE_CAPACITY)) })
                .collect(),
            pending: AtomicUsize::new(0),
            sleep: Mutex::new(()),
            work: Condvar::new(),
            shutdown: AtomicBool::new(false),
        });
        let workers = (1..threads)
            .map(|lane| {
                let shared = Arc::clone(&shared);
                thread::Builder::new()
                    .name(format!("platinum-pool-{lane}"))
                    .spawn(move || worker_loop(&shared, lane))
                    .expect("spawning pool worker")
            })
            .collect();
        Pool { shared, workers, threads }
    }

    /// Total concurrency (workers + the participating caller).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// The lane whose deque this thread should submit to / pop from
    /// first: its own lane when it is a worker of *this* pool, lane 0
    /// otherwise (external callers share lane 0; its deque mutex makes
    /// concurrent external submitters safe).
    fn home_lane(&self) -> usize {
        let (pool_id, lane) = WORKER_LANE.with(Cell::get);
        if pool_id == Arc::as_ptr(&self.shared) as *const () as usize && lane < self.threads {
            lane
        } else {
            0
        }
    }

    /// Execute every task and return once all have finished.
    ///
    /// Tasks may borrow from the caller's frame (see module docs for the
    /// soundness argument).  The caller's thread helps drain the deques,
    /// so a 1-thread pool degenerates to inline sequential execution.
    /// Re-panics on the calling thread if any task panicked.
    pub fn run<'scope>(&self, tasks: Vec<Task<'scope>>) {
        match tasks.len() {
            0 => return,
            // nothing to overlap: run inline, skip the latch machinery
            1 => {
                let mut tasks = tasks;
                (tasks.pop().unwrap())();
                return;
            }
            _ => {}
        }
        if self.workers.is_empty() {
            for task in tasks {
                task();
            }
            return;
        }
        let count = tasks.len();
        let latch = Arc::new(Latch::new(count));
        let home = self.home_lane();
        let lanes = self.shared.slots.len();
        // wrap every task BEFORE touching any lock (boxing outside the
        // critical sections), then distribute contiguous blocks of
        // ⌈count/lanes⌉ with ONE lock acquisition per lane, starting at
        // the submitter's own lane — a 2048-task batch takes `lanes`
        // locks, not 2048
        let jobs: Vec<Job> = tasks
            .into_iter()
            .map(|task| {
                // SAFETY: this call blocks on `latch` until every task
                // has run to completion, so no borrow captured by
                // `task` outlives the `'scope` it was created in.
                let task: Job =
                    unsafe { std::mem::transmute::<Task<'scope>, Task<'static>>(task) };
                let latch = Arc::clone(&latch);
                Box::new(move || {
                    let ok = catch_unwind(AssertUnwindSafe(task)).is_ok();
                    latch.complete(ok);
                }) as Job
            })
            .collect();
        // count up BEFORE the first push: `pending` must never read
        // lower than the number of queued jobs, or a racing claimant's
        // decrement would wrap it (transiently over-counting is fine —
        // an early-woken worker just rescans and re-parks)
        self.shared.pending.fetch_add(count, Ordering::Release);
        let per = count.div_ceil(lanes);
        let mut jobs = jobs.into_iter();
        let mut lane = home;
        loop {
            let mut q = self.shared.slots[lane].deque.lock().unwrap();
            let before = q.len();
            q.extend(jobs.by_ref().take(per));
            let pushed = q.len() - before;
            drop(q);
            if pushed < per {
                break; // iterator exhausted
            }
            lane = (lane + 1) % lanes;
        }
        {
            // notify under the sleep mutex: a worker between its "no
            // work" scan and its wait() holds this mutex, so it either
            // sees `pending > 0` or receives this notification
            let _guard = self.shared.sleep.lock().unwrap();
            self.shared.work.notify_all();
        }
        // help: the submitting thread claims jobs (its own lane's tail
        // first, then steals — possibly other batches') until nothing
        // is claimable or its own batch completed, then waits
        while !latch.is_done() {
            match find_job(&self.shared, home) {
                Some(job) => job(),
                None => break,
            }
        }
        if latch.wait() {
            panic!("platinum worker pool: a task panicked (see stderr)");
        }
    }

    /// Chunked dynamic scheduling: run `body` over every index in
    /// `0..len`, claimed in contiguous chunks of `grain` indices from a
    /// single atomic cursor by up to `threads` lanes.
    ///
    /// `grain == 0` selects the self-tuning grain ([`auto_grain`]).
    /// Unlike a static partition (`split_even` stripes), lanes that
    /// finish early keep claiming chunks, so ragged per-index costs,
    /// `threads > len`, and stragglers load-balance instead of idling.
    ///
    /// Exactness contract: every index is processed exactly once, and
    /// indices within one chunk are visited in ascending order by one
    /// lane — so a `body` whose per-index work is independent of *which*
    /// lane runs it (true for every hot path: per-row accumulation
    /// order is internal to the row) is bit-exact at any thread count.
    ///
    /// Re-panics on the calling thread if `body` panicked on any lane.
    pub fn for_each_chunk<F>(&self, threads: usize, len: usize, grain: usize, body: &F)
    where
        F: Fn(Range<usize>) + Sync,
    {
        let mut unit: [(); 0] = [];
        self.for_each_chunk_arena(threads, len, grain, &mut unit, &|_s, r| body(r));
    }

    /// [`Pool::for_each_chunk`] with per-lane scratch drawn from a
    /// caller-hoisted arena: `arena` is split evenly across the
    /// participating lanes (via [`take_slices`]) and `body` receives
    /// its lane's region mutably with every chunk it claims — so a hot
    /// path hoists its staging/accumulator buffers **once per call**
    /// (as with static striping) even though dynamic claims have no
    /// stable lane identity to pre-partition scratch by.  Size `arena`
    /// for `threads` lanes (`threads × width`); a lane's region is then
    /// at least `width` long (longer when fewer lanes participate), and
    /// `body` slices off the prefix it needs.  On the sequential path
    /// `body` sees the whole arena.
    pub fn for_each_chunk_arena<T, F>(
        &self,
        threads: usize,
        len: usize,
        grain: usize,
        arena: &mut [T],
        body: &F,
    ) where
        T: Send,
        F: Fn(&mut [T], Range<usize>) + Sync,
    {
        if len == 0 {
            return;
        }
        let grain = if grain == 0 { auto_grain(len, threads) } else { grain };
        let lanes = threads.max(1).min(len.div_ceil(grain));
        if lanes <= 1 || self.workers.is_empty() {
            let mut start = 0;
            while start < len {
                let end = (start + grain).min(len);
                body(arena, start..end);
                start = end;
            }
            return;
        }
        let cursor = AtomicUsize::new(0);
        let per = arena.len() / lanes;
        let parts = take_slices(arena, std::iter::repeat(per).take(lanes));
        let tasks: Vec<Task> = parts
            .into_iter()
            .map(|part| {
                let cursor = &cursor;
                Box::new(move || loop {
                    let start = cursor.fetch_add(grain, Ordering::Relaxed);
                    if start >= len {
                        break;
                    }
                    body(part, start..(start + grain).min(len));
                }) as Task
            })
            .collect();
        self.run(tasks);
    }
}

impl Drop for Pool {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::Release);
        {
            let _guard = self.shared.sleep.lock().unwrap();
            self.shared.work.notify_all();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Claim one job: `home`'s tail first (LIFO, cache-warm), then victims'
/// heads (FIFO) in a randomized rotation.  Returns `None` only after a
/// full sweep found every deque empty at the moment it was inspected.
fn find_job(shared: &Shared, home: usize) -> Option<Job> {
    if let Some(job) = shared.slots[home].deque.lock().unwrap().pop_back() {
        shared.pending.fetch_sub(1, Ordering::Release);
        return Some(job);
    }
    let lanes = shared.slots.len();
    if lanes > 1 && shared.pending.load(Ordering::Acquire) > 0 {
        // per-thread random rotation start: decorrelates victim choice
        // across lanes so thieves don't convoy on one deque
        let start = steal_rand() % lanes;
        for off in 0..lanes {
            let victim = (start + off) % lanes;
            if victim == home {
                continue;
            }
            if let Some(job) = shared.slots[victim].deque.lock().unwrap().pop_front() {
                shared.pending.fetch_sub(1, Ordering::Release);
                return Some(job);
            }
        }
    }
    None
}

fn worker_loop(shared: &Shared, lane: usize) {
    WORKER_LANE.with(|c| c.set((shared as *const Shared as *const () as usize, lane)));
    loop {
        if let Some(job) = find_job(shared, lane) {
            job();
            continue;
        }
        // park until there is (possibly) work or the pool shuts down
        let mut guard = shared.sleep.lock().unwrap();
        loop {
            if shared.shutdown.load(Ordering::Acquire) {
                return;
            }
            if shared.pending.load(Ordering::Acquire) > 0 {
                break;
            }
            guard = shared.work.wait(guard).unwrap();
        }
    }
}

/// Self-tuning chunk grain for [`Pool::for_each_chunk`]: targets ~8
/// claims per lane — enough slack for dynamic load balancing across
/// ragged chunk costs, few enough that cursor traffic stays negligible —
/// and never below one index per claim.
pub fn auto_grain(len: usize, threads: usize) -> usize {
    (len / (threads.max(1) * 8)).max(1)
}

/// Default concurrency: `PLATINUM_THREADS` env override, else the
/// machine's available parallelism.
pub fn default_threads() -> usize {
    std::env::var("PLATINUM_THREADS")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .filter(|&n| n > 0)
        .unwrap_or_else(|| thread::available_parallelism().map(|n| n.get()).unwrap_or(1))
}

/// The process-wide pool every default hot-path entry point runs on
/// (sized by [`default_threads`], created on first use, never torn
/// down).  Callers needing an exact concurrency — bench sweeps, the
/// `with_threads` backend constructors — build their own [`Pool`].
pub fn global() -> &'static Pool {
    static GLOBAL: OnceLock<Pool> = OnceLock::new();
    GLOBAL.get_or_init(|| Pool::new(default_threads()))
}

/// Shared handle to a mutable slice whose concurrent users write
/// **disjoint** ranges — the aliasing escape hatch
/// [`Pool::for_each_chunk`] bodies use to scatter into one output
/// buffer (a dynamic chunk claim can't be pre-partitioned the way
/// [`take_slices`] partitions for static stripes).
///
/// Safety contract: callers must guarantee that ranges passed to
/// [`DisjointSlice::range`] by concurrently running tasks never
/// overlap.  `for_each_chunk` hands out disjoint index ranges, so
/// mapping each index to a fixed, non-overlapping output range (e.g.
/// row `r` → `out[r*n..(r+1)*n]`) satisfies the contract by
/// construction.
pub struct DisjointSlice<'a, T> {
    ptr: *mut T,
    len: usize,
    _borrow: PhantomData<&'a mut [T]>,
}

// SAFETY: a DisjointSlice only hands out &mut to disjoint ranges (the
// caller's contract), so sending/sharing it across the pool's tasks is
// no more dangerous than split_at_mut — provided T itself is Send.
unsafe impl<T: Send> Send for DisjointSlice<'_, T> {}
unsafe impl<T: Send> Sync for DisjointSlice<'_, T> {}

impl<'a, T> DisjointSlice<'a, T> {
    pub fn new(slice: &'a mut [T]) -> Self {
        DisjointSlice { ptr: slice.as_mut_ptr(), len: slice.len(), _borrow: PhantomData }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Mutable view of `range`.
    ///
    /// # Safety
    /// No concurrently executing task may hold a range overlapping this
    /// one (see the type-level contract).  `range` must lie within the
    /// slice.
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn range(&self, range: Range<usize>) -> &mut [T] {
        debug_assert!(range.start <= range.end && range.end <= self.len);
        std::slice::from_raw_parts_mut(self.ptr.add(range.start), range.end - range.start)
    }
}

/// Split `buf` into consecutive mutable slices of the given widths —
/// the arena partitioner [`Pool::for_each_chunk_arena`] uses to hand
/// each lane its disjoint scratch region (and the general tool for any
/// static partition).  Trailing capacity beyond the widths' sum stays
/// unborrowed.
pub fn take_slices<'a, T>(
    mut buf: &'a mut [T],
    widths: impl Iterator<Item = usize>,
) -> Vec<&'a mut [T]> {
    let mut out = Vec::new();
    for w in widths {
        let (head, tail) = std::mem::take(&mut buf).split_at_mut(w);
        out.push(head);
        buf = tail;
    }
    out
}

/// Split `len` items into at most `parts` contiguous, near-equal,
/// non-empty ranges (fewer than `parts` when `len < parts`) — the
/// static decomposition used where shard boundaries are part of the
/// result's meaning (`engine::Sharded` row partitioning); hot-path
/// loops use [`Pool::for_each_chunk`] instead.
pub fn split_even(len: usize, parts: usize) -> Vec<Range<usize>> {
    let parts = parts.max(1).min(len);
    let mut out = Vec::with_capacity(parts);
    if len == 0 {
        return out;
    }
    let base = len / parts;
    let rem = len % parts;
    let mut start = 0;
    for i in 0..parts {
        let size = base + usize::from(i < rem);
        out.push(start..start + size);
        start += size;
    }
    debug_assert_eq!(start, len);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn runs_every_task() {
        let pool = Pool::new(4);
        let counter = AtomicUsize::new(0);
        let tasks: Vec<Task> = (0..64)
            .map(|_| {
                Box::new(|| {
                    counter.fetch_add(1, Ordering::Relaxed);
                }) as Task
            })
            .collect();
        pool.run(tasks);
        assert_eq!(counter.load(Ordering::Relaxed), 64);
    }

    #[test]
    fn scoped_borrows_of_disjoint_slices() {
        let pool = Pool::new(3);
        let mut data = vec![0u64; 100];
        let tasks: Vec<Task> = data
            .chunks_mut(7)
            .enumerate()
            .map(|(i, chunk)| {
                Box::new(move || {
                    for (j, v) in chunk.iter_mut().enumerate() {
                        *v = (i * 7 + j) as u64;
                    }
                }) as Task
            })
            .collect();
        pool.run(tasks);
        for (i, &v) in data.iter().enumerate() {
            assert_eq!(v, i as u64);
        }
    }

    #[test]
    fn single_thread_pool_runs_inline() {
        let pool = Pool::new(1);
        assert_eq!(pool.threads(), 1);
        let mut hits = 0;
        let tasks: Vec<Task> = (0..5).map(|_| Box::new(|| {}) as Task).collect();
        pool.run(tasks);
        // borrowed mutation still observable after run returns
        pool.run(vec![Box::new(|| hits += 1) as Task]);
        assert_eq!(hits, 1);
    }

    #[test]
    fn reuse_across_many_batches() {
        // the whole point vs thread::scope: no spawn per call
        let pool = Pool::new(2);
        let total = AtomicUsize::new(0);
        for round in 0..50 {
            let tasks: Vec<Task> = (0..4)
                .map(|_| {
                    Box::new(|| {
                        total.fetch_add(round, Ordering::Relaxed);
                    }) as Task
                })
                .collect();
            pool.run(tasks);
        }
        assert_eq!(total.load(Ordering::Relaxed), 4 * (0..50).sum::<usize>());
    }

    #[test]
    #[should_panic(expected = "a task panicked")]
    fn task_panic_propagates_without_wedging() {
        let pool = Pool::new(2);
        let tasks: Vec<Task> =
            vec![Box::new(|| {}) as Task, Box::new(|| panic!("boom")) as Task];
        pool.run(tasks);
    }

    #[test]
    fn pool_survives_a_panicked_batch() {
        let pool = Pool::new(2);
        let bad: Vec<Task> = vec![
            Box::new(|| panic!("expected")) as Task,
            Box::new(|| {}) as Task,
        ];
        assert!(catch_unwind(AssertUnwindSafe(|| pool.run(bad))).is_err());
        // the pool still executes subsequent batches
        let counter = AtomicUsize::new(0);
        let tasks: Vec<Task> = (0..8)
            .map(|_| {
                Box::new(|| {
                    counter.fetch_add(1, Ordering::Relaxed);
                }) as Task
            })
            .collect();
        pool.run(tasks);
        assert_eq!(counter.load(Ordering::Relaxed), 8);
    }

    #[test]
    fn deques_drain_after_every_batch() {
        // nothing may linger in any lane's deque once run() returns
        let pool = Pool::new(4);
        for _ in 0..20 {
            let tasks: Vec<Task> = (0..13).map(|_| Box::new(|| {}) as Task).collect();
            pool.run(tasks);
        }
        assert_eq!(pool.shared.pending.load(Ordering::Acquire), 0);
        for slot in &pool.shared.slots {
            assert!(slot.deque.lock().unwrap().is_empty());
        }
    }

    #[test]
    fn for_each_chunk_visits_every_index_once() {
        let pool = Pool::new(4);
        for (len, grain, threads) in
            [(100, 7, 4), (5, 1, 8), (64, 64, 4), (64, 200, 4), (1, 1, 1), (97, 0, 3)]
        {
            let hits: Vec<AtomicUsize> = (0..len).map(|_| AtomicUsize::new(0)).collect();
            pool.for_each_chunk(threads, len, grain, &|r: Range<usize>| {
                for i in r {
                    hits[i].fetch_add(1, Ordering::Relaxed);
                }
            });
            for (i, h) in hits.iter().enumerate() {
                assert_eq!(h.load(Ordering::Relaxed), 1, "index {i} len={len} grain={grain}");
            }
        }
    }

    #[test]
    fn for_each_chunk_zero_len_is_a_noop() {
        let pool = Pool::new(2);
        let called = AtomicUsize::new(0);
        pool.for_each_chunk(4, 0, 3, &|_r| {
            called.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(called.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn for_each_chunk_disjoint_writes_through_shared_slice() {
        let pool = Pool::new(4);
        let mut out = vec![0usize; 257];
        {
            let sl = DisjointSlice::new(&mut out);
            assert_eq!(sl.len(), 257);
            assert!(!sl.is_empty());
            pool.for_each_chunk(8, 257, 0, &|r: Range<usize>| {
                for i in r {
                    // SAFETY: chunk ranges are disjoint; each index is
                    // written by exactly one task
                    unsafe { sl.range(i..i + 1) }[0] = i * 3;
                }
            });
        }
        for (i, &v) in out.iter().enumerate() {
            assert_eq!(v, i * 3);
        }
    }

    #[test]
    fn for_each_chunk_arena_hands_each_lane_disjoint_scratch() {
        let pool = Pool::new(4);
        let sum = AtomicUsize::new(0);
        // arena sized for 4 lanes × width 8; every lane tallies its
        // claim count into its own region — no allocation per claim
        let mut arena = vec![0usize; 4 * 8];
        pool.for_each_chunk_arena(4, 1000, 1, &mut arena, &|scratch, r| {
            let scratch = &mut scratch[..8]; // prefix the body needs
            scratch[0] += 1;
            sum.fetch_add(r.len(), Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 1000);
        // all 1000 grain-1 claims are accounted for, spread over ≤4 lanes
        let claims: usize = arena.chunks(8).map(|c| c[0]).sum();
        assert_eq!(claims, 1000);
        assert!(arena.chunks(8).filter(|c| c[0] > 0).count() <= 4);
    }

    #[test]
    fn for_each_chunk_arena_sequential_path_sees_whole_arena() {
        let pool = Pool::new(1); // no workers: inline execution
        let mut arena = vec![0usize; 6];
        pool.for_each_chunk_arena(4, 10, 4, &mut arena, &|scratch, r| {
            assert_eq!(scratch.len(), 6);
            scratch[0] += r.len();
        });
        assert_eq!(arena[0], 10);
    }

    #[test]
    #[should_panic(expected = "a task panicked")]
    fn for_each_chunk_propagates_body_panic() {
        let pool = Pool::new(3);
        pool.for_each_chunk(3, 100, 1, &|r: Range<usize>| {
            if r.start == 50 {
                panic!("chunk boom");
            }
        });
    }

    #[test]
    fn auto_grain_is_positive_and_scales() {
        assert_eq!(auto_grain(0, 4), 1);
        assert_eq!(auto_grain(1, 8), 1);
        assert_eq!(auto_grain(640, 8), 10);
        assert!(auto_grain(1_000_000, 1) >= 1);
        // threads = 0 clamps, never divides by zero
        assert!(auto_grain(100, 0) >= 1);
    }

    #[test]
    fn take_slices_partitions_disjointly() {
        let mut buf = vec![0u8; 10];
        {
            let parts = take_slices(&mut buf, [3usize, 2, 4].into_iter());
            assert_eq!(parts.iter().map(|p| p.len()).collect::<Vec<_>>(), vec![3, 2, 4]);
            for (i, p) in parts.into_iter().enumerate() {
                p.fill(i as u8 + 1);
            }
        }
        assert_eq!(buf, vec![1, 1, 1, 2, 2, 3, 3, 3, 3, 0]);
    }

    #[test]
    fn split_even_covers_and_balances() {
        assert_eq!(split_even(10, 3), vec![0..4, 4..7, 7..10]);
        assert_eq!(split_even(8, 4), vec![0..2, 2..4, 4..6, 6..8]);
        // more parts than items: one range per item
        assert_eq!(split_even(3, 8), vec![0..1, 1..2, 2..3]);
        assert_eq!(split_even(0, 4), Vec::<Range<usize>>::new());
        assert_eq!(split_even(5, 1), vec![0..5]);
    }

    #[test]
    fn global_pool_is_reused() {
        let a = global() as *const Pool;
        let b = global() as *const Pool;
        assert_eq!(a, b);
        assert!(global().threads() >= 1);
    }

    #[test]
    fn default_threads_positive() {
        assert!(default_threads() >= 1);
    }
}
