//! Execution runtimes: the PJRT artifact runtime (S11) plus the
//! [`pool`] persistent worker pool (S14) that the functional CPU hot
//! paths run on.
//!
//! The rest of this file is the PJRT side: it loads the HLO-text
//! artifacts emitted by `python/compile/aot.py` (`make artifacts`),
//! compiles them on the PJRT CPU client, and executes them from the
//! coordinator's request path.
//!
//! Python never runs here — the interchange is HLO **text** (not a
//! serialized HloModuleProto: jax ≥ 0.5 emits 64-bit instruction ids
//! that xla_extension 0.5.1 rejects; the text parser reassigns ids).
//!
//! The manifest (`artifacts/manifest.json`) drives everything: input
//! names/shapes/dtypes per artifact, so the coordinator can bind packed
//! weights, activations and build paths positionally.

pub mod pool;

use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Tensor spec from the manifest.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    I32,
    F32,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One artifact's manifest entry.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: PathBuf,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    pub meta: BTreeMap<String, f64>,
}

/// Parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub artifacts: Vec<ArtifactSpec>,
    pub c_ternary: usize,
    pub c_binary: usize,
}

fn parse_tensor(j: &Json) -> Result<TensorSpec> {
    let name = j.get("name").and_then(Json::as_str).unwrap_or("out").to_string();
    let shape = j
        .req("shape")?
        .as_arr()
        .ok_or_else(|| anyhow!("shape must be array"))?
        .iter()
        .map(|v| v.as_usize().ok_or_else(|| anyhow!("bad dim")))
        .collect::<Result<Vec<_>>>()?;
    let dtype = match j.req("dtype")?.as_str() {
        Some("i32") => DType::I32,
        Some("f32") => DType::F32,
        other => bail!("unsupported dtype {other:?}"),
    };
    Ok(TensorSpec { name, shape, dtype })
}

impl Manifest {
    /// Load `artifacts/manifest.json` (dir = artifacts root).
    pub fn load(dir: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("reading manifest in {}", dir.display()))?;
        let j = Json::parse(&text)?;
        let mut artifacts = Vec::new();
        for a in j.req("artifacts")?.as_arr().ok_or_else(|| anyhow!("artifacts not array"))? {
            let name = a.req("name")?.as_str().unwrap_or_default().to_string();
            let file = dir.join(a.req("file")?.as_str().unwrap_or_default());
            let inputs = a
                .req("inputs")?
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .map(parse_tensor)
                .collect::<Result<Vec<_>>>()?;
            let outputs = a
                .req("outputs")?
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .map(parse_tensor)
                .collect::<Result<Vec<_>>>()?;
            let mut meta = BTreeMap::new();
            if let Some(Json::Obj(m)) = a.get("meta") {
                for (k, v) in m {
                    if let Some(f) = v.as_f64() {
                        meta.insert(k.clone(), f);
                    }
                }
            }
            artifacts.push(ArtifactSpec { name, file, inputs, outputs, meta });
        }
        // Chunk sizes are part of the lowered artifacts' ABI: a manifest
        // that omits them is from a stale toolchain, and silently
        // assuming the defaults makes shape mismatches undiagnosable.
        // Warn loudly (keep loading: the defaults match every artifact
        // generation the repo has ever shipped).
        let chunk_key = |key: &str, default: usize| -> usize {
            match j.get(key).and_then(Json::as_usize) {
                Some(c) => c,
                None => {
                    eprintln!(
                        "warning: {} omits {key:?}; assuming default {default} — \
                         regenerate artifacts (`make artifacts`) if results look wrong",
                        dir.join("manifest.json").display()
                    );
                    default
                }
            }
        };
        Ok(Manifest {
            artifacts,
            c_ternary: chunk_key("c_ternary", 5),
            c_binary: chunk_key("c_binary", 7),
        })
    }

    pub fn find(&self, name: &str) -> Option<&ArtifactSpec> {
        self.artifacts.iter().find(|a| a.name == name)
    }

    /// Find the first artifact whose name starts with `prefix`.
    pub fn find_prefix(&self, prefix: &str) -> Option<&ArtifactSpec> {
        self.artifacts.iter().find(|a| a.name.starts_with(prefix))
    }
}

/// Host-side tensor value bound to an artifact input.
#[derive(Debug, Clone)]
pub enum HostTensor {
    I32(Vec<i32>),
    F32(Vec<f32>),
}

impl HostTensor {
    pub fn len(&self) -> usize {
        match self {
            HostTensor::I32(v) => v.len(),
            HostTensor::F32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> Option<&[f32]> {
        match self {
            HostTensor::F32(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_i32(&self) -> Option<&[i32]> {
        match self {
            HostTensor::I32(v) => Some(v),
            _ => None,
        }
    }
}

/// A compiled artifact ready to execute on the PJRT CPU client.
pub struct LoadedArtifact {
    pub spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
}

/// The PJRT runtime: one CPU client, artifacts compiled once and cached.
pub struct Runtime {
    client: xla::PjRtClient,
    manifest: Manifest,
    loaded: BTreeMap<String, LoadedArtifact>,
}

impl Runtime {
    /// Create a CPU PJRT client and parse the manifest (lazy compile).
    pub fn new(artifacts_dir: &Path) -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let manifest = Manifest::load(artifacts_dir)?;
        Ok(Runtime { client, manifest, loaded: BTreeMap::new() })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (once) and return the artifact by name.
    pub fn load(&mut self, name: &str) -> Result<&LoadedArtifact> {
        if !self.loaded.contains_key(name) {
            let spec = self
                .manifest
                .find(name)
                .ok_or_else(|| anyhow!("artifact {name:?} not in manifest"))?
                .clone();
            let proto = xla::HloModuleProto::from_text_file(
                spec.file
                    .to_str()
                    .ok_or_else(|| anyhow!("non-utf8 artifact path"))?,
            )
            .with_context(|| format!("parsing HLO text {}", spec.file.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp).context("PJRT compile")?;
            self.loaded.insert(name.to_string(), LoadedArtifact { spec, exe });
        }
        Ok(&self.loaded[name])
    }

    /// Execute an artifact with positional inputs; returns the first
    /// output as a host tensor (artifacts are lowered with
    /// `return_tuple=True`, so the result is a 1-tuple).
    pub fn execute(&mut self, name: &str, inputs: &[HostTensor]) -> Result<HostTensor> {
        let art = self.load(name)?;
        if inputs.len() != art.spec.inputs.len() {
            bail!(
                "artifact {name}: expected {} inputs, got {}",
                art.spec.inputs.len(),
                inputs.len()
            );
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (h, spec) in inputs.iter().zip(&art.spec.inputs) {
            if h.len() != spec.elements() {
                bail!(
                    "input {:?}: expected {} elements ({:?}), got {}",
                    spec.name,
                    spec.elements(),
                    spec.shape,
                    h.len()
                );
            }
            let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
            let lit = match (h, spec.dtype) {
                (HostTensor::I32(v), DType::I32) => {
                    xla::Literal::vec1(v).reshape(&dims).context("reshape i32 input")?
                }
                (HostTensor::F32(v), DType::F32) => {
                    xla::Literal::vec1(v).reshape(&dims).context("reshape f32 input")?
                }
                _ => bail!("input {:?}: dtype mismatch", spec.name),
            };
            literals.push(lit);
        }
        let result = art.exe.execute::<xla::Literal>(&literals)?[0][0]
            .to_literal_sync()
            .context("device→host transfer")?;
        let out = result.to_tuple1().context("unwrapping 1-tuple output")?;
        let spec = &art.spec.outputs[0];
        Ok(match spec.dtype {
            DType::I32 => HostTensor::I32(out.to_vec::<i32>()?),
            DType::F32 => HostTensor::F32(out.to_vec::<f32>()?),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parses_shapes() {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        assert!(m.artifacts.len() >= 4);
        assert_eq!(m.c_ternary, 5);
        let lut = m.find_prefix("lut_gemm").expect("lut_gemm artifact");
        assert_eq!(lut.inputs.len(), 3);
        assert_eq!(lut.inputs[0].dtype, DType::I32);
        assert!(lut.meta.contains_key("m"));
    }

    #[test]
    fn tensor_spec_elements() {
        let t = TensorSpec { name: "x".into(), shape: vec![3, 4, 5], dtype: DType::F32 };
        assert_eq!(t.elements(), 60);
    }

    #[test]
    fn manifest_missing_chunk_keys_warns_and_defaults() {
        // stale-toolchain manifest without c_ternary/c_binary: loading
        // must still succeed (with a stderr warning) on the defaults
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("target")
            .join("tmp-manifest-missing-chunks");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), r#"{"artifacts": []}"#).unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!((m.c_ternary, m.c_binary), (5, 7));
        assert!(m.artifacts.is_empty());
    }
}
