//! The fault-plan grammar: a compact, seed-friendly description of what
//! goes wrong during a serving run.
//!
//! A plan is a comma-separated list of fault specs:
//!
//! ```text
//! straggler:r1:p0.05:x8     replica 1 runs ×8 slower on 5% of steps
//! linkdeg:0.2:4gbps         20% of steps re-ship their activations at 4 GB/s
//! swapfail:p0.01            each KV swap transfer fails with probability 0.01
//! crash:r2@t=1.5s           replica 2 crashes permanently at t = 1.5 s
//! ```
//!
//! Probabilistic specs draw from a dedicated seeded stream (see
//! [`crate::fault::FaultInjector`]); `crash` fires at a fixed virtual
//! time.  [`FaultPlan::label`] re-serializes the canonical form so a
//! plan can be echoed into the config section of the metrics JSON.

use anyhow::{bail, Result};

/// One fault clause from the plan grammar.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultSpec {
    /// `straggler:r<i>:p<f>:x<f>` — replica `i`'s step latency is
    /// multiplied by `slowdown` with per-step probability `p`.
    Straggler { replica: usize, p: f64, slowdown: f64 },
    /// `linkdeg:<p>:<g>gbps` — with per-step probability `p` the
    /// interconnect degrades and the step's activation bytes re-ship at
    /// `gbps` GB/s (priced as a pure stall).
    LinkDegrade { p: f64, gbps: f64 },
    /// `swapfail:p<f>` — each KV swap transfer fails with probability
    /// `p`; the sequence falls back to recompute.
    SwapFail { p: f64 },
    /// `crash:r<i>@t=<f>s` — replica `i` fails permanently at virtual
    /// time `t`; survivors absorb its shard after a priced
    /// weight-redistribution stall.
    Crash { replica: usize, t_s: f64 },
}

fn prob(tok: &str, clause: &str) -> Result<f64> {
    let Some(body) = tok.strip_prefix('p') else {
        bail!("fault clause {clause:?}: expected p<probability>, got {tok:?}")
    };
    match body.parse::<f64>() {
        Ok(p) if p.is_finite() && (0.0..=1.0).contains(&p) => Ok(p),
        _ => bail!("fault clause {clause:?}: probability {body:?} must be in [0, 1]"),
    }
}

fn replica(tok: &str, clause: &str) -> Result<usize> {
    let Some(body) = tok.strip_prefix('r') else {
        bail!("fault clause {clause:?}: expected r<replica-index>, got {tok:?}")
    };
    match body.parse::<usize>() {
        Ok(r) => Ok(r),
        Err(_) => bail!("fault clause {clause:?}: replica index {body:?} is not an integer"),
    }
}

fn positive(body: &str, clause: &str, what: &str) -> Result<f64> {
    match body.parse::<f64>() {
        Ok(v) if v.is_finite() && v > 0.0 => Ok(v),
        _ => bail!("fault clause {clause:?}: {what} {body:?} must be a finite number > 0"),
    }
}

/// A parsed, validated fault plan (possibly empty).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultPlan {
    pub specs: Vec<FaultSpec>,
}

impl FaultPlan {
    /// Parse the compact grammar; an empty/whitespace string is the
    /// empty plan (no faults — byte-identical to a plain run).
    pub fn parse(text: &str) -> Result<FaultPlan> {
        let mut specs = Vec::new();
        for clause in text.split(',') {
            let clause = clause.trim();
            if clause.is_empty() {
                continue;
            }
            let mut parts = clause.splitn(2, ':');
            let kind = parts.next().unwrap_or_default();
            let rest = parts.next().unwrap_or_default();
            let spec = match kind {
                "straggler" => {
                    let toks: Vec<&str> = rest.split(':').collect();
                    if toks.len() != 3 {
                        bail!("fault clause {clause:?}: expected straggler:r<i>:p<f>:x<f>");
                    }
                    let slowdown = match toks[2].strip_prefix('x') {
                        Some(body) => positive(body, clause, "slowdown")?,
                        None => bail!("fault clause {clause:?}: expected x<slowdown>"),
                    };
                    if slowdown < 1.0 {
                        bail!("fault clause {clause:?}: slowdown must be >= 1");
                    }
                    FaultSpec::Straggler {
                        replica: replica(toks[0], clause)?,
                        p: prob(toks[1], clause)?,
                        slowdown,
                    }
                }
                "linkdeg" => {
                    let toks: Vec<&str> = rest.split(':').collect();
                    if toks.len() != 2 {
                        bail!("fault clause {clause:?}: expected linkdeg:<p>:<gbps>gbps");
                    }
                    let p = positive(toks[0], clause, "probability")?;
                    if p > 1.0 {
                        bail!("fault clause {clause:?}: probability must be in (0, 1]");
                    }
                    let gbps = match toks[1].strip_suffix("gbps") {
                        Some(body) => positive(body, clause, "bandwidth")?,
                        None => bail!("fault clause {clause:?}: bandwidth needs a gbps suffix"),
                    };
                    FaultSpec::LinkDegrade { p, gbps }
                }
                "swapfail" => FaultSpec::SwapFail { p: prob(rest, clause)? },
                "crash" => {
                    let mut at = rest.splitn(2, "@t=");
                    let r = at.next().unwrap_or_default();
                    let Some(t_tok) = at.next() else {
                        bail!("fault clause {clause:?}: expected crash:r<i>@t=<f>s")
                    };
                    let t_s = match t_tok.strip_suffix('s') {
                        Some(body) => positive(body, clause, "crash time")?,
                        None => bail!("fault clause {clause:?}: crash time needs an s suffix"),
                    };
                    FaultSpec::Crash { replica: replica(r, clause)?, t_s }
                }
                other => bail!(
                    "unknown fault kind {other:?} in clause {clause:?} \
                     (expected straggler | linkdeg | swapfail | crash)"
                ),
            };
            specs.push(spec);
        }
        Ok(FaultPlan { specs })
    }

    pub fn is_empty(&self) -> bool {
        self.specs.is_empty()
    }

    /// Canonical re-serialization (round-trips through [`parse`]).
    ///
    /// [`parse`]: FaultPlan::parse
    pub fn label(&self) -> String {
        let clauses: Vec<String> = self
            .specs
            .iter()
            .map(|s| match s {
                FaultSpec::Straggler { replica, p, slowdown } => {
                    format!("straggler:r{replica}:p{p}:x{slowdown}")
                }
                FaultSpec::LinkDegrade { p, gbps } => format!("linkdeg:{p}:{gbps}gbps"),
                FaultSpec::SwapFail { p } => format!("swapfail:p{p}"),
                FaultSpec::Crash { replica, t_s } => format!("crash:r{replica}@t={t_s}s"),
            })
            .collect();
        clauses.join(",")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_the_issue_example_plan() {
        let plan =
            FaultPlan::parse("straggler:r1:p0.05:x8,linkdeg:0.2:4gbps,swapfail:p0.01,crash:r2@t=1.5s")
                .unwrap();
        assert_eq!(
            plan.specs,
            vec![
                FaultSpec::Straggler { replica: 1, p: 0.05, slowdown: 8.0 },
                FaultSpec::LinkDegrade { p: 0.2, gbps: 4.0 },
                FaultSpec::SwapFail { p: 0.01 },
                FaultSpec::Crash { replica: 2, t_s: 1.5 },
            ]
        );
    }

    #[test]
    fn label_round_trips() {
        let text = "straggler:r0:p0.5:x2,linkdeg:0.25:8gbps,swapfail:p0.1,crash:r3@t=2s";
        let plan = FaultPlan::parse(text).unwrap();
        assert_eq!(FaultPlan::parse(&plan.label()).unwrap(), plan);
    }

    #[test]
    fn empty_and_whitespace_plans_are_empty() {
        assert!(FaultPlan::parse("").unwrap().is_empty());
        assert!(FaultPlan::parse(" , ,").unwrap().is_empty());
        assert_eq!(FaultPlan::default().label(), "");
    }

    #[test]
    fn malformed_clauses_are_loud() {
        for bad in [
            "straggler:r1:p0.05",      // missing slowdown
            "straggler:r1:p2:x8",      // probability out of range
            "straggler:r1:p0.1:x0.5",  // speedup is not a straggler
            "linkdeg:0.2:4",           // missing gbps suffix
            "linkdeg:1.5:4gbps",       // probability > 1
            "swapfail:0.01",           // missing p prefix
            "crash:r2@t=1.5",          // missing s suffix
            "crash:r2:t=1.5s",         // wrong separator
            "meteor:p1",               // unknown kind
        ] {
            let err = FaultPlan::parse(bad);
            assert!(err.is_err(), "{bad:?} must fail to parse");
        }
    }
}
