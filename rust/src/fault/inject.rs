//! The deterministic fault injector: turns a parsed [`FaultPlan`] into
//! per-step fault outcomes drawn from a dedicated seeded stream.
//!
//! Determinism contract: the injector is consulted only from the
//! scheduler's single-threaded serve loop, in a fixed order (one
//! [`FaultInjector::begin_step`] per step, one
//! [`FaultInjector::swap_fails`] per swap transfer), and its RNG stream
//! is derived from the run seed alone.  Worker-pool size, wall-clock
//! jitter and backend internals can never perturb a draw, so one seed +
//! one plan ⇒ the same faults at the same virtual times, every run.

use super::plan::{FaultPlan, FaultSpec};
use super::ResilienceStats;
use crate::util::rng::Rng;

/// Domain-separation constant for the fault RNG stream: the injector
/// must not share draws with the load generator or executor bridges.
const FAULT_STREAM_SALT: u64 = 0xFA17_1A7E_0D00_C0DE;

/// The faults that fire on one scheduler step.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct StepFaults {
    /// Multiplier (≥ 1.0) applied to the step's compute latency —
    /// max over the straggler clauses that hit live replicas.
    pub slowdown: f64,
    /// Extra stall seconds from transient link degradation.
    pub link_penalty_s: f64,
    /// Replica indices whose crash clause fired this step (at most once
    /// per replica per run).
    pub crashes: Vec<usize>,
}

/// Seeded, deterministic fault source for one serving run.
pub struct FaultInjector {
    specs: Vec<FaultSpec>,
    rng: Rng,
    alive: Vec<bool>,
    fired: Vec<bool>, // per-spec: crash clauses fire at most once
}

impl FaultInjector {
    /// Build an injector for a run with `replicas` backend replicas.
    /// Crash/straggler clauses naming a replica index outside
    /// `0..replicas` are kept but can never fire (documented no-ops, so
    /// one plan string works across backend shapes).
    pub fn new(plan: &FaultPlan, seed: u64, replicas: usize) -> FaultInjector {
        FaultInjector {
            specs: plan.specs.clone(),
            rng: Rng::seed_from(seed ^ FAULT_STREAM_SALT),
            alive: vec![true; replicas.max(1)],
            fired: vec![false; plan.specs.len()],
        }
    }

    /// Replica liveness map (true = still serving).
    pub fn alive(&self) -> &[bool] {
        &self.alive
    }

    /// Replicas still alive.
    pub fn survivors(&self) -> usize {
        self.alive.iter().filter(|a| **a).count()
    }

    /// Whether any replica has crashed so far.
    pub fn degraded(&self) -> bool {
        self.alive.iter().any(|a| !*a)
    }

    /// Draw this step's faults.  `now_s` is the virtual time at the
    /// step's start, `step_bytes` the activation bytes the step moves
    /// over the interconnect (prices link degradation as a stall).
    /// Probabilistic clauses are drawn in plan order so the stream is
    /// reproducible; crash clauses fire once when `now_s` passes their
    /// deadline and the target replica is in range and alive.
    pub fn begin_step(
        &mut self,
        now_s: f64,
        step_bytes: f64,
        stats: &mut ResilienceStats,
    ) -> StepFaults {
        let mut out = StepFaults { slowdown: 1.0, ..StepFaults::default() };
        for (i, spec) in self.specs.iter().enumerate() {
            match *spec {
                FaultSpec::Straggler { replica, p, slowdown } => {
                    // Draw unconditionally so liveness changes never
                    // shift the stream for later clauses.
                    let hit = self.rng.f64() < p;
                    if hit && replica < self.alive.len() && self.alive[replica] {
                        out.slowdown = out.slowdown.max(slowdown);
                        stats.straggler_hits += 1;
                    }
                }
                FaultSpec::LinkDegrade { p, gbps } => {
                    let hit = self.rng.f64() < p;
                    if hit {
                        out.link_penalty_s += step_bytes / (gbps * 1e9);
                        stats.linkdeg_hits += 1;
                    }
                }
                FaultSpec::SwapFail { .. } => {} // drawn per swap transfer
                FaultSpec::Crash { replica, t_s } => {
                    if !self.fired[i]
                        && now_s >= t_s
                        && replica < self.alive.len()
                        && self.alive[replica]
                        && self.survivors() > 1
                    {
                        self.fired[i] = true;
                        self.alive[replica] = false;
                        out.crashes.push(replica);
                        stats.crashed_replicas += 1;
                    }
                }
            }
        }
        out
    }

    /// Draw whether one KV swap transfer fails (max over the plan's
    /// `swapfail` clauses; every clause draws so the stream is stable).
    pub fn swap_fails(&mut self, stats: &mut ResilienceStats) -> bool {
        let mut failed = false;
        for spec in &self.specs {
            if let FaultSpec::SwapFail { p } = *spec {
                if self.rng.f64() < p {
                    failed = true;
                }
            }
        }
        if failed {
            stats.swap_failures += 1;
        }
        failed
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stats() -> ResilienceStats {
        ResilienceStats::default()
    }

    #[test]
    fn same_seed_same_fault_sequence() {
        let plan = FaultPlan::parse("straggler:r0:p0.3:x4,linkdeg:0.3:2gbps,swapfail:p0.5").unwrap();
        let run = |seed| {
            let mut inj = FaultInjector::new(&plan, seed, 2);
            let mut st = stats();
            let mut trace = Vec::new();
            for step in 0..64 {
                let f = inj.begin_step(step as f64 * 0.01, 1e6, &mut st);
                trace.push((f.slowdown, f.link_penalty_s, inj.swap_fails(&mut st)));
            }
            (trace, st.straggler_hits, st.linkdeg_hits, st.swap_failures)
        };
        assert_eq!(run(7), run(7));
        assert_ne!(run(7).0, run(8).0, "different seeds draw different faults");
    }

    #[test]
    fn crash_fires_once_at_its_deadline_and_spares_the_last_replica() {
        let plan = FaultPlan::parse("crash:r1@t=1.5s,crash:r0@t=2.0s").unwrap();
        let mut inj = FaultInjector::new(&plan, 0, 2);
        let mut st = stats();
        assert!(inj.begin_step(1.0, 0.0, &mut st).crashes.is_empty());
        assert_eq!(inj.begin_step(1.6, 0.0, &mut st).crashes, vec![1]);
        assert!(!inj.alive()[1]);
        assert_eq!(inj.survivors(), 1);
        // the r0 clause can never fire: it would kill the last replica
        assert!(inj.begin_step(5.0, 0.0, &mut st).crashes.is_empty());
        assert_eq!(st.crashed_replicas, 1);
    }

    #[test]
    fn out_of_range_replicas_are_noops() {
        let plan = FaultPlan::parse("straggler:r9:p1:x8,crash:r9@t=0.1s").unwrap();
        let mut inj = FaultInjector::new(&plan, 3, 2);
        let mut st = stats();
        let f = inj.begin_step(1.0, 0.0, &mut st);
        assert_eq!(f.slowdown, 1.0);
        assert!(f.crashes.is_empty());
        assert_eq!(st.straggler_hits + st.crashed_replicas, 0);
    }

    #[test]
    fn link_degradation_prices_bytes_at_the_degraded_rate() {
        let plan = FaultPlan::parse("linkdeg:1:4gbps").unwrap();
        let mut inj = FaultInjector::new(&plan, 0, 1);
        let mut st = stats();
        let f = inj.begin_step(0.0, 8e9, &mut st);
        assert!((f.link_penalty_s - 2.0).abs() < 1e-12, "{}", f.link_penalty_s);
        assert_eq!(st.linkdeg_hits, 1);
    }
}
