//! Deterministic fault injection + SLO-grade resilience (S17).
//!
//! Every layer below this one assumed nothing ever fails: the traffic
//! scheduler never missed a deadline, `Sharded` replicas never crashed,
//! KV swaps never bounced.  End-to-end serving latency claims are only
//! earned under degraded conditions, and the repo's seeded-determinism
//! contract makes chaos testing *reproducible*: one seed + one fault
//! plan ⇒ byte-identical metrics JSON on the virtual clock, invariant
//! across worker-pool sizes (pinned in `tests/traffic_serving.rs`).
//!
//! Three pieces:
//!
//! * [`FaultPlan`] — the compact grammar
//!   (`straggler:r1:p0.05:x8,linkdeg:0.2:4gbps,swapfail:p0.01,crash:r2@t=1.5s`)
//!   parsed into validated clauses.
//! * [`FaultInjector`] — draws each clause's outcomes from a dedicated
//!   RNG stream derived from the run seed, consulted only at fixed
//!   points in the single-threaded serve loop.
//! * [`ResilienceConfig`] / [`ResilienceStats`] — the scheduler's
//!   responses (per-request deadlines with timeout-kill + KV
//!   reclamation, capped-exponential-backoff retry re-entering the
//!   arrival timeline deterministically, brownout load-shedding by
//!   deadline slack, `Sharded` failover with priced weight
//!   redistribution) and the `resilience` metrics section they emit.
//!
//! The section is *strictly additive*: with an empty plan and default
//! [`ResilienceConfig`] the scheduler takes the exact PR 6 code paths
//! and serializes byte-identical metrics.

mod inject;
mod plan;

pub use inject::{FaultInjector, StepFaults};
pub use plan::{FaultPlan, FaultSpec};

use crate::util::json::{num, obj, Json};

/// Resilience knobs for the serving scheduler.  The default (no
/// deadline, no retries, no brownout) disables every resilience code
/// path; combined with an empty [`FaultPlan`] the scheduler behaves —
/// and serializes — exactly as it did before this subsystem existed.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ResilienceConfig {
    /// Per-request end-to-end deadline (seconds from arrival).  A
    /// request past its deadline is timeout-killed wherever it sits
    /// (queue or batch) and its KV blocks are reclaimed.
    pub deadline_s: Option<f64>,
    /// Retry budget for rejected / timed-out / failed requests
    /// (0 = never retry).
    pub max_retries: u32,
    /// Capped exponential backoff: attempt `k` re-arrives after
    /// `min(retry_cap_s, retry_base_s * 2^(k-1))`.
    pub retry_base_s: f64,
    pub retry_cap_s: f64,
    /// Brownout trigger: queue depth at or above this sheds queued
    /// requests whose deadline slack is below `brownout_slack_s`
    /// (0 = brownout disabled).
    pub brownout_queue: usize,
    /// Minimum deadline slack (seconds) a queued request needs to
    /// survive admission while browned out.
    pub brownout_slack_s: f64,
    /// Run seed the injector's dedicated RNG stream is derived from
    /// (pass the load generator's seed for end-to-end reproducibility).
    pub fault_seed: u64,
}

impl Default for ResilienceConfig {
    fn default() -> ResilienceConfig {
        ResilienceConfig {
            deadline_s: None,
            max_retries: 0,
            retry_base_s: 0.05,
            retry_cap_s: 1.0,
            brownout_queue: 0,
            brownout_slack_s: 0.0,
            fault_seed: 0,
        }
    }
}

impl ResilienceConfig {
    /// Whether any resilience mechanism is switched on.  Together with
    /// a non-empty fault plan this decides if the `resilience` metrics
    /// section is emitted (byte-identity with pre-fault runs otherwise).
    pub fn active(&self) -> bool {
        self.deadline_s.is_some() || self.max_retries > 0 || self.brownout_queue > 0
    }

    /// Brownout slack threshold for one SLO class.  The scheduler
    /// evaluates brownout per class queue (a saturated batch tenant
    /// browns out alone instead of shedding every class); this is the
    /// per-class hook it consults.  All classes currently share the
    /// global `brownout_slack_s` — the signature keeps the evaluation
    /// point in one place so per-class slack overrides slot in without
    /// touching the scheduler.
    pub fn brownout_slack_for(&self, _class: usize) -> f64 {
        self.brownout_slack_s
    }
}

/// Counters and gauges for the `resilience` metrics section.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ResilienceStats {
    // resilience responses
    pub timeouts: u64,
    pub retries: u64,
    pub retry_exhausted: u64,
    pub shed: u64,
    pub failovers: u64,
    pub step_failures: u64,
    // injected faults
    pub straggler_hits: u64,
    pub linkdeg_hits: u64,
    pub swap_failures: u64,
    pub crashed_replicas: u64,
    // injected latency
    pub fault_extra_s: f64,
    pub redistribution_s: f64,
    /// completed / offered, set by the scheduler at drain.
    pub availability: f64,
    /// p99 deltas vs. a fault-free run of the same spec (set by
    /// `serve-bench` when it runs the baseline; `None` → JSON null).
    pub p99_ttft_delta_s: Option<f64>,
    pub p99_e2e_delta_s: Option<f64>,
}

impl ResilienceStats {
    /// The `resilience` section of the metrics JSON.
    pub fn to_json(&self) -> Json {
        let opt = |v: Option<f64>| v.map(num).unwrap_or(Json::Null);
        obj(vec![
            ("availability", num(self.availability)),
            (
                "counts",
                obj(vec![
                    ("timeouts", num(self.timeouts as f64)),
                    ("retries", num(self.retries as f64)),
                    ("retry_exhausted", num(self.retry_exhausted as f64)),
                    ("shed", num(self.shed as f64)),
                    ("failovers", num(self.failovers as f64)),
                    ("step_failures", num(self.step_failures as f64)),
                ]),
            ),
            (
                "faults",
                obj(vec![
                    ("straggler_hits", num(self.straggler_hits as f64)),
                    ("linkdeg_hits", num(self.linkdeg_hits as f64)),
                    ("swap_failures", num(self.swap_failures as f64)),
                    ("crashed_replicas", num(self.crashed_replicas as f64)),
                    ("extra_s", num(self.fault_extra_s)),
                    ("redistribution_s", num(self.redistribution_s)),
                ]),
            ),
            (
                "p99_delta_s",
                obj(vec![
                    ("ttft", opt(self.p99_ttft_delta_s)),
                    ("e2e", opt(self.p99_e2e_delta_s)),
                ]),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_config_is_inactive() {
        let cfg = ResilienceConfig::default();
        assert!(!cfg.active());
        assert!(ResilienceConfig { deadline_s: Some(0.5), ..cfg }.active());
        assert!(ResilienceConfig { max_retries: 3, ..cfg }.active());
        assert!(ResilienceConfig { brownout_queue: 64, ..cfg }.active());
    }

    #[test]
    fn brownout_slack_is_uniform_across_classes() {
        let cfg = ResilienceConfig {
            brownout_queue: 8,
            brownout_slack_s: 0.25,
            ..ResilienceConfig::default()
        };
        for class in 0..8 {
            assert_eq!(cfg.brownout_slack_for(class), 0.25);
        }
    }

    #[test]
    fn stats_json_round_trips() {
        let st = ResilienceStats {
            timeouts: 3,
            retries: 7,
            availability: 0.96875,
            p99_ttft_delta_s: Some(0.012),
            ..ResilienceStats::default()
        };
        let j = st.to_json();
        assert_eq!(j.get("availability").unwrap().as_f64(), Some(0.96875));
        assert_eq!(j.get("counts").unwrap().get("retries").unwrap().as_f64(), Some(7.0));
        assert_eq!(j.get("p99_delta_s").unwrap().get("e2e"), Some(&Json::Null));
        let text = j.to_string();
        assert_eq!(Json::parse(&text).unwrap().to_string(), text);
    }
}
