//! Deterministic fault injection + SLO-grade resilience (S17).
//!
//! Every layer below this one assumed nothing ever fails: the traffic
//! scheduler never missed a deadline, `Sharded` replicas never crashed,
//! KV swaps never bounced.  End-to-end serving latency claims are only
//! earned under degraded conditions, and the repo's seeded-determinism
//! contract makes chaos testing *reproducible*: one seed + one fault
//! plan ⇒ byte-identical metrics JSON on the virtual clock, invariant
//! across worker-pool sizes (pinned in `tests/traffic_serving.rs`).
//!
//! Three pieces:
//!
//! * [`FaultPlan`] — the compact grammar
//!   (`straggler:r1:p0.05:x8,linkdeg:0.2:4gbps,swapfail:p0.01,crash:r2@t=1.5s`)
//!   parsed into validated clauses.
//! * [`FaultInjector`] — draws each clause's outcomes from a dedicated
//!   RNG stream derived from the run seed, consulted only at fixed
//!   points in the single-threaded serve loop.
//! * [`ResilienceConfig`] / [`ResilienceStats`] — the scheduler's
//!   responses (per-request deadlines with timeout-kill + KV
//!   reclamation, capped-exponential-backoff retry re-entering the
//!   arrival timeline deterministically, brownout load-shedding by
//!   deadline slack, `Sharded` failover with priced weight
//!   redistribution) and the `resilience` metrics section they emit.
//!
//! The section is *strictly additive*: with an empty plan and default
//! [`ResilienceConfig`] the scheduler takes the exact PR 6 code paths
//! and serializes byte-identical metrics.

mod inject;
mod plan;

pub use inject::{FaultInjector, StepFaults};
pub use plan::{FaultPlan, FaultSpec};

use crate::traffic::loadgen::MAX_CLASSES;
use crate::util::json::{num, obj, Json};
use anyhow::{bail, Result};

/// Resilience knobs for the serving scheduler.  The default (no
/// deadline, no retries, no brownout) disables every resilience code
/// path; combined with an empty [`FaultPlan`] the scheduler behaves —
/// and serializes — exactly as it did before this subsystem existed.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ResilienceConfig {
    /// Per-request end-to-end deadline (seconds from arrival).  A
    /// request past its deadline is timeout-killed wherever it sits
    /// (queue or batch) and its KV blocks are reclaimed.
    pub deadline_s: Option<f64>,
    /// Retry budget for rejected / timed-out / failed requests
    /// (0 = never retry).
    pub max_retries: u32,
    /// Capped exponential backoff: attempt `k` re-arrives after
    /// `min(retry_cap_s, retry_base_s * 2^(k-1))`.
    pub retry_base_s: f64,
    pub retry_cap_s: f64,
    /// Brownout trigger: queue depth at or above this sheds queued
    /// requests whose deadline slack is below `brownout_slack_s`
    /// (0 = brownout disabled).
    pub brownout_queue: usize,
    /// Minimum deadline slack (seconds) a queued request needs to
    /// survive admission while browned out.
    pub brownout_slack_s: f64,
    /// Per-SLO-class overrides of `brownout_slack_s` (`None` → the
    /// global value).  A class with *looser* slack (larger threshold)
    /// sheds earlier under brownout — the knob that lets a batch tier
    /// absorb the shedding while interactive traffic rides through.
    pub brownout_slack_class: [Option<f64>; MAX_CLASSES],
    /// Run seed the injector's dedicated RNG stream is derived from
    /// (pass the load generator's seed for end-to-end reproducibility).
    pub fault_seed: u64,
}

impl Default for ResilienceConfig {
    fn default() -> ResilienceConfig {
        ResilienceConfig {
            deadline_s: None,
            max_retries: 0,
            retry_base_s: 0.05,
            retry_cap_s: 1.0,
            brownout_queue: 0,
            brownout_slack_s: 0.0,
            brownout_slack_class: [None; MAX_CLASSES],
            fault_seed: 0,
        }
    }
}

impl ResilienceConfig {
    /// Whether any resilience mechanism is switched on.  Together with
    /// a non-empty fault plan this decides if the `resilience` metrics
    /// section is emitted (byte-identity with pre-fault runs otherwise).
    pub fn active(&self) -> bool {
        self.deadline_s.is_some() || self.max_retries > 0 || self.brownout_queue > 0
    }

    /// Brownout slack threshold for one SLO class.  The scheduler
    /// evaluates brownout per class queue (a saturated batch tenant
    /// browns out alone instead of shedding every class); this is the
    /// per-class hook it consults: the class override when one was
    /// configured (`--brownout-slack-ms interactive:50,batch:500`),
    /// the global `brownout_slack_s` otherwise.
    pub fn brownout_slack_for(&self, class: usize) -> f64 {
        self.brownout_slack_class[class.min(MAX_CLASSES - 1)].unwrap_or(self.brownout_slack_s)
    }

    /// Parse the `--brownout-slack-ms` grammar into this config: either
    /// one global number (`"50"`), or a per-class list
    /// (`"interactive:50,batch:500"`) whose names resolve through
    /// `class_id` (the tenant mix's lookup; bare indices
    /// `0..MAX_CLASSES` always resolve).  Errors are loud and name the
    /// offending token — an unknown class never falls back silently.
    pub fn set_brownout_slack_spec(
        &mut self,
        spec: &str,
        class_id: &dyn Fn(&str) -> Option<usize>,
    ) -> Result<()> {
        let parse_ms = |tok: &str| -> Result<f64> {
            match tok.trim().parse::<f64>() {
                Ok(ms) if ms.is_finite() && ms >= 0.0 => Ok(ms),
                _ => bail!("--brownout-slack-ms expects a non-negative number, got {tok:?}"),
            }
        };
        let spec = spec.trim();
        if !spec.contains(':') {
            self.brownout_slack_s = parse_ms(spec)? * 1e-3;
            return Ok(());
        }
        for part in spec.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            let Some((name, ms)) = part.split_once(':') else {
                bail!(
                    "--brownout-slack-ms per-class entries look like class:ms \
                     (e.g. interactive:50,batch:500), got {part:?}"
                );
            };
            let name = name.trim();
            let idx = match name.parse::<usize>() {
                Ok(i) if i < MAX_CLASSES => i,
                _ => class_id(name).ok_or_else(|| {
                    anyhow::anyhow!(
                        "--brownout-slack-ms names unknown class {name:?}; declare it in \
                         --tenants or use a class index 0..{MAX_CLASSES}"
                    )
                })?,
            };
            if self.brownout_slack_class[idx].is_some() {
                bail!("--brownout-slack-ms sets class {name:?} twice");
            }
            self.brownout_slack_class[idx] = Some(parse_ms(ms)? * 1e-3);
        }
        Ok(())
    }
}

/// Counters and gauges for the `resilience` metrics section.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ResilienceStats {
    // resilience responses
    pub timeouts: u64,
    pub retries: u64,
    pub retry_exhausted: u64,
    pub shed: u64,
    pub failovers: u64,
    pub step_failures: u64,
    // injected faults
    pub straggler_hits: u64,
    pub linkdeg_hits: u64,
    pub swap_failures: u64,
    pub crashed_replicas: u64,
    // injected latency
    pub fault_extra_s: f64,
    pub redistribution_s: f64,
    /// completed / offered, set by the scheduler at drain.
    pub availability: f64,
    /// p99 deltas vs. a fault-free run of the same spec (set by
    /// `serve-bench` when it runs the baseline; `None` → JSON null).
    pub p99_ttft_delta_s: Option<f64>,
    pub p99_e2e_delta_s: Option<f64>,
}

impl ResilienceStats {
    /// The `resilience` section of the metrics JSON.
    pub fn to_json(&self) -> Json {
        let opt = |v: Option<f64>| v.map(num).unwrap_or(Json::Null);
        obj(vec![
            ("availability", num(self.availability)),
            (
                "counts",
                obj(vec![
                    ("timeouts", num(self.timeouts as f64)),
                    ("retries", num(self.retries as f64)),
                    ("retry_exhausted", num(self.retry_exhausted as f64)),
                    ("shed", num(self.shed as f64)),
                    ("failovers", num(self.failovers as f64)),
                    ("step_failures", num(self.step_failures as f64)),
                ]),
            ),
            (
                "faults",
                obj(vec![
                    ("straggler_hits", num(self.straggler_hits as f64)),
                    ("linkdeg_hits", num(self.linkdeg_hits as f64)),
                    ("swap_failures", num(self.swap_failures as f64)),
                    ("crashed_replicas", num(self.crashed_replicas as f64)),
                    ("extra_s", num(self.fault_extra_s)),
                    ("redistribution_s", num(self.redistribution_s)),
                ]),
            ),
            (
                "p99_delta_s",
                obj(vec![
                    ("ttft", opt(self.p99_ttft_delta_s)),
                    ("e2e", opt(self.p99_e2e_delta_s)),
                ]),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_config_is_inactive() {
        let cfg = ResilienceConfig::default();
        assert!(!cfg.active());
        assert!(ResilienceConfig { deadline_s: Some(0.5), ..cfg }.active());
        assert!(ResilienceConfig { max_retries: 3, ..cfg }.active());
        assert!(ResilienceConfig { brownout_queue: 64, ..cfg }.active());
    }

    #[test]
    fn brownout_slack_is_uniform_across_classes() {
        let cfg = ResilienceConfig {
            brownout_queue: 8,
            brownout_slack_s: 0.25,
            ..ResilienceConfig::default()
        };
        for class in 0..8 {
            assert_eq!(cfg.brownout_slack_for(class), 0.25);
        }
    }

    #[test]
    fn per_class_slack_overrides_the_global_value() {
        let mut cfg = ResilienceConfig {
            brownout_queue: 8,
            brownout_slack_s: 0.25,
            ..ResilienceConfig::default()
        };
        cfg.brownout_slack_class[1] = Some(2.0);
        assert_eq!(cfg.brownout_slack_for(0), 0.25);
        assert_eq!(cfg.brownout_slack_for(1), 2.0);
        // Out-of-range classes clamp to the last slot, never panic.
        assert_eq!(cfg.brownout_slack_for(MAX_CLASSES + 7), 0.25);
    }

    #[test]
    fn slack_spec_parses_global_and_per_class_forms() {
        let classes = ["interactive", "batch"];
        let lookup = |name: &str| classes.iter().position(|c| *c == name);
        let mut cfg = ResilienceConfig::default();
        cfg.set_brownout_slack_spec("50", &lookup).unwrap();
        assert_eq!(cfg.brownout_slack_s, 0.05);
        assert_eq!(cfg.brownout_slack_class, [None; MAX_CLASSES]);

        cfg.set_brownout_slack_spec("interactive:50, batch:500", &lookup).unwrap();
        assert_eq!(cfg.brownout_slack_for(0), 0.05);
        assert_eq!(cfg.brownout_slack_for(1), 0.5);
        // Bare indices resolve without the lookup.
        let mut by_index = ResilienceConfig::default();
        by_index.set_brownout_slack_spec("1:125", &lookup).unwrap();
        assert_eq!(by_index.brownout_slack_class[1], Some(0.125));
    }

    #[test]
    fn slack_spec_errors_are_loud() {
        let lookup = |_: &str| None;
        let fail = |spec: &str| {
            let mut cfg = ResilienceConfig::default();
            cfg.set_brownout_slack_spec(spec, &lookup).unwrap_err().to_string()
        };
        let unknown = fail("premium:50");
        assert!(unknown.contains("unknown class \"premium\""), "{unknown}");
        let dup = fail("0:50,0:60");
        assert!(dup.contains("twice"), "{dup}");
        let neg = fail("-5");
        assert!(neg.contains("non-negative"), "{neg}");
        let bad = fail("0:fast");
        assert!(bad.contains("non-negative"), "{bad}");
    }

    #[test]
    fn stats_json_round_trips() {
        let st = ResilienceStats {
            timeouts: 3,
            retries: 7,
            availability: 0.96875,
            p99_ttft_delta_s: Some(0.012),
            ..ResilienceStats::default()
        };
        let j = st.to_json();
        assert_eq!(j.get("availability").unwrap().as_f64(), Some(0.96875));
        assert_eq!(j.get("counts").unwrap().get("retries").unwrap().as_f64(), Some(7.0));
        assert_eq!(j.get("p99_delta_s").unwrap().get("e2e"), Some(&Json::Null));
        let text = j.to_string();
        assert_eq!(Json::parse(&text).unwrap().to_string(), text);
    }
}
