//! On-chip binary formats: the build-path instruction stream the
//! construction pipeline fetches, and the packed weight stream layout.
//!
//! A path entry occupies one 32-bit word in the build-path buffer:
//!
//! ```text
//!  31           24 23           16 15      12 11  9  8   7..1   0
//! ┌───────────────┬───────────────┬──────────┬──────┬────┬──────┐
//! │   dst (8b)    │   src (8b)    │ reserved │ j(3b)│sign│ rsvd │
//! └───────────────┴───────────────┴──────────┴──────┴────┴──────┘
//! ```
//!
//! The stream terminates with the `FINISH` token (all ones), which the
//! controller recognizes in the fetch stage (Algorithm 2's sentinel).
//! This module also cross-loads the JSON paths emitted by the python
//! toolchain (`artifacts/paths/*.json`) so the two generators can be
//! verified against each other.

use crate::pathgen::{BuildPath, PathEntry, PathKind};
use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};

/// Stream terminator ("Finish" token in Algorithm 2).
pub const FINISH: u32 = u32::MAX;

/// Encode one path entry into its 32-bit instruction word.
pub fn encode_entry(e: &PathEntry) -> u32 {
    assert!(e.dst < 256 && e.src < 256, "dst/src exceed 8-bit field");
    assert!(e.j < 8, "coordinate exceeds 3-bit field");
    ((e.dst as u32) << 24) | ((e.src as u32) << 16) | ((e.j as u32) << 9) | ((e.sign as u32) << 8)
}

/// Decode a 32-bit instruction word (None for FINISH).
pub fn decode_entry(word: u32) -> Option<PathEntry> {
    if word == FINISH {
        return None;
    }
    Some(PathEntry {
        dst: ((word >> 24) & 0xff) as u16,
        src: ((word >> 16) & 0xff) as u16,
        j: ((word >> 9) & 0x7) as u8,
        sign: (word >> 8) & 1 == 1,
    })
}

/// Serialize a build path into the instruction stream (with FINISH).
pub fn encode_path(path: &BuildPath) -> Vec<u32> {
    let mut words: Vec<u32> = path.entries.iter().map(encode_entry).collect();
    words.push(FINISH);
    words
}

/// Deserialize an instruction stream (stops at FINISH).
pub fn decode_stream(words: &[u32]) -> Vec<PathEntry> {
    words.iter().map_while(|&w| decode_entry(w)).collect()
}

/// Size in bytes of the build-path buffer a path needs.
pub fn path_buffer_bytes(path: &BuildPath) -> usize {
    (path.entries.len() + 1) * 4
}

/// Load a build path emitted by `python -m compile.aot`
/// (`artifacts/paths/*.json`) into the shared representation.
pub fn load_path_json(path: &std::path::Path) -> Result<BuildPath> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading {}", path.display()))?;
    let j = Json::parse(&text).context("parsing path json")?;
    let kind = match j.req("kind")?.as_str() {
        Some("ternary") => PathKind::Ternary,
        Some("binary") => PathKind::Binary,
        other => bail!("unknown path kind {other:?}"),
    };
    let c = j.req("c")?.as_usize().ok_or_else(|| anyhow!("c must be a number"))?;
    let min_raw = j
        .req("min_raw_distance")?
        .as_usize()
        .ok_or_else(|| anyhow!("min_raw_distance must be a number"))?;
    let root = match kind {
        PathKind::Ternary => crate::encoding::zero_index(c),
        PathKind::Binary => 0,
    };
    let entries = j
        .req("entries")?
        .as_arr()
        .ok_or_else(|| anyhow!("entries must be an array"))?
        .iter()
        .map(|row| -> Result<PathEntry> {
            let r = row.as_arr().ok_or_else(|| anyhow!("entry must be an array"))?;
            if r.len() != 4 {
                bail!("entry must have 4 fields");
            }
            let get = |i: usize| r[i].as_i64().ok_or_else(|| anyhow!("field {i} not a number"));
            Ok(PathEntry {
                dst: get(0)? as u16,
                src: get(1)? as u16,
                j: get(2)? as u8,
                sign: get(3)? == 1,
            })
        })
        .collect::<Result<Vec<_>>>()?;
    Ok(BuildPath { kind, c, root, entries, min_raw_distance: min_raw })
}

/// Final encoded weight stream (§III-C): the offline encoder emits the
/// packed bytes in the order the PPE array consumes them — chunk-major
/// round groups (each round covers `num_ppes` consecutive chunks, one
/// per PPE bank) with rows streaming inside a round — so the weight
/// buffer banks are read strictly sequentially at runtime and need no
/// address generation beyond an incrementing pointer.
///
/// Layout: for each n-independent round group g (chunks `g·L .. g·L+L`),
/// for each row r, L bytes — one per PPE — padded with the canonical
/// zero byte for absent chunks so every round has a full L-byte beat.
pub fn weight_stream(packed: &crate::encoding::PackedTernary, num_ppes: usize) -> Vec<u8> {
    let chunks = packed.chunks();
    let zero_byte = crate::encoding::zero_index(packed.c) as u8;
    let groups = chunks.div_ceil(num_ppes);
    let mut out = Vec::with_capacity(groups * packed.m * num_ppes);
    for g in 0..groups {
        for row in 0..packed.m {
            for lane in 0..num_ppes {
                let ch = g * num_ppes + lane;
                out.push(if ch < chunks { packed.at(row, ch) } else { zero_byte });
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pathgen;

    #[test]
    fn entry_roundtrip() {
        let e = PathEntry { dst: 121, src: 40, j: 3, sign: true };
        assert_eq!(decode_entry(encode_entry(&e)), Some(e));
    }

    #[test]
    fn finish_terminates() {
        assert_eq!(decode_entry(FINISH), None);
    }

    #[test]
    fn stream_roundtrip_full_paths() {
        for path in [pathgen::ternary_path(5), pathgen::binary_path(7)] {
            let words = encode_path(&path);
            assert_eq!(*words.last().unwrap(), FINISH);
            assert_eq!(decode_stream(&words), path.entries);
        }
    }

    #[test]
    fn weight_stream_is_sequential_and_complete() {
        use crate::encoding::pack_ternary;
        use crate::util::rng::Rng;
        let mut rng = Rng::seed_from(3);
        let (m, k, l) = (6, 37, 4); // 8 chunks over 4 PPEs → 2 round groups
        let w = rng.ternary_vec(m * k);
        let packed = pack_ternary(&w, m, k, 5);
        let stream = weight_stream(&packed, l);
        assert_eq!(stream.len(), 2 * m * l);
        // beat (g=0, row=0) holds chunks 0..4 of row 0, in lane order
        for lane in 0..l {
            assert_eq!(stream[lane], packed.at(0, lane));
        }
        // second group's lanes hold chunks 4..8
        let base = m * l;
        for lane in 0..l {
            assert_eq!(stream[base + lane], packed.at(0, 4 + lane));
        }
    }

    #[test]
    fn weight_stream_pads_with_zero_chunk() {
        use crate::encoding::pack_ternary;
        let w = vec![1i8; 5]; // 1 chunk, stream over 52 PPEs
        let packed = pack_ternary(&w, 1, 5, 5);
        let stream = weight_stream(&packed, 52);
        assert_eq!(stream.len(), 52);
        assert_eq!(stream[0], packed.at(0, 0));
        // padding lanes carry the canonical zero (queries return 0)
        assert!(stream[1..].iter().all(|&b| b as usize == crate::encoding::zero_index(5)));
    }

    #[test]
    fn path_buffer_fits_onchip_budget() {
        // both shipped paths fit comfortably in a 1 KB path buffer bank
        assert!(path_buffer_bytes(&pathgen::ternary_path(5)) <= 1024);
        assert!(path_buffer_bytes(&pathgen::binary_path(7)) <= 1024);
    }
}
