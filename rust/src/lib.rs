// Deliberate style choices the CI clippy gate (`clippy -- -D warnings`)
// should not fight: index-form loops mirror the paper's pseudocode
// (Algorithms 1 & 2) and keep the datapath's addressing explicit, and
// hot-path entry points take explicit argument tuples rather than a
// builder.  Everything else clippy flags is treated as an error.
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::field_reassign_with_default
)]

//! # Platinum — path-adaptable LUT-based accelerator for low-bit mpGEMM
//!
//! Full-system reproduction of *"Platinum: Path-Adaptable LUT-Based
//! Accelerator Tailored for Low-Bit Weight Matrix Multiplication"*
//! (Shan et al., 2025).
//!
//! The crate is the **Layer-3 coordinator** of a three-layer stack:
//!
//! * **L1** — Pallas kernels (`python/compile/kernels/`) implement the
//!   LUT construct/query datapath; AOT-lowered, never imported at runtime.
//! * **L2** — a JAX BitNet-style model (`python/compile/model.py`) calling
//!   the kernels; lowered once to `artifacts/*.hlo.txt`.
//! * **L3** — this crate: the offline toolchain (build-path generation,
//!   weight encoding), the cycle-accurate accelerator simulator with
//!   area/energy models, the baseline accelerators, the design-space
//!   explorer, a PJRT runtime that executes the L2 artifacts, and a tokio
//!   serving coordinator.
//!
//! Module map (↔ DESIGN.md system inventory):
//!
//! | module | system |
//! |---|---|
//! | [`config`] | accelerator + tiling configuration (S4, S6) |
//! | [`encoding`] | ternary/binary packing, mirror symmetry (S1) |
//! | [`pathgen`] | offline MST build paths + hazard scheduling (S2) |
//! | [`isa`] | path-entry / weight-stream binary formats (S2) |
//! | [`lut`] | functional golden model of Algorithms 1 & 2 (S3) |
//! | [`analysis`] | Eq (1)–(3) cost model, bits/weight (S10) |
//! | [`models`] | BitNet b1.58 layer shapes + kernel extraction (S9) |
//! | [`energy`] | 28nm synthesis / SRAM / DRAM area+energy models (S5) |
//! | [`sim`] | cycle-accurate Platinum simulator (S4) |
//! | [`baselines`] | SpikingEyeriss, Prosperity, T-MAC, naive (S8) |
//! | [`dse`] | design-space exploration over tiling (S7) |
//! | [`runtime`] | PJRT artifact load/execute + worker pool (S11, S14) |
//! | [`coordinator`] | tiling scheduler + serving loop (S6, S12) |
//! | [`engine`] | unified Backend/Workload/Report execution API (S13) |
//! | [`traffic`] | continuous-batching serving + load generation (S15) |
//! | [`kv`] | paged KV-cache allocator + SRAM/DRAM capacity model (S16) |
//! | [`fault`] | deterministic fault injection + SLO resilience (S17) |
//! | [`server`] | `platinum serve` daemon: std-only HTTP/1.1 wire protocol (S18) |
//!
//! All execution flows through [`engine`]: a [`engine::Registry`]
//! constructs [`engine::Backend`]s by name, each runs
//! [`engine::Workload`]s (kernel, model pass, batch) and returns the
//! unified [`engine::Report`] — the CLI, DSE, benches and the serving
//! coordinator are all thin frontends over that one API.  The
//! functional CPU hot paths ([`lut`], [`baselines::tmac::TMacCpu`])
//! execute in parallel blocked rounds on the persistent
//! [`runtime::pool`] worker pool, bit-exact at any thread count.

pub mod analysis;
pub mod baselines;
pub mod config;
pub mod coordinator;
pub mod dse;
pub mod encoding;
pub mod energy;
pub mod engine;
pub mod fault;
pub mod isa;
pub mod kv;
pub mod lut;
pub mod models;
pub mod pathgen;
pub mod runtime;
pub mod server;
pub mod sim;
pub mod traffic;
pub mod util;

pub use config::PlatinumConfig;
