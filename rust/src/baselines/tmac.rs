//! T-MAC baseline (§V-A): CPU LUT-based mpGEMM.
//!
//! Two forms:
//!
//! 1. [`simulate_m2pro`] — an analytical model of the paper's strong
//!    baseline: 16 threads on an Apple M2 Pro at 3.49 GHz, using NEON
//!    `tbl` table lookups (16 parallel 8-bit lookups per instruction)
//!    over 4-bit weight groups, calibrated to Table I's 715 GOP/s and a
//!    package power typical of an M2 Pro under all-core integer load.
//!
//! 2. [`TMacCpu`] — a **real, runnable** T-MAC-style implementation:
//!    per 4-wide binary weight group, a 16-entry LUT of activation sums
//!    is built per column block and queried per row; ternary runs as a
//!    fused pos/neg two-plane pass.  This is what the hotpath bench
//!    measures and what the examples use as the CPU reference; it is
//!    validated against the golden model.
//!
//! §Perf iteration 5: `gemm` runs on the persistent
//! [`runtime::pool`](crate::runtime::pool) instead of paying a
//! `std::thread::scope` spawn per call, and processes columns in blocks
//! of [`COL_BLOCK`]: each block's LUTs are built **once** into a shared
//! arena and then queried per row — the seed implementation rebuilt
//! every LUT per column *per stripe*, duplicating construction work
//! across threads.  §PR 4: both phases claim their work (groups, then
//! rows) dynamically via [`Pool::for_each_chunk`] on the work-stealing
//! pool, so ragged group/row counts and `threads > rows` decode shapes
//! load-balance; results stay bit-exact because each row's group
//! accumulation order is fixed regardless of which lane runs it.

use super::BaselineReport;
use crate::analysis::Gemm;
use crate::runtime::pool::{self, DisjointSlice, Pool};

/// T-MAC group width (4 binary weights → 16-entry LUT).
pub const GROUP: usize = 4;

/// Columns per LUT-reuse block in [`TMacCpu::gemm`] (matches the
/// paper's decode granularity; 130 groups × 16 entries × 8 columns of
/// i32 ≈ 65 KB arena for a 520-deep layer — L2-resident).
pub const COL_BLOCK: usize = 8;

// --- analytical M2 Pro model ---------------------------------------------

pub const M2_FREQ_HZ: f64 = 3.49e9;
pub const M2_THREADS: f64 = 16.0;
/// Effective naive-adds retired per core-cycle per thread: NEON tbl does
/// 16 byte-lookups/instr, each lookup covering a 4-weight group, but
/// table setup, accumulation and int8→int16 widening cost issue slots;
/// T-MAC's published numbers imply ~12.8 adds/cycle/thread on M2-class
/// cores.  Calibrated to Table I's 715 GOP/s on b1.58-3B prefill.
pub const ADDS_PER_CYCLE_THREAD: f64 = 12.8;
/// Package power under sustained all-core SIMD integer load (W).
pub const M2_PKG_POWER_W: f64 = 32.0;
/// Unified-memory bandwidth available to the CPU cluster (bytes/s).
pub const M2_MEM_BW: f64 = 100e9;

/// Analytical T-MAC latency/energy on the paper's CPU setup.
pub fn simulate_m2pro(g: Gemm) -> BaselineReport {
    let ops = g.naive_adds() as f64;
    let compute_s = ops / (ADDS_PER_CYCLE_THREAD * M2_THREADS * M2_FREQ_HZ);
    // memory: 2-bit weights + activations + outputs, streamed per pass
    let bytes = (g.m * g.k) as f64 / 4.0 + (g.k * g.n) as f64 + (g.m * g.n) as f64;
    let mem_s = bytes / M2_MEM_BW;
    let latency = compute_s.max(mem_s);
    // decode-shaped kernels leave some cores starved; T-MAC's published
    // decode scaling shows ~85 % efficiency at N=8
    let latency = if g.n <= 16 { latency / 0.85 } else { latency };
    BaselineReport {
        latency_s: latency,
        energy_j: latency * M2_PKG_POWER_W,
        throughput_gops: ops / latency / 1e9,
    }
}

// --- real CPU implementation ----------------------------------------------

/// A T-MAC-style CPU kernel instance: pre-grouped binary plane indices
/// (plane 0 = +1 weights, plane 1 = −1 weights; queries fuse the two).
pub struct TMacCpu {
    /// Per plane: (m × groups) 4-bit LUT indices.
    planes: Vec<Vec<u8>>,
    m: usize,
    k: usize,
    groups: usize,
}

impl TMacCpu {
    /// Prepare from a ternary weight matrix (row-major m×k).
    pub fn new(w: &[i8], m: usize, k: usize) -> Self {
        assert_eq!(w.len(), m * k);
        let groups = k.div_ceil(GROUP);
        let mut pos = vec![0u8; m * groups];
        let mut neg = vec![0u8; m * groups];
        for row in 0..m {
            for gidx in 0..groups {
                let mut pb = 0u8;
                let mut nb = 0u8;
                for i in 0..GROUP {
                    let kk = gidx * GROUP + i;
                    if kk < k {
                        match w[row * k + kk] {
                            1 => pb |= 1 << i,
                            -1 => nb |= 1 << i,
                            _ => {}
                        }
                    }
                }
                pos[row * groups + gidx] = pb;
                neg[row * groups + gidx] = nb;
            }
        }
        TMacCpu { planes: vec![pos, neg], m, k, groups }
    }

    /// Compute y = W · x for a single activation column (the
    /// decode-shaped hot path).  `x` is int8-range int32s, length k.
    pub fn gemv(&self, x: &[i32], out: &mut [i32]) {
        assert_eq!(x.len(), self.k);
        assert_eq!(out.len(), self.m);
        // build one 16-entry LUT per group: lut[t] = Σ_{i∈t} x[g·4+i]
        let mut luts = vec![0i32; self.groups * 16];
        for gidx in 0..self.groups {
            let base = gidx * GROUP;
            let lut = &mut luts[gidx * 16..(gidx + 1) * 16];
            // incremental construction: lut[t] = lut[t & (t-1)] + x[lsb]
            for t in 1..16usize {
                let j = t.trailing_zeros() as usize;
                let xv = if base + j < self.k { x[base + j] } else { 0 };
                lut[t] = lut[t & (t - 1)] + xv;
            }
        }
        // §Perf iteration 4: single pass over rows with both planes
        // fused (pos − neg per group) — halves the row-loop overhead and
        // keeps each group's 16-entry LUT line hot across both lookups.
        let pos = &self.planes[0];
        let neg = &self.planes[1];
        for (row, o) in out.iter_mut().enumerate() {
            let base = row * self.groups;
            let pi = &pos[base..base + self.groups];
            let ni = &neg[base..base + self.groups];
            let mut acc = 0i32;
            for gidx in 0..self.groups {
                let lut = &luts[gidx * 16..gidx * 16 + 16];
                acc += lut[pi[gidx] as usize] - lut[ni[gidx] as usize];
            }
            *o = acc;
        }
    }

    /// GEMM y = W · X over the process-wide worker pool with up to
    /// `threads` lanes claiming rows dynamically.  `x` is (k × n)
    /// row-major; `out` is (m × n) row-major.  Bit-exact for any
    /// thread count.
    pub fn gemm(&self, x: &[i32], n: usize, out: &mut [i32], threads: usize) {
        self.gemm_pool(x, n, out, threads, pool::global());
    }

    /// [`TMacCpu::gemm`] on an explicit pool (bench sweeps, backends
    /// with pinned thread counts).
    pub fn gemm_pool(&self, x: &[i32], n: usize, out: &mut [i32], threads: usize, pool: &Pool) {
        assert_eq!(x.len(), self.k * n);
        assert_eq!(out.len(), self.m * n);
        let threads = threads.max(1);
        let groups = self.groups;
        let k = self.k;
        let pos = &self.planes[0][..];
        let neg = &self.planes[1][..];

        // shared per-block LUT arena: entry t of group g for block
        // column j lives at luts[(g*16 + t) * nb + j], so one query
        // fetches nb contiguous accumulators
        let mut luts = vec![0i32; groups * 16 * COL_BLOCK];
        for col0 in (0..n).step_by(COL_BLOCK) {
            let nb = COL_BLOCK.min(n - col0);

            // phase 1: build the block's LUTs once — groups claimed
            // dynamically, each written to its disjoint arena region
            {
                let luts_sl = DisjointSlice::new(&mut luts);
                pool.for_each_chunk(threads, groups, 0, &|gs| {
                    for g in gs {
                        let base = g * GROUP;
                        // SAFETY: group g's 16·nb arena region is
                        // written only by this claim (claims disjoint)
                        let lut = unsafe { luts_sl.range(g * 16 * nb..(g + 1) * 16 * nb) };
                        lut[..nb].fill(0); // entry 0: empty subset
                        for t in 1..16usize {
                            let j = t.trailing_zeros() as usize;
                            let src = (t & (t - 1)) * nb;
                            let dst = t * nb;
                            if base + j < k {
                                let xrow =
                                    &x[(base + j) * n + col0..(base + j) * n + col0 + nb];
                                for jj in 0..nb {
                                    lut[dst + jj] = lut[src + jj] + xrow[jj];
                                }
                            } else {
                                // zero-padded k tail: copy the source entry
                                lut.copy_within(src..src + nb, dst);
                            }
                        }
                    }
                });
            }

            // phase 2: query — rows claimed dynamically, both planes
            // fused per group (as in gemv)
            {
                let luts_ref = &luts[..];
                let out_sl = DisjointSlice::new(&mut *out);
                pool.for_each_chunk(threads, self.m, 0, &|rows| {
                    for row in rows {
                        let pi = &pos[row * groups..(row + 1) * groups];
                        let ni = &neg[row * groups..(row + 1) * groups];
                        let mut acc = [0i32; COL_BLOCK];
                        for g in 0..groups {
                            let lp = &luts_ref[(g * 16 + pi[g] as usize) * nb..][..nb];
                            let ln = &luts_ref[(g * 16 + ni[g] as usize) * nb..][..nb];
                            for jj in 0..nb {
                                acc[jj] += lp[jj] - ln[jj];
                            }
                        }
                        // SAFETY: row's output segment is written only
                        // by this claim; row ranges are disjoint
                        let orow = unsafe { out_sl.range(row * n + col0..row * n + col0 + nb) };
                        orow.copy_from_slice(&acc[..nb]);
                    }
                });
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{Backend, TMacBackend, Workload};
    use crate::lut::naive_mpgemm;
    use crate::models::B158_3B;
    use crate::util::rng::Rng;

    #[test]
    fn table1_m2pro_throughput() {
        let r = TMacBackend.run(&Workload::prefill(B158_3B));
        assert!(
            (r.throughput_gops - 715.0).abs() / 715.0 < 0.25,
            "{:.0} GOP/s vs Table I 715",
            r.throughput_gops
        );
    }

    #[test]
    fn real_gemv_matches_naive() {
        let mut rng = Rng::seed_from(1);
        let (m, k) = (64, 57);
        let w = rng.ternary_vec(m * k);
        let x = rng.act_vec(k);
        let tm = TMacCpu::new(&w, m, k);
        let mut out = vec![0i32; m];
        tm.gemv(&x, &mut out);
        let want = naive_mpgemm(&w, m, k, &x, 1);
        for i in 0..m {
            assert_eq!(out[i] as i64, want[i], "row {i}");
        }
    }

    #[test]
    fn real_gemm_matches_naive_multithreaded() {
        let mut rng = Rng::seed_from(2);
        let (m, k, n) = (33, 29, 7);
        let w = rng.ternary_vec(m * k);
        let x = rng.act_vec(k * n);
        let tm = TMacCpu::new(&w, m, k);
        let mut out = vec![0i32; m * n];
        tm.gemm(&x, n, &mut out, 4);
        let want = naive_mpgemm(&w, m, k, &x, n);
        for i in 0..m * n {
            assert_eq!(out[i] as i64, want[i]);
        }
    }

    #[test]
    fn gemm_single_thread_same_as_gemv_columns() {
        let mut rng = Rng::seed_from(3);
        let (m, k) = (16, 20);
        let w = rng.ternary_vec(m * k);
        let tm = TMacCpu::new(&w, m, k);
        let x_col = rng.act_vec(k);
        let x_mat: Vec<i32> = x_col.clone(); // n = 1
        let mut a = vec![0i32; m];
        let mut b = vec![0i32; m];
        tm.gemv(&x_col, &mut a);
        tm.gemm(&x_mat, 1, &mut b, 1);
        assert_eq!(a, b);
    }

    #[test]
    fn gemm_threads_exceed_rows() {
        let mut rng = Rng::seed_from(4);
        let (m, k, n) = (5, 37, 3);
        let w = rng.ternary_vec(m * k);
        let x = rng.act_vec(k * n);
        let tm = TMacCpu::new(&w, m, k);
        let pool = Pool::new(8);
        let mut out = vec![0i32; m * n];
        tm.gemm_pool(&x, n, &mut out, 8, &pool);
        let want = naive_mpgemm(&w, m, k, &x, n);
        for i in 0..m * n {
            assert_eq!(out[i] as i64, want[i]);
        }
    }

    #[test]
    fn gemm_column_count_not_multiple_of_block() {
        // n straddles COL_BLOCK boundaries (tail block narrower)
        let mut rng = Rng::seed_from(5);
        let (m, k, n) = (24, 41, COL_BLOCK + 3);
        let w = rng.ternary_vec(m * k);
        let x = rng.act_vec(k * n);
        let tm = TMacCpu::new(&w, m, k);
        let mut out = vec![0i32; m * n];
        tm.gemm(&x, n, &mut out, 2);
        let want = naive_mpgemm(&w, m, k, &x, n);
        for i in 0..m * n {
            assert_eq!(out[i] as i64, want[i]);
        }
    }

    #[test]
    fn prop_gemm_pool_matches_single_thread() {
        let pool = Pool::new(4);
        crate::util::check_prop("tmac_pool_matches_single_thread", 10, |seed| {
            let mut rng = Rng::seed_from(seed);
            let m = 1 + rng.below(48) as usize;
            let k = 1 + rng.below(90) as usize;
            let n = 1 + rng.below(20) as usize;
            let w = rng.ternary_vec(m * k);
            let x = rng.act_vec(k * n);
            let tm = TMacCpu::new(&w, m, k);
            let single = Pool::new(1);
            let mut seq = vec![0i32; m * n];
            tm.gemm_pool(&x, n, &mut seq, 1, &single);
            let threads = 1 + rng.below(9) as usize;
            let mut par = vec![0i32; m * n];
            tm.gemm_pool(&x, n, &mut par, threads, &pool);
            crate::ensure_prop!(
                seq == par,
                "pool diverged at m={m} k={k} n={n} threads={threads}"
            );
            let want = naive_mpgemm(&w, m, k, &x, n);
            for i in 0..m * n {
                crate::ensure_prop!(seq[i] as i64 == want[i], "wrong vs naive at {i}");
            }
            Ok(())
        });
    }
}
