//! T-MAC baseline (§V-A): CPU LUT-based mpGEMM.
//!
//! Two forms:
//!
//! 1. [`simulate_m2pro`] — an analytical model of the paper's strong
//!    baseline: 16 threads on an Apple M2 Pro at 3.49 GHz, using NEON
//!    `tbl` table lookups (16 parallel 8-bit lookups per instruction)
//!    over 4-bit weight groups, calibrated to Table I's 715 GOP/s and a
//!    package power typical of an M2 Pro under all-core integer load.
//!
//! 2. [`TMacCpu`] — a **real, runnable** T-MAC-style implementation:
//!    per 4-wide binary weight group, a 16-entry LUT of activation sums
//!    is built per column block and queried per row; ternary runs as two
//!    passes.  Multithreaded over row stripes with `std::thread::scope`.
//!    This is what the hotpath bench measures and what the examples use
//!    as the CPU reference; it is validated against the golden model.

use super::BaselineReport;
use crate::analysis::Gemm;

/// T-MAC group width (4 binary weights → 16-entry LUT).
pub const GROUP: usize = 4;

// --- analytical M2 Pro model ---------------------------------------------

pub const M2_FREQ_HZ: f64 = 3.49e9;
pub const M2_THREADS: f64 = 16.0;
/// Effective naive-adds retired per core-cycle per thread: NEON tbl does
/// 16 byte-lookups/instr, each lookup covering a 4-weight group, but
/// table setup, accumulation and int8→int16 widening cost issue slots;
/// T-MAC's published numbers imply ~12.8 adds/cycle/thread on M2-class
/// cores.  Calibrated to Table I's 715 GOP/s on b1.58-3B prefill.
pub const ADDS_PER_CYCLE_THREAD: f64 = 12.8;
/// Package power under sustained all-core SIMD integer load (W).
pub const M2_PKG_POWER_W: f64 = 32.0;
/// Unified-memory bandwidth available to the CPU cluster (bytes/s).
pub const M2_MEM_BW: f64 = 100e9;

/// Analytical T-MAC latency/energy on the paper's CPU setup.
pub fn simulate_m2pro(g: Gemm) -> BaselineReport {
    let ops = g.naive_adds() as f64;
    let compute_s = ops / (ADDS_PER_CYCLE_THREAD * M2_THREADS * M2_FREQ_HZ);
    // memory: 2-bit weights + activations + outputs, streamed per pass
    let bytes = (g.m * g.k) as f64 / 4.0 + (g.k * g.n) as f64 + (g.m * g.n) as f64;
    let mem_s = bytes / M2_MEM_BW;
    let latency = compute_s.max(mem_s);
    // decode-shaped kernels leave some cores starved; T-MAC's published
    // decode scaling shows ~85 % efficiency at N=8
    let latency = if g.n <= 16 { latency / 0.85 } else { latency };
    BaselineReport {
        latency_s: latency,
        energy_j: latency * M2_PKG_POWER_W,
        throughput_gops: ops / latency / 1e9,
    }
}

// --- real CPU implementation ----------------------------------------------

/// A T-MAC-style CPU kernel instance: pre-grouped binary plane indices.
pub struct TMacCpu {
    /// Per plane: (m × groups) 4-bit LUT indices.
    planes: Vec<Vec<u8>>,
    plane_signs: Vec<i32>,
    m: usize,
    k: usize,
    groups: usize,
}

impl TMacCpu {
    /// Prepare from a ternary weight matrix (row-major m×k).
    pub fn new(w: &[i8], m: usize, k: usize) -> Self {
        assert_eq!(w.len(), m * k);
        let groups = k.div_ceil(GROUP);
        let mut pos = vec![0u8; m * groups];
        let mut neg = vec![0u8; m * groups];
        for row in 0..m {
            for gidx in 0..groups {
                let mut pb = 0u8;
                let mut nb = 0u8;
                for i in 0..GROUP {
                    let kk = gidx * GROUP + i;
                    if kk < k {
                        match w[row * k + kk] {
                            1 => pb |= 1 << i,
                            -1 => nb |= 1 << i,
                            _ => {}
                        }
                    }
                }
                pos[row * groups + gidx] = pb;
                neg[row * groups + gidx] = nb;
            }
        }
        TMacCpu { planes: vec![pos, neg], plane_signs: vec![1, -1], m, k, groups }
    }

    /// Compute y = W · x for a single activation column (the
    /// decode-shaped hot path).  `x` is int8-range int32s, length k.
    pub fn gemv(&self, x: &[i32], out: &mut [i32]) {
        assert_eq!(x.len(), self.k);
        assert_eq!(out.len(), self.m);
        // build one 16-entry LUT per group: lut[t] = Σ_{i∈t} x[g·4+i]
        let mut luts = vec![0i32; self.groups * 16];
        for gidx in 0..self.groups {
            let base = gidx * GROUP;
            let lut = &mut luts[gidx * 16..(gidx + 1) * 16];
            // incremental construction: lut[t] = lut[t & (t-1)] + x[lsb]
            for t in 1..16usize {
                let j = t.trailing_zeros() as usize;
                let xv = if base + j < self.k { x[base + j] } else { 0 };
                lut[t] = lut[t & (t - 1)] + xv;
            }
        }
        // §Perf iteration 4: single pass over rows with both planes
        // fused (pos − neg per group) — halves the row-loop overhead and
        // keeps each group's 16-entry LUT line hot across both lookups.
        let pos = &self.planes[0];
        let neg = &self.planes[1];
        for (row, o) in out.iter_mut().enumerate() {
            let base = row * self.groups;
            let pi = &pos[base..base + self.groups];
            let ni = &neg[base..base + self.groups];
            let mut acc = 0i32;
            for gidx in 0..self.groups {
                let lut = &luts[gidx * 16..gidx * 16 + 16];
                acc += lut[pi[gidx] as usize] - lut[ni[gidx] as usize];
            }
            *o = acc;
        }
    }

    /// Multithreaded GEMM y = W · X over row stripes.
    /// `x` is (k × n) row-major; `out` is (m × n) row-major.
    pub fn gemm(&self, x: &[i32], n: usize, out: &mut [i32], threads: usize) {
        assert_eq!(x.len(), self.k * n);
        assert_eq!(out.len(), self.m * n);
        let threads = threads.max(1);
        let stripe = self.m.div_ceil(threads);
        // per-column-group LUTs are built per thread to stay cache-local
        std::thread::scope(|scope| {
            for (tid, chunk) in out.chunks_mut(stripe * n).enumerate() {
                let row0 = tid * stripe;
                scope.spawn(move || {
                    self.gemm_stripe(x, n, row0, chunk);
                });
            }
        });
    }

    fn gemm_stripe(&self, x: &[i32], n: usize, row0: usize, out: &mut [i32]) {
        let rows = out.len() / n;
        out.fill(0);
        // process columns one at a time (decode) or in blocks; LUT per
        // (group, column) is rebuilt per column — T-MAC's act-major order
        let mut luts = vec![0i32; self.groups * 16];
        for col in 0..n {
            for gidx in 0..self.groups {
                let base = gidx * GROUP;
                let lut = &mut luts[gidx * 16..(gidx + 1) * 16];
                for t in 1..16usize {
                    let j = t.trailing_zeros() as usize;
                    let xv = if base + j < self.k { x[(base + j) * n + col] } else { 0 };
                    lut[t] = lut[t & (t - 1)] + xv;
                }
            }
            for r in 0..rows {
                let row = row0 + r;
                if row >= self.m {
                    break;
                }
                let mut acc = 0i32;
                for (plane, &sign) in self.planes.iter().zip(&self.plane_signs) {
                    let idxs = &plane[row * self.groups..(row + 1) * self.groups];
                    let mut pacc = 0i32;
                    for (gidx, &t) in idxs.iter().enumerate() {
                        pacc += luts[gidx * 16 + t as usize];
                    }
                    acc += sign * pacc;
                }
                out[r * n + col] = acc;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{Backend, TMacBackend, Workload};
    use crate::lut::naive_mpgemm;
    use crate::models::B158_3B;
    use crate::util::rng::Rng;

    #[test]
    fn table1_m2pro_throughput() {
        let r = TMacBackend.run(&Workload::prefill(B158_3B));
        assert!(
            (r.throughput_gops - 715.0).abs() / 715.0 < 0.25,
            "{:.0} GOP/s vs Table I 715",
            r.throughput_gops
        );
    }

    #[test]
    fn real_gemv_matches_naive() {
        let mut rng = Rng::seed_from(1);
        let (m, k) = (64, 57);
        let w = rng.ternary_vec(m * k);
        let x = rng.act_vec(k);
        let tm = TMacCpu::new(&w, m, k);
        let mut out = vec![0i32; m];
        tm.gemv(&x, &mut out);
        let want = naive_mpgemm(&w, m, k, &x, 1);
        for i in 0..m {
            assert_eq!(out[i] as i64, want[i], "row {i}");
        }
    }

    #[test]
    fn real_gemm_matches_naive_multithreaded() {
        let mut rng = Rng::seed_from(2);
        let (m, k, n) = (33, 29, 7);
        let w = rng.ternary_vec(m * k);
        let x = rng.act_vec(k * n);
        let tm = TMacCpu::new(&w, m, k);
        let mut out = vec![0i32; m * n];
        tm.gemm(&x, n, &mut out, 4);
        let want = naive_mpgemm(&w, m, k, &x, n);
        for i in 0..m * n {
            assert_eq!(out[i] as i64, want[i]);
        }
    }

    #[test]
    fn gemm_single_thread_same_as_gemv_columns() {
        let mut rng = Rng::seed_from(3);
        let (m, k) = (16, 20);
        let w = rng.ternary_vec(m * k);
        let tm = TMacCpu::new(&w, m, k);
        let x_col = rng.act_vec(k);
        let x_mat: Vec<i32> = x_col.clone(); // n = 1
        let mut a = vec![0i32; m];
        let mut b = vec![0i32; m];
        tm.gemv(&x_col, &mut a);
        tm.gemm(&x_mat, 1, &mut b, 1);
        assert_eq!(a, b);
    }
}
