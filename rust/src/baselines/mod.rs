//! Baseline accelerators (S8) — the paper's comparison points (§V-A):
//!
//! * [`eyeriss`] — SpikingEyeriss: a 168-PE row-stationary ASIC run in
//!   bit-serial two-pass mode for ternary weights.
//! * [`prosperity`] — Prosperity (HPCA'25): 256-PE product-sparsity
//!   accelerator with *runtime* shortcut scheduling (the dynamic-hardware
//!   overhead Platinum disaggregates away: +24 % area, 32.3 % power).
//! * [`tmac`] — T-MAC: CPU LUT-based mpGEMM.  Two forms: a calibrated
//!   analytical model of the paper's Apple-M2-Pro/16-thread setup, and a
//!   **real multithreaded implementation** measured on this machine
//!   (`tmac::TMacCpu`), used by the hotpath bench and the examples.
//!
//! Each baseline's `simulate` free function returns a [`BaselineReport`];
//! the preferred surface is [`crate::engine`], whose backends wrap these
//! functions and tabulate all systems through the unified
//! [`crate::engine::Report`] (that is what Fig 8/9/10 and the CLI use).

pub mod eyeriss;
pub mod prosperity;
pub mod tmac;

use crate::analysis::Gemm;
use crate::models::BitNetModel;

/// Uniform result row for baseline comparisons.
#[derive(Debug, Clone, Copy)]
pub struct BaselineReport {
    pub latency_s: f64,
    pub energy_j: f64,
    pub throughput_gops: f64,
}

impl BaselineReport {
    pub fn from_cycles(cycles: f64, freq_hz: f64, energy_j: f64, g: Gemm) -> Self {
        let latency_s = cycles / freq_hz;
        BaselineReport {
            latency_s,
            energy_j,
            throughput_gops: g.naive_adds() as f64 / latency_s / 1e9,
        }
    }
}

/// Aggregate a per-kernel baseline over a full model pass.
#[deprecated(
    note = "use engine::Backend::run with Workload::ModelPass — the engine \
            aggregates identically and returns the unified Report"
)]
pub fn model_report<F: Fn(Gemm) -> BaselineReport>(
    model: &BitNetModel,
    n: usize,
    f: F,
) -> BaselineReport {
    let mut lat = 0.0;
    let mut en = 0.0;
    let mut ops: u64 = 0;
    for (g, count) in model.model_gemms(n) {
        let r = f(g);
        lat += r.latency_s * count as f64;
        en += r.energy_j * count as f64;
        ops += g.naive_adds() * count as u64;
    }
    BaselineReport { latency_s: lat, energy_j: en, throughput_gops: ops as f64 / lat / 1e9 }
}

#[cfg(test)]
mod tests {
    use crate::engine::{Backend, Registry, Report, Workload};
    use crate::models::B158_3B;

    /// Run a backend id from the registry on a b1.58-3B model pass —
    /// the fig 10 tests now exercise exactly the engine surface the CLI
    /// and benches use.
    fn run(id: &str, w: &Workload) -> Report {
        Registry::with_defaults().build(id).unwrap().run(w)
    }

    /// E9 / Fig 10 — the paper's headline model-level comparisons.
    /// Our substitute models must land in the same bands ("who wins, by
    /// roughly what factor").
    #[test]
    fn fig10_prefill_speedups_hold() {
        let w = Workload::prefill(B158_3B);
        let plat = run("platinum-ternary", &w);
        let eye = run("eyeriss", &w);
        let pro = run("prosperity", &w);
        let tm = run("tmac", &w);

        let s_eye = eye.latency_s / plat.latency_s;
        let s_pro = pro.latency_s / plat.latency_s;
        let s_tm = tm.latency_s / plat.latency_s;
        // paper: 73.6×, 4.09×, 2.15× — accept ±40 % bands on the ratios
        assert!((44.0..=110.0).contains(&s_eye), "Eyeriss speedup {s_eye:.1}");
        assert!((2.4..=5.8).contains(&s_pro), "Prosperity speedup {s_pro:.2}");
        assert!((1.3..=3.1).contains(&s_tm), "T-MAC speedup {s_tm:.2}");
    }

    #[test]
    fn fig10_decode_speedups_hold() {
        let w = Workload::decode(B158_3B);
        let plat = run("platinum-ternary", &w);
        let eye = run("eyeriss", &w);
        let pro = run("prosperity", &w);
        let tm = run("tmac", &w);
        let s_eye = eye.latency_s / plat.latency_s;
        let s_pro = pro.latency_s / plat.latency_s;
        let s_tm = tm.latency_s / plat.latency_s;
        // paper: 47.6×, 28.4×, 1.75× — Eyeriss gets a wider band: its
        // decode mapping is the least-documented baseline configuration
        assert!((28.0..=95.0).contains(&s_eye), "Eyeriss decode {s_eye:.1}");
        assert!((17.0..=43.0).contains(&s_pro), "Prosperity decode {s_pro:.1}");
        assert!((1.0..=2.7).contains(&s_tm), "T-MAC decode {s_tm:.2}");
    }

    #[test]
    fn fig10_energy_ratios_hold() {
        let w = Workload::prefill(B158_3B);
        let plat = run("platinum-ternary", &w);
        let eye = run("eyeriss", &w);
        let pro = run("prosperity", &w);
        let tm = run("tmac", &w);
        let e_plat = plat.energy_j;
        // paper prefill energy ratios: 32.4× (Eyeriss), 3.23× (Prosperity),
        // 20.9× (T-MAC) — shape: Eyeriss ≫ T-MAC ≫ Prosperity > Platinum
        let r_eye = eye.energy_j / e_plat;
        let r_pro = pro.energy_j / e_plat;
        let r_tm = tm.energy_j / e_plat;
        assert!((19.0..=49.0).contains(&r_eye), "Eyeriss energy {r_eye:.1}");
        assert!((1.9..=4.9).contains(&r_pro), "Prosperity energy {r_pro:.2}");
        assert!((12.0..=32.0).contains(&r_tm), "T-MAC energy {r_tm:.1}");
        assert!(r_eye > r_tm && r_tm > r_pro && r_pro > 1.0);
    }

    #[test]
    fn table1_throughputs_hold() {
        // Table I GOP/s on 3B prefill: Eyeriss 20.8, Prosperity 375,
        // T-MAC 715 (±35 %)
        let w = Workload::prefill(B158_3B);
        let eye = run("eyeriss", &w);
        let pro = run("prosperity", &w);
        let tm = run("tmac", &w);
        assert!((eye.throughput_gops - 20.8).abs() / 20.8 < 0.35, "{}", eye.throughput_gops);
        assert!((pro.throughput_gops - 375.0).abs() / 375.0 < 0.35, "{}", pro.throughput_gops);
        assert!((tm.throughput_gops - 715.0).abs() / 715.0 < 0.35, "{}", tm.throughput_gops);
    }
}
