//! Prosperity baseline (HPCA'25, §II & §V-A): a 256-PE accelerator that
//! exploits **product sparsity** — when one row's support is a superset
//! of another's, the smaller row's partial sum is reused and only the
//! difference is accumulated — discovered by *runtime* scheduling
//! hardware (the overhead Platinum moves offline: 24 % of area, 32.3 %
//! of power).
//!
//! Timing model: per binary plane, rows are processed in M-tiles; for
//! each row the scheduler finds the best previously-computed ancestor
//! row inside the tile and accumulates only the residual support.  The
//! residual fraction ρ is measured by [`product_reuse_factor`] — an
//! actual implementation of the prefix-reuse search on sampled uniform
//! ternary tiles (the distribution the paper notes for BitNet) — then
//! cached.  PEs are arranged 4 (M) × 64 (N): decode workloads with
//! N < 64 under-fill the N lanes, reproducing the paper's observation
//! that "Prosperity suffers from significant underutilization of PEs for
//! decode workloads".

use super::BaselineReport;
use crate::analysis::Gemm;
use crate::energy::DRAM_PJ_PER_BIT;
use crate::util::rng::Rng;
use std::sync::OnceLock;

pub const NUM_PES: usize = 256;
/// Rows in flight per cycle (PE array = M_LANES × N_LANES).
pub const M_LANES: usize = 4;
/// Column (N) vector lanes — wide for SNN batch parallelism; decode
/// workloads with N=8 leave 56 of 64 lanes idle (§V-C).
pub const N_LANES: usize = 64;
pub const FREQ_HZ: f64 = 500e6;
/// Scheduler pipeline efficiency (detection latency, tile barriers) —
/// calibrated so b1.58-3B prefill reproduces Table I's 375 GOP/s.
pub const ETA: f64 = 0.82;
/// Effective residual-work fraction of the full ProSparsity mechanism
/// (prefix/product chains, not just the subset reuse our
/// [`product_reuse_factor`] measures) on uniform ternary planes —
/// calibrated to Table I.  The measured subset-only factor is kept as a
/// lower bound diagnostic.
pub const RHO_EFF: f64 = 0.42;
/// Average chip power while running (PE array + buffers + clock), W.
pub const CHIP_ACTIVE_W: f64 = 1.0;
/// Chunk width over which product sparsity is detected (prosperity
/// processes K in 16-wide segments).
pub const DETECT_K: usize = 16;
/// M-tile the scheduler searches within.
pub const DETECT_M: usize = 256;

/// Measure the product-sparsity work reduction on uniform ternary
/// planes: returns (residual ops) / (naive nnz ops), in (0, 1].
///
/// Greedy ancestor search (Prosperity's ProSparsity unit): for each row
/// bitmask, pick the earlier row whose support is a subset with maximal
/// overlap; the row then costs |support \ ancestor| accumulations.
pub fn product_reuse_factor() -> f64 {
    static FACTOR: OnceLock<f64> = OnceLock::new();
    *FACTOR.get_or_init(|| {
        let mut rng = Rng::seed_from(0x9e37_79b9);
        let mut naive: u64 = 0;
        let mut residual: u64 = 0;
        for _trial in 0..8 {
            // one plane of a uniform ternary tile: P(bit=1) = 1/3
            let masks: Vec<u16> = (0..DETECT_M)
                .map(|_| {
                    let mut m = 0u16;
                    for b in 0..DETECT_K {
                        if rng.below(3) == 0 {
                            m |= 1 << b;
                        }
                    }
                    m
                })
                .collect();
            for (i, &mi) in masks.iter().enumerate() {
                let pop = mi.count_ones() as u64;
                naive += pop;
                let mut best: u64 = 0;
                for &mj in &masks[..i] {
                    if mj & !mi == 0 {
                        // subset: reuse its sum
                        best = best.max(mj.count_ones() as u64);
                    }
                }
                residual += pop - best + if best > 0 { 1 } else { 0 };
            }
        }
        (residual as f64 / naive as f64).clamp(0.05, 1.0)
    })
}

/// Simulate one ternary mpGEMM kernel on Prosperity (two-pass binary
/// planes with product sparsity).
pub fn simulate(g: Gemm, _n_model: usize) -> BaselineReport {
    let (m, k, n) = (g.m as f64, g.k as f64, g.n as f64);
    // nnz per plane ≈ K/3 per row; two planes
    let nnz_two_pass = 2.0 * m * k / 3.0;
    let residual_ops = nnz_two_pass * RHO_EFF + m; // + merge per row
    // PEs: M_LANES rows in flight × N_LANES vector lanes.  Each cycle
    // retires M_LANES residual ops across min(n, N_LANES) columns; the
    // column dimension iterates in ⌈n/N_LANES⌉ groups.
    let col_groups = (n / N_LANES as f64).ceil().max(1.0);
    let compute_cycles = residual_ops / (M_LANES as f64 * ETA) * col_groups;

    // DRAM: 2-bit ternary encoding (no base-3 packing), weights streamed
    // once per column group; detection metadata adds ~12.5 % traffic.
    let weight_bytes = m * k / 4.0 * col_groups * 1.125;
    let act_bytes = k * n;
    let out_bytes = m * n;
    let dram_bytes = weight_bytes + act_bytes + out_bytes;
    let dram_cycles = dram_bytes / (57.6e9 / FREQ_HZ);
    let cycles = compute_cycles.max(dram_cycles);
    let latency = cycles / FREQ_HZ;

    // Energy: accumulations + SRAM + DRAM + active chip power + the
    // dynamic scheduler.  §II: runtime shortcut scheduling = 32.3 % of
    // total power.
    let acc_ops = residual_ops * n;
    let e_acc = acc_ops * 0.10e-12; // 8-bit adds + psum regs
    let e_sram = acc_ops * 4.0e-12; // operand/psum buffer + detect metadata
    let e_dram = dram_bytes * 8.0 * DRAM_PJ_PER_BIT * 1e-12;
    let e_active = CHIP_ACTIVE_W * latency;
    let base = e_acc + e_sram + e_dram + e_active;
    // scheduler burns 32.3 % of *total* power: total = base / (1-0.323)
    let energy = base / (1.0 - 0.323);
    BaselineReport::from_cycles(cycles, FREQ_HZ, energy, g)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{Backend, ProsperityBackend, Workload};
    use crate::models::B158_3B;

    #[test]
    fn reuse_factor_is_meaningful() {
        let rho = product_reuse_factor();
        // uniform ternary 16-wide planes show partial but not total reuse
        assert!(rho > 0.3 && rho < 0.95, "rho {rho}");
    }

    #[test]
    fn table1_prefill_throughput() {
        let r = ProsperityBackend.run(&Workload::prefill(B158_3B));
        assert!(
            (r.throughput_gops - 375.0).abs() / 375.0 < 0.3,
            "{:.0} GOP/s vs Table I 375",
            r.throughput_gops
        );
    }

    #[test]
    fn decode_underutilizes_n_lanes() {
        // §V-C: Prosperity's decode throughput collapses (N=8 of 64 lanes)
        let pre = ProsperityBackend.run(&Workload::prefill(B158_3B));
        let dec = ProsperityBackend.run(&Workload::decode(B158_3B));
        let drop = pre.throughput_gops / dec.throughput_gops;
        assert!(drop > 4.0, "decode drop only {drop:.1}×");
    }

    #[test]
    fn scheduler_tax_present() {
        // energy must include the 32.3 % dynamic-scheduling share
        let g = Gemm::new(1024, 1024, 64);
        let with = simulate(g, 64).energy_j;
        let base = with * (1.0 - 0.323);
        assert!((with / base - 1.0 / 0.677).abs() < 1e-9);
    }
}
