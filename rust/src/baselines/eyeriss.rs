//! SpikingEyeriss baseline (§V-A): Eyeriss's 12×14 row-stationary PE
//! array (168 PEs, 500 MHz, 28 nm — Table I) executing ternary mpGEMM
//! bit-serially in two passes ('+1' plane, '−1' plane, then merge).
//!
//! Timing model: the array folds the output space over the PE grid.  Two
//! mappings are available and the better one is chosen per kernel (the
//! compiler would do the same):
//!
//! * `mn-grid` — 12 rows of M × 14 columns of N spatially, K temporal:
//!   `⌈M/12⌉·⌈N/14⌉·K` cycles per pass.
//! * `m-flat` — all 168 PEs on M, N temporal (the decode-friendly
//!   mapping): `⌈M/168⌉·K·N` cycles per pass.
//!
//! A dataflow efficiency factor `ETA` (0.5) accounts for the
//! row-stationary array's psum-forwarding and fold-edge losses when
//! running GEMM instead of conv — calibrated so b1.58-3B prefill lands at
//! Table I's 20.8 GOP/s (the paper publishes no per-baseline breakdown).

use super::BaselineReport;
use crate::analysis::Gemm;
use crate::energy::DRAM_PJ_PER_BIT;

pub const PES_ROWS: usize = 12;
pub const PES_COLS: usize = 14;
pub const FREQ_HZ: f64 = 500e6;
/// GEMM-on-RS dataflow efficiency (see module doc).
pub const ETA: f64 = 0.5;
/// Passes for ternary bit-serial execution (+1 plane, −1 plane).
pub const PASSES: u64 = 2;
/// Average active chip power (array clocks + GLB + NoC), W.
pub const CHIP_ACTIVE_W: f64 = 0.7;

/// Simulate one kernel; `_n_model` is the batch·seq the kernel came from
/// (unused — kept for interface symmetry with prosperity).
pub fn simulate(g: Gemm, _n_model: usize) -> BaselineReport {
    let (m, k, n) = (g.m as u64, g.k as u64, g.n as u64);
    // mapping 1: M×N over the grid, K temporal
    let folds_mn = m.div_ceil(PES_ROWS as u64) * n.div_ceil(PES_COLS as u64);
    let cyc_mn = folds_mn * k;
    // mapping 2: M over all PEs, N temporal
    let cyc_mflat = m.div_ceil((PES_ROWS * PES_COLS) as u64) * k * n;
    let cyc_pass = cyc_mn.min(cyc_mflat);
    // merge pass: subtract the two plane results
    let merge = (m * n).div_ceil((PES_ROWS * PES_COLS) as u64);
    let compute_cycles = (PASSES * cyc_pass + merge) as f64 / ETA;

    // DRAM: byte-per-weight storage (no compact ternary encoding in the
    // spiking baseline), weights re-streamed per output fold column;
    // activations loaded once per pass.
    let n_reloads = n.div_ceil(PES_COLS as u64).min(n); // per N-fold
    let weight_bytes = m * k * n_reloads.max(1);
    let act_bytes = k * n * PASSES;
    let out_bytes = m * n;
    let dram_bytes = weight_bytes + act_bytes + out_bytes;
    let dram_cycles = dram_bytes as f64 / (57.6e9 / FREQ_HZ); // 64 GB/s × 0.9

    let cycles = compute_cycles.max(dram_cycles);

    // Energy: DRAM + active chip power.  Eyeriss's array clocks, GLB and
    // NoC burn near-constant power regardless of useful work — at the
    // poor GEMM utilization above, wall-clock dominates energy (the
    // reason the paper's 32.4× prefill energy gap is even larger than
    // the 73.6× speedup would scale to).  0.7 W ≈ the original Eyeriss's
    // 278 mW @ 200 MHz scaled to 500 MHz/28 nm, plus DRAM background.
    let accs = (2.0 / 3.0) * g.naive_adds() as f64;
    let e_dram = dram_bytes as f64 * 8.0 * DRAM_PJ_PER_BIT * 1e-12;
    let e_mac = accs * 0.9e-12; // 16-bit MAC datapath, 28 nm
    let latency = cycles / FREQ_HZ;
    let e_active = (CHIP_ACTIVE_W + 0.15) * latency; // chip + DRAM bkgd
    let energy = e_dram + e_mac + e_active;
    BaselineReport::from_cycles(cycles, FREQ_HZ, energy, g)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{Backend, EyerissBackend, Workload};
    use crate::models::B158_3B;

    #[test]
    fn table1_prefill_throughput() {
        let r = EyerissBackend.run(&Workload::prefill(B158_3B));
        assert!(
            (r.throughput_gops - 20.8).abs() / 20.8 < 0.3,
            "{:.1} GOP/s vs Table I 20.8",
            r.throughput_gops
        );
    }

    #[test]
    fn decode_mapping_prefers_m_flat() {
        // with N=8 the mn-grid wastes 6/14 columns; m-flat must win
        let g = Gemm::new(3200, 3200, 8);
        let r = simulate(g, 8);
        // m-flat pass cycles = ceil(3200/168)·3200·8·2/η + merge
        let expect = (20u64 * 3200 * 8 * 2) as f64 / ETA;
        assert!((r.latency_s * FREQ_HZ - expect).abs() / expect < 0.2);
    }

    #[test]
    fn energy_scales_with_work() {
        let small = simulate(Gemm::new(512, 512, 64), 64);
        let big = simulate(Gemm::new(1024, 1024, 64), 64);
        assert!(big.energy_j > small.energy_j * 3.0);
    }
}
