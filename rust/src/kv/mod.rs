//! Paged KV-cache memory subsystem (S16) — the vLLM-style allocator
//! behind the serving scheduler's admission and eviction decisions.
//!
//! Platinum is a 0.96 mm² edge accelerator: on-chip SRAM and the single
//! DDR4 channel, not FLOPs, bound the achievable batch.  This module
//! gives the serving layer a real memory model instead of the PR 5
//! Σ(prompt+output) token counter:
//!
//! * **Fixed-size blocks** ([`BlockPool`]) — KV storage is carved into
//!   blocks of `block_tokens` tokens × `kv_bytes_per_token` (from
//!   [`crate::models::BitNetModel::kv_bytes_per_token`], the single
//!   source of truth).  Low block ids live in SRAM, the rest in DRAM;
//!   the pool allocates lowest-id-first so hot sequences fill SRAM
//!   before spilling.
//! * **Per-sequence block tables** ([`KvCache`]) — each admitted
//!   sequence maps its token positions onto a block list; decode
//!   appends grow the table one block at a time.
//! * **Copy-on-write prefix sharing** — a repeated system prompt is
//!   cached once; later sequences retain the cache's full blocks
//!   (refcount++, zero new blocks for the shared span) and only
//!   copy-on-write the partial tail block before appending private
//!   tokens.
//! * **Swap vs. recompute under pressure** ([`KvPolicy`]) — when decode
//!   needs blocks a full pool cannot supply, the scheduler preempts the
//!   most recently admitted sequence: `Swap` spills its private blocks
//!   over the DRAM channel (priced by the [`crate::sim::DramModel`]
//!   timing model, stalling the timeline) and restores them later;
//!   `Recompute` drops the blocks and re-prefills from scratch.
//! * **Deterministic by construction** — block ids come from a
//!   [`std::collections::BTreeSet`], sequence tables from `BTreeMap`s;
//!   one seed ⇒ byte-identical metrics JSON, extended to every decision
//!   this module adds (pinned in `tests/traffic_serving.rs`).
//!
//! Capacity knobs come from [`KvConfig`]: defaults are ample (serving
//! behaves exactly like the token-counter era), `KvConfig::from_env`
//! reads the `PLATINUM_KV_*` variables, and `serve-bench` exposes the
//! same knobs as flags.  Utilization, prefix-cache hit rate, CoW/swap
//! counters and DRAM row-buffer stats all land in the `kv` section of
//! the metrics JSON via [`KvStats`].

mod block;
mod cache;

pub use block::{BlockId, BlockPool};
pub use cache::{Admission, KvCache};

use crate::sim::{DramModelKind, DramStats};
use crate::util::json::{num, obj, s, Json};

/// What to do with a sequence's KV when the pool runs dry mid-decode.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum KvPolicy {
    /// Spill private blocks to swap space over the DRAM channel and
    /// restore them (priced, stalling the timeline) when room frees up.
    Swap,
    /// Drop the blocks and re-prefill the sequence from scratch later
    /// (prefix-cache hits still discount the re-prefill).
    #[default]
    Recompute,
}

impl KvPolicy {
    pub fn parse(text: &str) -> Option<KvPolicy> {
        match text.trim().to_ascii_lowercase().as_str() {
            "swap" => Some(KvPolicy::Swap),
            "recompute" | "drop" => Some(KvPolicy::Recompute),
            _ => None,
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            KvPolicy::Swap => "swap",
            KvPolicy::Recompute => "recompute",
        }
    }
}

/// Capacity model + policy knobs for the paged KV cache.
///
/// `Copy` so it can ride inside `SchedulerConfig`.  Defaults are
/// deliberately ample (512 KiB SRAM + 2 GiB DRAM): untuned runs never
/// hit the eviction path, preserving the PR 5 scheduler behavior.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct KvConfig {
    /// Tokens per block (vLLM's block_size; default 16).
    pub block_tokens: usize,
    /// On-chip SRAM carved out for KV, KiB.
    pub sram_kib: usize,
    /// DRAM budget for KV, MiB.
    pub dram_mib: usize,
    /// Pressure policy.
    pub policy: KvPolicy,
    /// Share repeated system prompts across sequences.
    pub prefix_cache: bool,
    /// DRAM timing model pricing swap traffic.
    pub dram_model: DramModelKind,
    /// DRAM channel peak bandwidth (bytes/s) for swap pricing.
    pub dram_bw: f64,
    /// Accelerator clock (Hz) for cycle → second conversion.
    pub freq_hz: f64,
}

impl Default for KvConfig {
    fn default() -> KvConfig {
        KvConfig {
            block_tokens: 16,
            sram_kib: 512,
            dram_mib: 2048,
            policy: KvPolicy::default(),
            prefix_cache: true,
            dram_model: DramModelKind::default(),
            dram_bw: 64e9,
            freq_hz: 500e6,
        }
    }
}

impl KvConfig {
    /// Defaults overridden by `PLATINUM_KV_BLOCK`, `PLATINUM_KV_SRAM_KB`,
    /// `PLATINUM_KV_DRAM_MB` and `PLATINUM_KV_POLICY`.  Unset keeps the
    /// default; a set-but-unparsable value is a hard startup error
    /// naming the variable and the offending value (`util::env`).
    pub fn from_env() -> anyhow::Result<KvConfig> {
        let mut cfg = KvConfig::default();
        if let Some(b) = crate::util::env::positive_usize("PLATINUM_KV_BLOCK")? {
            cfg.block_tokens = b;
        }
        if let Some(kib) = crate::util::env::positive_usize("PLATINUM_KV_SRAM_KB")? {
            cfg.sram_kib = kib;
        }
        if let Some(mib) = crate::util::env::positive_usize("PLATINUM_KV_DRAM_MB")? {
            cfg.dram_mib = mib;
        }
        if let Some(p) =
            crate::util::env::read("PLATINUM_KV_POLICY", "swap | recompute", KvPolicy::parse)?
        {
            cfg.policy = p;
        }
        Ok(cfg)
    }

    /// Total modelled KV capacity in bytes (SRAM + DRAM budgets).
    pub fn capacity_bytes(&self) -> u64 {
        self.sram_kib as u64 * 1024 + self.dram_mib as u64 * 1024 * 1024
    }
}

/// Counters and gauges the cache accumulates for the metrics JSON.
///
/// The pressure *policy* is deliberately not serialized: with ample
/// capacity, swap and recompute runs take identical decisions and must
/// stay byte-identical (pinned in `tests/traffic_serving.rs`).
#[derive(Debug, Clone, Default)]
pub struct KvStats {
    // config echo (set at construction)
    pub block_tokens: u64,
    pub block_bytes: u64,
    pub bytes_per_token: u64,
    pub capacity_blocks: u64,
    pub sram_blocks: u64,
    // occupancy gauges
    pub allocated_max: u64,
    pub allocated_final: u64,
    pub sram_max: u64,
    pub overflow_max: u64,
    // prefix cache
    pub prefix_lookups: u64,
    pub prefix_hits: u64,
    pub prefix_tokens_saved: u64,
    pub prefix_evictions: u64,
    pub cow_copies: u64,
    // pressure
    pub evictions: u64,
    pub swap_outs: u64,
    pub swap_ins: u64,
    pub swapped_out_bytes: u64,
    pub swapped_in_bytes: u64,
    pub swap_stall_s: f64,
    pub recomputed_tokens: u64,
    // accounting-leak detectors (release builds report instead of
    // silently saturating; all-zero on a clean run and then absent from
    // the JSON, preserving byte-identity)
    pub token_release_underflows: u64,
    pub leaked_blocks: u64,
    pub leaked_seqs: u64,
    pub leaked_inflight_tokens: u64,
    // DRAM timing model behind the swap path
    pub dram_model: &'static str,
    pub dram: DramStats,
}

impl KvStats {
    /// Peak block utilization of the modelled capacity (can exceed 1.0
    /// when the single-sequence overflow escape hatch fired).
    pub fn utilization(&self) -> f64 {
        if self.capacity_blocks == 0 {
            0.0
        } else {
            self.allocated_max as f64 / self.capacity_blocks as f64
        }
    }

    /// Prefix-cache hit rate over admissions that carried a shared
    /// prefix (`None` when no lookups happened).
    pub fn prefix_hit_rate(&self) -> Option<f64> {
        if self.prefix_lookups == 0 {
            None
        } else {
            Some(self.prefix_hits as f64 / self.prefix_lookups as f64)
        }
    }

    /// Whether any accounting leak fired (in-flight token release
    /// underflow, blocks or sequence tables alive past drain).
    pub fn leaked(&self) -> bool {
        self.token_release_underflows
            + self.leaked_blocks
            + self.leaked_seqs
            + self.leaked_inflight_tokens
            > 0
    }

    /// The `kv` section of the metrics JSON.
    pub fn to_json(&self) -> Json {
        let rate = |r: Option<f64>| r.map(num).unwrap_or(Json::Null);
        let mut fields = vec![
            ("block_tokens", num(self.block_tokens as f64)),
            ("block_bytes", num(self.block_bytes as f64)),
            ("bytes_per_token", num(self.bytes_per_token as f64)),
            ("capacity_blocks", num(self.capacity_blocks as f64)),
            ("sram_blocks", num(self.sram_blocks as f64)),
            ("allocated_blocks_max", num(self.allocated_max as f64)),
            ("allocated_blocks_final", num(self.allocated_final as f64)),
            ("sram_blocks_max", num(self.sram_max as f64)),
            ("overflow_blocks_max", num(self.overflow_max as f64)),
            ("utilization", num(self.utilization())),
            (
                "prefix_cache",
                obj(vec![
                    ("lookups", num(self.prefix_lookups as f64)),
                    ("hits", num(self.prefix_hits as f64)),
                    ("hit_rate", rate(self.prefix_hit_rate())),
                    ("tokens_saved", num(self.prefix_tokens_saved as f64)),
                    ("evictions", num(self.prefix_evictions as f64)),
                ]),
            ),
            ("cow_copies", num(self.cow_copies as f64)),
            ("evictions", num(self.evictions as f64)),
            (
                "swap",
                obj(vec![
                    ("outs", num(self.swap_outs as f64)),
                    ("ins", num(self.swap_ins as f64)),
                    ("out_bytes", num(self.swapped_out_bytes as f64)),
                    ("in_bytes", num(self.swapped_in_bytes as f64)),
                    ("stall_s", num(self.swap_stall_s)),
                ]),
            ),
            ("recomputed_tokens", num(self.recomputed_tokens as f64)),
        ];
        // Leak detectors are exceptional-state reporting: the key only
        // appears when something actually leaked, so clean runs stay
        // byte-identical to the pre-detector era.
        if self.leaked() {
            fields.push((
                "leaks",
                obj(vec![
                    ("token_release_underflows", num(self.token_release_underflows as f64)),
                    ("blocks", num(self.leaked_blocks as f64)),
                    ("seqs", num(self.leaked_seqs as f64)),
                    ("inflight_tokens", num(self.leaked_inflight_tokens as f64)),
                ]),
            ));
        }
        fields.push((
            "dram",
            obj(vec![
                ("model", s(self.dram_model)),
                ("bursts", num(self.dram.bursts as f64)),
                ("row_hits", num(self.dram.row_hits as f64)),
                ("row_misses", num(self.dram.row_misses as f64)),
                ("row_conflicts", num(self.dram.row_conflicts as f64)),
                ("row_hit_rate", rate(self.dram.hit_rate())),
            ]),
        ));
        obj(fields)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn policy_parses() {
        assert_eq!(KvPolicy::parse("swap"), Some(KvPolicy::Swap));
        assert_eq!(KvPolicy::parse(" Recompute "), Some(KvPolicy::Recompute));
        assert_eq!(KvPolicy::parse("drop"), Some(KvPolicy::Recompute));
        assert_eq!(KvPolicy::parse("evict"), None);
        assert_eq!(KvPolicy::Swap.label(), "swap");
    }

    #[test]
    fn defaults_are_ample() {
        let cfg = KvConfig::default();
        assert_eq!(cfg.block_tokens, 16);
        assert!(cfg.prefix_cache);
        // ≥ 2 GiB of modelled KV: the untuned scheduler never evicts
        assert!(cfg.capacity_bytes() > 2 * 1024 * 1024 * 1024);
    }

    #[test]
    fn from_env_overrides_and_rejects_junk_loudly() {
        // narrow set → read → remove windows (PR 5 pattern)
        std::env::set_var("PLATINUM_KV_BLOCK", "8");
        std::env::set_var("PLATINUM_KV_POLICY", "swap");
        let cfg = KvConfig::from_env();
        std::env::remove_var("PLATINUM_KV_BLOCK");
        std::env::remove_var("PLATINUM_KV_POLICY");
        let cfg = cfg.unwrap();
        assert_eq!(cfg.block_tokens, 8);
        assert_eq!(cfg.policy, KvPolicy::Swap);
        // an unparsable knob is a startup error naming variable + value,
        // never a silent fallback to the default
        std::env::set_var("PLATINUM_KV_SRAM_KB", "zero");
        let err = KvConfig::from_env();
        std::env::remove_var("PLATINUM_KV_SRAM_KB");
        let msg = err.unwrap_err().to_string();
        assert!(msg.contains("PLATINUM_KV_SRAM_KB") && msg.contains("zero"), "{msg}");
    }

    #[test]
    fn leak_detectors_surface_only_when_something_leaked() {
        let clean = KvStats { dram_model: "bank", ..KvStats::default() };
        assert!(!clean.leaked());
        assert!(clean.to_json().get("leaks").is_none(), "clean runs emit no leaks key");
        let leaky = KvStats { leaked_blocks: 3, token_release_underflows: 1, ..clean };
        assert!(leaky.leaked());
        let j = leaky.to_json();
        assert_eq!(j.get("leaks").unwrap().get("blocks").unwrap().as_f64(), Some(3.0));
        let text = j.to_string();
        assert_eq!(Json::parse(&text).unwrap().to_string(), text);
    }

    #[test]
    fn stats_json_has_the_advertised_sections() {
        let st = KvStats {
            capacity_blocks: 100,
            allocated_max: 25,
            dram_model: "bank",
            ..KvStats::default()
        };
        let j = st.to_json();
        assert_eq!(j.get("utilization").unwrap().as_f64(), Some(0.25));
        assert_eq!(j.get("prefix_cache").unwrap().get("hit_rate"), Some(&Json::Null));
        assert_eq!(j.get("dram").unwrap().get("model").unwrap().as_str(), Some("bank"));
        // round-trips through the parser
        let text = j.to_string();
        assert_eq!(Json::parse(&text).unwrap().to_string(), text);
    }
}
