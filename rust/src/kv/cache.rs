//! Per-sequence block tables, prefix caching and preemption.
//!
//! [`KvCache`] owns the [`BlockPool`] and exposes the four verbs the
//! scheduler needs:
//!
//! * `try_admit` — all-or-nothing block reservation for a prompt.  A
//!   prefix-cache hit retains the cache's full blocks (zero new blocks
//!   for the shared span) and reports how many prompt tokens the
//!   prefill can skip; a partial tail block is copy-on-write copied so
//!   appends never touch shared storage.
//! * `append` — one decode token; allocates a block when the tail
//!   fills.
//! * `preempt_swap` / `preempt_recompute` — evict a sequence under
//!   pressure, either spilling private blocks (shared prefix blocks
//!   stay pinned — they are other sequences' storage too) or dropping
//!   everything for a later re-prefill.
//! * `release` — a finished sequence returns every reference.
//!
//! Accounting model, not a data store: blocks carry no payload.  What
//! is tracked — refcounts, residency, traffic volumes — is exactly what
//! the timing and capacity models need.  All bookkeeping is
//! `BTreeMap`/`BTreeSet`-backed, so iteration order (and therefore the
//! serving timeline) is deterministic.

use super::block::{BlockId, BlockPool};
use super::{KvConfig, KvStats};
use crate::sim::DramModel;
use anyhow::{bail, Result};
use std::collections::BTreeMap;

/// Outcome of a successful admission.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Admission {
    /// Prompt tokens the prefill can skip (prefix-cache hit span).
    pub cached_tokens: usize,
    /// Blocks newly allocated for this sequence (shared retains excluded).
    pub new_blocks: usize,
}

#[derive(Debug, Clone)]
struct SeqTable {
    /// Block per token-slot, in position order.
    blocks: Vec<BlockId>,
    /// Tokens currently stored.
    tokens: usize,
    /// Leading blocks shared with the prefix cache (never written).
    shared: usize,
}

#[derive(Debug, Clone)]
struct SwappedSeq {
    tokens: usize,
    /// Shared prefix blocks stay retained while swapped out — they are
    /// other sequences' live storage and cost nothing to keep mapped.
    shared_blocks: Vec<BlockId>,
    /// Private residency to restore (and re-read over DRAM) on swap-in.
    private_blocks: usize,
}

/// Admission shape for one prompt (pure function of cache state).
#[derive(Debug, Clone, Copy)]
struct AdmitPlan {
    /// Clamped shared-prefix span (0 = no sharing possible).
    s: usize,
    cached: usize,
    hit: bool,
    /// First admission carrying this prefix: build the cache entry.
    populate: bool,
    /// Cache-held blocks covering the prefix (populate path).
    prefix_blocks: usize,
    /// Leading seq slots that retain cache blocks instead of allocating.
    shared_full: usize,
    /// Private slots to allocate (includes the CoW tail slot).
    private: usize,
    /// Total fresh allocations (private + cache blocks when populating).
    new_blocks: usize,
    /// Partial tail block must be copy-on-write copied.
    cow: bool,
}

#[derive(Debug, Clone)]
pub struct KvCache {
    block_tokens: usize,
    block_bytes: u64,
    pool: BlockPool,
    prefix_enabled: bool,
    /// Cache-held references covering `prefix_tokens` of system prompt.
    prefix_blocks: Vec<BlockId>,
    prefix_tokens: usize,
    tables: BTreeMap<u64, SeqTable>,
    swapped: BTreeMap<u64, SwappedSeq>,
    stats: KvStats,
}

impl KvCache {
    pub fn new(cfg: &KvConfig, bytes_per_token: u64) -> Result<KvCache> {
        if cfg.block_tokens == 0 {
            bail!("kv block size must be ≥ 1 token");
        }
        if bytes_per_token == 0 {
            bail!("kv bytes/token must be ≥ 1");
        }
        let block_bytes = cfg.block_tokens as u64 * bytes_per_token;
        let capacity = (cfg.capacity_bytes() / block_bytes) as usize;
        if capacity == 0 {
            bail!(
                "kv capacity {} B holds no {} B block — raise \
                 --kv-sram-kb/--kv-dram-mb or shrink --kv-block",
                cfg.capacity_bytes(),
                block_bytes
            );
        }
        let sram_blocks = (cfg.sram_kib as u64 * 1024 / block_bytes) as usize;
        let stats = KvStats {
            block_tokens: cfg.block_tokens as u64,
            block_bytes,
            bytes_per_token,
            capacity_blocks: capacity as u64,
            sram_blocks: sram_blocks.min(capacity) as u64,
            ..KvStats::default()
        };
        Ok(KvCache {
            block_tokens: cfg.block_tokens,
            block_bytes,
            pool: BlockPool::new(capacity, sram_blocks),
            prefix_enabled: cfg.prefix_cache,
            prefix_blocks: Vec::new(),
            prefix_tokens: 0,
            tables: BTreeMap::new(),
            swapped: BTreeMap::new(),
            stats,
        })
    }

    pub fn block_tokens(&self) -> usize {
        self.block_tokens
    }

    pub fn block_bytes(&self) -> u64 {
        self.block_bytes
    }

    pub fn capacity_blocks(&self) -> usize {
        self.pool.capacity()
    }

    pub fn available_blocks(&self) -> usize {
        self.pool.available()
    }

    pub fn live_seqs(&self) -> usize {
        self.tables.len()
    }

    pub fn swapped_seqs(&self) -> usize {
        self.swapped.len()
    }

    pub fn stats(&self) -> &KvStats {
        &self.stats
    }

    /// True when no sequence state remains (only the prefix cache may
    /// still hold blocks) — the end-of-run invariant.
    pub fn is_quiescent(&self) -> bool {
        self.tables.is_empty()
            && self.swapped.is_empty()
            && self.pool.allocated() == self.prefix_blocks.len()
    }

    /// Accounting-leak counts at drain: blocks still allocated beyond
    /// the prefix cache, and sequence state (live tables + swapped)
    /// that survived the drain.  Both zero on a clean run; the
    /// scheduler surfaces nonzero values through `KvStats` so release
    /// builds report leaks instead of a `debug_assert` silently
    /// compiling out.
    pub fn leak_counts(&self) -> (u64, u64) {
        let blocks = self.pool.allocated().saturating_sub(self.prefix_blocks.len()) as u64;
        let seqs = (self.tables.len() + self.swapped.len()) as u64;
        (blocks, seqs)
    }

    /// Prompt tokens an admission would skip right now (non-mutating;
    /// the scheduler prices prefill on computed = prompt − cached).
    pub fn cached_tokens(&self, prompt_tokens: usize, shared_prefix: usize) -> usize {
        self.plan(prompt_tokens, shared_prefix).cached
    }

    fn plan(&self, prompt: usize, shared_prefix: usize) -> AdmitPlan {
        let b = self.block_tokens;
        let total_slots = prompt.div_ceil(b).max(1);
        let fully_private = AdmitPlan {
            s: 0,
            cached: 0,
            hit: false,
            populate: false,
            prefix_blocks: 0,
            shared_full: 0,
            private: total_slots,
            new_blocks: total_slots,
            cow: false,
        };
        // always compute ≥ 1 token so decode has a starting position
        let s = shared_prefix.min(prompt.saturating_sub(1));
        if !self.prefix_enabled || s == 0 {
            return fully_private;
        }
        let hit = self.prefix_tokens == s;
        let populate = !hit && self.prefix_tokens == 0;
        if !hit && !populate {
            // cache holds a *different* prefix (single-system-prompt
            // scope): count the lookup, share nothing
            return AdmitPlan { s, ..fully_private };
        }
        let shared_full = s / b;
        let private = total_slots - shared_full;
        let prefix_blocks = if populate { s.div_ceil(b) } else { 0 };
        AdmitPlan {
            s,
            cached: if hit { s } else { 0 },
            hit,
            populate,
            prefix_blocks,
            shared_full,
            private,
            new_blocks: private + prefix_blocks,
            cow: s % b != 0,
        }
    }

    fn alloc_block(&mut self, allow_overflow: bool) -> BlockId {
        match self.pool.alloc() {
            Some(id) => id,
            None => {
                debug_assert!(allow_overflow, "allocation past a failed admission check");
                self.pool.alloc_overflow()
            }
        }
    }

    fn note_usage(&mut self) {
        self.stats.allocated_max = self.stats.allocated_max.max(self.pool.allocated() as u64);
        self.stats.sram_max = self.stats.sram_max.max(self.pool.sram_in_use() as u64);
        self.stats.overflow_max = self.stats.overflow_max.max(self.pool.overflow() as u64);
    }

    /// All-or-nothing block reservation for a new sequence.  `None`
    /// when the pool cannot supply the plan and `allow_overflow` is
    /// off (the caller keeps the request queued — block backpressure).
    pub fn try_admit(
        &mut self,
        id: u64,
        prompt_tokens: usize,
        shared_prefix: usize,
        allow_overflow: bool,
    ) -> Option<Admission> {
        debug_assert!(prompt_tokens > 0, "empty prompt");
        debug_assert!(!self.tables.contains_key(&id), "seq {id} admitted twice");
        debug_assert!(!self.swapped.contains_key(&id), "seq {id} is swapped out");
        let plan = self.plan(prompt_tokens, shared_prefix);
        if !allow_overflow && plan.new_blocks > self.pool.available() {
            return None;
        }
        if plan.s > 0 {
            self.stats.prefix_lookups += 1;
            if plan.hit {
                self.stats.prefix_hits += 1;
                self.stats.prefix_tokens_saved += plan.cached as u64;
            }
        }
        if plan.populate {
            // the cache itself holds one reference per prefix block;
            // this sequence computes the tokens that fill them
            let blocks: Vec<BlockId> =
                (0..plan.prefix_blocks).map(|_| self.alloc_block(allow_overflow)).collect();
            self.prefix_blocks = blocks;
            self.prefix_tokens = plan.s;
        }
        let mut blocks = Vec::with_capacity(plan.shared_full + plan.private);
        for i in 0..plan.shared_full {
            let b = self.prefix_blocks[i];
            self.pool.retain(b);
            blocks.push(b);
        }
        if plan.cow {
            self.stats.cow_copies += 1;
        }
        for _ in 0..plan.private {
            let b = self.alloc_block(allow_overflow);
            blocks.push(b);
        }
        self.tables.insert(
            id,
            SeqTable { blocks, tokens: prompt_tokens, shared: plan.shared_full },
        );
        self.note_usage();
        Some(Admission { cached_tokens: plan.cached, new_blocks: plan.new_blocks })
    }

    /// Blocks the next decode token of `id` will allocate (0 or 1).
    pub fn append_blocks_needed(&self, id: u64) -> usize {
        let t = self.tables.get(&id).expect("append_blocks_needed on unknown seq");
        usize::from(t.tokens == t.blocks.len() * self.block_tokens)
    }

    /// Store one decode token.  `false` when a block is needed but the
    /// pool is dry and overflow is not allowed (caller must preempt).
    pub fn append(&mut self, id: u64, allow_overflow: bool) -> bool {
        let need = self.append_blocks_needed(id);
        if need > 0 && !allow_overflow && self.pool.available() == 0 {
            return false;
        }
        let fresh = if need > 0 { Some(self.alloc_block(allow_overflow)) } else { None };
        let t = self.tables.get_mut(&id).expect("append on unknown seq");
        if let Some(b) = fresh {
            t.blocks.push(b);
        } else {
            // the tail block is writable only if this seq owns it
            debug_assert!(t.blocks.len() > t.shared, "append into a shared block");
        }
        t.tokens += 1;
        self.note_usage();
        need == 0 || fresh.is_some()
    }

    /// A finished sequence returns every reference.  Double release is
    /// loud in debug builds, a no-op in release.
    pub fn release(&mut self, id: u64) {
        let Some(t) = self.tables.remove(&id) else {
            debug_assert!(false, "double release of seq {id}");
            return;
        };
        for b in t.blocks {
            self.pool.release(b);
        }
        self.note_usage();
    }

    /// Swap-out preemption: spill private blocks (returned for DRAM
    /// write pricing), keep shared prefix blocks retained.
    pub fn preempt_swap(&mut self, id: u64) -> Vec<BlockId> {
        let Some(t) = self.tables.remove(&id) else {
            debug_assert!(false, "preempt of unknown seq {id}");
            return Vec::new();
        };
        let shared_blocks = t.blocks[..t.shared].to_vec();
        let private = t.blocks[t.shared..].to_vec();
        for &b in &private {
            self.pool.release(b);
        }
        self.stats.evictions += 1;
        self.stats.swap_outs += 1;
        self.stats.swapped_out_bytes += private.len() as u64 * self.block_bytes;
        self.swapped.insert(
            id,
            SwappedSeq { tokens: t.tokens, shared_blocks, private_blocks: private.len() },
        );
        self.note_usage();
        private
    }

    /// Restore a swapped sequence; returns the freshly allocated block
    /// ids (for DRAM read pricing), or `None` when blocks are short and
    /// overflow is not allowed.
    pub fn resume_swapped(&mut self, id: u64, allow_overflow: bool) -> Option<Vec<BlockId>> {
        let need = self.swapped.get(&id).expect("resume of unknown seq").private_blocks;
        if !allow_overflow && need > self.pool.available() {
            return None;
        }
        let sw = self.swapped.remove(&id).unwrap();
        let fresh: Vec<BlockId> = (0..need).map(|_| self.alloc_block(allow_overflow)).collect();
        let mut blocks = sw.shared_blocks;
        let shared = blocks.len();
        blocks.extend_from_slice(&fresh);
        self.stats.swap_ins += 1;
        self.stats.swapped_in_bytes += need as u64 * self.block_bytes;
        self.tables.insert(id, SeqTable { blocks, tokens: sw.tokens, shared });
        self.note_usage();
        Some(fresh)
    }

    /// Terminal release of a swapped-out sequence (deadline kill): drop
    /// the retained shared-prefix references without paying to swap the
    /// private blocks back in first.
    pub fn release_swapped(&mut self, id: u64) {
        let Some(sw) = self.swapped.remove(&id) else {
            debug_assert!(false, "release_swapped of unknown seq {id}");
            return;
        };
        for b in sw.shared_blocks {
            self.pool.release(b);
        }
        self.note_usage();
    }

    /// Recompute preemption: drop everything; the sequence re-prefills
    /// later (prefix hits still discount it).  Counts the resident
    /// tokens whose KV must be recomputed.
    pub fn preempt_recompute(&mut self, id: u64) {
        let Some(t) = self.tables.remove(&id) else {
            debug_assert!(false, "preempt of unknown seq {id}");
            return;
        };
        self.stats.recomputed_tokens += t.tokens as u64;
        for b in t.blocks {
            self.pool.release(b);
        }
        self.stats.evictions += 1;
        self.note_usage();
    }

    /// Drop the cache's own prefix references when no sequence shares
    /// them (last-resort reclaim under pressure).  Returns blocks freed.
    pub fn reclaim_prefix(&mut self) -> usize {
        if self.prefix_blocks.is_empty()
            || self.prefix_blocks.iter().any(|&b| self.pool.refcount(b) > 1)
        {
            return 0;
        }
        let blocks = std::mem::take(&mut self.prefix_blocks);
        let n = blocks.len();
        for b in blocks {
            self.pool.release(b);
        }
        self.prefix_tokens = 0;
        self.stats.prefix_evictions += 1;
        self.note_usage();
        n
    }

    /// Accumulate timeline stall charged to swap traffic.
    pub fn note_swap_stall(&mut self, dt: f64) {
        self.stats.swap_stall_s += dt;
    }

    /// Final stats for the metrics JSON, annotated with the DRAM timing
    /// model that priced the swap traffic.
    pub fn snapshot(&self, dram: &dyn DramModel) -> KvStats {
        let mut st = self.stats.clone();
        st.allocated_final = self.pool.allocated() as u64;
        st.dram_model = dram.label();
        st.dram = dram.row_buffer().unwrap_or_default();
        st
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::DramModelKind;

    /// 4-token blocks at 64 B/token → 256 B blocks, SRAM-only budget
    /// (1 KiB = 4 blocks unless `cache()` resizes it).
    fn tiny_cfg() -> KvConfig {
        KvConfig { block_tokens: 4, sram_kib: 1, dram_mib: 0, ..KvConfig::default() }
    }

    fn cache(total_blocks: usize) -> KvCache {
        // size SRAM to exactly `total_blocks` 256 B blocks
        let cfg = KvConfig {
            sram_kib: total_blocks * 256 / 1024 + usize::from(total_blocks * 256 % 1024 != 0),
            ..tiny_cfg()
        };
        let kv = KvCache::new(&cfg, 64).unwrap();
        assert!(kv.capacity_blocks() >= total_blocks);
        kv
    }

    #[test]
    fn capacity_is_sized_from_bytes_per_token() {
        let cfg = KvConfig { block_tokens: 16, sram_kib: 512, dram_mib: 2, ..KvConfig::default() };
        // TINY-model bytes/token: 2 × 4 kv_heads × 16 head_dim × 2 layers = 256
        let kv = KvCache::new(&cfg, 256).unwrap();
        assert_eq!(kv.block_bytes(), 4096);
        assert_eq!(kv.capacity_blocks(), (512 * 1024 + 2 * 1024 * 1024) / 4096);
        assert_eq!(kv.stats().sram_blocks, 128);
        // a zero-capacity config is a loud error, not a silent hang
        let bad = KvConfig { block_tokens: 64, sram_kib: 1, dram_mib: 0, ..KvConfig::default() };
        assert!(KvCache::new(&bad, 1 << 20).is_err());
    }

    #[test]
    fn repeated_system_prompt_costs_zero_new_blocks_for_the_shared_span() {
        let mut kv = cache(64);
        // prompt = 8 shared + 2 unique, block = 4 → slots [S S P]
        let first = kv.try_admit(1, 10, 8, false).unwrap();
        assert_eq!(first.cached_tokens, 0, "first sighting computes everything");
        assert_eq!(first.new_blocks, 2 + 1, "2 cache blocks + 1 private");
        let second = kv.try_admit(2, 10, 8, false).unwrap();
        assert_eq!(second.cached_tokens, 8, "full shared span skipped");
        assert_eq!(second.new_blocks, 1, "only the private tail allocates");
        let st = kv.stats();
        assert_eq!(st.prefix_lookups, 2);
        assert_eq!(st.prefix_hits, 1);
        assert_eq!(st.prefix_tokens_saved, 8);
        assert_eq!(st.cow_copies, 0, "aligned prefix needs no CoW");
        // both finish: only the cache's own blocks remain
        kv.release(1);
        kv.release(2);
        assert!(kv.is_quiescent());
        assert_eq!(kv.reclaim_prefix(), 2);
        assert_eq!(kv.available_blocks(), kv.capacity_blocks());
    }

    #[test]
    fn unaligned_prefix_copies_the_tail_block_on_write() {
        let mut kv = cache(64);
        // s = 6 (1 full block + 2 tokens), prompt = 9 → slots [S C P]
        let a = kv.try_admit(1, 9, 6, false).unwrap();
        assert_eq!(a.new_blocks, 2 + 2, "cache 2 + private (CoW tail + 1)");
        let b = kv.try_admit(2, 9, 6, false).unwrap();
        assert_eq!(b.cached_tokens, 6);
        assert_eq!(b.new_blocks, 2, "CoW tail + private tail");
        assert_eq!(kv.stats().cow_copies, 2);
        // appends land in private storage, never the shared block
        for _ in 0..8 {
            assert!(kv.append(1, false));
        }
        assert_eq!(kv.stats().allocated_max, 4 + 2 + 2);
    }

    #[test]
    fn admission_respects_block_backpressure_and_overflow_escapes() {
        let mut kv = cache(4);
        let cap = kv.capacity_blocks();
        assert!(kv.try_admit(1, 4 * cap, 0, false).is_some(), "exactly fits");
        assert!(kv.try_admit(2, 4, 0, false).is_none(), "pool is full");
        assert_eq!(kv.stats().overflow_max, 0);
        let adm = kv.try_admit(2, 8, 0, true).unwrap();
        assert_eq!(adm.new_blocks, 2);
        assert!(kv.stats().overflow_max >= 2, "escape hatch is accounted");
        kv.release(1);
        kv.release(2);
        assert!(kv.is_quiescent());
    }

    #[test]
    fn swap_keeps_shared_blocks_pinned_and_restores_residency() {
        let mut kv = cache(64);
        kv.try_admit(1, 10, 8, false).unwrap();
        kv.try_admit(2, 10, 8, false).unwrap();
        let before = kv.stats().allocated_max;
        let spilled = kv.preempt_swap(2);
        assert_eq!(spilled.len(), 1, "only the private tail spills");
        assert_eq!(kv.swapped_seqs(), 1);
        assert_eq!(kv.stats().swapped_out_bytes, 256);
        // seq 1 still decodes into its own storage
        assert!(kv.append(1, false));
        let fresh = kv.resume_swapped(2, false).unwrap();
        assert_eq!(fresh.len(), 1);
        assert_eq!(kv.stats().swap_ins, 1);
        assert!(kv.append(2, false), "restored seq keeps decoding");
        assert!(kv.stats().allocated_max >= before);
        kv.release(1);
        kv.release(2);
        assert!(kv.is_quiescent());
    }

    #[test]
    fn recompute_preemption_drops_everything_and_counts_waste() {
        let mut kv = cache(64);
        kv.try_admit(1, 10, 8, false).unwrap();
        for _ in 0..3 {
            kv.append(1, false);
        }
        kv.preempt_recompute(1);
        assert_eq!(kv.stats().evictions, 1);
        assert_eq!(kv.stats().recomputed_tokens, 13);
        assert!(kv.is_quiescent());
        // the prefix cache survives: a re-admission still hits
        let again = kv.try_admit(1, 10, 8, false).unwrap();
        assert_eq!(again.cached_tokens, 8);
    }

    #[test]
    fn prefix_reclaim_refuses_while_shared() {
        let mut kv = cache(64);
        kv.try_admit(1, 10, 8, false).unwrap();
        assert_eq!(kv.reclaim_prefix(), 0, "seq 1 shares the cache blocks");
        kv.release(1);
        assert_eq!(kv.reclaim_prefix(), 2);
        assert_eq!(kv.stats().prefix_evictions, 1);
        // cold again: next admission repopulates
        let adm = kv.try_admit(2, 10, 8, false).unwrap();
        assert_eq!(adm.cached_tokens, 0);
    }

    #[test]
    fn disabled_prefix_cache_shares_nothing() {
        let cfg = KvConfig { prefix_cache: false, ..tiny_cfg() };
        let mut kv = KvCache::new(&cfg, 64).unwrap();
        let a = kv.try_admit(1, 10, 8, false).unwrap();
        let b = kv.try_admit(2, 10, 8, false).unwrap();
        assert_eq!((a.cached_tokens, b.cached_tokens), (0, 0));
        assert_eq!(a.new_blocks, 3);
        assert_eq!(b.new_blocks, 3, "every admission pays full price");
        assert_eq!(kv.stats().prefix_lookups, 0);
    }

    #[test]
    fn snapshot_attaches_the_dram_model() {
        let kv = cache(8);
        let mut dram = DramModelKind::Bank.build(64e9, 500e6);
        dram.transfer_cycles_at(0, 4096);
        let st = kv.snapshot(dram.as_ref());
        assert_eq!(st.dram_model, "bank");
        assert_eq!(st.dram.bursts, 64);
        assert_eq!(st.allocated_final, 0);
    }
}
