//! Refcounted fixed-size block pool with an SRAM/DRAM tier split.
//!
//! Block ids are dense integers; ids below `sram_blocks` model the
//! on-chip KV carve-out, the rest the DRAM budget.  The free list is a
//! `BTreeSet`, so allocation always hands out the lowest free id —
//! deterministic, and SRAM fills before anything spills to DRAM.
//! Copy-on-write sharing is plain refcounting: a prefix-cache hit
//! retains a block, release only frees it when the last holder lets go.

use std::collections::BTreeSet;

/// Index of one KV block (dense, lowest-first allocation).
pub type BlockId = u32;

#[derive(Debug, Clone)]
pub struct BlockPool {
    /// Modelled capacity in blocks (SRAM + DRAM).
    capacity: usize,
    /// Ids below this line are SRAM-resident.
    sram_blocks: usize,
    /// Refcount per ever-created id (0 = free or never reused).
    refcount: Vec<u32>,
    /// Freed ids awaiting reuse (lowest first).
    free: BTreeSet<BlockId>,
    /// Blocks with refcount > 0.
    allocated: usize,
    /// Allocated blocks on the SRAM side of the line.
    sram_in_use: usize,
}

impl BlockPool {
    pub fn new(capacity: usize, sram_blocks: usize) -> BlockPool {
        assert!(capacity >= 1, "pool needs at least one block");
        BlockPool {
            capacity,
            sram_blocks: sram_blocks.min(capacity),
            refcount: Vec::new(),
            free: BTreeSet::new(),
            allocated: 0,
            sram_in_use: 0,
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn sram_blocks(&self) -> usize {
        self.sram_blocks
    }

    pub fn allocated(&self) -> usize {
        self.allocated
    }

    pub fn sram_in_use(&self) -> usize {
        self.sram_in_use
    }

    /// Blocks allocated past the modelled capacity (the single-sequence
    /// escape hatch; 0 in healthy operation).
    pub fn overflow(&self) -> usize {
        self.allocated.saturating_sub(self.capacity)
    }

    /// Blocks an `alloc` could hand out without overflowing.
    pub fn available(&self) -> usize {
        self.free.len() + self.capacity.saturating_sub(self.refcount.len())
    }

    pub fn is_empty(&self) -> bool {
        self.allocated == 0
    }

    pub fn refcount(&self, id: BlockId) -> u32 {
        self.refcount.get(id as usize).copied().unwrap_or(0)
    }

    fn take(&mut self, id: BlockId) {
        debug_assert_eq!(self.refcount[id as usize], 0, "allocating a live block {id}");
        self.refcount[id as usize] = 1;
        self.allocated += 1;
        if (id as usize) < self.sram_blocks {
            self.sram_in_use += 1;
        }
    }

    /// Allocate the lowest free block, `None` when the pool is full.
    pub fn alloc(&mut self) -> Option<BlockId> {
        let id = if let Some(id) = self.free.pop_first() {
            id
        } else if self.refcount.len() < self.capacity {
            self.refcount.push(0);
            (self.refcount.len() - 1) as BlockId
        } else {
            return None;
        };
        self.take(id);
        Some(id)
    }

    /// Allocate even past capacity (the scheduler's guarantee that a
    /// lone oversized sequence always terminates).  Prefers a regular
    /// allocation when one is possible.
    pub fn alloc_overflow(&mut self) -> BlockId {
        if let Some(id) = self.alloc() {
            return id;
        }
        self.refcount.push(0);
        let id = (self.refcount.len() - 1) as BlockId;
        self.take(id);
        id
    }

    /// Add a sharer (prefix-cache hit / CoW parent).
    pub fn retain(&mut self, id: BlockId) {
        debug_assert!(self.refcount(id) > 0, "retain on free block {id}");
        if let Some(rc) = self.refcount.get_mut(id as usize) {
            *rc += 1;
        }
    }

    /// Drop one reference; returns `true` when the block became free.
    /// Saturating: a double release is a loud `debug_assert` in debug
    /// builds and a no-op (never corrupting the free list) in release.
    pub fn release(&mut self, id: BlockId) -> bool {
        debug_assert!(self.refcount(id) > 0, "double release of block {id}");
        let Some(rc) = self.refcount.get_mut(id as usize) else {
            return false;
        };
        if *rc == 0 {
            return false;
        }
        *rc -= 1;
        if *rc > 0 {
            return false;
        }
        self.allocated -= 1;
        if (id as usize) < self.sram_blocks {
            self.sram_in_use -= 1;
        }
        self.free.insert(id);
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_prefers_lowest_ids_sram_first() {
        let mut p = BlockPool::new(8, 2);
        let a = p.alloc().unwrap();
        let b = p.alloc().unwrap();
        let c = p.alloc().unwrap();
        assert_eq!((a, b, c), (0, 1, 2));
        assert_eq!(p.sram_in_use(), 2, "ids below the line fill SRAM first");
        p.release(a);
        assert_eq!(p.sram_in_use(), 1);
        // the freed SRAM block is reused before a fresh DRAM id
        assert_eq!(p.alloc().unwrap(), 0);
        assert_eq!(p.sram_in_use(), 2);
    }

    #[test]
    fn refcounted_sharing_frees_on_last_release() {
        let mut p = BlockPool::new(4, 0);
        let id = p.alloc().unwrap();
        p.retain(id);
        p.retain(id);
        assert_eq!(p.refcount(id), 3);
        assert!(!p.release(id));
        assert!(!p.release(id));
        assert_eq!(p.allocated(), 1);
        assert!(p.release(id), "last holder frees the block");
        assert!(p.is_empty());
        assert_eq!(p.available(), 4);
    }

    #[test]
    fn full_pool_rejects_then_overflow_escapes() {
        let mut p = BlockPool::new(2, 1);
        let a = p.alloc().unwrap();
        let _b = p.alloc().unwrap();
        assert_eq!(p.alloc(), None);
        assert_eq!(p.available(), 0);
        let c = p.alloc_overflow();
        assert_eq!(c, 2, "overflow extends past capacity");
        assert_eq!(p.overflow(), 1);
        // freeing a real block drains overflow accounting
        p.release(a);
        assert_eq!(p.overflow(), 0);
        assert_eq!(p.available(), 1);
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "double release")]
    fn double_release_is_loud_in_debug() {
        let mut p = BlockPool::new(2, 0);
        let id = p.alloc().unwrap();
        p.release(id);
        p.release(id);
    }

    #[test]
    fn release_of_free_block_is_saturating() {
        // the release-build contract: no free-list corruption
        let mut p = BlockPool::new(2, 0);
        let id = p.alloc().unwrap();
        assert!(p.release(id));
        if !cfg!(debug_assertions) {
            assert!(!p.release(id));
            assert_eq!(p.available(), 2);
            assert_eq!(p.alloc(), Some(id));
        }
    }
}
