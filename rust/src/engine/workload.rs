//! What a [`crate::engine::Backend`] executes: a single kernel, a full
//! model forward pass, or an ordered batch of kernels.
//!
//! The model-pass case absorbs what used to be scattered call-site logic
//! (`simulate_model`'s accumulation loop, `baselines::model_report`'s
//! closure dance): callers describe the workload once and every backend
//! aggregates it the same way inside the engine.

use crate::analysis::Gemm;
use crate::models::{BitNetModel, DECODE_N, PREFILL_N};

/// Inference stage label for a model pass (the paper's two operating
/// points: prefill N=1024, decode N=8).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Stage {
    Prefill,
    Decode,
}

impl Stage {
    pub fn label(&self) -> &'static str {
        match self {
            Stage::Prefill => "prefill",
            Stage::Decode => "decode",
        }
    }

    /// The paper's batch·seq product for this stage.
    pub fn default_n(&self) -> usize {
        match self {
            Stage::Prefill => PREFILL_N,
            Stage::Decode => DECODE_N,
        }
    }

    /// Classify an arbitrary batch·seq product (decode-shaped ⇔ the
    /// low-N regime where baselines underfill their lanes).
    pub fn from_n(n: usize) -> Stage {
        if n <= 16 {
            Stage::Decode
        } else {
            Stage::Prefill
        }
    }
}

/// One unit of work submitted to a backend.
#[derive(Debug, Clone)]
pub enum Workload {
    /// A single mpGEMM kernel dispatch.
    Kernel(Gemm),
    /// A full forward pass of a BitNet model at batch·seq = n.
    ModelPass { model: BitNetModel, n: usize, stage: Stage },
    /// An ordered sequence of kernels executed back-to-back (the serving
    /// coordinator prices a request batch this way).
    Batch(Vec<Gemm>),
    /// Kernels with occurrence counts — [`Workload::Batch`] without the
    /// expansion blowup.  This is what a row-sharded model pass becomes
    /// on each replica (`engine::Sharded` preserves the per-layer
    /// kernel counts instead of materializing hundreds of entries), and
    /// it keeps count-scaled aggregation (`latency × count`) instead of
    /// repeated addition, so shard reports stay bit-comparable with
    /// unsharded ones.
    Counted(Vec<(Gemm, usize)>),
}

impl Workload {
    /// Model pass at the paper's prefill operating point.
    pub fn prefill(model: BitNetModel) -> Workload {
        Workload::ModelPass { model, n: PREFILL_N, stage: Stage::Prefill }
    }

    /// Model pass at the paper's decode operating point.
    pub fn decode(model: BitNetModel) -> Workload {
        Workload::ModelPass { model, n: DECODE_N, stage: Stage::Decode }
    }

    /// Model pass at an arbitrary batch·seq product.
    pub fn model_pass(model: BitNetModel, n: usize) -> Workload {
        Workload::ModelPass { model, n, stage: Stage::from_n(n) }
    }

    /// One decode iteration of a continuous-batching scheduler: `seqs`
    /// running sequences each contribute exactly one token, so
    /// batch·seq = seqs — and the stage is **forced** to decode, since
    /// [`Stage::from_n`] would misclassify a batch wider than its
    /// threshold as prefill.
    pub fn decode_step(model: BitNetModel, seqs: usize) -> Workload {
        Workload::ModelPass { model, n: seqs.max(1), stage: Stage::Decode }
    }

    /// One coalesced prefill step over `tokens` total prompt tokens
    /// (possibly from several admitted requests batched together) —
    /// forced to the prefill stage even for short prompts.
    pub fn prefill_step(model: BitNetModel, tokens: usize) -> Workload {
        Workload::ModelPass { model, n: tokens.max(1), stage: Stage::Prefill }
    }

    /// Human/JSON label identifying the workload in a [`super::Report`].
    pub fn label(&self) -> String {
        match self {
            Workload::Kernel(g) => format!("gemm-{}x{}x{}", g.m, g.k, g.n),
            Workload::ModelPass { model, n, stage } => {
                format!("{}-{}-n{}", model.name, stage.label(), n)
            }
            Workload::Batch(gs) => format!("batch-{}", gs.len()),
            Workload::Counted(ps) => {
                format!("counted-{}", ps.iter().map(|(_, c)| c).sum::<usize>())
            }
        }
    }

    /// The constituent kernels with occurrence counts — the one place
    /// model-pass expansion happens for every backend.
    pub fn kernels(&self) -> Vec<(Gemm, usize)> {
        match self {
            Workload::Kernel(g) => vec![(*g, 1)],
            Workload::ModelPass { model, n, .. } => model.model_gemms(*n),
            Workload::Batch(gs) => gs.iter().map(|&g| (g, 1)).collect(),
            Workload::Counted(ps) => ps.clone(),
        }
    }

    /// Total naive additions (the paper's GOP/s normalization).
    pub fn naive_adds(&self) -> u64 {
        self.kernels().iter().map(|(g, c)| g.naive_adds() * *c as u64).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::B158_3B;

    #[test]
    fn labels_are_stable() {
        assert_eq!(Workload::Kernel(Gemm::new(2, 3, 4)).label(), "gemm-2x3x4");
        assert_eq!(Workload::prefill(B158_3B).label(), "b1.58-3B-prefill-n1024");
        assert_eq!(Workload::decode(B158_3B).label(), "b1.58-3B-decode-n8");
        assert_eq!(Workload::Batch(vec![Gemm::new(1, 1, 1)]).label(), "batch-1");
    }

    #[test]
    fn model_pass_ops_match_model_zoo() {
        let w = Workload::prefill(B158_3B);
        assert_eq!(w.naive_adds(), B158_3B.total_naive_adds(PREFILL_N));
    }

    #[test]
    fn counted_matches_expanded_batch() {
        let g1 = Gemm::new(4, 5, 6);
        let g2 = Gemm::new(7, 5, 6);
        let counted = Workload::Counted(vec![(g1, 3), (g2, 1)]);
        let batch = Workload::Batch(vec![g1, g1, g1, g2]);
        assert_eq!(counted.naive_adds(), batch.naive_adds());
        assert_eq!(counted.label(), "counted-4");
        assert_eq!(counted.kernels(), vec![(g1, 3), (g2, 1)]);
    }

    #[test]
    fn step_helpers_force_their_stage() {
        // a 64-wide decode batch would classify as prefill by n alone
        let d = Workload::decode_step(B158_3B, 64);
        assert_eq!(d.label(), "b1.58-3B-decode-n64");
        match d {
            Workload::ModelPass { n, stage, .. } => {
                assert_eq!((n, stage), (64, Stage::Decode));
            }
            other => panic!("decode_step must be a model pass, got {other:?}"),
        }
        // a 4-token chunked prefill would classify as decode by n alone
        let p = Workload::prefill_step(B158_3B, 4);
        assert_eq!(p.label(), "b1.58-3B-prefill-n4");
        // zero-token guards
        assert_eq!(Workload::decode_step(B158_3B, 0).naive_adds(), B158_3B.total_naive_adds(1));
        assert_eq!(Workload::prefill_step(B158_3B, 0).naive_adds(), B158_3B.total_naive_adds(1));
    }

    #[test]
    fn stage_classification() {
        assert_eq!(Stage::from_n(8), Stage::Decode);
        assert_eq!(Stage::from_n(1024), Stage::Prefill);
        assert_eq!(Stage::Prefill.default_n(), PREFILL_N);
        assert_eq!(Stage::Decode.default_n(), DECODE_N);
    }
}
