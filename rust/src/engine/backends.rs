//! [`Backend`](super::Backend) implementations for every system the
//! paper compares (§V-A): Platinum in both execution modes, the
//! SpikingEyeriss and Prosperity ASIC baselines, the analytical T-MAC
//! CPU model, and the real measured T-MAC CPU kernel.
//!
//! All backends share one aggregation routine ([`aggregate`]) for
//! multi-kernel workloads; its scalar arithmetic (latency, energy,
//! cycles, throughput, phases, activity) mirrors the legacy
//! `sim::simulate_model` / `baselines::model_report` accumulation
//! order exactly — those fields are pinned bit-identical by
//! `tests/engine_api.rs`.  One deliberate divergence: multi-kernel
//! `utilization.adders`/`dram_bw` are busy-/cycle-weighted averages
//! across kernels, whereas `simulate_model` carried the first kernel's
//! values through unchanged (the engine's number is the meaningful
//! one for a model pass).

use super::report::{BackendInfo, BackendKind, Report};
use super::workload::Workload;
use super::Backend;
use crate::analysis::Gemm;
use crate::baselines::{eyeriss, prosperity, tmac};
use crate::config::{ExecMode, PlatinumConfig};
use crate::encoding::pack_ternary;
use crate::energy::AreaModel;
use crate::lut::ternary_mpgemm_pool;
use crate::runtime::pool::{self, Pool};
use crate::sim::{simulate_gemm, Activity, DramChannel, EnergyBreakdown, PhaseCycles, Utilization};
use crate::util::rng::Rng;
use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::Instant;

/// Aggregate per-kernel reports into one workload report.
///
/// Scalar metrics accumulate in kernel order with per-kernel `count`
/// scaling — the exact float-op sequence of the legacy aggregators.
/// Detail sections (cycles/phases/activity/energy breakdown) survive
/// only when every kernel report carries them.
pub(crate) fn aggregate<F>(
    backend: &str,
    label: String,
    pairs: &[(Gemm, usize)],
    mut run: F,
) -> Report
where
    F: FnMut(Gemm) -> Report,
{
    let mut latency = 0.0f64;
    // energy aggregates only while every kernel models it; one
    // unmodelled kernel makes the workload's energy unmodelled (None)
    let mut energy_scalar = Some(0.0f64);
    let mut ops: u64 = 0;
    let mut detail = true;
    let mut cycles: u64 = 0;
    let mut phases = PhaseCycles::default();
    let mut activity = Activity::default();
    let mut energy = EnergyBreakdown::default();
    let mut adder_busy = 0.0f64;
    let mut dram_busy = 0.0f64;

    for &(g, count) in pairs {
        let r = run(g);
        let cf = count as f64;
        let cu = count as u64;
        latency += r.latency_s * cf;
        energy_scalar = match (energy_scalar, r.energy_j) {
            (Some(acc), Some(e)) => Some(acc + e * cf),
            _ => None,
        };
        ops += g.naive_adds() * cu;
        if detail {
            match (r.cycles, r.phases, r.activity, r.energy_breakdown) {
                (Some(c), Some(p), Some(a), Some(e)) => {
                    cycles += c * cu;
                    let mut p2 = p;
                    p2.scale(cu);
                    phases.add(&p2);
                    let mut a2 = a;
                    a2.scale(cu);
                    activity.add(&a2);
                    let mut e2 = e;
                    e2.scale(cf);
                    energy.add(&e2);
                    if let Some(u) = r.utilization {
                        adder_busy += u.adders * (p2.busy() as f64);
                        dram_busy += u.dram_bw * ((c * cu) as f64);
                    }
                }
                _ => detail = false,
            }
        }
    }

    let mut out = Report {
        backend: backend.to_string(),
        workload: label,
        latency_s: latency,
        energy_j: energy_scalar,
        throughput_gops: if latency > 0.0 { ops as f64 / latency / 1e9 } else { 0.0 },
        ops,
        ..Report::default()
    };
    if detail {
        // totalling the summed breakdown reproduces simulate_model's
        // energy exactly (components summed first, total last)
        out.energy_j = Some(energy.total());
        out.cycles = Some(cycles);
        out.phases = Some(phases);
        out.activity = Some(activity);
        out.energy_breakdown = Some(energy);
        let busy = phases.busy();
        out.utilization = Some(Utilization {
            adders: if busy > 0 { adder_busy / busy as f64 } else { 0.0 },
            lut_ports: if busy > 0 {
                (phases.construct + phases.query) as f64 / busy as f64
            } else {
                0.0
            },
            dram_bw: if cycles > 0 { dram_busy / cycles as f64 } else { 0.0 },
        });
    }
    out
}

/// Run a workload by mapping a per-kernel closure over its kernels.
fn run_workload<F>(backend: &str, w: &Workload, run: F) -> Report
where
    F: FnMut(Gemm) -> Report,
{
    aggregate(backend, w.label(), &w.kernels(), run)
}

// ---------------------------------------------------------------------------
// Platinum (cycle-accurate simulator, per ExecMode)
// ---------------------------------------------------------------------------

/// Cycle-accurate Platinum, in either execution mode.
pub struct PlatinumBackend {
    cfg: PlatinumConfig,
    mode: ExecMode,
}

impl PlatinumBackend {
    /// The shipped design point in ternary mode (the paper's headline
    /// "Platinum" rows).
    pub fn ternary() -> Self {
        PlatinumBackend::with_config(PlatinumConfig::default(), ExecMode::Ternary)
    }

    /// The bit-serial configuration ("Platinum-bs"): same silicon, the
    /// binary build path, k retiled to 728 = 2 rounds of 52×7 chunks.
    pub fn bitserial() -> Self {
        let mut cfg = PlatinumConfig::default();
        cfg.tiling.k = 728;
        PlatinumBackend::with_config(cfg, ExecMode::BitSerial { planes: 2 })
    }

    /// Arbitrary configuration (DSE sweeps, serving pricers).
    pub fn with_config(cfg: PlatinumConfig, mode: ExecMode) -> Self {
        PlatinumBackend { cfg, mode }
    }

    pub fn config(&self) -> &PlatinumConfig {
        &self.cfg
    }

    pub fn mode(&self) -> ExecMode {
        self.mode
    }
}

impl Backend for PlatinumBackend {
    fn id(&self) -> &str {
        match self.mode {
            ExecMode::Ternary => "platinum-ternary",
            ExecMode::BitSerial { .. } => "platinum-bitserial",
        }
    }

    fn describe(&self) -> BackendInfo {
        BackendInfo {
            id: match self.mode {
                ExecMode::Ternary => "platinum-ternary",
                ExecMode::BitSerial { .. } => "platinum-bitserial",
            }
            .into(),
            name: self.mode.label().into(),
            kind: BackendKind::Asic,
            freq_hz: self.cfg.freq_hz,
            pes: Some(self.cfg.num_pes()),
            area_mm2: Some(AreaModel::platinum(&self.cfg).breakdown().total()),
            tech_nm: Some(28),
            notes: format!(
                "cycle-accurate simulator, §IV phase laws (paper: 0.955 mm², 1534 GOP/s); \
                 dram eff {:.2} (PLATINUM_DRAM_EFF)",
                DramChannel::from_env(self.cfg.dram_bw, self.cfg.freq_hz)
                    .unwrap_or_else(|e| panic!("{e}"))
                    .efficiency
            ),
        }
    }

    fn run(&self, w: &Workload) -> Report {
        let id = self.id().to_string();
        run_workload(&id, w, |g| Report::from_sim(&id, &simulate_gemm(&self.cfg, self.mode, g)))
    }
}

// ---------------------------------------------------------------------------
// SpikingEyeriss
// ---------------------------------------------------------------------------

/// SpikingEyeriss: 168-PE row-stationary array, ternary bit-serial
/// two-pass mapping (analytical model calibrated to Table I).
pub struct EyerissBackend;

impl Backend for EyerissBackend {
    fn id(&self) -> &str {
        "eyeriss"
    }

    fn describe(&self) -> BackendInfo {
        BackendInfo {
            id: "eyeriss".into(),
            name: "SpikingEyeriss".into(),
            kind: BackendKind::Asic,
            freq_hz: eyeriss::FREQ_HZ,
            pes: Some(eyeriss::PES_ROWS * eyeriss::PES_COLS),
            area_mm2: Some(1.07),
            tech_nm: Some(28),
            notes: "row-stationary GEMM mapping, calibrated to Table I (20.8 GOP/s prefill)"
                .into(),
        }
    }

    fn run(&self, w: &Workload) -> Report {
        run_workload("eyeriss", w, |g| {
            let r = eyeriss::simulate(g, g.n);
            Report::from_scalars("eyeriss", g, r.latency_s, r.energy_j)
        })
    }
}

// ---------------------------------------------------------------------------
// Prosperity
// ---------------------------------------------------------------------------

/// Prosperity (HPCA'25): 256-PE product-sparsity accelerator with
/// runtime shortcut scheduling (analytical model calibrated to Table I).
pub struct ProsperityBackend;

impl Backend for ProsperityBackend {
    fn id(&self) -> &str {
        "prosperity"
    }

    fn describe(&self) -> BackendInfo {
        BackendInfo {
            id: "prosperity".into(),
            name: "Prosperity".into(),
            kind: BackendKind::Asic,
            freq_hz: prosperity::FREQ_HZ,
            pes: Some(prosperity::NUM_PES),
            area_mm2: Some(1.06),
            tech_nm: Some(28),
            notes: "product-sparsity model, 32.3% dynamic-scheduler power tax (Table I: 375 GOP/s)"
                .into(),
        }
    }

    fn run(&self, w: &Workload) -> Report {
        run_workload("prosperity", w, |g| {
            let r = prosperity::simulate(g, g.n);
            Report::from_scalars("prosperity", g, r.latency_s, r.energy_j)
        })
    }
}

// ---------------------------------------------------------------------------
// T-MAC (analytical M2 Pro model)
// ---------------------------------------------------------------------------

/// T-MAC on the paper's CPU setup: 16 threads on an Apple M2 Pro,
/// analytical model calibrated to Table I's 715 GOP/s.
pub struct TMacBackend;

impl Backend for TMacBackend {
    fn id(&self) -> &str {
        "tmac"
    }

    fn describe(&self) -> BackendInfo {
        BackendInfo {
            id: "tmac".into(),
            name: "T-MAC (M2 Pro)".into(),
            kind: BackendKind::Cpu,
            freq_hz: tmac::M2_FREQ_HZ,
            pes: None,
            area_mm2: Some(289.0),
            tech_nm: Some(5),
            notes: "analytical NEON-tbl LUT model, 16 threads, calibrated to Table I (715 GOP/s)"
                .into(),
        }
    }

    fn run(&self, w: &Workload) -> Report {
        run_workload("tmac", w, |g| {
            let r = tmac::simulate_m2pro(g);
            Report::from_scalars("tmac", g, r.latency_s, r.energy_j)
        })
    }
}

// ---------------------------------------------------------------------------
// T-MAC (real CPU kernel, measured on this machine)
// ---------------------------------------------------------------------------

/// The real multithreaded T-MAC-style CPU kernel
/// ([`tmac::TMacCpu`]), measured wall-clock on this host with seeded
/// synthetic ternary weights, on the persistent work-stealing pool
/// (`threads` bounds the lanes claiming rows dynamically).  Energy is
/// unmodelled (reported as `None`/JSON `null`, never `0.0`): this
/// backend exists for latency ground truth, not the energy axis.
pub struct TMacCpuBackend {
    threads: usize,
    seed: u64,
    /// Pinned-concurrency pool for `with_threads`; `None` = global pool.
    pool: Option<Pool>,
    /// Shape → measurement memo, persistent across `run` calls so a
    /// serving loop pricing the same shapes per batch measures once.
    memo: Mutex<BTreeMap<(usize, usize, usize), Report>>,
}

impl TMacCpuBackend {
    pub fn new() -> Self {
        TMacCpuBackend {
            threads: pool::default_threads().min(16),
            seed: 0x7AC,
            pool: None,
            memo: Mutex::new(BTreeMap::new()),
        }
    }

    pub fn with_threads(threads: usize) -> Self {
        let threads = threads.max(1);
        TMacCpuBackend {
            threads,
            seed: 0x7AC,
            pool: Some(Pool::new(threads)),
            memo: Mutex::new(BTreeMap::new()),
        }
    }

    fn pool(&self) -> &Pool {
        match &self.pool {
            Some(p) => p,
            None => pool::global(),
        }
    }

    fn measure(&self, g: Gemm) -> Report {
        let mut rng = Rng::seed_from(
            self.seed ^ (g.m as u64) ^ ((g.k as u64) << 20) ^ ((g.n as u64) << 40),
        );
        let w = rng.ternary_vec(g.m * g.k);
        let x = rng.act_vec(g.k * g.n);
        let kernel = tmac::TMacCpu::new(&w, g.m, g.k);
        let mut out = vec![0i32; g.m * g.n];
        // small kernels: warm up once and keep the best of two timed
        // runs; large ones pay for a single cold run only
        let runs = if g.naive_adds() < 100_000_000 { 2 } else { 1 };
        if runs > 1 {
            kernel.gemm_pool(&x, g.n, &mut out, self.threads, self.pool());
        }
        let mut best = f64::MAX;
        for _ in 0..runs {
            let t0 = Instant::now();
            kernel.gemm_pool(&x, g.n, &mut out, self.threads, self.pool());
            best = best.min(t0.elapsed().as_secs_f64());
        }
        let latency = best.max(1e-9);
        Report::from_measured("tmac-cpu", g, latency)
    }
}

impl Default for TMacCpuBackend {
    fn default() -> Self {
        TMacCpuBackend::new()
    }
}

impl Backend for TMacCpuBackend {
    fn id(&self) -> &str {
        "tmac-cpu"
    }

    fn describe(&self) -> BackendInfo {
        BackendInfo {
            id: "tmac-cpu".into(),
            name: "T-MAC (this host)".into(),
            kind: BackendKind::Cpu,
            freq_hz: 0.0,
            pes: None,
            area_mm2: None,
            tech_nm: None,
            notes: "real multithreaded LUT kernel, wall-clock on this machine; energy unmodelled"
                .into(),
        }
    }

    fn run(&self, w: &Workload) -> Report {
        // this backend executes every multiply-add for real; a 3B model
        // pass is minutes of host CPU — say so up front rather than
        // sitting silent (COMPARISON_IDS excludes this id for the same
        // reason)
        let unique_ops: u64 = {
            let mut seen = BTreeMap::new();
            for (g, _) in w.kernels() {
                seen.insert((g.m, g.k, g.n), g.naive_adds());
            }
            seen.values().sum()
        };
        if unique_ops > 2_000_000_000 {
            eprintln!(
                "warning: tmac-cpu measures {unique_ops} real multiply-adds wall-clock \
                 on this host; this may take minutes"
            );
        }
        // model passes repeat shapes across layers (and serving loops
        // repeat them across batches) — measure each unique (m,k,n)
        // once and reuse the observation for the backend's lifetime
        run_workload("tmac-cpu", w, |g| {
            let mut memo = self.memo.lock().unwrap();
            memo.entry((g.m, g.k, g.n)).or_insert_with(|| self.measure(g)).clone()
        })
    }
}

// ---------------------------------------------------------------------------
// Platinum golden datapath (real CPU execution, measured on this machine)
// ---------------------------------------------------------------------------

/// The functional golden model ([`crate::lut::ternary_mpgemm`])
/// executed **for real** on the work-stealing worker pool (construct
/// and query work claimed dynamically, so decode-shaped kernels with
/// few rows still spread across `threads` lanes), reporting measured
/// wall-clock latency/throughput through the unified [`Report`] — the
/// software twin of the PPE array as an engine citizen, so the
/// functional path and the perf models are selectable through the same
/// `--backend` surface.  Weights are seeded synthetic ternary (packed
/// once per unique shape); energy is unmodelled (`None`, ROADMAP: RAPL).
pub struct PlatinumCpuBackend {
    cfg: PlatinumConfig,
    threads: usize,
    seed: u64,
    /// Pinned-concurrency pool for `with_threads`; `None` = global pool.
    pool: Option<Pool>,
    /// Shape → measurement memo, persistent across `run` calls so a
    /// serving loop pricing the same shapes per batch measures once.
    memo: Mutex<BTreeMap<(usize, usize, usize), Report>>,
}

impl PlatinumCpuBackend {
    pub fn new() -> Self {
        PlatinumCpuBackend {
            cfg: PlatinumConfig::default(),
            threads: pool::default_threads().min(16),
            seed: 0x91A7,
            pool: None,
            memo: Mutex::new(BTreeMap::new()),
        }
    }

    pub fn with_threads(threads: usize) -> Self {
        let threads = threads.max(1);
        PlatinumCpuBackend {
            cfg: PlatinumConfig::default(),
            threads,
            seed: 0x91A7,
            pool: Some(Pool::new(threads)),
            memo: Mutex::new(BTreeMap::new()),
        }
    }

    fn pool(&self) -> &Pool {
        match &self.pool {
            Some(p) => p,
            None => pool::global(),
        }
    }

    fn measure(&self, g: Gemm) -> Report {
        let mut rng = Rng::seed_from(
            self.seed ^ (g.m as u64) ^ ((g.k as u64) << 20) ^ ((g.n as u64) << 40),
        );
        let w = rng.ternary_vec(g.m * g.k);
        let packed = pack_ternary(&w, g.m, g.k, self.cfg.c_ternary);
        let x = rng.act_vec(g.k * g.n);
        let runs = if g.naive_adds() < 100_000_000 { 2 } else { 1 };
        if runs > 1 {
            ternary_mpgemm_pool(&self.cfg, &packed, &x, g.n, self.pool(), self.threads);
        }
        let mut best = f64::MAX;
        for _ in 0..runs {
            let t0 = Instant::now();
            let (out, _) =
                ternary_mpgemm_pool(&self.cfg, &packed, &x, g.n, self.pool(), self.threads);
            best = best.min(t0.elapsed().as_secs_f64());
            std::hint::black_box(out);
        }
        Report::from_measured("platinum-cpu", g, best.max(1e-9))
    }
}

impl Default for PlatinumCpuBackend {
    fn default() -> Self {
        PlatinumCpuBackend::new()
    }
}

impl Backend for PlatinumCpuBackend {
    fn id(&self) -> &str {
        "platinum-cpu"
    }

    fn describe(&self) -> BackendInfo {
        BackendInfo {
            id: "platinum-cpu".into(),
            name: "Platinum (golden, this host)".into(),
            kind: BackendKind::Cpu,
            freq_hz: 0.0,
            pes: None,
            area_mm2: None,
            tech_nm: None,
            notes: "golden datapath executed for real on the worker pool; energy unmodelled"
                .into(),
        }
    }

    fn run(&self, w: &Workload) -> Report {
        let unique_ops: u64 = {
            let mut seen = BTreeMap::new();
            for (g, _) in w.kernels() {
                seen.insert((g.m, g.k, g.n), g.naive_adds());
            }
            seen.values().sum()
        };
        if unique_ops > 2_000_000_000 {
            eprintln!(
                "warning: platinum-cpu executes {unique_ops} real multiply-adds wall-clock \
                 on this host; this may take minutes"
            );
        }
        run_workload("platinum-cpu", w, |g| {
            let mut memo = self.memo.lock().unwrap();
            memo.entry((g.m, g.k, g.n)).or_insert_with(|| self.measure(g)).clone()
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::Stage;
    use crate::models::{B158_3B, PREFILL_N};
    use crate::sim::simulate_model;

    #[test]
    fn platinum_kernel_report_carries_detail() {
        let be = PlatinumBackend::ternary();
        let r = be.run(&Workload::Kernel(Gemm::new(1080, 520, 32)));
        assert_eq!(r.backend, "platinum-ternary");
        assert!(r.cycles.is_some() && r.phases.is_some());
        assert!(r.energy_breakdown.is_some() && r.utilization.is_some());
        let e = r.energy_j.expect("platinum models energy");
        assert!((e - r.energy_breakdown.unwrap().total()).abs() < 1e-18);
    }

    #[test]
    fn platinum_model_pass_matches_legacy_simulate_model() {
        let be = PlatinumBackend::ternary();
        let r = be.run(&Workload::ModelPass {
            model: B158_3B,
            n: PREFILL_N,
            stage: Stage::Prefill,
        });
        let legacy =
            simulate_model(&PlatinumConfig::default(), ExecMode::Ternary, &B158_3B, PREFILL_N);
        assert_eq!(r.cycles, Some(legacy.cycles));
        assert!((r.latency_s - legacy.latency_s).abs() <= legacy.latency_s * 1e-12);
        let e = r.energy_j.expect("platinum models energy");
        assert!((e - legacy.energy_j()).abs() <= legacy.energy_j() * 1e-12);
        assert!(
            (r.throughput_gops - legacy.throughput_gops).abs()
                <= legacy.throughput_gops * 1e-12
        );
    }

    #[test]
    fn baseline_model_pass_has_no_phantom_detail() {
        let r = EyerissBackend.run(&Workload::prefill(B158_3B));
        assert!(r.cycles.is_none() && r.phases.is_none());
        assert!(r.latency_s > 0.0 && r.energy_j.unwrap() > 0.0 && r.throughput_gops > 0.0);
    }

    #[test]
    fn tmac_cpu_measures_real_time() {
        let be = TMacCpuBackend::with_threads(2);
        let r = be.run(&Workload::Kernel(Gemm::new(64, 40, 8)));
        assert!(r.latency_s > 0.0);
        assert_eq!(r.ops, 64 * 40 * 8);
        assert_eq!(r.energy_j, None, "energy is documented as unmodelled (null, not 0)");
    }

    #[test]
    fn platinum_cpu_measures_real_time() {
        let be = PlatinumCpuBackend::with_threads(2);
        let r = be.run(&Workload::Kernel(Gemm::new(64, 40, 8)));
        assert_eq!(r.backend, "platinum-cpu");
        assert!(r.latency_s > 0.0 && r.throughput_gops > 0.0);
        assert_eq!(r.ops, 64 * 40 * 8);
        assert_eq!(r.energy_j, None, "measured backend: energy unmodelled");
    }

    #[test]
    fn measured_batch_energy_stays_unmodelled() {
        // aggregation over kernels must not materialize a 0.0 energy
        let be = PlatinumCpuBackend::with_threads(2);
        let r = be.run(&Workload::Batch(vec![Gemm::new(16, 20, 4), Gemm::new(8, 20, 4)]));
        assert_eq!(r.energy_j, None);
        assert_eq!(r.power_w(), None);
    }

    #[test]
    fn batch_sums_kernels() {
        let be = PlatinumBackend::ternary();
        let g1 = Gemm::new(1080, 520, 32);
        let g2 = Gemm::new(2160, 520, 32);
        let batch = be.run(&Workload::Batch(vec![g1, g2]));
        let a = be.run(&Workload::Kernel(g1));
        let b = be.run(&Workload::Kernel(g2));
        assert!((batch.latency_s - (a.latency_s + b.latency_s)).abs() <= batch.latency_s * 1e-12);
        assert_eq!(batch.cycles, Some(a.cycles.unwrap() + b.cycles.unwrap()));
        assert_eq!(batch.ops, a.ops + b.ops);
    }
}
