//! The unified execution [`Report`] — one result shape for every
//! backend, subsuming `sim::SimReport` (full detail) and
//! `baselines::BaselineReport` (scalars only), plus [`BackendInfo`]
//! static metadata.  Serializes via [`crate::util::json`].

use crate::analysis::Gemm;
use crate::sim::{Activity, EnergyBreakdown, PhaseCycles, SimReport, Utilization};
use crate::util::json::{num, obj, s, Json};

/// What kind of system a backend models.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    /// Cycle/analytically modelled ASIC.
    Asic,
    /// CPU software implementation (analytical or measured on this host).
    Cpu,
}

impl BackendKind {
    pub fn label(&self) -> &'static str {
        match self {
            BackendKind::Asic => "asic",
            BackendKind::Cpu => "cpu",
        }
    }
}

/// Static description of a backend (Table I's spec columns).  Owned
/// strings, because composite backends (`engine::Sharded`) carry
/// parameterized ids like `sharded:4:platinum-ternary`.
#[derive(Debug, Clone)]
pub struct BackendInfo {
    /// Registry id, e.g. `"platinum-ternary"`.
    pub id: String,
    /// Display name, e.g. `"Platinum"`.
    pub name: String,
    pub kind: BackendKind,
    /// Clock frequency in Hz (nominal for CPU backends).
    pub freq_hz: f64,
    /// Processing-element count, when the system has a meaningful one.
    pub pes: Option<usize>,
    /// Die/core area in mm², when modelled.
    pub area_mm2: Option<f64>,
    /// Process node in nm, when known.
    pub tech_nm: Option<u32>,
    /// One-line provenance note (calibration target, measurement caveat).
    pub notes: String,
}

impl BackendInfo {
    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("id", s(&self.id)),
            ("name", s(&self.name)),
            ("kind", s(self.kind.label())),
            ("freq_hz", num(self.freq_hz)),
            ("notes", s(&self.notes)),
        ];
        if let Some(p) = self.pes {
            pairs.push(("pes", num(p as f64)));
        }
        if let Some(a) = self.area_mm2 {
            pairs.push(("area_mm2", num(a)));
        }
        if let Some(t) = self.tech_nm {
            pairs.push(("tech_nm", num(t as f64)));
        }
        obj(pairs)
    }
}

/// Unified result of running one [`super::Workload`] on one backend.
///
/// Scalar headline metrics are always present; the `Option` sections
/// carry the cycle-accurate detail only the simulated Platinum backends
/// produce (analytical baselines report scalars, the measured CPU
/// backends report wall-clock latency only).
///
/// `energy_j` is `None` — serialized as JSON `null`, **not** `0.0` —
/// when the backend does not model energy at all (the measured
/// `tmac-cpu` / `platinum-cpu` kernels; RAPL-based measurement is the
/// ROADMAP fix).  A literal `0.0` would be indistinguishable from "this
/// system consumes no energy" in downstream comparisons.
#[derive(Debug, Clone, Default)]
pub struct Report {
    /// Backend id that produced this report.
    pub backend: String,
    /// Workload label (see [`super::Workload::label`]).
    pub workload: String,
    pub latency_s: f64,
    /// Total energy, when the backend models it (`None` = unmodelled).
    pub energy_j: Option<f64>,
    /// Naive-equivalent throughput (the paper's GOP/s normalization).
    pub throughput_gops: f64,
    /// Naive addition count of the workload.
    pub ops: u64,
    pub cycles: Option<u64>,
    pub phases: Option<PhaseCycles>,
    pub activity: Option<Activity>,
    pub energy_breakdown: Option<EnergyBreakdown>,
    pub utilization: Option<Utilization>,
}

impl Report {
    /// Average power over the workload (`None` when energy is
    /// unmodelled or latency is zero).
    pub fn power_w(&self) -> Option<f64> {
        match self.energy_j {
            Some(e) if self.latency_s > 0.0 => Some(e / self.latency_s),
            _ => None,
        }
    }

    /// Lift a cycle-accurate [`SimReport`] into the unified shape.
    pub fn from_sim(backend: &str, r: &SimReport) -> Report {
        Report {
            backend: backend.to_string(),
            workload: format!("gemm-{}x{}x{}", r.gemm.m, r.gemm.k, r.gemm.n),
            latency_s: r.latency_s,
            energy_j: Some(r.energy.total()),
            throughput_gops: r.throughput_gops,
            ops: r.gemm.naive_adds(),
            cycles: Some(r.cycles),
            phases: Some(r.phases),
            activity: Some(r.activity),
            energy_breakdown: Some(r.energy),
            utilization: Some(r.utilization),
        }
    }

    /// Lift an analytical baseline result (scalars only).
    pub fn from_scalars(backend: &str, g: Gemm, latency_s: f64, energy_j: f64) -> Report {
        Report {
            backend: backend.to_string(),
            workload: format!("gemm-{}x{}x{}", g.m, g.k, g.n),
            latency_s,
            energy_j: Some(energy_j),
            throughput_gops: if latency_s > 0.0 {
                g.naive_adds() as f64 / latency_s / 1e9
            } else {
                0.0
            },
            ops: g.naive_adds(),
            ..Report::default()
        }
    }

    /// Lift a wall-clock measurement: real latency, energy unmodelled.
    pub fn from_measured(backend: &str, g: Gemm, latency_s: f64) -> Report {
        Report {
            energy_j: None,
            ..Report::from_scalars(backend, g, latency_s, 0.0)
        }
    }

    /// Machine-readable form (stable key order; `--json` CLI surface).
    /// `energy_j`/`power_w` are `null` when energy is unmodelled.
    pub fn to_json(&self) -> Json {
        let opt = |v: Option<f64>| v.map(num).unwrap_or(Json::Null);
        let mut pairs = vec![
            ("backend", s(&self.backend)),
            ("workload", s(&self.workload)),
            ("latency_s", num(self.latency_s)),
            ("energy_j", opt(self.energy_j)),
            ("power_w", opt(self.power_w())),
            ("throughput_gops", num(self.throughput_gops)),
            ("ops", num(self.ops as f64)),
        ];
        if let Some(c) = self.cycles {
            pairs.push(("cycles", num(c as f64)));
        }
        if let Some(p) = &self.phases {
            pairs.push((
                "phases",
                obj(vec![
                    ("construct", num(p.construct as f64)),
                    ("query", num(p.query as f64)),
                    ("drain", num(p.drain as f64)),
                    ("dram_stall", num(p.dram_stall as f64)),
                ]),
            ));
        }
        if let Some(a) = &self.activity {
            pairs.push((
                "activity",
                obj(vec![
                    ("construct_adds", num(a.construct_adds as f64)),
                    ("reduce_adds", num(a.reduce_adds as f64)),
                    ("dram_read_bytes", num(a.dram_read_bytes as f64)),
                    ("dram_write_bytes", num(a.dram_write_bytes as f64)),
                    ("lut_read_bytes", num(a.lut_read_bytes as f64)),
                    ("lut_write_bytes", num(a.lut_write_bytes as f64)),
                    ("wbuf_read_bytes", num(a.wbuf_read_bytes as f64)),
                    ("wbuf_write_bytes", num(a.wbuf_write_bytes as f64)),
                    ("ibuf_read_bytes", num(a.ibuf_read_bytes as f64)),
                    ("ibuf_write_bytes", num(a.ibuf_write_bytes as f64)),
                    ("obuf_bytes", num(a.obuf_bytes as f64)),
                    ("path_read_bytes", num(a.path_read_bytes as f64)),
                ]),
            ));
        }
        if let Some(e) = &self.energy_breakdown {
            pairs.push((
                "energy_breakdown_j",
                obj(vec![
                    ("dram", num(e.dram)),
                    ("weight_buf", num(e.weight_buf)),
                    ("input_buf", num(e.input_buf)),
                    ("output_buf", num(e.output_buf)),
                    ("lut_buf", num(e.lut_buf)),
                    ("path_buf", num(e.path_buf)),
                    ("adders", num(e.adders)),
                    ("static_leak", num(e.static_leak)),
                ]),
            ));
        }
        if let Some(u) = &self.utilization {
            pairs.push((
                "utilization",
                obj(vec![
                    ("adders", num(u.adders)),
                    ("lut_ports", num(u.lut_ports)),
                    ("dram_bw", num(u.dram_bw)),
                ]),
            ));
        }
        obj(pairs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn to_json_golden_scalar_report() {
        let r = Report {
            backend: "platinum-ternary".into(),
            workload: "gemm-4x4x4".into(),
            latency_s: 0.5,
            energy_j: Some(2.0),
            throughput_gops: 1.5,
            ops: 64,
            cycles: Some(1000),
            ..Report::default()
        };
        assert_eq!(
            r.to_json().to_string(),
            "{\"backend\":\"platinum-ternary\",\"cycles\":1000,\"energy_j\":2,\
             \"latency_s\":0.5,\"ops\":64,\"power_w\":4,\"throughput_gops\":1.5,\
             \"workload\":\"gemm-4x4x4\"}"
        );
    }

    #[test]
    fn unmodelled_energy_serializes_as_null_not_zero() {
        let r = Report::from_measured("tmac-cpu", Gemm::new(4, 4, 4), 0.5);
        assert_eq!(r.energy_j, None);
        assert_eq!(r.power_w(), None);
        let text = r.to_json().to_string();
        assert!(text.contains("\"energy_j\":null"), "{text}");
        assert!(text.contains("\"power_w\":null"), "{text}");
        let parsed = Json::parse(&text).unwrap();
        assert_eq!(parsed.get("energy_j"), Some(&Json::Null));
        // a modelled zero still serializes as the number 0
        let z = Report::from_scalars("eyeriss", Gemm::new(4, 4, 4), 0.5, 0.0);
        assert!(z.to_json().to_string().contains("\"energy_j\":0"));
    }

    #[test]
    fn json_roundtrips_through_parser() {
        let mut r = Report {
            backend: "eyeriss".into(),
            workload: "b1.58-3B-prefill-n1024".into(),
            latency_s: 1.25e-3,
            energy_j: Some(3.5e-2),
            throughput_gops: 20.8,
            ops: 123_456,
            ..Report::default()
        };
        r.phases = Some(PhaseCycles { construct: 1, query: 2, drain: 3, dram_stall: 4 });
        let parsed = Json::parse(&r.to_json().to_string()).unwrap();
        assert_eq!(parsed.get("backend").unwrap().as_str(), Some("eyeriss"));
        assert_eq!(parsed.get("ops").unwrap().as_usize(), Some(123_456));
        assert_eq!(
            parsed.get("phases").unwrap().get("dram_stall").unwrap().as_usize(),
            Some(4)
        );
    }

    #[test]
    fn power_guards_zero_latency() {
        let r = Report { energy_j: Some(1.0), ..Report::default() };
        assert_eq!(r.power_w(), None, "zero latency yields no power figure");
        assert_eq!(Report::default().power_w(), None);
    }
}
