//! The unified execution engine (S13): **one API for every system that
//! can execute an mpGEMM workload**.
//!
//! Before this subsystem existed the crate had four unrelated execution
//! surfaces: `sim::simulate_gemm`/`simulate_model` returning
//! `SimReport`, per-baseline free functions returning `BaselineReport`,
//! ad-hoc `model_report` closure plumbing at every call site, and the
//! serving coordinator pricing requests straight against the simulator.
//! The engine collapses them into:
//!
//! * [`Backend`] — anything that executes a [`Workload`]: Platinum in
//!   either [`crate::config::ExecMode`], SpikingEyeriss, Prosperity,
//!   the analytical T-MAC model, and the two real measured CPU kernels
//!   (`tmac-cpu`, and `platinum-cpu` running the golden datapath on the
//!   [`crate::runtime::pool`] worker pool).
//! * [`Workload`] — kernel / model-pass / batch, with model-pass
//!   expansion and aggregation implemented once inside the engine.
//! * [`Report`] — one result shape (scalars always, cycle-accurate
//!   detail when the backend produces it), JSON-serializable via
//!   [`Report::to_json`].
//! * [`Registry`] — string-keyed backend construction, so every
//!   frontend (`--backend` CLI flags, DSE, benches, serving) selects
//!   systems the same way and new accelerators plug in at one place.
//!   Beyond the fixed table it resolves the parameterized multi-chip
//!   grammar `sharded:<replicas>[:<strategy>][:net=<topology>]:<inner-id>`.
//! * [`Sharded`] — the multi-chip composite: N replicas of any backend
//!   with a workload partitioned across them (`rows`/`batch`/`layers`)
//!   and reports merged under the max-latency/sum-energy rules plus a
//!   modelled interconnect term — analytic by default, or the
//!   event-driven topology simulator ([`crate::sim::net`]) when the id
//!   selects `net=ring|mesh2d|fattree`.
//!
//! The legacy free functions remain as thin shims over the same
//! arithmetic; `tests/engine_api.rs` pins the equivalence.

pub mod backends;
pub mod registry;
pub mod report;
pub mod sharded;
pub mod workload;

pub use backends::{
    EyerissBackend, PlatinumBackend, PlatinumCpuBackend, ProsperityBackend, TMacBackend,
    TMacCpuBackend,
};
pub use registry::{Registry, COMPARISON_IDS, SHARDED_GRAMMAR};
pub use report::{BackendInfo, BackendKind, Report};
pub use sharded::{Interconnect, ShardStrategy, Sharded};
pub use workload::{Stage, Workload};

/// A system that executes mpGEMM workloads.
///
/// Implementations must be deterministic given the workload (the
/// measured CPU backends are the deliberate exception: they report
/// real wall-clock time) and must fill every scalar field of the
/// returned [`Report`] (`energy_j` stays `None` when unmodelled).
pub trait Backend {
    /// Stable registry id (e.g. `"platinum-ternary"`).
    fn id(&self) -> &str;

    /// Static metadata (Table I's spec columns).
    fn describe(&self) -> BackendInfo;

    /// Execute a workload and report latency / energy / throughput,
    /// plus cycle-accurate detail when the backend models it.
    fn run(&self, workload: &Workload) -> Report;

    /// Replica count of a composite backend (1 for a single chip).
    /// Sizes the fault injector's liveness map in the serving layer.
    fn replicas(&self) -> usize {
        1
    }

    /// Execute with some replicas marked dead (`alive[i] == false`).
    /// Single-chip backends ignore the mask; [`Sharded`] re-partitions
    /// the dead replicas' shards across the survivors (failover).
    fn run_degraded(&self, workload: &Workload, _alive: &[bool]) -> Report {
        self.run(workload)
    }

    /// Priced weight-redistribution stall when one replica fails and
    /// its weight shard is re-assigned across `survivors` chips over
    /// the modelled interconnect (zero for single-chip backends).
    fn redistribute_cost_s(&self, _weight_bytes: u64, _survivors: usize) -> f64 {
        0.0
    }
}
