//! Multi-chip composition: [`Sharded`] wraps N replicas of any
//! registered backend and partitions a [`Workload`] across them — the
//! scaling axis the paper's 0.96 mm²-per-chip positioning implies and
//! the ROADMAP names first among the engine follow-ups.
//!
//! Three partition strategies:
//!
//! * [`ShardStrategy::Rows`] — split every kernel's M dimension (each
//!   chip owns a disjoint stripe of output rows; weights are
//!   partitioned, activations broadcast).  Default, and functionally
//!   lossless: stitching the per-shard outputs reproduces the
//!   unsharded result bit-exactly (pinned in `tests/engine_api.rs`).
//! * [`ShardStrategy::Batch`] — split the request axis: the entries of
//!   a [`Workload::Batch`], the N (batch·seq) dimension of a kernel or
//!   model pass (weights replicated, activations partitioned).
//! * [`ShardStrategy::Layers`] — split a model pass layer-wise across
//!   chips (pipeline parallelism; each chip holds a contiguous block
//!   of transformer layers).
//!
//! Aggregation follows the timing physics of each strategy: for the
//! data-parallel strategies (`rows`/`batch`) **latency is the max over
//! replicas plus a modelled interconnect/merge term**
//! ([`Interconnect`]); for `layers` a single dispatch traverses the
//! pipeline stages **sequentially**, so latency is the *sum* of stage
//! latencies plus the handoffs (max would describe steady-state
//! pipelined throughput, not one pass).  **Energy is always the sum** —
//! preserving the `Option<f64>` null-propagation contract (one replica
//! with unmodelled energy makes the composite's energy unmodelled).
//! Cycle-accurate detail survives when every active replica reports
//! it: cycles follow latency (max, or sum for `layers`), activity and
//! the energy breakdown are cross-chip sums, phases/utilization are
//! the critical (slowest) replica's view.
//!
//! The closed-form interconnect term is the default; an optional
//! **event-driven network model** ([`crate::sim::net`]) replaces it
//! when a topology is selected.  With `net=<topology>` the composite
//! builds a [`NetSim`] over the replica graph and prices every dispatch
//! as the *makespan of an event timeline*: each replica's output stripe
//! (rows/batch) or activation handoff (layers) becomes a routed
//! [`Transfer`] starting when that replica's compute span ends, links
//! serialize contending messages, and crash-failover weight
//! redistribution ([`Backend::redistribute_cost_s`]) is priced on the
//! same timeline instead of the analytic single-link formula.  Both
//! models read the same `PLATINUM_LINK_GBPS`/`PLATINUM_HOP_US`
//! calibration knobs; the analytic and event models agree on
//! contention-free patterns and diverge under congestion (pinned in
//! tests and `benches/net_topology.rs`).
//!
//! Registry grammar:
//! `sharded:<replicas>[:<strategy>][:net=<topology>]:<inner-id>`,
//! e.g. `sharded:4:platinum-ternary`, `sharded:8:batch:eyeriss`, or
//! `sharded:4:net=mesh2d:platinum-ternary` (strategy defaults to
//! `rows`, the interconnect to the analytic model; composites nest, so
//! `sharded:2:layers:sharded:4:platinum-ternary` is a 2-stage pipeline
//! of 4-way row-parallel chips).

use super::report::{BackendInfo, Report};
use super::workload::Workload;
use super::Backend;
use crate::analysis::Gemm;
use crate::runtime::pool::split_even;
use crate::sim::net::{NetSim, Topology, Transfer};
use anyhow::{bail, Result};

/// How a [`Sharded`] backend partitions a workload across replicas.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShardStrategy {
    /// Split every kernel's M (output-row) dimension.
    Rows,
    /// Split the request axis (batch entries / the N dimension).
    Batch,
    /// Split a model pass layer-wise (pipeline stages).
    Layers,
}

impl ShardStrategy {
    pub const ALL: [ShardStrategy; 3] =
        [ShardStrategy::Rows, ShardStrategy::Batch, ShardStrategy::Layers];

    pub fn label(&self) -> &'static str {
        match self {
            ShardStrategy::Rows => "rows",
            ShardStrategy::Batch => "batch",
            ShardStrategy::Layers => "layers",
        }
    }

    /// Parse a grammar token (`rows`/`batch`/`layers`).
    pub fn parse(s: &str) -> Option<ShardStrategy> {
        ShardStrategy::ALL.into_iter().find(|st| st.label() == s)
    }
}

/// Modelled chip-to-chip interconnect, charged once per dispatch for
/// collecting partial results (rows/batch: an all-gather of the output
/// stripes into one place; layers: activation handoffs between pipeline
/// stages).  Deliberately modest edge-class numbers — the point is that
/// scaling is *not* free, so replica sweeps show diminishing returns.
///
/// The constants are calibratable without a rebuild: registry-built
/// composites read `PLATINUM_LINK_GBPS` (sustained link bandwidth,
/// GB/s) and `PLATINUM_HOP_US` (per-hop latency, µs) via
/// [`Interconnect::from_env`], falling back to the 16 GB/s / 1 µs
/// defaults — so a measured chip-to-chip link (the ROADMAP
/// calibration follow-on) plugs in from the environment.  The active
/// values are surfaced in the composite's
/// [`BackendInfo::notes`].
#[derive(Debug, Clone, Copy)]
pub struct Interconnect {
    /// Sustained link bandwidth in bytes/s.
    pub link_bytes_per_s: f64,
    /// Per-hop latency of the reduction/gather tree (s).
    pub hop_s: f64,
}

impl Default for Interconnect {
    fn default() -> Interconnect {
        // 16 GB/s (PCIe-gen4-x4-ish edge link), 1 µs per tree hop
        Interconnect { link_bytes_per_s: 16e9, hop_s: 1e-6 }
    }
}

impl Interconnect {
    /// Defaults overridden by `PLATINUM_LINK_GBPS` / `PLATINUM_HOP_US`.
    /// Unset keeps the default for that knob; a set-but-invalid value
    /// (unparsable, zero, negative, non-finite) is a hard error naming
    /// the variable and the offending value (`util::env`).
    pub fn from_env() -> Result<Interconnect> {
        let mut ic = Interconnect::default();
        if let Some(gbps) = crate::util::env::positive_f64("PLATINUM_LINK_GBPS")? {
            ic.link_bytes_per_s = gbps * 1e9;
        }
        if let Some(us) = crate::util::env::positive_f64("PLATINUM_HOP_US")? {
            ic.hop_s = us * 1e-6;
        }
        Ok(ic)
    }
}

/// A composite [`Backend`]: N replicas of one inner backend executing
/// disjoint shards of every workload.  See the module docs for the
/// partition strategies and aggregation rules.
pub struct Sharded {
    id: String,
    inner: Vec<Box<dyn Backend>>,
    strategy: ShardStrategy,
    interconnect: Interconnect,
    /// Event-driven network model; `None` keeps the analytic term.
    net: Option<NetSim>,
}

impl Sharded {
    /// Compose `inner` replicas under `strategy` with the
    /// environment-calibratable interconnect
    /// ([`Interconnect::from_env`]).  Replicas are assumed homogeneous
    /// (the canonical id is derived from the first); errors on an
    /// empty replica set.
    pub fn new(inner: Vec<Box<dyn Backend>>, strategy: ShardStrategy) -> Result<Sharded> {
        Sharded::with_interconnect(inner, strategy, Interconnect::from_env()?)
    }

    /// [`Sharded::new`] with an explicit interconnect model.
    pub fn with_interconnect(
        inner: Vec<Box<dyn Backend>>,
        strategy: ShardStrategy,
        interconnect: Interconnect,
    ) -> Result<Sharded> {
        Sharded::compose(inner, strategy, interconnect, None)
    }

    /// [`Sharded::new`] with the event-driven interconnect over an
    /// explicit topology (env-calibrated link/hop constants).  Errors
    /// when the replica count cannot form the topology.
    pub fn with_net(
        inner: Vec<Box<dyn Backend>>,
        strategy: ShardStrategy,
        topology: Topology,
    ) -> Result<Sharded> {
        Sharded::compose(inner, strategy, Interconnect::from_env()?, Some(topology))
    }

    /// [`Sharded::with_net`] with an explicit interconnect calibration.
    pub fn with_net_interconnect(
        inner: Vec<Box<dyn Backend>>,
        strategy: ShardStrategy,
        topology: Topology,
        interconnect: Interconnect,
    ) -> Result<Sharded> {
        Sharded::compose(inner, strategy, interconnect, Some(topology))
    }

    fn compose(
        inner: Vec<Box<dyn Backend>>,
        strategy: ShardStrategy,
        interconnect: Interconnect,
        topology: Option<Topology>,
    ) -> Result<Sharded> {
        if inner.is_empty() {
            bail!("sharded backend needs at least one replica");
        }
        let net = match topology {
            None => None,
            Some(t) => Some(NetSim::new(
                t,
                inner.len(),
                interconnect.link_bytes_per_s,
                interconnect.hop_s,
            )?),
        };
        // canonical form omits the default strategy and the default
        // (analytic) interconnect, so `sharded:4:platinum-ternary`
        // round-trips unchanged
        let strat = match strategy {
            ShardStrategy::Rows => String::new(),
            st => format!("{}:", st.label()),
        };
        let nets = match topology {
            None => String::new(),
            Some(t) => format!("net={}:", t.label()),
        };
        let id = format!("sharded:{}:{}{}{}", inner.len(), strat, nets, inner[0].id());
        Ok(Sharded { id, inner, strategy, interconnect, net })
    }

    pub fn replicas(&self) -> usize {
        self.inner.len()
    }

    pub fn strategy(&self) -> ShardStrategy {
        self.strategy
    }

    /// The event-model topology, when one was selected (`net=` grammar).
    pub fn net_topology(&self) -> Option<Topology> {
        self.net.as_ref().map(|n| n.topology())
    }

    /// The per-replica shards of `w` (only non-empty shards; fewer than
    /// `replicas()` entries means idle chips).  A single replica passes
    /// the workload through untouched, which keeps `sharded:1:<id>`
    /// bit-exact with the inner backend.
    pub fn partition(&self, w: &Workload) -> Vec<Workload> {
        self.partition_n(w, self.inner.len())
    }

    /// [`Sharded::partition`] across an explicit replica count — the
    /// failover path re-partitions across the survivors of a crash.
    fn partition_n(&self, w: &Workload, n_rep: usize) -> Vec<Workload> {
        if n_rep == 1 {
            return vec![w.clone()];
        }
        match (self.strategy, w) {
            // rows: every kernel's M stripe-split, counts preserved
            (ShardStrategy::Rows, _) => {
                let mut shards: Vec<Vec<(Gemm, usize)>> = vec![Vec::new(); n_rep];
                for (g, cnt) in w.kernels() {
                    for (i, r) in split_even(g.m, n_rep).into_iter().enumerate() {
                        shards[i].push((Gemm::new(r.len(), g.k, g.n), cnt));
                    }
                }
                shards
                    .into_iter()
                    .filter(|s| !s.is_empty())
                    .map(Workload::Counted)
                    .collect()
            }
            // batch: split the request list / the N dimension; for
            // counted workloads the requests are the occurrences, so
            // each kernel's count is what splits (not the distinct-
            // kernel list, which may be a single high-count entry)
            (ShardStrategy::Batch, Workload::Batch(gs)) => split_even(gs.len(), n_rep)
                .into_iter()
                .map(|r| Workload::Batch(gs[r].to_vec()))
                .collect(),
            (ShardStrategy::Batch, Workload::Counted(ps))
            | (ShardStrategy::Layers, Workload::Counted(ps)) => {
                let mut shards: Vec<Vec<(Gemm, usize)>> = vec![Vec::new(); n_rep];
                for &(g, cnt) in ps {
                    for (i, r) in split_even(cnt, n_rep).into_iter().enumerate() {
                        shards[i].push((g, r.len()));
                    }
                }
                shards
                    .into_iter()
                    .filter(|s| !s.is_empty())
                    .map(Workload::Counted)
                    .collect()
            }
            (ShardStrategy::Batch, Workload::Kernel(g)) => split_even(g.n, n_rep)
                .into_iter()
                .map(|r| Workload::Kernel(Gemm::new(g.m, g.k, r.len())))
                .collect(),
            (ShardStrategy::Batch, Workload::ModelPass { model, n, stage }) => {
                split_even(*n, n_rep)
                    .into_iter()
                    .map(|r| Workload::ModelPass { model: *model, n: r.len(), stage: *stage })
                    .collect()
            }
            // layers: contiguous layer blocks of a model pass; lists
            // split stage-wise; a single kernel has no layer axis
            (ShardStrategy::Layers, Workload::ModelPass { model, n, stage }) => {
                split_even(model.layers, n_rep)
                    .into_iter()
                    .map(|r| {
                        let mut stage_model = *model;
                        stage_model.layers = r.len();
                        Workload::ModelPass { model: stage_model, n: *n, stage: *stage }
                    })
                    .collect()
            }
            (ShardStrategy::Layers, Workload::Batch(gs)) => split_even(gs.len(), n_rep)
                .into_iter()
                .map(|r| Workload::Batch(gs[r].to_vec()))
                .collect(),
            (ShardStrategy::Layers, Workload::Kernel(_)) => vec![w.clone()],
        }
    }

    /// The modelled interconnect/merge latency for collecting results
    /// from `active` busy replicas (zero when nothing needs merging).
    pub fn merge_latency_s(&self, w: &Workload, active: usize) -> f64 {
        if active <= 1 {
            return 0.0;
        }
        let boundaries = active as f64 - 1.0;
        let out_bytes = out_bytes(w);
        let (hops, bytes) = match (self.strategy, w) {
            // pipeline: (active-1) sequential stage boundaries, each
            // handing off the activation tile (n × hidden i32 words)
            (ShardStrategy::Layers, Workload::ModelPass { model, n, .. }) => {
                (boundaries, 4.0 * (*n as f64) * model.hidden as f64 * boundaries)
            }
            // pipeline over a kernel list: each boundary hands off
            // roughly one stage's share of the intermediate results
            (ShardStrategy::Layers, _) => {
                (boundaries, out_bytes * boundaries / active as f64)
            }
            // gather: a log2 reduction tree; every non-root chip ships
            // its output stripe
            _ => ((active as f64).log2().ceil(), out_bytes * boundaries / active as f64),
        };
        hops * self.interconnect.hop_s + bytes / self.interconnect.link_bytes_per_s
    }

    /// Event-timeline dispatch latency (the `net=` model): per-replica
    /// compute spans overlap with gather/handoff traffic routed over
    /// the topology, and the result is the makespan of the simulated
    /// timeline — not an analytic max-plus-merge.
    ///
    /// * rows/batch — every non-root busy replica ships its output
    ///   stripe to the gather root (the lowest-indexed live replica)
    ///   the moment *its own* shard finishes; stripes crossing the same
    ///   link serialize.  The dispatch completes when the root has both
    ///   finished its shard and received the last stripe.
    /// * layers — the dispatch traverses the pipeline stages
    ///   sequentially, each boundary handing the activation tile to the
    ///   next stage's physical node over its (possibly multi-hop,
    ///   e.g. around a dead replica) route.
    fn event_latency_s(
        &self,
        net: &NetSim,
        w: &Workload,
        shards: &[Workload],
        reports: &[Report],
        nodes: &[usize],
    ) -> f64 {
        let n = reports.len();
        if n <= 1 {
            return reports.first().map(|r| r.latency_s).unwrap_or(0.0);
        }
        if self.strategy == ShardStrategy::Layers {
            let handoff = match w {
                Workload::ModelPass { model, n: toks, .. } => {
                    4.0 * (*toks as f64) * model.hidden as f64
                }
                _ => out_bytes(w) / n as f64,
            };
            let mut t = 0.0;
            for (i, r) in reports.iter().enumerate() {
                t += r.latency_s;
                if i + 1 < n {
                    let hop = Transfer {
                        src: nodes[i],
                        dst: nodes[i + 1],
                        bytes: handoff,
                        start_s: t,
                    };
                    t = net.simulate(&[hop]).makespan_s;
                }
            }
            return t;
        }
        let root = nodes[0];
        let transfers: Vec<Transfer> = (1..n)
            .map(|i| Transfer {
                src: nodes[i],
                dst: root,
                bytes: out_bytes(&shards[i]),
                start_s: reports[i].latency_s,
            })
            .collect();
        reports[0].latency_s.max(net.simulate(&transfers).makespan_s)
    }

    /// Aggregate one dispatch over an explicit live-replica set — pairs
    /// of (physical replica index, backend) — the shared body of
    /// [`Backend::run`] and [`Backend::run_degraded`].  The physical
    /// indices are what the event model routes between, so failover
    /// traffic detours around dead replicas' positions.
    fn run_on(&self, w: &Workload, live: &[(usize, &dyn Backend)]) -> Report {
        let shards = self.partition_n(w, live.len().max(1));
        let reports: Vec<Report> =
            shards.iter().zip(live).map(|(shard, (_, be))| be.run(shard)).collect();
        let mut out = Report {
            backend: self.id.clone(),
            workload: w.label(),
            ops: w.naive_adds(),
            ..Report::default()
        };
        if reports.is_empty() {
            out.energy_j = Some(0.0);
            return out;
        }
        // latency: concurrent shards bound by the critical (slowest)
        // replica; pipeline stages traverse sequentially — plus the
        // interconnect term either way (analytic), or the makespan of
        // the routed event timeline (net= model)
        let crit = reports
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.latency_s.total_cmp(&b.1.latency_s))
            .map(|(i, _)| i)
            .unwrap_or(0);
        out.latency_s = match &self.net {
            Some(net) => {
                let nodes: Vec<usize> =
                    live.iter().take(reports.len()).map(|(i, _)| *i).collect();
                self.event_latency_s(net, w, &shards, &reports, &nodes)
            }
            None => {
                let compute_latency = match self.strategy {
                    ShardStrategy::Layers => reports.iter().map(|r| r.latency_s).sum(),
                    _ => reports[crit].latency_s,
                };
                compute_latency + self.merge_latency_s(w, reports.len())
            }
        };
        // energy: sum, with one unmodelled replica nulling the total
        out.energy_j = reports.iter().fold(Some(0.0f64), |acc, r| match (acc, r.energy_j) {
            (Some(a), Some(e)) => Some(a + e),
            _ => None,
        });
        out.throughput_gops =
            if out.latency_s > 0.0 { out.ops as f64 / out.latency_s / 1e9 } else { 0.0 };
        // detail survives only when every active replica carries it
        if reports.iter().all(|r| {
            r.cycles.is_some()
                && r.phases.is_some()
                && r.activity.is_some()
                && r.energy_breakdown.is_some()
        }) {
            out.cycles = match self.strategy {
                ShardStrategy::Layers => Some(reports.iter().map(|r| r.cycles.unwrap()).sum()),
                _ => reports.iter().map(|r| r.cycles.unwrap()).max(),
            };
            out.phases = reports[crit].phases;
            out.utilization = reports[crit].utilization;
            let mut activity = crate::sim::Activity::default();
            let mut breakdown = crate::sim::EnergyBreakdown::default();
            for r in &reports {
                activity.add(r.activity.as_ref().unwrap());
                breakdown.add(r.energy_breakdown.as_ref().unwrap());
            }
            out.activity = Some(activity);
            out.energy_breakdown = Some(breakdown);
        }
        out
    }
}

impl Backend for Sharded {
    fn id(&self) -> &str {
        &self.id
    }

    fn describe(&self) -> BackendInfo {
        let base = self.inner[0].describe();
        let n = self.inner.len();
        BackendInfo {
            id: self.id.clone(),
            name: format!("{}× {}", n, base.name),
            kind: base.kind,
            freq_hz: base.freq_hz,
            pes: base.pes.map(|p| p * n),
            area_mm2: base.area_mm2.map(|a| a * n as f64),
            tech_nm: base.tech_nm,
            notes: {
                let mut notes = format!(
                    "{n} {} replicas, {}-partitioned; latency = {} + interconnect \
                     ({} GB/s link, {} us/hop; env PLATINUM_LINK_GBPS/PLATINUM_HOP_US), \
                     energy = sum",
                    base.id,
                    self.strategy.label(),
                    match self.strategy {
                        ShardStrategy::Layers => "stage sum",
                        _ => "max",
                    },
                    self.interconnect.link_bytes_per_s / 1e9,
                    self.interconnect.hop_s * 1e6
                );
                if let Some(net) = &self.net {
                    notes.push_str(&format!(
                        "; net={} event-driven interconnect ({}): latency = timeline \
                         makespan with link contention and compute/comm overlap",
                        net.topology().label(),
                        net.topology().shape(n)
                    ));
                }
                notes
            },
        }
    }

    fn run(&self, w: &Workload) -> Report {
        let live: Vec<(usize, &dyn Backend)> =
            self.inner.iter().enumerate().map(|(i, b)| (i, b.as_ref())).collect();
        self.run_on(w, &live)
    }

    fn replicas(&self) -> usize {
        self.inner.len()
    }

    fn run_degraded(&self, w: &Workload, alive: &[bool]) -> Report {
        let live: Vec<(usize, &dyn Backend)> = self
            .inner
            .iter()
            .enumerate()
            .filter(|(i, _)| alive.get(*i).copied().unwrap_or(true))
            .map(|(i, b)| (i, b.as_ref()))
            .collect();
        if live.len() == self.inner.len() {
            return self.run(w);
        }
        // failover: the dead replicas' shards fold into the survivors'
        // partitions — same aggregation physics, fewer chips (and under
        // the net= model the survivors' physical positions keep their
        // routes, so traffic detours around the dead slots)
        self.run_on(w, &live)
    }

    fn redistribute_cost_s(&self, weight_bytes: u64, survivors: usize) -> f64 {
        if survivors == 0 || self.inner.len() <= 1 {
            return 0.0;
        }
        // The failed chip's weight shard must be re-shipped to the
        // survivors over the modelled link (the ROADMAP's still-open
        // weight-redistribution cost when shard assignment changes).
        let shard_bytes = weight_bytes as f64 / self.inner.len() as f64;
        match &self.net {
            // analytic: one hop to fan the stripe out, then the shard's
            // bytes serialized over a single link from the weight store
            None => self.interconnect.hop_s + shard_bytes / self.interconnect.link_bytes_per_s,
            // event model: the shard fans out from the weight store
            // (node 0) to the survivors in equal slices at t=0; the
            // timeline's makespan prices the near-source link
            // contention the analytic term cannot see
            Some(net) => {
                let fan = survivors.min(self.inner.len() - 1);
                let per = shard_bytes / fan as f64;
                let transfers: Vec<Transfer> = (1..=fan)
                    .map(|d| Transfer { src: 0, dst: d, bytes: per, start_s: 0.0 })
                    .collect();
                net.simulate(&transfers).makespan_s
            }
        }
    }
}

/// Total output bytes of a workload (i32 accumulator words) — what the
/// gather/handoff traffic ships between chips.
fn out_bytes(w: &Workload) -> f64 {
    w.kernels().iter().map(|(g, c)| 4.0 * (g.m * g.n) as f64 * *c as f64).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::backends::{EyerissBackend, PlatinumBackend};
    use crate::models::{B158_3B, PREFILL_N};

    fn sharded_platinum(n: usize, strategy: ShardStrategy) -> Sharded {
        let inner: Vec<Box<dyn Backend>> =
            (0..n).map(|_| Box::new(PlatinumBackend::ternary()) as Box<dyn Backend>).collect();
        Sharded::new(inner, strategy).unwrap()
    }

    #[test]
    fn strategy_labels_roundtrip() {
        for st in ShardStrategy::ALL {
            assert_eq!(ShardStrategy::parse(st.label()), Some(st));
        }
        assert_eq!(ShardStrategy::parse("diagonal"), None);
    }

    #[test]
    fn canonical_id_elides_default_strategy() {
        assert_eq!(sharded_platinum(4, ShardStrategy::Rows).id(), "sharded:4:platinum-ternary");
        assert_eq!(
            sharded_platinum(2, ShardStrategy::Batch).id(),
            "sharded:2:batch:platinum-ternary"
        );
    }

    #[test]
    fn empty_replica_set_is_an_error() {
        assert!(Sharded::new(Vec::new(), ShardStrategy::Rows).is_err());
    }

    #[test]
    fn rows_partition_covers_all_rows() {
        let sh = sharded_platinum(4, ShardStrategy::Rows);
        let shards = sh.partition(&Workload::Kernel(Gemm::new(10, 20, 8)));
        assert_eq!(shards.len(), 4);
        let total_m: usize = shards
            .iter()
            .flat_map(|s| s.kernels())
            .map(|(g, _)| {
                assert_eq!((g.k, g.n), (20, 8));
                g.m
            })
            .sum();
        assert_eq!(total_m, 10);
    }

    #[test]
    fn batch_partition_splits_n() {
        let sh = sharded_platinum(3, ShardStrategy::Batch);
        let shards = sh.partition(&Workload::Kernel(Gemm::new(16, 20, 7)));
        let ns: Vec<usize> = shards.iter().flat_map(|s| s.kernels()).map(|(g, _)| g.n).collect();
        assert_eq!(ns.iter().sum::<usize>(), 7);
        assert_eq!(ns.len(), 3);
    }

    #[test]
    fn batch_partition_splits_occurrence_counts() {
        // a single high-count kernel must still parallelize: the
        // occurrence counts split, not the distinct-kernel list
        let sh = sharded_platinum(4, ShardStrategy::Batch);
        let g = Gemm::new(16, 20, 8);
        let shards = sh.partition(&Workload::Counted(vec![(g, 100)]));
        let counts: Vec<usize> = shards
            .iter()
            .flat_map(|s| s.kernels())
            .map(|(sg, c)| {
                assert_eq!(sg, g);
                c
            })
            .collect();
        assert_eq!(counts, vec![25, 25, 25, 25]);
    }

    #[test]
    fn layers_partition_splits_model_depth() {
        let sh = sharded_platinum(4, ShardStrategy::Layers);
        let shards = sh.partition(&Workload::prefill(B158_3B));
        let layers: Vec<usize> = shards
            .iter()
            .map(|s| match s {
                Workload::ModelPass { model, n, .. } => {
                    assert_eq!(*n, PREFILL_N);
                    model.layers
                }
                other => panic!("layer shard must stay a model pass, got {other:?}"),
            })
            .collect();
        assert_eq!(layers.iter().sum::<usize>(), B158_3B.layers);
        assert_eq!(layers.len(), 4);
    }

    #[test]
    fn layers_latency_is_stage_sum_not_max() {
        // one dispatch traverses the pipeline sequentially: reporting
        // max(stages) would claim an impossible ~N× single-pass speedup
        let sh = sharded_platinum(2, ShardStrategy::Layers);
        let w = Workload::prefill(B158_3B);
        let inner = PlatinumBackend::ternary();
        let parts: Vec<Report> = sh.partition(&w).iter().map(|s| inner.run(s)).collect();
        let stage_sum: f64 = parts.iter().map(|r| r.latency_s).sum();
        let r = sh.run(&w);
        let expect = stage_sum + sh.merge_latency_s(&w, parts.len());
        assert!((r.latency_s - expect).abs() <= expect * 1e-12, "sum-of-stages rule");
        // and therefore never faster than the whole pass on one chip
        let single = inner.run(&w);
        assert!(r.latency_s >= single.latency_s * 0.99);
    }

    #[test]
    fn merge_term_zero_for_single_active_replica() {
        let sh = sharded_platinum(4, ShardStrategy::Rows);
        let w = Workload::Kernel(Gemm::new(64, 40, 8));
        assert_eq!(sh.merge_latency_s(&w, 1), 0.0);
        assert!(sh.merge_latency_s(&w, 2) > 0.0);
        assert!(sh.merge_latency_s(&w, 4) > sh.merge_latency_s(&w, 2));
    }

    #[test]
    fn interconnect_constants_come_from_env() {
        // direct math: a faster link / cheaper hop shrinks the merge term
        let inner = |n: usize| -> Vec<Box<dyn Backend>> {
            (0..n).map(|_| Box::new(PlatinumBackend::ternary()) as Box<dyn Backend>).collect()
        };
        let w = Workload::Kernel(Gemm::new(512, 40, 8));
        let slow = Sharded::with_interconnect(
            inner(4),
            ShardStrategy::Rows,
            Interconnect { link_bytes_per_s: 16e9, hop_s: 1e-6 },
        )
        .unwrap();
        let fast = Sharded::with_interconnect(
            inner(4),
            ShardStrategy::Rows,
            Interconnect { link_bytes_per_s: 32e9, hop_s: 0.5e-6 },
        )
        .unwrap();
        assert!(fast.merge_latency_s(&w, 4) < slow.merge_latency_s(&w, 4));

        // env round-trip: calibration knobs reach registry-built
        // composites and are surfaced in the notes.  Values chosen
        // strictly faster than the defaults so any concurrently-built
        // composite in another test only gets cheaper interconnect.
        std::env::set_var("PLATINUM_LINK_GBPS", "32");
        std::env::set_var("PLATINUM_HOP_US", "0.5");
        let ic = Interconnect::from_env();
        let sh = sharded_platinum(2, ShardStrategy::Rows);
        std::env::remove_var("PLATINUM_LINK_GBPS");
        std::env::remove_var("PLATINUM_HOP_US");
        let ic = ic.unwrap();
        assert_eq!(ic.link_bytes_per_s, 32e9);
        assert_eq!(ic.hop_s, 0.5e-6);
        let notes = sh.describe().notes;
        assert!(notes.contains("32 GB/s") && notes.contains("0.5 us/hop"), "{notes}");
        assert!(notes.contains("PLATINUM_LINK_GBPS"), "{notes}");
        // junk values are a loud startup error naming variable + value,
        // never a silent fallback to the defaults
        std::env::set_var("PLATINUM_LINK_GBPS", "not-a-number");
        let err = Interconnect::from_env();
        std::env::remove_var("PLATINUM_LINK_GBPS");
        let msg = err.unwrap_err().to_string();
        assert!(msg.contains("PLATINUM_LINK_GBPS") && msg.contains("not-a-number"), "{msg}");
        std::env::set_var("PLATINUM_HOP_US", "-3");
        let err = Interconnect::from_env();
        std::env::remove_var("PLATINUM_HOP_US");
        let msg = err.unwrap_err().to_string();
        assert!(msg.contains("PLATINUM_HOP_US") && msg.contains("-3"), "{msg}");
    }

    #[test]
    fn failover_repartitions_across_survivors_and_prices_redistribution() {
        let sh = sharded_platinum(4, ShardStrategy::Rows);
        let w = Workload::Kernel(Gemm::new(4320, 2080, 32));
        let healthy = sh.run(&w);
        // replica 2 dead: survivors each absorb a third of its stripe
        let degraded = Backend::run_degraded(&sh, &w, &[true, true, false, true]);
        assert_eq!(degraded.backend, healthy.backend);
        assert_eq!(degraded.ops, healthy.ops, "no work is lost in failover");
        assert!(
            degraded.latency_s > healthy.latency_s,
            "3 survivors must be slower than 4 replicas"
        );
        // all-alive mask is exactly the healthy path
        let same = Backend::run_degraded(&sh, &w, &[true; 4]);
        assert_eq!(same.latency_s, healthy.latency_s);
        // redistribution stall is positive and shrinks with a faster link
        let cost = Backend::redistribute_cost_s(&sh, 10_000_000, 3);
        assert!(cost > 0.0);
        let fast = Sharded::with_interconnect(
            (0..4).map(|_| Box::new(PlatinumBackend::ternary()) as Box<dyn Backend>).collect(),
            ShardStrategy::Rows,
            Interconnect { link_bytes_per_s: 64e9, hop_s: 1e-6 },
        )
        .unwrap();
        assert!(Backend::redistribute_cost_s(&fast, 10_000_000, 3) < cost);
        // single-chip backends have nothing to redistribute
        assert_eq!(Backend::redistribute_cost_s(&PlatinumBackend::ternary(), 1 << 20, 1), 0.0);
        assert_eq!(Backend::replicas(&PlatinumBackend::ternary()), 1);
        assert_eq!(Backend::replicas(&sh), 4);
    }

    #[test]
    fn describe_scales_area_and_pes() {
        let single = PlatinumBackend::ternary().describe();
        let info = sharded_platinum(4, ShardStrategy::Rows).describe();
        assert_eq!(info.id, "sharded:4:platinum-ternary");
        assert_eq!(info.pes, single.pes.map(|p| p * 4));
        let (a4, a1) = (info.area_mm2.unwrap(), single.area_mm2.unwrap());
        assert!((a4 - 4.0 * a1).abs() < 1e-12);
        assert!(info.notes.contains("rows"));
    }

    #[test]
    fn run_reports_detail_and_scaling() {
        // deep-k, tall-m kernel: the row-shard compute saving has to
        // dominate the interconnect gather (which scales with m·n only)
        let g = Gemm::new(4320, 2080, 32);
        let single = PlatinumBackend::ternary().run(&Workload::Kernel(g));
        let r = sharded_platinum(4, ShardStrategy::Rows).run(&Workload::Kernel(g));
        assert_eq!(r.backend, "sharded:4:platinum-ternary");
        assert_eq!(r.ops, single.ops);
        assert!(r.latency_s < single.latency_s, "4 chips must beat 1 on a tall kernel");
        assert!(r.cycles.is_some() && r.activity.is_some() && r.energy_breakdown.is_some());
        // cross-chip energy exceeds a single chip's (construct overhead
        // is replicated per shard dispatch)
        assert!(r.energy_j.unwrap() > 0.0);
    }

    fn sharded_net(n: usize, strategy: ShardStrategy, topo: Topology) -> Sharded {
        // explicit default calibration: immune to the env round-trip
        // test mutating PLATINUM_* in a sibling thread
        let inner: Vec<Box<dyn Backend>> =
            (0..n).map(|_| Box::new(PlatinumBackend::ternary()) as Box<dyn Backend>).collect();
        Sharded::with_net_interconnect(inner, strategy, topo, Interconnect::default()).unwrap()
    }

    fn sharded_analytic(n: usize, strategy: ShardStrategy) -> Sharded {
        let inner: Vec<Box<dyn Backend>> =
            (0..n).map(|_| Box::new(PlatinumBackend::ternary()) as Box<dyn Backend>).collect();
        Sharded::with_interconnect(inner, strategy, Interconnect::default()).unwrap()
    }

    #[test]
    fn net_canonical_id_and_notes() {
        let sh = sharded_net(4, ShardStrategy::Rows, Topology::Ring);
        assert_eq!(sh.id(), "sharded:4:net=ring:platinum-ternary");
        assert_eq!(sh.net_topology(), Some(Topology::Ring));
        let notes = sh.describe().notes;
        assert!(notes.contains("net=ring") && notes.contains("4-chip ring"), "{notes}");
        assert_eq!(
            sharded_net(4, ShardStrategy::Batch, Topology::Mesh2d).id(),
            "sharded:4:batch:net=mesh2d:platinum-ternary"
        );
        assert_eq!(sharded_analytic(4, ShardStrategy::Rows).net_topology(), None);
    }

    #[test]
    fn net_rejects_mismatched_replica_counts() {
        let inner = |n: usize| -> Vec<Box<dyn Backend>> {
            (0..n).map(|_| Box::new(PlatinumBackend::ternary()) as Box<dyn Backend>).collect()
        };
        let err = Sharded::with_net_interconnect(
            inner(7),
            ShardStrategy::Rows,
            Topology::Mesh2d,
            Interconnect::default(),
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("mesh2d") && err.contains('7'), "{err}");
        let err = Sharded::with_net_interconnect(
            inner(6),
            ShardStrategy::Rows,
            Topology::FatTree,
            Interconnect::default(),
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("power-of-two") && err.contains('6'), "{err}");
    }

    #[test]
    fn net_single_replica_is_passthrough() {
        let w = Workload::Kernel(Gemm::new(64, 40, 8));
        let single = PlatinumBackend::ternary().run(&w);
        for t in Topology::ALL {
            let r = sharded_net(1, ShardStrategy::Rows, t).run(&w);
            assert_eq!(r.latency_s.to_bits(), single.latency_s.to_bits(), "{}", t.label());
        }
    }

    #[test]
    fn net_contention_free_gather_matches_analytic() {
        // 2 replicas on a ring: one single-hop stripe, no contention —
        // the event timeline must reproduce the analytic model (the
        // tolerance pin the ROADMAP's validation follow-on asks for)
        let w = Workload::Kernel(Gemm::new(4320, 2080, 32));
        let analytic = sharded_analytic(2, ShardStrategy::Rows).run(&w).latency_s;
        let event = sharded_net(2, ShardStrategy::Rows, Topology::Ring).run(&w).latency_s;
        let gap = (event - analytic).abs() / analytic;
        assert!(gap < 0.10, "contention-free gap {gap} must stay under 10%");
    }

    #[test]
    fn net_layers_pipeline_matches_analytic_handoff() {
        // a 2-stage pipeline has one boundary and one route link: the
        // event handoff degenerates to the analytic term exactly
        let w = Workload::prefill(B158_3B);
        let analytic = sharded_analytic(2, ShardStrategy::Layers).run(&w).latency_s;
        let event = sharded_net(2, ShardStrategy::Layers, Topology::Ring).run(&w).latency_s;
        assert!((event - analytic).abs() <= analytic * 1e-9, "{event} vs {analytic}");
    }

    #[test]
    fn net_congested_gather_diverges_from_analytic() {
        // 8 stripes converging on one root share the ring's two inbound
        // links: the event timeline prices serialization + overlap the
        // log-tree analytic term cannot, so the models must separate
        let w = Workload::Kernel(Gemm::new(4320, 2080, 32));
        let analytic = sharded_analytic(8, ShardStrategy::Rows).run(&w);
        let sh = sharded_net(8, ShardStrategy::Rows, Topology::Ring);
        let event = sh.run(&w);
        assert_eq!(event.ops, analytic.ops);
        assert!(event.cycles.is_some(), "detail survives under the net model");
        let diff = (event.latency_s - analytic.latency_s).abs();
        assert!(diff > 5e-6, "congested models must diverge, diff {diff}");
    }

    #[test]
    fn net_failover_prices_redistribution_on_the_timeline() {
        let sh = sharded_net(4, ShardStrategy::Rows, Topology::Ring);
        let w = Workload::Kernel(Gemm::new(4320, 2080, 32));
        let healthy = sh.run(&w);
        let degraded = Backend::run_degraded(&sh, &w, &[true, true, false, true]);
        assert_eq!(degraded.ops, healthy.ops, "no work lost in net failover");
        assert!(degraded.latency_s > healthy.latency_s);
        // redistribution: the event fan-out from the weight store sees
        // link contention; the analytic single-link formula does not
        let cost_event = Backend::redistribute_cost_s(&sh, 12_000_000, 3);
        let cost_analytic =
            Backend::redistribute_cost_s(&sharded_analytic(4, ShardStrategy::Rows), 12_000_000, 3);
        assert!(cost_event > 0.0 && cost_analytic > 0.0);
        assert!(
            (cost_event - cost_analytic).abs() > 1e-7,
            "event {cost_event} vs analytic {cost_analytic} must differ"
        );
    }

    #[test]
    fn detail_drops_when_inner_has_none() {
        // eyeriss reports scalars only: the composite must not invent
        // phantom cycle detail
        let inner: Vec<Box<dyn Backend>> =
            (0..2).map(|_| Box::new(EyerissBackend) as Box<dyn Backend>).collect();
        let sh = Sharded::new(inner, ShardStrategy::Rows).unwrap();
        let r = sh.run(&Workload::Kernel(Gemm::new(64, 40, 8)));
        assert!(r.cycles.is_none() && r.phases.is_none());
        assert!(r.energy_j.unwrap() > 0.0 && r.latency_s > 0.0);
    }
}
