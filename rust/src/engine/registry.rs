//! Name → backend construction: callers select execution systems with
//! strings (`--backend platinum-ternary,prosperity,tmac-cpu`) and every
//! frontend — CLI, DSE, benches, serving — goes through the same table.
//!
//! New accelerators plug in via [`Registry::register`]; nothing else in
//! the crate needs to change to make them reachable from every surface.
//!
//! Besides the fixed ids the table also resolves the **parameterized
//! composite grammar** [`SHARDED_GRAMMAR`]: `sharded:4:platinum-ternary`
//! builds four Platinum replicas behind one [`Backend`] (see
//! [`super::Sharded`]), recursively, so composites nest.

use super::backends::{
    EyerissBackend, PlatinumBackend, PlatinumCpuBackend, ProsperityBackend, TMacBackend,
    TMacCpuBackend,
};
use super::sharded::{ShardStrategy, Sharded};
use super::Backend;
use crate::sim::net::Topology;
use anyhow::{anyhow, bail, Result};

type Builder = fn() -> Box<dyn Backend>;

fn build_platinum_ternary() -> Box<dyn Backend> {
    Box::new(PlatinumBackend::ternary())
}

fn build_platinum_bitserial() -> Box<dyn Backend> {
    Box::new(PlatinumBackend::bitserial())
}

fn build_eyeriss() -> Box<dyn Backend> {
    Box::new(EyerissBackend)
}

fn build_prosperity() -> Box<dyn Backend> {
    Box::new(ProsperityBackend)
}

fn build_tmac() -> Box<dyn Backend> {
    Box::new(TMacBackend)
}

fn build_tmac_cpu() -> Box<dyn Backend> {
    Box::new(TMacCpuBackend::new())
}

fn build_platinum_cpu() -> Box<dyn Backend> {
    Box::new(PlatinumCpuBackend::new())
}

/// Backend ids used for paper-style cross-system comparisons (every
/// modelled system; excludes the measured `tmac-cpu`/`platinum-cpu`
/// kernels, whose wall-clock measurement of a full model pass is
/// prohibitively slow and machine-dependent).
pub const COMPARISON_IDS: &str = "platinum-ternary,platinum-bitserial,eyeriss,prosperity,tmac";

/// The parameterized multi-chip id form [`Registry::build`] accepts on
/// top of the fixed table: replica count, optional partition strategy
/// (default `rows`), optional event-driven network topology (default:
/// the analytic interconnect), then any resolvable inner id
/// (composites nest).
pub const SHARDED_GRAMMAR: &str =
    "sharded:<replicas>[:rows|batch|layers][:net=ring|mesh2d|fattree]:<inner-id>";

/// Ceiling on the TOTAL chip count a `sharded:` id may construct,
/// multiplied across nesting levels — a typo/DoS guard (each replica
/// is a live backend instance), far above any plausible chip count.
const MAX_REPLICAS: usize = 4096;

/// Total chip count the nested `sharded:` prefixes of an id multiply
/// out to (1 for a plain backend id).  Malformed tails stop the walk —
/// the recursive build diagnoses them with a proper error.
fn nested_replicas(mut spec: &str) -> u128 {
    let mut total: u128 = 1;
    while let Some(rest) = spec.strip_prefix("sharded:") {
        let Some((count, tail)) = rest.split_once(':') else { break };
        let Ok(n) = count.parse::<u128>() else { break };
        total = total.saturating_mul(n.max(1));
        // skip the optional strategy token, then the optional net= token
        spec = match tail.split_once(':') {
            Some((tok, inner)) if ShardStrategy::parse(tok).is_some() => {
                match inner.split_once(':') {
                    Some((t2, inner2)) if t2.starts_with("net=") => inner2,
                    _ => inner,
                }
            }
            Some((tok, inner)) if tok.starts_with("net=") => inner,
            _ => tail,
        };
    }
    total
}

/// Constructs [`Backend`]s by id string.
pub struct Registry {
    entries: Vec<(&'static str, Builder)>,
}

impl Registry {
    /// Every system the repo models, under its canonical id.
    pub fn with_defaults() -> Registry {
        let mut r = Registry { entries: Vec::new() };
        r.register("platinum-ternary", build_platinum_ternary);
        r.register("platinum-bitserial", build_platinum_bitserial);
        r.register("eyeriss", build_eyeriss);
        r.register("prosperity", build_prosperity);
        r.register("tmac", build_tmac);
        r.register("tmac-cpu", build_tmac_cpu);
        r.register("platinum-cpu", build_platinum_cpu);
        r
    }

    /// Add (or override) a backend constructor.
    pub fn register(&mut self, id: &'static str, builder: Builder) {
        if let Some(slot) = self.entries.iter_mut().find(|(eid, _)| *eid == id) {
            slot.1 = builder;
        } else {
            self.entries.push((id, builder));
        }
    }

    /// Registered ids, in registration order.
    pub fn ids(&self) -> Vec<&'static str> {
        self.entries.iter().map(|(id, _)| *id).collect()
    }

    /// Construct one backend by id — a fixed table entry or a
    /// [`SHARDED_GRAMMAR`] composite.
    pub fn build(&self, id: &str) -> Result<Box<dyn Backend>> {
        let id = id.trim();
        if let Some(spec) = id.strip_prefix("sharded:") {
            return self.build_sharded(spec);
        }
        match self.entries.iter().find(|(eid, _)| *eid == id) {
            Some((_, builder)) => Ok(builder()),
            None => bail!(
                "unknown backend {:?}; registered backends: {}; \
                 composites: {SHARDED_GRAMMAR}",
                id,
                self.ids().join(", ")
            ),
        }
    }

    /// Resolve the tail of a `sharded:` id (everything after the
    /// prefix): `<replicas>[:<strategy>][:net=<topology>]:<inner-id>`.
    fn build_sharded(&self, spec: &str) -> Result<Box<dyn Backend>> {
        let (count, tail) = spec
            .split_once(':')
            .ok_or_else(|| anyhow!("malformed sharded id; expected {SHARDED_GRAMMAR}"))?;
        let replicas: usize = count.parse().map_err(|_| {
            anyhow!("sharded replica count {count:?} is not a number; expected {SHARDED_GRAMMAR}")
        })?;
        if replicas == 0 {
            bail!("sharded replica count must be >= 1; expected {SHARDED_GRAMMAR}");
        }
        // the strategy segment is optional; an unrecognized token here
        // is part of the inner id and diagnosed by the recursive build
        let (strategy, tail) = match tail.split_once(':') {
            Some((tok, rest)) => match ShardStrategy::parse(tok) {
                Some(st) => (st, rest),
                None => (ShardStrategy::Rows, tail),
            },
            None => (ShardStrategy::Rows, tail),
        };
        // the net= segment selects the event-driven interconnect; an
        // unknown topology or a count the topology cannot form is a
        // hard error naming the offending id — never a silent fallback
        // to the analytic model
        let (topology, inner_id) = match tail.split_once(':') {
            Some((tok, rest)) if tok.starts_with("net=") => {
                let name = &tok[4..];
                let t = Topology::parse(name).ok_or_else(|| {
                    anyhow!(
                        "unknown net topology {name:?} in backend id \"sharded:{spec}\"; \
                         known topologies: ring, mesh2d, fattree"
                    )
                })?;
                (Some(t), rest)
            }
            _ if tail.starts_with("net=") => {
                bail!(
                    "malformed backend id \"sharded:{spec}\": nothing after the net= \
                     segment; expected {SHARDED_GRAMMAR}"
                );
            }
            _ => (None, tail),
        };
        if let Some(t) = topology {
            t.validate(replicas)
                .map_err(|e| anyhow!("backend id \"sharded:{spec}\": {e}"))?;
        }
        // cap the TOTAL chip count: nested composites multiply, so a
        // per-level check alone would let sharded:4096:sharded:4096:…
        // eagerly construct millions of backend instances
        let total = (replicas as u128).saturating_mul(nested_replicas(inner_id));
        if total > MAX_REPLICAS as u128 {
            bail!(
                "sharded id would construct {total} chips (nested counts multiply), \
                 exceeding the {MAX_REPLICAS} sanity cap"
            );
        }
        let inner: Vec<Box<dyn Backend>> =
            (0..replicas).map(|_| self.build(inner_id)).collect::<Result<_>>()?;
        let sharded = match topology {
            None => Sharded::new(inner, strategy)?,
            Some(t) => Sharded::with_net(inner, strategy, t)?,
        };
        Ok(Box::new(sharded))
    }

    /// Construct several backends from a comma-separated selection
    /// (`"all"` expands to every registered id).
    pub fn build_selection(&self, spec: &str) -> Result<Vec<Box<dyn Backend>>> {
        if spec.trim() == "all" {
            return self.entries.iter().map(|(_, builder)| Ok(builder())).collect();
        }
        spec.split(',')
            .map(str::trim)
            .filter(|id| !id.is_empty())
            .map(|id| self.build(id))
            .collect()
    }
}

impl Default for Registry {
    fn default() -> Registry {
        Registry::with_defaults()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::Gemm;
    use crate::engine::Workload;

    /// Every registered id constructs, self-identifies, and runs a small
    /// kernel workload end to end.
    #[test]
    fn registry_roundtrip_every_id() {
        let reg = Registry::with_defaults();
        let g = Gemm::new(64, 40, 8);
        for id in reg.ids() {
            let be = reg.build(id).unwrap();
            assert_eq!(be.id(), id, "backend id mismatch");
            assert_eq!(be.describe().id, id, "describe() id mismatch");
            let r = be.run(&Workload::Kernel(g));
            assert_eq!(r.backend, id);
            assert_eq!(r.ops, g.naive_adds());
            assert!(r.latency_s > 0.0, "{id}: zero latency");
        }
    }

    #[test]
    fn unknown_id_lists_known_backends_and_sharded_grammar() {
        let err = Registry::with_defaults().build("sparsecore").unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("sparsecore") && msg.contains("platinum-ternary"), "{msg}");
        // the parameterized form must be discoverable from the error
        assert!(msg.contains(SHARDED_GRAMMAR), "{msg}");
    }

    #[test]
    fn sharded_ids_build_and_canonicalize() {
        let reg = Registry::with_defaults();
        for (spec, canon) in [
            ("sharded:4:platinum-ternary", "sharded:4:platinum-ternary"),
            // explicit default strategy canonicalizes to the short form
            ("sharded:4:rows:platinum-ternary", "sharded:4:platinum-ternary"),
            ("sharded:2:batch:eyeriss", "sharded:2:batch:eyeriss"),
            ("sharded:3:layers:prosperity", "sharded:3:layers:prosperity"),
            // composites nest (pipeline of row-parallel groups)
            (
                "sharded:2:layers:sharded:2:platinum-ternary",
                "sharded:2:layers:sharded:2:platinum-ternary",
            ),
        ] {
            let be = reg.build(spec).unwrap();
            assert_eq!(be.id(), canon, "{spec}");
            assert_eq!(be.describe().id, canon, "{spec}");
            let r = be.run(&Workload::Kernel(Gemm::new(64, 40, 8)));
            assert_eq!(r.backend, canon);
            assert!(r.latency_s > 0.0, "{spec}");
        }
    }

    #[test]
    fn net_sharded_ids_build_and_canonicalize() {
        let reg = Registry::with_defaults();
        for (spec, canon) in [
            ("sharded:4:net=mesh2d:platinum-ternary", "sharded:4:net=mesh2d:platinum-ternary"),
            // explicit default strategy still elides
            ("sharded:4:rows:net=ring:platinum-ternary", "sharded:4:net=ring:platinum-ternary"),
            ("sharded:2:batch:net=ring:eyeriss", "sharded:2:batch:net=ring:eyeriss"),
            ("sharded:8:net=fattree:platinum-ternary", "sharded:8:net=fattree:platinum-ternary"),
            // composites nest with independent network models per level
            (
                "sharded:2:layers:net=ring:sharded:2:net=ring:platinum-ternary",
                "sharded:2:layers:net=ring:sharded:2:net=ring:platinum-ternary",
            ),
        ] {
            let be = reg.build(spec).unwrap();
            assert_eq!(be.id(), canon, "{spec}");
            let r = be.run(&Workload::Kernel(Gemm::new(64, 40, 8)));
            assert_eq!(r.backend, canon);
            assert!(r.latency_s > 0.0, "{spec}");
        }
    }

    #[test]
    fn net_grammar_errors_name_the_offending_id() {
        let reg = Registry::with_defaults();
        // unknown topology token
        let err = reg.build("sharded:4:net=torus:platinum-ternary").unwrap_err().to_string();
        assert!(err.contains("torus"), "{err}");
        assert!(err.contains("sharded:4:net=torus:platinum-ternary"), "{err}");
        assert!(err.contains("ring") && err.contains("mesh2d") && err.contains("fattree"), "{err}");
        // topology/replica-count mismatches fail at resolve time
        let err = reg.build("sharded:7:net=mesh2d:platinum-ternary").unwrap_err().to_string();
        assert!(err.contains("sharded:7:net=mesh2d:platinum-ternary"), "{err}");
        assert!(err.contains("rectangular"), "{err}");
        let err = reg.build("sharded:6:net=fattree:platinum-ternary").unwrap_err().to_string();
        assert!(err.contains("sharded:6:net=fattree:platinum-ternary"), "{err}");
        assert!(err.contains("power-of-two"), "{err}");
        // net= with no inner id after it
        let err = reg.build("sharded:4:net=ring").unwrap_err().to_string();
        assert!(err.contains("sharded:4:net=ring"), "{err}");
        // the chip-count cap still sees through net= tokens when
        // walking nested composites (no construction happens)
        let err = reg
            .build("sharded:2:net=ring:sharded:2049:net=ring:platinum-ternary")
            .unwrap_err()
            .to_string();
        assert!(err.contains("4098") && err.contains("cap"), "{err}");
    }

    #[test]
    fn malformed_sharded_ids_error_clearly() {
        let reg = Registry::with_defaults();
        for bad in [
            "sharded:",
            "sharded:4",
            "sharded:zero:platinum-ternary",
            "sharded:0:platinum-ternary",
            "sharded:9999999:platinum-ternary",
            // nested counts multiply: each level is under the cap, the
            // product is not
            "sharded:4096:sharded:4096:platinum-ternary",
            "sharded:2:diagonal-strategy",
            "sharded:2:rows:nope",
        ] {
            let err = reg.build(bad).unwrap_err().to_string();
            assert!(err.contains("sharded") || err.contains("unknown backend"), "{bad}: {err}");
        }
    }

    #[test]
    fn selection_parses_csv_and_all() {
        let reg = Registry::with_defaults();
        let sel = reg.build_selection(" platinum-ternary , tmac ").unwrap();
        assert_eq!(sel.len(), 2);
        assert_eq!(sel[1].id(), "tmac");
        assert_eq!(reg.build_selection("all").unwrap().len(), reg.ids().len());
        assert!(reg.build_selection("platinum-ternary,nope").is_err());
    }

    #[test]
    fn comparison_ids_all_resolve() {
        let reg = Registry::with_defaults();
        let sel = reg.build_selection(COMPARISON_IDS).unwrap();
        assert_eq!(sel.len(), 5);
    }
}
