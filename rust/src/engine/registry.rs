//! Name → backend construction: callers select execution systems with
//! strings (`--backend platinum-ternary,prosperity,tmac-cpu`) and every
//! frontend — CLI, DSE, benches, serving — goes through the same table.
//!
//! New accelerators plug in via [`Registry::register`]; nothing else in
//! the crate needs to change to make them reachable from every surface.

use super::backends::{
    EyerissBackend, PlatinumBackend, PlatinumCpuBackend, ProsperityBackend, TMacBackend,
    TMacCpuBackend,
};
use super::Backend;
use anyhow::{bail, Result};

type Builder = fn() -> Box<dyn Backend>;

fn build_platinum_ternary() -> Box<dyn Backend> {
    Box::new(PlatinumBackend::ternary())
}

fn build_platinum_bitserial() -> Box<dyn Backend> {
    Box::new(PlatinumBackend::bitserial())
}

fn build_eyeriss() -> Box<dyn Backend> {
    Box::new(EyerissBackend)
}

fn build_prosperity() -> Box<dyn Backend> {
    Box::new(ProsperityBackend)
}

fn build_tmac() -> Box<dyn Backend> {
    Box::new(TMacBackend)
}

fn build_tmac_cpu() -> Box<dyn Backend> {
    Box::new(TMacCpuBackend::new())
}

fn build_platinum_cpu() -> Box<dyn Backend> {
    Box::new(PlatinumCpuBackend::new())
}

/// Backend ids used for paper-style cross-system comparisons (every
/// modelled system; excludes the measured `tmac-cpu`/`platinum-cpu`
/// kernels, whose wall-clock measurement of a full model pass is
/// prohibitively slow and machine-dependent).
pub const COMPARISON_IDS: &str = "platinum-ternary,platinum-bitserial,eyeriss,prosperity,tmac";

/// Constructs [`Backend`]s by id string.
pub struct Registry {
    entries: Vec<(&'static str, Builder)>,
}

impl Registry {
    /// Every system the repo models, under its canonical id.
    pub fn with_defaults() -> Registry {
        let mut r = Registry { entries: Vec::new() };
        r.register("platinum-ternary", build_platinum_ternary);
        r.register("platinum-bitserial", build_platinum_bitserial);
        r.register("eyeriss", build_eyeriss);
        r.register("prosperity", build_prosperity);
        r.register("tmac", build_tmac);
        r.register("tmac-cpu", build_tmac_cpu);
        r.register("platinum-cpu", build_platinum_cpu);
        r
    }

    /// Add (or override) a backend constructor.
    pub fn register(&mut self, id: &'static str, builder: Builder) {
        if let Some(slot) = self.entries.iter_mut().find(|(eid, _)| *eid == id) {
            slot.1 = builder;
        } else {
            self.entries.push((id, builder));
        }
    }

    /// Registered ids, in registration order.
    pub fn ids(&self) -> Vec<&'static str> {
        self.entries.iter().map(|(id, _)| *id).collect()
    }

    /// Construct one backend by id.
    pub fn build(&self, id: &str) -> Result<Box<dyn Backend>> {
        match self.entries.iter().find(|(eid, _)| *eid == id.trim()) {
            Some((_, builder)) => Ok(builder()),
            None => bail!(
                "unknown backend {:?}; registered backends: {}",
                id.trim(),
                self.ids().join(", ")
            ),
        }
    }

    /// Construct several backends from a comma-separated selection
    /// (`"all"` expands to every registered id).
    pub fn build_selection(&self, spec: &str) -> Result<Vec<Box<dyn Backend>>> {
        if spec.trim() == "all" {
            return self.entries.iter().map(|(_, builder)| Ok(builder())).collect();
        }
        spec.split(',')
            .map(str::trim)
            .filter(|id| !id.is_empty())
            .map(|id| self.build(id))
            .collect()
    }
}

impl Default for Registry {
    fn default() -> Registry {
        Registry::with_defaults()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::Gemm;
    use crate::engine::Workload;

    /// Every registered id constructs, self-identifies, and runs a small
    /// kernel workload end to end.
    #[test]
    fn registry_roundtrip_every_id() {
        let reg = Registry::with_defaults();
        let g = Gemm::new(64, 40, 8);
        for id in reg.ids() {
            let be = reg.build(id).unwrap();
            assert_eq!(be.id(), id, "backend id mismatch");
            assert_eq!(be.describe().id, id, "describe() id mismatch");
            let r = be.run(&Workload::Kernel(g));
            assert_eq!(r.backend, id);
            assert_eq!(r.ops, g.naive_adds());
            assert!(r.latency_s > 0.0, "{id}: zero latency");
        }
    }

    #[test]
    fn unknown_id_lists_known_backends() {
        let err = Registry::with_defaults().build("sparsecore").unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("sparsecore") && msg.contains("platinum-ternary"), "{msg}");
    }

    #[test]
    fn selection_parses_csv_and_all() {
        let reg = Registry::with_defaults();
        let sel = reg.build_selection(" platinum-ternary , tmac ").unwrap();
        assert_eq!(sel.len(), 2);
        assert_eq!(sel[1].id(), "tmac");
        assert_eq!(reg.build_selection("all").unwrap().len(), reg.ids().len());
        assert!(reg.build_selection("platinum-ternary,nope").is_err());
    }

    #[test]
    fn comparison_ids_all_resolve() {
        let reg = Registry::with_defaults();
        let sel = reg.build_selection(COMPARISON_IDS).unwrap();
        assert_eq!(sel.len(), 5);
    }
}
