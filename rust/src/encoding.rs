//! Weight encodings (paper §III-C) — the rust mirror of
//! `python/compile/kernels/encoding.py`; the two are cross-validated by
//! the integration tests via `artifacts/paths/*.json`.
//!
//! * Ternary chunk `w ∈ {-1,0,1}^c` ↦ base-3 integer
//!   `t = Σ (w_i+1)·3^i`; mirror `t ↦ 3^c−1−t`; encoded byte
//!   `sign << idx_bits | idx` with `idx = min(t, 3^c−1−t)`,
//!   `sign = t > (3^c−1)/2`.  c=5 → 1.6 bits/weight (Fig 6).
//! * Binary chunk `b ∈ {0,1}^c` ↦ plain LUT address `Σ b_i·2^i`.

/// Paper's ternary chunk size.
pub const TERNARY_C: usize = 5;
/// Paper's bit-serial chunk size.
pub const BINARY_C: usize = 7;

/// 3^c as usize (c ≤ 20).
#[inline]
pub fn pow3(c: usize) -> usize {
    3usize.pow(c as u32)
}

/// Number of stored (canonical) ternary LUT entries: ⌈3^c/2⌉.
#[inline]
pub fn lut_entries(c: usize) -> usize {
    (pow3(c) + 1) / 2
}

/// Canonical index of the all-zero chunk — the construction root.
#[inline]
pub fn zero_index(c: usize) -> usize {
    (pow3(c) - 1) / 2
}

/// Index bits of the ternary encoding: ⌈log2 3^c⌉ − 1.
#[inline]
pub fn index_bits(c: usize) -> usize {
    let mut bits = 0;
    let mut v = pow3(c) - 1;
    while v > 0 {
        bits += 1;
        v >>= 1;
    }
    bits - 1
}

/// Average encoded bits per ternary weight at pack size c (Fig 6).
#[inline]
pub fn bits_per_weight(c: usize) -> f64 {
    (index_bits(c) + 1) as f64 / c as f64
}

/// A packed ternary weight matrix: the sign|index byte stream the weight
/// buffer holds, plus its logical dimensions.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PackedTernary {
    /// Row-major (m × chunks) encoded bytes.
    pub data: Vec<u8>,
    pub m: usize,
    /// Logical K (pre-padding).
    pub k: usize,
    pub c: usize,
}

impl PackedTernary {
    #[inline]
    pub fn chunks(&self) -> usize {
        self.k.div_ceil(self.c)
    }

    #[inline]
    pub fn at(&self, row: usize, chunk: usize) -> u8 {
        self.data[row * self.chunks() + chunk]
    }

    /// Split an encoded byte into (index, sign).
    #[inline]
    pub fn decode(&self, byte: u8) -> (usize, bool) {
        let ib = index_bits(self.c);
        ((byte as usize) & ((1 << ib) - 1), (byte as usize) >> ib == 1)
    }
}

/// Pack a ternary row-major (m × k) matrix into the sign|index stream.
///
/// K is zero-padded to a multiple of c (zero chunks encode to the
/// canonical zero index with sign clear).
///
/// # Panics
/// If any weight is outside {-1, 0, 1}.
pub fn pack_ternary(w: &[i8], m: usize, k: usize, c: usize) -> PackedTernary {
    assert_eq!(w.len(), m * k, "weight slice/shape mismatch");
    let nchunks = k.div_ceil(c);
    let tz = zero_index(c);
    let ib = index_bits(c);
    assert!(ib < 8, "chunk size {c} does not fit the byte stream");
    let mut data = vec![0u8; m * nchunks];
    let full_chunks = k / c;
    let p3max = pow3(c) - 1;
    // §Perf iteration 2: slice-windowed hot loop for full chunks (the
    // overwhelmingly common case) — Horner-style digit accumulation over
    // a row slice lets the compiler drop bounds checks; the ragged tail
    // chunk takes the general path.
    for row in 0..m {
        let wrow = &w[row * k..(row + 1) * k];
        let drow = &mut data[row * nchunks..(row + 1) * nchunks];
        for (ch, out) in drow.iter_mut().enumerate().take(full_chunks) {
            let chunk = &wrow[ch * c..ch * c + c];
            // Horner from the most significant digit downward:
            // folding w_{c-1}..w_0 as t = t·3 + (w_i+1) yields exactly
            // t = Σ (w_i+1)·3^i (little-endian digits, as the ISA defines).
            let mut t: usize = 0;
            for &v in chunk.iter().rev() {
                assert!((-1..=1).contains(&v), "non-ternary weight {v}");
                t = t * 3 + (v + 1) as usize;
            }
            let (idx, sign) = if t > tz { (p3max - t, 1usize) } else { (t, 0) };
            *out = ((sign << ib) | idx) as u8;
        }
        if full_chunks < nchunks {
            // ragged tail: zero-padded
            let ch = full_chunks;
            let mut t: usize = 0;
            let mut p = 1usize;
            for i in 0..c {
                let kk = ch * c + i;
                let v = if kk < k { wrow[kk] } else { 0 };
                assert!((-1..=1).contains(&v), "non-ternary weight {v}");
                t += (v + 1) as usize * p;
                p *= 3;
            }
            let (idx, sign) = if t > tz { (p3max - t, 1usize) } else { (t, 0) };
            drow[ch] = ((sign << ib) | idx) as u8;
        }
    }
    PackedTernary { data, m, k, c }
}

/// Inverse of [`pack_ternary`]; returns row-major (m × k) ternary values.
pub fn unpack_ternary(p: &PackedTernary) -> Vec<i8> {
    let nchunks = p.chunks();
    let ib = index_bits(p.c);
    let mut w = vec![0i8; p.m * p.k];
    for row in 0..p.m {
        for ch in 0..nchunks {
            let byte = p.data[row * nchunks + ch] as usize;
            let sign = byte >> ib == 1;
            let mut t = byte & ((1 << ib) - 1);
            for i in 0..p.c {
                let digit = (t % 3) as i8 - 1;
                t /= 3;
                let kk = ch * p.c + i;
                if kk < p.k {
                    w[row * p.k + kk] = if sign { -digit } else { digit };
                }
            }
        }
    }
    w
}

/// Ternary chunk of a canonical index (length-c values in {-1,0,1}).
pub fn chunk_of_index(idx: usize, c: usize) -> Vec<i8> {
    let mut out = vec![0i8; c];
    let mut t = idx;
    for slot in out.iter_mut() {
        *slot = (t % 3) as i8 - 1;
        t /= 3;
    }
    out
}

/// A packed binary (bit-plane) matrix: plain LUT addresses per chunk.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PackedBinary {
    /// Row-major (m × chunks) addresses, each < 2^c.
    pub data: Vec<u8>,
    pub m: usize,
    pub k: usize,
    pub c: usize,
}

impl PackedBinary {
    #[inline]
    pub fn chunks(&self) -> usize {
        self.k.div_ceil(self.c)
    }

    #[inline]
    pub fn at(&self, row: usize, chunk: usize) -> u8 {
        self.data[row * self.chunks() + chunk]
    }
}

/// Pack a binary (m × k) matrix of {0,1} into LUT addresses.
pub fn pack_binary(b: &[u8], m: usize, k: usize, c: usize) -> PackedBinary {
    assert_eq!(b.len(), m * k);
    assert!(c <= 8);
    let nchunks = k.div_ceil(c);
    let mut data = vec![0u8; m * nchunks];
    for row in 0..m {
        for ch in 0..nchunks {
            let mut t = 0usize;
            for i in 0..c {
                let kk = ch * c + i;
                if kk < k {
                    let v = b[row * k + kk];
                    assert!(v <= 1, "non-binary value {v}");
                    t |= (v as usize) << i;
                }
            }
            data[row * nchunks + ch] = t as u8;
        }
    }
    PackedBinary { data, m, k, c }
}

/// Two-pass bit-serial decomposition of ternary weights: (+1 plane, −1
/// plane) — the execution mode the SNN baselines and Platinum-bs use.
pub fn ternary_planes(w: &[i8], m: usize, k: usize) -> (Vec<u8>, Vec<u8>) {
    let pos = w.iter().map(|&v| (v == 1) as u8).collect();
    let neg = w.iter().map(|&v| (v == -1) as u8).collect();
    debug_assert_eq!(m * k, w.len());
    (pos, neg)
}

/// Two's-complement bit planes for b-bit integer weights:
/// (planes[b] each m×k of {0,1}, plane_weights[b] with MSB negative).
pub fn int_bit_planes(w: &[i32], bits: usize) -> (Vec<Vec<u8>>, Vec<i32>) {
    let lo = -(1i32 << (bits - 1));
    let hi = (1i32 << (bits - 1)) - 1;
    assert!(
        w.iter().all(|&v| v >= lo && v <= hi),
        "weights out of range for int{bits}"
    );
    let mask = (1u32 << bits) - 1;
    let planes: Vec<Vec<u8>> = (0..bits)
        .map(|b| w.iter().map(|&v| (((v as u32) & mask) >> b & 1) as u8).collect())
        .collect();
    let mut pw: Vec<i32> = (0..bits).map(|b| 1i32 << b).collect();
    *pw.last_mut().unwrap() = -pw[bits - 1];
    (planes, pw)
}

#[cfg(test)]
mod tests {
    use super::*;


    #[test]
    fn constants_match_paper() {
        assert_eq!(lut_entries(5), 122);
        assert_eq!(zero_index(5), 121);
        assert_eq!(index_bits(5), 7);
        assert!((bits_per_weight(5) - 1.6).abs() < 1e-12);
    }

    #[test]
    fn fig6_minimum_at_c5() {
        let best = (1..=10).min_by(|&a, &b| {
            bits_per_weight(a).partial_cmp(&bits_per_weight(b)).unwrap()
        });
        assert_eq!(best, Some(5));
        for c in 1..=10 {
            assert!(bits_per_weight(c) >= 3f64.log2());
        }
    }

    #[test]
    fn zero_chunk_encodes_to_root() {
        let p = pack_ternary(&[0, 0, 0, 0, 0], 1, 5, 5);
        assert_eq!(p.data[0] as usize, zero_index(5));
    }

    #[test]
    fn mirror_symmetry_in_sign_bit() {
        let w: Vec<i8> = vec![1, -1, 0, 1, 0, -1, -1, 0, 1, 1];
        let wn: Vec<i8> = w.iter().map(|v| -v).collect();
        let p = pack_ternary(&w, 1, 10, 5);
        let pn = pack_ternary(&wn, 1, 10, 5);
        for (a, b) in p.data.iter().zip(&pn.data) {
            assert_eq!(a & 0x7f, b & 0x7f, "index must match");
            assert_eq!((a >> 7) ^ (b >> 7), 1, "sign must flip");
        }
    }

    #[test]
    fn padded_roundtrip() {
        let w: Vec<i8> = vec![1, -1, 0, 1, 0, -1, -1]; // k=7, pads to 10
        let p = pack_ternary(&w, 1, 7, 5);
        assert_eq!(p.chunks(), 2);
        assert_eq!(unpack_ternary(&p), w);
    }

    #[test]
    fn binary_pack_range() {
        let b = vec![1u8; 7];
        let p = pack_binary(&b, 1, 7, 7);
        assert_eq!(p.data[0], 127);
    }

    #[test]
    fn planes_reconstruct() {
        let w: Vec<i8> = vec![1, -1, 0, 0, 1, -1];
        let (pos, neg) = ternary_planes(&w, 2, 3);
        for i in 0..6 {
            assert_eq!(pos[i] as i8 - neg[i] as i8, w[i]);
        }
    }

    #[test]
    fn int_planes_reconstruct() {
        let w = vec![-4i32, 3, -1, 0, 2, -3];
        let (planes, pw) = int_bit_planes(&w, 3);
        for i in 0..w.len() {
            let mut acc = 0i32;
            for b in 0..3 {
                acc += planes[b][i] as i32 * pw[b];
            }
            assert_eq!(acc, w[i]);
        }
    }

    #[test]
    fn prop_ternary_roundtrip() {
        crate::util::check_prop("ternary_roundtrip", 64, |seed| {
            let mut rng = crate::util::rng::Rng::seed_from(seed);
            let m = 1 + rng.below(8) as usize;
            let k = 1 + rng.below(40) as usize;
            let w = rng.ternary_vec(m * k);
            let p = pack_ternary(&w, m, k, 5);
            crate::ensure_prop!(
                p.data.iter().all(|&b| (b & 0x7f) as usize <= zero_index(5)),
                "index exceeds canonical range"
            );
            crate::ensure_prop!(unpack_ternary(&p) == w, "roundtrip mismatch m={m} k={k}");
            Ok(())
        });
    }

    #[test]
    fn prop_pack_matches_index_decode() {
        crate::util::check_prop("pack_matches_index_decode", 64, |seed| {
            let mut rng = crate::util::rng::Rng::seed_from(seed);
            let w = rng.ternary_vec(5);
            let p = pack_ternary(&w, 1, 5, 5);
            let (idx, sign) = p.decode(p.data[0]);
            let chunk = chunk_of_index(idx, 5);
            let recon: Vec<i8> = chunk.iter().map(|&v| if sign { -v } else { v }).collect();
            crate::ensure_prop!(recon == w, "decode path disagrees with pack");
            Ok(())
        });
    }
}
