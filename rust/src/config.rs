//! Accelerator and tiling configuration (paper §III-A, §IV).
//!
//! The defaults are the paper's shipped design point: L=52 PPEs ×
//! n_cols=8 (416 PEs), ternary chunk c=5 (128-entry LUT), bit-serial
//! chunk c=7, 500 MHz @ 28 nm, 64 GB/s DDR4-2133, and the Fig-7 chosen
//! tiling (m=1080, k=520, n=32, mnk-stationary).

/// Which build path (and thus execution mode) the datapath runs.
///
/// Path adaptability is the paper's headline mechanism: the same PPE
/// array executes either mode purely by loading a different offline
/// build path and weight stream.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ExecMode {
    /// Ternary LUT, mirror-consolidated (c = 5, 122 live entries).
    Ternary,
    /// Binary LUT bit-serial (c = 7, 128 entries); `planes` passes per
    /// weight matrix (2 for ternary two-pass, b for b-bit integers).
    BitSerial { planes: u32 },
}

impl ExecMode {
    pub fn label(&self) -> &'static str {
        match self {
            ExecMode::Ternary => "Platinum",
            ExecMode::BitSerial { .. } => "Platinum-bs",
        }
    }
}

/// Loop-nest stationarity for the tiling scheduler (§IV-C, Fig 7).
///
/// The name lists loop levels outermost→innermost over tile indices;
/// e.g. `Mnk` keeps the output tile live across the innermost k loop
/// (output-stationary in k) while the weight tile changes per k step and
/// the m tile is reused longest.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Stationarity {
    Mnk,
    Mkn,
    Nmk,
    Nkm,
    Kmn,
    Knm,
}

impl Stationarity {
    pub const ALL: [Stationarity; 6] = [
        Stationarity::Mnk,
        Stationarity::Mkn,
        Stationarity::Nmk,
        Stationarity::Nkm,
        Stationarity::Kmn,
        Stationarity::Knm,
    ];

    pub fn label(&self) -> &'static str {
        match self {
            Stationarity::Mnk => "mnk",
            Stationarity::Mkn => "mkn",
            Stationarity::Nmk => "nmk",
            Stationarity::Nkm => "nkm",
            Stationarity::Kmn => "kmn",
            Stationarity::Knm => "knm",
        }
    }
}

/// Tile sizes for one GEMM dispatch (§IV-C).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Tiling {
    pub m: usize,
    pub k: usize,
    pub n: usize,
    pub order: Stationarity,
}

impl Default for Tiling {
    /// The paper's chosen point (red marker in Fig 7).
    fn default() -> Self {
        Tiling { m: 1080, k: 520, n: 32, order: Stationarity::Mnk }
    }
}

/// Full accelerator configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct PlatinumConfig {
    /// Number of Platinum Processing Elements (L). Each PPE owns one LUT
    /// buffer and processes one c-element input chunk per round.
    pub num_ppes: usize,
    /// LUT block size: input columns processed per query (§IV-A).
    pub n_cols: usize,
    /// Ternary chunk size (5 → 122-entry mirror-consolidated LUT).
    pub c_ternary: usize,
    /// Bit-serial chunk size (7 → 128-entry binary LUT).
    pub c_binary: usize,
    /// Construction pipeline depth (Fig 4: fetch/read/add/write).
    pub pipeline_depth: usize,
    /// LUT buffer read ports usable for queries per cycle (§IV-B).
    pub lut_ports: usize,
    /// Clock frequency in Hz.
    pub freq_hz: f64,
    /// Peak DRAM bandwidth in bytes/s (DDR4-2133, 64 GB/s in the paper).
    pub dram_bw: f64,
    /// LUT entry width in bits (8, aligned to BitNet's int8 activations).
    pub lut_entry_bits: usize,
    /// Output accumulator width in bits.
    pub acc_bits: usize,
    /// Tiling configuration.
    pub tiling: Tiling,
}

impl Default for PlatinumConfig {
    fn default() -> Self {
        PlatinumConfig {
            num_ppes: 52,
            n_cols: 8,
            c_ternary: 5,
            c_binary: 7,
            pipeline_depth: 4,
            lut_ports: 2,
            freq_hz: 500e6,
            dram_bw: 64e9,
            lut_entry_bits: 8,
            acc_bits: 32,
            tiling: Tiling::default(),
        }
    }
}

impl PlatinumConfig {
    /// Total PE count as the paper reports it (#adders = L × n_cols).
    pub fn num_pes(&self) -> usize {
        self.num_ppes * self.n_cols
    }

    /// K-dim elements consumed per construction round (L · c).
    pub fn k_per_round(&self, c: usize) -> usize {
        self.num_ppes * c
    }

    /// Live LUT entries for a mode (122 ternary / 128 binary).
    pub fn lut_entries(&self, mode: ExecMode) -> usize {
        match mode {
            ExecMode::Ternary => (3usize.pow(self.c_ternary as u32) + 1) / 2,
            ExecMode::BitSerial { .. } => 1 << self.c_binary,
        }
    }

    /// Physical LUT buffer capacity per PPE in bytes
    /// (entries rounded to a power of two × n_cols × entry bytes).
    pub fn lut_bytes_per_ppe(&self) -> usize {
        let entries = (3usize.pow(self.c_ternary as u32) + 1) / 2;
        let rounded = entries.next_power_of_two(); // 122 → 128
        rounded * self.n_cols * self.lut_entry_bits / 8
    }

    /// Total LUT SRAM in bytes (52 KB at the default design point).
    pub fn total_lut_bytes(&self) -> usize {
        self.num_ppes * self.lut_bytes_per_ppe()
    }

    /// Chunk size for a mode.
    pub fn chunk(&self, mode: ExecMode) -> usize {
        match mode {
            ExecMode::Ternary => self.c_ternary,
            ExecMode::BitSerial { .. } => self.c_binary,
        }
    }

    /// Encoded bits per weight for a mode (1.6 ternary / 2·1 two-pass...).
    pub fn weight_bits(&self, mode: ExecMode) -> f64 {
        match mode {
            ExecMode::Ternary => {
                let ib = crate::encoding::index_bits(self.c_ternary);
                (ib + 1) as f64 / self.c_ternary as f64
            }
            // bit-serial streams one LUT address (c bits) per plane chunk
            ExecMode::BitSerial { planes } => {
                planes as f64 * (self.c_binary as f64) / self.c_binary as f64
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_matches_paper_design_point() {
        let c = PlatinumConfig::default();
        assert_eq!(c.num_pes(), 416); // Table I
        assert_eq!(c.k_per_round(c.c_ternary), 260);
        assert_eq!(c.lut_entries(ExecMode::Ternary), 122);
        assert_eq!(c.lut_entries(ExecMode::BitSerial { planes: 2 }), 128);
        assert_eq!(c.lut_bytes_per_ppe(), 1024); // 128 × 8 × 1B
        assert_eq!(c.total_lut_bytes(), 52 * 1024); // 52 KB (§IV-C)
    }

    #[test]
    fn ternary_weight_bits_is_1_6() {
        let c = PlatinumConfig::default();
        assert!((c.weight_bits(ExecMode::Ternary) - 1.6).abs() < 1e-9);
    }

    #[test]
    fn default_tiling_is_fig7_choice() {
        let t = Tiling::default();
        assert_eq!((t.m, t.k, t.n), (1080, 520, 32));
        assert_eq!(t.order, Stationarity::Mnk);
    }
}
