//! The continuous-batching scheduler: a request queue, admission /
//! backpressure control, and a step loop that coalesces admitted
//! requests into prefill and per-step decode [`Workload`]s against any
//! registered [`Backend`] — the serving control plane the ROADMAP's
//! "heavy traffic" north star needs on top of the engine API.
//!
//! ## Step loop (vLLM/Orca-style, prefill-prioritized)
//!
//! Each iteration: (1) admit arrivals whose offset has passed into the
//! bounded queue (beyond [`SchedulerConfig::max_queue`] they are
//! **rejected** — open-loop backpressure); (2) resume swapped-out
//! sequences when blocks free up, then promote queued requests into the
//! running batch FCFS while the batch has a slot, the in-flight token
//! reservation fits ([`SchedulerConfig::max_inflight_tokens`]), the
//! step's prefill token budget holds, **and the paged KV cache can
//! reserve the prompt's blocks** ([`KvCache::try_admit`] — the real
//! memory backpressure; a prefix-cache hit discounts the prefill to the
//! uncached tokens); (3) if anything was promoted, run one **prefill
//! step** — all promoted prompts coalesced into a single
//! [`Workload::prefill_step`] whose end produces each prompt's first
//! token (TTFT); otherwise run one **decode step** — every running
//! sequence advances one token via [`Workload::decode_step`], after
//! preempting the most recently admitted sequences ([`KvPolicy`]: swap
//! the blocks over the priced DRAM channel, or drop them for a later
//! re-prefill) until the step's block appends fit; (4) charge the
//! step's priced latency (plus any swap-traffic stall) to the
//! [`Clock`] and evict finished sequences, returning their blocks.  An
//! idle scheduler jumps to the next arrival.
//!
//! The **pricing backend is the timeline**: the priced latency of each
//! step advances virtual time, so with a modelled backend (e.g.
//! `platinum-ternary`, or `sharded:4:...`) the whole run is a
//! deterministic discrete-event simulation, and with a measured
//! backend (`platinum-cpu`) the timeline follows real kernel
//! wall-clock.  Optional functional execution rides along through
//! [`StepExecutor`] (e.g. [`ExecutorBridge`] over
//! [`crate::coordinator::serve::GoldenExecutor`]) and **never**
//! influences decisions — `tests/traffic_serving.rs` pins metrics
//! byte-identical across worker-pool sizes {1, 8}.

use super::clock::Clock;
use super::loadgen::{TrafficRequest, MAX_CLASSES};
use super::metrics::{ClassMetrics, StepSample, TrafficMetrics};
use super::source::{ArrivalSource, Outcome, TraceSource};
use crate::coordinator::serve::Executor;
use crate::engine::{Backend, Workload};
use crate::fault::{FaultInjector, FaultPlan, ResilienceConfig, ResilienceStats};
use crate::kv::{BlockId, KvCache, KvConfig, KvPolicy};
use crate::models::BitNetModel;
use crate::sim::DramModel;
use crate::util::rng::Rng;
use anyhow::Result;
use std::collections::{BTreeMap, VecDeque};

/// Admission and batching policy.
#[derive(Debug, Clone, Copy)]
pub struct SchedulerConfig {
    /// Max sequences decoded per step (the running-batch slot count).
    pub max_batch: usize,
    /// Max waiting requests; arrivals beyond this are rejected.
    pub max_queue: usize,
    /// Backpressure bound on Σ(prompt + output) reserved by running
    /// sequences (KV-cache-style conservative reservation).
    pub max_inflight_tokens: usize,
    /// Token budget of one coalesced prefill step (counted on the
    /// *computed* tokens — prefix-cache hits don't consume it).
    pub max_prefill_tokens: usize,
    /// Fixed scheduling overhead charged to the timeline per step (s).
    pub step_overhead_s: f64,
    /// Paged KV-cache capacity model and pressure policy.
    pub kv: KvConfig,
    /// SLO responses (deadlines, retries, brownout) — inert by default;
    /// see [`ResilienceConfig`].
    pub resilience: ResilienceConfig,
    /// Chunked prefill: cap on one sequence's *computed* prompt tokens
    /// per prefill step.  A prompt larger than the chunk carries its
    /// remainder across steps (interleaving decode steps between
    /// chunks), so long prompts stop monopolizing whole steps.  0
    /// disables chunking (prompts prefill whole — the legacy
    /// behaviour); any chunk ≥ the longest prompt is decision-identical
    /// to 0.
    pub prefill_chunk: usize,
    /// Number of SLO classes configured (1 = single-tenant legacy; the
    /// per-class metrics section appears only beyond 1 or when a
    /// request carries a nonzero class).
    pub classes: usize,
    /// Weighted-fair-queueing weights per class id: under competition a
    /// class's in-flight token reservation is bounded by its weighted
    /// share of `max_inflight_tokens`; a lone class keeps the whole
    /// budget (work conservation), so single-tenant runs are
    /// decision-identical to the pre-class scheduler.
    pub class_weights: [u32; MAX_CLASSES],
}

impl Default for SchedulerConfig {
    fn default() -> SchedulerConfig {
        SchedulerConfig {
            max_batch: 32,
            max_queue: 256,
            max_inflight_tokens: 65_536,
            max_prefill_tokens: 2048,
            step_overhead_s: 0.0,
            kv: KvConfig::default(),
            resilience: ResilienceConfig::default(),
            prefill_chunk: 0,
            classes: 1,
            class_weights: [1; MAX_CLASSES],
        }
    }
}

/// What one executed step did — the scheduler's decision log entry.
#[derive(Debug, Clone, PartialEq)]
pub struct StepRecord {
    pub index: u64,
    pub kind: StepKind,
    /// Timeline position when the step launched (s).
    pub t_start_s: f64,
    /// Priced duration charged to the timeline (s).
    pub step_s: f64,
    /// Sequences the step served, in batch order.
    pub seq_ids: Vec<u64>,
    /// Prefill: total coalesced *computed* prompt tokens; decode: batch
    /// size.
    pub tokens: usize,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StepKind {
    Prefill,
    Decode,
}

impl StepKind {
    pub fn label(&self) -> &'static str {
        match self {
            StepKind::Prefill => "prefill",
            StepKind::Decode => "decode",
        }
    }
}

/// Pluggable functional execution hook, called once per step after
/// pricing.  The scheduler's timeline and decisions are already fixed
/// by the pricing backend when this runs; implementations produce the
/// actual tokens (golden datapath on the worker pool, PJRT artifacts,
/// …) or instrument the run.
pub trait StepExecutor {
    fn execute(&mut self, step: &StepRecord, workload: &Workload) -> Result<()>;
}

impl<F> StepExecutor for F
where
    F: FnMut(&StepRecord, &Workload) -> Result<()>,
{
    fn execute(&mut self, step: &StepRecord, workload: &Workload) -> Result<()> {
        self(step, workload)
    }
}

/// Adapts any [`Executor`] (the PR 2 serving trait — e.g.
/// [`crate::coordinator::serve::GoldenExecutor`], which runs the golden
/// ternary datapath on the worker pool) into a [`StepExecutor`]:
/// synthesizes seeded activations per step and drives the functional
/// forward — decode steps as `batch` single-token columns, prefill
/// steps as one `tokens`-long sequence.
pub struct ExecutorBridge<E: Executor> {
    exec: E,
    rng: Rng,
}

impl<E: Executor> ExecutorBridge<E> {
    pub fn new(exec: E) -> Self {
        ExecutorBridge { exec, rng: Rng::seed_from(0x7F1C) }
    }

    /// The wrapped executor (e.g. to inspect outputs after a run).
    pub fn executor(&self) -> &E {
        &self.exec
    }
}

impl<E: Executor> StepExecutor for ExecutorBridge<E> {
    fn execute(&mut self, step: &StepRecord, _workload: &Workload) -> Result<()> {
        let d = self.exec.d_model();
        let (seqs, seq_len) = match step.kind {
            StepKind::Decode => (step.seq_ids.len().max(1), 1),
            StepKind::Prefill => (1, step.tokens.max(1)),
        };
        let data: Vec<Vec<f32>> = (0..seqs)
            .map(|_| (0..seq_len * d).map(|_| self.rng.f64() as f32 - 0.5).collect())
            .collect();
        let xs: Vec<&[f32]> = data.iter().map(|v| v.as_slice()).collect();
        self.exec.run(&xs, seq_len)?;
        Ok(())
    }
}

/// Result of serving one request trace.
#[derive(Debug, Clone)]
pub struct RunResult {
    pub metrics: TrafficMetrics,
    /// Per-step decision log, in execution order.
    pub steps: Vec<StepRecord>,
}

/// One running sequence.
#[derive(Debug, Clone, Copy)]
struct Seq {
    req: TrafficRequest,
    generated: usize,
    /// Timeline position of the sequence's latest token — TPOT samples
    /// are true inter-token gaps, so interleaved prefill steps between
    /// a sequence's decode steps count against it.
    last_token_s: f64,
}

impl Seq {
    /// Tokens resident in the KV cache: the prompt plus one appended
    /// block slot per decode token (the prefill's own token is stored
    /// by the first decode append).
    fn resident_tokens(&self) -> usize {
        self.req.prompt_tokens + self.generated.saturating_sub(1)
    }
}

/// What completing a prompt emits at the end of its prefill step.
#[derive(Debug, Clone, Copy, PartialEq)]
enum Emit {
    /// The prompt's first output token (a TTFT sample).
    First,
    /// The next token of a re-prefill after recompute preemption (a
    /// TPOT sample over the preemption gap).
    Next,
}

/// One sequence entering the upcoming coalesced prefill step.
struct PrefillSeq {
    seq: Seq,
    /// First admission this step (counts admitted / queue-wait /
    /// prompt tokens).
    admit: bool,
    /// Computed prompt tokens still owed after this step — 0 means the
    /// prompt completes and `done_emit` fires (chunked prefill carries
    /// a nonzero remainder across steps).
    remaining: usize,
    done_emit: Emit,
    /// Continuation of an already-partial prompt (ordering: unfinished
    /// continuations re-enter ahead of freshly chunked admissions).
    from_partial: bool,
}

/// A partially-prefilled sequence between chunk steps (chunked
/// prefill): it holds its KV reservation and in-flight tokens but has
/// not emitted its first token yet.
struct Partial {
    seq: Seq,
    /// Computed prompt tokens still owed.
    remaining: usize,
    done_emit: Emit,
}

/// Queue/accounting index of one request's SLO class; ids beyond the
/// fixed table clamp into the last slot.
fn class_of(r: &TrafficRequest) -> usize {
    (r.class as usize).min(MAX_CLASSES - 1)
}

/// In-flight token reservation, tracked globally and per SLO class
/// (the WFQ share accounting).  Hardened like the legacy counter: an
/// underflow (releasing more tokens than were reserved) is a checked
/// error counted into the run's
/// `kv.leaks.token_release_underflows` — visible in release builds,
/// not just a debug assert — and the counters saturate instead of
/// wrapping.
struct Inflight {
    total: usize,
    per_class: [usize; MAX_CLASSES],
}

impl Inflight {
    fn new() -> Inflight {
        Inflight { total: 0, per_class: [0; MAX_CLASSES] }
    }

    fn reserve(&mut self, class: usize, n: usize) {
        self.total += n;
        self.per_class[class] += n;
    }

    fn release(&mut self, class: usize, n: usize, underflows: &mut u64) {
        if self.total < n || self.per_class[class] < n {
            *underflows += 1;
        }
        self.total = self.total.saturating_sub(n);
        self.per_class[class] = self.per_class[class].saturating_sub(n);
    }
}

/// Re-enter a rejected / timed-out / failed attempt into the arrival
/// timeline with capped exponential backoff, or exhaust its retry
/// budget (returns `false` — the attempt is terminal).  Keyed by
/// `(re-arrival time bits, id)` in a `BTreeMap`, so retried attempts
/// merge back into the timeline in a deterministic order (times are
/// non-negative, so the bit order is the numeric order).
fn schedule_retry(
    req: TrafficRequest,
    now: f64,
    rc: &ResilienceConfig,
    attempts: &mut BTreeMap<u64, u32>,
    retries: &mut BTreeMap<(u64, u64), TrafficRequest>,
    res: &mut ResilienceStats,
) -> bool {
    let attempt = attempts.get(&req.id).copied().unwrap_or(0) + 1;
    if attempt > rc.max_retries {
        res.retry_exhausted += 1;
        return false;
    }
    attempts.insert(req.id, attempt);
    let backoff = (rc.retry_base_s * f64::powi(2.0, attempt as i32 - 1)).min(rc.retry_cap_s);
    let mut r = req;
    r.arrival_s = now + backoff;
    retries.insert((r.arrival_s.to_bits(), r.id), r);
    res.retries += 1;
    true
}

/// How many loop iterations a cancellation may sit unmatched in
/// `cancel_wanted` before it is aged out.  A cancel that raced past
/// its request's terminal state (client hang-up at the same instant
/// the last token completed) never finds a request to kill; without a
/// bound those ids would accumulate for the daemon's lifetime.  The
/// only legitimate long wait is a request still pending *inside* the
/// source (future `arrival_s`), which the admission scan catches at
/// pop time — live pushes arrive due immediately, so this bound is
/// generous.
const CANCEL_WANTED_TTL: u32 = 1024;

/// Mark one offered request terminal: report the outcome to the source
/// and drop its per-id bookkeeping (retry `attempts`, any pending
/// `cancel_wanted` entry) so a long-running daemon does not accumulate
/// state for requests that no longer exist.
fn finish_request(
    source: &mut dyn ArrivalSource,
    attempts: &mut BTreeMap<u64, u32>,
    cancel_wanted: &mut BTreeMap<u64, u32>,
    id: u64,
    outcome: Outcome,
) {
    attempts.remove(&id);
    cancel_wanted.remove(&id);
    source.note_terminal(id, outcome);
}

/// Effective deadline of one attempt: the per-request deadline (set by
/// a live client's `X-Deadline-Ms` header or a captured trace) wins
/// over the global [`ResilienceConfig::deadline_s`].
fn effective_deadline(req: &TrafficRequest, rc: &ResilienceConfig) -> Option<f64> {
    req.deadline_s.or(rc.deadline_s)
}

/// Price moving `blocks` over the DRAM channel (seconds of timeline
/// stall).  Block ids map to addresses at block granularity, so the
/// bank-state model sees the real spatial pattern of the spill.
fn swap_traffic_s(
    dram: &mut dyn DramModel,
    blocks: &[BlockId],
    block_bytes: u64,
    freq_hz: f64,
) -> f64 {
    let mut cycles = 0u64;
    for &b in blocks {
        cycles += dram.transfer_cycles_at(b as u64 * block_bytes, block_bytes);
    }
    cycles as f64 / freq_hz
}

/// The continuous-batching serving scheduler (see module docs).
pub struct Scheduler<'a> {
    backend: &'a dyn Backend,
    model: BitNetModel,
    cfg: SchedulerConfig,
}

impl<'a> Scheduler<'a> {
    pub fn new(backend: &'a dyn Backend, model: BitNetModel, cfg: SchedulerConfig) -> Self {
        Scheduler { backend, model, cfg }
    }

    pub fn config(&self) -> &SchedulerConfig {
        &self.cfg
    }

    /// Serve a request trace to completion (pricing only).
    pub fn serve(&self, requests: &[TrafficRequest], clock: &mut dyn Clock) -> Result<RunResult> {
        self.serve_with(requests, clock, None)
    }

    /// Serve a request trace, optionally executing each step
    /// functionally through `exec`.
    ///
    /// Always terminates: every iteration either executes a step (a
    /// prefill admits ≥ 1 request — an oversized head-of-line request
    /// is admitted alone, with the KV pool's overflow escape hatch,
    /// rather than starved — and a decode advances every running
    /// sequence by one token after preempting until its block appends
    /// fit) or jumps the clock to the next pending arrival; arrivals
    /// are finite and preemption always frees the blocks it needs.
    pub fn serve_with(
        &self,
        requests: &[TrafficRequest],
        clock: &mut dyn Clock,
        exec: Option<&mut dyn StepExecutor>,
    ) -> Result<RunResult> {
        self.serve_faults(requests, clock, exec, &FaultPlan::default())
    }

    /// Serve a request trace under an injected fault `plan`, with the
    /// configured [`ResilienceConfig`] responses: per-request deadlines
    /// (timeout-kill + KV reclamation), capped-exponential-backoff
    /// retries merged back into the arrival timeline, brownout
    /// load-shedding by deadline slack, and `Sharded` failover with
    /// priced weight redistribution when a replica crash fires.
    ///
    /// Strictly additive: with an empty plan and a default (inactive)
    /// resilience config every branch below reduces to the legacy step
    /// loop and the metrics serialize byte-identically to a plain
    /// [`Scheduler::serve`] — no `resilience` section is emitted.
    pub fn serve_faults(
        &self,
        requests: &[TrafficRequest],
        clock: &mut dyn Clock,
        exec: Option<&mut dyn StepExecutor>,
        plan: &FaultPlan,
    ) -> Result<RunResult> {
        let mut source = TraceSource::new(requests);
        self.serve_source(&mut source, clock, exec, plan)
    }

    /// Serve from an external [`ArrivalSource`] — the S18 enabling
    /// refactor.  The loop *pulls* due arrivals instead of scanning a
    /// pre-materialized slice, so a live front end ([`crate::server`])
    /// pushes requests into the timeline as clients connect, a trace
    /// is just a [`TraceSource`], and the loadgen is one producer among
    /// several.  On top of the [`Scheduler::serve_faults`] semantics
    /// this adds:
    ///
    /// * **cancellation** — ids delivered through
    ///   [`ArrivalSource::drain_cancellations`] (a client hanging up
    ///   mid-stream) are killed wherever they sit, with their KV
    ///   blocks and token reservation reclaimed, counted in
    ///   `metrics.cancelled`;
    /// * **per-request deadlines** — a request carrying `deadline_s`
    ///   gets the PR 7 timeout-kill/retry treatment even when the
    ///   global [`ResilienceConfig`] is inert;
    /// * **terminal reporting** — every offered request ends in exactly
    ///   one [`ArrivalSource::note_terminal`] call (completed /
    ///   rejected / shed / exhausted / cancelled), which is how the
    ///   server routes outcomes back to waiting connections;
    /// * **idle parking** — with no pending work and no known wake-up
    ///   time the loop calls [`ArrivalSource::park`] instead of
    ///   terminating, so a wall-clock daemon idles on the producer's
    ///   condvar until [`ArrivalSource::finished`] turns true.
    ///
    /// Decision-identity: driven by a [`TraceSource`], every branch
    /// reduces to the legacy loop — pinned byte-identical in
    /// `tests/traffic_serving.rs`.
    pub fn serve_source(
        &self,
        source: &mut dyn ArrivalSource,
        clock: &mut dyn Clock,
        mut exec: Option<&mut dyn StepExecutor>,
        plan: &FaultPlan,
    ) -> Result<RunResult> {
        let mut kv = KvCache::new(&self.cfg.kv, self.model.kv_bytes_per_token())?;
        let mut dram = self.cfg.kv.dram_model.build(self.cfg.kv.dram_bw, self.cfg.kv.freq_hz)?;
        let block_bytes = kv.block_bytes();
        let freq_hz = self.cfg.kv.freq_hz;

        let rc = self.cfg.resilience;
        let fault_on = !plan.is_empty();
        // decides retry/absorb behaviour and whether the `resilience`
        // metrics section is emitted at drain; flips on the moment a
        // request carrying its own deadline arrives, so per-request
        // SLOs work without any global resilience config
        let mut resilience_on = fault_on || rc.active();
        // true once any admitted request carried `deadline_s`
        let mut req_deadlines = false;
        let mut res = ResilienceStats::default();
        let mut injector = FaultInjector::new(plan, rc.fault_seed, self.backend.replicas());

        let mut metrics = TrafficMetrics::new();
        let mut steps: Vec<StepRecord> = Vec::new();
        // per-SLO-class waiting queues (single-tenant runs only ever
        // populate class 0, reducing to the legacy FCFS queue)
        let mut queues: [VecDeque<TrafficRequest>; MAX_CLASSES] =
            std::array::from_fn(|_| VecDeque::new());
        let weights = self.cfg.class_weights;
        let chunk = self.cfg.prefill_chunk;
        // emits the per-class metrics section at drain; flips on the
        // moment a request carrying a nonzero class arrives, so tagged
        // live traffic is measurable without any class table configured
        let mut classes_on = self.cfg.classes > 1;
        let mut cls: [ClassMetrics; MAX_CLASSES] = std::array::from_fn(|_| ClassMetrics::default());
        // recompute-preempted sequences awaiting re-prefill (already
        // admitted: they keep their token reservation and re-enter
        // ahead of fresh arrivals)
        let mut requeued: VecDeque<Seq> = VecDeque::new();
        // swap-preempted sequences whose private blocks sit in swap
        // space; resumed FCFS as blocks free up
        let mut swapped: VecDeque<Seq> = VecDeque::new();
        // partially-prefilled prompts between chunk steps (chunked
        // prefill only; empty whenever `prefill_chunk` covers every
        // prompt, which is what keeps ample budgets decision-identical)
        let mut prefilling: VecDeque<Partial> = VecDeque::new();
        let mut running: Vec<Seq> = Vec::new();
        // retried attempts waiting to re-arrive, in timeline order
        let mut retries: BTreeMap<(u64, u64), TrafficRequest> = BTreeMap::new();
        let mut attempts: BTreeMap<u64, u32> = BTreeMap::new();
        let mut inflight = Inflight::new();
        let mut underflows = 0u64;
        let mut last_kind: Option<StepKind> = None;
        // cancellations whose request has not been located yet (it may
        // still be pending inside the source), each with a remaining-
        // iterations TTL so stale ids age out instead of accumulating
        let mut cancel_wanted: BTreeMap<u64, u32> = BTreeMap::new();

        loop {
            let now = clock.now();
            // DRAM stall accumulated by swap traffic this iteration;
            // charged to the step the iteration executes.
            let mut stall_s = 0.0f64;

            // (1) admission: fresh arrivals and due retried attempts
            // enter the bounded queue, merged in timeline order (a
            // retried attempt carries its re-arrival time in
            // `arrival_s`; with no retries pending this is the legacy
            // arrival scan)
            loop {
                let arrival_t = source.next_arrival_s().filter(|&t| t <= now);
                let retry_key = retries
                    .first_key_value()
                    .map(|(&k, _)| k)
                    .filter(|&(t_bits, _)| f64::from_bits(t_bits) <= now);
                let take_arrival = match (arrival_t, retry_key) {
                    (None, None) => break,
                    (Some(_), None) => true,
                    (None, Some(_)) => false,
                    (Some(a), Some((t_bits, _))) => a <= f64::from_bits(t_bits),
                };
                let r = if take_arrival {
                    let r = source.pop_due(now).expect("due arrival vanished");
                    metrics.offered += 1; // a retry is NOT a new offer
                    cls[class_of(&r)].offered += 1;
                    if r.class > 0 {
                        classes_on = true;
                    }
                    if r.deadline_s.is_some() {
                        resilience_on = true;
                        req_deadlines = true;
                    }
                    if cancel_wanted.contains_key(&r.id) {
                        // cancelled before it was even admitted
                        metrics.cancelled += 1;
                        finish_request(
                            source,
                            &mut attempts,
                            &mut cancel_wanted,
                            r.id,
                            Outcome::Cancelled,
                        );
                        continue;
                    }
                    r
                } else {
                    retries.remove(&retry_key.unwrap()).unwrap()
                };
                let waiting: usize = queues.iter().map(|q| q.len()).sum();
                if waiting >= self.cfg.max_queue {
                    metrics.rejected += 1;
                    cls[class_of(&r)].rejected += 1;
                    if resilience_on {
                        if !schedule_retry(r, now, &rc, &mut attempts, &mut retries, &mut res) {
                            finish_request(
                                source,
                                &mut attempts,
                                &mut cancel_wanted,
                                r.id,
                                Outcome::Exhausted,
                            );
                        }
                    } else {
                        finish_request(
                            source,
                            &mut attempts,
                            &mut cancel_wanted,
                            r.id,
                            Outcome::Rejected,
                        );
                    }
                } else {
                    queues[class_of(&r)].push_back(r);
                }
            }

            // (1d) cancellation: a client hanging up kills its request
            // wherever it sits — queued, awaiting re-prefill, swapped
            // out, running, or waiting on a retry — reclaiming every
            // resource it holds, exactly like the deadline kill path
            // but terminal (no retry).  One sweep per drained batch
            // suffices: an id the sweep does not find is either still
            // pending inside the source (the admission scan kills it at
            // pop time) or already terminal — the latter age out after
            // CANCEL_WANTED_TTL iterations instead of triggering full
            // sweeps for the daemon's lifetime.
            let drained = source.drain_cancellations();
            let sweep = !drained.is_empty();
            for id in drained {
                cancel_wanted.insert(id, CANCEL_WANTED_TTL);
            }
            if sweep {
                let mut killed: Vec<u64> = Vec::new();
                for q in queues.iter_mut() {
                    q.retain(|r| {
                        let hit = cancel_wanted.contains_key(&r.id);
                        if hit {
                            killed.push(r.id);
                        }
                        !hit
                    });
                }
                requeued.retain(|s| {
                    let hit = cancel_wanted.contains_key(&s.req.id);
                    if hit {
                        inflight.release(
                            class_of(&s.req),
                            s.req.reserved_tokens(),
                            &mut underflows,
                        );
                        killed.push(s.req.id);
                    }
                    !hit
                });
                prefilling.retain(|p| {
                    let hit = cancel_wanted.contains_key(&p.seq.req.id);
                    if hit {
                        kv.release(p.seq.req.id);
                        inflight.release(
                            class_of(&p.seq.req),
                            p.seq.req.reserved_tokens(),
                            &mut underflows,
                        );
                        killed.push(p.seq.req.id);
                    }
                    !hit
                });
                swapped.retain(|s| {
                    let hit = cancel_wanted.contains_key(&s.req.id);
                    if hit {
                        kv.release_swapped(s.req.id);
                        inflight.release(
                            class_of(&s.req),
                            s.req.reserved_tokens(),
                            &mut underflows,
                        );
                        killed.push(s.req.id);
                    }
                    !hit
                });
                running.retain(|s| {
                    let hit = cancel_wanted.contains_key(&s.req.id);
                    if hit {
                        kv.release(s.req.id);
                        inflight.release(
                            class_of(&s.req),
                            s.req.reserved_tokens(),
                            &mut underflows,
                        );
                        killed.push(s.req.id);
                    }
                    !hit
                });
                retries.retain(|&(_, id), _| {
                    let hit = cancel_wanted.contains_key(&id);
                    if hit {
                        killed.push(id);
                    }
                    !hit
                });
                for id in killed {
                    metrics.cancelled += 1;
                    finish_request(
                        source,
                        &mut attempts,
                        &mut cancel_wanted,
                        id,
                        Outcome::Cancelled,
                    );
                }
            }
            if !cancel_wanted.is_empty() {
                // age out cancels that raced past their terminal state
                cancel_wanted.retain(|_, ttl| {
                    *ttl -= 1;
                    *ttl > 0
                });
            }

            // (1b) deadline timeout-kill: an attempt past its deadline
            // is killed wherever it sits and every resource it holds —
            // KV blocks (live or swapped) and the in-flight token
            // reservation — is reclaimed before the killed attempt is
            // handed to the retry path
            if rc.deadline_s.is_some() || req_deadlines {
                let overdue = |r: &TrafficRequest| {
                    effective_deadline(r, &rc).is_some_and(|dl| now - r.arrival_s > dl)
                };
                let mut killed: Vec<TrafficRequest> = Vec::new();
                for q in queues.iter_mut() {
                    q.retain(|r| {
                        let dead = overdue(r);
                        if dead {
                            killed.push(*r);
                        }
                        !dead
                    });
                }
                requeued.retain(|s| {
                    let dead = overdue(&s.req);
                    if dead {
                        // recompute-preempted: blocks already dropped,
                        // only the token reservation is held
                        inflight.release(
                            class_of(&s.req),
                            s.req.reserved_tokens(),
                            &mut underflows,
                        );
                        killed.push(s.req);
                    }
                    !dead
                });
                prefilling.retain(|p| {
                    let dead = overdue(&p.seq.req);
                    if dead {
                        kv.release(p.seq.req.id);
                        inflight.release(
                            class_of(&p.seq.req),
                            p.seq.req.reserved_tokens(),
                            &mut underflows,
                        );
                        killed.push(p.seq.req);
                    }
                    !dead
                });
                swapped.retain(|s| {
                    let dead = overdue(&s.req);
                    if dead {
                        kv.release_swapped(s.req.id);
                        inflight.release(
                            class_of(&s.req),
                            s.req.reserved_tokens(),
                            &mut underflows,
                        );
                        killed.push(s.req);
                    }
                    !dead
                });
                running.retain(|s| {
                    let dead = overdue(&s.req);
                    if dead {
                        kv.release(s.req.id);
                        inflight.release(
                            class_of(&s.req),
                            s.req.reserved_tokens(),
                            &mut underflows,
                        );
                        killed.push(s.req);
                    }
                    !dead
                });
                for r in killed {
                    res.timeouts += 1;
                    if !schedule_retry(r, now, &rc, &mut attempts, &mut retries, &mut res) {
                        finish_request(
                            source,
                            &mut attempts,
                            &mut cancel_wanted,
                            r.id,
                            Outcome::Exhausted,
                        );
                    }
                }
            }

            // (1c) brownout load-shedding, evaluated **per SLO class**:
            // a class whose own queue is at or beyond the trigger depth
            // sheds its queued attempts without enough deadline slack —
            // one saturated batch tenant browns out alone instead of
            // dragging every class down (single-tenant runs only ever
            // populate class 0, so this is the legacy global trigger).
            // Shedding to the retry path would defeat the point of
            // shedding load, so sheds are terminal.
            if rc.brownout_queue > 0 {
                for (c, q) in queues.iter_mut().enumerate() {
                    if q.len() < rc.brownout_queue {
                        continue;
                    }
                    let slack = rc.brownout_slack_for(c);
                    q.retain(|r| match effective_deadline(r, &rc) {
                        Some(dl) => {
                            let keep = r.arrival_s + dl - now >= slack;
                            if !keep {
                                res.shed += 1;
                                cls[c].shed += 1;
                                finish_request(
                                    source,
                                    &mut attempts,
                                    &mut cancel_wanted,
                                    r.id,
                                    Outcome::Shed,
                                );
                            }
                            keep
                        }
                        // no deadline, no slack to judge by: never shed
                        None => true,
                    });
                }
            }

            // (2a) resume swapped-out sequences while blocks allow —
            // started work rejoins ahead of new admissions.  An
            // injected swap-in failure loses the transfer: the
            // sequence's swapped state is dropped and it falls back to
            // a recompute re-prefill.
            while running.len() < self.cfg.max_batch {
                let Some(front) = swapped.front() else { break };
                if fault_on && injector.swap_fails(&mut res) {
                    let seq = swapped.pop_front().unwrap();
                    kv.release_swapped(seq.req.id);
                    requeued.push_back(seq);
                    continue;
                }
                let Some(fresh) = kv.resume_swapped(front.req.id, false) else { break };
                stall_s += swap_traffic_s(dram.as_mut(), &fresh, block_bytes, freq_hz);
                running.push(swapped.pop_front().unwrap());
            }

            // chunked-prefill starvation guard: with partial prompts
            // outstanding AND decodes running, alternate — one chunk
            // step, one decode step — so chunks drip in without
            // stalling every running sequence (with ample chunk budgets
            // `prefilling` stays empty and this never fires)
            let interleave = chunk > 0
                && !prefilling.is_empty()
                && !running.is_empty()
                && last_kind == Some(StepKind::Prefill);

            // (2b0) chunked prefill: continue partially-prefilled
            // prompts first (they already hold KV blocks and token
            // reservations); each spends min(remaining, chunk) of the
            // step's computed-token budget.  The front partial
            // progresses even past the budget (mirroring the
            // oversized-alone escape) so chunked runs cannot wedge.
            let mut promoted: Vec<PrefillSeq> = Vec::new();
            let mut prefill_tokens = 0usize;
            if !interleave {
                while let Some(front) = prefilling.front() {
                    let take = front.remaining.min(chunk.max(1));
                    if prefill_tokens > 0
                        && prefill_tokens + take > self.cfg.max_prefill_tokens
                    {
                        break;
                    }
                    let p = prefilling.pop_front().unwrap();
                    prefill_tokens += take;
                    promoted.push(PrefillSeq {
                        seq: p.seq,
                        admit: false,
                        remaining: p.remaining - take,
                        done_emit: p.done_emit,
                        from_partial: true,
                    });
                }
            }

            // (2b) re-prefill recompute-preempted sequences, then (2c)
            // promote fresh arrivals: while slots, the token
            // reservation, the computed-token prefill budget, and the
            // KV block reservation all hold; an oversized request at
            // the head of an otherwise-empty system is admitted alone
            // (overflow allowed so it always terminates).  With a
            // chunk configured, a prompt bigger than the chunk takes
            // only its first chunk now and carries the rest across
            // steps.
            if !interleave {
                while let Some(front) = requeued.front() {
                    let resident = front.resident_tokens();
                    let computed =
                        resident - kv.cached_tokens(resident, front.req.shared_prefix_tokens);
                    let take = if chunk > 0 { computed.min(chunk) } else { computed };
                    let fits = running.len() + prefilling.len() + promoted.len()
                        < self.cfg.max_batch
                        && prefill_tokens + take <= self.cfg.max_prefill_tokens;
                    let alone = running.is_empty()
                        && promoted.is_empty()
                        && swapped.is_empty()
                        && prefilling.is_empty();
                    if !(fits || alone) {
                        break;
                    }
                    if kv
                        .try_admit(front.req.id, resident, front.req.shared_prefix_tokens, alone)
                        .is_none()
                    {
                        break; // block backpressure: stays queued
                    }
                    let seq = requeued.pop_front().unwrap();
                    prefill_tokens += take;
                    promoted.push(PrefillSeq {
                        seq,
                        admit: false,
                        remaining: computed - take,
                        done_emit: Emit::Next,
                        from_partial: false,
                    });
                    if alone && !fits {
                        break; // oversized re-prefill runs by itself
                    }
                }
                // (2c) weighted fair queueing across SLO classes: among
                // classes with waiting work, the one with the least
                // weight-normalized in-flight reservation admits next
                // (FCFS within a class).  The weighted share binds only
                // while another class is also waiting — WFQ is
                // work-conserving — so a single-tenant run reduces
                // exactly to the legacy FCFS loop.
                let mut blocked = [false; MAX_CLASSES];
                loop {
                    let mut best: Option<usize> = None;
                    for c in 0..MAX_CLASSES {
                        if blocked[c] || queues[c].is_empty() {
                            continue;
                        }
                        best = Some(match best {
                            None => c,
                            Some(b) => {
                                let nb =
                                    inflight.per_class[b] as f64 / weights[b].max(1) as f64;
                                let nc =
                                    inflight.per_class[c] as f64 / weights[c].max(1) as f64;
                                if nc < nb {
                                    c
                                } else {
                                    b
                                }
                            }
                        });
                    }
                    let Some(c) = best else { break };
                    let front = *queues[c].front().unwrap();
                    let reserve = front.reserved_tokens();
                    let computed = front.prompt_tokens
                        - kv.cached_tokens(front.prompt_tokens, front.shared_prefix_tokens);
                    let take = if chunk > 0 { computed.min(chunk) } else { computed };
                    let fits = running.len() + prefilling.len() + promoted.len()
                        < self.cfg.max_batch
                        && inflight.total + reserve <= self.cfg.max_inflight_tokens
                        && prefill_tokens + take <= self.cfg.max_prefill_tokens;
                    // weighted share of the in-flight token budget,
                    // enforced only under competition; a class with
                    // nothing in flight always gets one admission so a
                    // tiny share cannot starve it outright
                    let competing =
                        (0..MAX_CLASSES).any(|o| o != c && !queues[o].is_empty());
                    let share_ok = !competing || inflight.per_class[c] == 0 || {
                        let wsum: u64 = (0..MAX_CLASSES)
                            .filter(|&o| !queues[o].is_empty() || inflight.per_class[o] > 0)
                            .map(|o| weights[o].max(1) as u64)
                            .sum();
                        let share = (self.cfg.max_inflight_tokens as u64
                            * weights[c].max(1) as u64
                            / wsum.max(1)) as usize;
                        inflight.per_class[c] + reserve <= share
                    };
                    let alone = running.is_empty()
                        && promoted.is_empty()
                        && swapped.is_empty()
                        && requeued.is_empty()
                        && prefilling.is_empty();
                    if !((fits && share_ok) || alone) {
                        blocked[c] = true;
                        continue;
                    }
                    if kv
                        .try_admit(front.id, front.prompt_tokens, front.shared_prefix_tokens, alone)
                        .is_none()
                    {
                        blocked[c] = true; // block backpressure: stays queued
                        continue;
                    }
                    let r = queues[c].pop_front().unwrap();
                    inflight.reserve(c, reserve);
                    prefill_tokens += take;
                    promoted.push(PrefillSeq {
                        seq: Seq { req: r, generated: 0, last_token_s: now },
                        admit: true,
                        remaining: computed - take,
                        done_emit: Emit::First,
                        from_partial: false,
                    });
                    if alone && !fits {
                        break; // oversized request runs by itself
                    }
                }
            }

            // (3) pick and price the step
            let (kind, workload, seq_ids, tokens) = if !promoted.is_empty() {
                let ids: Vec<u64> = promoted.iter().map(|p| p.seq.req.id).collect();
                (
                    StepKind::Prefill,
                    Workload::prefill_step(self.model, prefill_tokens),
                    ids,
                    prefill_tokens,
                )
            } else {
                if running.is_empty() {
                    if let Some(seq) = swapped.pop_front() {
                        // nothing else can make progress: force the
                        // swap-in through the overflow escape hatch
                        let fresh = kv
                            .resume_swapped(seq.req.id, true)
                            .expect("forced resume cannot fail");
                        stall_s += swap_traffic_s(dram.as_mut(), &fresh, block_bytes, freq_hz);
                        running.push(seq);
                    } else {
                        // idle: jump to the next timeline event — a
                        // fresh arrival or a retried attempt — or, when
                        // no wake-up time is known, park on the source
                        // (a live daemon between requests) until it
                        // either produces work or finishes
                        let arrival_t = source.next_arrival_s();
                        let retry_t = retries
                            .first_key_value()
                            .map(|(&(t_bits, _), _)| f64::from_bits(t_bits));
                        let wake = match (arrival_t, retry_t) {
                            (Some(a), Some(r)) => Some(a.min(r)),
                            (a, r) => a.or(r),
                        };
                        if let Some(t) = wake {
                            clock.wait_until(t);
                            continue;
                        }
                        if source.finished() {
                            // drained (a leftover cancel for an id that
                            // already reached a terminal state is a
                            // no-op, not a reason to wait)
                            break;
                        }
                        source.park();
                        continue;
                    }
                }
                // (3b) block pressure: each decode token may need a
                // fresh block; preempt the most recently admitted
                // sequence until the step's appends fit
                while running.len() > 1 {
                    let need: usize =
                        running.iter().map(|s| kv.append_blocks_needed(s.req.id)).sum();
                    if need <= kv.available_blocks() {
                        break;
                    }
                    let victim = running.pop().unwrap();
                    match self.cfg.kv.policy {
                        KvPolicy::Swap => {
                            // an injected swap-out failure loses the
                            // spill mid-write: fall back to recompute
                            if fault_on && injector.swap_fails(&mut res) {
                                kv.preempt_recompute(victim.req.id);
                                requeued.push_front(victim);
                            } else {
                                let spilled = kv.preempt_swap(victim.req.id);
                                stall_s +=
                                    swap_traffic_s(dram.as_mut(), &spilled, block_bytes, freq_hz);
                                swapped.push_back(victim);
                            }
                        }
                        KvPolicy::Recompute => {
                            kv.preempt_recompute(victim.req.id);
                            requeued.push_front(victim);
                        }
                    }
                }
                let lone = running.len() == 1;
                for s in running.iter() {
                    // a lone sequence may overflow: it must terminate
                    let stored = kv.append(s.req.id, lone);
                    debug_assert!(stored, "append failed after the pressure check");
                }
                let ids: Vec<u64> = running.iter().map(|s| s.req.id).collect();
                let n = running.len();
                (StepKind::Decode, Workload::decode_step(self.model, n), ids, n)
            };

            // price the step.  Under a fault plan the injector's draws
            // for this step land first: a crash fires failover (the
            // dead replica's weight shard is re-assigned across the
            // survivors at a priced interconnect cost) and every later
            // step runs degraded; stragglers stretch the compute
            // latency; link degradation stalls the step's activation
            // traffic.
            let mut redist_s = 0.0f64;
            let priced_latency_s = if fault_on {
                let step_bytes = (tokens * self.model.hidden * 4) as f64;
                let faults = injector.begin_step(now, step_bytes, &mut res);
                for _ in &faults.crashes {
                    let cost = self.backend.redistribute_cost_s(
                        self.model.weight_bytes_ternary(),
                        injector.survivors(),
                    );
                    res.failovers += 1;
                    res.redistribution_s += cost;
                    redist_s += cost;
                }
                let base = if injector.degraded() {
                    self.backend.run_degraded(&workload, injector.alive()).latency_s
                } else {
                    self.backend.run(&workload).latency_s
                };
                res.fault_extra_s += base * (faults.slowdown - 1.0) + faults.link_penalty_s;
                base * faults.slowdown + faults.link_penalty_s
            } else {
                self.backend.run(&workload).latency_s
            };
            let step_s = priced_latency_s + self.cfg.step_overhead_s + stall_s + redist_s;
            kv.note_swap_stall(stall_s);
            let record = StepRecord {
                index: steps.len() as u64,
                kind,
                t_start_s: now,
                step_s,
                seq_ids,
                tokens,
            };
            let mut step_failed = false;
            if let Some(e) = exec.as_deref_mut() {
                if let Err(err) = e.execute(&record, &workload) {
                    if !resilience_on {
                        return Err(err);
                    }
                    // absorb the failure: the step's output is lost;
                    // its sequences are killed below and every attempt
                    // re-enters through the retry path
                    res.step_failures += 1;
                    step_failed = true;
                }
            }
            clock.advance(step_s);
            let t_end = clock.now();

            // (4) bookkeeping + eviction (finished sequences return
            // their blocks — the evict-after-finish path).  A step
            // whose functional execution failed still spent its priced
            // time, but its output is lost: every sequence it served is
            // killed, its KV and token reservation reclaimed, and the
            // attempt handed to the retry path.
            if step_failed {
                match kind {
                    StepKind::Prefill => metrics.prefill_steps += 1,
                    StepKind::Decode => {
                        metrics.decode_steps += 1;
                        metrics.decode_batch_sum += running.len() as u64;
                    }
                }
                let failed: Vec<Seq> = match kind {
                    StepKind::Prefill => promoted.into_iter().map(|p| p.seq).collect(),
                    StepKind::Decode => running.drain(..).collect(),
                };
                for s in failed {
                    kv.release(s.req.id);
                    inflight.release(class_of(&s.req), s.req.reserved_tokens(), &mut underflows);
                    if !schedule_retry(s.req, t_end, &rc, &mut attempts, &mut retries, &mut res) {
                        finish_request(
                            source,
                            &mut attempts,
                            &mut cancel_wanted,
                            s.req.id,
                            Outcome::Exhausted,
                        );
                    }
                }
            } else {
                match kind {
                    StepKind::Prefill => {
                        metrics.prefill_steps += 1;
                        let mut resumed: Vec<Partial> = Vec::new();
                        for p in promoted {
                            let mut s = p.seq;
                            let c = class_of(&s.req);
                            if p.admit {
                                metrics.admitted += 1;
                                cls[c].admitted += 1;
                                metrics.prompt_tokens += s.req.prompt_tokens as u64;
                                metrics.queue_wait.record(now - s.req.arrival_s);
                            }
                            if p.remaining > 0 {
                                // prompt not finished: carry the rest
                                // across steps (no token emitted yet)
                                let part = Partial {
                                    seq: s,
                                    remaining: p.remaining,
                                    done_emit: p.done_emit,
                                };
                                if p.from_partial {
                                    resumed.push(part);
                                } else {
                                    prefilling.push_back(part);
                                }
                                continue;
                            }
                            match p.done_emit {
                                Emit::First => {
                                    metrics.ttft.record(t_end - s.req.arrival_s);
                                    cls[c].ttft.record(t_end - s.req.arrival_s);
                                }
                                Emit::Next => {
                                    // a re-prefill emits the sequence's next
                                    // token: the preemption gap is a TPOT sample
                                    metrics.tpot.record(t_end - s.last_token_s);
                                    cls[c].tpot.record(t_end - s.last_token_s);
                                }
                            }
                            metrics.generated_tokens += 1;
                            s.generated += 1;
                            s.last_token_s = t_end;
                            if s.generated >= s.req.output_tokens {
                                metrics.completed += 1;
                                cls[c].completed += 1;
                                metrics.completed_tokens += s.req.output_tokens as u64;
                                cls[c].completed_tokens += s.req.output_tokens as u64;
                                metrics.e2e.record(t_end - s.req.arrival_s);
                                cls[c].e2e.record(t_end - s.req.arrival_s);
                                inflight.release(c, s.req.reserved_tokens(), &mut underflows);
                                kv.release(s.req.id);
                                finish_request(
                                    source,
                                    &mut attempts,
                                    &mut cancel_wanted,
                                    s.req.id,
                                    Outcome::Completed,
                                );
                            } else {
                                running.push(s);
                            }
                        }
                        // continuations rejoin at the FRONT (oldest
                        // first — reverse keeps their relative order)
                        // ahead of freshly chunked admissions
                        for part in resumed.into_iter().rev() {
                            prefilling.push_front(part);
                        }
                    }
                    StepKind::Decode => {
                        metrics.decode_steps += 1;
                        metrics.decode_batch_sum += running.len() as u64;
                        for s in running.iter_mut() {
                            s.generated += 1;
                            metrics.generated_tokens += 1;
                            // inter-token gap, not just this step's length:
                            // prefill steps that ran since the sequence's
                            // previous token are what loaded systems pay
                            metrics.tpot.record(t_end - s.last_token_s);
                            cls[class_of(&s.req)].tpot.record(t_end - s.last_token_s);
                            s.last_token_s = t_end;
                        }
                        running.retain(|s| {
                            if s.generated >= s.req.output_tokens {
                                let c = class_of(&s.req);
                                metrics.completed += 1;
                                cls[c].completed += 1;
                                metrics.completed_tokens += s.req.output_tokens as u64;
                                cls[c].completed_tokens += s.req.output_tokens as u64;
                                metrics.e2e.record(t_end - s.req.arrival_s);
                                cls[c].e2e.record(t_end - s.req.arrival_s);
                                inflight.release(c, s.req.reserved_tokens(), &mut underflows);
                                kv.release(s.req.id);
                                finish_request(
                                    source,
                                    &mut attempts,
                                    &mut cancel_wanted,
                                    s.req.id,
                                    Outcome::Completed,
                                );
                                false
                            } else {
                                true
                            }
                        });
                    }
                }
            }
            metrics.note_step(
                StepSample {
                    t_s: t_end,
                    queue_depth: queues.iter().map(|q| q.len()).sum::<usize>()
                        + requeued.len()
                        + swapped.len(),
                    batch: tokens,
                },
                inflight.total,
                step_s,
            );
            last_kind = Some(kind);
            steps.push(record);
        }

        // end-of-run quiescence, surfaced as checked leak counters in
        // the kv stats (formerly debug_asserts invisible in release
        // builds): blocks/sequences still held past drain and any
        // reservation-accounting underflows during the run
        metrics.kv = kv.snapshot(dram.as_ref());
        metrics.kv.token_release_underflows = underflows;
        let (leaked_blocks, leaked_seqs) = kv.leak_counts();
        metrics.kv.leaked_blocks = leaked_blocks;
        metrics.kv.leaked_seqs = leaked_seqs;
        metrics.kv.leaked_inflight_tokens = inflight.total as u64;
        metrics.makespan_s = clock.now();
        if classes_on {
            // trim trailing all-zero classes but keep at least the
            // configured class count so every tenant appears even when
            // one received no traffic
            let used = cls
                .iter()
                .rposition(|c| c.active())
                .map(|i| i + 1)
                .unwrap_or(1)
                .max(self.cfg.classes.min(MAX_CLASSES))
                .max(1);
            metrics.classes = Some(cls.into_iter().take(used).collect());
        }
        if resilience_on {
            res.availability = if metrics.offered > 0 {
                metrics.completed as f64 / metrics.offered as f64
            } else {
                1.0
            };
            metrics.resilience = Some(res);
        }
        Ok(RunResult { metrics, steps })
    }
}

/// Decode-capacity anchor: output tokens/s one `max_batch`-wide decode
/// step sustains on `backend`.  The sweep example, the serve_load
/// bench, and the saturation tests all place offered load relative to
/// this same yardstick.
pub fn decode_capacity_tok_s(
    backend: &dyn Backend,
    model: BitNetModel,
    max_batch: usize,
) -> f64 {
    let step = backend.run(&Workload::decode_step(model, max_batch)).latency_s;
    if step > 0.0 {
        max_batch as f64 / step
    } else {
        f64::INFINITY
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::PlatinumBackend;
    use crate::kv::KvConfig;
    use crate::traffic::clock::VirtualClock;
    use crate::traffic::loadgen::{with_shared_prefix, ArrivalPattern, LenDist, LoadSpec};

    /// A 2-layer toy model so modelled pricing stays microseconds-fast.
    const TINY: BitNetModel = BitNetModel {
        name: "tiny",
        params: "2M",
        hidden: 64,
        ffn: 160,
        heads: 4,
        kv_heads: 4,
        layers: 2,
    };

    fn poisson_load(rate: f64, requests: usize, seed: u64) -> Vec<TrafficRequest> {
        LoadSpec {
            pattern: ArrivalPattern::Poisson { rate_rps: rate },
            prompt: LenDist::Uniform { lo: 4, hi: 12 },
            output: LenDist::Fixed(6),
            requests,
            seed,
        }
        .generate()
        .unwrap()
    }

    /// TINY stores 256 B/token, so 4-token blocks are 1 KiB: `sram_kib`
    /// is the pool capacity in blocks, DRAM budget off.
    fn tight_kv(blocks: usize, policy: KvPolicy) -> KvConfig {
        KvConfig { block_tokens: 4, sram_kib: blocks, dram_mib: 0, policy, ..KvConfig::default() }
    }

    #[test]
    fn drains_every_request_and_counts_tokens() {
        let be = PlatinumBackend::ternary();
        let sched = Scheduler::new(&be, TINY, SchedulerConfig::default());
        let reqs = poisson_load(100.0, 40, 3);
        let mut clock = VirtualClock::new();
        let r = sched.serve(&reqs, &mut clock).unwrap();
        let m = &r.metrics;
        assert_eq!(m.offered, 40);
        assert_eq!(m.rejected, 0);
        assert_eq!(m.admitted, 40);
        assert_eq!(m.completed, 40);
        assert_eq!(m.completed_tokens, 40 * 6);
        assert_eq!(m.generated_tokens, 40 * 6);
        let prompts: u64 = reqs.iter().map(|q| q.prompt_tokens as u64).sum();
        assert_eq!(m.prompt_tokens, prompts);
        assert_eq!(m.ttft.count(), 40);
        assert_eq!(m.e2e.count(), 40);
        // every output token beyond the first came from a decode step
        assert_eq!(m.tpot.count(), 40 * 5);
        assert!(m.makespan_s > 0.0 && m.busy_s > 0.0);
        assert!(m.utilization() <= 1.0);
        // ample default KV capacity: blocks flow, nothing is evicted
        assert!(m.kv.allocated_max > 0);
        assert_eq!(m.kv.evictions, 0);
        assert_eq!(m.kv.overflow_max, 0);
        assert_eq!(m.kv.allocated_final, 0, "finished sequences returned every block");
        // decision log covers all steps in order
        assert_eq!(r.steps.len() as u64, m.steps());
        assert!(r.steps.windows(2).all(|w| w[0].index + 1 == w[1].index));
        assert!(r
            .steps
            .windows(2)
            .all(|w| w[0].t_start_s <= w[1].t_start_s));
    }

    #[test]
    fn simultaneous_arrivals_coalesce_into_batches() {
        let be = PlatinumBackend::ternary();
        let sched = Scheduler::new(&be, TINY, SchedulerConfig::default());
        // 16 requests all arriving at t=0, outputs long enough to decode
        let reqs: Vec<TrafficRequest> = (0..16)
            .map(|i| TrafficRequest {
                id: i,
                arrival_s: 0.0,
                prompt_tokens: 8,
                output_tokens: 10,
                ..TrafficRequest::default()
            })
            .collect();
        let r = sched.serve(&reqs, &mut VirtualClock::new()).unwrap();
        let m = &r.metrics;
        // one coalesced prefill (128 tokens < budget), then lockstep decode
        assert_eq!(m.prefill_steps, 1);
        assert_eq!(m.decode_steps, 9, "10 outputs = 1 prefill token + 9 decode steps");
        assert!((m.mean_decode_batch() - 16.0).abs() < 1e-9);
        assert_eq!(m.completed, 16);
    }

    #[test]
    fn queue_bound_rejects_and_never_exceeds() {
        let be = PlatinumBackend::ternary();
        let cfg = SchedulerConfig { max_queue: 4, max_batch: 2, ..SchedulerConfig::default() };
        let sched = Scheduler::new(&be, TINY, cfg);
        let reqs: Vec<TrafficRequest> = (0..64)
            .map(|i| TrafficRequest {
                id: i,
                arrival_s: 0.0,
                prompt_tokens: 4,
                output_tokens: 8,
                ..TrafficRequest::default()
            })
            .collect();
        let r = sched.serve(&reqs, &mut VirtualClock::new()).unwrap();
        let m = &r.metrics;
        assert!(m.rejected > 0, "open-loop overload must shed load");
        assert_eq!(m.offered, 64);
        assert_eq!(m.admitted + m.rejected, 64);
        assert_eq!(m.completed, m.admitted);
        assert!(m.queue_depth_max <= 4, "queue bound violated: {}", m.queue_depth_max);
    }

    #[test]
    fn token_backpressure_bounds_inflight() {
        let be = PlatinumBackend::ternary();
        let cfg = SchedulerConfig {
            max_inflight_tokens: 100,
            max_batch: 32,
            ..SchedulerConfig::default()
        };
        let sched = Scheduler::new(&be, TINY, cfg);
        let reqs: Vec<TrafficRequest> = (0..20)
            .map(|i| TrafficRequest {
                id: i,
                arrival_s: 0.0,
                prompt_tokens: 20,
                output_tokens: 20,
                ..TrafficRequest::default()
            })
            .collect();
        let r = sched.serve(&reqs, &mut VirtualClock::new()).unwrap();
        let m = &r.metrics;
        assert_eq!(m.completed, 20, "backpressure must delay, not deadlock");
        // 100-token budget over 40-token reservations ⇒ ≤ 2 in flight
        assert!(m.inflight_tokens_max <= 100, "{}", m.inflight_tokens_max);
        assert!(m.mean_decode_batch() <= 2.5);
    }

    #[test]
    fn oversized_request_is_admitted_alone_not_starved() {
        let be = PlatinumBackend::ternary();
        let cfg = SchedulerConfig {
            max_inflight_tokens: 50,
            max_prefill_tokens: 16,
            ..SchedulerConfig::default()
        };
        let sched = Scheduler::new(&be, TINY, cfg);
        // both the prompt and the reservation bust every budget
        let reqs = vec![TrafficRequest {
            id: 0,
            arrival_s: 0.0,
            prompt_tokens: 64,
            output_tokens: 64,
            ..TrafficRequest::default()
        }];
        let r = sched.serve(&reqs, &mut VirtualClock::new()).unwrap();
        assert_eq!(r.metrics.completed, 1);
        assert_eq!(r.steps[0].kind, StepKind::Prefill);
        assert_eq!(r.steps[0].tokens, 64);
    }

    #[test]
    fn step_executor_sees_every_step_and_cannot_change_decisions() {
        let be = PlatinumBackend::ternary();
        let sched = Scheduler::new(&be, TINY, SchedulerConfig::default());
        let reqs = poisson_load(200.0, 24, 9);
        let base = sched.serve(&reqs, &mut VirtualClock::new()).unwrap();
        let mut seen: Vec<(StepKind, usize)> = Vec::new();
        let mut hook = |s: &StepRecord, w: &Workload| -> anyhow::Result<()> {
            seen.push((s.kind, s.tokens));
            assert!(!w.label().is_empty());
            Ok(())
        };
        let hooked = sched
            .serve_with(&reqs, &mut VirtualClock::new(), Some(&mut hook))
            .unwrap();
        assert_eq!(seen.len(), hooked.steps.len());
        assert_eq!(base.steps, hooked.steps, "executor must not perturb decisions");
        assert_eq!(
            base.metrics.to_json().to_string(),
            hooked.metrics.to_json().to_string()
        );
    }

    #[test]
    fn idle_gaps_jump_to_next_arrival() {
        let be = PlatinumBackend::ternary();
        let sched = Scheduler::new(&be, TINY, SchedulerConfig::default());
        let reqs = vec![
            TrafficRequest {
                id: 0,
                arrival_s: 0.0,
                prompt_tokens: 4,
                output_tokens: 2,
                ..TrafficRequest::default()
            },
            TrafficRequest {
                id: 1,
                arrival_s: 100.0,
                prompt_tokens: 4,
                output_tokens: 2,
                ..TrafficRequest::default()
            },
        ];
        let r = sched.serve(&reqs, &mut VirtualClock::new()).unwrap();
        assert_eq!(r.metrics.completed, 2);
        assert!(r.metrics.makespan_s >= 100.0);
        assert!(r.metrics.utilization() < 0.5, "long idle gap must not count as busy");
    }

    #[test]
    fn shared_system_prompt_cuts_prefill_work_and_blocks() {
        let be = PlatinumBackend::ternary();
        let wave = |shared: usize| -> Vec<TrafficRequest> {
            let mut reqs: Vec<TrafficRequest> = (0..8)
                .map(|i| TrafficRequest {
                    id: i,
                    arrival_s: 0.0,
                    prompt_tokens: 4,
                    output_tokens: 4,
                    ..TrafficRequest::default()
                })
                .collect();
            with_shared_prefix(&mut reqs, shared);
            reqs
        };
        let run = |prefix_cache: bool| {
            let cfg = SchedulerConfig {
                kv: KvConfig { prefix_cache, ..KvConfig::default() },
                ..SchedulerConfig::default()
            };
            Scheduler::new(&be, TINY, cfg)
                .serve(&wave(64), &mut VirtualClock::new())
                .unwrap()
        };
        let on = run(true);
        let off = run(false);
        assert_eq!(on.metrics.completed, 8);
        assert_eq!(off.metrics.completed, 8);
        // the first admission computes the whole 68-token prompt and
        // populates the cache; the other 7 skip the 64 shared tokens
        assert_eq!(on.metrics.kv.prefix_lookups, 8);
        assert_eq!(on.metrics.kv.prefix_hits, 7);
        assert_eq!(on.metrics.kv.prefix_tokens_saved, 7 * 64);
        assert_eq!(on.steps[0].tokens, 68 + 7 * 4, "coalesced computed tokens");
        assert_eq!(off.steps[0].tokens, 8 * 68);
        // cheaper prefill ⇒ lower TTFT; shared blocks ⇒ fewer allocated
        assert!(
            on.metrics.ttft.mean().unwrap() < off.metrics.ttft.mean().unwrap(),
            "prefix caching must cut TTFT"
        );
        assert!(
            on.metrics.kv.allocated_max < off.metrics.kv.allocated_max,
            "shared span must not be stored 8 times: {} vs {}",
            on.metrics.kv.allocated_max,
            off.metrics.kv.allocated_max
        );
        // full prompt still counted as offered prompt tokens
        assert_eq!(on.metrics.prompt_tokens, 8 * 68);
    }

    #[test]
    fn block_pressure_preempts_via_recompute_and_still_drains() {
        let be = PlatinumBackend::ternary();
        let cfg = SchedulerConfig {
            kv: tight_kv(6, KvPolicy::Recompute),
            ..SchedulerConfig::default()
        };
        let sched = Scheduler::new(&be, TINY, cfg);
        let reqs: Vec<TrafficRequest> = (0..4)
            .map(|i| TrafficRequest {
                id: i,
                arrival_s: 0.0,
                prompt_tokens: 8,
                output_tokens: 8,
                ..TrafficRequest::default()
            })
            .collect();
        let r = sched.serve(&reqs, &mut VirtualClock::new()).unwrap();
        let m = &r.metrics;
        // 4 × (2 prompt blocks + appends) cannot fit 6 blocks at once
        assert_eq!(m.completed, 4, "preemption must delay, not deadlock");
        assert_eq!(m.generated_tokens, 4 * 8, "every token emitted exactly once");
        assert!(m.kv.evictions >= 1, "tight pool must evict");
        assert!(m.kv.recomputed_tokens >= 8, "dropped KV is recomputed");
        assert_eq!(m.kv.swap_outs, 0, "recompute policy never swaps");
        assert!(m.kv.utilization() >= 0.9, "pressure run should fill the pool");
        // re-prefills show up as extra prefill steps
        assert!(m.prefill_steps > 1, "{} prefill steps", m.prefill_steps);
    }

    #[test]
    fn block_pressure_swaps_and_prices_the_dram_traffic() {
        let be = PlatinumBackend::ternary();
        let cfg = SchedulerConfig {
            kv: tight_kv(6, KvPolicy::Swap),
            ..SchedulerConfig::default()
        };
        let sched = Scheduler::new(&be, TINY, cfg);
        let reqs: Vec<TrafficRequest> = (0..4)
            .map(|i| TrafficRequest {
                id: i,
                arrival_s: 0.0,
                prompt_tokens: 8,
                output_tokens: 8,
                ..TrafficRequest::default()
            })
            .collect();
        let r = sched.serve(&reqs, &mut VirtualClock::new()).unwrap();
        let m = &r.metrics;
        assert_eq!(m.completed, 4);
        assert_eq!(m.generated_tokens, 4 * 8);
        assert!(m.kv.swap_outs >= 1, "tight pool must swap out");
        assert!(m.kv.swap_ins >= 1, "swapped sequences must come back");
        assert_eq!(m.kv.recomputed_tokens, 0, "swap policy never recomputes");
        assert!(m.kv.swap_stall_s > 0.0, "swap traffic must stall the timeline");
        assert_eq!(
            m.kv.dram.bursts,
            (m.kv.swapped_out_bytes + m.kv.swapped_in_bytes) / 64,
            "every swapped byte moves through the DRAM timing model"
        );
        // same decisions twice: the pressure path is deterministic
        let again = sched.serve(&reqs, &mut VirtualClock::new()).unwrap();
        assert_eq!(
            r.metrics.to_json().to_string(),
            again.metrics.to_json().to_string()
        );
    }

    #[test]
    fn finished_sequences_free_blocks_for_queued_work() {
        // evict-after-finish regression: a 2-block pool serves two
        // 2-block prompts strictly in sequence — if release leaked, the
        // second would only fit through the overflow escape hatch
        let be = PlatinumBackend::ternary();
        let cfg = SchedulerConfig {
            kv: tight_kv(2, KvPolicy::Recompute),
            ..SchedulerConfig::default()
        };
        let sched = Scheduler::new(&be, TINY, cfg);
        let reqs: Vec<TrafficRequest> = (0..2)
            .map(|i| TrafficRequest {
                id: i,
                arrival_s: 0.0,
                prompt_tokens: 7,
                output_tokens: 2,
                ..TrafficRequest::default()
            })
            .collect();
        let r = sched.serve(&reqs, &mut VirtualClock::new()).unwrap();
        let m = &r.metrics;
        assert_eq!(m.completed, 2);
        assert_eq!(m.kv.allocated_max, 2, "never both resident");
        assert_eq!(m.kv.overflow_max, 0, "finished blocks were reused, not overflowed");
        assert_eq!(m.kv.evictions, 0, "sequential fit needs no preemption");
        assert_eq!(m.kv.allocated_final, 0);
        assert_eq!(m.prefill_steps, 2, "the second prompt waited for the first");
    }

    // ---- fault injection + resilience (S17) ----------------------------

    fn burst(n: u64, prompt: usize, output: usize) -> Vec<TrafficRequest> {
        (0..n)
            .map(|i| TrafficRequest {
                id: i,
                arrival_s: 0.0,
                prompt_tokens: prompt,
                output_tokens: output,
                ..TrafficRequest::default()
            })
            .collect()
    }

    #[test]
    fn empty_plan_and_inactive_config_emit_no_resilience_section() {
        let be = PlatinumBackend::ternary();
        let sched = Scheduler::new(&be, TINY, SchedulerConfig::default());
        let reqs = poisson_load(150.0, 32, 11);
        let plain = sched.serve(&reqs, &mut VirtualClock::new()).unwrap();
        let faulted = sched
            .serve_faults(&reqs, &mut VirtualClock::new(), None, &FaultPlan::default())
            .unwrap();
        let a = plain.metrics.to_json().to_string();
        assert_eq!(a, faulted.metrics.to_json().to_string());
        assert!(!a.contains("\"resilience\""), "inactive runs must not grow new keys");
        assert!(!a.contains("\"leaks\""), "clean runs must not report leaks");
    }

    #[test]
    fn deadlines_kill_overage_and_retries_re_enter_the_timeline() {
        let be = PlatinumBackend::ternary();
        let cfg = SchedulerConfig {
            max_batch: 2,
            step_overhead_s: 0.001,
            resilience: ResilienceConfig {
                deadline_s: Some(0.010),
                max_retries: 2,
                retry_base_s: 0.002,
                retry_cap_s: 0.008,
                ..ResilienceConfig::default()
            },
            ..SchedulerConfig::default()
        };
        let sched = Scheduler::new(&be, TINY, cfg);
        // 8 simultaneous requests over a 2-slot batch at ~1 ms/step:
        // the tail of the queue must blow the 10 ms deadline
        let reqs = burst(8, 8, 4);
        let run = || {
            sched
                .serve_faults(&reqs, &mut VirtualClock::new(), None, &FaultPlan::default())
                .unwrap()
        };
        let r = run();
        let m = &r.metrics;
        let res = m.resilience.as_ref().expect("resilience section");
        assert!(res.timeouts > 0, "queue tail must time out");
        assert!(res.retries > 0, "timed-out attempts must retry");
        // every offered request reaches exactly one terminal state
        assert_eq!(m.completed + res.shed + res.retry_exhausted, m.offered);
        assert!((res.availability - m.completed as f64 / m.offered as f64).abs() < 1e-12);
        assert!(m.completed > 0, "the head of the queue meets its deadline");
        assert!(!m.kv.leaked(), "kill paths must reclaim blocks and reservations");
        assert_eq!(
            r.metrics.to_json().to_string(),
            run().metrics.to_json().to_string(),
            "deadline/retry machinery must stay deterministic"
        );
    }

    #[test]
    fn unmeetable_deadline_without_retries_zeroes_availability() {
        let be = PlatinumBackend::ternary();
        let cfg = SchedulerConfig {
            step_overhead_s: 0.001,
            resilience: ResilienceConfig {
                deadline_s: Some(0.003),
                ..ResilienceConfig::default()
            },
            ..SchedulerConfig::default()
        };
        let sched = Scheduler::new(&be, TINY, cfg);
        // 8 output tokens ⇒ ≥ 8 steps ≈ 8 ms of service > 3 ms deadline
        let reqs = burst(4, 8, 8);
        let r = sched
            .serve_faults(&reqs, &mut VirtualClock::new(), None, &FaultPlan::default())
            .unwrap();
        let m = &r.metrics;
        let res = m.resilience.as_ref().unwrap();
        assert_eq!(m.completed, 0);
        assert_eq!(res.availability, 0.0);
        assert_eq!(res.timeouts, 4);
        assert_eq!(res.retry_exhausted, 4, "no retry budget ⇒ terminal on first kill");
        assert!(!m.kv.leaked());
    }

    #[test]
    fn brownout_sheds_low_slack_requests_at_depth() {
        let be = PlatinumBackend::ternary();
        let cfg = SchedulerConfig {
            max_batch: 2,
            step_overhead_s: 0.001,
            resilience: ResilienceConfig {
                deadline_s: Some(0.008),
                brownout_queue: 4,
                brownout_slack_s: 0.004,
                ..ResilienceConfig::default()
            },
            ..SchedulerConfig::default()
        };
        let sched = Scheduler::new(&be, TINY, cfg);
        let r = sched
            .serve_faults(&burst(12, 8, 6), &mut VirtualClock::new(), None, &FaultPlan::default())
            .unwrap();
        let m = &r.metrics;
        let res = m.resilience.as_ref().unwrap();
        assert!(res.shed > 0, "sustained overload must shed by deadline slack");
        assert_eq!(m.completed + res.shed + res.retry_exhausted, m.offered);
        assert!(res.availability < 1.0);
        assert!(!m.kv.leaked());
    }

    #[test]
    fn injected_swap_failures_fall_back_to_recompute() {
        let be = PlatinumBackend::ternary();
        let cfg =
            SchedulerConfig { kv: tight_kv(6, KvPolicy::Swap), ..SchedulerConfig::default() };
        let sched = Scheduler::new(&be, TINY, cfg);
        let reqs = burst(4, 8, 8);
        // sanity: this load swaps when healthy (same shape as the
        // block_pressure_swaps test)
        let healthy = sched.serve(&reqs, &mut VirtualClock::new()).unwrap();
        assert!(healthy.metrics.kv.swap_outs > 0);
        let plan = FaultPlan::parse("swapfail:p1").unwrap();
        let r = sched.serve_faults(&reqs, &mut VirtualClock::new(), None, &plan).unwrap();
        let m = &r.metrics;
        let res = m.resilience.as_ref().unwrap();
        assert!(res.swap_failures > 0);
        assert_eq!(m.kv.swap_outs, 0, "every swap-out failed over to recompute");
        assert!(m.kv.recomputed_tokens > 0, "the fallback recomputes the dropped KV");
        assert_eq!(m.completed, m.offered, "swap failures delay, never drop");
        assert!(!m.kv.leaked());
    }

    #[test]
    fn fault_plans_follow_the_seed_and_cost_time() {
        let be = PlatinumBackend::ternary();
        let reqs = poisson_load(150.0, 32, 11);
        let clean = Scheduler::new(&be, TINY, SchedulerConfig::default())
            .serve(&reqs, &mut VirtualClock::new())
            .unwrap();
        let plan = FaultPlan::parse("straggler:r0:p0.5:x8,linkdeg:0.5:1gbps").unwrap();
        let run = |seed: u64| {
            let cfg = SchedulerConfig {
                resilience: ResilienceConfig { fault_seed: seed, ..ResilienceConfig::default() },
                ..SchedulerConfig::default()
            };
            Scheduler::new(&be, TINY, cfg)
                .serve_faults(&reqs, &mut VirtualClock::new(), None, &plan)
                .unwrap()
        };
        let r = run(7);
        let m = &r.metrics;
        let res = m.resilience.as_ref().unwrap();
        assert!(res.straggler_hits > 0 && res.linkdeg_hits > 0);
        assert!(res.fault_extra_s > 0.0);
        assert!(m.makespan_s > clean.metrics.makespan_s, "faults must cost time");
        assert_eq!(m.completed, m.offered, "pure slowdowns delay, never drop");
        assert_eq!(
            m.to_json().to_string(),
            run(7).metrics.to_json().to_string(),
            "same seed + same plan ⇒ byte-identical metrics"
        );
        assert_ne!(
            m.to_json().to_string(),
            run(8).metrics.to_json().to_string(),
            "the fault stream follows the seed"
        );
    }

    #[test]
    fn executor_failure_is_absorbed_and_retried_when_resilient() {
        let be = PlatinumBackend::ternary();
        let reqs = burst(4, 8, 6);
        let fail_second_step = || {
            let mut n = 0u64;
            move |_: &StepRecord, _: &Workload| -> Result<()> {
                n += 1;
                if n == 2 {
                    anyhow::bail!("injected executor failure")
                }
                Ok(())
            }
        };
        // legacy contract: without resilience the error propagates
        let sched = Scheduler::new(&be, TINY, SchedulerConfig::default());
        let mut hook = fail_second_step();
        assert!(sched.serve_with(&reqs, &mut VirtualClock::new(), Some(&mut hook)).is_err());
        // with a retry budget the failed step's sequences are killed,
        // reclaimed, retried, and the run still drains everything
        let cfg = SchedulerConfig {
            resilience: ResilienceConfig { max_retries: 3, ..ResilienceConfig::default() },
            ..SchedulerConfig::default()
        };
        let sched = Scheduler::new(&be, TINY, cfg);
        let mut hook = fail_second_step();
        let r = sched.serve_with(&reqs, &mut VirtualClock::new(), Some(&mut hook)).unwrap();
        let m = &r.metrics;
        let res = m.resilience.as_ref().unwrap();
        assert_eq!(res.step_failures, 1);
        assert!(res.retries >= 1);
        assert_eq!(m.completed, m.offered, "the failed step's sequences recovered");
        assert!(!m.kv.leaked(), "absorbed failures must not leak blocks");
    }

    #[test]
    fn per_request_deadlines_bite_without_global_config() {
        let be = PlatinumBackend::ternary();
        // resilience config left fully inert: the deadline rides on the
        // requests themselves (the live server's X-Deadline-Ms path)
        let cfg =
            SchedulerConfig { max_batch: 2, step_overhead_s: 0.001, ..SchedulerConfig::default() };
        let sched = Scheduler::new(&be, TINY, cfg);
        let reqs: Vec<TrafficRequest> = (0..8)
            .map(|i| TrafficRequest {
                id: i,
                arrival_s: 0.0,
                prompt_tokens: 8,
                output_tokens: 6,
                // odd ids can't possibly finish 6 tokens in 4 ms over a
                // 2-slot batch at ~1 ms/step; even ids are unconstrained
                deadline_s: if i % 2 == 1 { Some(0.004) } else { None },
                ..TrafficRequest::default()
            })
            .collect();
        let run = || sched.serve(&reqs, &mut VirtualClock::new()).unwrap();
        let r = run();
        let m = &r.metrics;
        let res = m.resilience.as_ref().expect("request deadlines must emit the section");
        assert!(res.timeouts > 0, "tight per-request deadlines must kill");
        assert_eq!(res.retry_exhausted, res.timeouts, "no retry budget ⇒ terminal kills");
        assert!(m.completed >= 4, "requests without deadlines must be untouched");
        assert_eq!(m.completed + res.retry_exhausted, m.offered);
        assert!(!m.kv.leaked(), "deadline kills must reclaim blocks and reservations");
        assert_eq!(
            r.metrics.to_json().to_string(),
            run().metrics.to_json().to_string(),
            "per-request deadlines keep the determinism contract"
        );
    }
}
