//! Serving metrics: latency histograms (TTFT / TPOT / end-to-end /
//! queue wait), admission counters, queue-depth and batch-size time
//! series, and goodput — serialized through [`crate::util::json`] in
//! the same Report-JSON style as the rest of the crate.
//!
//! The histogram is **fixed-bucket** (log-spaced, 10 buckets per
//! decade from 100 ns up): recording is O(1), memory is constant, and
//! — critically for the determinism suite — the percentile estimates
//! are pure functions of the bucket counts, so two runs that make the
//! same recordings serialize byte-identical JSON.

use crate::fault::ResilienceStats;
use crate::kv::KvStats;
use crate::util::json::{arr, num, obj, Json};

/// Number of log-spaced buckets: 10 per decade starting at
/// [`Histogram::FLOOR_S`], covering 1e-7 s … >1e5 s.
const BUCKETS: usize = 121;

/// Fixed-bucket log-scale latency histogram.
///
/// Percentiles report the **upper bound** of the bucket holding the
/// requested rank (a deterministic ≤25% overestimate — one bucket is
/// 10^(1/10) ≈ 1.26× wide), alongside the exact mean and max.
#[derive(Debug, Clone)]
pub struct Histogram {
    counts: Vec<u64>,
    total: u64,
    sum: f64,
    max: f64,
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram::new()
    }
}

impl Histogram {
    /// Lower bound of bucket 0 (100 ns); everything smaller lands there.
    pub const FLOOR_S: f64 = 1e-7;

    pub fn new() -> Histogram {
        Histogram { counts: vec![0; BUCKETS], total: 0, sum: 0.0, max: 0.0 }
    }

    fn bucket(v: f64) -> usize {
        if v <= Self::FLOOR_S {
            return 0;
        }
        let i = ((v / Self::FLOOR_S).log10() * 10.0).floor() as usize;
        i.min(BUCKETS - 1)
    }

    /// Upper bound of bucket `i` (seconds).
    fn upper(i: usize) -> f64 {
        Self::FLOOR_S * 10f64.powf((i + 1) as f64 / 10.0)
    }

    /// Record one latency sample (seconds).  Non-finite or negative
    /// samples are ignored.
    pub fn record(&mut self, v: f64) {
        if !v.is_finite() || v < 0.0 {
            return;
        }
        self.counts[Self::bucket(v)] += 1;
        self.total += 1;
        self.sum += v;
        if v > self.max {
            self.max = v;
        }
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn mean(&self) -> Option<f64> {
        if self.total > 0 {
            Some(self.sum / self.total as f64)
        } else {
            None
        }
    }

    pub fn max(&self) -> Option<f64> {
        if self.total > 0 {
            Some(self.max)
        } else {
            None
        }
    }

    /// Bucket-resolution quantile `q` in [0, 1]: the upper bound of the
    /// bucket containing the ⌈q·total⌉-th sample.
    pub fn quantile(&self, q: f64) -> Option<f64> {
        if self.total == 0 {
            return None;
        }
        let rank = ((q * self.total as f64).ceil() as u64).clamp(1, self.total);
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return Some(Self::upper(i));
            }
        }
        Some(Self::upper(BUCKETS - 1))
    }

    /// `{count, mean, max, p50, p95, p99}` — percentiles/mean/max are
    /// `null` when nothing was recorded.
    pub fn to_json(&self) -> Json {
        let o = |v: Option<f64>| v.map(num).unwrap_or(Json::Null);
        obj(vec![
            ("count", num(self.total as f64)),
            ("mean", o(self.mean())),
            ("max", o(self.max())),
            ("p50", o(self.quantile(0.50))),
            ("p95", o(self.quantile(0.95))),
            ("p99", o(self.quantile(0.99))),
        ])
    }
}

/// Per-SLO-class slice of the serving metrics (multi-tenant runs): the
/// latency histograms and admission counters that measure one class's
/// service quality — and its interference with the others.  Indexed by
/// class id; serialized as the conditional `classes` array of
/// [`TrafficMetrics::to_json`].
#[derive(Debug, Clone, Default)]
pub struct ClassMetrics {
    pub ttft: Histogram,
    pub tpot: Histogram,
    pub e2e: Histogram,
    pub offered: u64,
    pub admitted: u64,
    pub rejected: u64,
    pub completed: u64,
    /// Brownout sheds charged to this class's queue.
    pub shed: u64,
    /// Output tokens of completed requests (per-class goodput
    /// numerator).
    pub completed_tokens: u64,
}

impl ClassMetrics {
    /// Whether the class saw any traffic at all (trailing inactive
    /// classes are trimmed from the serialized array).
    pub fn active(&self) -> bool {
        self.offered > 0
            || self.admitted > 0
            || self.rejected > 0
            || self.completed > 0
            || self.shed > 0
    }

    /// The per-class entry of the `classes` metrics array.
    pub fn to_json(&self, makespan_s: f64) -> Json {
        let goodput = if makespan_s > 0.0 {
            self.completed_tokens as f64 / makespan_s
        } else {
            0.0
        };
        obj(vec![
            (
                "counts",
                obj(vec![
                    ("offered", num(self.offered as f64)),
                    ("admitted", num(self.admitted as f64)),
                    ("rejected", num(self.rejected as f64)),
                    ("completed", num(self.completed as f64)),
                    ("shed", num(self.shed as f64)),
                ]),
            ),
            (
                "latency_s",
                obj(vec![
                    ("ttft", self.ttft.to_json()),
                    ("tpot", self.tpot.to_json()),
                    ("e2e", self.e2e.to_json()),
                ]),
            ),
            ("completed_output_tokens", num(self.completed_tokens as f64)),
            ("goodput_tokens_per_s", num(goodput)),
        ])
    }
}

/// One per-step sample of the time series.
#[derive(Debug, Clone, Copy)]
pub struct StepSample {
    /// Timeline position at the end of the step (s).
    pub t_s: f64,
    /// Waiting (admitted-but-queued) requests after the step.
    pub queue_depth: usize,
    /// Sequences served by the step (prompt count for prefill steps,
    /// batch size for decode steps).
    pub batch: usize,
}

/// Cap on serialized time-series points; longer runs are downsampled
/// by a deterministic stride so the JSON stays bounded.
const SERIES_CAP: usize = 200;

/// Aggregate serving metrics for one load run.
#[derive(Debug, Clone, Default)]
pub struct TrafficMetrics {
    /// Time to first token: arrival → end of the prefill step.
    pub ttft: Histogram,
    /// Time per output token: the gap between a sequence's consecutive
    /// tokens (its decode step **plus** any prefill steps interleaved
    /// since its previous token), one sample per sequence per decode
    /// step.
    pub tpot: Histogram,
    /// End-to-end: arrival → final token.
    pub e2e: Histogram,
    /// Arrival → admission into a prefill batch.
    pub queue_wait: Histogram,

    pub offered: u64,
    pub admitted: u64,
    pub rejected: u64,
    pub completed: u64,
    /// Requests killed by client cancellation (e.g. a dropped
    /// connection mid-stream) — serialized only when > 0, so runs
    /// without cancellations keep the legacy JSON schema byte-for-byte.
    pub cancelled: u64,

    pub prefill_steps: u64,
    pub decode_steps: u64,
    /// Sum of decode batch sizes (mean = sum / decode_steps).
    pub decode_batch_sum: u64,
    pub queue_depth_sum: u64,
    pub queue_depth_max: usize,
    pub inflight_tokens_max: usize,

    pub prompt_tokens: u64,
    pub generated_tokens: u64,
    /// Output tokens of *completed* requests only — the goodput
    /// numerator (tokens burned on rejected/unfinished work don't
    /// count).
    pub completed_tokens: u64,

    /// Total priced service time across steps (s).
    pub busy_s: f64,
    /// Timeline position when the run drained (s).
    pub makespan_s: f64,

    /// End-of-run KV-cache snapshot (block utilization, prefix-cache
    /// hits, swap/recompute pressure, DRAM row-buffer locality).
    pub kv: KvStats,

    /// Per-SLO-class metrics, indexed by class id — `Some` only when
    /// more than one class was configured or a request carried a
    /// nonzero class, so single-tenant runs serialize byte-identically
    /// to the pre-class era.
    pub classes: Option<Vec<ClassMetrics>>,

    /// Fault-injection / SLO-resilience counters — `Some` only when a
    /// fault plan or a resilience response was active, so fault-free
    /// runs serialize byte-identically to the pre-resilience era.
    pub resilience: Option<ResilienceStats>,

    series: Vec<StepSample>,
}

impl TrafficMetrics {
    pub fn new() -> TrafficMetrics {
        TrafficMetrics::default()
    }

    /// Record the end-of-step snapshot shared by both step kinds.
    pub fn note_step(&mut self, sample: StepSample, inflight_tokens: usize, step_s: f64) {
        self.queue_depth_sum += sample.queue_depth as u64;
        self.queue_depth_max = self.queue_depth_max.max(sample.queue_depth);
        self.inflight_tokens_max = self.inflight_tokens_max.max(inflight_tokens);
        self.busy_s += step_s;
        self.series.push(sample);
    }

    pub fn steps(&self) -> u64 {
        self.prefill_steps + self.decode_steps
    }

    pub fn mean_decode_batch(&self) -> f64 {
        if self.decode_steps > 0 {
            self.decode_batch_sum as f64 / self.decode_steps as f64
        } else {
            0.0
        }
    }

    pub fn mean_queue_depth(&self) -> f64 {
        let steps = self.steps();
        if steps > 0 {
            self.queue_depth_sum as f64 / steps as f64
        } else {
            0.0
        }
    }

    /// Completed output tokens per second of makespan — the headline
    /// goodput-vs-offered-load figure.
    pub fn goodput_tokens_per_s(&self) -> f64 {
        if self.makespan_s > 0.0 {
            self.completed_tokens as f64 / self.makespan_s
        } else {
            0.0
        }
    }

    /// Fraction of the makespan spent executing steps.
    pub fn utilization(&self) -> f64 {
        if self.makespan_s > 0.0 {
            (self.busy_s / self.makespan_s).min(1.0)
        } else {
            0.0
        }
    }

    /// The queue-depth / batch-size series, downsampled to at most
    /// [`SERIES_CAP`] points with a deterministic stride.
    pub fn series(&self) -> Vec<StepSample> {
        let stride = self.series.len().div_ceil(SERIES_CAP).max(1);
        self.series.iter().step_by(stride).copied().collect()
    }

    pub fn to_json(&self) -> Json {
        let series = self.series();
        let makespan = self.makespan_s;
        let rps = |n: u64| if makespan > 0.0 { n as f64 / makespan } else { 0.0 };
        let mut counts = vec![
            ("offered", num(self.offered as f64)),
            ("admitted", num(self.admitted as f64)),
            ("rejected", num(self.rejected as f64)),
            ("completed", num(self.completed as f64)),
        ];
        // conditional so cancellation-free runs keep the legacy schema
        if self.cancelled > 0 {
            counts.push(("cancelled", num(self.cancelled as f64)));
        }
        let mut fields = vec![
            ("counts", obj(counts)),
            (
                "latency_s",
                obj(vec![
                    ("ttft", self.ttft.to_json()),
                    ("tpot", self.tpot.to_json()),
                    ("e2e", self.e2e.to_json()),
                    ("queue_wait", self.queue_wait.to_json()),
                ]),
            ),
            (
                "steps",
                obj(vec![
                    ("total", num(self.steps() as f64)),
                    ("prefill", num(self.prefill_steps as f64)),
                    ("decode", num(self.decode_steps as f64)),
                    ("mean_decode_batch", num(self.mean_decode_batch())),
                    ("mean_queue_depth", num(self.mean_queue_depth())),
                    ("max_queue_depth", num(self.queue_depth_max as f64)),
                    ("max_inflight_tokens", num(self.inflight_tokens_max as f64)),
                ]),
            ),
            (
                "tokens",
                obj(vec![
                    ("prompt", num(self.prompt_tokens as f64)),
                    ("generated", num(self.generated_tokens as f64)),
                    ("completed_output", num(self.completed_tokens as f64)),
                ]),
            ),
            (
                "throughput",
                obj(vec![
                    ("offered_rps", num(rps(self.offered))),
                    ("completed_rps", num(rps(self.completed))),
                    ("goodput_tokens_per_s", num(self.goodput_tokens_per_s())),
                    ("busy_s", num(self.busy_s)),
                    ("makespan_s", num(makespan)),
                    ("utilization", num(self.utilization())),
                ]),
            ),
            ("kv", self.kv.to_json()),
        ];
        // conditional so single-tenant runs stay byte-identical
        if let Some(classes) = &self.classes {
            fields.push((
                "classes",
                arr(classes.iter().map(|c| c.to_json(makespan)).collect()),
            ));
        }
        // conditional so fault-free runs stay byte-identical
        if let Some(res) = &self.resilience {
            fields.push(("resilience", res.to_json()));
        }
        fields.push((
            "series",
            obj(vec![
                ("t_s", arr(series.iter().map(|p| num(p.t_s)).collect())),
                (
                    "queue_depth",
                    arr(series.iter().map(|p| num(p.queue_depth as f64)).collect()),
                ),
                ("batch", arr(series.iter().map(|p| num(p.batch as f64)).collect())),
            ]),
        ));
        obj(fields)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_quantiles_bracket_samples() {
        let mut h = Histogram::new();
        for i in 1..=100 {
            h.record(i as f64 * 1e-3); // 1..100 ms
        }
        assert_eq!(h.count(), 100);
        let p50 = h.quantile(0.50).unwrap();
        let p99 = h.quantile(0.99).unwrap();
        // bucket upper bounds: within one bucket width (×1.26) above
        assert!(p50 >= 0.050 && p50 <= 0.050 * 1.26, "p50 {p50}");
        assert!(p99 >= 0.099 && p99 <= 0.099 * 1.26, "p99 {p99}");
        assert!((h.mean().unwrap() - 0.0505).abs() < 1e-9);
        assert!((h.max().unwrap() - 0.1).abs() < 1e-12);
    }

    #[test]
    fn histogram_edges_and_garbage() {
        let mut h = Histogram::new();
        h.record(0.0); // below floor → bucket 0
        h.record(1e9); // beyond range → clamped to last bucket
        h.record(f64::NAN); // ignored
        h.record(-1.0); // ignored
        assert_eq!(h.count(), 2);
        assert!(h.quantile(0.01).unwrap() <= Histogram::FLOOR_S * 1.26);
        assert!(h.quantile(1.0).unwrap() > 1e4);
    }

    #[test]
    fn empty_histogram_serializes_nulls() {
        let h = Histogram::new();
        assert_eq!(h.quantile(0.5), None);
        let j = h.to_json().to_string();
        assert!(j.contains("\"p99\":null") && j.contains("\"count\":0"), "{j}");
    }

    #[test]
    fn histogram_json_is_deterministic() {
        let run = || {
            let mut h = Histogram::new();
            let mut rng = crate::util::rng::Rng::seed_from(7);
            for _ in 0..5000 {
                h.record(rng.exponential(100.0));
            }
            h.to_json().to_string()
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn series_downsamples_deterministically() {
        let mut m = TrafficMetrics::new();
        for i in 0..1000 {
            m.note_step(
                StepSample { t_s: i as f64, queue_depth: i % 7, batch: 3 },
                10,
                0.001,
            );
        }
        let s = m.series();
        assert!(s.len() <= SERIES_CAP, "{}", s.len());
        assert_eq!(s[0].t_s, 0.0);
        // stride 5 over 1000 points
        assert_eq!(s[1].t_s, 5.0);
        assert_eq!(m.queue_depth_max, 6);
        assert!((m.busy_s - 1.0).abs() < 1e-9);
    }

    #[test]
    fn resilience_section_appears_only_when_active() {
        let mut m = TrafficMetrics::new();
        assert!(
            !m.to_json().to_string().contains("\"resilience\""),
            "fault-free runs must not emit the section"
        );
        m.resilience =
            Some(ResilienceStats { timeouts: 2, availability: 0.5, ..ResilienceStats::default() });
        let j = m.to_json();
        assert_eq!(j.get("resilience").unwrap().get("availability").unwrap().as_f64(), Some(0.5));
        // placement: between kv and series so readers find it with the
        // other end-of-run sections
        let text = j.to_string();
        let (kv, res, ser) = (
            text.find("\"kv\"").unwrap(),
            text.find("\"resilience\"").unwrap(),
            text.find("\"series\"").unwrap(),
        );
        assert!(kv < res && res < ser, "{text}");
    }

    #[test]
    fn classes_section_appears_only_when_present() {
        let mut m = TrafficMetrics::new();
        assert!(
            !m.to_json().to_string().contains("\"classes\""),
            "single-tenant runs must not emit the section"
        );
        let mut interactive = ClassMetrics::default();
        interactive.offered = 5;
        interactive.completed = 4;
        interactive.completed_tokens = 40;
        interactive.ttft.record(0.01);
        let batch = ClassMetrics::default();
        assert!(interactive.active());
        assert!(!batch.active());
        m.classes = Some(vec![interactive, batch]);
        m.makespan_s = 10.0;
        let j = m.to_json();
        let cls = j.get("classes").unwrap().as_arr().unwrap();
        assert_eq!(cls.len(), 2);
        let c0 = &cls[0];
        assert_eq!(c0.get("counts").unwrap().get("offered").unwrap().as_f64(), Some(5.0));
        assert_eq!(c0.get("goodput_tokens_per_s").unwrap().as_f64(), Some(4.0));
        assert_eq!(
            c0.get("latency_s").unwrap().get("ttft").unwrap().get("count").unwrap().as_f64(),
            Some(1.0)
        );
        // placement: after kv, before resilience/series
        let text = j.to_string();
        let (kv, cl, ser) = (
            text.find("\"kv\"").unwrap(),
            text.find("\"classes\"").unwrap(),
            text.find("\"series\"").unwrap(),
        );
        assert!(kv < cl && cl < ser, "{text}");
    }

    #[test]
    fn cancelled_count_appears_only_when_nonzero() {
        let mut m = TrafficMetrics::new();
        assert!(
            !m.to_json().to_string().contains("\"cancelled\""),
            "cancellation-free runs must keep the legacy counts schema"
        );
        m.cancelled = 3;
        let j = m.to_json();
        assert_eq!(j.get("counts").unwrap().get("cancelled").unwrap().as_f64(), Some(3.0));
    }

    #[test]
    fn goodput_and_utilization() {
        let mut m = TrafficMetrics::new();
        m.completed_tokens = 500;
        m.makespan_s = 10.0;
        m.busy_s = 4.0;
        assert_eq!(m.goodput_tokens_per_s(), 50.0);
        assert_eq!(m.utilization(), 0.4);
        let j = m.to_json().to_string();
        assert!(j.contains("\"goodput_tokens_per_s\":50"), "{j}");
    }
}
