//! Deterministic load generation: request arrival processes and
//! prompt/output length distributions.
//!
//! A [`LoadSpec`] expands to a concrete, fully-materialized request
//! trace (`Vec<TrafficRequest>`) **before** the serving loop starts —
//! the generator and the scheduler share no state, so the same seed
//! always produces the same trace regardless of how the scheduler
//! interleaves execution.  Three arrival processes:
//!
//! * [`ArrivalPattern::Poisson`] — exponential inter-arrivals at a
//!   fixed rate, the classic open-loop model.
//! * [`ArrivalPattern::Burst`] — a 2-state Markov-modulated Poisson
//!   process (calm/burst with exponential sojourns); by memorylessness
//!   the redraw-on-switch construction is exact.  Mean rate matches the
//!   configured rate, so sweeps stay comparable with Poisson.
//! * [`ArrivalPattern::Replay`] — verbatim arrival offsets from a
//!   recorded trace (one f64 seconds-offset per request).

use crate::util::rng::Rng;
use anyhow::{anyhow, bail, Result};

/// Upper bound on distinct SLO classes one run can carry; class ids
/// are clamped into `0..MAX_CLASSES` by the scheduler, so a fixed-size
/// table suffices everywhere (keeps `SchedulerConfig` `Copy`).
pub const MAX_CLASSES: usize = 4;

/// One request of the load trace: a prompt to prefill and a number of
/// output tokens to decode, arriving at a fixed offset from run start.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TrafficRequest {
    pub id: u64,
    /// Arrival offset from the start of the run (s).
    pub arrival_s: f64,
    /// Prompt length (tokens prefilled in one pass), **including** any
    /// shared system-prompt prefix.
    pub prompt_tokens: usize,
    /// Output length (tokens decoded one step each); the first output
    /// token is produced by the prefill step itself.
    pub output_tokens: usize,
    /// Leading prompt tokens shared verbatim across requests (the
    /// system prompt) — what the KV prefix cache can deduplicate.
    pub shared_prefix_tokens: usize,
    /// Per-request deadline (seconds from `arrival_s`), carried by live
    /// requests (`X-Deadline-Ms` header) and captured traces; overrides
    /// the global `ResilienceConfig::deadline_s` when set.
    pub deadline_s: Option<f64>,
    /// SLO class (tenant tier) of the request — an index into the
    /// run's class table ([`TenantMix`] / `SchedulerConfig`); 0 is the
    /// default single-tenant class, so legacy traces are class 0.
    pub class: u8,
}

impl Default for TrafficRequest {
    fn default() -> TrafficRequest {
        TrafficRequest {
            id: 0,
            arrival_s: 0.0,
            prompt_tokens: 1,
            output_tokens: 1,
            shared_prefix_tokens: 0,
            deadline_s: None,
            class: 0,
        }
    }
}

impl TrafficRequest {
    /// Tokens this request reserves while in flight (KV-cache style
    /// conservative reservation: full prompt + full output; prefix
    /// sharing is accounted at block granularity by the KV allocator,
    /// not here).
    pub fn reserved_tokens(&self) -> usize {
        self.prompt_tokens + self.output_tokens
    }
}

/// Prepend a shared system prompt of `tokens` tokens to every request:
/// prompt lengths grow by `tokens` and the shared span is marked so the
/// KV prefix cache can deduplicate it.  A no-op when `tokens` is 0.
pub fn with_shared_prefix(requests: &mut [TrafficRequest], tokens: usize) {
    if tokens == 0 {
        // true no-op: leave any per-request shared spans (e.g. from a
        // capture-v1 replay trace) untouched
        return;
    }
    for r in requests.iter_mut() {
        r.prompt_tokens += tokens;
        r.shared_prefix_tokens = tokens;
    }
}

/// One SLO class of a tenant mix: a share of the offered traffic and a
/// weighted-fair-queueing weight for the scheduler's admission.
#[derive(Debug, Clone, PartialEq)]
pub struct TenantClass {
    pub name: String,
    /// Fraction of offered requests assigned to this class (the mix
    /// shares must sum to 1).
    pub share: f64,
    /// WFQ weight: this class's relative share of the scheduler's
    /// in-flight token budget while classes compete.
    pub weight: u32,
}

/// A tenant/SLO-class mix, parsed from the CLI grammar
/// `name:share[:w<weight>],...` — e.g.
/// `interactive:0.7:w4,batch:0.3:w1`.  Class ids are the positions in
/// the grammar (first entry is class 0).
#[derive(Debug, Clone, PartialEq, Default)]
pub struct TenantMix {
    pub classes: Vec<TenantClass>,
}

impl TenantMix {
    /// Parse the CLI grammar.  Shares must be positive and sum to 1
    /// (±1e-6); weights default to 1 and must be ≥ 1; at most
    /// [`MAX_CLASSES`] classes.
    pub fn parse(spec: &str) -> Result<TenantMix> {
        let mut classes = Vec::new();
        for part in spec.split(',') {
            let part = part.trim();
            if part.is_empty() {
                bail!("tenant mix {spec:?} has an empty class entry");
            }
            let fields: Vec<&str> = part.split(':').collect();
            if fields.len() < 2 || fields.len() > 3 {
                bail!("tenant class {part:?} is not name:share[:w<weight>]");
            }
            let name = fields[0].trim();
            if name.is_empty() || !name.chars().all(|c| c.is_ascii_alphanumeric() || c == '-') {
                bail!("tenant class name {name:?} must be non-empty alphanumeric/dash");
            }
            let share: f64 = fields[1]
                .parse()
                .map_err(|_| anyhow!("tenant class {part:?} has a bad share"))?;
            if !share.is_finite() || share <= 0.0 || share > 1.0 {
                bail!("tenant class {part:?} needs a share in (0, 1]");
            }
            let weight = match fields.get(2) {
                None => 1u32,
                Some(w) => {
                    let w = w
                        .strip_prefix('w')
                        .ok_or_else(|| anyhow!("tenant class {part:?}: weight must be w<n>"))?;
                    let w: u32 =
                        w.parse().map_err(|_| anyhow!("tenant class {part:?} has a bad weight"))?;
                    if w == 0 {
                        bail!("tenant class {part:?} needs a weight >= 1");
                    }
                    w
                }
            };
            if classes.iter().any(|c: &TenantClass| c.name == name) {
                bail!("tenant class {name:?} appears twice in {spec:?}");
            }
            classes.push(TenantClass { name: name.to_string(), share, weight });
        }
        if classes.len() > MAX_CLASSES {
            bail!("tenant mix {spec:?} has more than {MAX_CLASSES} classes");
        }
        let sum: f64 = classes.iter().map(|c| c.share).sum();
        if (sum - 1.0).abs() > 1e-6 {
            bail!("tenant mix shares must sum to 1, got {sum} in {spec:?}");
        }
        Ok(TenantMix { classes })
    }

    /// Class id of `name` (position in the grammar), case-insensitive.
    pub fn class_id(&self, name: &str) -> Option<u8> {
        self.classes.iter().position(|c| c.name.eq_ignore_ascii_case(name)).map(|i| i as u8)
    }

    /// The WFQ weight table the scheduler consumes (unconfigured slots
    /// default to weight 1).
    pub fn weights(&self) -> [u32; MAX_CLASSES] {
        let mut w = [1u32; MAX_CLASSES];
        for (i, c) in self.classes.iter().enumerate().take(MAX_CLASSES) {
            w[i] = c.weight;
        }
        w
    }

    /// Round-trippable spec string (`name:share:w<weight>,...`) for
    /// config echoes.
    pub fn label(&self) -> String {
        self.classes
            .iter()
            .map(|c| format!("{}:{}:w{}", c.name, c.share, c.weight))
            .collect::<Vec<_>>()
            .join(",")
    }

    /// Assign classes to a materialized trace by the mix shares.  The
    /// draw uses its own stream derived from `seed`, so a trace with a
    /// tenant mix keeps the exact shapes/arrivals of the same trace
    /// without one — classes ride on top.
    pub fn assign(&self, requests: &mut [TrafficRequest], seed: u64) {
        if self.classes.len() <= 1 {
            return;
        }
        let mut rng = Rng::seed_from(seed ^ 0x7E4A_47C1);
        for r in requests.iter_mut() {
            let u = rng.f64();
            let mut acc = 0.0;
            let mut class = self.classes.len() - 1;
            for (i, c) in self.classes.iter().enumerate() {
                acc += c.share;
                if u < acc {
                    class = i;
                    break;
                }
            }
            r.class = class as u8;
        }
    }
}

/// Prompt/output token-length distribution.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LenDist {
    /// Every request has exactly this many tokens.
    Fixed(usize),
    /// Uniform integer in `[lo, hi]` inclusive.
    Uniform { lo: usize, hi: usize },
}

impl LenDist {
    /// Parse the CLI grammar: `"16"` (fixed) or `"8:32"` (uniform).
    pub fn parse(spec: &str) -> Result<LenDist> {
        let spec = spec.trim();
        if let Some((lo, hi)) = spec.split_once(':') {
            let lo: usize =
                lo.parse().map_err(|_| anyhow!("bad length bound {lo:?} in {spec:?}"))?;
            let hi: usize =
                hi.parse().map_err(|_| anyhow!("bad length bound {hi:?} in {spec:?}"))?;
            if lo == 0 || hi < lo {
                bail!("length range must satisfy 1 <= lo <= hi, got {spec:?}");
            }
            Ok(LenDist::Uniform { lo, hi })
        } else {
            let n: usize = spec.parse().map_err(|_| {
                anyhow!("length spec {spec:?} is neither \"<n>\" nor \"<lo>:<hi>\"")
            })?;
            if n == 0 {
                bail!("length must be >= 1 token, got {spec:?}");
            }
            Ok(LenDist::Fixed(n))
        }
    }

    pub fn sample(&self, rng: &mut Rng) -> usize {
        match *self {
            LenDist::Fixed(n) => n.max(1),
            LenDist::Uniform { lo, hi } => rng.range_i64(lo as i64, hi as i64) as usize,
        }
    }

    pub fn mean(&self) -> f64 {
        match *self {
            LenDist::Fixed(n) => n as f64,
            LenDist::Uniform { lo, hi } => (lo + hi) as f64 / 2.0,
        }
    }

    pub fn label(&self) -> String {
        match *self {
            LenDist::Fixed(n) => n.to_string(),
            LenDist::Uniform { lo, hi } => format!("{lo}:{hi}"),
        }
    }
}

/// Request arrival process over time.
#[derive(Debug, Clone, PartialEq)]
pub enum ArrivalPattern {
    /// Exponential inter-arrivals at `rate_rps` requests/s.
    Poisson { rate_rps: f64 },
    /// 2-state MMPP: a calm state and a burst state with exponential
    /// sojourn times.  The burst state arrives at
    /// `rate_rps × burst_factor`; the calm rate is solved so the
    /// time-weighted mean stays `rate_rps`.
    Burst { rate_rps: f64, burst_factor: f64, mean_burst_s: f64, mean_calm_s: f64 },
    /// Replay recorded arrival offsets verbatim (sorted ascending).
    Replay { times_s: Vec<f64> },
}

impl ArrivalPattern {
    /// Burst pattern with the default shape (4× bursts, 0.5 s mean
    /// burst, 2 s mean calm).
    pub fn burst(rate_rps: f64) -> ArrivalPattern {
        ArrivalPattern::Burst {
            rate_rps,
            burst_factor: 4.0,
            mean_burst_s: 0.5,
            mean_calm_s: 2.0,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            ArrivalPattern::Poisson { .. } => "poisson",
            ArrivalPattern::Burst { .. } => "burst",
            ArrivalPattern::Replay { .. } => "replay",
        }
    }

    /// The configured mean offered rate (requests/s); for replay traces
    /// it is inferred from the trace span.
    pub fn rate_rps(&self) -> f64 {
        match self {
            ArrivalPattern::Poisson { rate_rps } | ArrivalPattern::Burst { rate_rps, .. } => {
                *rate_rps
            }
            ArrivalPattern::Replay { times_s } => {
                // the span is the *largest* offset: recorded traces are
                // not required to be sorted (arrival_times sorts a
                // copy), so `last()` would under- or over-state the
                // rate for an unsorted capture
                let span = times_s.iter().copied().fold(0.0f64, f64::max);
                if span > 0.0 {
                    times_s.len() as f64 / span
                } else {
                    0.0
                }
            }
        }
    }

    /// Generate `n` arrival offsets (ascending).  Replay ignores `rng`
    /// and truncates to the trace length.
    fn arrival_times(&self, n: usize, rng: &mut Rng) -> Result<Vec<f64>> {
        match self {
            ArrivalPattern::Poisson { rate_rps } => {
                if *rate_rps <= 0.0 {
                    bail!("poisson rate must be > 0 rps, got {rate_rps}");
                }
                let mut t = 0.0;
                Ok((0..n)
                    .map(|_| {
                        t += rng.exponential(*rate_rps);
                        t
                    })
                    .collect())
            }
            ArrivalPattern::Burst { rate_rps, burst_factor, mean_burst_s, mean_calm_s } => {
                if *rate_rps <= 0.0 || *burst_factor < 1.0 {
                    bail!("burst needs rate > 0 and burst_factor >= 1");
                }
                if *mean_burst_s <= 0.0 || *mean_calm_s <= 0.0 {
                    bail!("burst sojourn means must be > 0 s");
                }
                // time fraction spent bursting, and the exact calm
                // rate that keeps the weighted mean at rate_rps.  No
                // silent floor: a config whose bursts already carry
                // the whole mean (burst_factor × f ≥ 1) has no
                // non-negative calm rate that preserves the mean, so
                // it is rejected instead of quietly exceeding the
                // configured rate.
                let f = mean_burst_s / (mean_burst_s + mean_calm_s);
                let hi = rate_rps * burst_factor;
                let lo = (rate_rps - f * hi) / (1.0 - f);
                if lo <= 0.0 {
                    bail!(
                        "burst config cannot preserve the mean rate: burst_factor {burst_factor} \
                         over a {f:.3} burst time-fraction concentrates >= the whole mean into \
                         bursts; lower burst_factor or shorten bursts"
                    );
                }
                let mut out = Vec::with_capacity(n);
                let mut t = 0.0;
                let mut bursting = false;
                let mut state_end = rng.exponential(1.0 / mean_calm_s);
                while out.len() < n {
                    let rate = if bursting { hi } else { lo };
                    let dt = rng.exponential(rate);
                    if t + dt >= state_end {
                        // exponential inter-arrivals are memoryless, so
                        // discarding the partial draw at the switch is
                        // exact, not an approximation
                        t = state_end;
                        bursting = !bursting;
                        let mean = if bursting { *mean_burst_s } else { *mean_calm_s };
                        state_end = t + rng.exponential(1.0 / mean);
                    } else {
                        t += dt;
                        out.push(t);
                    }
                }
                Ok(out)
            }
            ArrivalPattern::Replay { times_s } => {
                if times_s.is_empty() {
                    bail!("replay trace is empty");
                }
                let mut out: Vec<f64> = times_s.iter().take(n).copied().collect();
                out.sort_by(|a, b| a.total_cmp(b));
                if out.first().copied().unwrap_or(0.0) < 0.0 {
                    bail!("replay trace contains negative arrival offsets");
                }
                Ok(out)
            }
        }
    }
}

/// A complete load description; [`LoadSpec::generate`] materializes the
/// deterministic request trace.
#[derive(Debug, Clone)]
pub struct LoadSpec {
    pub pattern: ArrivalPattern,
    pub prompt: LenDist,
    pub output: LenDist,
    /// Number of requests (replay truncates to the trace length).
    pub requests: usize,
    pub seed: u64,
}

impl LoadSpec {
    /// Materialize the request trace: arrival offsets first, then one
    /// (prompt, output) draw per request, all from one seeded stream.
    pub fn generate(&self) -> Result<Vec<TrafficRequest>> {
        let mut rng = Rng::seed_from(self.seed);
        let times = self.pattern.arrival_times(self.requests, &mut rng)?;
        Ok(times
            .into_iter()
            .enumerate()
            .map(|(i, arrival_s)| TrafficRequest {
                id: i as u64,
                arrival_s,
                prompt_tokens: self.prompt.sample(&mut rng),
                output_tokens: self.output.sample(&mut rng),
                ..TrafficRequest::default()
            })
            .collect())
    }
}

/// One parsed line of a replay trace.  Legacy traces carry only the
/// arrival offset; capture-v1 traces (written by `platinum serve
/// --capture`) also carry the live request's lengths and optional
/// deadline, so a production session replays verbatim.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TraceRecord {
    pub arrival_s: f64,
    /// `Some` on capture-v1 lines, `None` on legacy offset-only lines.
    pub prompt_tokens: Option<usize>,
    pub output_tokens: Option<usize>,
    pub deadline_s: Option<f64>,
    /// Leading prompt tokens shared across requests (the system
    /// prompt) — 0 on legacy lines and on 4-field capture lines
    /// written before the column existed.
    pub shared_prefix_tokens: usize,
    /// SLO class (tenant tier) — 0 on legacy lines and on 4/5-field
    /// capture lines written before the column existed.
    pub class: u8,
}

impl Default for TraceRecord {
    fn default() -> TraceRecord {
        TraceRecord {
            arrival_s: 0.0,
            prompt_tokens: None,
            output_tokens: None,
            deadline_s: None,
            shared_prefix_tokens: 0,
            class: 0,
        }
    }
}

/// Format one deadline for the capture's `deadline_ms|-` column so the
/// round-trip is **bit-exact**: in milliseconds when `ms × 1e-3`
/// reproduces the seconds value (every `X-Deadline-Ms`-derived
/// deadline does), otherwise in shortest-round-trip seconds with an
/// `s` suffix.  Writing `deadline_s * 1e3` and reading back `ms × 1e-3`
/// double-rounds and can perturb a replayed deadline by 1 ulp — enough
/// to flip a timeout-kill decision and break capture→replay
/// byte-identity.
fn format_deadline(dl_s: f64) -> String {
    let ms = dl_s * 1e3;
    if ms.is_finite() && ms * 1e-3 == dl_s {
        format!("{ms}")
    } else {
        format!("{dl_s}s")
    }
}

/// Parse a replay trace.  Two line grammars, mixable with blank lines
/// and `#` comments:
///
/// * legacy: `<arrival_s>` — one f64 seconds-offset per request;
/// * capture v1: `<arrival_s> <prompt_tokens> <output_tokens>
///   <deadline_ms|-> [<shared_prefix_tokens> [<class>]]` — what
///   [`format_capture`] writes; the trailing shared-prefix and class
///   columns default to 0 when absent (earlier captures had 4 or 5
///   fields).  A deadline with an `s` suffix is exact seconds (written
///   when the value does not round-trip through milliseconds).
pub fn parse_trace_records(text: &str) -> Result<Vec<TraceRecord>> {
    let mut out = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let err = |what: &str| anyhow!("trace line {}: {line:?} {what}", lineno + 1);
        let fields: Vec<&str> = line.split_whitespace().collect();
        let arrival_s: f64 =
            fields[0].parse().map_err(|_| err("is not a number"))?;
        if !arrival_s.is_finite() || arrival_s < 0.0 {
            return Err(err("has a negative or non-finite arrival offset"));
        }
        let rec = match fields.len() {
            1 => TraceRecord { arrival_s, ..TraceRecord::default() },
            4 | 5 | 6 => {
                let prompt: usize =
                    fields[1].parse().map_err(|_| err("has a bad prompt length"))?;
                let output: usize =
                    fields[2].parse().map_err(|_| err("has a bad output length"))?;
                if prompt == 0 || output == 0 {
                    return Err(err("needs prompt/output lengths >= 1"));
                }
                let deadline_s = if fields[3] == "-" {
                    None
                } else if let Some(sec) = fields[3].strip_suffix('s') {
                    // exact-seconds escape for deadlines that don't
                    // round-trip through the millisecond column
                    let s: f64 =
                        sec.parse().map_err(|_| err("has a bad deadline (ms, <s>s, or -)"))?;
                    if !s.is_finite() || s <= 0.0 {
                        return Err(err("needs a positive deadline or -"));
                    }
                    Some(s)
                } else {
                    let ms: f64 =
                        fields[3].parse().map_err(|_| err("has a bad deadline (ms, <s>s, or -)"))?;
                    if !ms.is_finite() || ms <= 0.0 {
                        return Err(err("needs a positive deadline (ms) or -"));
                    }
                    Some(ms * 1e-3)
                };
                let shared_prefix_tokens = match fields.get(4) {
                    Some(f) => {
                        let shared: usize =
                            f.parse().map_err(|_| err("has a bad shared-prefix length"))?;
                        if shared > prompt {
                            return Err(err("has a shared prefix longer than the prompt"));
                        }
                        shared
                    }
                    None => 0,
                };
                let class = match fields.get(5) {
                    Some(f) => {
                        let class: u8 = f.parse().map_err(|_| err("has a bad class id"))?;
                        if class as usize >= MAX_CLASSES {
                            return Err(err("has a class id beyond the class table"));
                        }
                        class
                    }
                    None => 0,
                };
                TraceRecord {
                    arrival_s,
                    prompt_tokens: Some(prompt),
                    output_tokens: Some(output),
                    deadline_s,
                    shared_prefix_tokens,
                    class,
                }
            }
            _ => return Err(err("has neither 1 field (legacy) nor 4-6 (capture v1)")),
        };
        out.push(rec);
    }
    if out.is_empty() {
        bail!("trace contains no arrival offsets");
    }
    Ok(out)
}

/// Parse a replay trace down to its arrival offsets (both grammars).
pub fn parse_trace(text: &str) -> Result<Vec<f64>> {
    Ok(parse_trace_records(text)?.iter().map(|r| r.arrival_s).collect())
}

/// Serialize captured live arrivals into the capture-v1 trace grammar.
/// Arrival offsets round-trip bit-exactly ([`parse_trace_records`]
/// reads back the same f64: Rust's `Display` is shortest-round-trip),
/// which is what makes a captured session a byte-reproducible replay.
pub fn format_capture(records: &[TraceRecord]) -> String {
    let mut out = String::from(
        "# platinum capture v1\n# arrival_s prompt_tokens output_tokens deadline_ms|- shared_prefix_tokens [class]\n",
    );
    for r in records {
        let prompt = r.prompt_tokens.unwrap_or(1);
        let output = r.output_tokens.unwrap_or(1);
        let shared = r.shared_prefix_tokens;
        let dl = match r.deadline_s {
            Some(dl) => format_deadline(dl),
            None => "-".to_string(),
        };
        // the class column is written only when nonzero, so
        // single-tenant captures stay byte-identical to the pre-class
        // grammar
        if r.class > 0 {
            out.push_str(&format!(
                "{} {} {} {} {} {}\n",
                r.arrival_s, prompt, output, dl, shared, r.class
            ));
        } else {
            out.push_str(&format!("{} {} {} {} {}\n", r.arrival_s, prompt, output, dl, shared));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(pattern: ArrivalPattern) -> LoadSpec {
        LoadSpec {
            pattern,
            prompt: LenDist::Uniform { lo: 4, hi: 16 },
            output: LenDist::Fixed(8),
            requests: 400,
            seed: 42,
        }
    }

    #[test]
    fn poisson_is_deterministic_and_rate_accurate() {
        let s = spec(ArrivalPattern::Poisson { rate_rps: 50.0 });
        let a = s.generate().unwrap();
        let b = s.generate().unwrap();
        assert_eq!(a, b, "same seed must give the identical trace");
        assert_eq!(a.len(), 400);
        let span = a.last().unwrap().arrival_s;
        let rate = a.len() as f64 / span;
        assert!((rate - 50.0).abs() < 10.0, "empirical rate {rate} rps");
        assert!(a.windows(2).all(|w| w[0].arrival_s <= w[1].arrival_s));
        let c = LoadSpec { seed: 43, ..s }.generate().unwrap();
        assert_ne!(a, c, "a different seed must give a different trace");
    }

    #[test]
    fn burst_keeps_mean_rate_but_clusters() {
        let s = spec(ArrivalPattern::burst(50.0));
        let a = s.generate().unwrap();
        let span = a.last().unwrap().arrival_s;
        let rate = a.len() as f64 / span;
        assert!((rate - 50.0).abs() < 20.0, "MMPP mean rate {rate} rps");
        // burstiness: the coefficient of variation of inter-arrivals
        // must exceed the Poisson baseline of ~1
        let gaps: Vec<f64> =
            a.windows(2).map(|w| w[1].arrival_s - w[0].arrival_s).collect();
        let mean = gaps.iter().sum::<f64>() / gaps.len() as f64;
        let var =
            gaps.iter().map(|g| (g - mean) * (g - mean)).sum::<f64>() / gaps.len() as f64;
        let cv = var.sqrt() / mean;
        assert!(cv > 1.15, "MMPP must be burstier than Poisson (cv {cv})");
    }

    #[test]
    fn replay_truncates_and_sorts() {
        let s = LoadSpec {
            pattern: ArrivalPattern::Replay { times_s: vec![0.5, 0.1, 0.9, 2.0] },
            prompt: LenDist::Fixed(4),
            output: LenDist::Fixed(2),
            requests: 3,
            seed: 1,
        };
        let a = s.generate().unwrap();
        assert_eq!(a.len(), 3);
        assert_eq!(a[0].arrival_s, 0.1);
        assert_eq!(a[2].arrival_s, 0.9);
        assert!(a.iter().all(|r| r.prompt_tokens == 4 && r.output_tokens == 2));
    }

    #[test]
    fn len_dist_parses_and_samples_in_range() {
        assert_eq!(LenDist::parse("16").unwrap(), LenDist::Fixed(16));
        assert_eq!(LenDist::parse("8:32").unwrap(), LenDist::Uniform { lo: 8, hi: 32 });
        assert!(LenDist::parse("0").is_err());
        assert!(LenDist::parse("9:3").is_err());
        assert!(LenDist::parse("abc").is_err());
        let mut rng = Rng::seed_from(5);
        let d = LenDist::Uniform { lo: 3, hi: 7 };
        for _ in 0..1000 {
            let v = d.sample(&mut rng);
            assert!((3..=7).contains(&v));
        }
        assert_eq!(d.mean(), 5.0);
        assert_eq!(d.label(), "3:7");
    }

    #[test]
    fn trace_parser_skips_comments_and_rejects_garbage() {
        let t = parse_trace("# header\n0.0\n\n0.25\n1.5\n").unwrap();
        assert_eq!(t, vec![0.0, 0.25, 1.5]);
        assert!(parse_trace("0.1\nnope\n").is_err());
        assert!(parse_trace("# only comments\n").is_err());
    }

    #[test]
    fn capture_records_roundtrip_and_legacy_lines_interleave() {
        let recs = vec![
            TraceRecord {
                arrival_s: 0.0,
                prompt_tokens: Some(8),
                output_tokens: Some(4),
                deadline_s: Some(0.25),
                shared_prefix_tokens: 3,
                class: 0,
            },
            TraceRecord {
                arrival_s: 1.0625,
                prompt_tokens: Some(16),
                output_tokens: Some(2),
                deadline_s: None,
                shared_prefix_tokens: 0,
                class: 2,
            },
        ];
        let text = format_capture(&recs);
        assert!(text.starts_with("# platinum capture v1"));
        assert_eq!(parse_trace_records(&text).unwrap(), recs, "capture must round-trip");
        // legacy offset-only lines parse as length-less records
        let legacy = parse_trace_records("0.1\n0.2\n").unwrap();
        assert!(legacy.iter().all(|r| {
            r.prompt_tokens.is_none() && r.deadline_s.is_none() && r.shared_prefix_tokens == 0
        }));
        assert_eq!(parse_trace("# c\n0.1\n0.2\n").unwrap(), vec![0.1, 0.2]);
        // 4-field captures (written before the shared-prefix column
        // existed) still parse, with a zero shared span
        let old = parse_trace_records("0.1 8 4 250\n").unwrap();
        assert_eq!(old[0].prompt_tokens, Some(8));
        assert_eq!(old[0].deadline_s, Some(0.25));
        assert_eq!(old[0].shared_prefix_tokens, 0);
        // strictness: partial records, bad deadlines, negative offsets,
        // malformed or oversized shared prefixes
        assert!(parse_trace_records("0.1 8\n").is_err(), "2-field lines are malformed");
        assert!(parse_trace_records("0.1 8 4 soon\n").is_err());
        assert!(parse_trace_records("0.1 0 4 -\n").is_err(), "zero-length prompt");
        assert!(parse_trace_records("-0.5\n").is_err(), "negative offsets rejected");
        assert!(parse_trace_records("0.1 8 4 - lots\n").is_err(), "bad shared prefix");
        assert!(
            parse_trace_records("0.1 8 4 - 9\n").is_err(),
            "shared prefix cannot exceed the prompt"
        );
        // class column: parses, bounds-checked, zero is implicit
        let classed = parse_trace_records("0.1 8 4 - 0 3\n").unwrap();
        assert_eq!(classed[0].class, 3);
        assert!(parse_trace_records("0.1 8 4 - 0 7\n").is_err(), "class beyond the table");
        assert!(parse_trace_records("0.1 8 4 - 0 batch\n").is_err(), "non-numeric class");
        assert!(parse_trace_records("0.1 8 4 - 0 1 9\n").is_err(), "7-field lines are malformed");
        // a class-0 record serializes without the column (legacy bytes)
        let zero = TraceRecord {
            arrival_s: 0.5,
            prompt_tokens: Some(4),
            output_tokens: Some(2),
            ..TraceRecord::default()
        };
        assert!(format_capture(&[zero]).ends_with("0.5 4 2 - 0\n"));
    }

    #[test]
    fn deadline_round_trip_is_bit_exact() {
        // awkward values: decimals, 1 ulp past a millisecond boundary,
        // huge, tiny, and a seeded sweep — every deadline must come
        // back bit-identical through format_capture → parse
        let mut awkward = vec![
            0.1,
            0.25,
            1e-3,
            f64::from_bits((1e-3f64).to_bits() + 1),
            f64::from_bits((0.1f64).to_bits() - 1),
            12345.6789,
            1e9,
            1e-9,
            0.017,
            2.0 / 3.0,
        ];
        let mut rng = Rng::seed_from(99);
        for _ in 0..500 {
            awkward.push(rng.exponential(10.0).max(1e-12));
        }
        for dl in awkward {
            let rec = TraceRecord {
                arrival_s: 0.0,
                prompt_tokens: Some(4),
                output_tokens: Some(2),
                deadline_s: Some(dl),
                ..TraceRecord::default()
            };
            let text = format_capture(&[rec]);
            let back = parse_trace_records(&text).unwrap();
            assert_eq!(
                back[0].deadline_s.unwrap().to_bits(),
                dl.to_bits(),
                "deadline {dl:?} must round-trip bit-exactly via {text:?}"
            );
        }
        // ms-representable deadlines keep the plain millisecond column
        let text = format_capture(&[TraceRecord {
            arrival_s: 0.0,
            prompt_tokens: Some(4),
            output_tokens: Some(2),
            deadline_s: Some(0.25),
            ..TraceRecord::default()
        }]);
        assert!(text.contains(" 250 "), "{text:?}");
    }

    #[test]
    fn replay_rate_uses_max_offset_even_when_unsorted() {
        // 3 requests over a 2 s span; the last *element* is not the
        // last *arrival*
        let p = ArrivalPattern::Replay { times_s: vec![2.0, 0.5, 1.0] };
        assert!((p.rate_rps() - 1.5).abs() < 1e-12, "rate {}", p.rate_rps());
        // sorted traces are unchanged
        let sorted = ArrivalPattern::Replay { times_s: vec![0.5, 1.0, 2.0] };
        assert_eq!(p.rate_rps(), sorted.rate_rps());
    }

    #[test]
    fn burst_rejects_configs_that_cannot_preserve_the_mean() {
        let mut rng = Rng::seed_from(1);
        // f = 0.5/2.5 = 0.2; burst_factor 5 puts the whole mean into
        // bursts (calm rate 0) — rejected at the boundary
        let bad = ArrivalPattern::Burst {
            rate_rps: 50.0,
            burst_factor: 5.0,
            mean_burst_s: 0.5,
            mean_calm_s: 2.0,
        };
        assert!(bad.arrival_times(16, &mut rng).is_err());
        let worse = ArrivalPattern::Burst {
            rate_rps: 50.0,
            burst_factor: 8.0,
            mean_burst_s: 0.5,
            mean_calm_s: 2.0,
        };
        assert!(worse.arrival_times(16, &mut rng).is_err());
        // just inside the boundary: accepted, and the calm rate is the
        // exact mean-preserving solution (no silent 2% floor)
        let ok = ArrivalPattern::Burst {
            rate_rps: 50.0,
            burst_factor: 4.99,
            mean_burst_s: 0.5,
            mean_calm_s: 2.0,
        };
        assert!(ok.arrival_times(16, &mut rng).is_ok());
    }

    #[test]
    fn tenant_mix_parses_assigns_and_stays_deterministic() {
        let mix = TenantMix::parse("interactive:0.7:w4,batch:0.3:w1").unwrap();
        assert_eq!(mix.classes.len(), 2);
        assert_eq!(mix.classes[0].name, "interactive");
        assert_eq!(mix.classes[0].weight, 4);
        assert_eq!(mix.class_id("BATCH"), Some(1));
        assert_eq!(mix.class_id("free"), None);
        assert_eq!(mix.weights(), [4, 1, 1, 1]);
        // grammar strictness
        assert!(TenantMix::parse("a:0.5,b:0.6").is_err(), "shares must sum to 1");
        assert!(TenantMix::parse("a:0.5:4,b:0.5").is_err(), "weight needs the w prefix");
        assert!(TenantMix::parse("a:0.5:w0,b:0.5").is_err(), "zero weight");
        assert!(TenantMix::parse("a:0.5,a:0.5").is_err(), "duplicate name");
        assert!(TenantMix::parse("a:0.2,b:0.2,c:0.2,d:0.2,e:0.2").is_err(), "too many classes");
        // assignment: deterministic, share-accurate, and shape-neutral
        let s = spec(ArrivalPattern::Poisson { rate_rps: 50.0 });
        let plain = s.generate().unwrap();
        let mut a = plain.clone();
        mix.assign(&mut a, s.seed);
        let mut b = plain.clone();
        mix.assign(&mut b, s.seed);
        assert_eq!(a, b, "same seed must give the identical class assignment");
        let interactive = a.iter().filter(|r| r.class == 0).count();
        assert!(
            (interactive as f64 / a.len() as f64 - 0.7).abs() < 0.08,
            "share {interactive}/{}",
            a.len()
        );
        assert!(a.iter().any(|r| r.class == 1));
        // shapes/arrivals are untouched — classes ride on top
        for (r, p) in a.iter().zip(&plain) {
            assert_eq!(
                (r.arrival_s, r.prompt_tokens, r.output_tokens),
                (p.arrival_s, p.prompt_tokens, p.output_tokens)
            );
        }
        // single-class mixes are a no-op
        let solo = TenantMix::parse("all:1.0:w2").unwrap();
        let mut c = plain.clone();
        solo.assign(&mut c, s.seed);
        assert_eq!(c, plain);
    }

    #[test]
    fn bad_patterns_error() {
        let mut rng = Rng::seed_from(1);
        assert!(ArrivalPattern::Poisson { rate_rps: 0.0 }.arrival_times(4, &mut rng).is_err());
        assert!(ArrivalPattern::Burst {
            rate_rps: 10.0,
            burst_factor: 0.5,
            mean_burst_s: 1.0,
            mean_calm_s: 1.0
        }
        .arrival_times(4, &mut rng)
        .is_err());
        assert!(ArrivalPattern::Replay { times_s: vec![] }.arrival_times(4, &mut rng).is_err());
        assert!(ArrivalPattern::Replay { times_s: vec![-1.0] }
            .arrival_times(1, &mut rng)
            .is_err());
    }

    #[test]
    fn reserved_tokens_sums_prompt_and_output() {
        let r = TrafficRequest {
            id: 0,
            arrival_s: 0.0,
            prompt_tokens: 12,
            output_tokens: 5,
            ..TrafficRequest::default()
        };
        assert_eq!(r.reserved_tokens(), 17);
    }

    #[test]
    fn shared_prefix_grows_prompts_and_marks_the_span() {
        let s = spec(ArrivalPattern::Poisson { rate_rps: 50.0 });
        let mut a = s.generate().unwrap();
        let plain: Vec<usize> = a.iter().map(|r| r.prompt_tokens).collect();
        with_shared_prefix(&mut a, 64);
        for (r, p) in a.iter().zip(&plain) {
            assert_eq!(r.prompt_tokens, p + 64);
            assert_eq!(r.shared_prefix_tokens, 64);
            // at least one unique token follows the shared span
            assert!(r.prompt_tokens > r.shared_prefix_tokens);
        }
    }
}
