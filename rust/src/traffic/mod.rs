//! Serving-under-load subsystem (S15): continuous batching, load
//! generation, and tail-latency metrics on top of the [`crate::engine`]
//! API.
//!
//! PRs 1–4 built the execution stack — a unified backend API, a
//! parallel runtime, multi-chip sharding, a work-stealing scheduler —
//! but every frontend ran **one-shot** workloads: there was no notion
//! of requests arriving over time, queueing, admission, or tail
//! latency, so none of that scale work could be evaluated under load.
//! This module turns the repo into a servable system:
//!
//! * [`scheduler`] — the continuous-batching control plane: a bounded
//!   request queue with admission/backpressure (queue depth, in-flight
//!   token reservation, and [`crate::kv`] paged-block reservation),
//!   prefill coalescing with prefix-cache discounts, per-step decode
//!   batching, swap/recompute preemption under block pressure, and
//!   eviction of finished sequences.  Any registered [`Backend`]
//!   (`platinum-ternary`, the measured `platinum-cpu`, `sharded:*`
//!   composites, …) prices the steps and thereby drives the timeline.
//! * [`loadgen`] — deterministic open-loop load: Poisson, bursty
//!   (2-state MMPP), and trace-replay arrivals with configurable
//!   prompt/output length distributions, materialized up front from one
//!   seed; a [`TenantMix`] assigns SLO classes (multi-tenant) on top.
//! * [`metrics`] — TTFT / TPOT / end-to-end / queue-wait percentiles
//!   from fixed-bucket log histograms, queue-depth and batch-size time
//!   series, goodput vs. offered load; JSON via [`crate::util::json`].
//! * [`clock`] — the [`Clock`] abstraction that makes the same control
//!   loop a deterministic discrete-event simulation ([`VirtualClock`])
//!   or a live paced run ([`WallClock`]).
//! * [`source`] — the S18 arrival-source abstraction: the serve loop
//!   pulls due arrivals from an [`ArrivalSource`] instead of scanning a
//!   pre-materialized slice, so traces ([`TraceSource`]), the loadgen,
//!   and live connections ([`PushSource`] fed through [`PushHandle`]s
//!   by [`crate::server`]) all drive the identical scheduler, with
//!   cancellation and terminal-outcome reporting riding along.
//!
//! The scheduler also hosts the [`crate::fault`] subsystem's responses
//! (`Scheduler::serve_faults`): deterministic fault injection with
//! deadlines, retries, brownout shedding and `Sharded` failover — see
//! that module's docs.
//!
//! CLI: `platinum serve-bench --backend <id> --rate <rps> --pattern
//! poisson|burst|replay [--json]`; `examples/traffic_sweep.rs` sweeps
//! offered load to the saturation knee.  `tests/traffic_serving.rs`
//! pins virtual-clock determinism (byte-identical metrics JSON per
//! seed, invariant across worker-pool sizes {1, 8}) and bounded,
//! deadlock-free behavior past saturation.
//!
//! [`Backend`]: crate::engine::Backend

pub mod clock;
pub mod loadgen;
pub mod metrics;
pub mod scheduler;
pub mod source;

pub use clock::{Clock, VirtualClock, WallClock};
pub use loadgen::{
    format_capture, parse_trace, parse_trace_records, with_shared_prefix, ArrivalPattern, LenDist,
    LoadSpec, TenantClass, TenantMix, TraceRecord, TrafficRequest, MAX_CLASSES,
};
pub use metrics::{ClassMetrics, Histogram, StepSample, TrafficMetrics};
pub use scheduler::{
    decode_capacity_tok_s, ExecutorBridge, RunResult, Scheduler, SchedulerConfig, StepExecutor,
    StepKind, StepRecord,
};
pub use source::{ArrivalSource, Outcome, PushHandle, PushSource, TraceSource};
