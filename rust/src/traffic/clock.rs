//! Time sources for the serving subsystem.
//!
//! The continuous-batching scheduler is written against the [`Clock`]
//! trait so the *same* control loop runs in two regimes:
//!
//! * [`VirtualClock`] — a discrete-event timeline: time moves only when
//!   the scheduler charges a step's priced latency ([`Clock::advance`])
//!   or jumps to the next arrival ([`Clock::wait_until`]).  Fully
//!   deterministic — given the same request trace and a deterministic
//!   pricing backend, every run produces byte-identical metrics, which
//!   is what `tests/traffic_serving.rs` pins.
//! * [`WallClock`] — real elapsed time.  `advance` is a no-op (running
//!   a measured backend already consumed the wall time it reported) and
//!   `wait_until` sleeps, so arrivals pace the loop like a live load
//!   generator.  Use with the measured `platinum-cpu`/`tmac-cpu`
//!   backends, where the priced latency *is* host wall-clock.

use std::time::{Duration, Instant};

/// The scheduler's notion of "now", in seconds since the run started.
pub trait Clock {
    /// Current time (s since start of the run).
    fn now(&mut self) -> f64;

    /// Charge `dt` seconds of service time to the timeline.  Virtual
    /// time jumps; wall time ignores it (the work already took real
    /// time to execute).
    fn advance(&mut self, dt: f64);

    /// Idle until `t` (the next request arrival).  Virtual time jumps;
    /// wall time sleeps.  A `t` in the past is a no-op.
    fn wait_until(&mut self, t: f64);

    /// `"virtual"` or `"wall"` — recorded in the metrics JSON so a
    /// report is self-describing.
    fn label(&self) -> &'static str;
}

/// Deterministic discrete-event clock (starts at 0.0 s).
#[derive(Debug, Clone, Default)]
pub struct VirtualClock {
    t: f64,
}

impl VirtualClock {
    pub fn new() -> VirtualClock {
        VirtualClock { t: 0.0 }
    }
}

impl Clock for VirtualClock {
    fn now(&mut self) -> f64 {
        self.t
    }

    fn advance(&mut self, dt: f64) {
        if dt > 0.0 {
            self.t += dt;
        }
    }

    fn wait_until(&mut self, t: f64) {
        if t > self.t {
            self.t = t;
        }
    }

    fn label(&self) -> &'static str {
        "virtual"
    }
}

/// Real elapsed time, anchored at construction.
#[derive(Debug, Clone)]
pub struct WallClock {
    start: Instant,
}

impl WallClock {
    pub fn new() -> WallClock {
        WallClock { start: Instant::now() }
    }

    /// A wall clock sharing an external anchor, so independent
    /// components (the server's accept loop stamping arrival offsets,
    /// the scheduler thread driving the serve loop) agree on t = 0.
    pub fn anchored_at(start: Instant) -> WallClock {
        WallClock { start }
    }
}

impl Default for WallClock {
    fn default() -> WallClock {
        WallClock::new()
    }
}

impl Clock for WallClock {
    fn now(&mut self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    fn advance(&mut self, _dt: f64) {
        // measured work already consumed real time; nothing to charge
    }

    fn wait_until(&mut self, t: f64) {
        let now = self.start.elapsed().as_secs_f64();
        if t > now {
            std::thread::sleep(Duration::from_secs_f64(t - now));
        }
    }

    fn label(&self) -> &'static str {
        "wall"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn virtual_clock_is_event_driven() {
        let mut c = VirtualClock::new();
        assert_eq!(c.now(), 0.0);
        c.advance(0.25);
        assert_eq!(c.now(), 0.25);
        // negative charges are ignored, time never runs backwards
        c.advance(-1.0);
        assert_eq!(c.now(), 0.25);
        c.wait_until(0.1);
        assert_eq!(c.now(), 0.25, "wait into the past is a no-op");
        c.wait_until(1.5);
        assert_eq!(c.now(), 1.5);
        assert_eq!(c.label(), "virtual");
    }

    #[test]
    fn wall_clock_moves_on_its_own() {
        let mut c = WallClock::new();
        let a = c.now();
        std::thread::sleep(Duration::from_millis(2));
        let b = c.now();
        assert!(b > a);
        // advance is a no-op: real execution already took real time
        c.advance(1000.0);
        assert!(c.now() < 500.0);
        assert_eq!(c.label(), "wall");
    }

    #[test]
    fn wall_clock_wait_until_sleeps() {
        let mut c = WallClock::new();
        let target = c.now() + 0.01;
        c.wait_until(target);
        assert!(c.now() >= target);
    }
}
