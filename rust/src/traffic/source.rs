//! Arrival sources: the scheduler's pluggable event feed (S18).
//!
//! PR 5's scheduler consumed a pre-materialized `&[TrafficRequest]`
//! slice — fine for benchmarks, useless for a live server, and the
//! ROADMAP names exactly this refactor as the unlock for both the
//! daemon and closed-loop clients.  [`ArrivalSource`] inverts the
//! dependency: the serve loop *pulls* due arrivals from a source, so
//! the materialized trace ([`TraceSource`]) becomes one producer among
//! several and a live front end pushes requests through a cloneable
//! [`PushHandle`] as clients connect ([`PushSource`]).  Sources can
//! also deliver mid-flight cancellations (a client hanging up) and are
//! told every request's terminal [`Outcome`], which is how the server
//! routes completions back to waiting connections.
//!
//! Determinism: the scheduler observes only the (time, id, request)
//! stream a source presents, so two sources presenting identical
//! streams drive byte-identical runs — pinned by
//! `tests/traffic_serving.rs` (pushed-arrival mode vs. the
//! pre-materialized path).

use super::loadgen::TrafficRequest;
use std::collections::BTreeMap;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

/// Terminal state of one offered request, reported back to the source
/// (a live server routes these to the waiting connection).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Outcome {
    /// Generated every output token.
    Completed,
    /// Dropped at admission (queue full, no retry budget configured).
    Rejected,
    /// Brownout-shed under overload.
    Shed,
    /// Killed (deadline miss or step failure) with the retry budget
    /// exhausted.
    Exhausted,
    /// Cancelled by the client mid-flight (e.g. disconnect).
    Cancelled,
}

impl Outcome {
    pub fn label(&self) -> &'static str {
        match self {
            Outcome::Completed => "completed",
            Outcome::Rejected => "rejected",
            Outcome::Shed => "shed",
            Outcome::Exhausted => "exhausted",
            Outcome::Cancelled => "cancelled",
        }
    }
}

/// An external feed of arrivals driving
/// [`super::Scheduler::serve_source`].
///
/// The contract the serve loop relies on:
///
/// * [`next_arrival_s`](ArrivalSource::next_arrival_s) /
///   [`pop_due`](ArrivalSource::pop_due) present pending arrivals in
///   nondecreasing `(arrival_s, id)` order;
/// * [`finished`](ArrivalSource::finished) means *no arrival will ever
///   come again* — distinct from "momentarily empty", which is what a
///   live source looks like between requests;
/// * [`park`](ArrivalSource::park) may block briefly while empty and
///   unfinished, so a wall-clock serve loop idles on the producer's
///   condvar instead of spinning.
pub trait ArrivalSource {
    /// Earliest pending arrival time, if one is currently known.
    fn next_arrival_s(&mut self) -> Option<f64>;

    /// Pop the earliest pending arrival if it is due at `now`.
    fn pop_due(&mut self, now: f64) -> Option<TrafficRequest>;

    /// `true` once the source is closed *and* drained.
    fn finished(&mut self) -> bool;

    /// Block briefly until new arrivals may exist.  Trace sources never
    /// need to (pending work always names a wake-up time).
    fn park(&mut self) {}

    /// Drain cancellation requests issued since the last call.
    fn drain_cancellations(&mut self) -> Vec<u64> {
        Vec::new()
    }

    /// Report a request's terminal state.  Called exactly once per
    /// offered request (and once per cancelled-before-offer id).
    fn note_terminal(&mut self, _id: u64, _outcome: Outcome) {}
}

/// The legacy pre-materialized trace as a source: sorts once, then
/// replays — [`super::Scheduler::serve_faults`] wraps every request
/// slice in one of these, so the old entry points are byte-identical
/// frontends over the new loop.
pub struct TraceSource {
    arrivals: Vec<TrafficRequest>,
    next: usize,
}

impl TraceSource {
    pub fn new(requests: &[TrafficRequest]) -> TraceSource {
        let mut arrivals = requests.to_vec();
        arrivals.sort_by(|a, b| a.arrival_s.total_cmp(&b.arrival_s));
        TraceSource { arrivals, next: 0 }
    }
}

impl ArrivalSource for TraceSource {
    fn next_arrival_s(&mut self) -> Option<f64> {
        self.arrivals.get(self.next).map(|r| r.arrival_s)
    }

    fn pop_due(&mut self, now: f64) -> Option<TrafficRequest> {
        let r = self.arrivals.get(self.next)?;
        if r.arrival_s <= now {
            self.next += 1;
            Some(*r)
        } else {
            None
        }
    }

    fn finished(&mut self) -> bool {
        self.next >= self.arrivals.len()
    }
}

/// Shared state between a [`PushSource`] (consumer: the serve loop) and
/// its [`PushHandle`]s (producers: connection threads).
struct PushState {
    /// Pending arrivals keyed `(arrival_s bits, id)` — times are
    /// non-negative, so the bit order is the numeric order and pops are
    /// deterministic even when producers race.
    pending: BTreeMap<(u64, u64), TrafficRequest>,
    cancels: Vec<u64>,
    closed: bool,
}

/// Producer handle: cheap to clone, safe to use from any thread.
#[derive(Clone)]
pub struct PushHandle {
    inner: Arc<(Mutex<PushState>, Condvar)>,
}

impl PushHandle {
    /// Enqueue one arrival (its `arrival_s` is the timeline position
    /// the scheduler will admit it at).
    pub fn push(&self, r: TrafficRequest) {
        let (m, cv) = &*self.inner;
        let mut g = m.lock().unwrap();
        g.pending.insert((r.arrival_s.to_bits(), r.id), r);
        cv.notify_all();
    }

    /// Cancel a previously pushed request wherever it currently sits
    /// (queued, running, swapped, or awaiting retry).
    pub fn cancel(&self, id: u64) {
        let (m, cv) = &*self.inner;
        let mut g = m.lock().unwrap();
        g.cancels.push(id);
        cv.notify_all();
    }

    /// No further pushes will come: once pending work drains, the serve
    /// loop returns.
    pub fn close(&self) {
        let (m, cv) = &*self.inner;
        let mut g = m.lock().unwrap();
        g.closed = true;
        cv.notify_all();
    }
}

/// A live, thread-safe arrival source fed through [`PushHandle`]s —
/// what `platinum serve` drives the scheduler with.
pub struct PushSource {
    inner: Arc<(Mutex<PushState>, Condvar)>,
    on_terminal: Option<Box<dyn FnMut(u64, Outcome) + Send>>,
}

impl PushSource {
    pub fn new() -> (PushSource, PushHandle) {
        let inner = Arc::new((
            Mutex::new(PushState { pending: BTreeMap::new(), cancels: Vec::new(), closed: false }),
            Condvar::new(),
        ));
        (PushSource { inner: inner.clone(), on_terminal: None }, PushHandle { inner })
    }

    /// Install the terminal-outcome observer (the server's router from
    /// scheduler events back to connection threads).
    pub fn set_observer(&mut self, f: Box<dyn FnMut(u64, Outcome) + Send>) {
        self.on_terminal = Some(f);
    }
}

impl ArrivalSource for PushSource {
    fn next_arrival_s(&mut self) -> Option<f64> {
        let g = self.inner.0.lock().unwrap();
        g.pending.keys().next().map(|&(bits, _)| f64::from_bits(bits))
    }

    fn pop_due(&mut self, now: f64) -> Option<TrafficRequest> {
        let mut g = self.inner.0.lock().unwrap();
        let &key = g.pending.keys().next()?;
        if f64::from_bits(key.0) <= now {
            g.pending.remove(&key)
        } else {
            None
        }
    }

    fn finished(&mut self) -> bool {
        let g = self.inner.0.lock().unwrap();
        g.closed && g.pending.is_empty() && g.cancels.is_empty()
    }

    fn park(&mut self) {
        let (m, cv) = &*self.inner;
        let g = m.lock().unwrap();
        if g.pending.is_empty() && g.cancels.is_empty() && !g.closed {
            // bounded wait: re-check even if a notify is lost
            let _ = cv.wait_timeout(g, Duration::from_millis(50)).unwrap();
        }
    }

    fn drain_cancellations(&mut self) -> Vec<u64> {
        let mut g = self.inner.0.lock().unwrap();
        std::mem::take(&mut g.cancels)
    }

    fn note_terminal(&mut self, id: u64, outcome: Outcome) {
        if let Some(f) = self.on_terminal.as_mut() {
            f(id, outcome);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, arrival_s: f64) -> TrafficRequest {
        TrafficRequest { id, arrival_s, prompt_tokens: 4, output_tokens: 2, ..Default::default() }
    }

    #[test]
    fn trace_source_replays_in_time_order() {
        let mut s = TraceSource::new(&[req(1, 0.5), req(0, 0.1)]);
        assert!(!s.finished());
        assert_eq!(s.next_arrival_s(), Some(0.1));
        assert!(s.pop_due(0.0).is_none(), "nothing due yet");
        assert_eq!(s.pop_due(0.2).unwrap().id, 0);
        assert_eq!(s.pop_due(1.0).unwrap().id, 1);
        assert!(s.finished());
        assert_eq!(s.next_arrival_s(), None);
    }

    #[test]
    fn push_source_orders_by_time_then_id_and_finishes_on_close() {
        let (mut s, h) = PushSource::new();
        h.push(req(7, 0.2));
        h.push(req(3, 0.2));
        h.push(req(1, 0.1));
        assert_eq!(s.next_arrival_s(), Some(0.1));
        assert_eq!(s.pop_due(1.0).unwrap().id, 1);
        assert_eq!(s.pop_due(1.0).unwrap().id, 3, "id breaks the time tie");
        assert_eq!(s.pop_due(1.0).unwrap().id, 7);
        assert!(!s.finished(), "empty but not closed: a live lull, not the end");
        h.close();
        assert!(s.finished());
    }

    #[test]
    fn cancellations_drain_once_and_park_returns() {
        let (mut s, h) = PushSource::new();
        h.cancel(9);
        h.cancel(11);
        assert_eq!(s.drain_cancellations(), vec![9, 11]);
        assert!(s.drain_cancellations().is_empty());
        h.push(req(0, 0.0));
        s.park(); // pending work: must return immediately
        h.close();
        s.park(); // closed: must return immediately
    }

    #[test]
    fn observer_sees_terminals() {
        let (mut s, _h) = PushSource::new();
        let seen = Arc::new(Mutex::new(Vec::new()));
        let sink = seen.clone();
        s.set_observer(Box::new(move |id, o| sink.lock().unwrap().push((id, o))));
        s.note_terminal(4, Outcome::Completed);
        s.note_terminal(5, Outcome::Cancelled);
        assert_eq!(
            *seen.lock().unwrap(),
            vec![(4, Outcome::Completed), (5, Outcome::Cancelled)]
        );
        assert_eq!(Outcome::Exhausted.label(), "exhausted");
    }
}
