//! Bandwidth-constrained DRAM channel model (the DRAMsim3 substitute).
//!
//! The paper uses DRAMsim3 for energy and a 64 GB/s DDR4-2133 cap for
//! timing.  We model the channel as a shared-bandwidth pipe with a fixed
//! access granularity (64 B bursts) and a small per-burst overhead to
//! mimic row-activation/refresh interference at high utilization.

/// DDR4 burst granularity in bytes (BL8 × 64-bit channel).
pub const BURST_BYTES: u64 = 64;

#[derive(Debug, Clone)]
pub struct DramChannel {
    /// Peak bandwidth in bytes/second.
    pub peak_bw: f64,
    /// Core clock frequency (cycles/second) used to express transfer
    /// time in accelerator cycles.
    pub freq_hz: f64,
    /// Sustained/peak efficiency (bank conflicts, refresh, rd/wr turn).
    pub efficiency: f64,
}

impl DramChannel {
    pub fn new(peak_bw: f64, freq_hz: f64) -> Self {
        DramChannel { peak_bw, freq_hz, efficiency: 0.9 }
    }

    /// Bytes transferable per accelerator cycle (sustained).
    pub fn bytes_per_cycle(&self) -> f64 {
        self.peak_bw * self.efficiency / self.freq_hz
    }

    /// Cycles to transfer `bytes` (rounded up to bursts).
    pub fn transfer_cycles(&self, bytes: u64) -> u64 {
        if bytes == 0 {
            return 0;
        }
        let bursts = bytes.div_ceil(BURST_BYTES);
        let padded = bursts * BURST_BYTES;
        (padded as f64 / self.bytes_per_cycle()).ceil() as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transfer_time_scales_linearly() {
        let d = DramChannel::new(64e9, 500e6);
        let one = d.transfer_cycles(64 * 1024);
        let four = d.transfer_cycles(256 * 1024);
        assert!((four as f64 / one as f64 - 4.0).abs() < 0.05);
    }

    #[test]
    fn sixty_four_gbs_at_500mhz_is_115_bytes_per_cycle() {
        let d = DramChannel::new(64e9, 500e6);
        let bpc = d.bytes_per_cycle();
        assert!((bpc - 115.2).abs() < 0.5, "{bpc}");
    }

    #[test]
    fn small_transfers_round_to_burst() {
        let d = DramChannel::new(64e9, 500e6);
        assert_eq!(d.transfer_cycles(1), d.transfer_cycles(64));
        assert!(d.transfer_cycles(65) > d.transfer_cycles(64));
    }

    #[test]
    fn zero_bytes_zero_cycles() {
        let d = DramChannel::new(64e9, 500e6);
        assert_eq!(d.transfer_cycles(0), 0);
    }
}
