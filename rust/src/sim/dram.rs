//! DRAM channel models (the DRAMsim3 substitute).
//!
//! The paper uses DRAMsim3 for energy and a 64 GB/s DDR4-2133 cap for
//! timing.  Two timing models live behind the [`DramModel`] trait:
//!
//! * [`DramChannel`] — the original fixed-efficiency pipe: peak
//!   bandwidth × a sustained-efficiency factor (default 0.9, calibratable
//!   via `PLATINUM_DRAM_EFF`), rounded to 64 B bursts.  Address-blind.
//! * [`BankStateDram`] — a bank-state model: per-bank open-row tracking
//!   with row-buffer hit / miss (closed row) / conflict (wrong row open)
//!   timing, a shared data bus, and a validated byte-address → (row,
//!   bank, column) mapping.  Sequential streams sweep a full row per
//!   bank and run near the bus rate; row-ping-pong patterns pay
//!   precharge + activate + CAS per burst and collapse to a small
//!   fraction of peak.
//!
//! The two models agree within a documented 25 % bound on streaming
//! patterns (the bank model's one activation per 8 KiB row is the only
//! overhead; the pipe's 0.9 factor prices the same interference
//! statistically) and diverge sharply — bank model slower — under
//! deliberate conflict patterns.  Both properties are pinned by tests.
//! Like every timing law in `sim/`, the models are deterministic:
//! identical call sequences produce identical cycle counts.

/// DDR4 burst granularity in bytes (BL8 × 64-bit channel).
pub const BURST_BYTES: u64 = 64;

/// Banks modelled per channel (DDR4 x8: 4 bank groups × 4 banks).
pub const DRAM_BANKS: u64 = 16;

/// Row-buffer (page) size per bank in bytes.
pub const DRAM_ROW_BYTES: u64 = 8192;

/// DDR4-2133 CL15-ish core timings, nanoseconds (tRCD ≈ tRP ≈ CL).
const T_RCD_NS: f64 = 14.0;
const T_RP_NS: f64 = 14.0;
const T_CAS_NS: f64 = 14.0;

/// A DRAM timing model the KV swap path and capacity pricing charge
/// against.  Stateful: each transfer queues behind previously submitted
/// traffic and leaves bank state behind, which is what lets the
/// bank-state implementation punish row-conflict access patterns.
pub trait DramModel {
    /// `"pipe"` or `"bank"` — recorded in reports.
    fn label(&self) -> &'static str;

    /// Cycles the channel is occupied transferring `bytes` starting at
    /// byte address `addr`, issued after all previously submitted
    /// traffic.  Address-blind models ignore `addr`.
    fn transfer_cycles_at(&mut self, addr: u64, bytes: u64) -> u64;

    /// Row-buffer statistics, for models that track them.
    fn row_buffer(&self) -> Option<DramStats>;

    /// Forget all bank/bus state (start of a new run).
    fn reset(&mut self);
}

/// Which DRAM timing model to build (serve-bench `--dram-model`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum DramModelKind {
    /// Fixed-efficiency bandwidth pipe ([`DramChannel`]).
    Pipe,
    /// Per-bank open-row state machine ([`BankStateDram`]).
    #[default]
    Bank,
}

impl DramModelKind {
    pub fn parse(text: &str) -> Option<DramModelKind> {
        match text.trim().to_ascii_lowercase().as_str() {
            "pipe" | "fixed" => Some(DramModelKind::Pipe),
            "bank" => Some(DramModelKind::Bank),
            _ => None,
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            DramModelKind::Pipe => "pipe",
            DramModelKind::Bank => "bank",
        }
    }

    /// Construct the model (pipe efficiency honours `PLATINUM_DRAM_EFF`;
    /// an invalid value in that variable is a loud startup error).
    pub fn build(self, peak_bw: f64, freq_hz: f64) -> anyhow::Result<Box<dyn DramModel>> {
        Ok(match self {
            DramModelKind::Pipe => Box::new(DramChannel::from_env(peak_bw, freq_hz)?),
            DramModelKind::Bank => Box::new(BankStateDram::new(peak_bw, freq_hz)),
        })
    }
}

/// Row-buffer outcome counters (→ `kv.dram` section of the metrics
/// JSON: row-buffer hit rate is the headline locality signal).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct DramStats {
    pub bursts: u64,
    pub row_hits: u64,
    pub row_misses: u64,
    pub row_conflicts: u64,
}

impl DramStats {
    /// Fraction of bursts that hit an open row (`None` before traffic).
    pub fn hit_rate(&self) -> Option<f64> {
        if self.bursts == 0 {
            None
        } else {
            Some(self.row_hits as f64 / self.bursts as f64)
        }
    }
}

/// Parse an efficiency override: finite and in (0, 1], else `None`.
fn parse_efficiency(text: &str) -> Option<f64> {
    text.trim()
        .parse::<f64>()
        .ok()
        .filter(|e| e.is_finite() && *e > 0.0 && *e <= 1.0)
}

#[derive(Debug, Clone)]
pub struct DramChannel {
    /// Peak bandwidth in bytes/second.
    pub peak_bw: f64,
    /// Core clock frequency (cycles/second) used to express transfer
    /// time in accelerator cycles.
    pub freq_hz: f64,
    /// Sustained/peak efficiency (bank conflicts, refresh, rd/wr turn).
    pub efficiency: f64,
}

impl DramChannel {
    pub fn new(peak_bw: f64, freq_hz: f64) -> Self {
        DramChannel { peak_bw, freq_hz, efficiency: 0.9 }
    }

    /// Like [`DramChannel::new`] but with the sustained-efficiency
    /// factor calibratable via `PLATINUM_DRAM_EFF` (accepted range
    /// (0, 1]).  Unset keeps the default 0.9; a set-but-invalid value
    /// is a hard error naming the variable and the offending value
    /// (`util::env`) — a silently-ignored calibration knob looks
    /// exactly like a successful calibration.
    pub fn from_env(peak_bw: f64, freq_hz: f64) -> anyhow::Result<Self> {
        let mut d = DramChannel::new(peak_bw, freq_hz);
        if let Some(eff) =
            crate::util::env::read("PLATINUM_DRAM_EFF", "a number in (0, 1]", parse_efficiency)?
        {
            d.efficiency = eff;
        }
        Ok(d)
    }

    /// Bytes transferable per accelerator cycle (sustained).
    pub fn bytes_per_cycle(&self) -> f64 {
        self.peak_bw * self.efficiency / self.freq_hz
    }

    /// Cycles to transfer `bytes` (rounded up to bursts).
    pub fn transfer_cycles(&self, bytes: u64) -> u64 {
        if bytes == 0 {
            return 0;
        }
        let bursts = bytes.div_ceil(BURST_BYTES);
        let padded = bursts * BURST_BYTES;
        (padded as f64 / self.bytes_per_cycle()).ceil() as u64
    }
}

impl DramModel for DramChannel {
    fn label(&self) -> &'static str {
        "pipe"
    }

    fn transfer_cycles_at(&mut self, _addr: u64, bytes: u64) -> u64 {
        self.transfer_cycles(bytes)
    }

    fn row_buffer(&self) -> Option<DramStats> {
        None
    }

    fn reset(&mut self) {}
}

/// Byte-address → (row, bank, column) bit-field mapping.
///
/// Low bits address the column within a row, middle bits select the
/// bank, high bits the row ("RoBaCo" from MSB to LSB in DRAMsim3
/// terms) — the interleave that lets a sequential stream sweep one full
/// row per bank before reopening anything.  The DRAMsim3 integration
/// lesson (SNIPPETS) is that a mis-sized field here silently aliases
/// addresses instead of failing; the constructor therefore validates
/// the mapping by round-tripping encode ∘ decode over samples of every
/// field's range and refuses to build a non-bijective layout.
#[derive(Debug, Clone, Copy)]
pub struct AddressMapping {
    pub col_bits: u32,
    pub bank_bits: u32,
}

impl AddressMapping {
    pub fn new(row_bytes: u64, banks: u64) -> AddressMapping {
        assert!(row_bytes.is_power_of_two(), "row size must be a power of two");
        assert!(banks.is_power_of_two(), "bank count must be a power of two");
        let m = AddressMapping {
            col_bits: row_bytes.trailing_zeros(),
            bank_bits: banks.trailing_zeros(),
        };
        if let Err(e) = m.validate() {
            panic!("invalid DRAM address mapping: {e}");
        }
        m
    }

    /// Split a byte address into (row, bank, column).
    pub fn decode(&self, addr: u64) -> (u64, u64, u64) {
        let col = addr & ((1u64 << self.col_bits) - 1);
        let bank = (addr >> self.col_bits) & ((1u64 << self.bank_bits) - 1);
        let row = addr >> (self.col_bits + self.bank_bits);
        (row, bank, col)
    }

    /// Reassemble a byte address from its fields.
    pub fn encode(&self, row: u64, bank: u64, col: u64) -> u64 {
        (row << (self.col_bits + self.bank_bits)) | (bank << self.col_bits) | col
    }

    /// Check the field layout is bijective: every sampled address
    /// round-trips through decode ∘ encode, every sampled field triple
    /// round-trips through encode ∘ decode, and fields cannot overflow
    /// into each other.
    pub fn validate(&self) -> Result<(), String> {
        if self.col_bits == 0 || self.bank_bits == 0 {
            return Err("degenerate field (0 bits)".into());
        }
        if self.col_bits + self.bank_bits >= 58 {
            return Err(format!(
                "col({}) + bank({}) bits leave no room for rows",
                self.col_bits, self.bank_bits
            ));
        }
        // address round-trips, including far past the low fields
        let mut addr: u64 = 0;
        while addr < (1u64 << (self.col_bits + self.bank_bits + 8)) {
            let (r, b, c) = self.decode(addr);
            if self.encode(r, b, c) != addr {
                return Err(format!("address {addr:#x} does not round-trip"));
            }
            addr = addr * 3 + 0x11;
        }
        // field round-trips across each field's full range boundaries
        let cols = [0u64, 1, (1u64 << self.col_bits) - 1];
        let rows = [0u64, 1, 37, (1u64 << 12) + 5];
        for &row in &rows {
            for bank in 0..(1u64 << self.bank_bits) {
                for &col in &cols {
                    let (r, b, c) = self.decode(self.encode(row, bank, col));
                    if (r, b, c) != (row, bank, col) {
                        return Err(format!(
                            "fields (row {row}, bank {bank}, col {col}) alias to \
                             (row {r}, bank {b}, col {c})"
                        ));
                    }
                }
            }
        }
        Ok(())
    }
}

/// Per-bank open-row DRAM timing model.
///
/// Every 64 B burst lands in one bank: a burst to the open row costs
/// only bus occupancy; a burst to a closed bank adds tRCD + tCAS; a
/// burst to a bank with a *different* row open adds tRP + tRCD + tCAS.
/// Activations serialize behind the bank's previous operation and the
/// shared data bus, so the model is deliberately conservative (no
/// activate-under-transfer overlap) — the documented ≤ 25 % streaming
/// gap vs. [`DramChannel`] comes from exactly this.
#[derive(Debug, Clone)]
pub struct BankStateDram {
    pub peak_bw: f64,
    pub freq_hz: f64,
    mapping: AddressMapping,
    /// Data-bus cycles one 64 B burst occupies (no efficiency derate:
    /// inefficiency emerges from bank timing instead).
    burst_cycles: f64,
    t_rcd: f64,
    t_rp: f64,
    t_cas: f64,
    open_row: Vec<Option<u64>>,
    bank_free: Vec<f64>,
    bus_free: f64,
    stats: DramStats,
}

impl BankStateDram {
    pub fn new(peak_bw: f64, freq_hz: f64) -> BankStateDram {
        let ns = freq_hz / 1e9;
        BankStateDram {
            peak_bw,
            freq_hz,
            mapping: AddressMapping::new(DRAM_ROW_BYTES, DRAM_BANKS),
            burst_cycles: BURST_BYTES as f64 * freq_hz / peak_bw,
            t_rcd: T_RCD_NS * ns,
            t_rp: T_RP_NS * ns,
            t_cas: T_CAS_NS * ns,
            open_row: vec![None; DRAM_BANKS as usize],
            bank_free: vec![0.0; DRAM_BANKS as usize],
            bus_free: 0.0,
            stats: DramStats::default(),
        }
    }

    pub fn mapping(&self) -> AddressMapping {
        self.mapping
    }

    fn burst(&mut self, addr: u64) {
        let (row, bank, _col) = self.mapping.decode(addr);
        let bank = bank as usize;
        self.stats.bursts += 1;
        let activate = match self.open_row[bank] {
            Some(open) if open == row => {
                self.stats.row_hits += 1;
                0.0
            }
            Some(_) => {
                self.stats.row_conflicts += 1;
                self.t_rp + self.t_rcd + self.t_cas
            }
            None => {
                self.stats.row_misses += 1;
                self.t_rcd + self.t_cas
            }
        };
        self.open_row[bank] = Some(row);
        let start = if activate > 0.0 {
            (self.bank_free[bank].max(self.bus_free) + activate).max(self.bus_free)
        } else {
            self.bus_free
        };
        let end = start + self.burst_cycles;
        self.bus_free = end;
        self.bank_free[bank] = end;
    }
}

impl DramModel for BankStateDram {
    fn label(&self) -> &'static str {
        "bank"
    }

    fn transfer_cycles_at(&mut self, addr: u64, bytes: u64) -> u64 {
        if bytes == 0 {
            return 0;
        }
        let t0 = self.bus_free;
        let mut a = addr - addr % BURST_BYTES;
        let end = addr + bytes;
        while a < end {
            self.burst(a);
            a += BURST_BYTES;
        }
        (self.bus_free - t0).ceil() as u64
    }

    fn row_buffer(&self) -> Option<DramStats> {
        Some(self.stats)
    }

    fn reset(&mut self) {
        self.open_row.iter_mut().for_each(|r| *r = None);
        self.bank_free.iter_mut().for_each(|f| *f = 0.0);
        self.bus_free = 0.0;
        self.stats = DramStats::default();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transfer_time_scales_linearly() {
        let d = DramChannel::new(64e9, 500e6);
        let one = d.transfer_cycles(64 * 1024);
        let four = d.transfer_cycles(256 * 1024);
        assert!((four as f64 / one as f64 - 4.0).abs() < 0.05);
    }

    #[test]
    fn sixty_four_gbs_at_500mhz_is_115_bytes_per_cycle() {
        let d = DramChannel::new(64e9, 500e6);
        let bpc = d.bytes_per_cycle();
        assert!((bpc - 115.2).abs() < 0.5, "{bpc}");
    }

    #[test]
    fn small_transfers_round_to_burst() {
        let d = DramChannel::new(64e9, 500e6);
        assert_eq!(d.transfer_cycles(1), d.transfer_cycles(64));
        assert!(d.transfer_cycles(65) > d.transfer_cycles(64));
    }

    #[test]
    fn zero_bytes_zero_cycles() {
        let d = DramChannel::new(64e9, 500e6);
        assert_eq!(d.transfer_cycles(0), 0);
        let mut b = BankStateDram::new(64e9, 500e6);
        assert_eq!(b.transfer_cycles_at(0, 0), 0);
        assert_eq!(b.row_buffer().unwrap().bursts, 0);
    }

    #[test]
    fn efficiency_parser_rejects_out_of_range() {
        assert_eq!(parse_efficiency("0.75"), Some(0.75));
        assert_eq!(parse_efficiency(" 1.0 "), Some(1.0));
        assert_eq!(parse_efficiency("0"), None);
        assert_eq!(parse_efficiency("-0.5"), None);
        assert_eq!(parse_efficiency("1.5"), None);
        assert_eq!(parse_efficiency("NaN"), None);
        assert_eq!(parse_efficiency("fast"), None);
    }

    #[test]
    fn from_env_calibrates_efficiency() {
        // narrow set → read → remove window, value near the default to
        // minimize cross-test interference (PR 5 interconnect pattern)
        std::env::set_var("PLATINUM_DRAM_EFF", "0.88");
        let d = DramChannel::from_env(64e9, 500e6);
        std::env::remove_var("PLATINUM_DRAM_EFF");
        assert!((d.unwrap().efficiency - 0.88).abs() < 1e-12);
        // out-of-range is a loud error naming variable + value, never a
        // silent fallback to the default
        std::env::set_var("PLATINUM_DRAM_EFF", "2.5");
        let err = DramChannel::from_env(64e9, 500e6);
        std::env::remove_var("PLATINUM_DRAM_EFF");
        let msg = err.unwrap_err().to_string();
        assert!(msg.contains("PLATINUM_DRAM_EFF") && msg.contains("2.5"), "{msg}");
    }

    #[test]
    fn mapping_is_bijective_and_streams_interleave_banks() {
        let m = AddressMapping::new(DRAM_ROW_BYTES, DRAM_BANKS);
        m.validate().unwrap();
        // one row per bank along a sequential stream: +8 KiB → next bank
        assert_eq!(m.decode(0), (0, 0, 0));
        assert_eq!(m.decode(DRAM_ROW_BYTES), (0, 1, 0));
        // bank field wraps after banks × row_bytes → next row, bank 0
        assert_eq!(m.decode(DRAM_ROW_BYTES * DRAM_BANKS), (1, 0, 0));
        assert_eq!(m.encode(1, 0, 0), DRAM_ROW_BYTES * DRAM_BANKS);
    }

    #[test]
    fn degenerate_mapping_is_rejected() {
        // the SNIPPETS lesson: a silent field mistake must fail loudly
        let bad = AddressMapping { col_bits: 0, bank_bits: 4 };
        assert!(bad.validate().is_err());
        let bad = AddressMapping { col_bits: 40, bank_bits: 20 };
        assert!(bad.validate().is_err());
    }

    #[test]
    fn streaming_agrees_with_pipe_within_documented_bound() {
        // 64 KiB sequential read: the bank model pays one activation per
        // 8 KiB row and bus occupancy otherwise; the pipe prices the
        // same interference as a flat 0.9.  Documented bound: ≤ 25 %.
        let pipe = DramChannel::new(64e9, 500e6);
        let mut bank = BankStateDram::new(64e9, 500e6);
        let bytes = 64 * 1024;
        let p = pipe.transfer_cycles(bytes);
        let b = bank.transfer_cycles_at(0, bytes);
        let rel = (b as f64 - p as f64).abs() / p as f64;
        assert!(rel < 0.25, "streaming gap {rel:.3} (pipe {p}, bank {b})");
        // exactly one miss per touched bank, zero conflicts, rest hits
        let st = bank.row_buffer().unwrap();
        assert_eq!(st.row_misses, bytes / DRAM_ROW_BYTES);
        assert_eq!(st.row_conflicts, 0);
        assert_eq!(st.bursts, bytes / BURST_BYTES);
        assert!(st.hit_rate().unwrap() > 0.95, "{st:?}");
    }

    #[test]
    fn bank_conflicts_diverge_slower_than_pipe() {
        // 256 bursts striding row_bytes × banks: every access reopens a
        // different row of bank 0 → tRP + tRCD + tCAS per burst
        let pipe = DramChannel::new(64e9, 500e6);
        let mut bank = BankStateDram::new(64e9, 500e6);
        let stride = DRAM_ROW_BYTES * DRAM_BANKS;
        let mut bank_cycles = 0u64;
        for i in 0..256u64 {
            bank_cycles += bank.transfer_cycles_at(i * stride, BURST_BYTES);
        }
        let pipe_cycles = pipe.transfer_cycles(256 * BURST_BYTES);
        assert!(
            bank_cycles as f64 > 3.0 * pipe_cycles as f64,
            "conflict pattern must be ≫ slower: bank {bank_cycles} vs pipe {pipe_cycles}"
        );
        let st = bank.row_buffer().unwrap();
        assert_eq!(st.row_conflicts, 255);
        assert_eq!(st.row_misses, 1);
        assert_eq!(st.hit_rate(), Some(0.0));
    }

    #[test]
    fn reset_restores_cold_state() {
        let mut bank = BankStateDram::new(64e9, 500e6);
        let cold = bank.transfer_cycles_at(0, 8192);
        let warm = bank.transfer_cycles_at(0, 8192);
        assert!(warm < cold, "open row must make the rerun cheaper");
        bank.reset();
        assert_eq!(bank.transfer_cycles_at(0, 8192), cold);
        assert_eq!(bank.row_buffer().unwrap().bursts, 8192 / BURST_BYTES);
    }

    #[test]
    fn kind_parses_and_builds_both_models() {
        assert_eq!(DramModelKind::parse("pipe"), Some(DramModelKind::Pipe));
        assert_eq!(DramModelKind::parse(" Bank "), Some(DramModelKind::Bank));
        assert_eq!(DramModelKind::parse("fixed"), Some(DramModelKind::Pipe));
        assert_eq!(DramModelKind::parse("hbm"), None);
        let mut p = DramModelKind::Pipe.build(64e9, 500e6).unwrap();
        let mut b = DramModelKind::Bank.build(64e9, 500e6).unwrap();
        assert_eq!(p.label(), "pipe");
        assert_eq!(b.label(), "bank");
        assert!(p.transfer_cycles_at(0, 4096) > 0);
        assert!(b.transfer_cycles_at(0, 4096) > 0);
    }
}
