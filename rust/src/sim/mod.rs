//! Cycle-accurate Platinum simulator (S4) — the substitute for the
//! paper's Prosperity-derived simulator (§V-A).
//!
//! The engine walks the exact tiled loop nest the coordinator would
//! dispatch, charging cycles per pipeline phase (construct / query /
//! reduce / drain), modelling DRAM as a bandwidth-constrained channel
//! overlapped with compute via double buffering, and counting every
//! buffer access and adder operation so the energy model can price them.
//!
//! Phase cycle laws (verified against §IV-B's published utilizations):
//!
//! * construct: `path_len + pipeline_depth` cycles per round — one path
//!   entry per cycle through the 4-stage pipeline (Fig 4), no hazards
//!   because the offline schedule guarantees RAW distance ≥ depth.
//! * query: both LUT ports stream queries — `⌈m_t · q_row / ports⌉`
//!   cycles per round, where q_row = queries per row (1 ternary,
//!   `planes` for bit-serial) — plus the aggregator tree drain.
//! * DRAM: transfers for the *next* tile overlap the current tile's
//!   compute; stall = max(0, load_cycles − compute_cycles).

mod dram;
pub mod net;
mod platinum;

pub use dram::{
    AddressMapping, BankStateDram, DramChannel, DramModel, DramModelKind, DramStats,
    BURST_BYTES, DRAM_BANKS, DRAM_ROW_BYTES,
};
pub use platinum::{simulate_gemm, simulate_model, SimReport};

use crate::config::ExecMode;

/// Activity counters accumulated by the engine (inputs to the energy
/// model and the §IV-B utilization checks).
#[derive(Debug, Default, Clone, Copy, PartialEq)]
pub struct Activity {
    /// 8-bit construction adds (one per path entry per lane).
    pub construct_adds: u64,
    /// 32-bit reduce/aggregate adds.
    pub reduce_adds: u64,
    /// LUT bytes written during construction.
    pub lut_write_bytes: u64,
    /// LUT bytes read (construction sources + queries).
    pub lut_read_bytes: u64,
    /// Weight buffer bytes read (query stream).
    pub wbuf_read_bytes: u64,
    /// Weight buffer bytes written (DRAM fills).
    pub wbuf_write_bytes: u64,
    /// Input buffer bytes read (construction operands).
    pub ibuf_read_bytes: u64,
    /// Input buffer bytes written (DRAM fills).
    pub ibuf_write_bytes: u64,
    /// Output buffer bytes accessed (accumulator read+write).
    pub obuf_bytes: u64,
    /// Build-path buffer bytes fetched.
    pub path_read_bytes: u64,
    /// DRAM bytes read (weights + inputs + output spills).
    pub dram_read_bytes: u64,
    /// DRAM bytes written (outputs + spills).
    pub dram_write_bytes: u64,
}

impl Activity {
    pub fn add(&mut self, o: &Activity) {
        self.construct_adds += o.construct_adds;
        self.reduce_adds += o.reduce_adds;
        self.lut_write_bytes += o.lut_write_bytes;
        self.lut_read_bytes += o.lut_read_bytes;
        self.wbuf_read_bytes += o.wbuf_read_bytes;
        self.wbuf_write_bytes += o.wbuf_write_bytes;
        self.ibuf_read_bytes += o.ibuf_read_bytes;
        self.ibuf_write_bytes += o.ibuf_write_bytes;
        self.obuf_bytes += o.obuf_bytes;
        self.path_read_bytes += o.path_read_bytes;
        self.dram_read_bytes += o.dram_read_bytes;
        self.dram_write_bytes += o.dram_write_bytes;
    }

    pub fn dram_total_bytes(&self) -> u64 {
        self.dram_read_bytes + self.dram_write_bytes
    }

    /// Multiply every counter by a kernel occurrence count (model-pass
    /// aggregation in [`crate::engine`] and [`simulate_model`]).
    pub fn scale(&mut self, c: u64) {
        self.construct_adds *= c;
        self.reduce_adds *= c;
        self.lut_write_bytes *= c;
        self.lut_read_bytes *= c;
        self.wbuf_read_bytes *= c;
        self.wbuf_write_bytes *= c;
        self.ibuf_read_bytes *= c;
        self.ibuf_write_bytes *= c;
        self.obuf_bytes *= c;
        self.path_read_bytes *= c;
        self.dram_read_bytes *= c;
        self.dram_write_bytes *= c;
    }
}

/// Per-component dynamic + static energy in joules (→ Fig 9, §V-B).
#[derive(Debug, Default, Clone, Copy, PartialEq)]
pub struct EnergyBreakdown {
    pub dram: f64,
    pub weight_buf: f64,
    pub input_buf: f64,
    pub output_buf: f64,
    pub lut_buf: f64,
    pub path_buf: f64,
    pub adders: f64,
    pub static_leak: f64,
}

impl EnergyBreakdown {
    pub fn total(&self) -> f64 {
        self.dram
            + self.weight_buf
            + self.input_buf
            + self.output_buf
            + self.lut_buf
            + self.path_buf
            + self.adders
            + self.static_leak
    }

    pub fn add(&mut self, o: &EnergyBreakdown) {
        self.dram += o.dram;
        self.weight_buf += o.weight_buf;
        self.input_buf += o.input_buf;
        self.output_buf += o.output_buf;
        self.lut_buf += o.lut_buf;
        self.path_buf += o.path_buf;
        self.adders += o.adders;
        self.static_leak += o.static_leak;
    }

    /// Multiply every component by a kernel occurrence count.
    pub fn scale(&mut self, c: f64) {
        self.dram *= c;
        self.weight_buf *= c;
        self.input_buf *= c;
        self.output_buf *= c;
        self.lut_buf *= c;
        self.path_buf *= c;
        self.adders *= c;
        self.static_leak *= c;
    }
}

/// Cycle occupancy per phase (→ utilization report, E11).
#[derive(Debug, Default, Clone, Copy, PartialEq)]
pub struct PhaseCycles {
    pub construct: u64,
    pub query: u64,
    pub drain: u64,
    pub dram_stall: u64,
}

impl PhaseCycles {
    pub fn busy(&self) -> u64 {
        self.construct + self.query + self.drain
    }

    pub fn total(&self) -> u64 {
        self.busy() + self.dram_stall
    }

    pub fn add(&mut self, o: &PhaseCycles) {
        self.construct += o.construct;
        self.query += o.query;
        self.drain += o.drain;
        self.dram_stall += o.dram_stall;
    }

    /// Multiply every phase by a kernel occurrence count.
    pub fn scale(&mut self, c: u64) {
        self.construct *= c;
        self.query *= c;
        self.drain *= c;
        self.dram_stall *= c;
    }
}

/// Hardware utilization summary (E11: §IV-B claims ~100 % LUT ports in
/// query, 90.5 % average adder utilization).
#[derive(Debug, Default, Clone, Copy)]
pub struct Utilization {
    pub adders: f64,
    pub lut_ports: f64,
    pub dram_bw: f64,
}

/// Label helper for reports.
pub fn mode_label(mode: ExecMode) -> &'static str {
    mode.label()
}
