//! The Platinum simulation engine: walks the exact tiled loop nest,
//! charges per-phase cycles, DRAM transfers, buffer accesses and adder
//! operations, and prices them with the energy model.

use super::{Activity, DramChannel, EnergyBreakdown, PhaseCycles, Utilization};
use crate::analysis::Gemm;
use crate::config::{ExecMode, PlatinumConfig, Stationarity};
use crate::energy::{AreaModel, EnergyTable};
use crate::models::BitNetModel;
use crate::pathgen;

/// Result of simulating one kernel (or an aggregated model pass).
#[derive(Debug, Clone)]
pub struct SimReport {
    pub gemm: Gemm,
    pub mode: ExecMode,
    pub cycles: u64,
    pub phases: PhaseCycles,
    pub activity: Activity,
    pub energy: EnergyBreakdown,
    pub latency_s: f64,
    /// Naive-equivalent throughput (paper's GOP/s normalization).
    pub throughput_gops: f64,
    pub utilization: Utilization,
}

impl SimReport {
    pub fn energy_j(&self) -> f64 {
        self.energy.total()
    }

    pub fn power_w(&self) -> f64 {
        self.energy.total() / self.latency_s
    }
}

/// Walk order helper: produce tile index triples in the configured
/// stationarity order; returns (m0, k0, n0) origin per step.
fn tile_walk(
    g: Gemm,
    mt: usize,
    kt: usize,
    nt: usize,
    order: Stationarity,
) -> Vec<(usize, usize, usize)> {
    let ms: Vec<usize> = (0..g.m).step_by(mt).collect();
    let ks: Vec<usize> = (0..g.k).step_by(kt).collect();
    let ns: Vec<usize> = (0..g.n).step_by(nt).collect();
    let mut out = Vec::with_capacity(ms.len() * ks.len() * ns.len());
    // loop order outermost→innermost as named
    macro_rules! walk {
        ($a:ident, $b:ident, $c:ident, $f:expr) => {
            for &x in &$a {
                for &y in &$b {
                    for &z in &$c {
                        out.push($f(x, y, z));
                    }
                }
            }
        };
    }
    match order {
        Stationarity::Mnk => walk!(ms, ns, ks, |m, n, k| (m, k, n)),
        Stationarity::Mkn => walk!(ms, ks, ns, |m, k, n| (m, k, n)),
        Stationarity::Nmk => walk!(ns, ms, ks, |n, m, k| (m, k, n)),
        Stationarity::Nkm => walk!(ns, ks, ms, |n, k, m| (m, k, n)),
        Stationarity::Kmn => walk!(ks, ms, ns, |k, m, n| (m, k, n)),
        Stationarity::Knm => walk!(ks, ns, ms, |k, n, m| (m, k, n)),
    }
    out
}

/// Is k the innermost loop level? (Output tile accumulates on-chip and
/// spills to DRAM only once; otherwise partials spill per k-step.)
fn k_innermost(order: Stationarity) -> bool {
    matches!(order, Stationarity::Mnk | Stationarity::Nmk)
}

/// Simulate one mpGEMM kernel dispatch on Platinum.
pub fn simulate_gemm(cfg: &PlatinumConfig, mode: ExecMode, g: Gemm) -> SimReport {
    let t = cfg.tiling;
    let c = cfg.chunk(mode);
    let planes = match mode {
        ExecMode::Ternary => 1u64,
        ExecMode::BitSerial { planes } => planes as u64,
    };
    // weight stream bits per weight element
    let wbits = match mode {
        ExecMode::Ternary => 1.6,
        // one c-bit LUT address per chunk per plane → 1 bit/weight/plane
        ExecMode::BitSerial { planes } => planes as f64,
    };
    // §Perf iteration 1: memoized paths (value-independent, see pathgen)
    let path = match mode {
        ExecMode::Ternary => pathgen::ternary_path_cached(c),
        ExecMode::BitSerial { .. } => pathgen::binary_path_cached(c),
    };
    let construct_cycles_round = path.construct_cycles(cfg.pipeline_depth) as u64;
    let tree_drain = (usize::BITS - cfg.num_ppes.leading_zeros()) as u64 + 1;
    // infallible pricing path: an invalid PLATINUM_DRAM_EFF is a
    // configuration bug worth halting on, with the variable named
    let dram = DramChannel::from_env(cfg.dram_bw, cfg.freq_hz).unwrap_or_else(|e| panic!("{e}"));
    let area = AreaModel::platinum(cfg);
    let etab = EnergyTable::from_area(&area);

    let walk = tile_walk(g, t.m, t.k, t.n, t.order);
    let kin = k_innermost(t.order);

    let mut act = Activity::default();
    let mut phases = PhaseCycles::default();
    let mut compute_cycles_total: u64 = 0;
    let mut prev_mk: Option<(usize, usize)> = None;
    let mut prev_kn: Option<(usize, usize)> = None;
    // adder-utilization accounting (§IV-B)
    let mut adder_busy: f64 = 0.0;
    let total_adders = (cfg.num_pes() * 2) as f64; // construct + extra reduce adders

    for &(m0, k0, n0) in &walk {
        let mt = t.m.min(g.m - m0);
        let kt = t.k.min(g.k - k0);
        let nt = t.n.min(g.n - n0);
        let chunks = kt.div_ceil(c);
        let n_blocks = nt.div_ceil(cfg.n_cols) as u64;
        let rounds_k = chunks.div_ceil(cfg.num_ppes) as u64;

        // ---- DRAM traffic for this tile --------------------------------
        let mut dram_rd: u64 = 0;
        let mut dram_wr: u64 = 0;
        if prev_mk != Some((m0, k0)) {
            let wbytes = ((mt * kt) as f64 * wbits / 8.0).ceil() as u64;
            dram_rd += wbytes;
            act.wbuf_write_bytes += wbytes;
            prev_mk = Some((m0, k0));
        }
        if prev_kn != Some((k0, n0)) {
            let ibytes = (kt * nt) as u64; // int8 activations
            dram_rd += ibytes;
            act.ibuf_write_bytes += ibytes;
            prev_kn = Some((k0, n0));
        }
        let last_k = k0 + kt >= g.k;
        let first_k = k0 == 0;
        if kin {
            // output written once per (m,n) tile after the k loop
            if last_k {
                dram_wr += (mt * nt) as u64; // int8 requantized output
            }
        } else {
            // partial spills: read back previous partials, write new ones
            if !first_k {
                dram_rd += (mt * nt * 4) as u64;
            }
            dram_wr += (mt * nt * 4) as u64;
        }
        act.dram_read_bytes += dram_rd;
        act.dram_write_bytes += dram_wr;

        // ---- compute cycles --------------------------------------------
        // query cycles per round: each PPE serves `planes` queries per
        // row through `lut_ports` ports, all PPEs in lockstep over mt
        // rows — ceil(mt·planes / ports) cycles.
        let query_cycles_round = ((mt as u64) * planes).div_ceil(cfg.lut_ports as u64);
        let rounds = rounds_k * n_blocks;
        let tile_construct = rounds * construct_cycles_round;
        let tile_query = rounds * query_cycles_round;
        let tile_drain = rounds * tree_drain;
        let tile_compute = tile_construct + tile_query + tile_drain;

        phases.construct += tile_construct;
        phases.query += tile_query;
        phases.drain += tile_drain;
        compute_cycles_total += tile_compute;

        // ---- DRAM overlap (double buffering): next tile loads overlap
        // this tile's compute; charge stall when loads are longer.
        let load_cycles = dram.transfer_cycles(dram_rd + dram_wr);
        phases.dram_stall += load_cycles.saturating_sub(tile_compute);

        // ---- activity ----------------------------------------------------
        // per round: active PPEs construct their LUT (path_len adds ×
        // n_cols lanes), last k-round may have fewer active PPEs
        let full_rounds = (chunks / cfg.num_ppes) as u64;
        let rem_ppes = (chunks % cfg.num_ppes) as u64;
        let active_ppe_rounds =
            (full_rounds * cfg.num_ppes as u64 + rem_ppes) * n_blocks;
        let lanes = cfg.n_cols as u64;
        let path_len = path.entries.len() as u64;
        let cons_adds = active_ppe_rounds * path_len * lanes;
        act.construct_adds += cons_adds;
        act.lut_write_bytes += active_ppe_rounds * path_len * lanes;
        act.lut_read_bytes += active_ppe_rounds * path_len * lanes; // src reads
        act.ibuf_read_bytes += active_ppe_rounds * path_len * lanes;
        act.path_read_bytes += active_ppe_rounds * path_len * 4;

        // queries: every row queries every active chunk (× planes)
        let queries = (mt as u64) * (chunks as u64) * planes * n_blocks;
        act.wbuf_read_bytes += queries; // 1 encoded byte per query
        act.lut_read_bytes += queries * lanes;
        // reduce: aggregating one partial per active chunk per row per lane
        let red_adds = queries * lanes;
        act.reduce_adds += red_adds;
        // output accumulator traffic: read+write 4B per row×lane per round
        act.obuf_bytes += rounds_k * n_blocks * (mt as u64) * lanes * 8;

        // adder busy integral: construct phase uses n_cols adders per
        // active PPE; query phase uses the full reduce array
        adder_busy += cons_adds as f64;
        adder_busy += red_adds as f64;
    }

    // pipeline fill for the first tile's loads (not overlapped)
    if let Some(&(m0, k0, _)) = walk.first() {
        let mt = t.m.min(g.m - m0);
        let kt = t.k.min(g.k - k0);
        let first_bytes = ((mt * kt) as f64 * wbits / 8.0).ceil() as u64;
        phases.dram_stall += dram.transfer_cycles(first_bytes);
    }

    let cycles = compute_cycles_total + phases.dram_stall;
    let latency_s = cycles as f64 / cfg.freq_hz;

    // ---- energy --------------------------------------------------------
    let mut en = EnergyBreakdown {
        dram: act.dram_total_bytes() as f64 * 8.0 * etab.dram_pj_per_bit * 1e-12,
        weight_buf: (act.wbuf_read_bytes as f64 * etab.wbuf_read_pj_per_byte
            + act.wbuf_write_bytes as f64 * etab.wbuf_write_pj_per_byte)
            * 1e-12,
        input_buf: (act.ibuf_read_bytes as f64 * etab.ibuf_read_pj_per_byte
            + act.ibuf_write_bytes as f64 * etab.ibuf_write_pj_per_byte)
            * 1e-12,
        output_buf: act.obuf_bytes as f64 * etab.obuf_rw_pj_per_byte * 1e-12,
        lut_buf: (act.lut_read_bytes as f64 * etab.lut_read_pj_per_byte
            + act.lut_write_bytes as f64 * etab.lut_write_pj_per_byte)
            * 1e-12,
        path_buf: act.path_read_bytes as f64 * etab.path_read_pj_per_byte * 1e-12,
        adders: (act.construct_adds as f64 * etab.add8_pj
            + act.reduce_adds as f64 * etab.add32_pj)
            * 1e-12,
        static_leak: 0.0,
    };
    en.static_leak = etab.static_mw * 1e-3 * latency_s;

    let busy = phases.busy().max(1);
    let util = Utilization {
        adders: adder_busy / (total_adders * busy as f64),
        lut_ports: {
            // construct: RW + RO ports both busy; query: both ports busy;
            // drain idles them.  Steady-state metric: cold-start DRAM
            // fill (a one-time cost) is excluded, matching §IV-B's
            // "theoretically near 100% utilization of both LUT ports".
            (phases.construct + phases.query) as f64 / busy as f64
        },
        dram_bw: act.dram_total_bytes() as f64
            / (cycles as f64
                * DramChannel::from_env(cfg.dram_bw, cfg.freq_hz)
                    .unwrap_or_else(|e| panic!("{e}"))
                    .bytes_per_cycle()),
    };

    SimReport {
        gemm: g,
        mode,
        cycles,
        phases,
        activity: act,
        energy: en,
        latency_s,
        throughput_gops: g.naive_adds() as f64 / latency_s / 1e9,
        utilization: util,
    }
}

/// Simulate a full model forward pass (Σ kernels × counts × layers).
///
/// Prefer [`crate::engine::PlatinumBackend`] with a
/// [`crate::engine::Workload::ModelPass`] — the engine aggregates with
/// identical arithmetic and returns the unified report; this free
/// function is kept as a stable shim for existing callers.
pub fn simulate_model(
    cfg: &PlatinumConfig,
    mode: ExecMode,
    model: &BitNetModel,
    n: usize,
) -> SimReport {
    let mut total: Option<SimReport> = None;
    let mut naive: u64 = 0;
    for (g, count) in model.model_gemms(n) {
        let r = simulate_gemm(cfg, mode, g);
        naive += g.naive_adds() * count as u64;
        match &mut total {
            None => {
                let mut first = r.clone();
                first.cycles *= count as u64;
                first.latency_s *= count as f64;
                first.phases.scale(count as u64);
                first.activity.scale(count as u64);
                first.energy.scale(count as f64);
                total = Some(first);
            }
            Some(acc) => {
                acc.cycles += r.cycles * count as u64;
                acc.latency_s += r.latency_s * count as f64;
                let mut ph = r.phases;
                ph.scale(count as u64);
                acc.phases.add(&ph);
                let mut a = r.activity;
                a.scale(count as u64);
                acc.activity.add(&a);
                let mut e = r.energy;
                e.scale(count as f64);
                acc.energy.add(&e);
            }
        }
    }
    let mut out = total.expect("model has kernels");
    out.gemm = Gemm::new(0, 0, n);
    out.throughput_gops = naive as f64 / out.latency_s / 1e9;
    // recompute aggregate utilization from phase integrals
    out.utilization.lut_ports =
        (out.phases.construct + out.phases.query) as f64 / out.phases.busy().max(1) as f64;
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{B158_3B, DECODE_N, PREFILL_N};

    fn cfg() -> PlatinumConfig {
        PlatinumConfig::default()
    }

    #[test]
    fn prefill_throughput_matches_table1() {
        // Table I: 1534 GOP/s on b1.58-3B, N=1024 (±12 % band for the
        // analytical substitution)
        let r = simulate_model(&cfg(), ExecMode::Ternary, &B158_3B, PREFILL_N);
        assert!(
            (r.throughput_gops - 1534.0).abs() / 1534.0 < 0.12,
            "throughput {:.0} GOP/s vs paper 1534",
            r.throughput_gops
        );
    }

    #[test]
    fn prefill_power_matches_section_vb() {
        // §V-B: 3.2 W running b1.58-3B prefill (±20 %)
        let r = simulate_model(&cfg(), ExecMode::Ternary, &B158_3B, PREFILL_N);
        let p = r.power_w();
        assert!((p - 3.2).abs() / 3.2 < 0.20, "power {p:.2} W vs paper 3.2");
    }

    #[test]
    fn power_breakdown_shape_matches_paper() {
        // §V-B: DRAM 53.5 %, weight buffer 31.6 % — shape check: DRAM is
        // the top consumer, weight buffer second, LUT well below both.
        let r = simulate_model(&cfg(), ExecMode::Ternary, &B158_3B, PREFILL_N);
        let e = r.energy;
        assert!(e.dram > e.weight_buf, "DRAM must dominate");
        assert!(e.weight_buf > e.lut_buf, "wbuf above LUT");
        assert!(e.weight_buf > e.output_buf);
        let dram_share = e.dram / e.total();
        let wbuf_share = e.weight_buf / e.total();
        assert!((dram_share - 0.535).abs() < 0.12, "dram {dram_share:.3}");
        assert!((wbuf_share - 0.316).abs() < 0.12, "wbuf {wbuf_share:.3}");
    }

    #[test]
    fn adder_utilization_matches_section_ivb() {
        // §IV-B: ~90.5 % average adder utilization, ~100 % LUT ports
        let g = Gemm::new(1080, 520, 32); // exactly one tile
        let r = simulate_gemm(&cfg(), ExecMode::Ternary, g);
        assert!((r.utilization.adders - 0.905).abs() < 0.04, "{:.3}", r.utilization.adders);
        assert!(r.utilization.lut_ports > 0.9, "{:.3}", r.utilization.lut_ports);
    }

    #[test]
    fn ternary_faster_than_bitserial_by_1_3x() {
        // §V-C: ternary optimization gives 1.3–1.4× over Platinum-bs.
        let mut c_bs = cfg();
        // Platinum-bs retiles k to align chunks with L (52·7·2 = 728)
        c_bs.tiling.k = 728;
        let model = &B158_3B;
        let t = simulate_model(&cfg(), ExecMode::Ternary, model, PREFILL_N);
        let b = simulate_model(&c_bs, ExecMode::BitSerial { planes: 2 }, model, PREFILL_N);
        let ratio = b.latency_s / t.latency_s;
        assert!((1.2..=1.9).contains(&ratio), "Platinum-bs ratio {ratio:.2}");
    }

    #[test]
    fn decode_keeps_utilization() {
        // §V-C: n_cols = 8 guarantees utilization under low-N workloads;
        // decode per-op latency should be within ~35 % of prefill
        let p = simulate_model(&cfg(), ExecMode::Ternary, &B158_3B, PREFILL_N);
        let d = simulate_model(&cfg(), ExecMode::Ternary, &B158_3B, DECODE_N);
        let per_op_p = p.latency_s / B158_3B.total_naive_adds(PREFILL_N) as f64;
        let per_op_d = d.latency_s / B158_3B.total_naive_adds(DECODE_N) as f64;
        assert!(per_op_d / per_op_p < 1.6, "decode per-op {:.2}×", per_op_d / per_op_p);
    }

    #[test]
    fn cycles_conserve_phases() {
        let g = Gemm::new(2048, 1024, 64);
        let r = simulate_gemm(&cfg(), ExecMode::Ternary, g);
        assert_eq!(r.cycles, r.phases.busy() + r.phases.dram_stall);
        assert!(r.latency_s > 0.0 && r.energy_j() > 0.0);
    }

    #[test]
    fn op_counters_match_analysis_structure() {
        // construct adds per chunk = path_len × n_cols; cross-check the
        // simulator's counter against Eq (3)'s construction term.
        let g = Gemm::new(1080, 520, 32);
        let r = simulate_gemm(&cfg(), ExecMode::Ternary, g);
        let chunks = 104u64;
        let n_blocks = 4u64;
        assert_eq!(r.activity.construct_adds, chunks * n_blocks * 121 * 8);
        // queries = m × chunks × n_blocks
        assert_eq!(r.activity.wbuf_read_bytes, 1080 * chunks * n_blocks);
    }

    #[test]
    fn dram_traffic_at_least_weights_once() {
        let g = Gemm::new(3200, 3200, 1024);
        let r = simulate_gemm(&cfg(), ExecMode::Ternary, g);
        let min_weights = (3200u64 * 3200) / 5; // 1.6 b/w = 1 B / 5 weights
        assert!(r.activity.dram_read_bytes >= min_weights);
    }

    #[test]
    fn stationarity_changes_traffic() {
        let g = Gemm::new(3200, 3200, 1024);
        let mut totals = std::collections::BTreeMap::new();
        for order in Stationarity::ALL {
            let mut c = cfg();
            c.tiling.order = order;
            let r = simulate_gemm(&c, ExecMode::Ternary, g);
            totals.insert(order.label(), r.activity.dram_total_bytes());
        }
        let vals: Vec<u64> = totals.values().copied().collect();
        assert!(vals.iter().any(|&v| v != vals[0]), "orders all equal: {totals:?}");
    }
}
