//! Link tables and deterministic routing for the [`super`] interconnect
//! simulator.
//!
//! A [`Graph`] is built once per [`super::NetSim`]: every physical link
//! becomes one *directed* entry in a dense link table (so the two
//! directions of a full-duplex link never contend with each other), and
//! routing is a pure function of `(topology, src, dst)` — no adaptive or
//! load-dependent choices, which is what keeps the event timeline
//! deterministic and pool-size invariant.
//!
//! Routes per topology:
//!
//! * `ring` — shortest direction around the cycle; an exact tie between
//!   the two directions goes clockwise (ascending ids), so the choice
//!   is deterministic.
//! * `mesh2d` — dimension-order (XY) routing: correct the column first,
//!   then the row.  Deadlock-free and the standard NoC baseline.
//! * `fattree` — up-down routing through the lowest common ancestor of
//!   a complete binary tree whose leaves are the replicas.  Links fatten
//!   toward the root (bandwidth multiplier doubles per level), the
//!   textbook fat-tree bisection story.

use super::Topology;
use std::collections::BTreeMap;

/// One directed link `from → to`.  `bw_mult` scales the base per-link
/// bandwidth (1.0 everywhere except fat-tree upper levels).
#[derive(Debug, Clone, Copy)]
pub(crate) struct Link {
    pub bw_mult: f64,
}

/// The static link table + routing function for one topology instance.
/// Node ids `0..chips` are replica endpoints; the fat tree adds internal
/// switch nodes, but [`Graph::route`] always takes *replica* indices.
#[derive(Debug, Clone)]
pub(crate) struct Graph {
    topology: Topology,
    chips: usize,
    /// mesh2d factorization (rows, cols); `None` for other topologies.
    mesh: Option<(usize, usize)>,
    pub links: Vec<Link>,
    index: BTreeMap<(usize, usize), usize>,
}

/// Most-square factorization `rows × cols` of `chips` with both factors
/// ≥ 2 (`rows ≤ cols`).  `None` means the count cannot form a 2-D mesh
/// (primes, 1, 2) — callers turn that into a loud validation error.
pub(crate) fn mesh_dims(chips: usize) -> Option<(usize, usize)> {
    let mut r = (chips as f64).sqrt().floor() as usize;
    while r >= 2 {
        if chips % r == 0 {
            return Some((r, chips / r));
        }
        r -= 1;
    }
    None
}

impl Graph {
    /// Build the link table.  Assumes `topology.validate(chips)` passed;
    /// a single chip yields an empty (linkless) graph for any topology.
    pub fn build(topology: Topology, chips: usize) -> Graph {
        let mut g = Graph { topology, chips, mesh: None, links: Vec::new(), index: BTreeMap::new() };
        match topology {
            Topology::Ring => {
                for i in 0..chips {
                    let next = (i + 1) % chips;
                    if next != i {
                        g.add_link(i, next, 1.0);
                        g.add_link(next, i, 1.0);
                    }
                }
            }
            Topology::Mesh2d => {
                let (rows, cols) = mesh_dims(chips).unwrap_or((1, chips.max(1)));
                g.mesh = Some((rows, cols));
                for r in 0..rows {
                    for c in 0..cols {
                        let v = r * cols + c;
                        if c + 1 < cols {
                            g.add_link(v, v + 1, 1.0);
                            g.add_link(v + 1, v, 1.0);
                        }
                        if r + 1 < rows {
                            g.add_link(v, v + cols, 1.0);
                            g.add_link(v + cols, v, 1.0);
                        }
                    }
                }
            }
            Topology::FatTree => {
                // Complete binary tree in heap order: internal nodes
                // 0..chips-1, leaves chips-1..2·chips-1; replica i is
                // tree node chips-1+i.  The link from a node at height h
                // (leaves: h = 0) to its parent carries multiplier 2^h.
                if chips > 1 {
                    let depth = chips.trailing_zeros();
                    for v in 1..2 * chips - 1 {
                        let parent = (v - 1) / 2;
                        let dv = usize::BITS - 1 - (v + 1).leading_zeros();
                        let mult = (1u64 << (depth - dv)) as f64;
                        g.add_link(v, parent, mult);
                        g.add_link(parent, v, mult);
                    }
                }
            }
        }
        g
    }

    /// The deterministic route from replica `src` to replica `dst` as a
    /// sequence of link indices (empty when `src == dst`).
    pub fn route(&self, src: usize, dst: usize) -> Vec<usize> {
        assert!(
            src < self.chips && dst < self.chips,
            "route endpoints must be replica indices < {}",
            self.chips
        );
        if src == dst {
            return Vec::new();
        }
        let mut out = Vec::new();
        match self.topology {
            Topology::Ring => {
                let n = self.chips;
                let fwd = (dst + n - src) % n;
                // shortest direction; exact tie goes clockwise
                let step = if fwd <= n - fwd { 1 } else { n - 1 };
                let mut cur = src;
                while cur != dst {
                    let next = (cur + step) % n;
                    out.push(self.link(cur, next));
                    cur = next;
                }
            }
            Topology::Mesh2d => {
                let (_, cols) = self.mesh.expect("mesh dims set at build");
                let (mut r, mut c) = (src / cols, src % cols);
                let (dr, dc) = (dst / cols, dst % cols);
                while c != dc {
                    let nc = if dc > c { c + 1 } else { c - 1 };
                    out.push(self.link(r * cols + c, r * cols + nc));
                    c = nc;
                }
                while r != dr {
                    let nr = if dr > r { r + 1 } else { r - 1 };
                    out.push(self.link(r * cols + c, nr * cols + c));
                    r = nr;
                }
            }
            Topology::FatTree => {
                // leaves share a depth, so the two climbs to the lowest
                // common ancestor stay in lockstep
                let (mut a, mut b) = (self.chips - 1 + src, self.chips - 1 + dst);
                let mut down = Vec::new();
                while a != b {
                    let (pa, pb) = ((a - 1) / 2, (b - 1) / 2);
                    out.push(self.link(a, pa));
                    down.push(self.link(pb, b));
                    a = pa;
                    b = pb;
                }
                out.extend(down.into_iter().rev());
            }
        }
        out
    }

    fn add_link(&mut self, from: usize, to: usize, bw_mult: f64) {
        if let std::collections::btree_map::Entry::Vacant(e) = self.index.entry((from, to)) {
            e.insert(self.links.len());
            self.links.push(Link { bw_mult });
        }
    }

    fn link(&self, from: usize, to: usize) -> usize {
        *self.index.get(&(from, to)).expect("routes step only along constructed links")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mesh_dims_most_square_or_none() {
        assert_eq!(mesh_dims(4), Some((2, 2)));
        assert_eq!(mesh_dims(6), Some((2, 3)));
        assert_eq!(mesh_dims(12), Some((3, 4)));
        assert_eq!(mesh_dims(9), Some((3, 3)));
        for bad in [1usize, 2, 3, 5, 7, 11, 13] {
            assert_eq!(mesh_dims(bad), None, "{bad} has no r×c (both ≥ 2) factorization");
        }
    }

    #[test]
    fn ring_routes_take_the_short_way() {
        let g = Graph::build(Topology::Ring, 6);
        assert_eq!(g.route(0, 0).len(), 0);
        assert_eq!(g.route(0, 1).len(), 1);
        assert_eq!(g.route(0, 5).len(), 1, "backward is shorter");
        assert_eq!(g.route(0, 3).len(), 3, "exact tie routes clockwise");
        assert_eq!(g.route(4, 1).len(), 3);
        // the two directions of one physical link are distinct entries
        assert_ne!(g.route(0, 1), g.route(1, 0));
    }

    #[test]
    fn mesh_routes_are_dimension_order_manhattan() {
        // 6 chips → 2×3: node = row·3 + col
        let g = Graph::build(Topology::Mesh2d, 6);
        assert_eq!(g.route(0, 5).len(), 3, "(0,0)→(1,2) is |Δc|+|Δr|");
        assert_eq!(g.route(0, 4).len(), 2);
        assert_eq!(g.route(3, 2).len(), 3);
        // column corrected first: 0→4 shares its first link with 0→1
        assert_eq!(g.route(0, 4)[0], g.route(0, 1)[0]);
    }

    #[test]
    fn fattree_routes_climb_to_the_lca() {
        let g = Graph::build(Topology::FatTree, 8);
        assert_eq!(g.route(0, 1).len(), 2, "siblings meet one level up");
        assert_eq!(g.route(0, 2).len(), 4);
        assert_eq!(g.route(0, 4).len(), 6, "opposite halves meet at the root");
        assert_eq!(g.route(0, 7).len(), 6);
        // upper links are fatter: the root-adjacent link of an 8-leaf
        // tree carries 4× the leaf-link bandwidth
        let top = g.route(0, 4)[2];
        let leaf = g.route(0, 4)[0];
        assert_eq!(g.links[leaf].bw_mult, 1.0);
        assert_eq!(g.links[top].bw_mult, 4.0);
    }

    #[test]
    fn single_chip_graphs_are_linkless() {
        for t in Topology::ALL {
            let g = Graph::build(t, 1);
            assert!(g.links.is_empty());
            assert!(g.route(0, 0).is_empty());
        }
    }
}
