//! Event-driven multi-chip interconnect simulator (S21) — the
//! topology-aware replacement for [`crate::engine::Sharded`]'s
//! closed-form `max + link + hops` interconnect term.
//!
//! Platinum's 0.96 mm² positioning implies *many* chips per deployment,
//! and an analytic gather term cannot see the three effects that decide
//! whether a topology scales: **link contention** (two stripes crossing
//! the same link serialize), **route length** (a mesh corner pays more
//! hops than its neighbor), and **compute/communication overlap** (a
//! replica's stripe starts moving the moment *its* shard finishes, not
//! when the slowest one does).  This module prices all three with a
//! deterministic discrete-event simulation:
//!
//! * [`Topology`] — `ring`, `mesh2d` (dimension-order routing over the
//!   most-square `r×c` factorization), `fattree` (up-down routing over
//!   a complete binary tree, links fattening 2× per level toward the
//!   root).  Replica-count validation is loud: a prime count cannot be
//!   a mesh, a non-power-of-two cannot be a fat tree.
//! * [`NetSim`] — the event engine.  Each [`Transfer`] is routed
//!   store-and-forward over its links; a link serializes at
//!   `bytes / (base_bw · bw_mult)` and is FIFO-owned while doing so
//!   (later messages queue), while the per-hop propagation `hop_s` adds
//!   latency without occupying the link.  The engine is a binary heap
//!   of `(time, seq)` events — ties break on insertion order, times are
//!   compared as raw non-negative f64 bits — so one input always yields
//!   one byte-identical [`NetReport`], independent of thread pools or
//!   wall clocks (the serving determinism contract).
//!
//! Calibration rides the same env knobs as the analytic model:
//! `PLATINUM_LINK_GBPS` is the per-link base bandwidth and
//! `PLATINUM_HOP_US` the per-hop propagation, both via
//! [`crate::engine::Interconnect::from_env`] at composition time.
//!
//! Guidance: the analytic term and the event timeline agree to within a
//! few percent on contention-free patterns (pinned in tests), so for
//! quick sweeps the analytic model is fine; reach for `net=` when the
//! pattern is congested (all-to-all, many-to-one gathers at high
//! replica counts) or when comparing topologies — that is where the two
//! models diverge by design (pinned at >1.5× on an all-to-all ring).

mod graph;

use anyhow::{bail, Result};
use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// The replica-graph shape simulated by [`NetSim`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Topology {
    /// Bidirectional cycle; shortest-direction routing (ties clockwise).
    Ring,
    /// 2-D mesh over the most-square `r×c` factorization (both ≥ 2);
    /// dimension-order (XY) routing.
    Mesh2d,
    /// Complete binary fat tree over a power-of-two leaf count; up-down
    /// routing, link bandwidth doubling per level toward the root.
    FatTree,
}

impl Topology {
    pub const ALL: [Topology; 3] = [Topology::Ring, Topology::Mesh2d, Topology::FatTree];

    pub fn label(&self) -> &'static str {
        match self {
            Topology::Ring => "ring",
            Topology::Mesh2d => "mesh2d",
            Topology::FatTree => "fattree",
        }
    }

    /// Parse a grammar token (`ring`/`mesh2d`/`fattree`).
    pub fn parse(s: &str) -> Option<Topology> {
        Topology::ALL.into_iter().find(|t| t.label() == s)
    }

    /// Check that `chips` replicas can form this topology; the error
    /// names the constraint and the offending count.  A single chip is
    /// trivially valid everywhere (linkless graph, pass-through).
    pub fn validate(&self, chips: usize) -> Result<()> {
        if chips == 0 {
            bail!("topology {} needs at least one chip", self.label());
        }
        match self {
            Topology::Ring => Ok(()),
            Topology::Mesh2d => {
                if chips == 1 || graph::mesh_dims(chips).is_some() {
                    Ok(())
                } else {
                    bail!(
                        "mesh2d needs a rectangular replica count (r x c, both >= 2): \
                         {chips} has no such factorization (try 4, 6, 8, 9, 12, ...)"
                    )
                }
            }
            Topology::FatTree => {
                if chips.is_power_of_two() {
                    Ok(())
                } else {
                    bail!("fattree needs a power-of-two replica count, got {chips}")
                }
            }
        }
    }

    /// Human-readable shape at a given replica count, e.g. `2x3 mesh`.
    pub fn shape(&self, chips: usize) -> String {
        match self {
            Topology::Ring => format!("{chips}-chip ring"),
            Topology::Mesh2d => match graph::mesh_dims(chips) {
                Some((r, c)) => format!("{r}x{c} mesh"),
                None => format!("{chips}-chip mesh"),
            },
            Topology::FatTree => format!("{chips}-leaf fat tree"),
        }
    }
}

/// One message on the network: `bytes` from replica `src` to replica
/// `dst`, becoming ready to inject at absolute time `start_s`.
#[derive(Debug, Clone, Copy)]
pub struct Transfer {
    pub src: usize,
    pub dst: usize,
    pub bytes: f64,
    pub start_s: f64,
}

/// Outcome of one [`NetSim::simulate`] timeline.
#[derive(Debug, Clone, Default)]
pub struct NetReport {
    /// Latest arrival time across all transfers (absolute; 0 if none).
    pub makespan_s: f64,
    /// Per-transfer arrival time at its destination, input order.
    pub finish_s: Vec<f64>,
    /// Summed time messages spent queued behind busy links — the
    /// contention the analytic model cannot see (0 ⇒ contention-free).
    pub queue_wait_s: f64,
    /// Worst single queueing wait on any hop.
    pub max_queue_wait_s: f64,
}

/// Heap event: message `msg` is ready to enter hop `hop` of its route at
/// time `f64::from_bits(t_bits)`.  Non-negative f64 bit patterns order
/// like the values, and `seq` (global insertion order) breaks ties, so
/// `Ord` is total and deterministic.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
struct Ev {
    t_bits: u64,
    seq: u64,
    msg: u32,
    hop: u32,
}

/// A deterministic discrete-event simulator of one topology instance.
/// See the module docs for the link/contention model.
#[derive(Debug, Clone)]
pub struct NetSim {
    topology: Topology,
    chips: usize,
    link_bytes_per_s: f64,
    hop_s: f64,
    graph: graph::Graph,
}

impl NetSim {
    /// Validates the (topology, count) pair and the calibration values;
    /// all failures are loud errors naming the offending input.
    pub fn new(
        topology: Topology,
        chips: usize,
        link_bytes_per_s: f64,
        hop_s: f64,
    ) -> Result<NetSim> {
        topology.validate(chips)?;
        if !link_bytes_per_s.is_finite() || link_bytes_per_s <= 0.0 {
            bail!("net link bandwidth must be positive and finite, got {link_bytes_per_s}");
        }
        if !hop_s.is_finite() || hop_s < 0.0 {
            bail!("net hop latency must be non-negative and finite, got {hop_s}");
        }
        let graph = graph::Graph::build(topology, chips);
        Ok(NetSim { topology, chips, link_bytes_per_s, hop_s, graph })
    }

    pub fn topology(&self) -> Topology {
        self.topology
    }

    pub fn chips(&self) -> usize {
        self.chips
    }

    /// Route length in links between two replicas.
    pub fn hops(&self, src: usize, dst: usize) -> usize {
        self.graph.route(src, dst).len()
    }

    /// The contention-blind price of one message: the sum over its route
    /// of serialization + propagation, as if it had the network to
    /// itself.  Equal to `simulate(&[t]).makespan_s - t.start_s` for a
    /// single transfer; the gap between `max(solo)` and a simulated
    /// makespan is exactly the congestion the event model adds.
    pub fn solo_latency_s(&self, src: usize, dst: usize, bytes: f64) -> f64 {
        self.graph
            .route(src, dst)
            .iter()
            .map(|&l| bytes.max(0.0) / self.link_bw(l) + self.hop_s)
            .sum()
    }

    fn link_bw(&self, link: usize) -> f64 {
        self.link_bytes_per_s * self.graph.links[link].bw_mult
    }

    /// Run the event timeline for a set of transfers.  Store-and-forward
    /// per hop: a message entering a link at `t` starts serializing at
    /// `max(t, link_free)`, holds the link for `bytes/bw`, and arrives
    /// at the next node `hop_s` later.  Links are FIFO in ready-time
    /// order (ties by injection order).  Pure function of its inputs.
    pub fn simulate(&self, transfers: &[Transfer]) -> NetReport {
        let routes: Vec<Vec<usize>> = transfers
            .iter()
            .map(|t| {
                assert!(
                    t.src < self.chips && t.dst < self.chips,
                    "transfer endpoints must be replica indices < {}",
                    self.chips
                );
                self.graph.route(t.src, t.dst)
            })
            .collect();
        let mut free = vec![0.0f64; self.graph.links.len()];
        let mut finish = vec![0.0f64; transfers.len()];
        let mut heap: BinaryHeap<Reverse<Ev>> = BinaryHeap::new();
        let mut seq: u64 = 0;
        for (i, t) in transfers.iter().enumerate() {
            let start = if t.start_s.is_finite() && t.start_s > 0.0 { t.start_s } else { 0.0 };
            heap.push(Reverse(Ev { t_bits: start.to_bits(), seq, msg: i as u32, hop: 0 }));
            seq += 1;
        }
        let (mut queue_wait, mut max_wait) = (0.0f64, 0.0f64);
        while let Some(Reverse(ev)) = heap.pop() {
            let t = f64::from_bits(ev.t_bits);
            let route = &routes[ev.msg as usize];
            if ev.hop as usize == route.len() {
                finish[ev.msg as usize] = t;
                continue;
            }
            let link = route[ev.hop as usize];
            let start = t.max(free[link]);
            let wait = start - t;
            queue_wait += wait;
            max_wait = max_wait.max(wait);
            let ser = transfers[ev.msg as usize].bytes.max(0.0) / self.link_bw(link);
            free[link] = start + ser;
            let arrive = start + ser + self.hop_s;
            heap.push(Reverse(Ev { t_bits: arrive.to_bits(), seq, msg: ev.msg, hop: ev.hop + 1 }));
            seq += 1;
        }
        let makespan_s = finish.iter().copied().fold(0.0f64, f64::max);
        NetReport {
            makespan_s,
            finish_s: finish,
            queue_wait_s: queue_wait,
            max_queue_wait_s: max_wait,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const BW: f64 = 16e9;
    const HOP: f64 = 1e-6;

    fn net(t: Topology, chips: usize) -> NetSim {
        NetSim::new(t, chips, BW, HOP).unwrap()
    }

    #[test]
    fn topology_labels_roundtrip() {
        for t in Topology::ALL {
            assert_eq!(Topology::parse(t.label()), Some(t));
        }
        assert_eq!(Topology::parse("torus"), None);
        assert_eq!(Topology::parse("Ring"), None, "grammar tokens are lowercase");
    }

    #[test]
    fn validation_is_loud_and_specific() {
        assert!(Topology::Ring.validate(1).is_ok());
        assert!(Topology::Ring.validate(7).is_ok());
        assert!(Topology::Mesh2d.validate(6).is_ok());
        assert!(Topology::FatTree.validate(8).is_ok());
        for t in Topology::ALL {
            assert!(t.validate(0).is_err());
            assert!(t.validate(1).is_ok(), "one chip is trivially valid on {}", t.label());
        }
        let msg = Topology::Mesh2d.validate(7).unwrap_err().to_string();
        assert!(msg.contains("mesh2d") && msg.contains('7'), "{msg}");
        let msg = Topology::FatTree.validate(6).unwrap_err().to_string();
        assert!(msg.contains("power-of-two") && msg.contains('6'), "{msg}");
        // calibration junk is rejected at construction
        assert!(NetSim::new(Topology::Ring, 4, 0.0, HOP).is_err());
        assert!(NetSim::new(Topology::Ring, 4, BW, -1.0).is_err());
        assert!(NetSim::new(Topology::Ring, 4, f64::NAN, HOP).is_err());
    }

    #[test]
    fn shapes_read_naturally() {
        assert_eq!(Topology::Mesh2d.shape(6), "2x3 mesh");
        assert_eq!(Topology::Ring.shape(4), "4-chip ring");
        assert_eq!(Topology::FatTree.shape(8), "8-leaf fat tree");
    }

    #[test]
    fn solo_latency_matches_single_message_simulation() {
        for t in Topology::ALL {
            let n = net(t, 4);
            for dst in 1..4 {
                let solo = n.solo_latency_s(0, dst, 1e6);
                let rep = n.simulate(&[Transfer { src: 0, dst, bytes: 1e6, start_s: 0.0 }]);
                assert!(
                    (rep.makespan_s - solo).abs() < 1e-15,
                    "{}: solo {solo} vs sim {}",
                    t.label(),
                    rep.makespan_s
                );
                assert_eq!(rep.queue_wait_s, 0.0, "one message never queues");
            }
        }
    }

    #[test]
    fn fattree_upper_links_are_fatter() {
        let n = net(Topology::FatTree, 8);
        // 6 hops to the opposite half, but the upper links serialize at
        // 2× and 4×: total serialization is 3.5·bytes/bw, not 6×
        let bytes = 8e6;
        let expect = bytes / BW * (1.0 + 0.5 + 0.25 + 0.25 + 0.5 + 1.0) + 6.0 * HOP;
        let got = n.solo_latency_s(0, 4, bytes);
        assert!((got - expect).abs() < 1e-15, "got {got} expect {expect}");
    }

    #[test]
    fn contending_messages_serialize_on_a_shared_link() {
        let n = net(Topology::Ring, 4);
        let bytes = 16e6;
        let ser = bytes / BW; // 1 ms
        // both messages leave node 0 clockwise at t=0 → the 0→1 link is
        // the bottleneck; injection order breaks the tie
        let rep = n.simulate(&[
            Transfer { src: 0, dst: 1, bytes, start_s: 0.0 },
            Transfer { src: 0, dst: 1, bytes, start_s: 0.0 },
        ]);
        assert!((rep.finish_s[0] - (ser + HOP)).abs() < 1e-12);
        assert!((rep.finish_s[1] - (2.0 * ser + HOP)).abs() < 1e-12);
        assert!((rep.queue_wait_s - ser).abs() < 1e-12, "second message waits one serialization");
        assert_eq!(rep.max_queue_wait_s, rep.queue_wait_s);
    }

    #[test]
    fn propagation_does_not_occupy_the_link() {
        let n = net(Topology::Ring, 4);
        let bytes = 16e6;
        let ser = bytes / BW;
        // the second message becomes ready exactly when the first ends
        // serialization: the link is free even though the first message
        // is still propagating (hop_s) → zero queueing
        let rep = n.simulate(&[
            Transfer { src: 0, dst: 1, bytes, start_s: 0.0 },
            Transfer { src: 0, dst: 1, bytes, start_s: ser },
        ]);
        assert_eq!(rep.queue_wait_s, 0.0);
        assert!((rep.finish_s[1] - (2.0 * ser + HOP)).abs() < 1e-12);
    }

    #[test]
    fn disjoint_links_run_concurrently() {
        let n = net(Topology::Ring, 4);
        let bytes = 16e6;
        let solo = n.solo_latency_s(0, 1, bytes);
        // clockwise 0→1 and counter-clockwise 0→3 share no directed link
        let rep = n.simulate(&[
            Transfer { src: 0, dst: 1, bytes, start_s: 0.0 },
            Transfer { src: 0, dst: 3, bytes, start_s: 0.0 },
        ]);
        assert_eq!(rep.queue_wait_s, 0.0);
        assert!((rep.makespan_s - solo).abs() < 1e-12, "no shared link ⇒ no slowdown");
    }

    #[test]
    fn all_to_all_congestion_diverges_from_contention_blind_model() {
        // the satellite pin: under an all-to-all pattern the event
        // timeline must exceed max(solo latencies) by well over 1.5×
        let n = net(Topology::Ring, 8);
        let bytes = 4e6;
        let mut transfers = Vec::new();
        let mut blind = 0.0f64;
        for s in 0..8 {
            for d in 0..8 {
                if s != d {
                    transfers.push(Transfer { src: s, dst: d, bytes, start_s: 0.0 });
                    blind = blind.max(n.solo_latency_s(s, d, bytes));
                }
            }
        }
        let rep = n.simulate(&transfers);
        assert!(rep.queue_wait_s > 0.0);
        let ratio = rep.makespan_s / blind;
        assert!(ratio > 1.5, "all-to-all ring congestion ratio {ratio} must exceed 1.5");
    }

    #[test]
    fn timeline_is_deterministic_and_pure() {
        let n = net(Topology::Mesh2d, 6);
        let transfers: Vec<Transfer> = (0..6)
            .flat_map(|s| (0..6).filter(move |d| *d != s))
            .zip(0..)
            .map(|(d, i)| Transfer {
                src: i % 6,
                dst: d,
                bytes: 1e5 * (i + 1) as f64,
                start_s: 1e-7 * i as f64,
            })
            .collect();
        let a = n.simulate(&transfers);
        let b = n.simulate(&transfers);
        let bits = |r: &NetReport| r.finish_s.iter().map(|f| f.to_bits()).collect::<Vec<u64>>();
        assert_eq!(bits(&a), bits(&b), "same input ⇒ bit-identical timeline");
        assert_eq!(a.makespan_s.to_bits(), b.makespan_s.to_bits());
        assert_eq!(a.queue_wait_s.to_bits(), b.queue_wait_s.to_bits());
    }

    #[test]
    fn empty_and_self_transfers_are_trivial() {
        let n = net(Topology::Ring, 4);
        let rep = n.simulate(&[]);
        assert_eq!(rep.makespan_s, 0.0);
        let rep = n.simulate(&[Transfer { src: 2, dst: 2, bytes: 1e9, start_s: 0.25 }]);
        assert_eq!(rep.finish_s, vec![0.25], "self-transfer arrives at its start time");
        assert_eq!(rep.queue_wait_s, 0.0);
    }
}
