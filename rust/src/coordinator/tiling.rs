//! Tiled loop-nest scheduler (§IV-C): produces the exact dispatch
//! sequence the accelerator executes and accounts the DRAM transfers per
//! step — the same walk the simulator prices, exposed as a plan so the
//! serving layer, the DSE, and the tests all share one source of truth.

use crate::analysis::Gemm;
use crate::config::{Stationarity, Tiling};

/// One tile dispatch: origin + extent + which buffers must be (re)filled.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TileStep {
    pub m0: usize,
    pub k0: usize,
    pub n0: usize,
    pub mt: usize,
    pub kt: usize,
    pub nt: usize,
    /// Weight tile changed → DMA weights (m_t × k_t packed bytes).
    pub load_weights: bool,
    /// Input tile changed → DMA activations (k_t × n_t bytes).
    pub load_inputs: bool,
    /// Output tile completes after this step → write back.
    pub store_outputs: bool,
    /// Partial sums must spill (k is not innermost).
    pub spill_partials: bool,
}

/// A complete dispatch plan for one GEMM.
#[derive(Debug, Clone)]
pub struct DispatchPlan {
    pub gemm: Gemm,
    pub tiling: Tiling,
    pub steps: Vec<TileStep>,
}

impl DispatchPlan {
    /// Build the plan: walk tile origins in the stationarity order,
    /// tracking which operand tiles change between steps.
    pub fn build(g: Gemm, t: Tiling) -> DispatchPlan {
        let ms: Vec<usize> = (0..g.m).step_by(t.m).collect();
        let ks: Vec<usize> = (0..g.k).step_by(t.k).collect();
        let ns: Vec<usize> = (0..g.n).step_by(t.n).collect();
        let k_inner = matches!(t.order, Stationarity::Mnk | Stationarity::Nmk);

        let mut triples: Vec<(usize, usize, usize)> = Vec::new();
        macro_rules! walk {
            ($a:expr, $b:expr, $c:expr, $f:expr) => {
                for &x in $a {
                    for &y in $b {
                        for &z in $c {
                            triples.push($f(x, y, z));
                        }
                    }
                }
            };
        }
        match t.order {
            Stationarity::Mnk => walk!(&ms, &ns, &ks, |m, n, k| (m, k, n)),
            Stationarity::Mkn => walk!(&ms, &ks, &ns, |m, k, n| (m, k, n)),
            Stationarity::Nmk => walk!(&ns, &ms, &ks, |n, m, k| (m, k, n)),
            Stationarity::Nkm => walk!(&ns, &ks, &ms, |n, k, m| (m, k, n)),
            Stationarity::Kmn => walk!(&ks, &ms, &ns, |k, m, n| (m, k, n)),
            Stationarity::Knm => walk!(&ks, &ns, &ms, |k, n, m| (m, k, n)),
        }

        let mut steps = Vec::with_capacity(triples.len());
        let mut prev_mk: Option<(usize, usize)> = None;
        let mut prev_kn: Option<(usize, usize)> = None;
        for (m0, k0, n0) in triples {
            let mt = t.m.min(g.m - m0);
            let kt = t.k.min(g.k - k0);
            let nt = t.n.min(g.n - n0);
            let last_k = k0 + kt >= g.k;
            steps.push(TileStep {
                m0,
                k0,
                n0,
                mt,
                kt,
                nt,
                load_weights: prev_mk != Some((m0, k0)),
                load_inputs: prev_kn != Some((k0, n0)),
                store_outputs: if k_inner { last_k } else { true },
                spill_partials: !k_inner,
            });
            prev_mk = Some((m0, k0));
            prev_kn = Some((k0, n0));
        }
        DispatchPlan { gemm: g, tiling: t, steps }
    }

    /// Total DRAM read bytes (weights at `wbits` b/w + int8 inputs +
    /// partial-sum reloads).
    pub fn dram_read_bytes(&self, wbits: f64) -> u64 {
        let mut total = 0u64;
        let mut first_k_seen = std::collections::HashSet::new();
        for s in &self.steps {
            if s.load_weights {
                total += ((s.mt * s.kt) as f64 * wbits / 8.0).ceil() as u64;
            }
            if s.load_inputs {
                total += (s.kt * s.nt) as u64;
            }
            if s.spill_partials && !first_k_seen.insert((s.m0, s.n0)) {
                total += (s.mt * s.nt * 4) as u64;
            }
        }
        total
    }

    /// Total DRAM write bytes (outputs once, or 4-byte partials per step).
    pub fn dram_write_bytes(&self) -> u64 {
        self.steps
            .iter()
            .map(|s| {
                if s.spill_partials {
                    (s.mt * s.nt * 4) as u64
                } else if s.store_outputs {
                    (s.mt * s.nt) as u64
                } else {
                    0
                }
            })
            .sum()
    }

    /// Every output element is covered exactly ⌈K/k_t⌉ times (validity).
    pub fn validate_coverage(&self) -> bool {
        let g = self.gemm;
        let kt_tiles = g.k.div_ceil(self.tiling.k);
        let mut cover = vec![0u32; g.m.div_ceil(self.tiling.m) * g.n.div_ceil(self.tiling.n)];
        let nt_tiles = g.n.div_ceil(self.tiling.n);
        for s in &self.steps {
            let mi = s.m0 / self.tiling.m;
            let ni = s.n0 / self.tiling.n;
            cover[mi * nt_tiles + ni] += 1;
        }
        cover.iter().all(|&c| c == kt_tiles as u32)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Tiling;

    fn g() -> Gemm {
        Gemm::new(3200, 3200, 1024)
    }

    #[test]
    fn plan_covers_all_tiles_every_order() {
        for order in Stationarity::ALL {
            let t = Tiling { order, ..Tiling::default() };
            let plan = DispatchPlan::build(g(), t);
            assert!(plan.validate_coverage(), "{order:?}");
            let expect =
                3200usize.div_ceil(1080) * 3200usize.div_ceil(520) * 1024usize.div_ceil(32);
            assert_eq!(plan.steps.len(), expect);
        }
    }

    #[test]
    fn mnk_loads_weights_per_k_step_but_writes_outputs_once() {
        let plan = DispatchPlan::build(g(), Tiling::default());
        let stores = plan.steps.iter().filter(|s| s.store_outputs).count();
        let out_tiles = 3200usize.div_ceil(1080) * 1024usize.div_ceil(32);
        assert_eq!(stores, out_tiles);
        assert!(plan.steps.iter().all(|s| !s.spill_partials));
    }

    #[test]
    fn kmn_spills_partials() {
        let t = Tiling { order: Stationarity::Kmn, ..Tiling::default() };
        let plan = DispatchPlan::build(g(), t);
        assert!(plan.steps.iter().all(|s| s.spill_partials));
        // spilling orders move strictly more DRAM than the mnk default
        let mnk = DispatchPlan::build(g(), Tiling::default());
        assert!(
            plan.dram_write_bytes() > mnk.dram_write_bytes() * 4,
            "kmn {} vs mnk {}",
            plan.dram_write_bytes(),
            mnk.dram_write_bytes()
        );
    }

    #[test]
    fn mkn_reuses_weights() {
        // weight-stationary order: weights loaded exactly once per (m,k)
        let t = Tiling { order: Stationarity::Mkn, ..Tiling::default() };
        let plan = DispatchPlan::build(g(), t);
        let weight_loads = plan.steps.iter().filter(|s| s.load_weights).count();
        assert_eq!(weight_loads, 3200usize.div_ceil(1080) * 3200usize.div_ceil(520));
    }

    #[test]
    fn edge_tiles_clipped() {
        let plan = DispatchPlan::build(Gemm::new(1100, 530, 40), Tiling::default());
        let last = plan.steps.iter().find(|s| s.m0 == 1080).unwrap();
        assert_eq!(last.mt, 20);
        assert!(plan.validate_coverage());
    }
}
