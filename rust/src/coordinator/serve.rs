//! Serving layer (S12): a batching request loop for the end-to-end
//! examples, shaped like an edge-LLM serving frontend.
//!
//! **Superseded for load evaluation by [`crate::traffic`]** — this
//! one-shot synchronous batch loop has no notion of request arrival
//! over time, admission, or tail latency.  It is kept as a working
//! shim for the PJRT examples, and its [`Executor`] implementations
//! (notably [`GoldenExecutor`]) remain the functional substrate the
//! continuous-batching scheduler executes through via
//! [`crate::traffic::ExecutorBridge`]; new serving work should target
//! `traffic::Scheduler`.
//!
//! Requests (token sequences) arrive on a channel; the batcher groups
//! them into accelerator-friendly batches (multiples of n_cols = 8, the
//! paper's decode granularity), runs the functional forward through a
//! pluggable [`Executor`] (PJRT artifacts in the examples,
//! [`GoldenExecutor`] for the pooled golden datapath), and attaches
//! accelerator timing/energy from a pluggable engine backend — the
//! classic functional + performance model split, or, with the measured
//! `platinum-cpu` pricer, one fast substrate serving both roles.
//!
//! Because the pricer is any [`Backend`], a **sharded multi-chip
//! pricer** (`Registry::build("sharded:4:platinum-ternary")`) drops in
//! unchanged: batch pricing then reflects N chips splitting the
//! dispatch (max-replica latency + interconnect, summed energy), which
//! is how the serving layer models scale-out deployments.

use crate::analysis::Gemm;
use crate::config::{ExecMode, PlatinumConfig};
use crate::encoding::{pack_ternary, PackedTernary};
use crate::engine::{Backend, PlatinumBackend, Workload};
use crate::lut::ternary_mpgemm;
use std::collections::VecDeque;
use std::sync::mpsc;
use std::time::{Duration, Instant};

/// One inference request.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    /// Sequence of token embeddings (flattened seq × d_model f32).
    pub x: Vec<f32>,
    pub seq: usize,
    pub arrived: Instant,
}

/// Completed response.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub y: Vec<f32>,
    /// Wall-clock latency of the functional execution.
    pub wall: Duration,
    /// Simulated accelerator latency for this request's share.
    pub sim_latency_s: f64,
    pub sim_energy_j: f64,
    /// Queueing delay before the batch launched.
    pub queue_delay: Duration,
}

/// Pluggable functional executor: given a batch of (seq × d) inputs,
/// produce outputs of the same shape.  (Deliberately not `Send`: the
/// PJRT executable handle is a raw pointer; the server owns it on one
/// thread and producers talk to it over channels.)
pub trait Executor {
    /// Feature dimension the executor expects.
    fn d_model(&self) -> usize;
    /// Run a batch: `xs` is a slice of per-request inputs.
    fn run(&mut self, xs: &[&[f32]], seq: usize) -> anyhow::Result<Vec<Vec<f32>>>;
    /// GEMMs executed per request forward (for simulation pricing).
    fn gemms(&self, seq: usize) -> Vec<Gemm>;
}

/// Batching policy.
#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    /// Max requests per batch.
    pub max_batch: usize,
    /// How long the batcher waits for more requests before launching.
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(2) }
    }
}

/// Serving statistics.
#[derive(Debug, Default, Clone)]
pub struct ServeStats {
    pub completed: u64,
    pub batches: u64,
    pub total_wall: Duration,
    pub total_queue: Duration,
    pub sim_latency_s: f64,
    pub sim_energy_j: f64,
}

impl ServeStats {
    pub fn mean_batch_size(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.completed as f64 / self.batches as f64
        }
    }
}

/// The serving coordinator: single-threaded batch loop (the accelerator
/// is one device; concurrency lives in the request producers).
///
/// Timing/energy pricing goes through a pluggable
/// [`engine::Backend`](crate::engine::Backend) — Platinum by default,
/// any registered system via [`Server::with_backend`].
pub struct Server<E: Executor> {
    exec: E,
    pricer: Box<dyn Backend>,
    policy: BatchPolicy,
    pub stats: ServeStats,
}

impl<E: Executor> Server<E> {
    /// Price on the cycle-accurate Platinum model at `cfg` (ternary).
    pub fn new(exec: E, cfg: PlatinumConfig, policy: BatchPolicy) -> Self {
        Server::with_backend(
            exec,
            Box::new(PlatinumBackend::with_config(cfg, ExecMode::Ternary)),
            policy,
        )
    }

    /// Price on an arbitrary engine backend.
    pub fn with_backend(exec: E, pricer: Box<dyn Backend>, policy: BatchPolicy) -> Self {
        Server { exec, pricer, policy, stats: ServeStats::default() }
    }

    /// Price one request batch's GEMMs on the engine backend.  Energy
    /// is 0 when the pricer doesn't model it (measured CPU backends);
    /// latency is always real.
    fn price(&self, seq: usize, batch: usize) -> (f64, f64) {
        // the batch shares the N dimension: one dispatch serves all
        let gemms: Vec<Gemm> = self
            .exec
            .gemms(seq)
            .iter()
            .map(|g| Gemm::new(g.m, g.k, g.n * batch))
            .collect();
        let r = self.pricer.run(&Workload::Batch(gemms));
        (r.latency_s, r.energy_j.unwrap_or(0.0))
    }

    /// Drain the channel until it closes, batching and executing.
    /// Responses are pushed to `out`.
    pub fn run(
        &mut self,
        rx: mpsc::Receiver<Request>,
        out: &mut Vec<Response>,
    ) -> anyhow::Result<()> {
        let mut pending: VecDeque<Request> = VecDeque::new();
        let mut open = true;
        while open || !pending.is_empty() {
            // fill the batch window
            let deadline = Instant::now() + self.policy.max_wait;
            while open && pending.len() < self.policy.max_batch {
                let timeout = deadline.saturating_duration_since(Instant::now());
                match rx.recv_timeout(timeout) {
                    Ok(r) => pending.push_back(r),
                    Err(mpsc::RecvTimeoutError::Timeout) => break,
                    Err(mpsc::RecvTimeoutError::Disconnected) => {
                        open = false;
                    }
                }
            }
            if pending.is_empty() {
                continue;
            }
            // group by equal sequence length (static-shape artifacts)
            let seq = pending.front().unwrap().seq;
            let take: Vec<Request> = {
                let mut batch = Vec::new();
                let mut rest = VecDeque::new();
                while let Some(r) = pending.pop_front() {
                    if r.seq == seq && batch.len() < self.policy.max_batch {
                        batch.push(r);
                    } else {
                        rest.push_back(r);
                    }
                }
                pending = rest;
                batch
            };
            let launch = Instant::now();
            let xs: Vec<&[f32]> = take.iter().map(|r| r.x.as_slice()).collect();
            let t0 = Instant::now();
            let ys = self.exec.run(&xs, seq)?;
            let wall = t0.elapsed();
            let (sim_lat, sim_en) = self.price(seq, take.len());
            self.stats.batches += 1;
            for (req, y) in take.into_iter().zip(ys) {
                self.stats.completed += 1;
                self.stats.total_wall += wall;
                let qd = launch.duration_since(req.arrived);
                self.stats.total_queue += qd;
                self.stats.sim_latency_s += sim_lat / self.exec_batch_share();
                self.stats.sim_energy_j += sim_en / self.exec_batch_share();
                out.push(Response {
                    id: req.id,
                    y,
                    wall,
                    sim_latency_s: sim_lat,
                    sim_energy_j: sim_en,
                    queue_delay: qd,
                });
            }
        }
        Ok(())
    }

    fn exec_batch_share(&self) -> f64 {
        self.policy.max_batch as f64
    }
}

/// Functional [`Executor`] running one BitLinear layer through the
/// golden ternary datapath ([`crate::lut::ternary_mpgemm`]) on the
/// worker pool — the serving loop's fast CPU substrate.  Pair it with
/// the measured `platinum-cpu` pricer and the functional execution and
/// the latency pricing finally share one implementation; pair it with
/// `platinum-ternary` for the classic functional + cycle-model split.
///
/// Inputs are quantized to the int8 grid (×127), run exactly, and
/// dequantized — mirroring BitNet's activation quantization.
pub struct GoldenExecutor {
    packed: PackedTernary,
    d: usize,
    m: usize,
    cfg: PlatinumConfig,
}

impl GoldenExecutor {
    /// Wrap a ternary weight matrix (row-major m × d).
    pub fn new(w: &[i8], m: usize, d: usize, cfg: PlatinumConfig) -> Self {
        let c = cfg.c_ternary;
        GoldenExecutor { packed: pack_ternary(w, m, d, c), d, m, cfg }
    }

    /// Output feature count.
    pub fn d_out(&self) -> usize {
        self.m
    }
}

impl Executor for GoldenExecutor {
    fn d_model(&self) -> usize {
        self.d
    }

    fn run(&mut self, xs: &[&[f32]], seq: usize) -> anyhow::Result<Vec<Vec<f32>>> {
        let n = xs.len() * seq;
        // quantize to int8 grid, run the golden datapath, dequantize
        let mut acts = vec![0i32; self.d * n];
        for (r, x) in xs.iter().enumerate() {
            for s in 0..seq {
                for f in 0..self.d {
                    let col = r * seq + s;
                    acts[f * n + col] = (x[s * self.d + f] * 127.0).round() as i32;
                }
            }
        }
        let (y, _) = ternary_mpgemm(&self.cfg, &self.packed, &acts, n);
        Ok(xs
            .iter()
            .enumerate()
            .map(|(r, _)| {
                let mut o = vec![0f32; seq * self.m];
                for s in 0..seq {
                    for mm in 0..self.m {
                        let col = r * seq + s;
                        o[s * self.m + mm] = y[mm * n + col] as f32 / 127.0;
                    }
                }
                o
            })
            .collect())
    }

    fn gemms(&self, seq: usize) -> Vec<Gemm> {
        vec![Gemm::new(self.m, self.d, seq)]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Random-weight [`GoldenExecutor`] with d inputs and m outputs.
    fn golden_exec(d: usize, m: usize) -> GoldenExecutor {
        let mut rng = Rng::seed_from(11);
        let w = rng.ternary_vec(m * d);
        GoldenExecutor::new(&w, m, d, PlatinumConfig::default())
    }

    #[test]
    fn serves_batched_requests() {
        let exec = golden_exec(40, 16);
        let mut server = Server::new(
            exec,
            PlatinumConfig::default(),
            BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(1) },
        );
        let (tx, rx) = mpsc::channel();
        let mut rng = Rng::seed_from(3);
        for id in 0..10u64 {
            let x: Vec<f32> = (0..40).map(|_| (rng.f64() as f32 - 0.5)).collect();
            tx.send(Request { id, x, seq: 1, arrived: Instant::now() }).unwrap();
        }
        drop(tx);
        let mut out = Vec::new();
        server.run(rx, &mut out).unwrap();
        assert_eq!(out.len(), 10);
        assert_eq!(server.stats.completed, 10);
        assert!(server.stats.batches <= 10);
        assert!(server.stats.mean_batch_size() >= 1.0);
        assert!(out.iter().all(|r| r.y.len() == 16 && r.sim_latency_s > 0.0));
    }

    #[test]
    fn pricing_backend_is_pluggable() {
        // same functional path, priced on a baseline instead of Platinum
        let exec = golden_exec(24, 8);
        let mut server = Server::with_backend(
            exec,
            Box::new(crate::engine::EyerissBackend),
            BatchPolicy { max_batch: 2, max_wait: Duration::from_millis(1) },
        );
        let (tx, rx) = mpsc::channel();
        let mut rng = Rng::seed_from(5);
        for id in 0..4u64 {
            let x: Vec<f32> = (0..24).map(|_| (rng.f64() as f32 - 0.5)).collect();
            tx.send(Request { id, x, seq: 1, arrived: Instant::now() }).unwrap();
        }
        drop(tx);
        let mut out = Vec::new();
        server.run(rx, &mut out).unwrap();
        assert_eq!(out.len(), 4);
        assert!(out.iter().all(|r| r.sim_latency_s > 0.0 && r.sim_energy_j > 0.0));
    }

    #[test]
    fn batches_execute_through_measured_platinum_cpu() {
        // functional execution AND pricing both run the golden datapath:
        // the pricer is the measured platinum-cpu backend, so
        // sim_latency is real wall-clock of the same substrate (energy
        // deliberately 0: the measured backend reports it unmodelled)
        let exec = golden_exec(30, 12);
        let pricer = crate::engine::Registry::with_defaults().build("platinum-cpu").unwrap();
        let mut server = Server::with_backend(
            exec,
            pricer,
            BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(1) },
        );
        let (tx, rx) = mpsc::channel();
        let mut rng = Rng::seed_from(7);
        for id in 0..6u64 {
            let x: Vec<f32> = (0..30).map(|_| (rng.f64() as f32 - 0.5)).collect();
            tx.send(Request { id, x, seq: 1, arrived: Instant::now() }).unwrap();
        }
        drop(tx);
        let mut out = Vec::new();
        server.run(rx, &mut out).unwrap();
        assert_eq!(out.len(), 6);
        assert!(out.iter().all(|r| r.y.len() == 12));
        assert!(out.iter().all(|r| r.sim_latency_s > 0.0), "measured latency must be real");
        assert!(out.iter().all(|r| r.sim_energy_j == 0.0), "unmodelled energy prices as 0");
    }

    #[test]
    fn sharded_pricer_prices_batches_below_single_chip() {
        // the multi-chip composite drops in as a pricer unchanged.
        // Shapes are deep in k (d=1040) so the row-sharded compute
        // saving dominates the modelled interconnect gather (which
        // scales with output bytes m·n only).
        let run_with = |pricer: Box<dyn Backend>| -> f64 {
            let (d, m, seq) = (1040, 2080, 8);
            let exec = golden_exec(d, m);
            let mut server = Server::with_backend(
                exec,
                pricer,
                BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(5) },
            );
            let (tx, rx) = mpsc::channel();
            let mut rng = Rng::seed_from(13);
            for id in 0..4u64 {
                let x: Vec<f32> = (0..seq * d).map(|_| (rng.f64() as f32 - 0.5)).collect();
                tx.send(Request { id, x, seq, arrived: Instant::now() }).unwrap();
            }
            drop(tx);
            let mut out = Vec::new();
            server.run(rx, &mut out).unwrap();
            assert_eq!(out.len(), 4);
            assert!(out.iter().all(|r| r.sim_latency_s > 0.0 && r.sim_energy_j > 0.0));
            out[0].sim_latency_s
        };
        let reg = crate::engine::Registry::with_defaults();
        let single = run_with(reg.build("platinum-ternary").unwrap());
        let sharded = run_with(reg.build("sharded:4:platinum-ternary").unwrap());
        assert!(sharded < single, "4-chip pricer must beat 1 chip: {sharded} vs {single}");
    }

    #[test]
    fn batching_reduces_batches() {
        // with a generous wait window all 8 requests coalesce
        let exec = golden_exec(20, 8);
        let mut server = Server::new(
            exec,
            PlatinumConfig::default(),
            BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(50) },
        );
        let (tx, rx) = mpsc::channel();
        for id in 0..8u64 {
            tx.send(Request {
                id,
                x: vec![0.1; 20],
                seq: 1,
                arrived: Instant::now(),
            })
            .unwrap();
        }
        drop(tx);
        let mut out = Vec::new();
        server.run(rx, &mut out).unwrap();
        assert_eq!(server.stats.batches, 1, "all requests should share one batch");
    }
}
