//! L3 coordinator (S6, S12): the system layer that owns dispatch.
//!
//! * [`tiling`] — the tiled loop-nest scheduler: turns a GEMM and a
//!   [`crate::config::Tiling`] into an ordered dispatch plan with exact
//!   DRAM traffic accounting (the same walk the simulator charges).
//! * [`serve`] — a batching request server for the end-to-end examples:
//!   requests arrive, a batcher groups them to the accelerator's n_cols
//!   granularity, the functional result is produced through the PJRT
//!   artifacts (or the golden model), and timing/energy comes from the
//!   cycle-accurate simulator — the standard performance-model +
//!   functional-model split of architecture evaluation.

pub mod serve;
pub mod tiling;

pub use tiling::{DispatchPlan, TileStep};
