//! BitNet b1.58 model zoo (§V-A "Model and Kernel Extraction").
//!
//! The paper extracts the (K, M) feature dimensions of every BitLinear
//! layer in the b1.58 suite {700M (b1.58-l), 1.3B (b1.58-xl), 3B} and
//! varies N (batch × sequence) for prefill (N=1024) and decode (N=8).
//! The architecture hyper-parameters below follow the public BitNet
//! b1.58 reproductions (LLaMA-shaped: fused-less QKV/out projections and
//! a gated FFN with 8/3·h inner width, rounded to hardware-friendly
//! multiples).

use crate::analysis::Gemm;

/// Bytes per cached K/V element.  Activations flow through the datapath
/// as int8 (§III quantization), so the KV cache stores one byte per
/// element — unlike the 1.58 b weights, K/V are *computed* values.
pub const KV_DTYPE_BYTES: usize = 1;

/// Architecture description of one BitNet b1.58 model.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BitNetModel {
    pub name: &'static str,
    pub params: &'static str,
    pub hidden: usize,
    pub ffn: usize,
    pub heads: usize,
    pub kv_heads: usize,
    pub layers: usize,
}

/// The three evaluated models (public b1.58 suite shapes).
pub const B158_700M: BitNetModel = BitNetModel {
    name: "b1.58-l",
    params: "700M",
    hidden: 1536,
    ffn: 4096,
    heads: 16,
    kv_heads: 16,
    layers: 24,
};

pub const B158_1_3B: BitNetModel = BitNetModel {
    name: "b1.58-xl",
    params: "1.3B",
    hidden: 2048,
    ffn: 5460,
    heads: 32,
    kv_heads: 32,
    layers: 24,
};

pub const B158_3B: BitNetModel = BitNetModel {
    name: "b1.58-3B",
    params: "3B",
    hidden: 3200,
    ffn: 8640,
    heads: 32,
    kv_heads: 32,
    layers: 26,
};

pub const ALL_MODELS: [BitNetModel; 3] = [B158_700M, B158_1_3B, B158_3B];

/// Paper's evaluation batch·seq products.
pub const PREFILL_N: usize = 1024;
pub const DECODE_N: usize = 8;

/// One extracted BitLinear kernel (weights M×K) with an occurrence count
/// per transformer layer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Kernel {
    pub name: &'static str,
    pub m: usize,
    pub k: usize,
    /// Instances per layer (e.g. Q, K, V are three m=h,k=h kernels).
    pub count: usize,
}

impl BitNetModel {
    /// The distinct BitLinear kernels of one transformer layer.
    ///
    /// LLaMA-shaped BitNet block: Wq/Wk/Wv (h→h), Wo (h→h),
    /// W_gate/W_up (h→ffn), W_down (ffn→h).
    pub fn kernels(&self) -> Vec<Kernel> {
        vec![
            Kernel { name: "qkv", m: self.hidden, k: self.hidden, count: 3 },
            Kernel { name: "out", m: self.hidden, k: self.hidden, count: 1 },
            Kernel { name: "gate_up", m: self.ffn, k: self.hidden, count: 2 },
            Kernel { name: "down", m: self.hidden, k: self.ffn, count: 1 },
        ]
    }

    /// Unique (m, k) kernel shapes for kernel-level evaluation (Fig 8/9).
    pub fn unique_shapes(&self) -> Vec<(usize, usize)> {
        let mut shapes: Vec<(usize, usize)> = self
            .kernels()
            .iter()
            .map(|kr| (kr.m, kr.k))
            .collect();
        shapes.sort_unstable();
        shapes.dedup();
        shapes
    }

    /// All GEMMs of a full forward pass at batch·seq = n
    /// (kernel × count × layers).
    pub fn model_gemms(&self, n: usize) -> Vec<(Gemm, usize)> {
        self.kernels()
            .iter()
            .map(|kr| (Gemm::new(kr.m, kr.k, n), kr.count * self.layers))
            .collect()
    }

    /// Total naive additions for one forward pass at batch·seq = n —
    /// the paper's op normalization for GOP/s (Table I footnote ‡).
    pub fn total_naive_adds(&self, n: usize) -> u64 {
        self.model_gemms(n)
            .iter()
            .map(|(g, cnt)| g.naive_adds() * *cnt as u64)
            .sum()
    }

    /// Attention head dimension (uniform across Q and KV heads).
    pub fn head_dim(&self) -> usize {
        self.hidden / self.heads
    }

    /// KV-cache bytes one token pins across the whole layer stack:
    /// K and V planes × kv_heads × head_dim × dtype × layers.  Single
    /// source of truth for the paged allocator and SRAM-sizing DSE.
    pub fn kv_bytes_per_token(&self) -> u64 {
        (2 * self.kv_heads * self.head_dim() * KV_DTYPE_BYTES * self.layers) as u64
    }

    /// Ternary weight bytes of one layer stack at 1.6 b/w.
    pub fn weight_bytes_ternary(&self) -> u64 {
        let per_layer: u64 = self
            .kernels()
            .iter()
            .map(|kr| (kr.m * kr.k * kr.count) as u64)
            .sum();
        per_layer * self.layers as u64 / 5 // 1 byte per 5 weights
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parameter_counts_are_plausible() {
        // BitLinear params ≈ advertised scale (within 2×; embeddings and
        // norms excluded).
        for (model, lo, hi) in [
            (B158_700M, 0.3e9, 1.4e9),
            (B158_1_3B, 0.6e9, 2.6e9),
            (B158_3B, 1.5e9, 6.0e9),
        ] {
            let p: u64 = model
                .kernels()
                .iter()
                .map(|kr| (kr.m * kr.k * kr.count) as u64)
                .sum::<u64>()
                * model.layers as u64;
            assert!(
                (p as f64) > lo && (p as f64) < hi,
                "{}: {}B params",
                model.name,
                p as f64 / 1e9
            );
        }
    }

    #[test]
    fn three_b_kernel_dims_match_paper_tiling() {
        // the chosen tile m=1080 divides into 8640 (ffn) and k=520·c
        // grouping covers 3200 — the §IV-A claim that L=52 "facilitates
        // tiling for BitNet-b1.58 models".
        assert_eq!(B158_3B.hidden, 3200);
        assert_eq!(B158_3B.ffn, 8640);
        assert_eq!(B158_3B.ffn % 1080, 0);
        // k=520 → 104 chunks of 5 → exactly 2 rounds of 52 PPEs
        assert_eq!(520 / 5 % 52, 0);
    }

    #[test]
    fn kernel_extraction_counts() {
        let ks = B158_3B.kernels();
        assert_eq!(ks.iter().map(|k| k.count).sum::<usize>(), 7);
        assert_eq!(B158_3B.unique_shapes().len(), 3); // h→h, h→ffn, ffn→h
    }

    #[test]
    fn kv_bytes_per_token_pins_the_suite() {
        // 3B: 2 planes × 32 kv_heads × (3200/32) head_dim × 1 B × 26 layers
        assert_eq!(B158_3B.head_dim(), 100);
        assert_eq!(B158_3B.kv_bytes_per_token(), 166_400);
        // 700M: 2 × 16 × 96 × 1 × 24
        assert_eq!(B158_700M.head_dim(), 96);
        assert_eq!(B158_700M.kv_bytes_per_token(), 73_728);
        // 1.3B: 2 × 32 × 64 × 1 × 24
        assert_eq!(B158_1_3B.kv_bytes_per_token(), 98_304);
        // a 2k-token context stays far below the ternary weight
        // footprint for every model — KV is DRAM-resident, weights too
        for m in ALL_MODELS {
            assert!(2048 * m.kv_bytes_per_token() < 2 * m.weight_bytes_ternary(), "{}", m.name);
        }
    }

    #[test]
    fn prefill_ops_scale() {
        let total = B158_3B.total_naive_adds(PREFILL_N);
        // ~2 × params × N: 3B-ish params × 1024 ≈ 2-6 T adds
        assert!(total > 1e12 as u64 && total < 1e13 as u64, "{total}");
        assert_eq!(
            B158_3B.total_naive_adds(DECODE_N) * (PREFILL_N / DECODE_N) as u64,
            total
        );
    }
}
