// Same clippy posture as lib.rs (CI gates on `clippy -- -D warnings`):
// index-form loops and wide argument lists are deliberate style here.
#![allow(clippy::needless_range_loop, clippy::too_many_arguments)]

//! `platinum` CLI — the leader entrypoint of the L3 coordinator.
//!
//! Subcommands:
//!   simulate   — run a kernel or model pass on any engine backend
//!   report     — area / power / utilization breakdowns (E5, E6, E11)
//!   dse        — the Fig 7 tiling sweep
//!   paths      — generate + inspect offline build paths (ISA dump)
//!   baselines  — Table I cross-system comparison via the engine registry
//!   backends   — list registered engine backends
//!   serve-bench — continuous-batching load run with TTFT/TPOT percentiles
//!   serve      — long-running HTTP/1.1 daemon over the same scheduler
//!   runtime    — list / smoke-run the PJRT artifacts
//!
//! Execution goes through `engine::Registry`/`engine::Backend`: pick a
//! system with `--backend <id>` and emit machine-readable unified
//! reports with `--json`.

use anyhow::{anyhow, bail, Result};
use platinum::analysis::Gemm;
use platinum::config::{PlatinumConfig, Tiling};
use platinum::energy::{AreaModel, EnergyTable};
use platinum::engine::{
    Backend, PlatinumBackend, Registry, Report, Workload, COMPARISON_IDS, SHARDED_GRAMMAR,
};
use platinum::fault::{FaultPlan, ResilienceConfig};
use platinum::kv::{KvConfig, KvPolicy};
use platinum::models::{ALL_MODELS, B158_3B, DECODE_N, PREFILL_N};
use platinum::runtime::{HostTensor, Runtime};
use platinum::server::{self, ServeOptions};
use platinum::sim::net::Topology;
use platinum::sim::DramModelKind;
use platinum::traffic::{
    parse_trace_records, with_shared_prefix, ArrivalPattern, Clock, LenDist, LoadSpec, Scheduler,
    SchedulerConfig, TenantMix, TraceRecord, TrafficRequest, VirtualClock, WallClock,
};
use platinum::util::cli;
use platinum::util::env as envknob;
use platinum::util::json::{arr, num, obj, s, Json};
use platinum::{dse, encoding, isa, pathgen};

fn main() -> Result<()> {
    let args = cli::parse(std::env::args().skip(1))?;
    match args.command.as_deref() {
        Some("simulate") => cmd_simulate(&args),
        Some("report") => cmd_report(&args),
        Some("dse") => cmd_dse(&args),
        Some("paths") => cmd_paths(&args),
        Some("baselines") => cmd_baselines(&args),
        Some("backends") => cmd_backends(&args),
        Some("serve-bench") => cmd_serve_bench(&args),
        Some("serve") => cmd_serve(&args),
        Some("runtime") => cmd_runtime(&args),
        Some(other) => bail!("unknown command {other:?}; run without args for help"),
        None => {
            print_help();
            Ok(())
        }
    }
}

fn print_help() {
    println!(
        "platinum — path-adaptable LUT-based accelerator (paper reproduction)\n\
         \n\
         USAGE: platinum <command> [flags]\n\
         \n\
         COMMANDS:\n\
           simulate   --model {{700m|1.3b|3b}} --n <batch·seq> [--mode ternary|bitserial]\n\
                      or --m --k --n for a single kernel;\n\
                      [--backend <id>] runs any registered system, [--json] emits the report\n\
                      [--threads <t>] caps the worker pool (overrides PLATINUM_THREADS)\n\
                      (--mode bitserial ≡ --backend platinum-bitserial: k retiled to 728)\n\
           report     --area --power --util   breakdowns vs paper §V-B  [--json]\n\
           dse        [--full] [--replicas <list>] [--topology <list>]\n\
                      Fig 7 tiling sweep (× chip count × interconnect:\n\
                      ring,mesh2d,fattree,analytic)\n\
           paths      [--kind ternary|binary] [--c <chunk>] [--dump] ISA dump\n\
           baselines  [--backend <ids|all>] [--json] [--threads <t>]\n\
                      Table I comparison on b1.58-3B\n\
           backends   list engine backend ids with specs\n\
           serve-bench --backend <id> --rate <rps> --pattern poisson|burst|replay\n\
                      [--model {{700m|1.3b|3b}}] [--requests <n>] [--seed <n>]\n\
                      [--prompt-tokens <n|lo:hi>] [--output-tokens <n|lo:hi>]\n\
                      [--trace <file>] [--clock virtual|wall] [--json]\n\
                      [--max-batch <n>] [--max-queue <n>] [--max-inflight-tokens <n>]\n\
                      [--max-prefill-tokens <n>] [--step-overhead-us <f>] [--threads <t>]\n\
                      [--kv-block <tok>] [--kv-sram-kb <n>] [--kv-dram-mb <n>]\n\
                      [--kv-policy swap|recompute] [--no-prefix-cache]\n\
                      [--dram-model pipe|bank] [--shared-prefix <tok>]\n\
                      [--faults <plan>] deterministic fault injection, e.g.\n\
                      \"straggler:r1:p0.05:x8,linkdeg:0.2:4gbps,swapfail:p0.01,crash:r2@t=1.5s\"\n\
                      [--deadline-ms <f>] [--retries <n>] [--retry-base-ms <f>]\n\
                      [--retry-cap-ms <f>] [--brownout-queue <n>]\n\
                      [--brownout-slack-ms <f | class:ms,...>] global slack, or\n\
                      per-class e.g. \"interactive:50,batch:500\" (classes from\n\
                      --tenants; looser slack sheds first under brownout)\n\
                      [--tenants <name:share[:wN],...>] SLO-class mix with weighted\n\
                      fair queueing, e.g. \"interactive:0.7:w4,batch:0.3:w1\"\n\
                      (per-class TTFT/TPOT/E2E/goodput in a `classes` section)\n\
                      [--prefill-chunk <tok>] chunked prefill: prompts larger than\n\
                      the chunk interleave with decode steps (0 = off)\n\
                      continuous-batching load run: TTFT/TPOT/E2E percentiles,\n\
                      batch/queue series, paged-KV block/prefix-cache stats,\n\
                      goodput vs offered load; under faults/SLO flags the\n\
                      metrics grow a `resilience` section (availability,\n\
                      timeout/retry/failover/shed counters, p99 deltas)\n\
           serve      [--addr <host:port>] [--max-conns <n>] [--backend <id>]\n\
                      [--model {{700m|1.3b|3b}}] [--capture <file>] [--metrics-out <file>]\n\
                      [+ the serve-bench scheduler/KV/SLO flags]\n\
                      std-only HTTP/1.1 daemon: POST /v1/generate streams chunked\n\
                      ndjson tokens (X-Deadline-Ms sets a per-request deadline,\n\
                      X-Tenant-Class tags the SLO class: interactive|batch|0-3),\n\
                      GET /health + /metrics, POST /shutdown or SIGTERM drains\n\
                      gracefully; --capture records live arrivals as a replay\n\
                      trace (env: PLATINUM_ADDR, PLATINUM_MAX_CONNS)\n\
           runtime    [--artifacts <dir>] [--run <name>] PJRT artifacts\n\
         \n\
         BACKENDS (see `platinum backends`):\n\
           platinum-ternary, platinum-bitserial, eyeriss, prosperity, tmac,\n\
           tmac-cpu, platinum-cpu (measured on this host; energy reported null);\n\
           multi-chip composites:\n\
           sharded:<replicas>[:rows|batch|layers][:net=ring|mesh2d|fattree]:<inner-id>\n\
           (e.g. --backend sharded:4:platinum-ternary; net= prices dispatches on an\n\
           event-driven topology timeline with link contention instead of the\n\
           analytic interconnect term)"
    );
}

fn model_by_name(name: &str) -> Result<&'static platinum::models::BitNetModel> {
    let lname = name.to_ascii_lowercase();
    ALL_MODELS
        .iter()
        .find(|m| {
            m.params.eq_ignore_ascii_case(&lname)
                || m.name.eq_ignore_ascii_case(&lname)
                || (lname == "3b" && m.params == "3B")
                || (lname == "700m" && m.params == "700M")
                || (lname == "1.3b" && m.params == "1.3B")
        })
        .ok_or_else(|| anyhow!("unknown model {name:?} (700m, 1.3b, 3b)"))
}

/// Apply `--threads <t>` by overriding `PLATINUM_THREADS` before the
/// global worker pool is first touched (the pool is created lazily on
/// first hot-path use, which is always after flag parsing).  The flag
/// wins over an inherited env var.
fn apply_threads_flag(args: &cli::Args) -> Result<()> {
    if let Some(t) = args.get("threads") {
        let n: usize = t
            .parse()
            .ok()
            .filter(|&n| n >= 1)
            .ok_or_else(|| anyhow!("--threads expects a positive integer, got {t:?}"))?;
        std::env::set_var("PLATINUM_THREADS", n.to_string());
    }
    Ok(())
}

/// Map `--mode` to the registry-identical Platinum backend, so
/// `--mode bitserial` and `--backend platinum-bitserial` produce the
/// same configuration (and therefore the same numbers).
fn platinum_from_mode(args: &cli::Args) -> Result<PlatinumBackend> {
    match args.get_str("mode", "ternary") {
        "ternary" => Ok(PlatinumBackend::ternary()),
        "bitserial" => Ok(PlatinumBackend::bitserial()),
        other => bail!("unknown --mode {other:?}; valid modes: ternary, bitserial"),
    }
}

fn cmd_simulate(args: &cli::Args) -> Result<()> {
    apply_threads_flag(args)?;
    let backend: Box<dyn Backend> = match args.get("backend") {
        Some(id) => {
            if args.get("mode").is_some() {
                bail!(
                    "--mode only applies to the default Platinum surface; \
                     with --backend, pick platinum-ternary or platinum-bitserial instead"
                );
            }
            Registry::with_defaults().build(id)?
        }
        // default surface: Platinum, with --mode selecting the
        // execution path (same config the registry ids construct)
        None => Box::new(platinum_from_mode(args)?),
    };
    let workload = if let Some(mname) = args.get("model") {
        let model = model_by_name(mname)?;
        Workload::model_pass(*model, args.get_usize("n", PREFILL_N)?)
    } else {
        let m = args.get_usize("m", 3200)?;
        let k = args.get_usize("k", 3200)?;
        let n = args.get_usize("n", PREFILL_N)?;
        Workload::Kernel(Gemm::new(m, k, n))
    };
    let r = backend.run(&workload);
    if args.flag("json") {
        println!("{}", r.to_json().to_string());
    } else {
        println!("{}  on {} ({})", r.workload, backend.describe().name, r.backend);
        print_report(&r);
    }
    Ok(())
}

fn print_report(r: &Report) {
    println!("  latency      {:>14.6} s", r.latency_s);
    println!("  throughput   {:>14.1} GOP/s (naive-adds)", r.throughput_gops);
    match (r.energy_j, r.power_w()) {
        (Some(e), Some(p)) => {
            println!("  energy       {:>14.4} J", e);
            println!("  power        {:>14.2} W", p);
        }
        (Some(e), None) => println!("  energy       {:>14.4} J", e),
        _ => println!("  energy           unmodelled  (ROADMAP: RAPL measurement)"),
    }
    println!("  ops          {:>14}", r.ops);
    if let Some(c) = r.cycles {
        println!("  cycles       {:>14}", c);
    }
    if let Some(p) = &r.phases {
        println!(
            "  phases: construct {} query {} drain {} dram-stall {}",
            p.construct, p.query, p.drain, p.dram_stall
        );
    }
    if let Some(u) = &r.utilization {
        println!(
            "  util: adders {:.1}%  lut-ports {:.1}%  dram {:.1}%",
            u.adders * 100.0,
            u.lut_ports * 100.0,
            u.dram_bw * 100.0
        );
    }
}

fn cmd_report(args: &cli::Args) -> Result<()> {
    let cfg = PlatinumConfig::default();
    let plat_backend = PlatinumBackend::ternary();
    let all = !(args.flag("area") || args.flag("power") || args.flag("util"));
    let json = args.flag("json");
    let mut out: Vec<(&str, Json)> = Vec::new();
    if args.flag("area") || all {
        let b = AreaModel::platinum(&cfg).breakdown();
        let t = b.total();
        if json {
            out.push((
                "area_mm2",
                obj(vec![
                    ("weight_buf", num(b.weight_buf)),
                    ("input_buf", num(b.input_buf)),
                    ("output_buf", num(b.output_buf)),
                    ("path_buf", num(b.path_buf)),
                    ("lut_bufs", num(b.lut_bufs)),
                    ("ppes", num(b.ppes)),
                    ("aggregator", num(b.aggregator)),
                    ("sfu", num(b.sfu)),
                    ("total", num(t)),
                ]),
            ));
        } else {
            println!(
                "== area breakdown (paper §V-B: 0.955 mm²; buffers 65%, +LUT 83.3%, compute 15%) =="
            );
            let pct = |part: f64| 100.0 * part / t;
            println!("  weight buffer   {:>7.4} mm²  {:>5.1}%", b.weight_buf, pct(b.weight_buf));
            println!("  input buffer    {:>7.4} mm²  {:>5.1}%", b.input_buf, pct(b.input_buf));
            println!("  output buffer   {:>7.4} mm²  {:>5.1}%", b.output_buf, pct(b.output_buf));
            println!("  path buffer     {:>7.4} mm²  {:>5.1}%", b.path_buf, pct(b.path_buf));
            println!("  LUT buffers     {:>7.4} mm²  {:>5.1}%", b.lut_bufs, pct(b.lut_bufs));
            println!("  PPEs            {:>7.4} mm²  {:>5.1}%", b.ppes, pct(b.ppes));
            println!("  aggregator      {:>7.4} mm²  {:>5.1}%", b.aggregator, pct(b.aggregator));
            println!("  SFU             {:>7.4} mm²  {:>5.1}%", b.sfu, pct(b.sfu));
            println!("  TOTAL           {t:>7.4} mm²   (paper: 0.955)");
            println!(
                "  data buffers {:.1}%  +LUT {:.1}%  compute {:.1}%",
                100.0 * b.data_buffers() / t,
                100.0 * (b.data_buffers() + b.lut_bufs) / t,
                100.0 * (b.ppes + b.aggregator) / t
            );
        }
    }
    if args.flag("power") || all {
        let r = plat_backend.run(&Workload::prefill(B158_3B));
        let e = r.energy_breakdown.expect("platinum model pass carries energy detail");
        if json {
            out.push((
                "power",
                obj(vec![
                    ("total_w", num(r.power_w().expect("platinum models energy"))),
                    ("dram_j", num(e.dram)),
                    ("weight_buf_j", num(e.weight_buf)),
                    ("input_buf_j", num(e.input_buf)),
                    ("output_buf_j", num(e.output_buf)),
                    ("lut_buf_j", num(e.lut_buf)),
                    ("path_buf_j", num(e.path_buf)),
                    ("adders_j", num(e.adders)),
                    ("static_leak_j", num(e.static_leak)),
                    ("total_j", num(e.total())),
                ]),
            ));
        } else {
            let t = e.total();
            println!(
                "== power breakdown, b1.58-3B prefill (§V-B: 3.2 W; DRAM 53.5%, wbuf 31.6%) =="
            );
            println!(
                "  total power     {:>7.2} W",
                r.power_w().expect("platinum models energy")
            );
            println!("  DRAM            {:>5.1}%", 100.0 * e.dram / t);
            println!("  weight buffer   {:>5.1}%", 100.0 * e.weight_buf / t);
            println!("  LUT buffers     {:>5.1}%", 100.0 * e.lut_buf / t);
            println!("  output buffer   {:>5.1}%", 100.0 * e.output_buf / t);
            println!("  input buffer    {:>5.1}%", 100.0 * e.input_buf / t);
            println!("  adders          {:>5.1}%", 100.0 * e.adders / t);
            println!("  static          {:>5.1}%", 100.0 * e.static_leak / t);
            let etab = EnergyTable::from_area(&AreaModel::platinum(&cfg));
            println!(
                "  (model: wbuf {:.1} pJ/B, LUT {:.1} pJ/B, DRAM {:.0} pJ/bit)",
                etab.wbuf_read_pj_per_byte, etab.lut_read_pj_per_byte, etab.dram_pj_per_bit
            );
        }
    }
    if args.flag("util") || all {
        let r = plat_backend.run(&Workload::Kernel(Gemm::new(1080, 520, 32)));
        let u = r.utilization.expect("platinum kernel carries utilization");
        if json {
            out.push((
                "util",
                obj(vec![
                    ("adders", num(u.adders)),
                    ("lut_ports", num(u.lut_ports)),
                    ("dram_bw", num(u.dram_bw)),
                ]),
            ));
        } else {
            println!(
                "== utilization, steady-state tile (paper §IV-B: adders 90.5%, LUT ports ~100%) =="
            );
            println!("  adders          {:>5.1}%", 100.0 * u.adders);
            println!("  LUT ports       {:>5.1}%", 100.0 * u.lut_ports);
        }
    }
    if json {
        println!("{}", obj(out).to_string());
    }
    Ok(())
}

fn cmd_dse(args: &cli::Args) -> Result<()> {
    let grid = dse::default_grid();
    let models: Vec<platinum::models::BitNetModel> =
        if args.flag("full") { ALL_MODELS.to_vec() } else { vec![B158_3B] };
    // `--replicas 1,2,4` crosses the tiling grid with multi-chip
    // sharding (rows strategy) — the scaling axis of the DSE
    let replicas: Vec<usize> = match args.get("replicas") {
        None => vec![1],
        Some(spec) => spec
            .split(',')
            .map(str::trim)
            .filter(|t| !t.is_empty())
            .map(|t| {
                t.parse::<usize>()
                    .ok()
                    .filter(|&n| n >= 1)
                    .ok_or_else(|| anyhow!("--replicas expects positive integers, got {t:?}"))
            })
            .collect::<Result<_>>()?,
    };
    if replicas.is_empty() {
        bail!("--replicas expects a comma-separated list of positive integers, e.g. 1,2,4");
    }
    // `--topology ring,mesh2d,fattree[,analytic]` crosses the sweep
    // with event-driven interconnect models ("which topology at N
    // chips"); the default is the analytic merge term alone
    let topologies: Vec<Option<Topology>> = match args.get("topology") {
        None => vec![None],
        Some(spec) => spec
            .split(',')
            .map(str::trim)
            .filter(|t| !t.is_empty())
            .map(|t| match t {
                "analytic" => Ok(None),
                _ => Topology::parse(t).map(Some).ok_or_else(|| {
                    anyhow!(
                        "--topology expects ring, mesh2d, fattree or analytic \
                         (comma-separated), got {t:?}"
                    )
                }),
            })
            .collect::<Result<_>>()?,
    };
    if topologies.is_empty() {
        bail!("--topology expects a comma-separated list, e.g. ring,mesh2d,fattree");
    }
    for (t, r, why) in dse::skipped_topology_pairs(&replicas, &topologies) {
        println!("note: skipping {} at {} chips: {why}", t.label(), r);
    }
    let pts = dse::sweep_topology(&grid, &replicas, &topologies, &models);
    let front = dse::pareto(&pts);
    println!(
        "== Fig 7 DSE: {} points ({} tilings × {} chip counts × {} interconnects), \
         {} on the Pareto frontier ==",
        pts.len(),
        grid.len(),
        replicas.len(),
        topologies.len(),
        front.len()
    );
    println!(
        "{:<22} {:>6} {:>9} {:>12} {:>12} {:>9} {:>9}  pareto",
        "tiling", "chips", "net", "latency(s)", "energy(J)", "mm²", "KB"
    );
    for (i, p) in pts.iter().enumerate() {
        let t = &p.tiling;
        let tag = format!("m{} k{} n{} {}", t.m, t.k, t.n, t.order.label());
        let chosen = p.tiling == Tiling::default() && p.replicas == 1 && p.topology.is_none();
        println!(
            "{:<22} {:>6} {:>9} {:>12.4} {:>12.3} {:>9.3} {:>9.0}  {}{}",
            tag,
            p.replicas,
            p.topology.map(|t| t.label()).unwrap_or("analytic"),
            p.latency_s,
            p.energy_j,
            p.area_mm2,
            p.sram_kb,
            if front.contains(&i) { "*" } else { "" },
            if chosen { "  <-- paper's choice" } else { "" }
        );
    }
    Ok(())
}

fn cmd_paths(args: &cli::Args) -> Result<()> {
    let kind = args.get_str("kind", "ternary");
    let path = match kind {
        "ternary" => pathgen::ternary_path(args.get_usize("c", encoding::TERNARY_C)?),
        "binary" => pathgen::binary_path(args.get_usize("c", encoding::BINARY_C)?),
        other => bail!("unknown path kind {other:?}"),
    };
    println!(
        "{kind} path c={}: {} entries, min RAW distance {} (pipeline depth {}), hazard-free: {}",
        path.c,
        path.entries.len(),
        path.min_raw_distance,
        pathgen::PIPELINE_DEPTH,
        path.hazard_free()
    );
    if args.flag("dump") {
        for (i, e) in path.entries.iter().enumerate() {
            println!(
                "{i:4}: LUT[{:3}] = LUT[{:3}] {} a[{}]   (word {:#010x})",
                e.dst,
                e.src,
                if e.sign { "-" } else { "+" },
                e.j,
                isa::encode_entry(e)
            );
        }
        println!("FINISH {:#010x}", isa::FINISH);
    }
    Ok(())
}

fn cmd_baselines(args: &cli::Args) -> Result<()> {
    apply_threads_flag(args)?;
    let registry = Registry::with_defaults();
    let backends = registry.build_selection(args.get_str("backend", COMPARISON_IDS))?;
    let json = args.flag("json");
    let mut rows: Vec<Json> = Vec::new();
    if !json {
        println!(
            "== Table I reproduction: b1.58-3B, prefill N={PREFILL_N} / decode N={DECODE_N} =="
        );
        println!(
            "{:<20} {:>8} {:>8} {:>14} {:>14}",
            "system", "PEs", "mm²", "prefill GOP/s", "decode GOP/s"
        );
    }
    for be in &backends {
        let info = be.describe();
        let pre = be.run(&Workload::prefill(B158_3B));
        let dec = be.run(&Workload::decode(B158_3B));
        if json {
            rows.push(pre.to_json());
            rows.push(dec.to_json());
        } else {
            let pes = info.pes.map(|p| p.to_string()).unwrap_or_else(|| "-".to_string());
            let area = info.area_mm2.map(|a| format!("{a:.3}")).unwrap_or_else(|| "-".to_string());
            println!(
                "{:<20} {:>8} {:>8} {:>14.1} {:>14.1}",
                info.name, pes, area, pre.throughput_gops, dec.throughput_gops
            );
        }
    }
    if json {
        println!("{}", arr(rows).to_string());
    } else {
        println!(
            "(paper Table I: Eyeriss 20.8, Prosperity 375, T-MAC 715, Platinum 1534 GOP/s prefill)"
        );
    }
    Ok(())
}

fn cmd_backends(args: &cli::Args) -> Result<()> {
    let registry = Registry::with_defaults();
    if args.flag("json") {
        let rows: Vec<Json> = registry
            .build_selection("all")?
            .iter()
            .map(|be| be.describe().to_json())
            .collect();
        println!("{}", arr(rows).to_string());
        return Ok(());
    }
    println!("{:<20} {:<18} {:>6} {:>10} {:>8}  notes", "id", "name", "kind", "freq MHz", "PEs");
    for be in registry.build_selection("all")? {
        let info = be.describe();
        println!(
            "{:<20} {:<18} {:>6} {:>10.0} {:>8}  {}",
            info.id,
            info.name,
            info.kind.label(),
            info.freq_hz / 1e6,
            info.pes.map(|p| p.to_string()).unwrap_or_else(|| "-".to_string()),
            info.notes
        );
    }
    println!(
        "\nmulti-chip composites: {SHARDED_GRAMMAR}\n\
         (latency = max over replicas + interconnect, energy = sum; nests recursively)"
    );
    Ok(())
}

/// `--tenants <name:share[:wN],...>` SLO-class mix shared by
/// `serve-bench` and `serve` — `None` when the flag is absent
/// (single-tenant legacy behaviour).
fn tenant_mix_from_args(args: &cli::Args) -> Result<Option<TenantMix>> {
    args.get("tenants").map(TenantMix::parse).transpose()
}

/// Scheduler / KV / SLO configuration shared by `serve-bench` and
/// `serve`: env (`PLATINUM_KV_*`) seeds the KV defaults, flags win; the
/// resilience knobs stay inert unless given, so a flagless run
/// serializes exactly as before the fault subsystem existed.
fn scheduler_config_from_args(args: &cli::Args) -> Result<SchedulerConfig> {
    let mut kv = KvConfig::from_env()?;
    kv.block_tokens = args.get_usize("kv-block", kv.block_tokens)?;
    kv.sram_kib = args.get_usize("kv-sram-kb", kv.sram_kib)?;
    kv.dram_mib = args.get_usize("kv-dram-mb", kv.dram_mib)?;
    if let Some(p) = args.get("kv-policy") {
        kv.policy = KvPolicy::parse(p)
            .ok_or_else(|| anyhow!("unknown --kv-policy {p:?}; valid: swap, recompute"))?;
    }
    if let Some(d) = args.get("dram-model") {
        kv.dram_model = DramModelKind::parse(d)
            .ok_or_else(|| anyhow!("unknown --dram-model {d:?}; valid: pipe, bank"))?;
    }
    kv.prefix_cache = !args.flag("no-prefix-cache");
    let deadline_s = match args.get("deadline-ms") {
        Some(_) => Some(args.get_f64("deadline-ms", 0.0)? * 1e-3),
        None => None,
    };
    let mix = tenant_mix_from_args(args)?;
    let mut resilience = ResilienceConfig {
        deadline_s,
        max_retries: args.get_usize("retries", 0)? as u32,
        retry_base_s: args.get_f64("retry-base-ms", 50.0)? * 1e-3,
        retry_cap_s: args.get_f64("retry-cap-ms", 1000.0)? * 1e-3,
        brownout_queue: args.get_usize("brownout-queue", 0)?,
        fault_seed: args.get_usize("seed", 0)? as u64,
        ..ResilienceConfig::default()
    };
    if let Some(spec) = args.get("brownout-slack-ms") {
        let lookup = |name: &str| mix.as_ref().and_then(|m| m.class_id(name)).map(|i| i as usize);
        resilience.set_brownout_slack_spec(spec, &lookup)?;
    }
    let mut cfg = SchedulerConfig {
        max_batch: args.get_usize("max-batch", 32)?,
        max_queue: args.get_usize("max-queue", 256)?,
        max_inflight_tokens: args.get_usize("max-inflight-tokens", 65_536)?,
        max_prefill_tokens: args.get_usize("max-prefill-tokens", 2048)?,
        step_overhead_s: args.get_f64("step-overhead-us", 0.0)? * 1e-6,
        kv,
        resilience,
        ..SchedulerConfig::default()
    };
    cfg.prefill_chunk = args.get_usize("prefill-chunk", 0)?;
    if let Some(mix) = mix {
        cfg.classes = mix.classes.len();
        cfg.class_weights = mix.weights();
    }
    Ok(cfg)
}

/// `--faults <plan>` clause grammar (S17), shared by `serve-bench` and
/// `serve`.
fn fault_plan_from_args(args: &cli::Args) -> Result<FaultPlan> {
    match args.get("faults") {
        Some(text) => FaultPlan::parse(text),
        None => Ok(FaultPlan::default()),
    }
}

/// `platinum serve`: the long-running daemon — identical scheduler and
/// flags as `serve-bench`, but wall-clock time and arrivals pushed by
/// live HTTP connections instead of a pre-materialized trace.
fn cmd_serve(args: &cli::Args) -> Result<()> {
    apply_threads_flag(args)?;
    let backend_id = args.get_str("backend", "platinum-ternary").to_string();
    // fail fast on a typo'd id rather than inside the scheduler thread
    Registry::with_defaults().build(&backend_id)?;
    let model = model_by_name(args.get_str("model", "700m"))?;
    let addr = match args.get("addr") {
        Some(a) => a.to_string(),
        None => envknob::read("PLATINUM_ADDR", "a host:port listen address", |t| {
            t.contains(':').then(|| t.to_string())
        })?
        .unwrap_or_else(|| "127.0.0.1:8080".to_string()),
    };
    let max_conns = match args.get("max-conns") {
        Some(_) => args.get_usize("max-conns", 0)?,
        None => envknob::positive_usize("PLATINUM_MAX_CONNS")?.unwrap_or(64),
    };
    if max_conns == 0 {
        bail!("--max-conns must be >= 1");
    }
    server::run(ServeOptions {
        addr,
        max_conns,
        capture: args.get("capture").map(String::from),
        metrics_out: args.get("metrics-out").map(String::from),
        backend_id,
        model: *model,
        cfg: scheduler_config_from_args(args)?,
        plan: fault_plan_from_args(args)?,
    })
}

/// `serve-bench`: generate a deterministic load trace, serve it through
/// the continuous-batching scheduler against any registered backend,
/// and report TTFT/TPOT/E2E percentiles, batch/queue series, and
/// goodput.  The default virtual clock makes the run a reproducible
/// discrete-event simulation (the measured backends still contribute
/// real kernel wall-clock as the per-step service time); `--clock wall`
/// paces arrivals in real time instead.
fn cmd_serve_bench(args: &cli::Args) -> Result<()> {
    apply_threads_flag(args)?;
    let backend = Registry::with_defaults().build(args.get_str("backend", "platinum-cpu"))?;
    let model = model_by_name(args.get_str("model", "700m"))?;
    let rate = args.get_f64("rate", 50.0)?;
    // a capture-v1 trace (`platinum serve --capture`) carries request
    // shapes, deadlines, and shared-prefix spans: replay it verbatim
    // instead of sampling
    let mut replay_records: Option<Vec<TraceRecord>> = None;
    let pattern = match args.get_str("pattern", "poisson") {
        "poisson" => ArrivalPattern::Poisson { rate_rps: rate },
        "burst" => ArrivalPattern::Burst {
            rate_rps: rate,
            burst_factor: args.get_f64("burst-factor", 4.0)?,
            mean_burst_s: args.get_f64("mean-burst", 0.5)?,
            mean_calm_s: args.get_f64("mean-calm", 2.0)?,
        },
        "replay" => {
            let path = args.get("trace").ok_or_else(|| {
                anyhow!(
                    "--pattern replay needs --trace <file> (legacy arrival offsets \
                     or a `platinum serve --capture` trace)"
                )
            })?;
            let text = std::fs::read_to_string(path)
                .map_err(|e| anyhow!("cannot read trace {path:?}: {e}"))?;
            let recs = parse_trace_records(&text)?;
            if recs.iter().all(|r| r.prompt_tokens.is_some()) {
                replay_records = Some(recs.clone());
            }
            ArrivalPattern::Replay { times_s: recs.iter().map(|r| r.arrival_s).collect() }
        }
        other => bail!("unknown --pattern {other:?}; valid patterns: poisson, burst, replay"),
    };
    let spec = LoadSpec {
        pattern,
        prompt: LenDist::parse(args.get_str("prompt-tokens", "32"))?,
        output: LenDist::parse(args.get_str("output-tokens", "16"))?,
        requests: args.get_usize("requests", 128)?,
        seed: args.get_usize("seed", 0)? as u64,
    };
    let shared_prefix = args.get_usize("shared-prefix", 0)?;
    let plan = fault_plan_from_args(args)?;
    let cfg = scheduler_config_from_args(args)?;
    let mut requests = match &replay_records {
        Some(recs) => {
            let n = match args.get("requests") {
                Some(_) => args.get_usize("requests", 0)?.min(recs.len()),
                None => recs.len(),
            };
            let mut recs = recs[..n].to_vec();
            recs.sort_by(|a, b| a.arrival_s.total_cmp(&b.arrival_s));
            recs.iter()
                .enumerate()
                .map(|(i, r)| TrafficRequest {
                    id: i as u64,
                    arrival_s: r.arrival_s,
                    prompt_tokens: r.prompt_tokens.unwrap_or(1),
                    output_tokens: r.output_tokens.unwrap_or(1),
                    shared_prefix_tokens: r.shared_prefix_tokens,
                    deadline_s: r.deadline_s,
                    class: r.class,
                })
                .collect()
        }
        None => spec.generate()?,
    };
    with_shared_prefix(&mut requests, shared_prefix);
    // applied post-generation like `with_shared_prefix`, from its own
    // seeded stream, so a tenant mix never perturbs arrivals or shapes
    let mix = tenant_mix_from_args(args)?;
    if let Some(mix) = &mix {
        mix.assign(&mut requests, spec.seed);
    }
    let mut clock: Box<dyn Clock> = match args.get_str("clock", "virtual") {
        "virtual" => Box::new(VirtualClock::new()),
        "wall" => Box::new(WallClock::new()),
        other => bail!("unknown --clock {other:?}; valid clocks: virtual, wall"),
    };
    let sched = Scheduler::new(backend.as_ref(), *model, cfg);
    let mut result = sched.serve_faults(&requests, clock.as_mut(), None, &plan)?;
    // p99-under-fault deltas need a fault-free baseline of the same
    // trace; only worth the second pass on the virtual clock (a wall
    // run would double real time)
    if result.metrics.resilience.is_some() && args.get_str("clock", "virtual") == "virtual" {
        let base_cfg =
            SchedulerConfig { resilience: ResilienceConfig::default(), ..cfg };
        let base = Scheduler::new(backend.as_ref(), *model, base_cfg)
            .serve(&requests, &mut VirtualClock::new())?;
        let ttft = result.metrics.ttft.quantile(0.99).zip(base.metrics.ttft.quantile(0.99));
        let e2e = result.metrics.e2e.quantile(0.99).zip(base.metrics.e2e.quantile(0.99));
        if let Some(res) = result.metrics.resilience.as_mut() {
            res.p99_ttft_delta_s = ttft.map(|(f, b)| f - b);
            res.p99_e2e_delta_s = e2e.map(|(f, b)| f - b);
        }
    }
    let m = &result.metrics;
    if args.flag("json") {
        let mut config = vec![
            ("backend", s(backend.id())),
            ("model", s(model.name)),
            ("pattern", s(spec.pattern.label())),
            // for replay traces the --rate flag is ignored, so
            // report the rate the pattern actually offers
            ("rate_rps", num(spec.pattern.rate_rps())),
            ("requests", num(requests.len() as f64)),
            ("seed", num(spec.seed as f64)),
            ("prompt_tokens", s(&spec.prompt.label())),
            ("output_tokens", s(&spec.output.label())),
            ("clock", s(clock.label())),
            ("max_batch", num(cfg.max_batch as f64)),
            ("max_queue", num(cfg.max_queue as f64)),
            ("max_inflight_tokens", num(cfg.max_inflight_tokens as f64)),
            ("max_prefill_tokens", num(cfg.max_prefill_tokens as f64)),
            ("kv_block_tokens", num(cfg.kv.block_tokens as f64)),
            ("kv_sram_kib", num(cfg.kv.sram_kib as f64)),
            ("kv_dram_mib", num(cfg.kv.dram_mib as f64)),
            ("kv_policy", s(cfg.kv.policy.label())),
            ("kv_prefix_cache", s(if cfg.kv.prefix_cache { "on" } else { "off" })),
            ("dram_model", s(cfg.kv.dram_model.label())),
            ("shared_prefix_tokens", num(shared_prefix as f64)),
        ];
        // only when the flags are set, so single-tenant unchunked
        // output stays byte-identical to the pre-class era
        if let Some(mix) = &mix {
            config.push(("tenants", s(&mix.label())));
        }
        if cfg.prefill_chunk > 0 {
            config.push(("prefill_chunk", num(cfg.prefill_chunk as f64)));
        }
        // only when the resilience section exists, so fault-free output
        // stays byte-identical to the pre-fault era
        if m.resilience.is_some() {
            config.push(("faults", s(&plan.label())));
            config.push((
                "deadline_ms",
                cfg.resilience.deadline_s.map(|d| num(d * 1e3)).unwrap_or(Json::Null),
            ));
            config.push(("retries", num(cfg.resilience.max_retries as f64)));
            config.push(("retry_base_ms", num(cfg.resilience.retry_base_s * 1e3)));
            config.push(("retry_cap_ms", num(cfg.resilience.retry_cap_s * 1e3)));
            config.push(("brownout_queue", num(cfg.resilience.brownout_queue as f64)));
            config.push(("brownout_slack_ms", num(cfg.resilience.brownout_slack_s * 1e3)));
            // only when per-class overrides exist, so global-slack runs
            // stay byte-identical to the pre-override era
            if cfg.resilience.brownout_slack_class.iter().any(Option::is_some) {
                let per_class: Vec<Json> = cfg
                    .resilience
                    .brownout_slack_class
                    .iter()
                    .map(|o| o.map(|v| num(v * 1e3)).unwrap_or(Json::Null))
                    .collect();
                config.push(("brownout_slack_class_ms", arr(per_class)));
            }
        }
        let doc = obj(vec![
            ("bench", s("serve-bench")),
            ("config", obj(config)),
            ("metrics", m.to_json()),
        ]);
        println!("{}", doc.to_string());
    } else {
        let q = |h: &platinum::traffic::Histogram| {
            let f = |v: Option<f64>| {
                v.map(|x| format!("{:>10.4}", x * 1e3)).unwrap_or_else(|| format!("{:>10}", "-"))
            };
            format!(
                "p50 {} ms  p95 {} ms  p99 {} ms  (n={})",
                f(h.quantile(0.50)),
                f(h.quantile(0.95)),
                f(h.quantile(0.99)),
                h.count()
            )
        };
        println!(
            "== serve-bench: {} requests, {} @ {:.1} rps on {} ({} clock) ==",
            requests.len(),
            spec.pattern.label(),
            spec.pattern.rate_rps(),
            backend.id(),
            clock.label()
        );
        println!(
            "  offered {}  admitted {}  rejected {}  completed {}",
            m.offered, m.admitted, m.rejected, m.completed
        );
        println!(
            "  steps: {} prefill + {} decode, mean decode batch {:.2}, \
             queue depth mean {:.2} / max {}",
            m.prefill_steps,
            m.decode_steps,
            m.mean_decode_batch(),
            m.mean_queue_depth(),
            m.queue_depth_max
        );
        let hit = m
            .kv
            .prefix_hit_rate()
            .map(|r| format!("{:.0}%", r * 100.0))
            .unwrap_or_else(|| "-".to_string());
        println!(
            "  kv: peak {}/{} blocks × {} tok ({} policy, {} dram), \
             prefix hits {}, evictions {}, swap stall {:.3} ms",
            m.kv.allocated_max,
            m.kv.capacity_blocks,
            m.kv.block_tokens,
            cfg.kv.policy.label(),
            m.kv.dram_model,
            hit,
            m.kv.evictions,
            m.kv.swap_stall_s * 1e3
        );
        println!("  TTFT        {}", q(&m.ttft));
        println!("  TPOT        {}", q(&m.tpot));
        println!("  E2E         {}", q(&m.e2e));
        println!("  queue wait  {}", q(&m.queue_wait));
        if let Some(classes) = &m.classes {
            for (i, c) in classes.iter().enumerate() {
                let name = mix
                    .as_ref()
                    .and_then(|mx| mx.classes.get(i))
                    .map(|t| t.name.clone())
                    .unwrap_or_else(|| format!("class{i}"));
                println!(
                    "  [{name:<12}] offered {:>5}  completed {:>5}  shed {:>4}  TTFT {}",
                    c.offered,
                    c.completed,
                    c.shed,
                    q(&c.ttft)
                );
            }
        }
        if let Some(res) = &m.resilience {
            println!(
                "  resilience: availability {:.4}  timeouts {}  retries {}  shed {}  \
                 failovers {}  step failures {}",
                res.availability,
                res.timeouts,
                res.retries,
                res.shed,
                res.failovers,
                res.step_failures
            );
            println!(
                "  faults: stragglers {}  linkdeg {}  swap failures {}  crashes {}  \
                 extra {:.3} ms  redistribution {:.3} ms",
                res.straggler_hits,
                res.linkdeg_hits,
                res.swap_failures,
                res.crashed_replicas,
                res.fault_extra_s * 1e3,
                res.redistribution_s * 1e3
            );
        }
        let completed_rps =
            if m.makespan_s > 0.0 { m.completed as f64 / m.makespan_s } else { 0.0 };
        println!(
            "  goodput {:.1} tok/s  completed {:.2} req/s  utilization {:.1}%  \
             makespan {:.3} s",
            m.goodput_tokens_per_s(),
            completed_rps,
            m.utilization() * 100.0,
            m.makespan_s
        );
    }
    Ok(())
}

fn cmd_runtime(args: &cli::Args) -> Result<()> {
    let dir = std::path::PathBuf::from(args.get_str("artifacts", "artifacts"));
    let mut rt = Runtime::new(&dir)?;
    println!("PJRT platform: {}", rt.platform());
    println!("artifacts:");
    for a in &rt.manifest().artifacts {
        println!("  {:<28} inputs: {}  output: {:?}", a.name, a.inputs.len(), a.outputs[0].shape);
    }
    if let Some(name) = args.get("run").map(String::from) {
        let spec = rt
            .manifest()
            .find(&name)
            .ok_or_else(|| anyhow!("artifact {name:?} not found"))?
            .clone();
        let inputs: Vec<HostTensor> = spec
            .inputs
            .iter()
            .map(|t| match t.dtype {
                platinum::runtime::DType::I32 => HostTensor::I32(vec![0; t.elements()]),
                platinum::runtime::DType::F32 => HostTensor::F32(vec![0.0; t.elements()]),
            })
            .collect();
        let t0 = std::time::Instant::now();
        let out = rt.execute(&name, &inputs)?;
        println!("ran {name} in {:?}; output elems {}", t0.elapsed(), out.len());
    }
    Ok(())
}
