//! `platinum` CLI — the leader entrypoint of the L3 coordinator.
//!
//! Subcommands:
//!   simulate   — cycle-accurate simulation of a kernel or model pass
//!   report     — area / power / utilization breakdowns (E5, E6, E11)
//!   dse        — the Fig 7 tiling sweep
//!   paths      — generate + inspect offline build paths (ISA dump)
//!   baselines  — Table I throughput comparison
//!   runtime    — list / smoke-run the PJRT artifacts

use anyhow::{anyhow, bail, Result};
use platinum::analysis::Gemm;
use platinum::baselines::{eyeriss, model_report, prosperity, tmac};
use platinum::config::{ExecMode, PlatinumConfig, Tiling};
use platinum::energy::{AreaModel, EnergyTable};
use platinum::models::{ALL_MODELS, B158_3B, DECODE_N, PREFILL_N};
use platinum::runtime::{HostTensor, Runtime};
use platinum::sim::{simulate_gemm, simulate_model};
use platinum::util::cli;
use platinum::{dse, encoding, isa, pathgen};

fn main() -> Result<()> {
    let args = cli::parse(std::env::args().skip(1))?;
    match args.command.as_deref() {
        Some("simulate") => cmd_simulate(&args),
        Some("report") => cmd_report(&args),
        Some("dse") => cmd_dse(&args),
        Some("paths") => cmd_paths(&args),
        Some("baselines") => cmd_baselines(&args),
        Some("runtime") => cmd_runtime(&args),
        Some(other) => bail!("unknown command {other:?}; run without args for help"),
        None => {
            print_help();
            Ok(())
        }
    }
}

fn print_help() {
    println!(
        "platinum — path-adaptable LUT-based accelerator (paper reproduction)\n\
         \n\
         USAGE: platinum <command> [flags]\n\
         \n\
         COMMANDS:\n\
           simulate   --model {{700m|1.3b|3b}} --n <batch·seq> [--mode ternary|bitserial]\n\
                      or --m --k --n for a single kernel\n\
           report     --area --power --util   breakdowns vs paper §V-B\n\
           dse        [--full]                Fig 7 tiling sweep\n\
           paths      [--kind ternary|binary] [--c <chunk>] [--dump] ISA dump\n\
           baselines  Table I comparison on b1.58-3B\n\
           runtime    [--artifacts <dir>] [--run <name>] PJRT artifacts"
    );
}

fn model_by_name(name: &str) -> Result<&'static platinum::models::BitNetModel> {
    let lname = name.to_ascii_lowercase();
    ALL_MODELS
        .iter()
        .find(|m| {
            m.params.eq_ignore_ascii_case(&lname)
                || m.name.eq_ignore_ascii_case(&lname)
                || (lname == "3b" && m.params == "3B")
                || (lname == "700m" && m.params == "700M")
                || (lname == "1.3b" && m.params == "1.3B")
        })
        .ok_or_else(|| anyhow!("unknown model {name:?} (700m, 1.3b, 3b)"))
}

fn mode_from(args: &cli::Args) -> ExecMode {
    match args.get_str("mode", "ternary") {
        "bitserial" => ExecMode::BitSerial { planes: 2 },
        _ => ExecMode::Ternary,
    }
}

fn cmd_simulate(args: &cli::Args) -> Result<()> {
    let cfg = PlatinumConfig::default();
    let mode = mode_from(args);
    if let Some(mname) = args.get("model") {
        let model = model_by_name(mname)?;
        let n = args.get_usize("n", PREFILL_N)?;
        let r = simulate_model(&cfg, mode, model, n);
        println!(
            "model {} ({} params)  N={n}  mode={}",
            model.name,
            model.params,
            mode.label()
        );
        print_sim(&r, model.total_naive_adds(n));
    } else {
        let m = args.get_usize("m", 3200)?;
        let k = args.get_usize("k", 3200)?;
        let n = args.get_usize("n", PREFILL_N)?;
        let g = Gemm::new(m, k, n);
        let r = simulate_gemm(&cfg, mode, g);
        println!("kernel {m}x{k}x{n}  mode={}", mode.label());
        print_sim(&r, g.naive_adds());
    }
    Ok(())
}

fn print_sim(r: &platinum::sim::SimReport, ops: u64) {
    println!("  cycles       {:>14}", r.cycles);
    println!("  latency      {:>14.6} s", r.latency_s);
    println!("  throughput   {:>14.1} GOP/s (naive-adds)", r.throughput_gops);
    println!("  energy       {:>14.4} J", r.energy_j());
    println!("  power        {:>14.2} W", r.power_w());
    println!("  ops          {:>14}", ops);
    println!(
        "  phases: construct {} query {} drain {} dram-stall {}",
        r.phases.construct, r.phases.query, r.phases.drain, r.phases.dram_stall
    );
    println!(
        "  util: adders {:.1}%  lut-ports {:.1}%  dram {:.1}%",
        r.utilization.adders * 100.0,
        r.utilization.lut_ports * 100.0,
        r.utilization.dram_bw * 100.0
    );
}

fn cmd_report(args: &cli::Args) -> Result<()> {
    let cfg = PlatinumConfig::default();
    let all = !(args.flag("area") || args.flag("power") || args.flag("util"));
    if args.flag("area") || all {
        let b = AreaModel::platinum(&cfg).breakdown();
        let t = b.total();
        println!("== area breakdown (paper §V-B: 0.955 mm²; buffers 65%, +LUT 83.3%, compute 15%) ==");
        println!("  weight buffer   {:>7.4} mm²  {:>5.1}%", b.weight_buf, 100.0 * b.weight_buf / t);
        println!("  input buffer    {:>7.4} mm²  {:>5.1}%", b.input_buf, 100.0 * b.input_buf / t);
        println!("  output buffer   {:>7.4} mm²  {:>5.1}%", b.output_buf, 100.0 * b.output_buf / t);
        println!("  path buffer     {:>7.4} mm²  {:>5.1}%", b.path_buf, 100.0 * b.path_buf / t);
        println!("  LUT buffers     {:>7.4} mm²  {:>5.1}%", b.lut_bufs, 100.0 * b.lut_bufs / t);
        println!("  PPEs            {:>7.4} mm²  {:>5.1}%", b.ppes, 100.0 * b.ppes / t);
        println!("  aggregator      {:>7.4} mm²  {:>5.1}%", b.aggregator, 100.0 * b.aggregator / t);
        println!("  SFU             {:>7.4} mm²  {:>5.1}%", b.sfu, 100.0 * b.sfu / t);
        println!("  TOTAL           {t:>7.4} mm²   (paper: 0.955)");
        println!(
            "  data buffers {:.1}%  +LUT {:.1}%  compute {:.1}%",
            100.0 * b.data_buffers() / t,
            100.0 * (b.data_buffers() + b.lut_bufs) / t,
            100.0 * (b.ppes + b.aggregator) / t
        );
    }
    if args.flag("power") || all {
        let r = simulate_model(&cfg, ExecMode::Ternary, &B158_3B, PREFILL_N);
        let e = r.energy;
        let t = e.total();
        println!("== power breakdown, b1.58-3B prefill (paper §V-B: 3.2 W; DRAM 53.5%, wbuf 31.6%) ==");
        println!("  total power     {:>7.2} W", r.power_w());
        println!("  DRAM            {:>5.1}%", 100.0 * e.dram / t);
        println!("  weight buffer   {:>5.1}%", 100.0 * e.weight_buf / t);
        println!("  LUT buffers     {:>5.1}%", 100.0 * e.lut_buf / t);
        println!("  output buffer   {:>5.1}%", 100.0 * e.output_buf / t);
        println!("  input buffer    {:>5.1}%", 100.0 * e.input_buf / t);
        println!("  adders          {:>5.1}%", 100.0 * e.adders / t);
        println!("  static          {:>5.1}%", 100.0 * e.static_leak / t);
        let etab = EnergyTable::from_area(&AreaModel::platinum(&cfg));
        println!(
            "  (model: wbuf {:.1} pJ/B, LUT {:.1} pJ/B, DRAM {:.0} pJ/bit)",
            etab.wbuf_read_pj_per_byte, etab.lut_read_pj_per_byte, etab.dram_pj_per_bit
        );
    }
    if args.flag("util") || all {
        let g = Gemm::new(1080, 520, 32);
        let r = simulate_gemm(&cfg, ExecMode::Ternary, g);
        println!("== utilization, steady-state tile (paper §IV-B: adders 90.5%, LUT ports ~100%) ==");
        println!("  adders          {:>5.1}%", 100.0 * r.utilization.adders);
        println!("  LUT ports       {:>5.1}%", 100.0 * r.utilization.lut_ports);
    }
    Ok(())
}

fn cmd_dse(args: &cli::Args) -> Result<()> {
    let grid = dse::default_grid();
    let models: Vec<platinum::models::BitNetModel> =
        if args.flag("full") { ALL_MODELS.to_vec() } else { vec![B158_3B] };
    let pts = dse::sweep(&grid, &models);
    let front = dse::pareto(&pts);
    println!("== Fig 7 DSE: {} points, {} on the Pareto frontier ==", pts.len(), front.len());
    println!(
        "{:<22} {:>12} {:>12} {:>9} {:>9}  pareto",
        "tiling", "latency(s)", "energy(J)", "mm²", "KB"
    );
    for (i, p) in pts.iter().enumerate() {
        let tag = format!("m{} k{} n{} {}", p.tiling.m, p.tiling.k, p.tiling.n, p.tiling.order.label());
        let chosen = p.tiling == Tiling::default();
        println!(
            "{:<22} {:>12.4} {:>12.3} {:>9.3} {:>9.0}  {}{}",
            tag,
            p.latency_s,
            p.energy_j,
            p.area_mm2,
            p.sram_kb,
            if front.contains(&i) { "*" } else { "" },
            if chosen { "  <-- paper's choice" } else { "" }
        );
    }
    Ok(())
}

fn cmd_paths(args: &cli::Args) -> Result<()> {
    let kind = args.get_str("kind", "ternary");
    let path = match kind {
        "ternary" => pathgen::ternary_path(args.get_usize("c", encoding::TERNARY_C)?),
        "binary" => pathgen::binary_path(args.get_usize("c", encoding::BINARY_C)?),
        other => bail!("unknown path kind {other:?}"),
    };
    println!(
        "{kind} path c={}: {} entries, min RAW distance {} (pipeline depth {}), hazard-free: {}",
        path.c,
        path.entries.len(),
        path.min_raw_distance,
        pathgen::PIPELINE_DEPTH,
        path.hazard_free()
    );
    if args.flag("dump") {
        for (i, e) in path.entries.iter().enumerate() {
            println!(
                "{i:4}: LUT[{:3}] = LUT[{:3}] {} a[{}]   (word {:#010x})",
                e.dst,
                e.src,
                if e.sign { "-" } else { "+" },
                e.j,
                isa::encode_entry(e)
            );
        }
        println!("FINISH {:#010x}", isa::FINISH);
    }
    Ok(())
}

fn cmd_baselines(_args: &cli::Args) -> Result<()> {
    let cfg = PlatinumConfig::default();
    println!("== Table I reproduction: b1.58-3B, prefill N={PREFILL_N} / decode N={DECODE_N} ==");
    println!(
        "{:<16} {:>8} {:>8} {:>14} {:>14}",
        "system", "PEs", "mm²", "prefill GOP/s", "decode GOP/s"
    );
    let plat_p = simulate_model(&cfg, ExecMode::Ternary, &B158_3B, PREFILL_N);
    let plat_d = simulate_model(&cfg, ExecMode::Ternary, &B158_3B, DECODE_N);
    let area = AreaModel::platinum(&cfg).breakdown().total();
    let eye_p = model_report(&B158_3B, PREFILL_N, |g| eyeriss::simulate(g, PREFILL_N));
    let eye_d = model_report(&B158_3B, DECODE_N, |g| eyeriss::simulate(g, DECODE_N));
    let pro_p = model_report(&B158_3B, PREFILL_N, |g| prosperity::simulate(g, PREFILL_N));
    let pro_d = model_report(&B158_3B, DECODE_N, |g| prosperity::simulate(g, DECODE_N));
    let tm_p = model_report(&B158_3B, PREFILL_N, |g| tmac::simulate_m2pro(g));
    let tm_d = model_report(&B158_3B, DECODE_N, |g| tmac::simulate_m2pro(g));
    println!("{:<16} {:>8} {:>8.3} {:>14.1} {:>14.1}", "SpikingEyeriss", 168, 1.07, eye_p.throughput_gops, eye_d.throughput_gops);
    println!("{:<16} {:>8} {:>8.3} {:>14.1} {:>14.1}", "Prosperity", 256, 1.06, pro_p.throughput_gops, pro_d.throughput_gops);
    println!("{:<16} {:>8} {:>8} {:>14.1} {:>14.1}", "T-MAC (M2 Pro)", "-", "289", tm_p.throughput_gops, tm_d.throughput_gops);
    println!("{:<16} {:>8} {:>8.3} {:>14.1} {:>14.1}", "Platinum", cfg.num_pes(), area, plat_p.throughput_gops, plat_d.throughput_gops);
    println!("(paper Table I: Eyeriss 20.8, Prosperity 375, T-MAC 715, Platinum 1534 GOP/s prefill)");
    Ok(())
}

fn cmd_runtime(args: &cli::Args) -> Result<()> {
    let dir = std::path::PathBuf::from(args.get_str("artifacts", "artifacts"));
    let mut rt = Runtime::new(&dir)?;
    println!("PJRT platform: {}", rt.platform());
    println!("artifacts:");
    for a in &rt.manifest().artifacts {
        println!("  {:<28} inputs: {}  output: {:?}", a.name, a.inputs.len(), a.outputs[0].shape);
    }
    if let Some(name) = args.get("run").map(String::from) {
        let spec = rt
            .manifest()
            .find(&name)
            .ok_or_else(|| anyhow!("artifact {name:?} not found"))?
            .clone();
        let inputs: Vec<HostTensor> = spec
            .inputs
            .iter()
            .map(|t| match t.dtype {
                platinum::runtime::DType::I32 => HostTensor::I32(vec![0; t.elements()]),
                platinum::runtime::DType::F32 => HostTensor::F32(vec![0.0; t.elements()]),
            })
            .collect();
        let t0 = std::time::Instant::now();
        let out = rt.execute(&name, &inputs)?;
        println!("ran {name} in {:?}; output elems {}", t0.elapsed(), out.len());
    }
    Ok(())
}
