#!/usr/bin/env python3
"""End-to-end smoke for `platinum serve` (CI job: daemon-smoke).

Stdlib only — no requests/pytest — so the job needs nothing beyond a
Python interpreter and the release binary:

  python3 python/tools/daemon_smoke.py rust/target/release/platinum

What it pins, in order:

1. the daemon comes up and answers `/health`;
2. 32 concurrent `POST /v1/generate` requests (half carrying an
   `X-Deadline-Ms` header, the other half an `X-Tenant-Class: batch`
   header, a quarter a shared prompt prefix) each stream chunked
   ndjson token lines ending in a `{"done":true,"outcome":"completed"}`
   record whose token count matches the streamed lines;
3. `/metrics` parses, counts all 32 completions, and reports a finite
   positive p99 TTFT;
4. SIGTERM drains and the process exits 0, writing the capture trace
   and the final metrics JSON;
5. the capture holds exactly 32 records — the tenant class surviving
   as the capture-v1 sixth column on exactly the `batch` half — and
   feeding it back through `serve-bench --pattern replay --clock
   virtual` is byte-identical across repeat runs *and* across
   worker-pool sizes — the replay determinism contract.
"""

import http.client
import json
import math
import os
import signal
import socket
import subprocess
import sys
import tempfile
import threading
import time

REQUESTS = 32
PROMPT_TOKENS = 16
OUTPUT_TOKENS = 8
SHARED_PREFIX_TOKENS = 8  # sent by every 4th request (idx % 4 == 0)


def free_port():
    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


def wait_health(port, proc, deadline_s=60.0):
    t0 = time.monotonic()
    while time.monotonic() - t0 < deadline_s:
        if proc.poll() is not None:
            raise SystemExit("daemon exited before becoming healthy: rc=%d" % proc.returncode)
        try:
            conn = http.client.HTTPConnection("127.0.0.1", port, timeout=2)
            conn.request("GET", "/health")
            resp = conn.getresponse()
            body = json.loads(resp.read())
            conn.close()
            if resp.status == 200 and body.get("status") == "ok":
                return body
        except (OSError, ValueError):
            time.sleep(0.05)
    raise SystemExit("daemon did not become healthy within %gs" % deadline_s)


def one_generate(port, idx, results):
    try:
        conn = http.client.HTTPConnection("127.0.0.1", port, timeout=60)
        headers = {"Content-Type": "application/json"}
        if idx % 2 == 0:
            headers["X-Deadline-Ms"] = "10000"
        else:
            headers["X-Tenant-Class"] = "batch"
        req = {"prompt_tokens": PROMPT_TOKENS, "output_tokens": OUTPUT_TOKENS}
        if idx % 4 == 0:
            req["shared_prefix_tokens"] = SHARED_PREFIX_TOKENS
        body = json.dumps(req)
        conn.request("POST", "/v1/generate", body=body, headers=headers)
        resp = conn.getresponse()
        if resp.status != 200:
            raise AssertionError("status %d: %r" % (resp.status, resp.read(4096)))
        if resp.getheader("Transfer-Encoding") != "chunked":
            raise AssertionError("expected a chunked stream, got %r" % dict(resp.getheaders()))
        lines = [json.loads(l) for l in resp.read().decode().splitlines() if l]
        conn.close()
        done = lines[-1]
        tokens = [l for l in lines[:-1] if "token" in l]
        assert done.get("done") is True, done
        assert done.get("outcome") == "completed", done
        assert len(tokens) >= 1, lines
        assert done.get("tokens") == len(tokens), (done, len(tokens))
        results[idx] = None
    except Exception as e:  # noqa: BLE001 — collected and reported per request
        results[idx] = "%s: %s" % (type(e).__name__, e)


def fetch_metrics(port):
    conn = http.client.HTTPConnection("127.0.0.1", port, timeout=5)
    conn.request("GET", "/metrics")
    resp = conn.getresponse()
    assert resp.status == 200, resp.status
    doc = json.loads(resp.read())
    conn.close()
    return doc


def run_replay(binary, trace, threads):
    env = dict(os.environ, PLATINUM_THREADS=str(threads))
    out = subprocess.run(
        [
            binary, "serve-bench",
            "--backend", "platinum-ternary", "--model", "700m",
            "--pattern", "replay", "--trace", trace,
            "--max-batch", "8", "--clock", "virtual", "--json",
        ],
        env=env, capture_output=True, timeout=300, check=True,
    )
    return out.stdout


def main():
    if len(sys.argv) != 2:
        raise SystemExit("usage: daemon_smoke.py <path-to-platinum-binary>")
    binary = os.path.abspath(sys.argv[1])
    port = free_port()
    workdir = tempfile.mkdtemp(prefix="daemon-smoke-")
    capture = os.path.join(workdir, "capture.trace")
    metrics_out = os.path.join(workdir, "serve_metrics.json")

    env = dict(os.environ, PLATINUM_THREADS="4")
    proc = subprocess.Popen(
        [
            binary, "serve",
            "--addr", "127.0.0.1:%d" % port,
            "--backend", "platinum-ternary", "--model", "700m",
            "--max-conns", "64",
            "--capture", capture,
            "--metrics-out", metrics_out,
        ],
        env=env,
    )
    try:
        wait_health(port, proc)
        print("daemon-smoke: healthy on port %d" % port)

        results = ["did not finish within the join timeout"] * REQUESTS
        threads = [
            threading.Thread(target=one_generate, args=(port, i, results))
            for i in range(REQUESTS)
        ]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=120)
        failures = [(i, r) for i, r in enumerate(results) if r is not None]
        assert not failures, "generate failures: %s" % failures
        print("daemon-smoke: %d concurrent streams completed" % REQUESTS)

        m = fetch_metrics(port)
        counts = m["serve"]["counts"]
        assert counts["completed"] == REQUESTS, counts
        assert counts["active"] == 0, counts
        p99 = m["serve"]["latency_s"]["ttft"]["p99"]
        assert isinstance(p99, (int, float)) and math.isfinite(p99) and p99 > 0, p99
        print("daemon-smoke: /metrics ok, p99 TTFT %.6f s" % p99)
    except BaseException:
        proc.kill()
        proc.wait()
        raise

    # graceful shutdown: SIGTERM must drain and exit 0
    proc.send_signal(signal.SIGTERM)
    rc = proc.wait(timeout=30)
    assert rc == 0, "daemon exited %d on SIGTERM" % rc
    print("daemon-smoke: SIGTERM drained, exit 0")

    final = json.load(open(metrics_out))
    assert final["serve"]["counts"]["completed"] == REQUESTS, final["serve"]["counts"]

    records = [
        l.split() for l in open(capture).read().splitlines()
        if l.strip() and not l.startswith("#")
    ]
    assert len(records) == REQUESTS, "capture has %d records, want %d" % (len(records), REQUESTS)
    # capture-v1 line: arrival_s prompt output deadline_ms|- shared_prefix
    # [class] — the class column is written only when nonzero, so the
    # default-class half stays in the 5-field shape older tools expect
    assert all(len(r) in (5, 6) for r in records), records
    with_deadline = [r for r in records if r[3] != "-"]
    assert len(with_deadline) == REQUESTS // 2, records
    with_shared = [r for r in records if r[4] == str(SHARED_PREFIX_TOKENS)]
    assert len(with_shared) == REQUESTS // 4, records
    assert all(r[4] in ("0", str(SHARED_PREFIX_TOKENS)) for r in records), records
    with_class = [r for r in records if len(r) == 6]
    assert len(with_class) == REQUESTS // 2, records
    assert all(r[5] == "1" for r in with_class), with_class
    # X-Tenant-Class went to the non-deadline half, so no overlap
    assert all(r[3] == "-" for r in with_class), with_class
    print("daemon-smoke: capture holds %d records (%d with deadlines, %d with shared "
          "prefixes, %d with tenant classes)"
          % (len(records), len(with_deadline), len(with_shared), len(with_class)))

    # replay determinism: byte-identical across runs and pool sizes
    a = run_replay(binary, capture, threads=1)
    b = run_replay(binary, capture, threads=1)
    c = run_replay(binary, capture, threads=4)
    assert a == b, "replay is not deterministic across runs"
    assert a == c, "replay metrics depend on the worker-pool size"
    doc = json.loads(a)
    assert doc["metrics"]["counts"]["completed"] == REQUESTS, doc["metrics"]["counts"]
    # the tenant class survived capture → replay: the batch half drives
    # the per-class metrics section on the replay side
    classes = doc["metrics"]["classes"]
    assert len(classes) == 2, classes
    per_class = [c["counts"]["completed"] for c in classes]
    assert per_class == [REQUESTS // 2, REQUESTS // 2], per_class
    print("daemon-smoke: replay byte-identical across runs and pool sizes 1/4, "
          "classes %s" % per_class)
    print("daemon-smoke: OK")


if __name__ == "__main__":
    main()
